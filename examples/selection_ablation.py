"""Ablation example (paper Figs. 3/6): profiling methods and init schemes.

    PYTHONPATH=src REPRO_BENCH_SCALE=tiny python examples/selection_ablation.py
"""

from benchmarks import fig3_profiling, fig45_init_invariance, fig6_init_robustness


def main():
    print("-- Fig. 4/5: kernel init-invariance --")
    r = fig45_init_invariance.run()
    print(f"kernel corr across inits: {r['kernel_corr']:.3f} "
          f"(profiles only: {r['profile_corr']:.3f})")
    print("-- Fig. 3: profiling ablation --")
    fig3_profiling.run()
    print("-- Fig. 6: init robustness --")
    fig6_init_robustness.run()


if __name__ == "__main__":
    main()

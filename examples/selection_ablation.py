"""Selection-strategy ablation on one non-IID federation, engine-native.

Every registry strategy with a pure ``select_fn`` — the paper's k-DPP
(sampled + greedy-MAP), FedAvg uniform, FedSAE loss-weighted, clustered
sampling, power-of-choice — runs on the SAME federation through the scanned
engine (DESIGN.md §7): one multi-strategy ``round_fn`` dispatched by
``lax.switch``, all strategies × seeds as one ``run_many`` grid, host-side
work (cluster fitting, the spectral cache) done once in
``init_server_state``.  Prints final accuracy / mean GEMD / rounds-to-target
per strategy.

    PYTHONPATH=src python examples/selection_ablation.py [--rounds 30]
"""

import argparse

import jax
import numpy as np

from repro.core import make_strategy
from repro.data import make_image_dataset, skewness_partition
from repro.fl import engine
from repro.fl.engine import FLConfig
from repro.models import cnn

METHODS = (
    "fl-dp3s", "fl-dp3s-map", "fedavg", "fedsae", "cluster", "power-of-choice"
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=30)
    ap.add_argument("--clients", type=int, default=20)
    ap.add_argument("--per-round", type=int, default=4)
    ap.add_argument("--seeds", type=int, default=2)
    ap.add_argument("--xi", type=float, default=1.0)
    ap.add_argument("--target-acc", type=float, default=0.6)
    args = ap.parse_args()

    cfg = FLConfig(
        num_clients=args.clients, clients_per_round=args.per_round,
        rounds=args.rounds, local_epochs=2, lr=0.1, eval_every=2, seed=0,
    )
    ds = make_image_dataset(n=args.clients * 120, seed=0)
    shards = skewness_partition(
        ds.ys, args.clients, args.xi, ds.num_classes,
        samples_per_client=120, seed=0,
    )
    cxs = np.stack([ds.xs[s] for s in shards])
    cys = np.stack([ds.ys[s] for s in shards])

    strategies = tuple(make_strategy(m) for m in METHODS)
    states = []
    for seed in range(args.seeds):
        params = cnn.init_cnn(jax.random.key(seed))
        shared = None
        for i, strat in enumerate(strategies):
            state = engine.init_server_state(
                cfg, params, cnn.cnn_loss, cnn.apply_with_features, cxs, cys,
                strategy=strat, strategy_index=i,
                key=jax.random.key(100 * seed + i),
                profiles=shared.profiles if shared else None,
                kernel=shared.kernel if shared else None,
                losses=shared.losses if shared else None,
            )
            shared = shared or state
            states.append(state)

    round_fn = engine.make_round_fn(
        cfg, cnn.cnn_loss, strategies, accuracy_fn=cnn.accuracy
    )
    _, outs = engine.run_many(
        round_fn, engine.stack_states(states), args.rounds
    )
    per_run = engine.unstack_outputs(outs)

    print(f"{'strategy':>16s}  {'final acc':>9s}  {'mean GEMD':>9s}  "
          f"rounds to acc>={args.target_acc}")
    for i, name in enumerate(METHODS):
        arm = [per_run[seed * len(METHODS) + i] for seed in range(args.seeds)]
        accs, gemds, rtts = [], [], []
        for r in arm:
            hist = engine.history_from_outputs(r, cfg.eval_every)
            accs.append(hist["acc"][-1])
            gemds.append(float(np.mean(hist["gemd"])))
            hit = [t for t, a in zip(hist["round"], hist["acc"])
                   if a >= args.target_acc]
            rtts.append(hit[0] if hit else args.rounds)
        print(f"{name:>16s}  {np.mean(accs):9.4f}  {np.mean(gemds):9.3f}  "
              f"{np.mean(rtts):6.1f}")


if __name__ == "__main__":
    main()

"""Serving example: batched prefill + decode for several architectures,
including the O(1)-state SSM (rwkv6) and the hybrid (recurrentgemma).

    PYTHONPATH=src python examples/serve_batched.py
"""

import sys

from repro.launch import serve as serve_mod


def main():
    for arch in ("smollm-360m", "rwkv6-7b", "recurrentgemma-9b"):
        print(f"=== {arch} (reduced) ===")
        sys.argv = ["serve", "--arch", arch, "--batch", "2",
                    "--prompt-len", "16", "--gen", "16"]
        serve_mod.main()


if __name__ == "__main__":
    main()

"""Quickstart: FL-DP³S vs FedAvg on synthetic non-IID image data.

Runs the paper's Algorithm 1 at reduced scale (CPU-friendly) straight on the
scanned federation engine (DESIGN.md §7): both strategies share ONE
multi-strategy ``round_fn`` (``lax.switch`` on ``ServerState.strategy_index``)
and execute as a single ``run_many`` grid — one compiled XLA program for the
whole comparison, zero per-round host round-trips.

    PYTHONPATH=src python examples/quickstart.py [--rounds 40] [--xi 1.0]

Local updates are pluggable (DESIGN.md §12): swap FedAvg SGD for a
drift-corrected algorithm without touching the selection comparison, e.g.

    PYTHONPATH=src python examples/quickstart.py --local-algo fedprox --prox-mu 0.01
"""

import argparse

import jax
import numpy as np

from repro.core import make_strategy
from repro.data import make_image_dataset, skewness_partition
from repro.fl import engine, local_algos
from repro.fl.engine import FLConfig
from repro.models import cnn

METHODS = ("fl-dp3s", "fedavg")


def build_states(cfg, xi, strategies, data_seed=0):
    """One federation, one state per strategy (shared data/profiles/kernel;
    per-strategy spectral cache + strategy_index)."""
    ds = make_image_dataset(n=cfg.num_clients * 200, seed=data_seed)
    shards = skewness_partition(
        ds.ys, cfg.num_clients, xi, ds.num_classes,
        samples_per_client=200, seed=cfg.seed,
    )
    client_xs = np.stack([ds.xs[s] for s in shards])
    client_ys = np.stack([ds.ys[s] for s in shards])
    params = cnn.init_cnn(jax.random.key(cfg.seed))

    states = []
    for i, strat in enumerate(strategies):
        state = engine.init_server_state(
            cfg, params, cnn.cnn_loss, cnn.apply_with_features,
            client_xs, client_ys, strategy=strat, strategy_index=i,
            # shared Alg.-1 init: profiles/kernel/losses computed once by the
            # first strategy's state, reused by the rest
            profiles=states[0].profiles if states else None,
            kernel=states[0].kernel if states else None,
            losses=states[0].losses if states else None,
        )
        states.append(state)
    return states


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=40)
    ap.add_argument("--clients", type=int, default=30)
    ap.add_argument("--per-round", type=int, default=5)
    ap.add_argument("--xi", default="1.0")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--local-algo", default="fedavg",
                    choices=sorted(local_algos.ALGO_NAMES))
    ap.add_argument("--prox-mu", type=float, default=None)
    ap.add_argument("--feddyn-alpha", type=float, default=None)
    args = ap.parse_args()
    xi = args.xi if args.xi in ("H", "h") else float(args.xi)

    cfg = FLConfig(
        num_clients=args.clients,
        clients_per_round=args.per_round,
        rounds=args.rounds,
        local_epochs=2,
        lr=0.1,
        eval_every=5,
        seed=args.seed,
        local_algo=args.local_algo,
        prox_mu=args.prox_mu,
        feddyn_alpha=args.feddyn_alpha,
    )
    strategies = tuple(make_strategy(m) for m in METHODS)
    states = build_states(cfg, xi, strategies)

    # the whole strategy grid: ONE compiled scan program via run_many
    round_fn = engine.make_round_fn(
        cfg, cnn.cnn_loss, strategies, accuracy_fn=cnn.accuracy
    )
    final, outs = engine.run_many(
        round_fn, engine.stack_states(states), args.rounds
    )
    per_run = engine.unstack_outputs(outs)

    for i, name in enumerate(METHODS):
        final_acc = None
        if args.rounds % cfg.eval_every != 0:
            params_i = jax.tree_util.tree_map(lambda x, i=i: x[i], final.params)
            xs = states[i].client_xs.reshape((-1,) + states[i].client_xs.shape[2:])
            final_acc = float(
                cnn.accuracy(params_i, xs, states[i].client_ys.reshape(-1))
            )
        hist = engine.history_from_outputs(
            per_run[i], cfg.eval_every, final_acc=final_acc
        )
        for t, a, g, l in zip(hist["round"], hist["acc"], hist["gemd"], hist["loss"]):
            print(f"[{name}] round {t:4d} acc={a:.4f} gemd={g:.3f} loss={l:.4f}")
        mean_gemd = float(np.mean(hist["gemd"]))
        print(f"== {name}: final acc={hist['acc'][-1]:.4f}  mean GEMD={mean_gemd:.3f}\n")


if __name__ == "__main__":
    main()

"""Quickstart: FL-DP³S vs FedAvg on synthetic non-IID image data.

Runs the paper's Algorithm 1 at reduced scale (CPU-friendly) and prints the
accuracy / GEMD trajectories of both selection strategies.

    PYTHONPATH=src python examples/quickstart.py [--rounds 40] [--xi 1.0]
"""

import argparse

import jax
import numpy as np

from repro.core import make_strategy
from repro.data import make_image_dataset, skewness_partition
from repro.fl import FLConfig, FLTrainer
from repro.models import cnn


def build_trainer(cfg, xi, strategy_name, data_seed=0):
    ds = make_image_dataset(n=cfg.num_clients * 200, seed=data_seed)
    shards = skewness_partition(
        ds.ys, cfg.num_clients, xi, ds.num_classes,
        samples_per_client=200, seed=cfg.seed,
    )
    client_xs = np.stack([ds.xs[s] for s in shards])
    client_ys = np.stack([ds.ys[s] for s in shards])
    params = cnn.init_cnn(jax.random.key(cfg.seed))
    return FLTrainer(
        cfg,
        params,
        loss_fn=cnn.cnn_loss,
        feature_fn=cnn.apply_with_features,
        client_xs=client_xs,
        client_ys=client_ys,
        strategy=make_strategy(strategy_name),
        accuracy_fn=cnn.accuracy,
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=40)
    ap.add_argument("--clients", type=int, default=30)
    ap.add_argument("--per-round", type=int, default=5)
    ap.add_argument("--xi", default="1.0")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    xi = args.xi if args.xi in ("H", "h") else float(args.xi)

    for name in ("fl-dp3s", "fedavg"):
        cfg = FLConfig(
            num_clients=args.clients,
            clients_per_round=args.per_round,
            rounds=args.rounds,
            local_epochs=2,
            lr=0.1,
            eval_every=5,
            seed=args.seed,
        )
        trainer = build_trainer(cfg, xi, name)
        hist = trainer.run(progress=True)
        mean_gemd = float(np.mean(hist["gemd"]))
        print(f"== {name}: final acc={hist['acc'][-1]:.4f}  mean GEMD={mean_gemd:.3f}\n")


if __name__ == "__main__":
    main()

"""End-to-end driver example: federated LM training with DPP selection.

Trains a reduced smollm-family decoder across topic-skewed clients for a few
hundred rounds, comparing FL-DP³S vs FedAvg selection on the same corpora —
the LLM-scale version of the paper's experiment (profiles = mean pre-logits
hidden state, DESIGN.md §3).

    PYTHONPATH=src python examples/train_fl_llm.py --rounds 300
"""

import argparse
import sys

from repro.launch import train as train_mod


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=60)
    ap.add_argument("--arch", default="smollm-360m")
    args = ap.parse_args()

    for selection in ("fl-dp3s", "fedavg"):
        print(f"=== selection: {selection} ===")
        sys.argv = [
            "train", "--arch", args.arch, "--mode", "fl",
            "--selection", selection, "--rounds", str(args.rounds),
            "--clients", "10", "--per-round", "4", "--local-steps", "2",
            "--local-batch", "4", "--seq", "128", "--log-every", "10",
        ]
        train_mod.main()


if __name__ == "__main__":
    main()

"""The CI bench-regression gate: passes at parity, bites on slowdowns."""

import json
import os

import pytest

from benchmarks import check_regression as cr

DPP = {
    "host_cores": 8,
    "scanned_rounds_per_sec": {
        "16": {"baseline": 100.0, "cached": 400.0, "speedup": 4.0}
    },
}
SHARD = {
    "host_cores": 8,
    "by_devices": {"1": {"rounds_per_sec": 50.0},
                   "8": {"rounds_per_sec": 120.0}},
}


def _write(path, payload):
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(payload, f)


@pytest.fixture
def dirs(tmp_path):
    base = tmp_path / "baselines"
    cur = tmp_path / "current"
    for d in (base, cur):
        _write(str(d / "BENCH_dpp_smoke.json"), DPP)
        _write(str(d / "BENCH_shard_smoke.json"), SHARD)
    return str(cur), str(base)


def test_identical_metrics_pass(dirs):
    cur, base = dirs
    assert cr.check(cur, base, tolerance=0.25) == []


def test_small_regression_within_tolerance_passes(dirs):
    cur, base = dirs
    assert cr.check(cur, base, tolerance=0.25, scale=0.80) == []


def test_injected_slowdown_fails(dirs):
    cur, base = dirs
    failures = cr.check(cur, base, tolerance=0.25, scale=0.5)
    assert len(failures) == 4  # every throughput metric regressed
    assert all("<" in f for f in failures)


def test_speedup_never_fails(dirs):
    cur, base = dirs
    assert cr.check(cur, base, tolerance=0.25, scale=3.0) == []


def test_cross_hardware_skips_comparison(dirs, tmp_path):
    """Baselines from a different box never fail the gate: throughput does
    not transfer across core counts (ratios included — devN/dev1 scaling is
    ceilinged by cores, tiny-shape ratios are noise)."""
    cur = tmp_path / "cur2"
    slow = json.loads(json.dumps(DPP))
    slow["host_cores"] = 2
    slow["scanned_rounds_per_sec"]["16"]["baseline"] = 10.0
    slow["scanned_rounds_per_sec"]["16"]["cached"] = 10.0
    _write(str(cur / "BENCH_dpp_smoke.json"), slow)
    _write(str(cur / "BENCH_shard_smoke.json"), dict(SHARD, host_cores=2))
    _, base = dirs
    assert cr.check(str(cur), base, tolerance=0.25) == []


def test_missing_current_json_fails(dirs):
    cur, base = dirs
    os.remove(os.path.join(cur, "BENCH_shard_smoke.json"))
    failures = cr.check(cur, base, tolerance=0.25)
    assert any("produced no JSON" in f for f in failures)


def test_missing_baseline_skips(dirs, tmp_path):
    cur, _ = dirs
    empty = tmp_path / "empty_baselines"
    empty.mkdir()
    assert cr.check(cur, str(empty), tolerance=0.25) == []


def test_main_exit_codes(dirs):
    cur, base = dirs
    cr.main(["--current-dir", cur, "--baseline-dir", base])  # passes
    with pytest.raises(SystemExit):
        cr.main(["--current-dir", cur, "--baseline-dir", base, "--scale", "0.5"])


def test_repo_baselines_are_committed():
    """The real baselines the CI gate reads must exist in-repo."""
    for name in cr.MANIFEST:
        assert os.path.exists(os.path.join(cr.BASELINE_DIR, name)), name

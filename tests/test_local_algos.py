"""Pluggable local-update algorithm registry (DESIGN.md §12).

Tier-1 (single device): registry ergonomics + FLConfig validation, the
deprecated ``build_local_update`` wrapper, the ``prox_mu=0 ⇒ fedavg``
reduction (hypothesis property when available, deterministic fallback
always), FedDyn state evolution, and feddyn checkpoint round-trip parity.
The sharded variants (resident, slot-capped, stale, fault-guarded) run
under the CI ``multidevice`` job
(``XLA_FLAGS=--xla_force_host_platform_device_count=8``).
"""

import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import selection as selection_lib
from repro.fl import engine, faults, local_algos, scenarios
from repro.fl import rounds as rounds_lib
from repro.launch.mesh import make_client_mesh

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

multidevice = pytest.mark.skipif(
    jax.device_count() < 2,
    reason="needs >1 device (XLA_FLAGS=--xla_force_host_platform_device_count=8)",
)

FEAT, N_C, NCLS = 8, 6, 4


def linear_loss(params, x, y):
    logp = jax.nn.log_softmax(x @ params["w"] + params["b"])
    return -jnp.mean(jnp.take_along_axis(logp, y[..., None], axis=-1))


def _federation(c, seed=0):
    rng = np.random.default_rng(seed)
    xs = jnp.asarray(rng.normal(size=(c, N_C, FEAT)).astype(np.float32))
    ys = jnp.asarray(rng.integers(0, NCLS, size=(c, N_C)), jnp.int32)
    params = {
        "w": jnp.asarray(0.01 * rng.normal(size=(FEAT, NCLS)).astype(np.float32)),
        "b": jnp.zeros((NCLS,), jnp.float32),
    }
    return xs, ys, params


def _state_and_cfg(c, k, strategy, mesh=None, rounds=8, **cfg_kw):
    xs, ys, params = _federation(c)
    cfg = engine.FLConfig(
        num_clients=c, clients_per_round=k, local_epochs=2, lr=0.1,
        rounds=rounds, eval_every=2, num_classes=NCLS, seed=0, **cfg_kw,
    )
    state = engine.init_server_state(
        cfg, params, linear_loss, None, xs, ys,
        strategy=strategy, profiles=xs.mean(axis=1), mesh=mesh,
    )
    return cfg, state


def _run(cfg, state, rounds, mesh=None):
    rf = engine.make_round_fn(cfg, linear_loss, (selection_lib.UniformSelection(),),
                              mesh=mesh)
    fin, outs = engine.run_scanned(rf, state, rounds)
    return fin, jax.tree_util.tree_map(np.asarray, outs)


def _max_param_diff(a, b):
    return max(
        float(jnp.max(jnp.abs(x.astype(jnp.float32) - y.astype(jnp.float32))))
        for x, y in zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b))
    )


# ------------------------------------------------------------ registry


def test_unknown_local_algo_lists_known():
    with pytest.raises(ValueError) as e:
        local_algos.get_local_algo("nope")
    msg = str(e.value)
    for name in local_algos.ALGO_NAMES:
        assert name in msg


def test_registry_error_shape_uniform():
    """make_strategy / scenario / fault / local-algo registries raise the
    SAME ValueError shape: ``unknown <what> '<name>'; known: [...]``."""
    raisers = [
        lambda: selection_lib.make_strategy("nope"),
        lambda: scenarios.get_scenario("nope"),
        lambda: faults.get_fault_model("nope"),
        lambda: local_algos.get_local_algo("nope"),
    ]
    for fn in raisers:
        with pytest.raises(ValueError, match=r"unknown .*'nope'; known: \["):
            fn()


def test_all_algo_names_resolve():
    assert local_algos.ALGO_NAMES == tuple(sorted(local_algos.LOCAL_ALGOS))
    for name in local_algos.ALGO_NAMES:
        a = local_algos.get_local_algo(name)
        assert a.name == name
    assert not local_algos.get_local_algo("fedavg").stateful
    assert not local_algos.get_local_algo("fedprox").stateful
    assert local_algos.get_local_algo("feddyn").stateful


@pytest.mark.parametrize("bad", [
    lambda: local_algos.FedProx(prox_mu=-0.1),
    lambda: local_algos.FedDyn(feddyn_alpha=0.0),
    lambda: local_algos.FedDyn(feddyn_alpha=-1.0),
])
def test_algo_hyperparam_validation(bad):
    with pytest.raises(ValueError):
        bad()


@pytest.mark.parametrize("bad_kw", [
    dict(local_algo="nope"),
    dict(local_algo="fedavg", prox_mu=0.01),
    dict(local_algo="fedavg", feddyn_alpha=0.01),
    dict(local_algo="fedprox", feddyn_alpha=0.01),
    dict(local_algo="fedprox", prox_mu=-0.5),
    dict(local_algo="feddyn", prox_mu=0.01),
    dict(local_algo="feddyn", feddyn_alpha=0.0),
])
def test_flconfig_validates_algo_combos(bad_kw):
    with pytest.raises(ValueError):
        engine.FLConfig(
            num_clients=8, clients_per_round=4, local_epochs=1, lr=0.1,
            rounds=2, eval_every=1, num_classes=NCLS, seed=0, **bad_kw,
        )


# ------------------------------------------------- deprecated wrapper


def test_build_local_update_deprecated_but_identical():
    xs, ys, params = _federation(4)
    batched = lambda p, b: linear_loss(p, b[0], b[1])
    steps = (xs[0].reshape(2, 3, FEAT), ys[0].reshape(2, 3))  # (steps=2, B=3)
    with pytest.warns(DeprecationWarning, match="build_local_algo_update"):
        legacy = rounds_lib.build_local_update(batched, 0.1)
    fresh = rounds_lib.build_local_algo_update(
        local_algos.get_local_algo("fedavg"), batched, 0.1
    )
    p1, l1 = legacy(params, steps)
    p2, l2 = fresh(params, steps)
    assert _max_param_diff(p1, p2) == 0.0
    assert bool(jnp.array_equal(l1, l2))


# ------------------------------------------------- mu=0 reduction


def _local_update_outputs(algo, seed):
    rng = np.random.default_rng(seed)
    params = {
        "w": jnp.asarray(rng.normal(size=(FEAT, NCLS)).astype(np.float32)),
        "b": jnp.asarray(rng.normal(size=(NCLS,)).astype(np.float32)),
    }
    x = jnp.asarray(rng.normal(size=(3, 5, FEAT)).astype(np.float32))
    y = jnp.asarray(rng.integers(0, NCLS, size=(3, 5)), jnp.int32)
    batched = lambda p, b: linear_loss(p, b[0], b[1])
    upd = rounds_lib.build_local_algo_update(algo, batched, 0.07)
    return upd(params, (x, y))


def _assert_prox_zero_is_fedavg(seed):
    p_avg, l_avg = _local_update_outputs(local_algos.FedAvg(), seed)
    p_prx, l_prx = _local_update_outputs(local_algos.FedProx(prox_mu=0.0), seed)
    assert _max_param_diff(p_avg, p_prx) == 0.0
    assert bool(jnp.array_equal(l_avg, l_prx))


def test_fedprox_zero_mu_is_fedavg_local_update():
    for seed in range(3):
        _assert_prox_zero_is_fedavg(seed)


if HAVE_HYPOTHESIS:

    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(min_value=0, max_value=2**31 - 1))
    def test_fedprox_zero_mu_is_fedavg_property(seed):
        """Hypothesis property: prox_mu=0 reduces fedprox to fedavg EXACTLY
        (same compiled program, bit-identical params and losses)."""
        _assert_prox_zero_is_fedavg(seed)


def test_fedprox_zero_mu_engine_history_bit_identical():
    c, k = 12, 4
    cfg_a, s_a = _state_and_cfg(c, k, selection_lib.UniformSelection())
    cfg_p, s_p = _state_and_cfg(
        c, k, selection_lib.UniformSelection(),
        local_algo="fedprox", prox_mu=0.0,
    )
    f_a, o_a = _run(cfg_a, s_a, 6)
    f_p, o_p = _run(cfg_p, s_p, 6)
    assert np.array_equal(o_a["selected"], o_p["selected"])
    assert np.array_equal(o_a["loss"], o_p["loss"])
    assert _max_param_diff(f_a.params, f_p.params) == 0.0


def test_fedprox_nonzero_mu_changes_trajectory():
    c, k = 12, 4
    cfg_a, s_a = _state_and_cfg(c, k, selection_lib.UniformSelection())
    cfg_p, s_p = _state_and_cfg(
        c, k, selection_lib.UniformSelection(),
        local_algo="fedprox", prox_mu=1.0,
    )
    f_a, o_a = _run(cfg_a, s_a, 6)
    f_p, o_p = _run(cfg_p, s_p, 6)
    # same cohorts (selection is algorithm-independent), different params
    assert np.array_equal(o_a["selected"], o_p["selected"])
    assert _max_param_diff(f_a.params, f_p.params) > 0.0


# ------------------------------------------------- feddyn state


def test_feddyn_state_lives_in_server_state():
    c, k = 12, 4
    cfg, state = _state_and_cfg(
        c, k, selection_lib.UniformSelection(),
        local_algo="feddyn", feddyn_alpha=0.1,
    )
    assert state.algo_state is not None
    for leaf, p_leaf in zip(
        jax.tree_util.tree_leaves(state.algo_state),
        jax.tree_util.tree_leaves(state.params),
    ):
        assert leaf.shape == (c,) + p_leaf.shape
        assert leaf.dtype == jnp.float32
        assert float(jnp.abs(leaf).sum()) == 0.0


def test_feddyn_state_updates_only_selected_clients():
    c, k = 12, 4
    cfg, state = _state_and_cfg(
        c, k, selection_lib.UniformSelection(),
        local_algo="feddyn", feddyn_alpha=0.1,
    )
    fin, outs = _run(cfg, state, 1)
    sel = set(np.asarray(outs["selected"]).ravel().tolist())
    h_norm = sum(
        np.abs(np.asarray(l)).sum(axis=tuple(range(1, l.ndim)))
        for l in jax.tree_util.tree_leaves(fin.algo_state)
    )
    for ci in range(c):
        if ci in sel:
            assert h_norm[ci] > 0.0, ci
        else:
            assert h_norm[ci] == 0.0, ci


def test_feddyn_differs_from_fedavg():
    c, k = 12, 4
    cfg_a, s_a = _state_and_cfg(c, k, selection_lib.UniformSelection())
    cfg_d, s_d = _state_and_cfg(
        c, k, selection_lib.UniformSelection(),
        local_algo="feddyn", feddyn_alpha=0.5,
    )
    f_a, o_a = _run(cfg_a, s_a, 6)
    f_d, o_d = _run(cfg_d, s_d, 6)
    assert np.array_equal(o_a["selected"], o_d["selected"])
    assert _max_param_diff(f_a.params, f_d.params) > 0.0


def test_feddyn_checkpoint_roundtrip_bit_parity(tmp_path):
    """FedDyn's client state is part of the ServerState snapshot: a mid-run
    save/restore resumes bit-identically (params AND algo_state)."""
    cfg, state = _state_and_cfg(
        10, 4, selection_lib.UniformSelection(),
        local_algo="feddyn", feddyn_alpha=0.1,
    )
    rf = engine.make_round_fn(cfg, linear_loss,
                              (selection_lib.UniformSelection(),))
    full, outs_full = engine.run_scanned(rf, state, 6)

    half, _ = engine.run_scanned(rf, state, 3)
    assert half.algo_state is not None
    engine.save_server_state(str(tmp_path), half)
    restored = engine.restore_server_state(str(tmp_path), half)
    assert _max_param_diff(half.algo_state, restored.algo_state) == 0.0
    resumed, outs_tail = engine.run_scanned(rf, restored, 3)

    assert _max_param_diff(full.params, resumed.params) == 0.0
    assert _max_param_diff(full.algo_state, resumed.algo_state) == 0.0
    assert int(resumed.round) == 6
    tail = np.asarray(outs_full["selected"])[3:]
    assert np.array_equal(tail, np.asarray(outs_tail["selected"]))


def test_feddyn_guarded_state_only_for_selected():
    """Under the fault guard a client's penalty state can only advance in a
    round it was selected AND its update survived the guard — in particular
    never for a client outside every cohort."""
    c, k = 12, 6
    cfg, state = _state_and_cfg(
        c, k, selection_lib.UniformSelection(),
        local_algo="feddyn", feddyn_alpha=0.1,
        faults="corrupt", aggregator="trimmed_mean",
    )
    fin, outs = _run(cfg, state, 4)
    assert np.isfinite(
        np.concatenate([np.asarray(l).ravel()
                        for l in jax.tree_util.tree_leaves(fin.algo_state)])
    ).all()
    h_norm = sum(
        np.abs(np.asarray(l)).sum(axis=tuple(range(1, l.ndim)))
        for l in jax.tree_util.tree_leaves(fin.algo_state)
    )
    sel = set(np.asarray(outs["selected"]).ravel().tolist())
    for ci in range(c):
        if h_norm[ci] > 0:
            assert ci in sel, ci


# ------------------------------------------------- selection protocol


def test_draw_fn_dispatches_to_legacy_select_fn():
    class Legacy(selection_lib.SelectionStrategy):
        name = "legacy"

        def select_fn(self, key, state, k):
            return jnp.arange(k, dtype=jnp.int32)

    s = Legacy()
    st_ = selection_lib.selection_state(8, 3)
    out = np.asarray(s.draw_fn(jax.random.key(0), st_, 3))
    assert np.array_equal(out, [0, 1, 2])
    # avail mask with no select_avail_fn override: availability-blind (the
    # old base default)
    avail = jnp.zeros((8,), bool).at[4:].set(True)
    out = np.asarray(s.draw_fn(jax.random.key(0), st_, 3, avail))
    assert np.array_equal(out, [0, 1, 2])


def test_base_draw_fn_without_any_override_raises():
    s = selection_lib.SelectionStrategy()
    st_ = selection_lib.selection_state(8, 3)
    with pytest.raises(NotImplementedError):
        s.draw_fn(jax.random.key(0), st_, 3)


def test_legacy_adapters_route_through_draw_fn():
    for name in selection_lib.STRATEGY_NAMES:
        s = selection_lib.make_strategy(name)
        st_ = selection_lib.selection_state(10, 4, cluster_labels=jnp.asarray(
            np.arange(10) % 4, jnp.int32))
        key = jax.random.key(3)
        a = np.asarray(s.select_fn(key, st_, 4))
        b = np.asarray(s.draw_fn(key, st_, 4))
        assert np.array_equal(a, b), name
        avail = jnp.asarray(np.arange(10) % 2 == 0)
        a = np.asarray(s.select_avail_fn(key, st_, 4, avail))
        b = np.asarray(s.draw_fn(key, st_, 4, avail))
        assert np.array_equal(a, b), name


# ------------------------------------------------- sharded modes


@multidevice
@pytest.mark.parametrize("mode_kw", [
    dict(),
    dict(cohort_cap=2),
    dict(staleness_bound=2, scenario="heavy_tail"),
    dict(faults="corrupt", aggregator="trimmed_mean"),
    dict(candidate_frac=0.75),
])
def test_sharded_fedavg_registry_bit_identical(mode_kw):
    """local_algo='fedavg' and fedprox(mu=0) compile to the same program in
    every sharded engine mode — the registry plumbing is invisible."""
    c = jax.device_count() * 2
    k = max(2, jax.device_count() // 2)
    mesh = make_client_mesh()
    cfg_a, s_a = _state_and_cfg(c, k, selection_lib.UniformSelection(),
                                mesh=mesh, **mode_kw)
    cfg_p, s_p = _state_and_cfg(c, k, selection_lib.UniformSelection(),
                                mesh=mesh, local_algo="fedprox", prox_mu=0.0,
                                **mode_kw)
    f_a, o_a = _run(cfg_a, s_a, 4, mesh=mesh)
    f_p, o_p = _run(cfg_p, s_p, 4, mesh=mesh)
    assert np.array_equal(o_a["selected"], o_p["selected"])
    assert _max_param_diff(f_a.params, f_p.params) == 0.0


@multidevice
def test_sharded_feddyn_matches_single_device():
    c, k = jax.device_count() * 2, 4
    mesh = make_client_mesh()
    kw = dict(local_algo="feddyn", feddyn_alpha=0.1)
    cfg_1, s_1 = _state_and_cfg(c, k, selection_lib.UniformSelection(), **kw)
    cfg_m, s_m = _state_and_cfg(c, k, selection_lib.UniformSelection(),
                                mesh=mesh, **kw)
    f_1, o_1 = _run(cfg_1, s_1, 4)
    f_m, o_m = _run(cfg_m, s_m, 4, mesh=mesh)
    assert np.array_equal(o_1["selected"], o_m["selected"])
    assert _max_param_diff(f_1.params, f_m.params) < 1e-5
    assert _max_param_diff(f_1.algo_state, f_m.algo_state) < 1e-5


@multidevice
def test_slot_feddyn_state_scatter():
    """Slot-compacted rounds gather/scatter the per-client state through
    slot_index: only trained residents advance their h."""
    c, k = jax.device_count() * 2, 2
    mesh = make_client_mesh()
    cfg, state = _state_and_cfg(
        c, k, selection_lib.UniformSelection(), mesh=mesh,
        local_algo="feddyn", feddyn_alpha=0.1, cohort_cap=2,
    )
    fin, outs = _run(cfg, state, 3, mesh=mesh)
    sel = set(np.asarray(outs["selected"]).ravel().tolist())
    h_norm = sum(
        np.abs(np.asarray(l)).sum(axis=tuple(range(1, l.ndim)))
        for l in jax.tree_util.tree_leaves(fin.algo_state)
    )
    for ci in range(c):
        if h_norm[ci] > 0:
            assert ci in sel, ci


@multidevice
def test_stale_feddyn_runs_and_carries_state():
    c, k = jax.device_count() * 2, 4
    mesh = make_client_mesh()
    cfg, state = _state_and_cfg(
        c, k, selection_lib.UniformSelection(), mesh=mesh,
        local_algo="feddyn", feddyn_alpha=0.1,
        staleness_bound=2, scenario="heavy_tail",
    )
    fin, outs = _run(cfg, state, 6, mesh=mesh)
    assert np.isfinite(outs["loss"]).all()
    h_sum = sum(float(np.abs(np.asarray(l)).sum())
                for l in jax.tree_util.tree_leaves(fin.algo_state))
    assert h_sum > 0.0

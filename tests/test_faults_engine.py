"""Fault-injection + robust-aggregation engine (DESIGN.md §11).

Pure pieces (the fault registry, draw determinism/precedence, config
validation, checkpoint-resume parity, quarantine feedback) are tier-1: they
run on one device.  The sharded variants (guard inside the shard_map,
blackout, slot/stale composition) run under the CI ``multidevice`` job
(``XLA_FLAGS=--xla_force_host_platform_device_count=8``).
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import selection as selection_lib
from repro.fl import engine, faults
from repro.launch.mesh import make_client_mesh

multidevice = pytest.mark.skipif(
    jax.device_count() < 2,
    reason="needs >1 device (XLA_FLAGS=--xla_force_host_platform_device_count=8)",
)

FEAT, N_C, NCLS = 8, 6, 4


def linear_loss(params, x, y):
    logp = jax.nn.log_softmax(x @ params["w"] + params["b"])
    return -jnp.mean(jnp.take_along_axis(logp, y[..., None], axis=-1))


def _federation(c, seed=0):
    rng = np.random.default_rng(seed)
    xs = jnp.asarray(rng.normal(size=(c, N_C, FEAT)).astype(np.float32))
    ys = jnp.asarray(rng.integers(0, NCLS, size=(c, N_C)), jnp.int32)
    params = {
        "w": jnp.asarray(0.01 * rng.normal(size=(FEAT, NCLS)).astype(np.float32)),
        "b": jnp.zeros((NCLS,), jnp.float32),
    }
    return xs, ys, params


def _state_and_cfg(c, k, strategy, mesh=None, rounds=8, **cfg_kw):
    xs, ys, params = _federation(c)
    cfg = engine.FLConfig(
        num_clients=c, clients_per_round=k, local_epochs=2, lr=0.1,
        rounds=rounds, eval_every=2, num_classes=NCLS, seed=0, **cfg_kw,
    )
    state = engine.init_server_state(
        cfg, params, linear_loss, None, xs, ys,
        strategy=strategy, profiles=xs.mean(axis=1), mesh=mesh,
    )
    return cfg, state


def _run(cfg, state, rounds, mesh=None):
    rf = engine.make_round_fn(cfg, linear_loss, (selection_lib.UniformSelection(),),
                              mesh=mesh)
    fin, outs = engine.run_scanned(rf, state, rounds)
    return fin, jax.tree_util.tree_map(np.asarray, outs)


def _max_param_diff(a, b):
    return max(
        float(jnp.max(jnp.abs(x.astype(jnp.float32) - y.astype(jnp.float32))))
        for x, y in zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b))
    )


# ------------------------------------------------------------ registry


def test_unknown_fault_model_lists_known():
    with pytest.raises(ValueError) as e:
        faults.get_fault_model("nope")
    msg = str(e.value)
    for name in faults.FAULT_NAMES:
        assert name in msg


def test_all_registry_names_resolve():
    assert faults.FAULT_NAMES == tuple(sorted(faults.FAULT_MODELS))
    for name in faults.FAULT_NAMES:
        m = faults.get_fault_model(name)
        assert m.name == name


@pytest.mark.parametrize("bad", [
    dict(dropout=1.5), dict(nan=-0.1), dict(garbage_scale=0.0),
    dict(lemon_frac=2.0), dict(lemon_mode="weird"),
])
def test_fault_model_validation(bad):
    with pytest.raises(ValueError):
        faults.FaultModel(name="x", **bad)


def test_lemon_mask_deterministic_count():
    m = faults.FaultModel(name="x", lemon_frac=0.25)
    mask = faults.lemon_mask(m, 16)
    assert mask.shape == (16,)
    assert mask.dtype == jnp.bool_
    assert int(mask.sum()) == 4
    assert bool(jnp.array_equal(mask, faults.lemon_mask(m, 16)))
    # at least one lemon even when the fraction rounds to zero
    tiny = faults.FaultModel(name="y", lemon_frac=0.01)
    assert int(faults.lemon_mask(tiny, 8).sum()) == 1


def test_draw_round_faults_determinism_and_precedence():
    m = faults.get_fault_model("chaos")
    key = jax.random.key(0)
    d1 = faults.draw_round_faults(key, m, 32, num_shards=4)
    d2 = faults.draw_round_faults(key, m, 32, num_shards=4)
    for a, b in zip(d1, d2):
        assert a.shape == (32,) and a.dtype == jnp.bool_
        assert bool(jnp.array_equal(a, b))
    delivered, nan_m, garb_m, flip_m = (np.asarray(x) for x in d1)
    # corruption categories are disjoint and only hit delivered clients
    assert not np.any(nan_m & garb_m)
    assert not np.any(nan_m & flip_m)
    assert not np.any(garb_m & flip_m)
    for mask in (nan_m, garb_m, flip_m):
        assert not np.any(mask & ~delivered)
    other = faults.draw_round_faults(jax.random.key(1), m, 32, num_shards=4)
    assert any(not bool(jnp.array_equal(a, b)) for a, b in zip(d1, other))


def test_fault_free_model_draws_nothing():
    m = faults.FaultModel(name="calm")
    d = faults.draw_round_faults(jax.random.key(0), m, 16)
    assert bool(d.delivered.all())
    assert not bool(d.nan.any() | d.garbage.any() | d.sign_flip.any())


# ------------------------------------------------------- config contract


@pytest.mark.parametrize("bad", [
    dict(aggregator="median"),
    dict(faults="nope"),
    dict(faults="corrupt", robust_norm_mult=0.0),
    dict(faults="corrupt", min_survivors=0),
    dict(faults="corrupt", min_survivors=99),
    dict(faults="corrupt", quarantine_rounds=-1),
    dict(ckpt_every=0),
])
def test_flconfig_rejects_bad_fault_config(bad):
    with pytest.raises(ValueError):
        engine.FLConfig(
            num_clients=8, clients_per_round=4, local_epochs=1, lr=0.1,
            rounds=4, eval_every=2, num_classes=NCLS, seed=0, **bad,
        )


def test_zero_fault_state_has_no_quarantine_field():
    cfg, state = _state_and_cfg(8, 4, selection_lib.UniformSelection())
    assert state.quarantine is None
    _, outs = _run(cfg, state, 4)
    assert "survivors" not in outs and "flagged" not in outs


def test_guarded_state_carries_quarantine():
    cfg, state = _state_and_cfg(
        8, 4, selection_lib.UniformSelection(), faults="corrupt",
        aggregator="trimmed_mean",
    )
    assert state.quarantine is not None
    assert state.quarantine.shape == (8,)
    assert state.quarantine.dtype == jnp.int32


# --------------------------------------------------- engine fault behavior


def test_total_dropout_is_identity_rounds(monkeypatch):
    monkeypatch.setitem(
        faults.FAULT_MODELS, "all_drop",
        faults.FaultModel(name="all_drop", dropout=1.0),
    )
    cfg, state = _state_and_cfg(
        8, 4, selection_lib.UniformSelection(), faults="all_drop",
    )
    fin, outs = _run(cfg, state, 4)
    assert np.all(outs["survivors"] == 0)
    assert np.all(outs["identity_round"] == 1)
    assert np.all(np.isnan(outs["loss"]))  # no cohort, no round mean
    assert _max_param_diff(fin.params, state.params) == 0.0


def test_total_nan_trimmed_floors_to_identity(monkeypatch):
    monkeypatch.setitem(
        faults.FAULT_MODELS, "all_nan",
        faults.FaultModel(name="all_nan", nan=1.0),
    )
    cfg, state = _state_and_cfg(
        8, 4, selection_lib.UniformSelection(), faults="all_nan",
        aggregator="trimmed_mean", quarantine_rounds=0,
    )
    fin, outs = _run(cfg, state, 4)
    assert np.all(outs["survivors"] == 0)
    assert np.all(outs["identity_round"] == 1)
    assert np.all(outs["flagged"] == 4)  # whole cohort screened out
    assert _max_param_diff(fin.params, state.params) == 0.0


def test_total_nan_plain_mean_poisons_params(monkeypatch):
    # the unprotected control: with aggregator="mean" the guard screens
    # nothing, so one NaN cohort destroys the params — exactly the failure
    # mode the robust modes exist for
    monkeypatch.setitem(
        faults.FAULT_MODELS, "all_nan",
        faults.FaultModel(name="all_nan", nan=1.0),
    )
    cfg, state = _state_and_cfg(
        8, 4, selection_lib.UniformSelection(), faults="all_nan",
        aggregator="mean",
    )
    fin, outs = _run(cfg, state, 2)
    assert not np.isfinite(
        np.asarray(jax.tree_util.tree_leaves(fin.params)[0])
    ).all()
    assert np.all(np.isnan(outs["loss"]))  # NaN-aware mean: no finite entry


def test_corrupt_trimmed_stays_finite_and_quarantines():
    cfg, state = _state_and_cfg(
        12, 6, selection_lib.UniformSelection(), faults="corrupt",
        aggregator="trimmed_mean", rounds=12,
    )
    fin, outs = _run(cfg, state, 12)
    for leaf in jax.tree_util.tree_leaves(fin.params):
        assert np.isfinite(np.asarray(leaf)).all()
    assert np.isfinite(outs["loss"]).any()
    assert np.all(outs["survivors"] <= 6)
    # flagged clients entered quarantine at some point
    if outs["flagged"].sum() > 0:
        assert outs["quarantined"].max() > 0


def test_quarantine_prevents_lemon_reselection():
    c, k, rounds = 12, 4, 16
    model = faults.get_fault_model("lemons")
    lemons = np.nonzero(np.asarray(faults.lemon_mask(model, c)))[0]
    cfg, state = _state_and_cfg(
        c, k, selection_lib.UniformSelection(), faults="lemons",
        aggregator="trimmed_mean", quarantine_rounds=10 * rounds,
        rounds=rounds,
    )
    _, outs = _run(cfg, state, rounds)
    sel = outs["selected"].reshape(-1)
    for lem in lemons:
        assert int(np.sum(sel == lem)) <= 1
    # the contrast: cooldown 0 clears the counter the same round it is set,
    # so lemons keep getting drawn
    cfg0, state0 = _state_and_cfg(
        c, k, selection_lib.UniformSelection(), faults="lemons",
        aggregator="trimmed_mean", quarantine_rounds=0, rounds=rounds,
    )
    _, outs0 = _run(cfg0, state0, rounds)
    sel0 = outs0["selected"].reshape(-1)
    assert max(int(np.sum(sel0 == lem)) for lem in lemons) > 1


def test_quarantine_counter_decays():
    cfg, state = _state_and_cfg(
        12, 6, selection_lib.UniformSelection(), faults="lemons",
        aggregator="trimmed_mean", quarantine_rounds=3, rounds=16,
    )
    fin, outs = _run(cfg, state, 16)
    q = outs["quarantined"]
    # a lemon gets flagged (counter 3), then the count decays back to zero
    # within the cooldown unless re-flagged; by the end every counter is
    # bounded by the cooldown
    assert int(np.asarray(fin.quarantine).max()) <= 3


def test_guard_without_faults_keeps_clean_cohorts():
    # robust aggregation on a fault-free federation: nothing to screen, all
    # survivors, loss finite every round
    cfg, state = _state_and_cfg(
        8, 4, selection_lib.UniformSelection(), aggregator="clipped_mean",
    )
    fin, outs = _run(cfg, state, 6)
    assert np.all(outs["survivors"] == 4)
    assert np.isfinite(outs["loss"]).all()
    assert np.all(outs["identity_round"] == 0)


def test_engine_run_is_deterministic_under_faults():
    cfg, s1 = _state_and_cfg(
        10, 4, selection_lib.UniformSelection(), faults="chaos",
        aggregator="trimmed_mean",
    )
    _, s2 = _state_and_cfg(
        10, 4, selection_lib.UniformSelection(), faults="chaos",
        aggregator="trimmed_mean",
    )
    f1, o1 = _run(cfg, s1, 6)
    f2, o2 = _run(cfg, s2, 6)
    assert np.array_equal(o1["selected"], o2["selected"])
    assert _max_param_diff(f1.params, f2.params) == 0.0


# --------------------------------------------------- checkpoint / resume


def test_checkpoint_resume_bit_parity(tmp_path):
    cfg, state = _state_and_cfg(
        10, 4, selection_lib.UniformSelection(), faults="corrupt",
        aggregator="trimmed_mean",
    )
    rf = engine.make_round_fn(cfg, linear_loss, (selection_lib.UniformSelection(),))
    full, outs_full = engine.run_scanned(rf, state, 6)

    half, _ = engine.run_scanned(rf, state, 3)
    engine.save_server_state(str(tmp_path), half)
    restored = engine.restore_server_state(str(tmp_path), half)
    resumed, outs_tail = engine.run_scanned(rf, restored, 3)

    assert _max_param_diff(full.params, resumed.params) == 0.0
    assert bool(jnp.array_equal(full.quarantine, resumed.quarantine))
    assert bool(jnp.array_equal(full.losses, resumed.losses))
    assert int(resumed.round) == 6
    tail = np.asarray(outs_full["selected"])[3:]
    assert np.array_equal(tail, np.asarray(outs_tail["selected"]))


def test_checkpoint_resume_clean_config(tmp_path):
    # resume parity is not a faults-only property: the plain engine state
    # (typed PRNG key included) must round-trip bit-identically too
    cfg, state = _state_and_cfg(8, 4, selection_lib.UniformSelection())
    rf = engine.make_round_fn(cfg, linear_loss, (selection_lib.UniformSelection(),))
    full, _ = engine.run_scanned(rf, state, 4)
    half, _ = engine.run_scanned(rf, state, 2)
    engine.save_server_state(str(tmp_path), half)
    restored = engine.restore_server_state(str(tmp_path), half)
    resumed, _ = engine.run_scanned(rf, restored, 2)
    assert _max_param_diff(full.params, resumed.params) == 0.0


def test_restore_server_state_rejects_other_config(tmp_path):
    cfg, state = _state_and_cfg(8, 4, selection_lib.UniformSelection())
    engine.save_server_state(str(tmp_path), state)
    _, other = _state_and_cfg(12, 4, selection_lib.UniformSelection())
    with pytest.raises(ValueError):
        engine.restore_server_state(str(tmp_path), other)


def test_run_checkpointed_matches_run_scanned(tmp_path):
    cfg, state = _state_and_cfg(
        10, 4, selection_lib.UniformSelection(), faults="corrupt",
        aggregator="clipped_mean",
    )
    rf = engine.make_round_fn(cfg, linear_loss, (selection_lib.UniformSelection(),))
    ref_state, ref_outs = engine.run_scanned(rf, state, 7)
    ck_state, ck_outs = engine.run_checkpointed(
        rf, state, 7, ckpt_dir=str(tmp_path), ckpt_every=3,
    )
    assert _max_param_diff(ref_state.params, ck_state.params) == 0.0
    for k in ref_outs:
        a, b = np.asarray(ref_outs[k]), np.asarray(ck_outs[k])
        eq_nan = np.issubdtype(a.dtype, np.floating)
        assert np.array_equal(a, b, equal_nan=eq_nan), k
    # snapshots at the segment boundaries: rounds 3, 6, 7
    import os

    steps = sorted(os.listdir(str(tmp_path)))
    assert steps == ["step_00000003", "step_00000006", "step_00000007"]


def test_run_checkpointed_without_dir_is_run_scanned():
    cfg, state = _state_and_cfg(8, 4, selection_lib.UniformSelection())
    rf = engine.make_round_fn(cfg, linear_loss, (selection_lib.UniformSelection(),))
    a, outs_a = engine.run_scanned(rf, state, 3)
    b, outs_b = engine.run_checkpointed(rf, state, 3)
    assert _max_param_diff(a.params, b.params) == 0.0
    assert np.array_equal(np.asarray(outs_a["selected"]),
                          np.asarray(outs_b["selected"]))


# ------------------------------------------------------------- sharded


@multidevice
def test_sharded_zero_fault_parity():
    # the acceptance contract: a zero-fault mean config through the new
    # engine build is the SAME program as before — sharded and single-device
    # runs still agree (bit-identical cohorts, fp32-close params)
    c = jax.device_count() * 2
    mesh = make_client_mesh(jax.device_count())
    cfg, st1 = _state_and_cfg(c, 4, selection_lib.UniformSelection())
    f1, o1 = _run(cfg, st1, 6)
    _, stm = _state_and_cfg(c, 4, selection_lib.UniformSelection(), mesh=mesh)
    fm, om = _run(cfg, stm, 6, mesh=mesh)
    assert np.array_equal(o1["selected"], om["selected"])
    assert _max_param_diff(f1.params, fm.params) < 1e-5


@multidevice
def test_sharded_faulty_run_stays_finite():
    c = jax.device_count() * 2
    mesh = make_client_mesh(jax.device_count())
    cfg, state = _state_and_cfg(
        c, 4, selection_lib.UniformSelection(), mesh=mesh, faults="corrupt",
        aggregator="trimmed_mean",
    )
    fin, outs = _run(cfg, state, 8, mesh=mesh)
    for leaf in jax.tree_util.tree_leaves(fin.params):
        assert np.isfinite(np.asarray(leaf)).all()
    assert np.all(outs["survivors"] <= 4)


@multidevice
def test_sharded_total_blackout_is_identity(monkeypatch):
    monkeypatch.setitem(
        faults.FAULT_MODELS, "dark",
        faults.FaultModel(name="dark", shard_blackout=1.0),
    )
    c = jax.device_count() * 2
    mesh = make_client_mesh(jax.device_count())
    cfg, state = _state_and_cfg(
        c, 4, selection_lib.UniformSelection(), mesh=mesh, faults="dark",
    )
    fin, outs = _run(cfg, state, 4, mesh=mesh)
    assert np.all(outs["survivors"] == 0)
    assert np.all(outs["identity_round"] == 1)
    assert _max_param_diff(fin.params, state.params) == 0.0


@multidevice
def test_slot_mode_faulty_run():
    c = jax.device_count() * 4
    mesh = make_client_mesh(jax.device_count())
    cfg, state = _state_and_cfg(
        c, 4, selection_lib.UniformSelection(), mesh=mesh, faults="corrupt",
        aggregator="clipped_mean", cohort_cap=4,
    )
    fin, outs = _run(cfg, state, 6, mesh=mesh)
    for leaf in jax.tree_util.tree_leaves(fin.params):
        assert np.isfinite(np.asarray(leaf)).all()
    assert np.all(outs["survivors"] <= 4)


@multidevice
def test_stale_mode_faulty_run():
    c = jax.device_count() * 2
    mesh = make_client_mesh(jax.device_count())
    cfg, state = _state_and_cfg(
        c, 4, selection_lib.UniformSelection(), mesh=mesh, faults="corrupt",
        aggregator="trimmed_mean", scenario="heavy_tail", staleness_bound=2,
    )
    fin, outs = _run(cfg, state, 8, mesh=mesh)
    for leaf in jax.tree_util.tree_leaves(fin.params):
        assert np.isfinite(np.asarray(leaf)).all()
    assert "sim_time" in outs and "survivors" in outs


@multidevice
def test_sharded_checkpoint_resume_parity(tmp_path):
    c = jax.device_count() * 2
    mesh = make_client_mesh(jax.device_count())
    cfg, state = _state_and_cfg(
        c, 4, selection_lib.UniformSelection(), mesh=mesh, faults="chaos",
        aggregator="trimmed_mean",
    )
    rf = engine.make_round_fn(cfg, linear_loss, (selection_lib.UniformSelection(),),
                              mesh=mesh)
    full, _ = engine.run_scanned(rf, state, 6)
    half, _ = engine.run_scanned(rf, state, 3)
    engine.save_server_state(str(tmp_path), half)
    restored = engine.restore_server_state(str(tmp_path), half)
    restored = engine.shard_server_state(restored, mesh)
    resumed, _ = engine.run_scanned(rf, restored, 3)
    assert _max_param_diff(full.params, resumed.params) == 0.0
    assert bool(jnp.array_equal(full.quarantine, resumed.quarantine))

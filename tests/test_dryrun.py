"""Dry-run smoke (CI): spawn the launcher as a subprocess (it forces 512 host
devices, which must never leak into this test process) on reduced configs."""

import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_dryrun(*args):
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    return subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", *args],
        capture_output=True, text=True, timeout=560, env=env, cwd=REPO,
    )


@pytest.mark.slow
def test_dryrun_reduced_train_single_pod(tmp_path):
    out = tmp_path / "dr.jsonl"
    r = _run_dryrun("--arch", "smollm-360m", "--shape", "train_4k",
                    "--reduced", "--out", str(out))
    assert r.returncode == 0, r.stdout + r.stderr
    rec = json.loads(out.read_text().splitlines()[-1])
    assert rec["ok"], rec.get("error")
    assert rec["mesh"] == "16x16"
    assert rec["cost"].get("flops", 0) > 0
    assert "total" in rec["collectives"]


@pytest.mark.slow
def test_dryrun_reduced_decode_multi_pod(tmp_path):
    out = tmp_path / "dr.jsonl"
    r = _run_dryrun("--arch", "rwkv6-7b", "--shape", "decode_32k",
                    "--reduced", "--multi-pod", "--out", str(out))
    assert r.returncode == 0, r.stdout + r.stderr
    rec = json.loads(out.read_text().splitlines()[-1])
    assert rec["ok"], rec.get("error")
    assert rec["mesh"] == "2x16x16"


def test_main_process_still_single_device():
    import jax

    assert len(jax.devices()) == 1  # the XLA_FLAGS hack must not leak

"""Gram kernel + fused profiles→DPP-kernel Pallas pipeline vs the jnp
oracles (interpret mode).  Deliberately hypothesis-free — this module backs
the PR's fused-kernel acceptance criterion, so it must run (not skip) in
minimal containers without the optional dev deps."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.gram import ops as gram_ops
from repro.kernels.gram import ref as gram_ref
from repro.kernels.pairwise_l2 import ref as pw_ref
from repro.kernels.pairwise_l2.pairwise_l2 import pairwise_dists_stats_kernel

RNG = np.random.default_rng(0)


@pytest.mark.parametrize("m,n", [(5, 4), (64, 64), (130, 70), (33, 257)])
def test_gram_matches_ref(m, n):
    x = jnp.asarray(RNG.normal(size=(m, n)).astype(np.float32))
    got = np.asarray(gram_ops.gram(x))
    want = np.asarray(gram_ref.gram_ref(x))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_gram_bf16_inputs_fp32_accumulation():
    x = jnp.asarray(RNG.normal(size=(96, 40))).astype(jnp.bfloat16)
    got = np.asarray(gram_ops.gram(x))
    assert got.dtype == np.float32
    want = np.asarray(gram_ref.gram_ref(x.astype(jnp.float32)))
    np.testing.assert_allclose(got, want, rtol=2e-2, atol=2e-2 * abs(want).max())


@pytest.mark.parametrize(
    "c,q", [(4, 3), (10, 7), (100, 128), (130, 257), (257, 33)]
)
def test_fused_kernel_from_profiles_matches_oracle(c, q):
    """The two-launch Pallas profiles→DPP-kernel pipeline vs the jnp oracle,
    including non-tile-multiple C and Q (interpret mode)."""
    f = jnp.asarray(RNG.normal(size=(c, q)).astype(np.float32))
    got = np.asarray(gram_ops.kernel_from_profiles(f))
    want = np.asarray(gram_ref.kernel_from_profiles_ref(f))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_fused_matches_core_similarity_path():
    """similarity.kernel_from_profiles(use_kernel=True) routes through the
    fused pipeline and must agree with its own use_kernel=False oracle."""
    from repro.core import similarity

    f = jnp.asarray(RNG.normal(size=(70, 48)).astype(np.float32))
    got = np.asarray(similarity.kernel_from_profiles(f, use_kernel=True))
    want = np.asarray(similarity.kernel_from_profiles(f, use_kernel=False))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_fused_kernel_from_profiles_bf16():
    f = jnp.asarray(RNG.normal(size=(50, 40))).astype(jnp.bfloat16)
    got = np.asarray(gram_ops.kernel_from_profiles(f))
    want = np.asarray(gram_ref.kernel_from_profiles_ref(f.astype(jnp.float32)))
    np.testing.assert_allclose(got, want, rtol=3e-2, atol=3e-2 * abs(want).max())


def test_pairwise_dists_stats_scalars():
    """lo/hi from the stats epilogue == global extrema of the real region."""
    c, q = 130, 37
    f = jnp.asarray(RNG.normal(size=(c, q)).astype(np.float32))
    s0, lo, hi = pairwise_dists_stats_kernel(f, interpret=True)
    want = np.asarray(pw_ref.pairwise_sq_dists_ref(f)) * (1 - np.eye(c))
    want = np.sqrt(np.maximum(want, 0.0))
    assert float(lo) == 0.0  # diagonal pin ⇒ min(S⁰) = 0 exactly
    np.testing.assert_allclose(float(hi), want.max(), rtol=1e-5)
    np.testing.assert_allclose(
        np.asarray(s0)[:c, :c], want, atol=1e-3 * max(1.0, want.max())
    )


@pytest.mark.parametrize("bm,bk", [(8, 8), (16, 32), (128, 128)])
def test_fused_block_shape_independent(bm, bk):
    f = jnp.asarray(RNG.normal(size=(37, 21)).astype(np.float32))
    got = np.asarray(
        gram_ops.kernel_from_profiles(
            f, block_m=bm, block_n=bm, block_k=bk, block_gram=bm
        )
    )
    want = np.asarray(gram_ref.kernel_from_profiles_ref(f))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

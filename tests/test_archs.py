"""Per-architecture smoke tests (assignment requirement).

For each of the 10 assigned archs: instantiate the REDUCED variant of the
same family (<=2 pattern units of layers, d_model<=256, <=4 experts), run one
forward/train step and one cached decode step on CPU, assert output shapes
and the absence of NaNs.  The FULL configs are exercised only via the
dry-run (ShapeDtypeStruct, no allocation).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, INPUT_SHAPES, get_arch
from repro.launch import sharding
from repro.models import transformer as T


@pytest.fixture(scope="module")
def arch_specs():
    return {name: get_arch(name) for name in ARCH_NAMES}


def test_registry_has_all_ten(arch_specs):
    assert len(ARCH_NAMES) == 10
    types = {s.model.arch_type for s in arch_specs.values()}
    assert types == {"dense", "vlm", "moe", "ssm", "hybrid", "audio"}


def test_exact_assigned_configs(arch_specs):
    """Pin the exact published numbers from the assignment table."""
    m = {n: s.model for n, s in arch_specs.items()}
    a = m["granite-3-2b"]
    assert (a.num_layers, a.d_model, a.num_heads, a.num_kv_heads, a.d_ff, a.vocab_size) == (
        40, 2048, 32, 8, 8192, 49155)
    a = m["qwen2-vl-2b"]
    assert (a.num_layers, a.d_model, a.num_heads, a.num_kv_heads, a.d_ff, a.vocab_size) == (
        28, 1536, 12, 2, 8960, 151936)
    assert a.pos_style == "mrope"
    a = m["internlm2-20b"]
    assert (a.num_layers, a.d_model, a.num_heads, a.num_kv_heads, a.d_ff, a.vocab_size) == (
        48, 6144, 48, 8, 16384, 92544)
    a = m["smollm-360m"]
    assert (a.num_layers, a.d_model, a.num_heads, a.num_kv_heads, a.d_ff, a.vocab_size) == (
        32, 960, 15, 5, 2560, 49152)
    a = m["gemma-7b"]
    assert (a.num_layers, a.d_model, a.num_heads, a.head_dim, a.d_ff, a.vocab_size) == (
        28, 3072, 16, 256, 24576, 256000)
    assert a.mlp_variant == "geglu"
    a = m["recurrentgemma-9b"]
    assert (a.num_layers, a.d_model, a.num_heads, a.num_kv_heads, a.d_ff, a.vocab_size) == (
        38, 4096, 16, 1, 12288, 256000)
    assert a.block_pattern == ("rglru+mlp", "rglru+mlp", "local+mlp")
    a = m["llama4-maverick-400b-a17b"]
    assert (a.num_layers, a.d_model, a.num_heads, a.num_kv_heads, a.d_ff, a.vocab_size) == (
        48, 5120, 40, 8, 8192, 202048)
    assert (a.num_experts, a.experts_per_token) == (128, 1)
    a = m["rwkv6-7b"]
    assert (a.num_layers, a.d_model, a.d_ff, a.vocab_size) == (32, 4096, 14336, 65536)
    assert a.block_pattern == ("rwkv+cmix",)
    a = m["mixtral-8x7b"]
    assert (a.num_layers, a.d_model, a.num_heads, a.num_kv_heads, a.d_ff, a.vocab_size) == (
        32, 4096, 32, 8, 14336, 32000)
    assert (a.num_experts, a.experts_per_token, a.window) == (8, 2, 4096)
    a = m["musicgen-medium"]
    assert (a.num_layers, a.d_model, a.num_heads, a.num_kv_heads, a.d_ff, a.vocab_size) == (
        48, 1536, 24, 24, 6144, 2048)


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_arch_smoke_reduced(name, arch_specs):
    """Reduced variant: one train step + one decode step, no NaNs."""
    spec = arch_specs[name]
    cfg = spec.model.reduced(param_dtype="float32", dtype="float32", remat=False)
    assert cfg.d_model <= 256 and cfg.num_experts <= 4
    assert cfg.num_layers <= 2 * len(cfg.block_pattern)

    params = T.init_params(jax.random.key(0), cfg)
    b, s = 2, 16
    toks = jax.random.randint(jax.random.key(1), (b, s), 0, cfg.vocab_size)

    # train step (plain SGD on the LM loss)
    loss, g = jax.value_and_grad(lambda p: T.lm_loss(cfg, p, toks))(params)
    assert np.isfinite(float(loss)), name
    new = jax.tree_util.tree_map(lambda w, gw: w - 1e-2 * gw, params, g)
    loss2 = T.lm_loss(cfg, new, toks)
    assert np.isfinite(float(loss2)), name

    # one decode step against a cache
    caches = T.init_caches(cfg, b, cache_len=s)
    logits, caches = T.decode_step(cfg, params, toks[:, :1], caches)
    assert logits.shape == (b, 1, T.vocab_padded(cfg))
    assert np.isfinite(np.asarray(logits, np.float32)).all(), name


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_sharding_rules_are_complete_and_conflict_free(name, arch_specs):
    """Every param tensor gets a spec; no tensor reuses a mesh axis twice."""
    spec = arch_specs[name]
    cfg = spec.model
    logical = sharding.param_logical_specs(cfg)
    for mode_rules in (spec.train_rules, spec.serve_rules):
        specs = sharding.specs_from_logical(logical, mode_rules)
        leaves = jax.tree_util.tree_leaves(
            specs, is_leaf=lambda s: isinstance(s, jax.sharding.PartitionSpec)
        )
        assert leaves, name
        for sp in leaves:
            axes = [a for a in jax.tree_util.tree_leaves(tuple(sp)) if a]
            flat = []
            for a in axes:
                flat.extend(a if isinstance(a, tuple) else (a,))
            assert len(flat) == len(set(flat)), (name, sp)


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_full_config_dims_divisible_for_rules(name, arch_specs):
    """Sharded dims must divide the 16-way axes they map to (compile-time
    guarantee for the dry-run)."""
    spec = arch_specs[name]
    cfg = spec.model
    v = T.vocab_padded(cfg)
    for rules in (spec.train_rules, spec.serve_rules):
        def ok(dim, logical):
            ax = rules.get(logical)
            if ax is None:
                return True
            size = {"model": 16, "data": 16}[ax]
            return dim % (size * (2 if ax == "data" else 1)) == 0  # 32 on multi-pod data

        assert ok(v, "vocab_w"), (name, "vocab")
        assert ok(cfg.d_model, "embed_w"), (name, "embed")
        assert ok(cfg.d_model, "attn_in_w"), (name, "attn_in")
        assert ok(cfg.d_ff, "mlp_w"), (name, "mlp")
        if rules.get("heads_w"):
            assert cfg.q_dim % 16 == 0 and (cfg.q_dim // 16) % cfg.head_dim == 0, name
        if cfg.num_experts and rules.get("experts_w"):
            assert cfg.num_experts % 32 == 0, name  # ('pod','data') on multi-pod
        if cfg.num_experts and rules.get("expert_mlp_w"):
            assert cfg.d_ff % 16 == 0, name


def test_long_context_policy(arch_specs):
    native = {n for n, s in arch_specs.items() if s.long_context == "native"}
    assert native == {"recurrentgemma-9b", "rwkv6-7b", "mixtral-8x7b"}
    # SWA variants replace full attention with windowed attention
    lc = arch_specs["granite-3-2b"].long_context_model()
    assert lc.block_pattern == ("swa+mlp",)
    lc = arch_specs["llama4-maverick-400b-a17b"].long_context_model()
    assert lc.block_pattern == ("swa+mlp", "swa+moe")


def test_input_shapes_table():
    assert INPUT_SHAPES["train_4k"].seq_len == 4096
    assert INPUT_SHAPES["train_4k"].global_batch == 256
    assert INPUT_SHAPES["prefill_32k"].seq_len == 32768
    assert INPUT_SHAPES["prefill_32k"].global_batch == 32
    assert INPUT_SHAPES["decode_32k"].global_batch == 128
    assert INPUT_SHAPES["long_500k"].seq_len == 524288
    assert INPUT_SHAPES["long_500k"].global_batch == 1

"""Capacity-slot scheduling parity (DESIGN.md §8, slot-gather subsection).

The contract under test: with ``cfg.cohort_cap`` set, the sharded round packs
each shard's selected residents into ``cap = min(C_loc, cohort_cap)`` slots
and trains only those — yet selects **bit-identical cohorts** (selection is
replicated at the jit level, untouched by slotting) and matches both the
unslotted sharded scan and the single-device scan to fp32 tolerance on
params / losses / metrics.

The multidevice cases run under
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the CI multidevice
job); the 1-device-mesh cases exercise the same slot gather/scatter machinery
in tier-1 on any host.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import selection as selection_lib
from repro.fl import engine, rounds as rounds_lib
from repro.fl.trainer import FLTrainer
from repro.launch.mesh import make_client_mesh

multidevice = pytest.mark.skipif(
    jax.device_count() < 2,
    reason="needs >1 device (XLA_FLAGS=--xla_force_host_platform_device_count=8)",
)

FEAT, N_C, NCLS = 8, 6, 4


def linear_loss(params, x, y):
    logp = jax.nn.log_softmax(x @ params["w"] + params["b"])
    return -jnp.mean(jnp.take_along_axis(logp, y[..., None], axis=-1))


def linear_accuracy(params, x, y):
    return jnp.mean(jnp.argmax(x @ params["w"] + params["b"], -1) == y)


def linear_features(params, x):
    h = x @ params["w"] + params["b"]
    return h, h


def _federation(c, seed=0):
    rng = np.random.default_rng(seed)
    xs = jnp.asarray(rng.normal(size=(c, N_C, FEAT)).astype(np.float32))
    ys = jnp.asarray(rng.integers(0, NCLS, size=(c, N_C)), jnp.int32)
    params = {
        "w": jnp.asarray(0.01 * rng.normal(size=(FEAT, NCLS)).astype(np.float32)),
        "b": jnp.zeros((NCLS,), jnp.float32),
    }
    return xs, ys, params


def _state_and_cfg(c, k, strategy, **cfg_kw):
    xs, ys, params = _federation(c)
    cfg = engine.FLConfig(
        num_clients=c, clients_per_round=k, local_epochs=2, lr=0.1,
        rounds=6, eval_every=2, num_classes=NCLS, seed=0, **cfg_kw,
    )
    state = engine.init_server_state(
        cfg, params, linear_loss, None, xs, ys,
        strategy=strategy, profiles=xs.mean(axis=1),
    )
    return cfg, state


def _max_param_diff(a, b):
    return max(
        float(jnp.max(jnp.abs(x.astype(jnp.float32) - y.astype(jnp.float32))))
        for x, y in zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b))
    )


def _three_way(cfg, state, mesh, cohort_cap, rounds=None):
    """(single-device, unslotted-sharded, slotted-sharded) runs of one cfg."""
    rounds = rounds or cfg.rounds
    strategy = selection_lib.DPPSelection()
    ref_fn = engine.make_round_fn(cfg, linear_loss, (strategy,),
                                  accuracy_fn=linear_accuracy)
    ref = engine.run_scanned(ref_fn, state, rounds)
    sh_fn = engine.make_round_fn(cfg, linear_loss, (strategy,),
                                 accuracy_fn=linear_accuracy, mesh=mesh)
    sh = engine.run_scanned(sh_fn, state, rounds, mesh=mesh)
    cap_cfg = dataclasses.replace(cfg, cohort_cap=cohort_cap)
    cap_fn = engine.make_round_fn(cap_cfg, linear_loss, (strategy,),
                                  accuracy_fn=linear_accuracy, mesh=mesh)
    cap = engine.run_scanned(cap_fn, state, rounds, mesh=mesh)
    return ref, sh, cap


def _assert_parity(ref, other, atol=1e-5):
    st_ref, out_ref = ref
    st_o, out_o = other
    np.testing.assert_array_equal(
        np.asarray(out_ref["selected"]), np.asarray(out_o["selected"]),
        err_msg="slotted cohorts diverged",
    )
    assert _max_param_diff(st_ref.params, st_o.params) < atol
    np.testing.assert_allclose(
        np.asarray(st_ref.losses), np.asarray(st_o.losses), atol=atol
    )
    for key in ("loss", "gemd"):
        np.testing.assert_allclose(
            np.asarray(out_ref[key]), np.asarray(out_o[key]), atol=atol
        )
    a_ref, a_o = np.asarray(out_ref["acc"]), np.asarray(out_o["acc"])
    np.testing.assert_array_equal(np.isnan(a_ref), np.isnan(a_o))
    np.testing.assert_allclose(
        a_ref[~np.isnan(a_ref)], a_o[~np.isnan(a_o)], atol=atol
    )


# ------------------------------------------------------------- multidevice


@multidevice
@pytest.mark.parametrize("local_batch_size", [None, 3])
def test_slot_parity_small_cohort(local_batch_size):
    """k ≪ C: the paper's regime — slots must not change any observable."""
    mesh = make_client_mesh(jax.device_count())
    n = jax.device_count()
    c, k = 4 * n, 3  # C_loc = 4, cap = 3 (also non-divisible C_loc/cap)
    cfg, state = _state_and_cfg(
        c, k, selection_lib.DPPSelection(), local_batch_size=local_batch_size
    )
    ref, sh, cap = _three_way(cfg, state, mesh, cohort_cap=k)
    _assert_parity(ref, cap)
    _assert_parity(sh, cap)


@multidevice
def test_slot_parity_full_participation():
    """k = C: every slot table degenerates to the full resident list."""
    mesh = make_client_mesh(jax.device_count())
    c = 2 * jax.device_count()
    cfg, state = _state_and_cfg(c, c, selection_lib.UniformSelection())
    ref, sh, cap = _three_way(cfg, state, mesh, cohort_cap=c, rounds=4)
    _assert_parity(ref, cap)
    _assert_parity(sh, cap)


@multidevice
def test_slot_trainer_parity_across_reprofile_boundary():
    """FLTrainer with cohort_cap crosses a reprofile_every segment boundary
    with the same cohorts and fp32-close history as the uncapped trainers."""
    mesh = make_client_mesh(jax.device_count())
    c = 2 * jax.device_count()
    xs, ys, params = _federation(c)
    cfg = engine.FLConfig(
        num_clients=c, clients_per_round=4, local_epochs=1, lr=0.1,
        rounds=6, eval_every=3, num_classes=NCLS, seed=0,
        reprofile_every=4,  # boundary inside the 6-round run
    )

    def trainer(cfg_arg, mesh_arg):
        return FLTrainer(
            cfg_arg, params, linear_loss, linear_features, np.asarray(xs),
            np.asarray(ys), selection_lib.DPPSelection(),
            accuracy_fn=linear_accuracy, mesh=mesh_arg,
        )

    h_ref = trainer(cfg, None).run()
    h_cap = trainer(dataclasses.replace(cfg, cohort_cap=4), mesh).run()
    assert h_ref["round"] == h_cap["round"]
    np.testing.assert_allclose(h_ref["acc"], h_cap["acc"], atol=1e-5)
    np.testing.assert_allclose(h_ref["gemd"], h_cap["gemd"], atol=1e-5)
    np.testing.assert_allclose(h_ref["loss"], h_cap["loss"], atol=1e-5)


# ------------------------------------------------- tier-1 (any device count)


def test_slot_parity_single_device_mesh():
    """The slot gather/scatter machinery runs on a 1-device mesh too (cap =
    min(C, k) = k), so tier-1 exercises it without virtual devices."""
    mesh = make_client_mesh(1)
    cfg, state = _state_and_cfg(8, 3, selection_lib.DPPSelection())
    ref, sh, cap = _three_way(cfg, state, mesh, cohort_cap=3)
    _assert_parity(ref, cap)
    _assert_parity(sh, cap)


def test_cohort_cap_validation():
    """cohort_cap < min(k, C_loc) could silently drop cohort members — the
    engine must refuse to build such a round."""
    mesh = make_client_mesh(1)
    cfg, _ = _state_and_cfg(8, 4, selection_lib.UniformSelection())
    bad = dataclasses.replace(cfg, cohort_cap=2)
    with pytest.raises(ValueError, match="cohort_cap"):
        engine.make_round_fn(bad, linear_loss, (selection_lib.UniformSelection(),),
                             mesh=mesh)


def test_shard_round_masks_noncohort_losses():
    """Satellite contract: build_shard_cohort_round returns NaN (the
    documented convention) for every resident outside the cohort, in both
    resident and slot mode — an unselected client's loss can never read as a
    cohort measurement."""
    mesh = make_client_mesh(1)
    c_loc, steps = 4, 2
    rng = np.random.default_rng(0)
    params = {"w": jnp.asarray(rng.normal(size=(FEAT, NCLS)).astype(np.float32))}

    def loss(p, batch):
        x, y = batch
        logp = jax.nn.log_softmax(x @ p["w"])
        return -jnp.mean(jnp.take_along_axis(logp, y[..., None], axis=-1))

    xb = jnp.asarray(rng.normal(size=(c_loc, steps, N_C, FEAT)).astype(np.float32))
    yb = jnp.asarray(rng.integers(0, NCLS, size=(c_loc, steps, N_C)), jnp.int32)
    weights = jnp.asarray([2.0, 0.0, 3.0, 0.0])  # clients 1, 3 not in cohort

    resident = rounds_lib.build_shard_cohort_round(loss, 0.1, engine.CLIENT_AXIS)
    body = engine._checked_shard_map(
        lambda p, b, w: resident(p, b, w)[:3], mesh=mesh,
        in_specs=(engine.P(), engine.P(engine.CLIENT_AXIS),
                  engine.P(engine.CLIENT_AXIS)),
        out_specs=(engine.P(), engine.P(engine.CLIENT_AXIS), engine.P()),
    )
    _, losses, _ = body(params, (xb, yb), weights)
    assert np.isnan(np.asarray(losses)[[1, 3]]).all()
    assert np.isfinite(np.asarray(losses)[[0, 2]]).all()

    cap = 2
    slot_index = jnp.asarray([0, 2], jnp.int32)
    slotted = rounds_lib.build_shard_cohort_round(
        loss, 0.1, engine.CLIENT_AXIS, cap=cap
    )
    body = engine._checked_shard_map(
        lambda p, b, w, s: slotted(p, b, w, s)[:3], mesh=mesh,
        in_specs=(engine.P(), engine.P(engine.CLIENT_AXIS), engine.P(),
                  engine.P(engine.CLIENT_AXIS)),
        out_specs=(engine.P(), engine.P(), engine.P()),
    )
    agg, slot_losses, mean_loss = body(
        params, (xb[:cap], yb[:cap]), weights, slot_index
    )
    sl = np.asarray(slot_losses)
    assert np.isfinite(sl[[0, 2]]).all()
    assert np.isnan(sl[[1, 3]]).all()  # never trained AND not in cohort
    assert np.isfinite(float(mean_loss))

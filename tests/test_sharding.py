"""Sharding-layer tests: spec trees mirror param/cache trees; rules resolve;
Mode-A/B step functions lower under a small mesh (in-process, 1 device)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_NAMES, get_arch
from repro.launch import sharding as sh
from repro.models import transformer as T


@pytest.mark.parametrize("name", ["granite-3-2b", "mixtral-8x7b", "rwkv6-7b",
                                  "recurrentgemma-9b", "llama4-maverick-400b-a17b"])
def test_param_spec_tree_matches_param_tree(name):
    cfg = get_arch(name).model.reduced()
    shapes = jax.eval_shape(lambda k: T.init_params(k, cfg), jax.random.key(0))
    logical = sh.param_logical_specs(cfg)
    specs = sh.specs_from_logical(logical, get_arch(name).serve_rules)
    # tree structures must match leaf-for-leaf
    jax.tree_util.tree_map(
        lambda sdt, spec: None
        if len(spec) <= len(sdt.shape)
        else pytest.fail(f"{spec} too long for {sdt.shape}"),
        shapes, specs,
        is_leaf=lambda x: isinstance(x, (jax.ShapeDtypeStruct, P)),
    )


@pytest.mark.parametrize("name", ["granite-3-2b", "rwkv6-7b", "recurrentgemma-9b"])
def test_cache_spec_tree_matches_cache_tree(name):
    cfg = get_arch(name).model.reduced()
    shapes = jax.eval_shape(lambda: T.init_caches(cfg, 2, 64))
    specs = sh.specs_from_logical(
        sh.cache_logical_specs(cfg), get_arch(name).serve_rules
    )
    flat_a = jax.tree_util.tree_structure(
        jax.tree_util.tree_map(lambda x: 0, shapes,
                               is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct)))
    flat_b = jax.tree_util.tree_structure(
        jax.tree_util.tree_map(lambda x: 0, specs,
                               is_leaf=lambda x: isinstance(x, P)))
    assert flat_a == flat_b


def test_resolve_axis_multipod():
    assert sh.resolve_axis("data", True) == ("pod", "data")
    assert sh.resolve_axis("data", False) == "data"
    assert sh.resolve_axis("model", True) == "model"
    assert sh.resolve_axis(None, True) is None


def test_constrain_is_noop_without_rules():
    x = jnp.ones((4, 4))
    y = sh.constrain(x, "act_batch", None)
    np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_constrain_applies_under_rules_and_mesh():
    axis_type = getattr(jax.sharding, "AxisType", None)  # jax >= 0.5 only
    if axis_type is not None:
        mesh = jax.make_mesh((1,), ("data",), axis_types=(axis_type.Auto,))
    else:
        mesh = jax.make_mesh((1,), ("data",))

    def f(x):
        return sh.constrain(x, "act_batch", None) * 2

    mesh_ctx = jax.set_mesh(mesh) if hasattr(jax, "set_mesh") else mesh
    with mesh_ctx, sh.use_rules({"act_batch": "data"}):
        out = jax.jit(f)(jnp.ones((4, 4)))
    np.testing.assert_allclose(np.asarray(out), 2.0)


def test_optimizer_state_specs_shapes():
    pspecs = {"w": P("data", "model"), "b": P(None)}
    adam = sh.optimizer_state_specs("adam", pspecs)
    assert adam.mu == pspecs and adam.nu == pspecs
    af = sh.optimizer_state_specs("adafactor", pspecs)
    assert af.vr["w"] == P("data")
    assert af.vc["w"] == P("model")
    assert af.vr["b"] == P(None)
    assert sh.optimizer_state_specs("sgd", pspecs) == ()


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_every_arch_logical_spec_covers_every_leaf(name):
    spec = get_arch(name)
    cfg = spec.model
    shapes = jax.eval_shape(lambda k: T.init_params(k, cfg), jax.random.key(0))
    logical = sh.param_logical_specs(cfg)
    n_shapes = len(jax.tree_util.tree_leaves(
        shapes, is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct)))
    n_specs = len(jax.tree_util.tree_leaves(logical, is_leaf=lambda x: isinstance(x, sh.Ax)))
    assert n_shapes == n_specs, (name, n_shapes, n_specs)

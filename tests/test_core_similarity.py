"""Tests for eq.-(14) similarity matrix and the L = SᵀS kernel."""

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402
from hypothesis.extra import numpy as hnp

from repro.core import similarity


def test_pairwise_matches_naive():
    rng = np.random.default_rng(0)
    f = rng.normal(size=(10, 7)).astype(np.float32)
    naive = np.linalg.norm(f[:, None, :] - f[None, :, :], axis=-1)
    got = np.asarray(similarity.pairwise_dists(jnp.asarray(f)))
    # fp32 ‖a‖²+‖b‖²−2ab expansion: allow cancellation-level error
    np.testing.assert_allclose(got, naive, atol=3e-3)
    np.testing.assert_allclose(np.diag(got), 0.0, atol=0)


def test_similarity_eq14_range_and_diagonal():
    rng = np.random.default_rng(1)
    f = rng.normal(size=(12, 5)).astype(np.float32)
    s = np.asarray(similarity.similarity_matrix(jnp.asarray(f)))
    assert (s >= -1e-6).all() and (s <= 1 + 1e-6).all()
    np.testing.assert_allclose(np.diag(s), 1.0, atol=1e-6)  # min(S0)=0 on diag
    # the most distant pair gets similarity exactly 0
    assert np.isclose(s.min(), 0.0, atol=1e-6)
    np.testing.assert_allclose(s, s.T, atol=1e-6)


@settings(max_examples=25, deadline=None)
@given(
    hnp.arrays(
        np.float32,
        hnp.array_shapes(min_dims=2, max_dims=2, min_side=2, max_side=16),
        elements=st.floats(-100, 100, width=32),
    )
)
def test_kernel_is_psd(f):
    """Property: L = SᵀS is PSD for any profile matrix."""
    kern = np.asarray(similarity.kernel_from_profiles(jnp.asarray(f)))
    eig = np.linalg.eigvalsh(kern)
    assert eig.min() >= -1e-3 * max(1.0, abs(eig).max())
    np.testing.assert_allclose(kern, kern.T, atol=1e-4)


def test_similarity_monotone_in_distance():
    """Closer profiles must be scored at least as similar (eq. 14 is affine
    decreasing in distance)."""
    f = jnp.asarray([[0.0, 0.0], [0.1, 0.0], [3.0, 0.0]])
    s = np.asarray(similarity.similarity_matrix(f))
    assert s[0, 1] > s[0, 2]

"""Bounded-staleness engine + scenario simulator (DESIGN.md §9).

Extends the ``tests/test_shard_engine.py`` parity pattern with the staleness
contract: under ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the
CI ``multidevice`` job) ``staleness_bound=0`` must reproduce the synchronous
sharded engine — bit-identical cohorts, fp32-tolerance params — and bounded
runs must respect the staleness invariants (counters ≤ bound, per-round
simulated time ≤ the synchronous barrier under the same latency draws).

Pure pieces (decay weighting, ring buffer, counter dynamics, scenario
registry, availability-masked selection) are tier-1: they run on one device.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import dpp as dpp_lib
from repro.core import selection as selection_lib
from repro.fl import engine, scenarios, staleness
from repro.fl.trainer import FLTrainer
from repro.launch.mesh import make_client_mesh

multidevice = pytest.mark.skipif(
    jax.device_count() < 2,
    reason="needs >1 device (XLA_FLAGS=--xla_force_host_platform_device_count=8)",
)

FEAT, N_C, NCLS = 8, 6, 4


def linear_loss(params, x, y):
    logp = jax.nn.log_softmax(x @ params["w"] + params["b"])
    return -jnp.mean(jnp.take_along_axis(logp, y[..., None], axis=-1))


def linear_features(params, x):
    h = x @ params["w"] + params["b"]
    return h, h


def linear_accuracy(params, x, y):
    return jnp.mean(jnp.argmax(x @ params["w"] + params["b"], -1) == y)


def _federation(c, seed=0):
    rng = np.random.default_rng(seed)
    xs = jnp.asarray(rng.normal(size=(c, N_C, FEAT)).astype(np.float32))
    ys = jnp.asarray(rng.integers(0, NCLS, size=(c, N_C)), jnp.int32)
    params = {
        "w": jnp.asarray(0.01 * rng.normal(size=(FEAT, NCLS)).astype(np.float32)),
        "b": jnp.zeros((NCLS,), jnp.float32),
    }
    return xs, ys, params


def _state_and_cfg(c, k, strategy, mesh=None, **cfg_kw):
    xs, ys, params = _federation(c)
    cfg = engine.FLConfig(
        num_clients=c, clients_per_round=k, local_epochs=2, lr=0.1,
        rounds=8, eval_every=2, num_classes=NCLS, seed=0, **cfg_kw,
    )
    state = engine.init_server_state(
        cfg, params, linear_loss, None, xs, ys,
        strategy=strategy, profiles=xs.mean(axis=1), mesh=mesh,
    )
    return cfg, state


def _max_param_diff(a, b):
    return max(
        float(jnp.max(jnp.abs(x.astype(jnp.float32) - y.astype(jnp.float32))))
        for x, y in zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b))
    )


# ------------------------------------------------------- decay weighting


@pytest.mark.parametrize("family", staleness.DECAY_FAMILIES)
def test_decay_weights_basic_contract(family):
    s = jnp.arange(6)
    lam = staleness.decay_weights(s, family, 0.7)
    lam = np.asarray(lam)
    assert np.all(lam > 0) and np.all(lam <= 1.0)
    assert lam[0] == 1.0  # λ(0) = 1 for every family: s=0 ⇒ synchronous
    assert np.all(np.diff(lam) <= 1e-7)  # non-increasing in staleness


def test_decay_weights_unknown_family():
    with pytest.raises(ValueError, match="unknown staleness decay"):
        staleness.decay_weights(jnp.arange(3), "bogus", 0.5)


def test_decay_weights_property():
    """Hypothesis: normalised weights are a distribution for every family,
    rate, and staleness vector."""
    hyp = pytest.importorskip("hypothesis")
    from hypothesis import given, settings
    from hypothesis import strategies as st

    @settings(max_examples=40, deadline=None)
    @given(
        family=st.sampled_from(staleness.DECAY_FAMILIES),
        alpha=st.floats(0.0, 5.0, allow_nan=False),
        svec=st.lists(st.integers(0, 12), min_size=1, max_size=16),
    )
    def check(family, alpha, svec):
        w = np.asarray(
            staleness.normalized_decay_weights(jnp.asarray(svec), family, alpha)
        )
        assert np.all(w >= 0)
        assert np.isclose(w.sum(), 1.0, atol=1e-5)

    check()


# -------------------------------------------------- ring buffer / dynamics


def test_param_hist_ring_semantics():
    params = {"w": jnp.arange(4.0)}
    hist = staleness.init_param_hist(params, bound=2)
    assert hist["w"].shape == (3, 4)
    # write rounds 1..4 and read them back at every reachable staleness
    for t in range(1, 5):
        hist = staleness.update_param_hist(
            hist, {"w": jnp.full((4,), float(t))}, t, bound=2
        )
    for s in range(3):
        slot = staleness.read_slots(jnp.asarray(4), jnp.asarray([s]), bound=2)
        got = hist["w"][int(slot[0]), 0]
        assert float(got) == 4.0 - s


def test_staleness_step_dynamics():
    s = jnp.asarray([0, 1, 2, 2, 0], jnp.int32)
    slow = jnp.asarray([False, True, True, False, True])
    new_s, forced = staleness.staleness_step(s, slow, bound=2)
    np.testing.assert_array_equal(np.asarray(new_s), [0, 2, 0, 0, 1])
    np.testing.assert_array_equal(np.asarray(forced), [False, False, True, False, False])
    # bound 0: every slow shard is forced every round (the sync barrier)
    new_s0, forced0 = staleness.staleness_step(
        jnp.zeros((3,), jnp.int32), jnp.asarray([True, False, True]), bound=0
    )
    np.testing.assert_array_equal(np.asarray(new_s0), [0, 0, 0])
    np.testing.assert_array_equal(np.asarray(forced0), [True, False, True])


def test_round_sim_time_semantics():
    lat = jnp.asarray([0.5, 3.0, 9.0], jnp.float32)
    slow = jnp.asarray([False, True, True])
    # no forced shard: stragglers cut off at the deadline
    t = staleness.round_sim_time(lat, slow, jnp.zeros((3,), bool), 2.0)
    assert float(t) == 2.0
    # forced shard blocks at full latency
    t = staleness.round_sim_time(lat, slow, jnp.asarray([False, False, True]), 2.0)
    assert float(t) == 9.0
    # all fast: round closes at the slowest shard, below the deadline
    t = staleness.round_sim_time(lat, jnp.zeros((3,), bool), jnp.zeros((3,), bool), 2.0)
    assert float(t) == 9.0  # slow=False everywhere ⇒ raw latencies


# ------------------------------------------------------ config validation


def test_config_rejects_cohort_cap_with_staleness():
    with pytest.raises(ValueError, match="incompatible"):
        engine.FLConfig(cohort_cap=2, staleness_bound=1, scenario="uniform")


def test_config_rejects_staleness_without_scenario():
    with pytest.raises(ValueError, match="requires a latency scenario"):
        engine.FLConfig(staleness_bound=1)


def test_config_rejects_negative_bound_and_bad_decay():
    with pytest.raises(ValueError, match="must be >= 0"):
        engine.FLConfig(staleness_bound=-1, scenario="uniform")
    with pytest.raises(ValueError, match="unknown staleness_decay"):
        engine.FLConfig(
            staleness_bound=1, scenario="uniform", staleness_decay="bogus"
        )


def test_config_rejects_unknown_scenario():
    with pytest.raises(ValueError, match="unknown scenario"):
        engine.FLConfig(scenario="does-not-exist")


def test_make_round_fn_rejects_staleness_without_mesh():
    cfg = engine.FLConfig(
        num_clients=4, clients_per_round=2, staleness_bound=1,
        scenario="uniform",
    )
    with pytest.raises(ValueError, match="requires the mesh-sharded engine"):
        engine.make_round_fn(cfg, linear_loss, (selection_lib.UniformSelection(),))


# --------------------------------------------------------- scenarios


def test_scenario_registry_deterministic():
    for name in scenarios.SCENARIO_NAMES:
        scen = scenarios.get_scenario(name)
        key = jax.random.key(3)
        a = np.asarray(scen.latency(key, 32))
        b = np.asarray(scen.latency(key, 32))
        np.testing.assert_array_equal(a, b)
        assert a.dtype == np.float32 and np.all(a > 0)
        if scen.availability is not None:
            m = np.asarray(scen.availability(key, jnp.asarray(5), 32))
            np.testing.assert_array_equal(
                m, np.asarray(scen.availability(key, jnp.asarray(5), 32))
            )
            assert m.dtype == bool
    with pytest.raises(ValueError, match="unknown scenario"):
        scenarios.get_scenario("nope")


def _masked_state(c, k, rng):
    profiles = jnp.asarray(rng.normal(size=(c, 5)).astype(np.float32))
    kernel = profiles @ profiles.T + 0.1 * jnp.eye(c)
    return selection_lib.selection_state(
        c, k,
        kernel=kernel,
        losses=jnp.asarray(rng.uniform(0.5, 2.0, size=(c,)).astype(np.float32)),
        client_sizes=jnp.full((c,), 10.0),
        cluster_labels=jnp.asarray(rng.integers(0, k, size=(c,)), jnp.int32),
    )


@pytest.mark.parametrize(
    "strat",
    [
        selection_lib.UniformSelection(),
        selection_lib.DPPSelection(),
        selection_lib.DPPSelection(mode="map"),
        selection_lib.FedSAESelection(),
        selection_lib.ClusterSelection(),
        selection_lib.PowerOfChoiceSelection(d=6),
    ],
    ids=lambda s: s.name,
)
def test_select_avail_fn_respects_mask(strat):
    """With ≥ k clients available, every pick is available; with fewer the
    draw falls back to the unmasked strategy but stays well-formed."""
    c, k = 12, 4
    rng = np.random.default_rng(0)
    state = _masked_state(c, k, rng)
    avail = jnp.asarray(rng.uniform(size=(c,)) < 0.6)
    if int(jnp.sum(avail)) < k:  # keep the test's premise
        avail = avail.at[:k].set(True)
    sel = np.asarray(strat.select_avail_fn(jax.random.key(1), state, k, avail))
    assert sel.shape == (k,)
    assert np.all(np.asarray(avail)[sel]), (sel, np.asarray(avail))
    # degenerate mask: fewer than k available -> fallback still yields k ids
    scarce = jnp.zeros((c,), bool).at[0].set(True)
    sel = np.asarray(strat.select_avail_fn(jax.random.key(2), state, k, scarce))
    assert sel.shape == (k,) and np.all((0 <= sel) & (sel < c))


def test_engine_emits_sim_time_single_device():
    """A latency-only scenario works without a mesh: sim_time = the cohort's
    synchronous barrier, and cohorts are bit-identical to a scenario-free run."""
    strategy = selection_lib.DPPSelection()
    cfg, state = _state_and_cfg(8, 3, strategy)
    scfg = dataclasses.replace(cfg, scenario="heavy_tail")
    rf = engine.make_round_fn(cfg, linear_loss, (strategy,))
    srf = engine.make_round_fn(scfg, linear_loss, (strategy,))
    _, out = engine.run_scanned(rf, state, 4)
    _, sout = engine.run_scanned(srf, state, 4)
    np.testing.assert_array_equal(
        np.asarray(out["selected"]), np.asarray(sout["selected"])
    )
    np.testing.assert_allclose(
        np.asarray(out["loss"]), np.asarray(sout["loss"]), atol=1e-6
    )
    assert np.all(np.asarray(sout["sim_time"]) > 0)


def test_engine_availability_masks_cohorts():
    """The 'flaky' scenario's availability mask rides the outputs and bounds
    the cohort whenever enough clients are up."""
    strategy = selection_lib.UniformSelection()
    cfg, state = _state_and_cfg(8, 3, strategy, scenario="flaky")
    rf = engine.make_round_fn(cfg, linear_loss, (strategy,))
    _, out = engine.run_scanned(rf, state, 8)
    avail = np.asarray(out["avail"])
    sel = np.asarray(out["selected"])
    assert avail.shape == (8, 8) and avail.dtype == bool
    for r in range(8):
        if avail[r].sum() >= 3:
            assert np.all(avail[r][sel[r]]), (r, avail[r], sel[r])


# ------------------------------------------------- sharded staleness parity


@multidevice
@pytest.mark.parametrize("strat_name", ["fl-dp3s", "fedavg"])
def test_stale_bound0_matches_synchronous(strat_name):
    """The acceptance contract: staleness_bound=0 reproduces the synchronous
    sharded engine — bit-identical cohorts, fp32-tolerance params/metrics."""
    from repro.core import make_strategy

    strategy = make_strategy(strat_name)
    mesh = make_client_mesh(jax.device_count())
    c = 2 * jax.device_count()
    cfg, state = _state_and_cfg(c, 4, strategy)
    rounds = cfg.rounds

    sync_fn = engine.make_round_fn(cfg, linear_loss, (strategy,),
                                   accuracy_fn=linear_accuracy, mesh=mesh)
    st_sync, out_sync = engine.run_scanned(sync_fn, state, rounds, mesh=mesh)

    scfg = dataclasses.replace(
        cfg, staleness_bound=0, staleness_decay="polynomial",
        scenario="heavy_tail",
    )
    xs, ys, params = _federation(c)
    sstate = engine.init_server_state(
        scfg, params, linear_loss, None, xs, ys, strategy=strategy,
        profiles=xs.mean(axis=1), mesh=mesh,
    )
    stale_fn = engine.make_round_fn(scfg, linear_loss, (strategy,),
                                    accuracy_fn=linear_accuracy, mesh=mesh)
    st_stale, out_stale = engine.run_scanned(stale_fn, sstate, rounds, mesh=mesh)

    np.testing.assert_array_equal(
        np.asarray(out_sync["selected"]), np.asarray(out_stale["selected"]),
        err_msg="staleness_bound=0 cohorts diverged from the synchronous engine",
    )
    assert _max_param_diff(st_sync.params, st_stale.params) < 1e-5
    np.testing.assert_allclose(
        np.asarray(st_sync.losses), np.asarray(st_stale.losses), atol=1e-5
    )
    for key in ("loss", "gemd"):
        np.testing.assert_allclose(
            np.asarray(out_sync[key]), np.asarray(out_stale[key]), atol=1e-5
        )
    # the bound-0 counters are pinned at zero: the sync semantics held
    assert np.all(np.asarray(st_stale.shard_staleness) == 0)


@multidevice
def test_stale_bounded_run_invariants():
    """s≥1: counters stay within the bound, stale rounds never cost more
    simulated time than the synchronous barrier under the same draws, and
    latency-only staleness leaves the cohorts untouched."""
    strategy = selection_lib.DPPSelection()
    mesh = make_client_mesh(jax.device_count())
    c = 2 * jax.device_count()
    xs, ys, params = _federation(c)
    base = dict(
        num_clients=c, clients_per_round=4, local_epochs=2, lr=0.1,
        rounds=10, eval_every=5, num_classes=NCLS, seed=0,
        scenario="heavy_tail",
    )
    cfg_sync = engine.FLConfig(**base)
    cfg_stale = engine.FLConfig(
        **base, staleness_bound=3, staleness_decay="exponential",
        staleness_alpha=0.3,
    )

    def run(cfg):
        st = engine.init_server_state(
            cfg, params, linear_loss, None, xs, ys, strategy=strategy,
            profiles=xs.mean(axis=1), mesh=mesh,
        )
        rf = engine.make_round_fn(cfg, linear_loss, (strategy,), mesh=mesh)
        return engine.run_scanned(rf, st, 10, mesh=mesh)

    st_sync, out_sync = run(cfg_sync)
    st_stale, out_stale = run(cfg_stale)

    np.testing.assert_array_equal(
        np.asarray(out_sync["selected"]), np.asarray(out_stale["selected"]),
        err_msg="a latency-only scenario must never move the cohorts",
    )
    assert np.all(np.isfinite(np.asarray(out_stale["loss"])))
    assert np.all(np.asarray(st_stale.shard_staleness) <= 3)
    assert np.all(np.asarray(out_stale["staleness"]) <= 3.0)
    sim_sync = np.asarray(out_sync["sim_time"])
    sim_stale = np.asarray(out_stale["sim_time"])
    assert np.all(sim_stale <= sim_sync + 1e-5), (sim_stale, sim_sync)


@multidevice
def test_stale_run_many_grid():
    """The staleness machinery composes with the vmapped run grid: ring
    buffers / counters ride the stacked state per grid point."""
    strategy = selection_lib.UniformSelection()
    mesh = make_client_mesh(jax.device_count())
    c = 2 * jax.device_count()
    cfg, s0 = _state_and_cfg(
        c, 4, strategy, mesh=mesh, staleness_bound=2,
        staleness_decay="polynomial", scenario="lognormal",
    )
    s1 = dataclasses.replace(s0, key=jax.random.key(123))
    stacked = engine.stack_states([s0, s1])
    rf = engine.make_round_fn(cfg, linear_loss, (strategy,), mesh=mesh)
    final, outs = engine.run_many(rf, stacked, 4, mesh=mesh)
    assert np.asarray(outs["loss"]).shape == (2, 4)
    assert np.all(np.isfinite(np.asarray(outs["loss"])))
    assert np.all(np.asarray(final.shard_staleness) <= 2)


@multidevice
def test_trainer_stale_run():
    """FLTrainer(mesh=...) drives the staleness engine through segments."""
    mesh = make_client_mesh(jax.device_count())
    c = 2 * jax.device_count()
    xs, ys, params = _federation(c)
    cfg = engine.FLConfig(
        num_clients=c, clients_per_round=4, local_epochs=1, lr=0.1,
        rounds=6, eval_every=3, num_classes=NCLS, seed=0, reprofile_every=4,
        staleness_bound=2, staleness_decay="polynomial", scenario="heavy_tail",
    )
    trainer = FLTrainer(
        cfg, params, linear_loss, linear_features, np.asarray(xs),
        np.asarray(ys), selection_lib.DPPSelection(),
        accuracy_fn=linear_accuracy, mesh=mesh,
    )
    hist = trainer.run()
    assert hist["round"] == [3, 6]
    assert np.all(np.isfinite(hist["loss"]))

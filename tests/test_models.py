"""Model-substrate tests: every block family trains (finite loss + grads) and
its cached decode path exactly matches the full forward pass."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.models import cnn
from repro.models import transformer as T

FAMILIES = {
    "dense": dict(),
    "swa": dict(block_pattern=("swa+mlp",), window=8),
    "moe": dict(
        arch_type="moe",
        block_pattern=("attn+mlp", "attn+moe"),
        num_experts=4,
        experts_per_token=2,
        num_layers=4,
        capacity_factor=4.0,  # dropless bound => decode == train path
    ),
    "geglu_softcap": dict(mlp_variant="geglu", embed_scale=True, logits_soft_cap=30.0),
    "mrope": dict(pos_style="mrope", mrope_sections=(6, 5, 5), arch_type="vlm"),
    "hybrid": dict(
        arch_type="hybrid",
        block_pattern=("rglru+mlp", "rglru+mlp", "local+mlp"),
        num_layers=8,  # tests the remainder-layer path (8 = 2*3 + 2)
        local_window=8,
        rnn_width=128,
    ),
    "rwkv": dict(arch_type="ssm", block_pattern=("rwkv+cmix",), rwkv_head_dim=32),
    "sinusoidal_ln": dict(
        pos_style="sinusoidal", norm_type="layernorm", mlp_variant="gelu",
        tie_embeddings=False,
    ),
}


def _cfg(name, **kw):
    return ModelConfig(
        name=name,
        num_layers=kw.pop("num_layers", 2),
        d_model=128,
        num_heads=4,
        num_kv_heads=2,
        head_dim=32,
        d_ff=256,
        vocab_size=256,
        arch_type=kw.pop("arch_type", "dense"),
        **kw,
    )


@pytest.mark.parametrize("family", list(FAMILIES))
def test_family_train_and_decode(family):
    cfg = _cfg(family, **FAMILIES[family])
    params = T.init_params(jax.random.key(0), cfg)
    toks = jax.random.randint(jax.random.key(1), (2, 16), 0, cfg.vocab_size)

    loss = T.lm_loss(cfg, params, toks)
    assert np.isfinite(float(loss))
    g = jax.grad(lambda p: T.lm_loss(cfg, p, toks))(params)
    gsum = sum(float(jnp.sum(jnp.abs(x))) for x in jax.tree_util.tree_leaves(g))
    assert np.isfinite(gsum) and gsum > 0

    pos = jnp.broadcast_to(jnp.arange(16)[None], (2, 16))
    if cfg.pos_style == "mrope":
        pos = jnp.broadcast_to(pos[None], (3, 2, 16))
    hid, _, _ = T.forward(cfg, params, toks, pos)
    logits_full = T.logits_from_hidden(cfg, params, hid)

    caches = T.init_caches(cfg, 2, 16)
    lg = None
    for t in range(16):
        lg, caches = T.decode_step(cfg, params, toks[:, t : t + 1], caches)
    err = float(jnp.max(jnp.abs(lg[:, 0] - logits_full[:, -1])))
    assert err < 2e-2, (family, err)


def test_prefill_then_decode_matches_full():
    """Prefill building the cache, then one decode step == full forward."""
    cfg = _cfg("dense")
    params = T.init_params(jax.random.key(0), cfg)
    toks = jax.random.randint(jax.random.key(1), (2, 17), 0, cfg.vocab_size)
    pos_full = jnp.broadcast_to(jnp.arange(17)[None], (2, 17))
    hid, _, _ = T.forward(cfg, params, toks, pos_full)
    want = T.logits_from_hidden(cfg, params, hid)[:, -1]

    caches = T.init_caches(cfg, 2, 17)
    pos_pre = pos_full[:, :16]
    _, caches, _ = T.forward(cfg, params, toks[:, :16], pos_pre, caches)
    got, _ = T.decode_step(cfg, params, toks[:, 16:17], caches)
    np.testing.assert_allclose(np.asarray(got[:, 0]), np.asarray(want), atol=2e-4)


def test_swa_matches_full_attention_within_window():
    """With window >= seq_len, SWA must equal full attention."""
    kw = dict(FAMILIES["swa"])
    cfg_full = _cfg("dense")
    cfg_swa = _cfg("swa", **{**kw, "window": 64})
    params = T.init_params(jax.random.key(0), cfg_full)
    toks = jax.random.randint(jax.random.key(1), (2, 16), 0, 256)
    l_full = T.lm_loss(cfg_full, params, toks)
    l_swa = T.lm_loss(cfg_swa, params, toks)
    np.testing.assert_allclose(float(l_full), float(l_swa), rtol=1e-5)


def test_moe_aux_loss_nonzero_and_capacity_scaling():
    cfg = _cfg("moe", **FAMILIES["moe"])
    params = T.init_params(jax.random.key(0), cfg)
    toks = jax.random.randint(jax.random.key(1), (2, 16), 0, 256)
    pos = jnp.broadcast_to(jnp.arange(16)[None], (2, 16))
    _, _, aux = T.forward(cfg, params, toks, pos)
    assert float(aux) > 0.0


def test_long_context_swa_cache_is_window_sized():
    """SWA decode cache must be O(window), not O(seq) — the long_500k story."""
    cfg = _cfg("swa", block_pattern=("swa+mlp",), window=8)
    caches = T.init_caches(cfg, batch=1, cache_len=4096)
    k = caches["unit"][0]["k"]
    assert k.shape == (2, 1, 8, 2, 32)  # (reps, B, slots=window, Hk, hd)


def test_rwkv_state_is_constant_size():
    cfg = _cfg("rwkv", **FAMILIES["rwkv"])
    caches = T.init_caches(cfg, batch=1, cache_len=1 << 19)
    sizes = [x.size for x in jax.tree_util.tree_leaves(caches)]
    assert sum(sizes) < 1e6  # O(1) in seq_len


def test_cnn_profile_feature_is_fc1_preact():
    params = cnn.init_cnn(jax.random.key(0))
    x = jnp.asarray(np.random.default_rng(0).normal(size=(4, 28, 28, 1)).astype(np.float32))
    logits, feats = cnn.apply_with_features(params, x)
    assert logits.shape == (4, 10)
    assert feats.shape == (4, 128)
    # pre-activation: must take negative values (relu'd version wouldn't)
    assert float(feats.min()) < 0


@pytest.mark.parametrize("scheme", list(cnn.INIT_SCHEMES))
def test_cnn_init_schemes(scheme):
    params = cnn.init_cnn(jax.random.key(1), scheme=scheme)
    x = jnp.zeros((2, 28, 28, 1))
    logits = cnn.apply_cnn(params, x)
    assert np.isfinite(np.asarray(logits)).all()

"""Two-stage selection funnel (DESIGN.md §10).

Contracts under test:

* **Q=C parity** — with ``candidate_frac=1.0`` the funnel is the identity
  permutation (``CandidateSet.ids == arange(C)``), so every observable —
  selected cohorts, params, losses, loss/GEMD/acc curves — must be
  **bit-identical** to the unfunneled path, for every registered strategy,
  including availability-aware scenarios, ``--shard-clients`` meshes,
  ``cohort_cap`` slots, and bounded staleness s>0.
* **candidate guard** — a round with fewer than k available *candidates*
  falls back deterministically (the shared ``availability_logits``
  convention, gathered through ``candidate_availability``) and can never
  select a non-candidate, even when plenty of non-candidates are available.
* **no C×C** — a funneled ``ServerState`` never materialises a C×C array:
  kernel, spectral cache and cluster labels all live on the Q-block.
* **shard-local Gram assembly** — ``candidate_profile_block`` on a mesh is
  bit-identical to the unsharded gather (zero-fill + one psum).
* **empty-client profiles** — ``fc1_profile`` of an empty local dataset is
  the zero profile of width Q (regression: used to TypeError on n=0).

The multidevice cases run under
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the CI multidevice
job); the 1-device-mesh cases exercise the same machinery in tier-1.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import profiles as profiles_lib
from repro.core import selection as selection_lib
from repro.core import similarity as similarity_lib
from repro.fl import engine
from repro.fl.trainer import FLTrainer
from repro.kernels.gram import ops as gram_ops
from repro.launch.mesh import make_client_mesh

multidevice = pytest.mark.skipif(
    jax.device_count() < 2,
    reason="needs >1 device (XLA_FLAGS=--xla_force_host_platform_device_count=8)",
)

FEAT, N_C, NCLS = 8, 6, 4

STRATEGIES = {
    "uniform": selection_lib.UniformSelection,
    "dpp": selection_lib.DPPSelection,
    "fedsae": selection_lib.FedSAESelection,
    "power-of-choice": lambda: selection_lib.PowerOfChoiceSelection(d=5),
    "cluster": selection_lib.ClusterSelection,
}

# run modes for the Q=C parity sweep; "mesh" requests a 1-device client mesh
# (tier-1-safe; the multidevice job reruns the sweep on the full mesh)
MODES = {
    "plain": {},
    "avail": {"scenario": "flaky"},  # availability-aware select path
    "sharded": {"mesh": True},
    "cohort-cap": {"mesh": True, "cohort_cap": 3},
    "stale": {"mesh": True, "scenario": "heavy_tail", "staleness_bound": 1},
}


def linear_loss(params, x, y):
    logp = jax.nn.log_softmax(x @ params["w"] + params["b"])
    return -jnp.mean(jnp.take_along_axis(logp, y[..., None], axis=-1))


def linear_accuracy(params, x, y):
    return jnp.mean(jnp.argmax(x @ params["w"] + params["b"], -1) == y)


def linear_features(params, x):
    h = x @ params["w"] + params["b"]
    return h, h


def _federation(c, seed=0):
    rng = np.random.default_rng(seed)
    xs = jnp.asarray(rng.normal(size=(c, N_C, FEAT)).astype(np.float32))
    ys = jnp.asarray(rng.integers(0, NCLS, size=(c, N_C)), jnp.int32)
    params = {
        "w": jnp.asarray(0.01 * rng.normal(size=(FEAT, NCLS)).astype(np.float32)),
        "b": jnp.zeros((NCLS,), jnp.float32),
    }
    return xs, ys, params


def _run(strategy_factory, frac, c=8, k=3, rounds=4, mesh=None, **cfg_kw):
    xs, ys, params = _federation(c)
    cfg = engine.FLConfig(
        num_clients=c, clients_per_round=k, local_epochs=1, lr=0.1,
        rounds=rounds, eval_every=2, num_classes=NCLS, seed=0,
        candidate_frac=frac, **cfg_kw,
    )
    strat = strategy_factory()
    state = engine.init_server_state(
        cfg, params, linear_loss, None, xs, ys,
        strategy=strat, profiles=xs.mean(axis=1), mesh=mesh,
    )
    fn = engine.make_round_fn(
        cfg, linear_loss, (strat,), accuracy_fn=linear_accuracy, mesh=mesh
    )
    return cfg, state, engine.run_scanned(fn, state, rounds, mesh=mesh)


def _assert_bit_identical(ref, fun):
    """Every observable identical to the last bit (NaN == NaN positionally)."""
    st_r, out_r = ref
    st_f, out_f = fun
    np.testing.assert_array_equal(
        np.asarray(out_r["selected"]), np.asarray(out_f["selected"]),
        err_msg="Q=C funnel cohorts diverged from unfunneled",
    )
    for a, b in zip(
        jax.tree_util.tree_leaves(st_r.params),
        jax.tree_util.tree_leaves(st_f.params),
    ):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(
        np.asarray(st_r.losses), np.asarray(st_f.losses)
    )
    for key in ("loss", "gemd", "acc"):
        np.testing.assert_array_equal(
            np.asarray(out_r[key]), np.asarray(out_f[key]), err_msg=key
        )


# --------------------------------------------------- Q=C parity (tentpole)


@pytest.mark.parametrize("mode", sorted(MODES))
@pytest.mark.parametrize("name", sorted(STRATEGIES))
def test_q_equals_c_bit_identical(name, mode):
    kw = dict(MODES[mode])
    mesh = make_client_mesh(1) if kw.pop("mesh", False) else None
    _, _, ref = _run(STRATEGIES[name], None, mesh=mesh, **kw)
    _, state, fun = _run(STRATEGIES[name], 1.0, mesh=mesh, **kw)
    np.testing.assert_array_equal(np.asarray(state.candidates), np.arange(8))
    _assert_bit_identical(ref, fun)


@multidevice
@pytest.mark.parametrize("mode", ["sharded", "cohort-cap", "stale"])
def test_q_equals_c_bit_identical_multidevice(mode):
    kw = dict(MODES[mode])
    kw.pop("mesh")
    n = jax.device_count()
    mesh = make_client_mesh(n)
    _, _, ref = _run(selection_lib.DPPSelection, None, c=4 * n, mesh=mesh, **kw)
    _, _, fun = _run(selection_lib.DPPSelection, 1.0, c=4 * n, mesh=mesh, **kw)
    _assert_bit_identical(ref, fun)


# ------------------------------------------------ funnelled runs with Q < C


def test_funnel_selects_only_candidates_and_no_cxc():
    """frac<1: cohorts live inside the candidate set; no state leaf is C×C."""
    c, k = 64, 4
    cfg, state, (st, outs) = _run(
        selection_lib.DPPSelection, 0.25, c=c, k=k, rounds=5
    )
    q = cfg.candidate_count()
    assert q == 16
    assert state.kernel.shape == (q, q)
    assert state.candidates.shape == (q,)
    cand = np.asarray(state.candidates)
    assert (np.diff(cand) > 0).all()  # ascending, unique global ids
    for leaf in jax.tree_util.tree_leaves(state):
        shape = getattr(leaf, "shape", ())
        assert not (len(shape) >= 2 and shape[0] == c and shape[1] == c), (
            f"funneled state materialised a C×C array: {shape}"
        )
    sel = np.asarray(outs["selected"])
    assert sel.shape == (5, k)
    assert np.isin(sel, cand).all(), "selected a non-candidate"


def test_funnel_prefers_high_loss_candidates():
    """The stage-1 score is loss-driven: with unit latency/availability the
    candidate set is exactly the top-Q-by-loss clients."""
    losses = jnp.asarray([0.1, 5.0, 0.2, 4.0, 3.0, 0.3, 2.0, 1.0])
    scores = selection_lib.funnel_scores(losses)
    cand = selection_lib.funnel_candidates(scores, 4)
    np.testing.assert_array_equal(np.asarray(cand), [1, 3, 4, 6])


def test_funnel_scores_signals():
    losses = jnp.asarray([1.0, 1.0, 1.0, 1.0])
    # availability zeroes a client out entirely
    avail = jnp.asarray([True, False, True, True])
    s = selection_lib.funnel_scores(losses, avail=avail)
    assert float(s[1]) == 0.0 and float(s[0]) > 0.0
    # latency demotes stragglers monotonically
    lat = jnp.asarray([0.0, 1.0, 3.0, 9.0])
    s = selection_lib.funnel_scores(losses, latency=lat)
    assert (np.diff(np.asarray(s)) < 0).all()
    # non-positive losses clamp to eps, never to a negative score
    s = selection_lib.funnel_scores(jnp.asarray([-1.0, 0.0]))
    assert (np.asarray(s) > 0).all()


def test_funnel_candidates_identity_at_q_equals_c():
    scores = selection_lib.funnel_scores(jnp.asarray([3.0, 1.0, 2.0, 5.0]))
    cand = selection_lib.funnel_candidates(scores, 4)
    np.testing.assert_array_equal(np.asarray(cand), np.arange(4))
    assert cand.dtype == jnp.int32


# ------------------------------------- availability guard (satellite #2)


@pytest.mark.parametrize("name", sorted(STRATEGIES))
def test_avail_fallback_respects_candidate_set(name):
    """<k available candidates ⇒ the deterministic unmasked-candidate draw —
    never a non-candidate, even with every non-candidate available."""
    c, q, k = 16, 6, 4
    rng = np.random.default_rng(3)
    profiles = jnp.asarray(rng.normal(size=(c, FEAT)).astype(np.float32))
    losses = jnp.asarray(rng.uniform(0.5, 2.0, size=(c,)).astype(np.float32))
    cand = selection_lib.funnel_candidates(selection_lib.funnel_scores(losses), q)
    state = selection_lib.selection_state(
        q, k,
        kernel=similarity_lib.candidate_kernel(profiles, cand),
        losses=jnp.take(losses, cand),
        client_sizes=jnp.full((q,), float(N_C)),
        decompose_kernel=True,
        candidates=selection_lib.CandidateSet(ids=cand),
    )
    # only 2 (< k) candidates available; every NON-candidate is available
    avail = jnp.ones((c,), bool).at[cand].set(False).at[cand[:2]].set(True)
    assert int(jnp.sum(selection_lib.candidate_availability(avail, state.candidates))) == 2
    strat = STRATEGIES[name]()
    key = jax.random.key(7)
    sel_few = strat.select_global_fn(key, state, k, avail=avail)
    assert np.isin(np.asarray(sel_few), np.asarray(cand)).all(), (
        f"{name}: fallback escaped the candidate set"
    )
    # the fallback is exactly the draw with an all-available mask (the
    # availability_logits convention, posed in candidate space)
    sel_all = strat.select_global_fn(key, state, k, avail=jnp.ones((c,), bool))
    np.testing.assert_array_equal(np.asarray(sel_few), np.asarray(sel_all))


def test_candidate_availability_gather():
    avail = jnp.asarray([True, False, True, False, True])
    cand = selection_lib.CandidateSet(ids=jnp.asarray([1, 2, 4], jnp.int32))
    np.testing.assert_array_equal(
        np.asarray(selection_lib.candidate_availability(avail, cand)),
        [False, True, True],
    )


# ----------------------------------------- empty-client profile (satellite #1)


def test_fc1_profile_empty_dataset():
    """Regression: n=0 used to TypeError (``total`` never assigned); the
    contract is the zero profile of width Q so stacking still works."""
    params = {
        "w": jnp.ones((FEAT, 5), jnp.float32),
        "b": jnp.zeros((5,), jnp.float32),
    }

    def feat(p, x):
        h = x @ p["w"] + p["b"]
        return h, h

    p = profiles_lib.fc1_profile(feat, params, jnp.zeros((0, FEAT)))
    assert p.shape == (5,)
    assert (np.asarray(p) == 0.0).all()
    stacked = profiles_lib.profile_all_clients(
        feat, params, [jnp.zeros((0, FEAT)), jnp.ones((3, FEAT))]
    )
    assert stacked.shape == (2, 5)
    assert np.isfinite(np.asarray(stacked)).all()


# ------------------------------------------------ candidate Gram (kernels)


def test_candidate_kernel_matches_gathered_pipeline():
    """candidate_kernel == eq.-(14) pipeline on the gathered rows — exactly,
    for both the jnp path and the fused Pallas path (ragged Q=11)."""
    rng = np.random.default_rng(0)
    f = jnp.asarray(rng.normal(size=(37, 16)).astype(np.float32))
    cand = selection_lib.funnel_candidates(
        selection_lib.funnel_scores(jnp.asarray(rng.uniform(size=(37,)))), 11
    )
    fq = jnp.take(f, cand, axis=0)
    got = similarity_lib.candidate_kernel(f, cand)
    np.testing.assert_array_equal(
        np.asarray(got), np.asarray(similarity_lib.kernel_from_profiles(fq))
    )
    got_pallas = similarity_lib.candidate_kernel(f, cand, use_kernel=True)
    # same Pallas pipeline, same tile geometry ⇒ bit-identical to the direct
    # fused call on the gathered rows …
    np.testing.assert_array_equal(
        np.asarray(got_pallas), np.asarray(gram_ops.kernel_from_profiles(fq))
    )
    # … and numerically tight against the jnp oracle
    np.testing.assert_allclose(
        np.asarray(got_pallas), np.asarray(got), atol=2e-5
    )


def test_candidate_kernel_is_not_a_cxc_submatrix():
    """min-max normalisation runs over the candidate block — slicing the full
    C×C kernel would use the WRONG normalisation constants."""
    rng = np.random.default_rng(1)
    f = jnp.asarray(rng.normal(size=(12, 6)).astype(np.float32))
    cand = jnp.asarray([0, 3, 5, 9], jnp.int32)
    block = np.asarray(similarity_lib.candidate_kernel(f, cand))
    full = np.asarray(similarity_lib.kernel_from_profiles(f))
    sub = full[np.ix_(np.asarray(cand), np.asarray(cand))]
    assert not np.allclose(block, sub, atol=1e-6)


def test_candidate_profile_block_mesh_matches_gather():
    """Zero-fill + one psum on a mesh == the plain unsharded take, bitwise."""
    rng = np.random.default_rng(2)
    profiles = jnp.asarray(rng.normal(size=(16, FEAT)).astype(np.float32))
    cand = jnp.asarray([1, 4, 7, 9, 12, 15], jnp.int32)
    ref = engine.candidate_profile_block(profiles, cand)
    got = engine.candidate_profile_block(
        profiles, cand, mesh=make_client_mesh(1)
    )
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


@multidevice
def test_candidate_profile_block_multidevice():
    n = jax.device_count()
    rng = np.random.default_rng(2)
    profiles = jnp.asarray(rng.normal(size=(4 * n, FEAT)).astype(np.float32))
    cand = selection_lib.funnel_candidates(
        selection_lib.funnel_scores(
            jnp.asarray(rng.uniform(size=(4 * n,)).astype(np.float32))
        ),
        2 * n,
    )
    ref = engine.candidate_profile_block(profiles, cand)
    got = engine.candidate_profile_block(
        profiles, cand, mesh=make_client_mesh(n)
    )
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


# --------------------------------------------------------- config contracts


def test_candidate_frac_validation():
    def cfg(frac, k=2):
        return engine.FLConfig(
            num_clients=8, clients_per_round=k, local_epochs=1, lr=0.1,
            rounds=1, eval_every=1, num_classes=NCLS, seed=0,
            candidate_frac=frac,
        )

    for bad in (0.0, -0.5, 1.5):
        with pytest.raises(ValueError, match="candidate_frac"):
            cfg(bad)
    # Q clamps to [k, C]: a cohort must always fit in the candidate set
    assert cfg(0.01, k=4).candidate_count() == 4
    assert cfg(1.0).candidate_count() == 8
    assert cfg(0.5).candidate_count() == 4


def test_init_rejects_precomputed_kernel_under_funnel():
    xs, ys, params = _federation(8)
    cfg = engine.FLConfig(
        num_clients=8, clients_per_round=2, local_epochs=1, lr=0.1,
        rounds=1, eval_every=1, num_classes=NCLS, seed=0, candidate_frac=0.5,
    )
    with pytest.raises(ValueError, match="funnel-owned"):
        engine.init_server_state(
            cfg, params, linear_loss, None, xs, ys,
            strategy=selection_lib.DPPSelection(),
            profiles=xs.mean(axis=1), kernel=jnp.eye(8),
        )


# ------------------------------------------------------------- FLTrainer


def _trainer(cfg, seed=0):
    xs, ys, params = _federation(cfg.num_clients, seed=seed)
    return FLTrainer(
        cfg, params, linear_loss, linear_features, np.asarray(xs),
        np.asarray(ys), selection_lib.DPPSelection(),
        accuracy_fn=linear_accuracy,
    )


def test_trainer_q_equals_c_parity_across_reprofile():
    """FLTrainer with frac=1.0 crosses a reprofile boundary (re-funnel) with
    bit-identical history to the unfunneled trainer."""
    cfg = engine.FLConfig(
        num_clients=8, clients_per_round=3, local_epochs=1, lr=0.1,
        rounds=5, eval_every=2, num_classes=NCLS, seed=0,
        reprofile_every=3,  # boundary (and re-funnel) inside the run
    )
    h_ref = _trainer(cfg).run()
    h_fun = _trainer(dataclasses.replace(cfg, candidate_frac=1.0)).run()
    assert h_ref["round"] == h_fun["round"]
    for key in ("loss", "gemd", "acc"):
        np.testing.assert_array_equal(
            np.asarray(h_ref[key]), np.asarray(h_fun[key]), err_msg=key
        )


def test_trainer_refunnels_each_segment():
    """frac<1: each reprofile segment re-runs stage 1 on the evolved losses;
    the run stays finite and the final state is still candidate-space."""
    cfg = engine.FLConfig(
        num_clients=16, clients_per_round=3, local_epochs=1, lr=0.1,
        rounds=6, eval_every=3, num_classes=NCLS, seed=0,
        reprofile_every=3, candidate_frac=0.5,
    )
    tr = _trainer(cfg)
    h = tr.run()
    # history records the eval grid (t % eval_every == 0 plus the final
    # round), not every round
    assert len(h["loss"]) == len(h["round"]) >= 2
    assert h["round"][-1] == cfg.rounds
    assert np.isfinite(np.asarray(h["loss"])).all()

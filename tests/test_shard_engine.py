"""Mesh-sharded engine parity (DESIGN.md §8).

Run under ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the CI
``multidevice`` job) — with a single visible device the mesh tests skip.

The contract under test: for any mesh size, the sharded execution path picks
**bit-identical cohorts** (selection stays replicated: same kernel, same
spectral cache, same key chain) and matches the single-device scan's params /
losses / metrics to fp32 tolerance (eq.-(6) is re-associated into per-shard
partial sums + psum).
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import selection as selection_lib
from repro.core import similarity as similarity_lib
from repro.fl import engine
from repro.fl.trainer import FLTrainer
from repro.launch.mesh import make_client_mesh

multidevice = pytest.mark.skipif(
    jax.device_count() < 2,
    reason="needs >1 device (XLA_FLAGS=--xla_force_host_platform_device_count=8)",
)

FEAT, N_C, NCLS = 8, 6, 4


def linear_loss(params, x, y):
    logp = jax.nn.log_softmax(x @ params["w"] + params["b"])
    return -jnp.mean(jnp.take_along_axis(logp, y[..., None], axis=-1))


def linear_accuracy(params, x, y):
    return jnp.mean(jnp.argmax(x @ params["w"] + params["b"], -1) == y)


def linear_features(params, x):
    h = x @ params["w"] + params["b"]
    return h, h


def _federation(c, seed=0):
    rng = np.random.default_rng(seed)
    xs = jnp.asarray(rng.normal(size=(c, N_C, FEAT)).astype(np.float32))
    ys = jnp.asarray(rng.integers(0, NCLS, size=(c, N_C)), jnp.int32)
    params = {
        "w": jnp.asarray(0.01 * rng.normal(size=(FEAT, NCLS)).astype(np.float32)),
        "b": jnp.zeros((NCLS,), jnp.float32),
    }
    return xs, ys, params


def _mesh():
    n = jax.device_count()
    return make_client_mesh(n), n


def _state_and_cfg(c, k, strategy, **cfg_kw):
    xs, ys, params = _federation(c)
    cfg = engine.FLConfig(
        num_clients=c, clients_per_round=k, local_epochs=2, lr=0.1,
        rounds=8, eval_every=2, num_classes=NCLS, seed=0, **cfg_kw,
    )
    state = engine.init_server_state(
        cfg, params, linear_loss, None, xs, ys,
        strategy=strategy, profiles=xs.mean(axis=1),
    )
    return cfg, state


def _max_param_diff(a, b):
    return max(
        float(jnp.max(jnp.abs(x.astype(jnp.float32) - y.astype(jnp.float32))))
        for x, y in zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b))
    )


@multidevice
@pytest.mark.parametrize("strat_name", ["fl-dp3s", "fedavg"])
def test_scanned_parity_vs_single_device(strat_name):
    """Cohorts bit-identical, params/metrics within fp32 tolerance."""
    from repro.core import make_strategy

    strategy = make_strategy(strat_name)
    mesh, n = _mesh()
    c = 2 * n  # two resident clients per shard
    cfg, state = _state_and_cfg(c, 4, strategy)
    rounds = cfg.rounds

    ref_fn = engine.make_round_fn(cfg, linear_loss, (strategy,),
                                  accuracy_fn=linear_accuracy)
    st_ref, out_ref = engine.run_scanned(ref_fn, state, rounds)

    sh_fn = engine.make_round_fn(cfg, linear_loss, (strategy,),
                                 accuracy_fn=linear_accuracy, mesh=mesh)
    st_sh, out_sh = engine.run_scanned(sh_fn, state, rounds, mesh=mesh)

    np.testing.assert_array_equal(
        np.asarray(out_ref["selected"]), np.asarray(out_sh["selected"]),
        err_msg="sharded cohorts diverged from the single-device scan",
    )
    assert _max_param_diff(st_ref.params, st_sh.params) < 1e-5
    np.testing.assert_allclose(
        np.asarray(st_ref.losses), np.asarray(st_sh.losses), atol=1e-5
    )
    for key in ("loss", "gemd"):
        np.testing.assert_allclose(
            np.asarray(out_ref[key]), np.asarray(out_sh[key]), atol=1e-5
        )
    # same eval grid: NaN off-rounds, matching accuracy on eval rounds
    a_ref, a_sh = np.asarray(out_ref["acc"]), np.asarray(out_sh["acc"])
    np.testing.assert_array_equal(np.isnan(a_ref), np.isnan(a_sh))
    np.testing.assert_allclose(
        a_ref[~np.isnan(a_ref)], a_sh[~np.isnan(a_sh)], atol=1e-5
    )


@multidevice
def test_scanned_parity_minibatch_permutations():
    """Per-client permutation batches follow the cohort-slot keys exactly."""
    strategy = selection_lib.DPPSelection()
    mesh, n = _mesh()
    cfg, state = _state_and_cfg(2 * n, 4, strategy, local_batch_size=3)

    ref_fn = engine.make_round_fn(cfg, linear_loss, (strategy,))
    st_ref, out_ref = engine.run_scanned(ref_fn, state, cfg.rounds)
    sh_fn = engine.make_round_fn(cfg, linear_loss, (strategy,), mesh=mesh)
    st_sh, out_sh = engine.run_scanned(sh_fn, state, cfg.rounds, mesh=mesh)

    np.testing.assert_array_equal(
        np.asarray(out_ref["selected"]), np.asarray(out_sh["selected"])
    )
    assert _max_param_diff(st_ref.params, st_sh.params) < 1e-5


@multidevice
def test_full_participation_cohort():
    """k = C (the selection-light scaling regime): every shard trains all
    residents; aggregate must match the gathered path."""
    strategy = selection_lib.UniformSelection()
    mesh, n = _mesh()
    c = n
    cfg, state = _state_and_cfg(c, c, strategy)

    ref_fn = engine.make_round_fn(cfg, linear_loss, (strategy,))
    st_ref, out_ref = engine.run_scanned(ref_fn, state, 4)
    sh_fn = engine.make_round_fn(cfg, linear_loss, (strategy,), mesh=mesh)
    st_sh, out_sh = engine.run_scanned(sh_fn, state, 4, mesh=mesh)

    np.testing.assert_array_equal(
        np.asarray(out_ref["selected"]), np.asarray(out_sh["selected"])
    )
    assert _max_param_diff(st_ref.params, st_sh.params) < 1e-5
    np.testing.assert_allclose(
        np.asarray(out_ref["loss"]), np.asarray(out_sh["loss"]), atol=1e-5
    )


@multidevice
def test_trainer_parity_across_reprofile_boundary():
    """FLTrainer(mesh=...) crosses a reprofile_every segment boundary with the
    same cohorts and fp32-close history as the single-device trainer."""
    mesh, n = _mesh()
    c = 2 * n
    xs, ys, params = _federation(c)
    cfg = engine.FLConfig(
        num_clients=c, clients_per_round=4, local_epochs=1, lr=0.1,
        rounds=6, eval_every=3, num_classes=NCLS, seed=0,
        reprofile_every=4,  # boundary inside the 6-round run
    )

    def trainer(mesh_arg):
        return FLTrainer(
            cfg, params, linear_loss, linear_features, np.asarray(xs),
            np.asarray(ys), selection_lib.DPPSelection(),
            accuracy_fn=linear_accuracy, mesh=mesh_arg,
        )

    h_ref = trainer(None).run()
    h_sh = trainer(mesh).run()
    assert h_ref["round"] == h_sh["round"]
    np.testing.assert_allclose(h_ref["acc"], h_sh["acc"], atol=1e-5)
    np.testing.assert_allclose(h_ref["gemd"], h_sh["gemd"], atol=1e-5)
    np.testing.assert_allclose(h_ref["loss"], h_sh["loss"], atol=1e-5)


@multidevice
def test_run_many_sharded_matches_unsharded():
    """The vmapped grid composes with the client mesh (batch axis replicated,
    client axis sharded)."""
    strategy = selection_lib.DPPSelection()
    mesh, n = _mesh()
    cfg, s0 = _state_and_cfg(2 * n, 4, strategy)
    s1 = dataclasses.replace(s0, key=jax.random.key(123))
    stacked = engine.stack_states([s0, s1])

    ref_fn = engine.make_round_fn(cfg, linear_loss, (strategy,))
    _, out_ref = engine.run_many(ref_fn, stacked, 4)
    sh_fn = engine.make_round_fn(cfg, linear_loss, (strategy,), mesh=mesh)
    _, out_sh = engine.run_many(sh_fn, stacked, 4, mesh=mesh)

    np.testing.assert_array_equal(
        np.asarray(out_ref["selected"]), np.asarray(out_sh["selected"])
    )
    np.testing.assert_allclose(
        np.asarray(out_ref["loss"]), np.asarray(out_sh["loss"]), atol=1e-5
    )


@multidevice
def test_shard_server_state_layout():
    """Client fields land sharded over the mesh axis, the rest replicated."""
    mesh, n = _mesh()
    cfg, state = _state_and_cfg(2 * n, 4, selection_lib.UniformSelection())
    sharded = engine.shard_server_state(state, mesh)

    for f in engine.CLIENT_SHARDED_FIELDS:
        arr = getattr(sharded, f)
        if arr is None:  # optional per-client state (algo_state for fedavg)
            continue
        shard_shapes = {s.data.shape for s in arr.addressable_shards}
        assert len(shard_shapes) == 1
        assert next(iter(shard_shapes))[0] == arr.shape[0] // n, f
    # kernel replicated: every device holds the full (C, C) Gram matrix
    kern_shards = {s.data.shape for s in sharded.kernel.addressable_shards}
    assert kern_shards == {sharded.kernel.shape}


def test_shard_server_state_divisibility_error():
    mesh = make_client_mesh(jax.device_count())
    if mesh.shape[engine.CLIENT_AXIS] == 1:
        pytest.skip("needs >1 device for a real divisibility constraint")
    cfg, state = _state_and_cfg(
        mesh.shape[engine.CLIENT_AXIS] + 1, 2, selection_lib.UniformSelection()
    )
    with pytest.raises(ValueError, match="not divisible"):
        engine.shard_server_state(state, mesh)


def test_client_batches_from_keys_matches_gathered():
    """Single-device identity: make_client_batches == take + from_keys."""
    c, k = 6, 3
    xs, ys, _ = _federation(c)
    cfg = engine.FLConfig(
        num_clients=c, clients_per_round=k, local_epochs=2,
        local_batch_size=2, num_classes=NCLS,
    )
    key = jax.random.key(7)
    sel = jnp.asarray([4, 0, 2], jnp.int32)
    ref = engine.make_client_batches(cfg, key, xs, ys, sel)
    keys = jax.random.split(key, k)
    alt = engine.client_batches_from_keys(
        cfg, keys, jnp.take(xs, sel, 0), jnp.take(ys, sel, 0)
    )
    for a, b in zip(ref, alt):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

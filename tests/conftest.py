import pytest


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: spawns subprocess dry-runs (512 host devices)"
    )

"""Tests for the k-DPP sampler (paper §3.2, eq. 12-13)."""

import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

from repro.core import dpp, similarity


def _random_kernel(rng, c, q=5):
    f = rng.normal(size=(c, q)).astype(np.float32)
    return np.asarray(similarity.kernel_from_profiles(jnp.asarray(f)))


def test_elementary_symmetric_matches_numpy():
    lam = jnp.asarray([1.0, 2.0, 3.0, 4.0])
    e = dpp.elementary_symmetric(lam, 3)
    # e_1 = 10, e_2 = 35, e_3 = 50 over all four
    assert np.isclose(e[1, 4], 10.0)
    assert np.isclose(e[2, 4], 35.0)
    assert np.isclose(e[3, 4], 50.0)
    assert np.allclose(np.asarray(e[0, :]), 1.0)


@pytest.mark.parametrize("k", [1, 2, 3])
def test_kdpp_matches_bruteforce_distribution(k):
    rng = np.random.default_rng(0)
    c = 5
    kern = _random_kernel(rng, c)
    subsets = list(itertools.combinations(range(c), k))
    dets = np.array([max(np.linalg.det(kern[np.ix_(s, s)]), 0.0) for s in subsets])
    p_true = dets / dets.sum()

    ns = 1500
    keys = jax.random.split(jax.random.key(k), ns)
    out = np.asarray(jax.vmap(lambda kk: dpp.sample_kdpp(kk, jnp.asarray(kern), k))(keys))
    counts = {s: 0 for s in subsets}
    for row in out:
        s = tuple(sorted(row.tolist()))
        assert len(set(s)) == k  # always k distinct items
        counts[s] += 1
    p_emp = np.array([counts[s] / ns for s in subsets])
    tv = 0.5 * np.abs(p_emp - p_true).sum()
    assert tv < 0.08, (tv, p_true, p_emp)


def test_greedy_map_finds_argmax_on_small_instance():
    rng = np.random.default_rng(1)
    kern = _random_kernel(rng, 7)
    k = 3
    subsets = list(itertools.combinations(range(7), k))
    dets = np.array([np.linalg.det(kern[np.ix_(s, s)]) for s in subsets])
    best = set(subsets[int(np.argmax(dets))])
    got = set(np.asarray(dpp.greedy_map_kdpp(jnp.asarray(kern), k)).tolist())
    # greedy is not guaranteed optimal, but must be distinct, size-k and
    # within a constant factor of optimal on these easy instances.
    assert len(got) == k
    got_det = np.linalg.det(kern[np.ix_(sorted(got), sorted(got))])
    # Greedy MAP is a (1/e)-style approximation, not exact — require the
    # chosen subset to be within a constant factor of the true optimum.
    assert got_det >= 0.25 * dets.max(), (got, best, got_det, dets.max())


@settings(max_examples=15, deadline=None)
@given(
    c=st.integers(min_value=3, max_value=12),
    k=st.integers(min_value=1, max_value=4),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_kdpp_property_distinct_and_in_range(c, k, seed):
    """Property: samples are always k distinct, in-range indices."""
    k = min(k, c)
    rng = np.random.default_rng(seed)
    kern = _random_kernel(rng, c)
    idx = np.asarray(dpp.sample_kdpp(jax.random.key(seed), jnp.asarray(kern), k))
    assert idx.shape == (k,)
    assert len(set(idx.tolist())) == k
    assert (idx >= 0).all() and (idx < c).all()


def test_kdpp_repels_duplicates():
    """Two identical clients should (almost) never be co-selected."""
    rng = np.random.default_rng(2)
    f = rng.normal(size=(6, 8)).astype(np.float32)
    f[1] = f[0]  # duplicate client
    kern = jnp.asarray(np.asarray(similarity.kernel_from_profiles(jnp.asarray(f))))
    keys = jax.random.split(jax.random.key(0), 300)
    out = np.asarray(jax.vmap(lambda kk: dpp.sample_kdpp(kk, kern, 2))(keys))
    both = sum(1 for row in out if set(row.tolist()) == {0, 1})
    assert both <= 3  # det of the {0,1} submatrix is ~0


def test_log_det_subset():
    rng = np.random.default_rng(3)
    kern = _random_kernel(rng, 6)
    idx = jnp.asarray([0, 2, 4])
    want = np.linalg.slogdet(kern[np.ix_([0, 2, 4], [0, 2, 4])])[1]
    got = dpp.log_det_subset(jnp.asarray(kern), idx)
    assert np.isclose(got, want, rtol=1e-4)

"""Checkpoint save/restore contracts.

The regression this pins: ``checkpoint.restore`` used to unflatten whatever
arrays it found against the template's treedef — a snapshot from a different
config (different leaf count / shapes / dtypes) silently became garbage
state.  Now every mismatch raises a descriptive ``ValueError``.
"""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint


def _tree():
    return {
        "w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
        "b": jnp.ones((4,), jnp.float32),
        "step": jnp.asarray(7, jnp.int32),
    }


def test_roundtrip(tmp_path):
    tree = _tree()
    path = checkpoint.save(str(tmp_path), 3, tree)
    assert os.path.isdir(path)
    out = checkpoint.restore(str(tmp_path), tree)
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(out)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
        assert np.asarray(a).dtype == np.asarray(b).dtype


def test_latest_step(tmp_path):
    assert checkpoint.latest_step(str(tmp_path)) is None
    tree = _tree()
    checkpoint.save(str(tmp_path), 2, tree)
    checkpoint.save(str(tmp_path), 10, tree)
    assert checkpoint.latest_step(str(tmp_path)) == 10
    with pytest.raises(FileNotFoundError):
        checkpoint.restore(str(tmp_path / "empty"), tree)


def test_restore_rejects_leaf_count_mismatch(tmp_path):
    checkpoint.save(str(tmp_path), 1, _tree())
    smaller = {"w": jnp.zeros((3, 4), jnp.float32)}
    with pytest.raises(ValueError, match="leaves"):
        checkpoint.restore(str(tmp_path), smaller)


def test_restore_rejects_shape_mismatch(tmp_path):
    checkpoint.save(str(tmp_path), 1, _tree())
    other = dict(_tree(), w=jnp.zeros((4, 3), jnp.float32))  # same size!
    with pytest.raises(ValueError, match="shape"):
        checkpoint.restore(str(tmp_path), other)


def test_restore_rejects_dtype_mismatch(tmp_path):
    checkpoint.save(str(tmp_path), 1, _tree())
    other = dict(_tree(), b=jnp.ones((4,), jnp.int32))
    with pytest.raises(ValueError, match="dtype"):
        checkpoint.restore(str(tmp_path), other)


def test_restore_rejects_corrupt_meta(tmp_path):
    tree = _tree()
    path = checkpoint.save(str(tmp_path), 1, tree)
    meta_path = os.path.join(path, "tree.json")
    with open(meta_path) as f:
        meta = json.load(f)
    meta["num_leaves"] = 99
    with open(meta_path, "w") as f:
        json.dump(meta, f)
    with pytest.raises(ValueError, match="corrupt checkpoint"):
        checkpoint.restore(str(tmp_path), tree)


def test_restore_rejects_meta_shape_drift(tmp_path):
    # tree.json disagreeing with arrays.npz is corruption even when the
    # arrays happen to match the template
    tree = _tree()
    path = checkpoint.save(str(tmp_path), 1, tree)
    meta_path = os.path.join(path, "tree.json")
    with open(meta_path) as f:
        meta = json.load(f)
    meta["shapes"][0] = [999]
    with open(meta_path, "w") as f:
        json.dump(meta, f)
    with pytest.raises(ValueError, match="corrupt checkpoint"):
        checkpoint.restore(str(tmp_path), tree)

"""Engine tests: the scanned federation must reproduce the legacy Python
loop bit-for-bit, and vmapped batched simulation must match per-case runs."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import make_strategy
from repro.data import make_image_dataset, skewness_partition
from repro.fl import FLConfig, FLTrainer, engine
from repro.models import cnn

C, N, HW = 10, 30, 14


@pytest.fixture(scope="module")
def federation():
    ds = make_image_dataset(n=C * N, seed=3, h=HW, w=HW)
    shards = skewness_partition(ds.ys, C, 1.0, 10, samples_per_client=N, seed=0)
    return (
        np.stack([ds.xs[s] for s in shards]),
        np.stack([ds.ys[s] for s in shards]),
    )


def _trainer(federation, name, rounds=6, seed=0, **cfg_kw):
    cxs, cys = federation
    params = cnn.init_cnn(
        jax.random.key(seed), in_hw=(HW, HW), channels=(4, 8), fc1_dim=32
    )
    cfg = FLConfig(
        num_clients=C, clients_per_round=3, rounds=rounds, local_epochs=1,
        lr=0.05, eval_every=2, seed=seed, **cfg_kw,
    )
    return FLTrainer(
        cfg, params, cnn.cnn_loss, cnn.apply_with_features, cxs, cys,
        make_strategy(name), accuracy_fn=cnn.accuracy,
    )


@pytest.mark.parametrize("name", ["fedavg", "fl-dp3s"])
def test_scanned_matches_legacy_history(federation, name):
    """run() (scanned engine) == run_legacy() (host loop), ≥5 rounds."""
    h_eng = _trainer(federation, name).run()
    h_leg = _trainer(federation, name).run_legacy()
    assert h_eng["round"] == h_leg["round"]
    for k in ("acc", "gemd", "loss"):
        assert np.array_equal(h_eng[k], h_leg[k]), (name, k, h_eng[k], h_leg[k])


def test_scanned_matches_legacy_cluster_and_fedsae(federation):
    """The host-fit + pure-draw split (cluster) and loss-weighted sampling
    (fedsae) also reproduce the loop exactly."""
    for name in ("cluster", "fedsae"):
        h_eng = _trainer(federation, name, rounds=5).run()
        h_leg = _trainer(federation, name, rounds=5).run_legacy()
        for k in ("acc", "gemd", "loss"):
            assert np.array_equal(h_eng[k], h_leg[k]), (name, k)


def test_run_scanned_outputs_per_round(federation):
    tr = _trainer(federation, "fedavg", rounds=4)
    state, outs = engine.run_scanned(tr.round_fn(), tr.server_state(), 4)
    assert np.asarray(outs["gemd"]).shape == (4,)
    assert np.asarray(outs["selected"]).shape == (4, 3)
    assert int(state.round) == 4
    # acc is evaluated on the eval grid only (eval_every=2) — NaN elsewhere
    acc = np.asarray(outs["acc"])
    assert np.isnan(acc[0]) and np.isfinite(acc[1])


def test_run_many_matches_sequential():
    """vmapped multi-(seed, strategy) simulation == per-case scanned runs."""
    c, n, hw, rounds = 6, 8, 10, 3
    ds = make_image_dataset(n=c * n, seed=5, h=hw, w=hw)
    shards = skewness_partition(ds.ys, c, 1.0, 10, samples_per_client=n, seed=0)
    cxs = np.stack([ds.xs[s] for s in shards])
    cys = np.stack([ds.ys[s] for s in shards])
    strategies = (make_strategy("fedavg"), make_strategy("fl-dp3s"))
    cfg = FLConfig(
        num_clients=c, clients_per_round=2, rounds=rounds, local_epochs=1,
        lr=0.05, eval_every=rounds, seed=0,
    )
    round_fn = engine.make_round_fn(cfg, cnn.cnn_loss, strategies)
    states = []
    for si in range(2):
        for seed in range(2):
            params = cnn.init_cnn(
                jax.random.key(seed), in_hw=(hw, hw), channels=(1, 2), fc1_dim=8
            )
            st = engine.init_server_state(
                dataclasses.replace(cfg, seed=seed), params, cnn.cnn_loss,
                cnn.apply_with_features, cxs, cys,
                strategy=strategies[si], strategy_index=si,
            )
            states.append(st)
    stacked = engine.stack_states(states)
    _, outs = engine.run_many(round_fn, stacked, rounds)
    per_case = engine.unstack_outputs(outs)
    assert len(per_case) == 4
    for i, st in enumerate(states):
        _, ref = engine.run_scanned(round_fn, st, rounds)
        for k in ("gemd", "loss"):
            np.testing.assert_allclose(
                per_case[i][k], np.asarray(ref[k]), rtol=1e-5, atol=1e-6,
                err_msg=f"case {i} key {k}",
            )


def test_reprofile_refreshes_kernel_in_engine_path(federation):
    """reprofile_every runs scan segments with a host profile refresh between
    them; the trainer's kernel must change once params have moved."""
    tr = _trainer(federation, "fl-dp3s", rounds=4, reprofile_every=2)
    k0 = np.asarray(tr.round_state.kernel).copy()
    tr.run()
    k1 = np.asarray(tr.round_state.kernel)
    assert tr.round_state.round == 4
    assert not np.allclose(k0, k1)


def test_history_from_outputs_final_round_fill():
    outs = {
        "round": np.asarray([1, 2, 3]),
        "acc": np.asarray([np.nan, 0.5, np.nan]),
        "gemd": np.asarray([1.0, 0.9, 0.8]),
        "loss": np.asarray([2.0, 1.5, 1.2]),
    }
    h = engine.history_from_outputs(outs, eval_every=2, final_acc=0.7)
    assert h["round"] == [2, 3]
    assert h["acc"] == [0.5, 0.7]


def test_history_from_outputs_empty_run():
    """Zero-round outputs (e.g. a run_many grid scanned for 0 rounds) yield
    an empty history, not an IndexError."""
    outs = {
        "round": np.zeros((0,), np.int32),
        "acc": np.zeros((0,), np.float32),
        "gemd": np.zeros((0,), np.float32),
        "loss": np.zeros((0,), np.float32),
    }
    h = engine.history_from_outputs(outs, eval_every=2)
    assert h == {"round": [], "acc": [], "gemd": [], "loss": []}


def test_steps_per_round_uses_shared_num_batches():
    """_steps_per_round and batches_from_indices must agree on batches/epoch
    (one shared _num_batches helper — drop-remainder, at least one)."""
    cfg = FLConfig(num_clients=4, clients_per_round=2, local_epochs=3,
                   local_batch_size=4)
    for n_c in (3, 4, 9, 10):
        steps = engine._steps_per_round(cfg, n_c)
        nb = engine._num_batches(n_c, cfg.local_batch_size)
        assert steps == cfg.local_epochs * nb
        ids = jnp.stack([jax.random.permutation(jax.random.key(0), n_c)])
        xs = jnp.zeros((1, n_c, 2))
        ys = jnp.zeros((1, n_c), jnp.int32)
        xb, yb = engine.batches_from_indices(cfg, ids, xs, ys)
        assert xb.shape[1] == steps and yb.shape[1] == steps


def test_make_client_batches_full_batch_mode():
    cfg = FLConfig(num_clients=4, clients_per_round=2, local_epochs=3)
    xs = jnp.arange(4 * 5 * 2, dtype=jnp.float32).reshape(4, 5, 2)
    ys = jnp.arange(4 * 5, dtype=jnp.int32).reshape(4, 5)
    xb, yb = engine.make_client_batches(
        cfg, jax.random.key(0), xs, ys, jnp.asarray([1, 3])
    )
    assert xb.shape == (2, 3, 5, 2) and yb.shape == (2, 3, 5)
    np.testing.assert_array_equal(np.asarray(xb[0, 0]), np.asarray(xs[1]))


def test_make_client_batches_with_replacement():
    cfg = FLConfig(
        num_clients=4, clients_per_round=2, local_batch_size=3, local_steps=5,
        sample_with_replacement=True,
    )
    xs = jnp.arange(4 * 7, dtype=jnp.float32).reshape(4, 7)
    ys = jnp.arange(4 * 7, dtype=jnp.int32).reshape(4, 7)
    xb, yb = engine.make_client_batches(
        cfg, jax.random.key(0), xs, ys, jnp.asarray([0, 2])
    )
    assert xb.shape == (2, 5, 3) and yb.shape == (2, 5, 3)
    # draws come from the selected client's own shard
    assert set(np.asarray(xb[0]).ravel().tolist()) <= set(np.asarray(xs[0]).tolist())
    assert set(np.asarray(xb[1]).ravel().tolist()) <= set(np.asarray(xs[2]).tolist())

"""Direct contracts of the scenario registry (DESIGN.md §9).

Until now ``repro.fl.scenarios`` was only exercised through the staleness
engine; these tests pin its own API: registry error paths, PRNG determinism
(same key, same draw), and the shape/dtype contracts the jit-level engine
call sites rely on (``latency(key, n) -> (n,) float32 > 0``,
``availability(key, t, n) -> (n,) bool``).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.fl import scenarios


def test_get_scenario_unknown_name_lists_known():
    with pytest.raises(ValueError) as e:
        scenarios.get_scenario("nope")
    msg = str(e.value)
    assert "nope" in msg
    for name in scenarios.SCENARIO_NAMES:
        assert name in msg


def test_all_registry_names_resolve():
    assert scenarios.SCENARIO_NAMES == tuple(sorted(scenarios.SCENARIOS))
    for name in scenarios.SCENARIO_NAMES:
        s = scenarios.get_scenario(name)
        assert s.name == name
        assert s.deadline > 0
        assert callable(s.latency)


@pytest.mark.parametrize("name", scenarios.SCENARIO_NAMES)
def test_latency_contract(name):
    s = scenarios.get_scenario(name)
    key = jax.random.key(7)
    lat = s.latency(key, 33)
    assert lat.shape == (33,)
    assert lat.dtype == jnp.float32
    assert bool(jnp.all(lat > 0))
    assert bool(jnp.all(jnp.isfinite(lat)))
    # same key -> same draw (the scanned engine's reproducibility contract)
    again = s.latency(key, 33)
    assert bool(jnp.array_equal(lat, again))
    # different key -> different draw (not a constant function)
    other = s.latency(jax.random.key(8), 33)
    assert not bool(jnp.array_equal(lat, other))


def test_latency_jit_compatible():
    s = scenarios.get_scenario("heavy_tail")
    fn = jax.jit(lambda k: s.latency(k, 16))
    assert bool(jnp.array_equal(fn(jax.random.key(3)), s.latency(jax.random.key(3), 16)))


def test_availability_contract():
    s = scenarios.get_scenario("flaky")
    assert s.availability is not None
    key = jax.random.key(11)
    m = s.availability(key, jnp.asarray(4, jnp.int32), 40)
    assert m.shape == (40,)
    assert m.dtype == jnp.bool_
    assert bool(jnp.array_equal(m, s.availability(key, jnp.asarray(4, jnp.int32), 40)))
    # the diurnal model is time-varying: the same key at different rounds
    # must not produce one frozen mask
    masks = [
        np.asarray(s.availability(key, jnp.asarray(t, jnp.int32), 40))
        for t in range(8)
    ]
    assert any(not np.array_equal(masks[0], m) for m in masks[1:])


def test_latency_only_scenarios_have_no_availability():
    for name in ("uniform", "lognormal", "heavy_tail"):
        assert scenarios.get_scenario(name).availability is None

"""Tests for data/, optim/, checkpoint/ substrates."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

from repro import optim
from repro.checkpoint import latest_step, restore, save
from repro.data import (
    batch_iterator,
    dirichlet_partition,
    make_image_dataset,
    make_token_dataset,
    skewness_partition,
)

# ---------------------------------------------------------------- data


def test_image_dataset_shapes_and_normalisation():
    ds = make_image_dataset(n=2000, seed=0)
    assert ds.xs.shape == (2000, 28, 28, 1)
    assert ds.ys.shape == (2000,)
    assert abs(float(ds.xs.mean())) < 0.05
    assert 0.8 < float(ds.xs.std()) < 1.2
    assert set(np.unique(ds.ys)) <= set(range(10))


@pytest.mark.parametrize("xi,expect_dom", [(1.0, 1.0), (0.8, 0.8), (0.5, 0.5), ("H", 0.5)])
def test_skewness_partition_matches_protocol(xi, expect_dom):
    ds = make_image_dataset(n=6000, seed=1)
    shards = skewness_partition(ds.ys, num_clients=10, xi=xi, num_classes=10,
                                samples_per_client=500, seed=0)
    assert len(shards) == 10
    for c, idx in enumerate(shards):
        assert len(idx) == 500
        labels = ds.ys[idx]
        counts = np.bincount(labels, minlength=10)
        dom_frac = counts.max() / 500
        assert abs(dom_frac - expect_dom) < 0.05, (xi, c, dom_frac)
        if xi == "H":
            assert (counts > 0).sum() == 2  # exactly two classes
        if xi == 1.0:
            assert (counts > 0).sum() == 1


def test_partitions_are_disjoint():
    ds = make_image_dataset(n=6000, seed=2)
    shards = skewness_partition(ds.ys, 10, 0.8, 10, samples_per_client=400, seed=0)
    all_idx = np.concatenate(shards)
    assert len(all_idx) == len(set(all_idx.tolist()))


def test_dirichlet_partition_covers_everything_once():
    ds = make_image_dataset(n=3000, seed=3)
    shards = dirichlet_partition(ds.ys, 7, alpha=0.5, num_classes=10, seed=0)
    all_idx = np.concatenate(shards)
    assert sorted(all_idx.tolist()) == list(range(3000))


def test_token_dataset_topic_structure():
    docs, topics = make_token_dataset(n_docs=200, doc_len=64, vocab=100, num_topics=5)
    band = 100 // 5
    for t in range(5):
        d = docs[topics == t]
        in_band = ((d >= t * band) & (d < (t + 1) * band)).mean()
        assert in_band > 0.6


def test_batch_iterator_static_shapes():
    ds = make_image_dataset(n=1000, seed=4)
    it = batch_iterator(ds.xs, ds.ys, batch_size=128, seed=0)
    for _ in range(10):
        xb, yb = next(it)
        assert xb.shape == (128, 28, 28, 1)
        assert yb.shape == (128,)


# ---------------------------------------------------------------- optim


def _quadratic_losses():
    target = jnp.asarray([1.0, -2.0, 3.0])

    def loss(p):
        return jnp.sum((p["w"] - target) ** 2) + jnp.sum(p["b"] ** 2)

    params = {"w": jnp.zeros(3), "b": jnp.ones(2)}
    return loss, params


@pytest.mark.parametrize(
    "opt",
    [
        optim.sgd(0.1),
        optim.sgd(0.05, momentum=0.9),
        optim.adam(0.1),
        optim.adamw(0.1, weight_decay=0.001),
        optim.adafactor(0.3),
    ],
    ids=["sgd", "sgd-momentum", "adam", "adamw", "adafactor"],
)
def test_optimizers_minimise_quadratic(opt):
    loss, params = _quadratic_losses()
    state = opt.init(params)
    l0 = float(loss(params))
    for _ in range(150):
        g = jax.grad(loss)(params)
        upd, state = opt.update(g, state, params)
        params = optim.apply_updates(params, upd)
    assert float(loss(params)) < 0.05 * l0


def test_sgd_matches_analytic_step():
    opt = optim.sgd(0.5)
    p = {"w": jnp.asarray([2.0])}
    g = {"w": jnp.asarray([3.0])}
    upd, _ = opt.update(g, opt.init(p), p)
    new = optim.apply_updates(p, upd)
    assert np.isclose(float(new["w"][0]), 2.0 - 0.5 * 3.0)


def test_adafactor_state_is_factored():
    opt = optim.adafactor(0.1)
    p = {"m": jnp.zeros((64, 32))}
    state = opt.init(p)
    assert state.vr["m"].shape == (64,)
    assert state.vc["m"].shape == (32,)


def test_clip_by_global_norm():
    g = {"a": jnp.asarray([3.0, 4.0])}  # norm 5
    clipped = optim.clip_by_global_norm(g, 1.0)
    assert np.isclose(float(jnp.linalg.norm(clipped["a"])), 1.0, rtol=1e-5)
    not_clipped = optim.clip_by_global_norm(g, 10.0)
    np.testing.assert_allclose(np.asarray(not_clipped["a"]), [3.0, 4.0], rtol=1e-6)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10**6))
def test_apply_updates_roundtrip_property(seed):
    rng = np.random.default_rng(seed)
    p = {"x": jnp.asarray(rng.normal(size=(4,)).astype(np.float32))}
    u = {"x": jnp.asarray(rng.normal(size=(4,)).astype(np.float32))}
    out = optim.apply_updates(p, u)
    np.testing.assert_allclose(np.asarray(out["x"]), np.asarray(p["x"]) + np.asarray(u["x"]), rtol=1e-6)


# ---------------------------------------------------------------- checkpoint


def test_checkpoint_roundtrip(tmp_path):
    tree = {
        "params": {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3)},
        "step": jnp.asarray(7, jnp.int32),
    }
    save(str(tmp_path), 7, tree)
    save(str(tmp_path), 12, jax.tree_util.tree_map(lambda x: x + 1, tree))
    assert latest_step(str(tmp_path)) == 12
    template = jax.tree_util.tree_map(jnp.zeros_like, tree)
    got = restore(str(tmp_path), template)  # latest
    np.testing.assert_allclose(np.asarray(got["params"]["w"]), np.arange(6).reshape(2, 3) + 1)
    got7 = restore(str(tmp_path), template, step=7)
    assert int(got7["step"]) == 7

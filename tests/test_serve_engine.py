"""Serving engine (DESIGN.md §13): scan-decode parity with the legacy loop,
continuous slot refill, per-slot stopping, flash-decode oracle, and the
zero-recompile contract."""

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.kernels.flash_attention import ops as flash_ops
from repro.kernels.flash_attention import ref as flash_ref
from repro.models import transformer as T
from repro.launch import serve as serve_mod
from repro.serve import (
    ServeConfig,
    ServeEngine,
    init_decode_state,
    make_decode_fn,
    run_scan,
    run_while,
    sample_tokens,
)

RNG = np.random.default_rng(0)

# one arch per cache family: dense GQA KV, O(1) recurrent state,
# SWA ring buffer + MoE
ARCHS = ["smollm-360m", "rwkv6-7b", "mixtral-8x7b"]


@functools.lru_cache(maxsize=None)
def _model(arch):
    return serve_mod.build_model(arch, seed=0)


def _prompts(cfg, b, p, seed=1):
    return jax.random.randint(jax.random.key(seed), (b, p), 0,
                              cfg.vocab_size, jnp.int32)


def _solo_greedy(cfg, params, prompt, budget):
    """One sequence decoded alone through the legacy loop."""
    out, _ = serve_mod.run_legacy(cfg, params, prompt[None], budget)
    return out[0]


# ------------------------------------------------- scan/legacy bit parity


@pytest.mark.parametrize("arch", ARCHS)
def test_scan_decode_bit_identical_to_legacy(arch):
    cfg, params = _model(arch)
    prompts = _prompts(cfg, 3, 6)
    legacy, _ = serve_mod.run_legacy(cfg, params, prompts, 5)
    scan, _ = serve_mod.run_scan_mode(cfg, params, prompts, 5)
    assert (scan == legacy).all(), f"{arch}: scan tokens diverge from legacy"


# ------------------------------------------------------ per-slot stopping


def test_while_scan_per_slot_stopping():
    cfg, params = _model("smollm-360m")
    b, p, g = 4, 6, 8
    prompts = _prompts(cfg, b, p)
    legacy, _ = serve_mod.run_legacy(cfg, params, prompts, g)

    scfg = ServeConfig(batch=b, cache_len=p + g, max_new=g)
    caches = T.init_caches(cfg, b, p + g, per_slot=True)
    positions = jnp.broadcast_to(jnp.arange(p)[None], (b, p))
    hidden, caches, _ = T.forward(cfg, params, prompts, positions, caches)
    logits = T.logits_from_hidden(cfg, params, hidden[:, -1:])
    tok0 = jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32)

    targets = jnp.asarray([2, g, 1, 5], jnp.int32)
    state = dataclasses.replace(
        init_decode_state(cfg, scfg),
        caches=caches, last_tok=tok0[:, None],
        out_tokens=jnp.zeros((b, g), jnp.int32).at[:, 0].set(tok0),
        n_gen=jnp.ones((b,), jnp.int32), gen_target=targets,
        active=targets > 1, seq_ids=jnp.arange(b, dtype=jnp.int32),
    )
    decode_fn = make_decode_fn(cfg, scfg)
    state = jax.jit(lambda prm, s: run_while(decode_fn, prm, s, g))(
        params, state)

    n_gen = np.asarray(state.n_gen)
    assert (n_gen == np.asarray(targets)).all()
    assert not np.asarray(state.active).any()
    # the while-scan exits at the longest slot, not the full budget
    assert int(state.step) == g - 1
    out = np.asarray(state.out_tokens)
    for i in range(b):
        assert (out[i, : n_gen[i]] == legacy[i, : n_gen[i]]).all()


def test_eos_stops_slots_early():
    cfg, params = _model("smollm-360m")
    b, p, g = 3, 6, 7
    prompts = _prompts(cfg, b, p)
    legacy, _ = serve_mod.run_legacy(cfg, params, prompts, g)
    eos = int(legacy[0, 2])  # slot 0 emits this at generation index 2

    scfg = ServeConfig(batch=b, cache_len=p + g, max_new=g, eos_id=eos)
    caches = T.init_caches(cfg, b, p + g, per_slot=True)
    positions = jnp.broadcast_to(jnp.arange(p)[None], (b, p))
    hidden, caches, _ = T.forward(cfg, params, prompts, positions, caches)
    logits = T.logits_from_hidden(cfg, params, hidden[:, -1:])
    tok0 = jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32)
    state = dataclasses.replace(
        init_decode_state(cfg, scfg),
        caches=caches, last_tok=tok0[:, None],
        out_tokens=jnp.zeros((b, g), jnp.int32).at[:, 0].set(tok0),
        n_gen=jnp.ones((b,), jnp.int32),
        gen_target=jnp.full((b,), g, jnp.int32),
        active=jnp.ones((b,), bool), seq_ids=jnp.arange(b, dtype=jnp.int32),
    )
    decode_fn = make_decode_fn(cfg, scfg)
    state = jax.jit(lambda prm, s: run_while(decode_fn, prm, s, g))(
        params, state)

    n_gen = np.asarray(state.n_gen)
    for i in range(b):
        hits = np.nonzero(legacy[i] == eos)[0]
        expect = int(hits[0]) + 1 if hits.size else g
        assert n_gen[i] == expect, (i, n_gen[i], expect)
        assert (np.asarray(state.out_tokens)[i, :expect]
                == legacy[i, :expect]).all()


# --------------------------------------- continuous slot refill + parity


def test_continuous_refill_matches_solo_decode():
    cfg, params = _model("smollm-360m")
    b, p, g, n = 2, 6, 8, 5
    scfg = ServeConfig(batch=b, cache_len=p + g, max_new=g, decode_chunk=3)
    eng = ServeEngine(cfg, scfg, params, prompt_len=p)
    prompts = np.asarray(_prompts(cfg, n, p, seed=2))
    budgets = [3, g, 1, 6, 4]
    for i in range(n):
        eng.submit(prompts[i], budgets[i])
    finished = eng.run()
    assert sorted(f.seq_id for f in finished) == list(range(n))
    for f in finished:
        assert len(f.tokens) == budgets[f.seq_id]
        solo = _solo_greedy(cfg, params, jnp.asarray(prompts[f.seq_id]),
                            budgets[f.seq_id])
        assert (f.tokens == solo).all(), f"seq {f.seq_id} diverges solo"


@pytest.mark.parametrize("drain", [False, True])
def test_budget1_not_clobbered_by_same_wave_admission(drain):
    """A budget-1 sequence finishes at prefill and sits inactive-but-occupied
    until harvest; the NEXT admission in the same refill wave must pick a
    different slot (free = unoccupied, not merely inactive) or the budget-1
    result is silently overwritten and vanishes from ``finished``."""
    cfg, params = _model("smollm-360m")
    b, p, g, n = 3, 6, 6, 4
    scfg = ServeConfig(batch=b, cache_len=p + g, max_new=g, decode_chunk=2)
    eng = ServeEngine(cfg, scfg, params, prompt_len=p)
    prompts = np.asarray(_prompts(cfg, n, p, seed=4))
    budgets = [1, 1, g, 3]  # two budget-1 admissions in the first wave
    for i in range(n):
        eng.submit(prompts[i], budgets[i])
    finished = eng.run(drain=drain)
    assert sorted(f.seq_id for f in finished) == list(range(n))
    for f in finished:
        assert len(f.tokens) == budgets[f.seq_id], f"seq {f.seq_id} truncated"
        solo = _solo_greedy(cfg, params, jnp.asarray(prompts[f.seq_id]),
                            budgets[f.seq_id])
        assert (f.tokens == solo).all(), f"seq {f.seq_id} diverges solo"


def test_engine_rejects_undersized_cache():
    """cache_len < prompt_len + max_new would wrap the per-slot write index
    and silently corrupt the oldest context — the engine must refuse it."""
    cfg, params = _model("smollm-360m")
    scfg = ServeConfig(batch=2, cache_len=8, max_new=6)
    with pytest.raises(ValueError, match="cache_len"):
        ServeEngine(cfg, scfg, params, prompt_len=4)


def test_slot_refill_does_not_retrace():
    """Mixed-length traffic reuses ONE compiled admit and ONE compiled
    decode-chunk program — the continuous-batching zero-recompile
    contract."""
    cfg, params = _model("smollm-360m")
    b, p, g = 2, 6, 6
    scfg = ServeConfig(batch=b, cache_len=p + g, max_new=g, decode_chunk=2)
    eng = ServeEngine(cfg, scfg, params, prompt_len=p)
    prompts = np.asarray(_prompts(cfg, 7, p, seed=3))
    for i, budget in enumerate([1, g, 2, 5, 3, g, 2]):
        eng.submit(prompts[i], budget)
    eng.run()
    counts = eng.compile_counts()
    assert counts == {"decode_chunk": 1, "admit": 1}, counts

    # a second traffic wave on the same engine compiles nothing new
    eng.reset()
    for i in range(4):
        eng.submit(prompts[i], 2 + i)
    eng.run()
    assert eng.compile_counts() == counts


# ------------------------------------------------------------- sampling


def test_temperature_sampling_per_slot_streams():
    logits = jnp.asarray(RNG.normal(size=(4, 1, 16)).astype(np.float32))
    keys = jax.random.key_data(jax.random.split(jax.random.key(7), 4))
    t1, k1 = sample_tokens(logits, keys, 0.8)
    t2, _ = sample_tokens(logits, keys, 0.8)
    assert (np.asarray(t1) == np.asarray(t2)).all()  # same keys -> same draw
    assert not (np.asarray(k1) == np.asarray(keys)).all()  # streams advance
    t3, _ = sample_tokens(logits, k1, 0.8)
    assert t3.shape == (4,) and t3.dtype == jnp.int32
    # greedy branch is exact argmax and leaves keys untouched
    tg, kg = sample_tokens(logits, keys, 0.0)
    assert (np.asarray(tg) == np.asarray(jnp.argmax(logits[:, 0], -1))).all()
    assert kg is keys


# ------------------------------------------------------ flash-decode oracle


@pytest.mark.parametrize(
    "b,s,h,hk,hd,bk,lengths",
    [
        (5, 40, 4, 2, 32, 16, [0, 1, 7, 33, 40]),  # ragged + empty + 3 tiles
        (2, 64, 4, 4, 16, 32, [64, 50]),           # MHA, full + partial tile
        (3, 16, 4, 1, 64, 128, [16, 3, 9]),        # MQA, S < block_k (pad)
    ],
)
def test_flash_decode_matches_ref(b, s, h, hk, hd, bk, lengths):
    q = jnp.asarray(RNG.normal(size=(b, 1, h, hd)).astype(np.float32))
    k = jnp.asarray(RNG.normal(size=(b, s, hk, hd)).astype(np.float32))
    v = jnp.asarray(RNG.normal(size=(b, s, hk, hd)).astype(np.float32))
    ln = jnp.asarray(lengths, jnp.int32)
    got = flash_ops.flash_decode(q, k, v, ln, block_k=bk)
    want = flash_ref.decode_attention_ref(q, k, v, ln)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


def test_flash_decode_rejects_bad_shapes():
    q = jnp.zeros((2, 1, 4, 8))
    k = v = jnp.zeros((2, 16, 2, 8))
    with pytest.raises(ValueError):
        flash_ops.flash_decode(q, k, v, jnp.zeros((3,), jnp.int32))
    with pytest.raises(ValueError):
        flash_ops.flash_decode(jnp.zeros((2, 2, 4, 8)), k, v,
                               jnp.zeros((2,), jnp.int32))


def test_decode_step_flash_routes_and_matches():
    """use_flash=True on the per-slot decode path agrees with the jnp
    attention to fp tolerance (same math, kernel evaluation order)."""
    cfg, params = _model("smollm-360m")
    b, p, g = 2, 6, 3
    prompts = _prompts(cfg, b, p)
    plain, _ = serve_mod.run_scan_mode(cfg, params, prompts, g)
    flash, _ = serve_mod.run_scan_mode(cfg, params, prompts, g,
                                       use_flash=True)
    assert (plain == flash).all()

"""Integration tests for the train/serve drivers (reduced configs, CPU)."""

import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import serve as serve_mod
from repro.launch import train as train_mod


class _Args:
    def __init__(self, **kw):
        self.__dict__.update(kw)


def test_fl_driver_selects_and_learns():
    args = _Args(
        arch="smollm-360m", selection="fl-dp3s", rounds=6, clients=6,
        per_round=3, docs_per_client=6, local_steps=1, local_batch=2,
        seq=32, seed=0, log_every=100, ckpt=None,
    )
    params = train_mod.run_fl(args)
    assert params is not None
    leaves = jax.tree_util.tree_leaves(params)
    assert all(np.isfinite(np.asarray(l, np.float32)).all() for l in leaves)


def test_fl_driver_faults_and_crash_resume(tmp_path, capsys):
    """--faults/--aggregator drive the guarded engine, and --ckpt-every +
    --ckpt give crash-resume: a second launch picks up from the latest
    ServerState snapshot and finishes the remaining rounds."""
    import os

    kw = dict(
        arch="smollm-360m", selection="fedavg", clients=6,
        per_round=3, docs_per_client=6, local_steps=1, local_batch=2,
        seq=32, seed=0, log_every=100, ckpt=str(tmp_path),
        faults="corrupt", aggregator="trimmed_mean", ckpt_every=2,
    )
    train_mod.run_fl(_Args(rounds=4, **kw))
    first = capsys.readouterr().out
    assert "faults=corrupt" in first and "aggregator=trimmed_mean" in first
    assert sorted(os.listdir(str(tmp_path))) == [
        "step_00000002", "step_00000004",
    ]

    params = train_mod.run_fl(_Args(rounds=6, **kw))
    out = capsys.readouterr().out
    assert "resumed round 4" in out
    assert "step_00000006" in sorted(os.listdir(str(tmp_path)))
    leaves = jax.tree_util.tree_leaves(params)
    assert all(np.isfinite(np.asarray(l, np.float32)).all() for l in leaves)


def test_pretrain_driver_loss_decreases(capsys):
    args = _Args(
        arch="smollm-360m", steps=30, local_batch=4, seq=32, seed=0,
        log_every=5, ckpt=None, lr=2e-3,
    )
    train_mod.run_pretrain(args)
    out = capsys.readouterr().out
    losses = [float(l.split("loss=")[1].split()[0]) for l in out.splitlines() if "loss=" in l]
    assert len(losses) >= 2
    assert losses[-1] < losses[0]  # learning on topic-structured tokens


def test_serve_driver_generates(capsys):
    args = _Args(
        arch="smollm-360m", batch=2, prompt_len=8, gen=6, seed=0,
        scan=False, continuous=False, requests=0, mixed=False,
        temperature=0.0, flash=False, check=False,
    )
    gen = serve_mod.serve(args)
    assert gen.shape == (2, 6)
    out = capsys.readouterr().out
    assert "decode" in out


def test_fl_driver_dpp_vs_uniform_select_different_cohorts():
    """DPP must use the kernel: with clustered topics, its cohorts hit more
    distinct topics than uniform on average."""
    import numpy as np

    from repro.configs import get_arch
    from repro.core import RoundState, kernel_from_profiles, make_strategy
    from repro.models import transformer as T

    cfg = get_arch("smollm-360m").model.reduced(param_dtype="float32", dtype="float32")
    params = T.init_params(jax.random.key(0), cfg)
    clients = train_mod._token_clients(cfg, 12, 6, 32)
    feats = []
    for c in range(12):
        _, f = T.features(cfg, params, jnp.asarray(clients[c][:4]))
        feats.append(f.mean(0))
    profiles = jnp.stack(feats)
    state = RoundState(num_clients=12, profiles=profiles,
                       kernel=kernel_from_profiles(profiles),
                       client_sizes=jnp.ones((12,)))
    topics = np.arange(12) % 10

    def distinct(strategy, n=20):
        tot = 0
        for i in range(n):
            sel = np.asarray(strategy.select(jax.random.key(i), state, 4))
            tot += len(set(topics[sel].tolist()))
        return tot / n

    d_dpp = distinct(make_strategy("fl-dp3s"))
    d_uni = distinct(make_strategy("fedavg"))
    assert d_dpp >= d_uni  # profiles of an *untrained* LM are still topic-informative

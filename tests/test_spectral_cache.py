"""Spectral-cache tests: cached k-DPP draws vs the one-shot sampler, the
engine's eig-cache lifecycle across reprofile boundaries, and the vectorised
cluster draw.  (Deliberately hypothesis-free so the suite runs in minimal
containers.)"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import dpp, selection, similarity
from repro.data import make_image_dataset, skewness_partition
from repro.fl import FLConfig, FLTrainer
from repro.models import cnn


def _kernel(c=12, q=6, seed=0):
    rng = np.random.default_rng(seed)
    f = jnp.asarray(rng.normal(size=(c, q)).astype(np.float32))
    return similarity.kernel_from_profiles(f)


# ------------------------------------------------------------------ sampler


def test_sample_from_eigh_bitwise_matches_one_shot():
    """sample_kdpp_from_eigh(key, kdpp_sampler_state(L, k), k) must equal
    sample_kdpp(key, L, k) bit-for-bit: the engine draws from the cache, the
    legacy path decomposes per call, and the two must stay interchangeable."""
    kern = _kernel(c=16)
    for k in (1, 3, 5):
        state = dpp.kdpp_sampler_state(kern, k)
        for i in range(25):
            key = jax.random.key(i * 7 + k)
            a = np.asarray(dpp.sample_kdpp(key, kern, k))
            b = np.asarray(dpp.sample_kdpp_from_eigh(key, state, k))
            np.testing.assert_array_equal(a, b)


def test_sampler_state_shapes_and_k_mismatch():
    kern = _kernel(c=9)
    state = dpp.kdpp_sampler_state(kern, 3)
    assert state.num_items == 9 and state.k == 3
    assert state.esp.shape == (4, 10)
    with pytest.raises(ValueError):
        dpp.sample_kdpp_from_eigh(jax.random.key(0), state, 4)


def test_cached_draw_is_scan_compatible():
    """The cached draw must close into lax.scan without re-tracing eigh."""
    kern = _kernel(c=10)
    k = 3
    state = dpp.kdpp_sampler_state(kern, k)

    def body(key, _):
        key, sub = jax.random.split(key)
        return key, dpp.sample_kdpp_from_eigh(sub, state, k)

    _, sels = jax.lax.scan(body, jax.random.key(0), None, length=8)
    sels = np.asarray(sels)
    assert sels.shape == (8, k)
    for row in sels:
        assert len(set(row.tolist())) == k


def test_identity_sampler_state_layout_matches_real():
    real = dpp.kdpp_sampler_state(_kernel(c=7), 2)
    ident = dpp.identity_sampler_state(7, 2)
    assert jax.tree_util.tree_structure(real) == jax.tree_util.tree_structure(ident)
    for a, b in zip(jax.tree_util.tree_leaves(real), jax.tree_util.tree_leaves(ident)):
        assert a.shape == b.shape and a.dtype == b.dtype


def test_dpp_selection_cached_vs_uncached_bitwise():
    """DPPSelection(use_cache=True) and the eigh-per-draw baseline must pick
    identical cohorts for the same key (the BENCH_dpp acceptance contract)."""
    c, k = 14, 4
    kern = _kernel(c=c)
    st = selection.RoundState(num_clients=c, kernel=kern)
    cached = selection.DPPSelection()
    baseline = selection.DPPSelection(use_cache=False)
    for i in range(20):
        key = jax.random.key(i)
        np.testing.assert_array_equal(
            np.asarray(cached.select(key, st, k)),
            np.asarray(baseline.select(key, st, k)),
        )


def test_cluster_select_fn_vectorised_one_per_cluster():
    """The vmapped masked-categorical draw keeps the one-pick-per-cluster
    semantics (including the empty-cluster fallback)."""
    labels = jnp.asarray([0, 0, 1, 1, 2, 2], jnp.int32)
    st = selection.selection_state(
        6, 3, cluster_labels=labels, client_sizes=jnp.ones((6,))
    )
    strat = selection.ClusterSelection()
    for i in range(10):
        picks = np.asarray(strat.select_fn(jax.random.key(i), st, 3))
        assert sorted(np.asarray(labels)[picks].tolist()) == [0, 1, 2]
    # empty cluster 3 -> falls back to a size-weighted draw over everyone
    st4 = selection.selection_state(
        6, 4, cluster_labels=labels, client_sizes=jnp.ones((6,))
    )
    picks = np.asarray(strat.select_fn(jax.random.key(0), st4, 4))
    assert picks.shape == (4,) and (picks >= 0).all() and (picks < 6).all()


# ------------------------------------------------------------------ engine


C, N, HW = 10, 24, 12


@pytest.fixture(scope="module")
def federation():
    ds = make_image_dataset(n=C * N, seed=7, h=HW, w=HW)
    shards = skewness_partition(ds.ys, C, 1.0, 10, samples_per_client=N, seed=0)
    return (
        np.stack([ds.xs[s] for s in shards]),
        np.stack([ds.ys[s] for s in shards]),
    )


def _trainer(federation, rounds=4, **cfg_kw):
    cxs, cys = federation
    params = cnn.init_cnn(
        jax.random.key(0), in_hw=(HW, HW), channels=(4, 8), fc1_dim=32
    )
    cfg = FLConfig(
        num_clients=C, clients_per_round=3, rounds=rounds, local_epochs=1,
        lr=0.05, eval_every=2, seed=0, **cfg_kw,
    )
    return FLTrainer(
        cfg, params, cnn.cnn_loss, cnn.apply_with_features, cxs, cys,
        selection.DPPSelection(), accuracy_fn=cnn.accuracy,
    )


def test_eig_cache_invalidated_across_reprofile_boundary(federation):
    """reprofile_every refreshes the kernel between scan segments — the
    spectral cache must be rebuilt from the refreshed kernel, not reused."""
    tr = _trainer(federation, rounds=4, reprofile_every=2)
    eig0 = tr.eig_state()
    lam0 = np.asarray(eig0.lam).copy()
    tr.run()
    eig1 = tr.eig_state()
    assert eig1 is not eig0  # memo dropped at the segment boundary
    assert not np.allclose(lam0, np.asarray(eig1.lam))
    # the refreshed cache decomposes exactly the refreshed kernel
    kern = np.asarray(tr.round_state.kernel, np.float64)
    lam, vecs = np.asarray(eig1.lam), np.asarray(eig1.vecs)
    scale = np.maximum(np.mean(np.abs(np.linalg.eigvalsh(kern))), 1e-30)
    recon = (vecs * (lam * scale)) @ vecs.T
    np.testing.assert_allclose(recon, kern, atol=1e-3)


def test_eig_cache_memoised_between_calls(federation):
    tr = _trainer(federation)
    assert tr.eig_state() is tr.eig_state()  # no re-decomposition
    tr._init_profiles()
    assert tr._eig_state is None  # kernel refresh drops the memo


def test_server_state_carries_spectral_cache(federation):
    tr = _trainer(federation)
    st = tr.server_state()
    sel_state = st.selection_state()
    assert sel_state.eig_state.esp.shape == (4, C + 1)
    # a draw from the carried cache equals the one-shot sampler on the kernel
    key = jax.random.key(3)
    np.testing.assert_array_equal(
        np.asarray(dpp.sample_kdpp_from_eigh(key, sel_state.eig_state, 3)),
        np.asarray(dpp.sample_kdpp(key, st.kernel, 3)),
    )

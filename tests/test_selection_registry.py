"""Registry + pure-selection tests (no optional deps — always collected).

Covers every ``make_strategy`` name, uniform kwargs forwarding, the pure
``select_fn`` layer under jit, and the content-based cluster-cache
invalidation regression (labels used to be cached on fingerprint *shape*
only, so refreshed profiles silently never re-clustered)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import selection, similarity


def _sstate(c=20, q=6, seed=0, k_clusters=None, k=5):
    rng = np.random.default_rng(seed)
    f = jnp.asarray(rng.normal(size=(c, q)).astype(np.float32))
    labels = None
    if k_clusters is not None:
        labels = jnp.asarray(np.arange(c) % k_clusters, jnp.int32)
    return selection.selection_state(
        c,
        k,
        kernel=similarity.kernel_from_profiles(f),
        losses=jnp.asarray(rng.uniform(0.1, 3.0, size=(c,)).astype(np.float32)),
        client_sizes=jnp.full((c,), 50.0),
        cluster_labels=labels,
        decompose_kernel=True,  # real spectral cache (the DPP draw reads it)
    )


def test_make_strategy_every_name_constructs():
    for name in selection.STRATEGY_NAMES:
        s = selection.make_strategy(name)
        assert isinstance(s, selection.SelectionStrategy), name


def test_make_strategy_kwargs_forward_uniformly():
    assert selection.make_strategy("power-of-choice", d=7).d == 7
    assert selection.make_strategy("fl-dp3s", mode="map").mode == "map"
    assert selection.make_strategy("dpp", mode="sample").mode == "sample"
    # the fl-dp3s-map alias pre-binds mode but still accepts no extra kwargs
    assert selection.make_strategy("fl-dp3s-map").mode == "map"
    assert selection.make_strategy("fl-dp3s-map").name == "fl-dp3s-map"


def test_make_strategy_unknown_name_raises():
    with pytest.raises(ValueError, match="unknown selection strategy"):
        selection.make_strategy("nope")


def test_every_strategy_select_fn_is_pure_and_jittable():
    k = 5
    for name in selection.STRATEGY_NAMES:
        s = selection.make_strategy(name)
        st = _sstate(k_clusters=k)
        jitted = jax.jit(lambda key, ss, s=s: s.select_fn(key, ss, k))
        idx = np.asarray(jitted(jax.random.key(1), st))
        assert idx.shape == (k,), name
        assert len(set(idx.tolist())) == k, (name, idx)
        assert (idx >= 0).all() and (idx < st.num_clients).all(), name
        # pure: same key, same state -> same cohort
        idx2 = np.asarray(jitted(jax.random.key(1), st))
        np.testing.assert_array_equal(idx, idx2, err_msg=name)


def test_power_of_choice_d_limits_candidates():
    s = selection.make_strategy("power-of-choice", d=3)
    st = _sstate(c=30)
    idx = np.asarray(s.select_fn(jax.random.key(0), st, 3))
    assert len(set(idx.tolist())) == 3
    # d > C clips to C without error
    big = selection.make_strategy("power-of-choice", d=10_000)
    idx = np.asarray(big.select_fn(jax.random.key(0), st, 5))
    assert len(set(idx.tolist())) == 5


def test_cluster_fit_invalidates_on_content():
    """Regression: same-shape, different-content fingerprints must re-fit
    (labels were cached on ``(shape, k)`` only, so a reprofile with unchanged
    shapes silently kept the stale clustering)."""
    rng = np.random.default_rng(0)
    centers = 5.0 * np.eye(3, 4)
    blobs = [c + rng.normal(0, 0.05, size=(4, 4)) for c in centers]
    feats = np.concatenate(blobs).astype(np.float32)  # clients 0-3|4-7|8-11
    strat = selection.ClusterSelection()
    labels1 = np.asarray(strat.fit(feats, 3))
    assert labels1[0] == labels1[3] and labels1[0] != labels1[8]
    # same shape, new content: clients 0-1 now sit in blob 2's location
    feats2 = feats.copy()
    feats2[[0, 1]] = centers[2] + rng.normal(0, 0.05, size=(2, 4))
    labels2 = np.asarray(strat.fit(feats2.astype(np.float32), 3))
    assert labels2[0] == labels2[8], (labels1, labels2)  # re-clustered
    assert labels2[0] != labels2[2], (labels1, labels2)
    # identical content -> served from the cache, identical labels
    again = np.asarray(strat.fit(feats2.astype(np.float32), 3))
    np.testing.assert_array_equal(labels2, again)


def test_cluster_select_fn_one_pick_per_cluster():
    c, k = 12, 3
    st = selection.selection_state(
        c,
        k,
        client_sizes=jnp.ones((c,)),
        cluster_labels=jnp.asarray(np.arange(c) % k, jnp.int32),
    )
    strat = selection.ClusterSelection()
    for i in range(10):
        idx = np.asarray(strat.select_fn(jax.random.key(i), st, k))
        assert sorted(int(j) % k for j in idx) == [0, 1, 2]


def test_legacy_select_wrapper_matches_pure_path():
    """select(key, RoundState) must equal prepare() + select_fn(key, ...)."""
    rng = np.random.default_rng(4)
    f = jnp.asarray(rng.normal(size=(15, 5)).astype(np.float32))
    rs = selection.RoundState(
        num_clients=15,
        kernel=similarity.kernel_from_profiles(f),
        profiles=f,
        losses=jnp.asarray(rng.uniform(0.1, 2.0, size=(15,)).astype(np.float32)),
        client_sizes=jnp.full((15,), 10.0),
    )
    for name in selection.STRATEGY_NAMES:
        s = selection.make_strategy(name)
        a = np.asarray(s.select(jax.random.key(7), rs, 4))
        b = np.asarray(s.select_fn(jax.random.key(7), s.prepare(rs, 4), 4))
        np.testing.assert_array_equal(a, b, err_msg=name)

"""Property tests on system invariants (hypothesis).

The big one: *causality* — logits at position t must not depend on tokens at
positions > t, for every mixer family (full attention, SWA, RG-LRU hybrid,
RWKV).  This catches masking bugs, ring-buffer off-by-ones, and scan-carry
leaks that shape-only smoke tests miss.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

from repro.configs.base import ModelConfig
from repro.models import transformer as T

S = 24
FAMS = {
    "dense": dict(),
    "swa": dict(block_pattern=("swa+mlp",), window=6),
    "hybrid": dict(block_pattern=("rglru+mlp", "rglru+mlp", "local+mlp"),
                   num_layers=3, local_window=6, rnn_width=64, arch_type="hybrid"),
    "rwkv": dict(block_pattern=("rwkv+cmix",), rwkv_head_dim=16, arch_type="ssm"),
}


@functools.lru_cache(maxsize=8)
def _model(fam):
    kw = dict(FAMS[fam])
    cfg = ModelConfig(
        name=fam, arch_type=kw.pop("arch_type", "dense"),
        num_layers=kw.pop("num_layers", 2), d_model=64, num_heads=2,
        num_kv_heads=2, head_dim=32, d_ff=128, vocab_size=64, **kw,
    )
    params = T.init_params(jax.random.key(0), cfg)

    @jax.jit
    def logits(tokens):
        pos = jnp.broadcast_to(jnp.arange(tokens.shape[1])[None], tokens.shape)
        hid, _, _ = T.forward(cfg, params, tokens, pos)
        return T.logits_from_hidden(cfg, params, hid)

    return cfg, params, logits


@pytest.mark.parametrize("fam", list(FAMS))
@settings(max_examples=5, deadline=None)
@given(data=st.data())
def test_causality(fam, data):
    _, _, logits = _model(fam)
    toks = np.asarray(
        data.draw(st.lists(st.integers(0, 63), min_size=S, max_size=S)), np.int32
    )[None]
    t = data.draw(st.integers(1, S - 2))
    toks2 = toks.copy()
    toks2[:, t + 1 :] = (toks2[:, t + 1 :] + 17) % 64  # perturb the future
    a = np.asarray(logits(jnp.asarray(toks)))[:, : t + 1]
    b = np.asarray(logits(jnp.asarray(toks2)))[:, : t + 1]
    np.testing.assert_allclose(a, b, atol=1e-4), fam


@settings(max_examples=5, deadline=None)
@given(shift=st.integers(1, 100))
def test_rope_relative_position_invariance(shift):
    """RoPE attention depends on relative positions only: shifting all
    position ids must not change the outputs."""
    from repro.models import attention as A

    cfg, params, _ = _model("dense")
    x = jax.random.normal(jax.random.key(1), (1, 8, cfg.d_model))
    p = jax.tree_util.tree_map(lambda v: v, params["unit"][0])
    blk = jax.tree_util.tree_map(lambda v: v[0], p)  # first stacked layer
    pos0 = jnp.arange(8)[None]
    y0, _ = A.apply_attention(cfg, blk["mixer"], x, pos0)
    y1, _ = A.apply_attention(cfg, blk["mixer"], x, pos0 + shift)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1), atol=1e-3)


def test_swa_cache_ring_wraparound_matches_full_history():
    """Decoding far past the window: the ring buffer must equal recomputing
    attention over the true last-`window` tokens."""
    cfg, params, logits = _model("swa")
    toks = jax.random.randint(jax.random.key(2), (1, S), 0, 64)
    full = logits(toks)
    caches = T.init_caches(cfg, 1, S)
    lg = None
    for t in range(S):
        lg, caches = T.decode_step(cfg, params, toks[:, t : t + 1], caches)
    np.testing.assert_allclose(
        np.asarray(lg[:, 0]), np.asarray(full[:, -1]), atol=1e-3
    )


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 10**6))
def test_moe_outputs_finite_any_routing(seed):
    """MoE must stay finite under any routing pattern (incl. all-to-one
    overflow -> capacity drops)."""
    from repro.models import moe as M

    cfg = ModelConfig(
        name="m", arch_type="moe", num_layers=2, d_model=32, num_heads=2,
        num_kv_heads=2, head_dim=16, d_ff=64, vocab_size=64,
        num_experts=4, experts_per_token=2, capacity_factor=1.0,
    )
    p = M.init_moe(jax.random.key(seed), cfg)
    x = jax.random.normal(jax.random.key(seed + 1), (2, 8, 32))
    y, aux = M.apply_moe(cfg, p, x)
    assert np.isfinite(np.asarray(y, np.float32)).all()
    assert np.isfinite(float(aux))

"""Per-kernel allclose vs the ref.py oracles, swept over shapes/dtypes.

All kernels run in interpret mode on CPU (TPU is the compile target)."""

import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

from repro.kernels.flash_attention import ops as flash_ops
from repro.kernels.flash_attention import ref as flash_ref
from repro.kernels.pairwise_l2 import ops as pw_ops
from repro.kernels.pairwise_l2 import ref as pw_ref
from repro.kernels.rwkv6_scan import ops as wkv_ops
from repro.kernels.rwkv6_scan import ref as wkv_ref

RNG = np.random.default_rng(0)


# ------------------------------------------------------------ pairwise_l2


@pytest.mark.parametrize(
    "c,q", [(4, 3), (10, 7), (100, 128), (130, 257), (64, 512)]
)
@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_pairwise_l2_sweep(c, q, dtype):
    f = jnp.asarray(RNG.normal(size=(c, q))).astype(dtype)
    got = np.asarray(pw_ops.pairwise_sq_dists(f))
    want = np.asarray(pw_ref.pairwise_sq_dists_ref(f)) * (1 - np.eye(c))
    tol = 5e-2 * max(1.0, want.max()) if dtype == jnp.bfloat16 else 1e-3 * max(1.0, want.max())
    np.testing.assert_allclose(got, want, atol=tol)
    assert (got >= 0).all()
    np.testing.assert_allclose(np.diag(got), 0.0, atol=0)


@settings(max_examples=10, deadline=None)
@given(
    c=st.integers(2, 40),
    q=st.integers(1, 64),
    bm=st.sampled_from([8, 16, 128]),
    bk=st.sampled_from([8, 32, 512]),
)
def test_pairwise_l2_block_shape_property(c, q, bm, bk):
    """Property: result is block-shape independent."""
    f = jnp.asarray(np.random.default_rng(c * 100 + q).normal(size=(c, q)).astype(np.float32))
    got = np.asarray(pw_ops.pairwise_sq_dists(f, block_m=bm, block_n=bm, block_k=bk))
    want = np.asarray(pw_ref.pairwise_sq_dists_ref(f)) * (1 - np.eye(c))
    np.testing.assert_allclose(got, want, atol=1e-3 * max(1.0, want.max()))


# (gram / fused profiles→kernel coverage lives in tests/test_gram_kernels.py,
# which is deliberately hypothesis-free so it runs in minimal containers)

# ------------------------------------------------------------ flash attention


@pytest.mark.parametrize(
    "b,s,h,hk,hd,window,bq,bk",
    [
        (2, 64, 4, 2, 32, None, 32, 32),
        (1, 100, 4, 4, 16, None, 32, 16),   # padded, MHA
        (2, 64, 8, 2, 32, 16, 32, 32),      # GQA + window
        (1, 128, 4, 1, 64, 32, 64, 32),     # MQA + window
        (1, 32, 2, 2, 8, None, 8, 8),
    ],
)
def test_flash_attention_sweep(b, s, h, hk, hd, window, bq, bk):
    q = jnp.asarray(RNG.normal(size=(b, s, h, hd)).astype(np.float32))
    k = jnp.asarray(RNG.normal(size=(b, s, hk, hd)).astype(np.float32))
    v = jnp.asarray(RNG.normal(size=(b, s, hk, hd)).astype(np.float32))
    got = flash_ops.flash_attention(q, k, v, window=window, block_q=bq, block_k=bk)
    want = flash_ref.attention_ref(q, k, v, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


def test_flash_attention_bf16():
    q = jnp.asarray(RNG.normal(size=(1, 64, 4, 32))).astype(jnp.bfloat16)
    k = jnp.asarray(RNG.normal(size=(1, 64, 2, 32))).astype(jnp.bfloat16)
    v = jnp.asarray(RNG.normal(size=(1, 64, 2, 32))).astype(jnp.bfloat16)
    got = flash_ops.flash_attention(q, k, v)
    want = flash_ref.attention_ref(q, k, v)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), atol=3e-2
    )


def test_flash_attention_rejects_bad_heads():
    q = jnp.zeros((1, 8, 3, 4))
    k = v = jnp.zeros((1, 8, 2, 4))
    with pytest.raises(ValueError):
        flash_ops.flash_attention(q, k, v)


# ------------------------------------------------------------ rwkv6 scan


@pytest.mark.parametrize(
    "b,t,h,hd,bt",
    [(2, 64, 2, 16, 32), (1, 100, 3, 32, 64), (2, 33, 1, 64, 16), (1, 16, 2, 8, 16)],
)
def test_rwkv6_scan_sweep(b, t, h, hd, bt):
    r = jnp.asarray(RNG.normal(size=(b, t, h, hd)).astype(np.float32))
    k = jnp.asarray(RNG.normal(size=(b, t, h, hd)).astype(np.float32))
    v = jnp.asarray(RNG.normal(size=(b, t, h, hd)).astype(np.float32))
    w = jnp.asarray(RNG.uniform(0.4, 0.99, size=(b, t, h, hd)).astype(np.float32))
    u = jnp.asarray(RNG.normal(size=(h, hd)).astype(np.float32))
    s0 = jnp.asarray(RNG.normal(size=(b, h, hd, hd)).astype(np.float32))
    y1, s1 = wkv_ops.wkv6(r, k, v, w, u, s0, block_t=bt)
    y2, s2 = wkv_ref.wkv6_scan_ref(r, k, v, w, u, s0)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=5e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=5e-4)


def test_rwkv6_state_handoff_equals_one_shot():
    """Running T in two halves with state hand-off == one shot (decode path)."""
    b, t, h, hd = 1, 32, 2, 16
    r = jnp.asarray(RNG.normal(size=(b, t, h, hd)).astype(np.float32))
    k = jnp.asarray(RNG.normal(size=(b, t, h, hd)).astype(np.float32))
    v = jnp.asarray(RNG.normal(size=(b, t, h, hd)).astype(np.float32))
    w = jnp.asarray(RNG.uniform(0.5, 0.99, size=(b, t, h, hd)).astype(np.float32))
    u = jnp.asarray(RNG.normal(size=(h, hd)).astype(np.float32))
    s0 = jnp.zeros((b, h, hd, hd), jnp.float32)
    y_full, s_full = wkv_ops.wkv6(r, k, v, w, u, s0, block_t=16)
    y1, s_mid = wkv_ops.wkv6(r[:, :16], k[:, :16], v[:, :16], w[:, :16], u, s0, block_t=16)
    y2, s_end = wkv_ops.wkv6(r[:, 16:], k[:, 16:], v[:, 16:], w[:, 16:], u, s_mid, block_t=16)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)), np.asarray(y_full), atol=1e-4)
    np.testing.assert_allclose(np.asarray(s_end), np.asarray(s_full), atol=1e-4)

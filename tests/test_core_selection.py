"""Tests for selection strategies and the GEMD metric."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

from repro.core import metrics, selection, similarity  # noqa: E402


def _state(c=20, q=6, seed=0, with_losses=True):
    rng = np.random.default_rng(seed)
    f = jnp.asarray(rng.normal(size=(c, q)).astype(np.float32))
    kern = similarity.kernel_from_profiles(f)
    return selection.RoundState(
        num_clients=c,
        kernel=kern,
        profiles=f,
        losses=jnp.asarray(rng.uniform(0.1, 3.0, size=(c,)).astype(np.float32))
        if with_losses
        else None,
        client_sizes=jnp.full((c,), 50.0),
    )


def test_all_strategies_return_k_distinct():
    st_ = _state()
    for strat in [
        selection.UniformSelection(),
        selection.DPPSelection(),
        selection.DPPSelection(mode="map"),
        selection.FedSAESelection(),
        selection.ClusterSelection(),
        selection.PowerOfChoiceSelection(d=10),
    ]:
        idx = np.asarray(strat.select(jax.random.key(0), st_, 5))
        assert idx.shape == (5,), strat.name
        assert len(set(idx.tolist())) == 5, strat.name
        assert (idx >= 0).all() and (idx < st_.num_clients).all()


def test_fedsae_prefers_high_loss():
    st_ = _state(c=30)
    losses = np.asarray(st_.losses)
    hits = np.zeros(30)
    for i in range(200):
        idx = np.asarray(
            selection.FedSAESelection().select(jax.random.key(i), st_, 5)
        )
        hits[idx] += 1
    top = np.argsort(-losses)[:10]
    bot = np.argsort(losses)[:10]
    assert hits[top].mean() > 1.5 * hits[bot].mean()


def test_cluster_selection_one_per_cluster():
    # Three well-separated blobs of fingerprints -> with k=3, each pick
    # comes from a different blob.
    rng = np.random.default_rng(0)
    centers = 5.0 * np.eye(3, 4)  # three orthogonal directions (cosine-separable)
    blobs = [c + rng.normal(0, 0.05, size=(5, 4)) for c in centers]
    f = jnp.asarray(np.concatenate(blobs).astype(np.float32))
    st_ = selection.RoundState(num_clients=15, profiles=f, client_sizes=jnp.ones((15,)))
    idx = np.asarray(selection.ClusterSelection().select(jax.random.key(0), st_, 3))
    groups = set(i // 5 for i in idx.tolist())
    assert groups == {0, 1, 2}


def test_gemd_zero_for_perfect_mix():
    # two complementary clients average to the global distribution
    dists = jnp.asarray([[1.0, 0.0], [0.0, 1.0]])
    sizes = jnp.asarray([10.0, 10.0])
    g = metrics.gemd(dists, sizes, jnp.asarray([0, 1]), jnp.asarray([0.5, 0.5]))
    assert np.isclose(float(g), 0.0, atol=1e-6)


def test_gemd_max_for_single_class_cohort():
    dists = jnp.asarray([[1.0, 0.0], [0.0, 1.0]])
    sizes = jnp.asarray([10.0, 10.0])
    g = metrics.gemd(dists, sizes, jnp.asarray([0, 0]), jnp.asarray([0.5, 0.5]))
    assert np.isclose(float(g), 1.0, atol=1e-6)  # |1-0.5|+|0-0.5|


@settings(max_examples=20, deadline=None)
@given(
    c=st.integers(min_value=2, max_value=10),
    n=st.integers(min_value=2, max_value=6),
    seed=st.integers(min_value=0, max_value=10**6),
)
def test_gemd_bounds_property(c, n, seed):
    """Property: 0 <= GEMD <= 2 (L1 distance of two distributions)."""
    rng = np.random.default_rng(seed)
    d = rng.dirichlet(np.ones(n), size=c).astype(np.float32)
    sizes = rng.integers(1, 100, size=c).astype(np.float32)
    global_d = (sizes[:, None] * d).sum(0) / sizes.sum()
    sel = rng.choice(c, size=min(3, c), replace=False)
    g = float(
        metrics.gemd(jnp.asarray(d), jnp.asarray(sizes), jnp.asarray(sel), jnp.asarray(global_d))
    )
    assert -1e-5 <= g <= 2.0 + 1e-5


def test_dpp_selection_lowers_gemd_vs_uniform():
    """The paper's headline mechanism: DPP cohorts are more diverse (lower
    GEMD) than uniform cohorts when profiles reflect label skew."""
    rng = np.random.default_rng(0)
    c, n = 30, 10
    labels = np.arange(c) % n  # one class per client (xi = 1)
    dists = np.eye(n, dtype=np.float32)[labels]
    # profiles = class embedding + tiny noise (ideal profiling)
    centers = rng.normal(size=(n, 8)).astype(np.float32)
    f = centers[labels] + 0.01 * rng.normal(size=(c, 8)).astype(np.float32)
    kern = similarity.kernel_from_profiles(jnp.asarray(f))
    sizes = jnp.full((c,), 10.0)
    global_d = jnp.asarray(dists.mean(0))
    st_ = selection.RoundState(
        num_clients=c, kernel=kern, profiles=jnp.asarray(f), client_sizes=sizes
    )

    def avg_gemd(strat, rounds=40):
        tot = 0.0
        for i in range(rounds):
            idx = strat.select(jax.random.key(i), st_, n)
            tot += float(metrics.gemd(jnp.asarray(dists), sizes, idx, global_d))
        return tot / rounds

    g_dpp = avg_gemd(selection.DPPSelection())
    g_uni = avg_gemd(selection.UniformSelection())
    assert g_dpp < 0.7 * g_uni, (g_dpp, g_uni)

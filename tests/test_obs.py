"""Telemetry contract (DESIGN.md §14).

Tier-1 (single device): the static-flag bit-identity contract
(``telemetry=False`` is the default and ``telemetry=True`` changes no
carried state or shared metric — final-params parity across plain / funnel /
fault / scenario modes), JSONL schema round-trips, manifest determinism,
serve zero-recompile with a sink attached, and the report renderer.  The
mesh-sharded and bounded-staleness variants run under the CI
``multidevice`` job (``XLA_FLAGS=--xla_force_host_platform_device_count=8``).
"""

import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import report as report_lib
from repro.core import selection as selection_lib
from repro.fl import engine
from repro.fl.trainer import FLTrainer
from repro.launch import serve as serve_mod
from repro.launch.mesh import make_client_mesh
from repro.obs import (
    TelemetrySink,
    config_hash,
    load_events,
    run_manifest,
)
from repro.obs.telemetry import Telemetry
from repro.serve import ServeConfig, ServeEngine

multidevice = pytest.mark.skipif(
    jax.device_count() < 2,
    reason="needs >1 device (XLA_FLAGS=--xla_force_host_platform_device_count=8)",
)

FEAT, N_C, NCLS = 8, 6, 4


def linear_loss(params, x, y):
    logp = jax.nn.log_softmax(x @ params["w"] + params["b"])
    return -jnp.mean(jnp.take_along_axis(logp, y[..., None], axis=-1))


def _federation(c, seed=0):
    rng = np.random.default_rng(seed)
    xs = jnp.asarray(rng.normal(size=(c, N_C, FEAT)).astype(np.float32))
    ys = jnp.asarray(rng.integers(0, NCLS, size=(c, N_C)), jnp.int32)
    params = {
        "w": jnp.asarray(0.01 * rng.normal(size=(FEAT, NCLS)).astype(np.float32)),
        "b": jnp.zeros((NCLS,), jnp.float32),
    }
    return xs, ys, params


def _run(c=12, k=4, rounds=6, mesh=None, telemetry=False, **cfg_kw):
    xs, ys, params = _federation(c)
    cfg = engine.FLConfig(
        num_clients=c, clients_per_round=k, local_epochs=2, lr=0.1,
        rounds=rounds, eval_every=2, num_classes=NCLS, seed=0,
        telemetry=telemetry, **cfg_kw,
    )
    strat = selection_lib.UniformSelection()
    state = engine.init_server_state(
        cfg, params, linear_loss, None, xs, ys,
        strategy=strat, profiles=xs.mean(axis=1), mesh=mesh,
    )
    rf = engine.make_round_fn(cfg, linear_loss, (strat,), mesh=mesh)
    fin, outs = engine.run_scanned(rf, state, rounds, mesh=mesh)
    return fin, jax.tree_util.tree_map(np.asarray, outs)


def _max_param_diff(a, b):
    return max(
        float(jnp.max(jnp.abs(x.astype(jnp.float32) - y.astype(jnp.float32))))
        for x, y in zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b))
    )


# ------------------------------------------------- off-by-default contract


def test_telemetry_default_off_and_no_extra_outputs():
    assert engine.FLConfig().telemetry is False
    _, outs = _run(telemetry=False)
    assert "telemetry" not in outs


MODES = {
    "plain": {},
    "funnel": {"candidate_frac": 0.75},
    "fault_guarded": {"faults": "chaos", "aggregator": "trimmed_mean"},
    "scenario": {"scenario": "flaky"},
}


@pytest.mark.parametrize("mode", sorted(MODES))
def test_telemetry_on_is_bit_identical(mode):
    """telemetry=True only ADDS output leaves: the carried state (final
    params) and every shared per-round metric are bit-equal to the
    telemetry=False run — the key-stream / state-purity contract."""
    fin_off, outs_off = _run(telemetry=False, **MODES[mode])
    fin_on, outs_on = _run(telemetry=True, **MODES[mode])
    assert _max_param_diff(fin_off.params, fin_on.params) == 0.0
    assert set(outs_on) == set(outs_off) | {"telemetry"}
    for k in outs_off:
        np.testing.assert_array_equal(
            outs_off[k], outs_on[k], err_msg=f"{mode}: metric {k!r} diverged"
        )
    assert isinstance(outs_on["telemetry"], Telemetry)


@multidevice
@pytest.mark.parametrize("extra", [
    {},
    {"staleness_bound": 1, "scenario": "uniform"},
])
def test_telemetry_bit_identical_sharded(extra):
    mesh = make_client_mesh(jax.device_count())
    c = 4 * jax.device_count()
    fin_off, _ = _run(c=c, mesh=mesh, telemetry=False, **extra)
    fin_on, outs_on = _run(c=c, mesh=mesh, telemetry=True, **extra)
    assert _max_param_diff(fin_off.params, fin_on.params) == 0.0
    if "staleness_bound" in extra:
        hist = outs_on["telemetry"].staleness_hist
        assert hist.shape == (6, extra["staleness_bound"] + 1)
        # every shard contributes at exactly one lag each round
        assert (hist.sum(axis=1) == jax.device_count()).all()


# -------------------------------------------------------- telemetry fields


def test_telemetry_field_semantics():
    _, outs = _run(telemetry=True, rounds=6, reprofile_every=3,
                   candidate_frac=0.5)
    tel = outs["telemetry"]
    q = engine.FLConfig(
        num_clients=12, clients_per_round=4, candidate_frac=0.5,
    ).candidate_count()
    assert (tel.funnel_q == q).all()
    np.testing.assert_allclose(tel.funnel_survival, q / 12, rtol=1e-6)
    # cache age resets on the aligned reprofile boundary
    np.testing.assert_array_equal(tel.cache_age, [0, 1, 2, 0, 1, 2])
    # honest path: full cohort survives, nothing flagged or quarantined
    assert (tel.survivors == 4).all()
    assert (tel.flagged == 0).all() and (tel.quarantined == 0).all()
    assert (tel.identity_round == 0).all()
    # spectrum summary: positive trace, erank within [1, Q]
    assert (tel.spectrum_trace > 0).all()
    assert (tel.spectrum_erank >= 1).all() and (tel.spectrum_erank <= q).all()
    assert tel.avail_frac is None and tel.staleness_hist is None
    # availability-aware scenario populates the availability fraction
    _, outs_f = _run(telemetry=True, scenario="flaky")
    af = outs_f["telemetry"].avail_frac
    assert af.shape == (6,) and (af >= 0).all() and (af <= 1).all()


# ------------------------------------------------------------ JSONL schema

FL_ROUND_REQUIRED = {
    "event", "t", "wall", "round", "acc", "gemd", "loss", "selected",
    "funnel_q", "funnel_survival", "cache_age", "spectrum_top",
    "spectrum_trace", "spectrum_erank", "survivors", "flagged",
    "quarantined", "identity_round",
}


def test_jsonl_schema_roundtrip(tmp_path):
    path = tmp_path / "train.jsonl"
    _, outs = _run(telemetry=True, rounds=5)
    with TelemetrySink(str(path)) as sink:
        man = sink.write_manifest(
            config={"demo": 1}, extra={"mode": "fl"}
        )
        from repro.obs.sink import drain_fl_outputs

        assert drain_fl_outputs(sink, outs) == 5
    # strict JSON: every line parses, NaN sanitised to null
    lines = path.read_text().strip().splitlines()
    for line in lines:
        json.loads(line)
    events = load_events(str(path))
    assert [e["event"] for e in events] == ["manifest"] + ["fl_round"] * 5
    assert events[0]["config_hash"] == man["config_hash"]
    for k in ("jax_version", "backend", "device_count", "host_cores"):
        assert k in events[0]
    for i, e in enumerate(events[1:]):
        assert FL_ROUND_REQUIRED <= set(e)
        assert e["round"] == i + 1
        assert e["acc"] is None or isinstance(e["acc"], float)
        assert isinstance(e["selected"], list) and len(e["selected"]) == 4


def test_trainer_drains_sink_at_segment_boundaries(tmp_path):
    xs, ys, params = _federation(8)
    cfg = engine.FLConfig(
        num_clients=8, clients_per_round=3, local_epochs=1, lr=0.1,
        rounds=6, eval_every=2, num_classes=NCLS, seed=0,
        reprofile_every=2, telemetry=True,
    )
    tr = FLTrainer(
        cfg, params, linear_loss,
        lambda p, x: (None, x @ p["w"]),
        np.asarray(xs), np.asarray(ys),
        strategy=selection_lib.UniformSelection(),
    )
    path = tmp_path / "trainer.jsonl"
    with TelemetrySink(str(path)) as sink:
        sink.write_manifest(config=dataclasses.asdict(cfg))
        tr.run(sink=sink)
    events = load_events(str(path))
    kinds = [e["event"] for e in events]
    assert kinds.count("fl_round") == 6
    assert kinds.count("fl_reprofile") == 2  # boundaries inside the run
    assert kinds[0] == "manifest"


def test_checkpointed_merge_with_telemetry(tmp_path):
    """run_checkpointed's segment merge is tree-aware: the telemetry
    subtree concatenates across segments like any other output leaf."""
    xs, ys, params = _federation(10)
    cfg = engine.FLConfig(
        num_clients=10, clients_per_round=3, local_epochs=1, lr=0.1,
        rounds=7, eval_every=2, num_classes=NCLS, seed=0,
        ckpt_every=3, telemetry=True,
    )
    strat = selection_lib.UniformSelection()
    state = engine.init_server_state(
        cfg, params, linear_loss, None, xs, ys,
        strategy=strat, profiles=xs.mean(axis=1),
    )
    rf = engine.make_round_fn(cfg, linear_loss, (strat,))
    fin, outs = engine.run_checkpointed(
        rf, state, 7, ckpt_dir=str(tmp_path / "ckpt"), ckpt_every=3
    )
    assert outs["telemetry"].cache_age.shape == (7,)
    np.testing.assert_array_equal(np.asarray(outs["round"]), np.arange(1, 8))


# ----------------------------------------------------- manifest determinism


def test_manifest_determinism():
    cfg = engine.FLConfig(num_clients=16, clients_per_round=4, telemetry=True)
    h1 = config_hash(cfg)
    h2 = config_hash(engine.FLConfig(
        num_clients=16, clients_per_round=4, telemetry=True
    ))
    assert h1 == h2
    assert config_hash(dataclasses.asdict(cfg)) == h1
    assert run_manifest(config=cfg)["config_hash"] == h1
    assert config_hash(
        engine.FLConfig(num_clients=16, clients_per_round=5, telemetry=True)
    ) != h1


# --------------------------------------------------- serve zero-recompile


def test_serve_zero_recompile_and_token_parity_with_telemetry(tmp_path):
    cfg, params = serve_mod.build_model("smollm-360m", seed=0)
    b, p, g = 3, 6, 8
    scfg = ServeConfig(batch=b, cache_len=p + g, max_new=g, decode_chunk=4)
    prompts = np.asarray(jax.random.randint(
        jax.random.key(1), (7, p), 0, cfg.vocab_size, jnp.int32
    ))
    budgets = [8, 3, 1, 5, 8, 2, 4]

    def traffic(telemetry):
        eng = ServeEngine(cfg, scfg, params, prompt_len=p,
                          key=jax.random.key(0), telemetry=telemetry)
        for i in range(len(budgets)):
            eng.submit(prompts[i], budgets[i])
        fin = eng.run()
        return eng, {f.seq_id: f.tokens for f in fin}

    path = tmp_path / "serve.jsonl"
    sink = TelemetrySink(str(path))
    eng_on, toks_on = traffic(sink)
    sink.close()
    eng_off, toks_off = traffic(None)
    # exactly-two-compiled-programs guarantee survives the sink
    assert eng_on.compile_counts() == {"decode_chunk": 1, "admit": 1}
    # telemetry is host-only: the token streams are bit-identical
    assert set(toks_on) == set(toks_off)
    for sid in toks_on:
        np.testing.assert_array_equal(toks_on[sid], toks_off[sid])
    events = load_events(str(path))
    kinds = [e["event"] for e in events]
    assert kinds.count("serve_submit") == 7
    assert kinds.count("serve_admit") == 7
    assert kinds.count("serve_finish") == 7
    assert kinds.count("serve_chunk") >= 1
    for e in events:
        if e["event"] == "serve_admit":
            assert e["ttft_s"] >= 0 and 1 <= e["occupancy"] <= b
        if e["event"] == "serve_chunk":
            assert e["tokens"] >= 0 and e["dt_s"] > 0
    fin_by_id = {
        e["seq_id"]: e for e in events if e["event"] == "serve_finish"
    }
    assert {sid: e["n_tokens"] for sid, e in fin_by_id.items()} == {
        i: budgets[i] for i in range(7)
    }


# ------------------------------------------------------------------ report


def test_report_renders_train_and_serve(tmp_path):
    path = tmp_path / "mixed.jsonl"
    _, outs = _run(telemetry=True, rounds=5)
    with TelemetrySink(str(path)) as sink:
        sink.write_manifest(config={"demo": 1}, extra={"mode": "fl"})
        from repro.obs.sink import drain_fl_outputs

        drain_fl_outputs(sink, outs)
        sink.emit("serve_submit", seq_id=0, gen_target=4, queue_depth=1)
        sink.emit("serve_admit", seq_id=0, ttft_s=0.01, queue_depth=0,
                  occupancy=1)
        sink.emit("serve_chunk", steps=4, tokens=4, dt_s=0.002, tok_s=2000.0,
                  active_slots=1, batch=2, queue_depth=0)
        sink.emit("serve_finish", seq_id=0, n_tokens=4, latency_s=0.02)
    text = report_lib.summarize(load_events(str(path)))
    assert "run manifest" in text
    assert "training: 5 rounds" in text
    assert "serving: 1 finished seqs" in text
    assert "TTFT" in text
    assert report_lib.summarize([]) == "no telemetry events"

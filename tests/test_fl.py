"""FL runtime tests: eq.-(6) aggregation, Mode-A/Mode-B round steps, and the
end-to-end Algorithm-1 integration (accuracy rises; DPP lowers GEMD)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim
from repro.core import make_strategy
from repro.data import make_image_dataset, skewness_partition
from repro.fl import (
    FLConfig,
    FLTrainer,
    build_client_parallel_round,
    build_fedsgd_step,
    build_server_opt_round,
    weighted_average,
)
from repro.models import cnn


def test_weighted_average_matches_eq6():
    trees = {"w": jnp.asarray([[1.0, 1.0], [3.0, 3.0]])}
    weights = jnp.asarray([1.0, 3.0])
    out = weighted_average(trees, weights)
    np.testing.assert_allclose(np.asarray(out["w"]), [2.5, 2.5])


def test_client_parallel_round_is_local_sgd():
    """One client, quadratic loss: Mode-A round == E plain SGD steps."""
    lr, steps = 0.1, 3

    def loss(p, batch):
        return jnp.sum((p["w"] - batch) ** 2)

    step_fn = build_client_parallel_round(loss, lr, steps)
    params = {"w": jnp.zeros((2,))}
    target = jnp.asarray([1.0, -1.0])
    batches = jnp.broadcast_to(target, (1, steps, 2))  # (C_p=1, steps, ...)
    out, _ = step_fn(params, batches, jnp.asarray([1.0]))
    # analytic: w_{t+1} = w + 2*lr*(target - w);  w0=0
    w = np.zeros(2)
    for _ in range(steps):
        w = w + 2 * lr * (np.asarray(target) - w)
    np.testing.assert_allclose(np.asarray(out["w"]), w, rtol=1e-5)


def test_client_parallel_aggregation_averages_divergent_clients():
    def loss(p, batch):
        return jnp.sum((p["w"] - batch) ** 2)

    step_fn = build_client_parallel_round(loss, 0.25, 1)
    params = {"w": jnp.zeros((1,))}
    batches = jnp.asarray([[[2.0]], [[-2.0]]])  # two clients, opposite targets
    out, _ = step_fn(params, batches, jnp.asarray([1.0, 1.0]))
    np.testing.assert_allclose(np.asarray(out["w"]), [0.0], atol=1e-6)
    out2, _ = step_fn(params, batches, jnp.asarray([3.0, 1.0]))  # n_c weighting
    assert float(out2["w"][0]) > 0


def test_fedsgd_step_reduces_loss():
    def loss(p, batch):
        x, y = batch
        return jnp.mean((x @ p["w"] - y) ** 2)

    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(64, 4)).astype(np.float32))
    w_true = jnp.asarray([1.0, -2.0, 0.5, 3.0])
    y = x @ w_true
    opt = optim.adam(0.05)
    step = jax.jit(build_fedsgd_step(loss, opt))
    params = {"w": jnp.zeros((4,))}
    state = opt.init(params)
    l0 = float(loss(params, (x, y)))
    for _ in range(100):
        params, state, l = step(params, state, (x, y))
    assert float(l) < 0.05 * l0


def test_server_opt_round_matches_plain_round_with_sgd1():
    """FedOpt with server SGD(lr=1) reduces exactly to vanilla FedAvg."""

    def loss(p, batch):
        return jnp.sum((p["w"] - batch) ** 2)

    plain = build_client_parallel_round(loss, 0.1, 2)
    sopt = optim.sgd(1.0)
    fedopt = build_server_opt_round(loss, 0.1, 2, sopt)
    params = {"w": jnp.zeros((2,))}
    batches = jnp.asarray([[[1.0, -1.0]] * 2, [[2.0, 0.5]] * 2])  # (2 clients, 2 steps, 2)
    w = jnp.ones((2,))
    out_plain, _ = plain(params, batches, w)
    out_fedopt, _, _ = fedopt(params, sopt.init(params), batches, w)
    np.testing.assert_allclose(
        np.asarray(out_plain["w"]), np.asarray(out_fedopt["w"]), rtol=1e-6
    )


def test_server_momentum_accelerates_on_quadratic():
    def loss(p, batch):
        return jnp.sum((p["w"] - batch) ** 2)

    target = jnp.asarray([4.0])
    batches = jnp.broadcast_to(target, (1, 1, 1))
    w = jnp.ones((1,))
    plain = build_client_parallel_round(loss, 0.05, 1)
    sopt = optim.sgd(1.0, momentum=0.6)
    fedopt = build_server_opt_round(loss, 0.05, 1, sopt)
    p1 = {"w": jnp.zeros((1,))}
    p2 = {"w": jnp.zeros((1,))}
    st = sopt.init(p2)
    for _ in range(20):
        p1, _ = plain(p1, batches, w)
        p2, st, _ = fedopt(p2, st, batches, w)
    # momentum closes the gap to the target faster
    assert abs(float(p2["w"][0]) - 4.0) < abs(float(p1["w"][0]) - 4.0)


@pytest.fixture(scope="module")
def small_federation():
    # 20 clients over 10 classes at ξ=1 -> ~2 single-class clients per class,
    # so cohort *diversity* is a real choice (several clients look alike) —
    # the regime the paper's k-DPP mechanism targets.
    ds = make_image_dataset(n=20 * 60, seed=0)
    shards = skewness_partition(ds.ys, 20, 1.0, 10, samples_per_client=60, seed=0)
    cxs = np.stack([ds.xs[s] for s in shards])
    cys = np.stack([ds.ys[s] for s in shards])
    return cxs, cys


def _trainer(small_federation, strategy_name, rounds=8, eval_every=None):
    cxs, cys = small_federation
    params = cnn.init_cnn(jax.random.key(0), channels=(8, 16), fc1_dim=64)
    cfg = FLConfig(
        num_clients=20, clients_per_round=5, rounds=rounds, local_epochs=1,
        lr=0.05, eval_every=eval_every or rounds, seed=0,
    )
    return FLTrainer(
        cfg, params, cnn.cnn_loss, cnn.apply_with_features, cxs, cys,
        make_strategy(strategy_name), accuracy_fn=cnn.accuracy,
    )


def test_fl_dp3s_end_to_end_accuracy_improves(small_federation):
    tr = _trainer(small_federation, "fl-dp3s", rounds=16, eval_every=4)
    hist = tr.run()
    assert max(hist["acc"]) > 0.25  # well above the 0.1 random baseline


def test_dpp_gemd_below_uniform(small_federation):
    from repro.fl import engine

    g = {}
    for name in ("fl-dp3s", "fedavg"):
        tr = _trainer(small_federation, name, rounds=16)
        # per-round GEMD for ALL rounds via the engine's stacked scan outputs
        _, outs = engine.run_scanned(tr.round_fn(), tr.server_state(), 16)
        g[name] = float(np.mean(np.asarray(outs["gemd"])))
    assert g["fl-dp3s"] < g["fedavg"], g


def test_profiles_are_init_invariant_in_kernel_space(small_federation):
    """Fig. 4/5 claim: profiles differ per init scheme, but the *kernel* is
    nearly invariant."""
    from repro.core import kernel_from_profiles, profile_all_clients

    cxs, _ = small_federation
    kernels = []
    for scheme in ("kaiming_uniform", "xavier_normal"):
        params = cnn.init_cnn(jax.random.key(3), scheme=scheme)
        f = profile_all_clients(
            jax.jit(cnn.apply_with_features), params, list(jnp.asarray(cxs))
        )
        kernels.append(np.asarray(kernel_from_profiles(f)))
    a, b = kernels
    corr = np.corrcoef(a.ravel(), b.ravel())[0, 1]
    assert corr > 0.8, corr

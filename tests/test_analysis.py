"""Tests for the HLO collective parser and roofline term computation."""

import numpy as np

from repro.analysis import hlo
from repro.analysis.roofline import active_param_count, analyse, model_flops
from repro.configs import get_arch

HLO_SNIPPET = """
  %all-gather = f32[4,8]{0,1} all-gather(%bitcast), channel_id=1, replica_groups=[4,2]<=[2,4]T(1,0), dimensions={1}
  %all-reduce = bf16[16,128]{1,0} all-reduce(%dot), channel_id=2, replica_groups=[2,4]<=[8], to_apply=%add
  %rs = f32[2,8]{1,0} reduce-scatter(%x), channel_id=3, replica_groups={{0,1,2,3}}, dimensions={0}
  %cp = f32[64]{0} collective-permute(%y), source_target_pairs={{0,1}}
  %ag-done = f32[4,8]{0,1} all-gather-done(%ag-start)
  %dot.1 = f32[128,128]{1,0} dot(%a, %b)
"""


def test_collective_bytes_parser():
    out = hlo.collective_bytes(HLO_SNIPPET)
    assert out["all-gather"] == 4 * 8 * 4  # result bytes
    assert out["all-reduce"] == 2 * 16 * 128 * 2  # 2x bf16 bytes
    assert out["reduce-scatter"] == 2 * 8 * 4 * 4  # result x group(4)
    assert out["collective-permute"] == 64 * 4
    # -done ops must not double count: only 4 collectives + totals
    assert out["total"] == sum(v for k, v in out.items() if k != "total")
    assert len([k for k in out if k != "total"]) == 4


def test_op_histogram():
    h = hlo.op_histogram(HLO_SNIPPET)
    assert h.get("dot") == 1
    assert h.get("all-gather") == 1


def test_active_params_moe_smaller_than_total():
    cfg = get_arch("mixtral-8x7b").model
    act = active_param_count(cfg)
    tot = active_param_count(cfg, total=True)
    assert act < tot
    # mixtral: ~13B active vs ~47B total (non-embedding)
    assert 0.2 < act / tot < 0.4


def test_llama4_active_params_about_17b():
    cfg = get_arch("llama4-maverick-400b-a17b").model
    act = active_param_count(cfg)
    tot = active_param_count(cfg, total=True)
    assert 350e9 < tot < 450e9, tot  # ~400B total
    assert 10e9 < act < 25e9, act  # ~17B active


def test_model_flops_monotonic_in_shape():
    f_train = model_flops("granite-3-2b", "train_4k", "client_parallel", 4)
    f_prefill = model_flops("granite-3-2b", "prefill_32k", "serve")
    f_decode = model_flops("granite-3-2b", "decode_32k", "serve")
    assert f_train > f_prefill > f_decode > 0


def test_analyse_terms_and_dominant():
    rec = dict(
        ok=True, mesh="16x16", arch="granite-3-2b", shape="decode_32k",
        fl_mode="serve",
        cost={"flops": 1e9, "bytes accessed": 5e9},
        collectives={"all-reduce": 1e6, "total": 1e6},
        memory={},
    )
    rows = analyse([rec])
    assert len(rows) == 1
    r = rows[0]
    np.testing.assert_allclose(r["t_compute"], 1e9 / 197e12)
    np.testing.assert_allclose(r["t_memory"], 5e9 / 819e9)
    np.testing.assert_allclose(r["t_collective"], 1e6 / 50e9)
    assert r["dominant"] == "memory"
    assert r["useful_ratio"] > 0


def test_analyse_skips_failed_and_wrong_mesh():
    recs = [
        dict(ok=False, mesh="16x16", arch="granite-3-2b", shape="train_4k"),
        dict(ok=True, mesh="2x16x16", arch="granite-3-2b", shape="train_4k",
             fl_mode="client_parallel", cost={}, collectives={}, memory={}),
    ]
    assert analyse(recs) == []

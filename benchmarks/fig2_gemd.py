"""Fig. 2: GEMD (eq. 15) per selection method across ξ.

Paper claim: FL-DP³S achieves the lowest GEMD, and lower GEMD tracks faster
convergence.  Reads the same cached runs as fig1.
"""

from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.configs.paper_cnn import METHODS, XIS


def run(quiet=False):
    exp = common.scale()
    rows = []
    for ds in common.DATASETS:
        for xi in XIS:
            means = {}
            for m in METHODS:
                g = [
                    float(np.mean(common.run_case(ds, xi, m, s, exp)["gemd"]))
                    for s in range(exp.seeds)
                ]
                means[m] = float(np.mean(g))
            rows.append(dict(dataset=ds, xi=str(xi), gemd=means))
            if not quiet:
                print(f"  fig2 {ds} xi={xi} " + " ".join(f"{m}={v:.3f}" for m, v in means.items()))
    return rows


def main():
    rows = run()
    for ds in common.DATASETS:
        sub = [r for r in rows if r["dataset"] == ds]
        dp3s_lowest = all(
            r["gemd"]["fl-dp3s"] <= min(v for k, v in r["gemd"].items() if k != "fl-dp3s") + 1e-9
            for r in sub
        )
        derived = f"dp3s_lowest_gemd={dp3s_lowest} xi1=" + "/".join(
            f"{m}:{r['gemd'][m]:.3f}" for r in sub if r["xi"] == "1.0" for m in sorted(r["gemd"])
        )
        print(common.csv_line(f"fig2_gemd[{ds}]", 0.0, derived))
    return rows


if __name__ == "__main__":
    main()

"""Bounded-staleness vs synchronous barrier: simulated wall-clock-to-target.

Prices the scanned federation engine's two aggregation modes (DESIGN.md §§8-9)
under the system-heterogeneity scenarios of ``repro.fl.scenarios``: for each
latency model the SAME federation (same clients, same selection key chain —
cohorts are bit-identical by construction, latency-only scenarios never touch
the selection stream) runs once through the synchronous sharded round (round
cost = max latency over the cohort, the psum barrier) and once through
bounded-staleness aggregation (round cost = the scenario deadline for
stragglers, their contributions landing stale and decay-weighted).  Both
runs' per-round ``sim_time`` metrics come straight out of the compiled scan.

The headline metric is **simulated wall clock to equal final loss**: the
target is the loss floor both arms reach, and the speedup is the ratio of
cumulative simulated time to first hit it.  Under the heavy-tail scenario
(Pareto α=1.1 stragglers) the synchronous barrier pays the max of the
cohort's draws every round while the stale round is cut off at the deadline,
so the win is structural — gated at ≥1.5x (full mode only; the metric is
*simulated*, so unlike the shard-scaling gates it does not depend on host
core count).  The child also asserts the staleness-parity contract:
``staleness_bound=0`` picks bit-identical cohorts and fp32-close params vs
the synchronous engine.

Runs in a subprocess with ``XLA_FLAGS=--xla_force_host_platform_device_count``
(the staleness engine needs a client mesh; the flag must precede jax init).
Writes ``BENCH_async.json`` (repo root); ``--smoke`` runs tiny shapes with no
gate and writes ``BENCH_async_smoke.json`` (CI harness + check_regression
input):

    PYTHONPATH=src python -m benchmarks.async_bench [--smoke]
"""

from __future__ import annotations

import argparse
import json
import os
import re
import subprocess
import sys
import time

OUT_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_async.json")
SMOKE_OUT_PATH = os.path.join(
    os.path.dirname(__file__), "..", "BENCH_async_smoke.json"
)

# one federation, three latency regimes; rounds_stale > rounds_sync because
# stale gradients buy cheap rounds at a small per-round convergence cost —
# time-to-target is the honest comparison, not rounds-to-target
FULL = dict(clients=16, n_c=32, feat=32, hidden=64, steps=4, k=8, devices=8,
            rounds_sync=48, rounds_stale=72, bound=4,
            decay="polynomial", alpha=0.5, lr=0.05)
SMOKE = dict(clients=8, n_c=8, feat=8, hidden=16, steps=2, k=4, devices=4,
             rounds_sync=8, rounds_stale=12, bound=2,
             decay="polynomial", alpha=0.5, lr=0.05)
BENCH_SCENARIOS = ("uniform", "lognormal", "heavy_tail")
ASYNC_TARGET = 1.5  # x, heavy_tail time-to-target, full mode only


# ----------------------------------------------------------------- child


def _time_to_target(losses, sim_times, target):
    """Cumulative simulated time at the first round whose running-best loss
    reaches ``target`` (the loss signal is the cohort mean — monotonise with
    a running min before thresholding)."""
    import numpy as np

    best = np.minimum.accumulate(np.asarray(losses, np.float64))
    cum = np.cumsum(np.asarray(sim_times, np.float64))
    hit = np.nonzero(best <= target)[0]
    return float(cum[hit[0]]) if hit.size else None


def _child(w: dict) -> dict:
    import dataclasses

    import jax
    import numpy as np

    from benchmarks.shard_bench import _mlp_workload, _parity
    from repro.core import selection as selection_lib
    from repro.fl import engine
    from repro.launch.mesh import make_client_mesh

    assert jax.device_count() == w["devices"], (jax.device_count(), w)
    loss_fn, xs, ys, params, ncls = _mlp_workload(w)
    mesh = make_client_mesh(w["devices"])
    strat = selection_lib.UniformSelection()
    base = dict(
        num_clients=w["clients"], clients_per_round=w["k"],
        local_epochs=w["steps"], lr=w["lr"], rounds=w["rounds_sync"],
        eval_every=10 * w["rounds_stale"], num_classes=ncls, seed=0,
    )

    def run(cfg, rounds):
        state = engine.init_server_state(
            cfg, params, loss_fn, None, xs, ys, strategy=strat,
            profiles=xs.mean(axis=1), mesh=mesh,
        )
        rf = engine.make_round_fn(cfg, loss_fn, (strat,), mesh=mesh)
        st, outs = engine.run_scanned(rf, state, rounds, mesh=mesh)
        return st, jax.tree_util.tree_map(np.asarray, outs)

    by_scenario = {}
    parity = None
    for scen in BENCH_SCENARIOS:
        cfg_sync = engine.FLConfig(**dict(base, scenario=scen))
        st_sync, out_sync = run(cfg_sync, w["rounds_sync"])
        cfg_stale = engine.FLConfig(**dict(
            base, scenario=scen, staleness_bound=w["bound"],
            staleness_decay=w["decay"], staleness_alpha=w["alpha"],
        ))
        st_stale, out_stale = run(cfg_stale, w["rounds_stale"])

        if scen == "heavy_tail":
            # the s=0 parity contract: bit-identical cohorts, fp32 params
            cfg_s0 = engine.FLConfig(**dict(
                base, scenario=scen, staleness_bound=0,
                staleness_decay=w["decay"], staleness_alpha=w["alpha"],
            ))
            st_s0, out_s0 = run(cfg_s0, w["rounds_sync"])
            parity = _parity((st_sync, out_sync), (st_s0, out_s0))

        # equal-final-loss target: the loss floor BOTH arms reach
        floor_sync = float(np.min(out_sync["loss"]))
        floor_stale = float(np.min(out_stale["loss"]))
        target = max(floor_sync, floor_stale)
        t_sync = _time_to_target(out_sync["loss"], out_sync["sim_time"], target)
        t_stale = _time_to_target(
            out_stale["loss"], out_stale["sim_time"], target
        )
        by_scenario[scen] = dict(
            target_loss=target,
            final_loss_sync=floor_sync,
            final_loss_stale=floor_stale,
            time_to_target_sync=t_sync,
            time_to_target_stale=t_stale,
            speedup=(t_sync / t_stale) if t_sync and t_stale else None,
            mean_round_time_sync=float(np.mean(out_sync["sim_time"])),
            mean_round_time_stale=float(np.mean(out_stale["sim_time"])),
            mean_staleness=float(np.mean(out_stale["staleness"])),
        )
    return dict(by_scenario=by_scenario, parity=parity)


# ---------------------------------------------------------------- parent


def _spawn(w: dict) -> dict:
    env = dict(os.environ)
    flags = re.sub(
        r"--xla_force_host_platform_device_count=\d+", "",
        env.get("XLA_FLAGS", ""),
    ).strip()
    env["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={w['devices']} " + flags
    ).strip()
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.async_bench", "--child",
         json.dumps(w)],
        env=env, capture_output=True, text=True, timeout=1200,
    )
    if proc.returncode != 0:
        raise RuntimeError(
            f"async_bench child failed:\n{proc.stdout}\n{proc.stderr}"
        )
    return json.loads(proc.stdout.splitlines()[-1])


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes, no perf gate (CI harness check)")
    ap.add_argument("--child", default=None, help=argparse.SUPPRESS)
    args = ap.parse_args(argv)

    if args.child is not None:
        print(json.dumps(_child(json.loads(args.child))))
        return None

    from benchmarks import common

    t0 = time.time()
    w = SMOKE if args.smoke else FULL
    res = _spawn(w)
    for scen, row in res["by_scenario"].items():
        sp = row["speedup"]
        head = (
            f"sync={row['time_to_target_sync']:8.2f} "
            f"stale={row['time_to_target_stale']:8.2f} speedup={sp:.2f}x"
            if sp is not None else "target unreached"
        )
        print(f"  async_bench {scen:11s} {head}  "
              f"mean_round sync={row['mean_round_time_sync']:.2f} "
              f"stale={row['mean_round_time_stale']:.2f} "
              f"mean_staleness={row['mean_staleness']:.2f}")

    heavy = res["by_scenario"]["heavy_tail"]
    parity = res["parity"] or {}
    gate_enforced = not args.smoke
    ok = bool(parity.get("ok", False))
    if gate_enforced:
        ok = ok and (heavy["speedup"] or 0.0) >= ASYNC_TARGET

    payload = dict(
        bench="async_sim_wall_clock_to_target",
        smoke=args.smoke,
        workload=dict(w, model="mlp(2-layer)", selection="uniform"),
        host_cores=os.cpu_count() or 1,
        target_speedup=ASYNC_TARGET,
        gate_enforced=gate_enforced,
        gate_note=(
            f"heavy_tail simulated time-to-equal-final-loss must be >= "
            f"{ASYNC_TARGET}x the synchronous barrier; simulated metrics "
            "are core-count independent, so the gate arms on every full "
            "run; s=0 parity always enforced"
        ),
        parity=parity,
        by_scenario=res["by_scenario"],
        ok=ok,
        total_s=round(time.time() - t0, 2),
    )
    out_path = SMOKE_OUT_PATH if args.smoke else OUT_PATH
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=2)
    hs = heavy["speedup"]
    hs_str = f"{hs:.2f}x" if hs is not None else "n/a"
    print(common.csv_line(
        "async_stale_vs_sync",
        0.0,
        f"heavy_tail_speedup={hs_str} parity_ok={parity.get('ok')} "
        f"gate_enforced={gate_enforced} ok={ok}",
    ))
    print(f"ok={ok}  wrote {os.path.abspath(out_path)}")
    if not ok:
        raise SystemExit(1)
    return payload


if __name__ == "__main__":
    main()

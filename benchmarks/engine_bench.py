"""Engine benchmark: scan-compiled federation vs the legacy host loop.

Runs the SAME (strategy, seed, rounds) paper-CNN workload twice —

* ``FLTrainer.run_legacy`` — the host Python loop (pre-engine structure,
  current selection math): one jitted round step per round, selection /
  batch building / loss refresh / GEMD dispatched from host every round;
* ``engine.run_scanned`` — all rounds compiled into a single ``lax.scan``
  with zero per-round host round-trips —

verifies the two produce matching final accuracy / GEMD (the scanned engine
is bit-compatible with the loop), and records the wall-clock speedup in
``BENCH_engine.json`` (repo root).

The headline workload is *selection-bound*: the paper's 2-conv/2-FC CNN at a
width where the per-round device compute is tiny, so the measurement isolates
the federation-loop overhead the engine removes — the regime every accelerator
run sits in (device rounds are µs; the Python loop is the bottleneck).  A
second, compute-bound context row at the regular bench scale is reported for
honesty: there the round compute dominates on CPU and both paths converge.

Also exercises ``engine.run_many``: S seeds × K strategies stacked into ONE
compiled program (the Fig.-1 sweep workload), cross-checked against per-case
scanned runs.  Note ``run_many`` vmaps the client convolutions, which XLA-CPU
lowers to grouped convolutions (~10x slow) — its wall-clock win is an
accelerator story; on CPU we verify correctness only.

    PYTHONPATH=src python -m benchmarks.engine_bench
"""

from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Dict, Tuple

import jax
import numpy as np

from benchmarks import common
from repro.core import make_strategy
from repro.data import make_image_dataset, skewness_partition
from repro.fl import FLConfig, FLTrainer, engine
from repro.models import cnn

OUT_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_engine.json")

# headline: selection-bound paper CNN (same topology, minimal width) — the
# per-round compute is ~1 ms so the loop overhead dominates, as on real
# accelerators.  Tuned for the CPU container; ≥20 rounds per the claim.
HEADLINE = dict(
    num_clients=20, samples_per_client=2, clients_per_round=2, rounds=60,
    hw=10, channels=(1, 2), fc1_dim=8,
)
# context: the regular (compute-bound on CPU) bench scale, fewer rounds
CONTEXT = dict(
    num_clients=16, samples_per_client=20, clients_per_round=4, rounds=20,
    hw=14, channels=(4, 8), fc1_dim=32,
)
STRATEGIES = ("fedavg", "fl-dp3s")
REPEATS = 6


def _federation(w) -> Tuple[np.ndarray, np.ndarray]:
    ds = make_image_dataset(
        n=w["num_clients"] * w["samples_per_client"], seed=11, h=w["hw"], w=w["hw"]
    )
    shards = skewness_partition(
        ds.ys, w["num_clients"], 1.0, 10,
        samples_per_client=w["samples_per_client"], seed=0,
    )
    return (
        np.stack([ds.xs[s] for s in shards]),
        np.stack([ds.ys[s] for s in shards]),
    )


def _trainer(w, cxs, cys, name: str, seed: int = 0) -> FLTrainer:
    params = cnn.init_cnn(
        jax.random.key(seed), in_hw=(w["hw"], w["hw"]),
        channels=w["channels"], fc1_dim=w["fc1_dim"],
    )
    cfg = FLConfig(
        num_clients=w["num_clients"], clients_per_round=w["clients_per_round"],
        rounds=w["rounds"], local_epochs=1, lr=0.08,
        eval_every=w["rounds"], seed=seed,
    )
    return FLTrainer(
        cfg, params, cnn.cnn_loss, cnn.apply_with_features, cxs, cys,
        make_strategy(name), accuracy_fn=cnn.accuracy,
    )


def _bench_case(w, cxs, cys, name: str) -> Dict:
    rounds = w["rounds"]
    # -- scanned: one compiled program, timed post-compile ------------------
    tr = _trainer(w, cxs, cys, name)
    round_fn = tr.round_fn()
    state0 = tr.server_state()
    jax.block_until_ready(engine.run_scanned(round_fn, state0, rounds))
    scanned_s = []
    for _ in range(REPEATS):
        t0 = time.perf_counter()
        _, outs = engine.run_scanned(round_fn, state0, rounds)
        jax.block_until_ready(outs)
        scanned_s.append(time.perf_counter() - t0)

    # -- legacy loop: same workload, warm compile, fresh trainer per rep ----
    _trainer(w, cxs, cys, name).run_legacy()
    legacy_s = []
    for _ in range(REPEATS):
        tr_l = _trainer(w, cxs, cys, name)  # construction outside the timer
        t0 = time.perf_counter()
        tr_l.run_legacy()
        legacy_s.append(time.perf_counter() - t0)

    # -- correctness: identical history from both paths ---------------------
    h_eng = _trainer(w, cxs, cys, name).run()
    h_leg = _trainer(w, cxs, cys, name).run_legacy()
    acc_match = bool(np.allclose(h_eng["acc"], h_leg["acc"], rtol=1e-5, atol=1e-6))
    gemd_match = bool(np.allclose(h_eng["gemd"], h_leg["gemd"], rtol=1e-5, atol=1e-6))

    return dict(
        strategy=name,
        rounds=rounds,
        scanned_s=min(scanned_s),
        legacy_s=min(legacy_s),
        speedup=min(legacy_s) / min(scanned_s),
        final_acc_scanned=h_eng["acc"][-1],
        final_acc_legacy=h_leg["acc"][-1],
        final_gemd_scanned=h_eng["gemd"][-1],
        final_gemd_legacy=h_leg["gemd"][-1],
        acc_match=acc_match,
        gemd_match=gemd_match,
    )


def _bench_run_many(w, cxs, cys, seeds=(0, 1)) -> Dict:
    """S seeds × K strategies in one vmapped program; verify vs per-case."""
    rounds = w["rounds"]
    strategies = tuple(make_strategy(n) for n in STRATEGIES)
    cfg = FLConfig(
        num_clients=w["num_clients"], clients_per_round=w["clients_per_round"],
        rounds=rounds, local_epochs=1, lr=0.08, eval_every=rounds, seed=0,
    )
    round_fn = engine.make_round_fn(
        cfg, cnn.cnn_loss, strategies, accuracy_fn=cnn.accuracy
    )
    states = []
    for si in range(len(strategies)):
        for seed in seeds:
            tr = _trainer(w, cxs, cys, STRATEGIES[si], seed)
            states.append(
                dataclasses.replace(
                    tr.server_state(), strategy_index=np.int32(si)
                )
            )
    stacked = engine.stack_states(states)
    t0 = time.perf_counter()
    _, outs = engine.run_many(round_fn, stacked, rounds)
    jax.block_until_ready(outs)
    wall = time.perf_counter() - t0
    per_case = engine.unstack_outputs(outs)
    max_err = 0.0
    for i, st in enumerate(states):
        _, ref = engine.run_scanned(round_fn, st, rounds)
        for k in ("gemd", "loss"):
            max_err = max(
                max_err,
                float(np.max(np.abs(per_case[i][k] - np.asarray(ref[k])))),
            )
    return dict(
        cases=len(states),
        rounds=rounds,
        wall_s=wall,
        max_abs_err_vs_sequential=max_err,
        matches_sequential=bool(max_err < 1e-4),
    )


def main():
    t_all = time.time()
    records = {"headline": [], "context": []}
    cxs, cys = _federation(HEADLINE)
    for name in STRATEGIES:
        rec = _bench_case(HEADLINE, cxs, cys, name)
        records["headline"].append(rec)
        print(
            f"  engine_bench[headline] {name:10s} scanned={rec['scanned_s']:.3f}s "
            f"legacy={rec['legacy_s']:.3f}s speedup={rec['speedup']:.2f}x "
            f"acc_match={rec['acc_match']} gemd_match={rec['gemd_match']}"
        )
    ccxs, ccys = _federation(CONTEXT)
    for name in STRATEGIES:
        rec = _bench_case(CONTEXT, ccxs, ccys, name)
        records["context"].append(rec)
        print(
            f"  engine_bench[context]  {name:10s} scanned={rec['scanned_s']:.3f}s "
            f"legacy={rec['legacy_s']:.3f}s speedup={rec['speedup']:.2f}x"
        )
    many = _bench_run_many(HEADLINE, cxs, cys)
    print(
        f"  engine_bench[run_many] {many['cases']} cases in one program: "
        f"{many['wall_s']:.2f}s matches_sequential={many['matches_sequential']}"
    )

    speedup = min(r["speedup"] for r in records["headline"])
    ok = (
        speedup >= 3.0
        and all(r["acc_match"] and r["gemd_match"] for r in records["headline"])
    )
    payload = dict(
        bench="engine_scanned_vs_legacy_loop",
        workload=dict(HEADLINE, model="paper-cnn(2conv+2fc)"),
        context_workload=dict(CONTEXT, model="paper-cnn(2conv+2fc)"),
        strategies=list(STRATEGIES),
        repeats=REPEATS,
        speedup=speedup,
        target_speedup=3.0,
        ok=bool(ok),
        headline=records["headline"],
        context=records["context"],
        run_many=many,
        total_s=round(time.time() - t_all, 2),
    )
    with open(OUT_PATH, "w") as f:
        json.dump(payload, f, indent=2)
    print(common.csv_line(
        "engine_scanned_vs_legacy",
        0.0,
        f"speedup={speedup:.2f}x target=3.0x ok={ok} "
        f"rounds={HEADLINE['rounds']} run_many_ok={many['matches_sequential']}",
    ))
    return payload


if __name__ == "__main__":
    main()

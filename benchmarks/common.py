"""Shared FL experiment runner for the paper-figure benchmarks.

Runs the Algorithm-1 protocol over a (dataset × ξ × method × seed) grid at
the CPU-budget scale (``paper_cnn.bench_scale``) and caches every history in
``results/fl_grid.json`` so benchmark modules (fig1/fig2/table1 all read the
same runs) and re-invocations never recompute.

Scale via env: REPRO_BENCH_SCALE = tiny | bench (default) | paper.
"""

from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Dict, List, Optional, Sequence

import jax
import numpy as np

from repro.configs import paper_cnn
from repro.core import make_strategy
from repro.data import make_image_dataset, skewness_partition
from repro.fl import FLConfig, FLTrainer, engine
from repro.models import cnn

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results")
GRID_PATH = os.path.join(RESULTS, "fl_grid.json")

# two synthetic datasets stand in for MNIST / Fashion-MNIST (data gate —
# DESIGN.md §4): same shape/scale, different generative seeds & noise.
DATASETS = {"synth-mnist": dict(seed=11, noise=0.5), "synth-fashion": dict(seed=23, noise=0.8)}


def scale() -> paper_cnn.PaperExperiment:
    s = os.environ.get("REPRO_BENCH_SCALE", "bench")
    if s == "paper":
        return paper_cnn.paper_scale()
    if s == "tiny":
        return paper_cnn.PaperExperiment(
            num_clients=16, clients_per_round=4, samples_per_client=60,
            local_epochs=1, lr=0.08, rounds=10, eval_every=2, seeds=1,
            cnn_channels=(8, 16), fc1_dim=64,
        )
    return paper_cnn.bench_scale()


def _load_grid() -> Dict:
    if os.path.exists(GRID_PATH):
        with open(GRID_PATH) as f:
            return json.load(f)
    return {}


def _save_grid(grid: Dict) -> None:
    os.makedirs(RESULTS, exist_ok=True)
    tmp = GRID_PATH + ".tmp"
    with open(tmp, "w") as f:
        json.dump(grid, f)
    os.replace(tmp, GRID_PATH)


def build_trainer(
    exp: paper_cnn.PaperExperiment,
    dataset: str,
    xi,
    method: str,
    seed: int,
    init_scheme: str = "kaiming_uniform",
    profile_kind: str = "fc1",
) -> FLTrainer:
    dkw = DATASETS[dataset]
    ds = make_image_dataset(
        n=exp.num_clients * exp.samples_per_client, seed=dkw["seed"], noise=dkw["noise"]
    )
    shards = skewness_partition(
        ds.ys, exp.num_clients, xi, ds.num_classes,
        samples_per_client=exp.samples_per_client, seed=seed,
    )
    cxs = np.stack([ds.xs[s] for s in shards])
    cys = np.stack([ds.ys[s] for s in shards])
    params = cnn.init_cnn(
        jax.random.key(seed),
        channels=exp.cnn_channels,
        fc1_dim=exp.fc1_dim,
        scheme=init_scheme,
    )
    cfg = paper_cnn.fl_config(exp, seed=seed)
    trainer = FLTrainer(
        cfg, params, cnn.cnn_loss, cnn.apply_with_features, cxs, cys,
        make_strategy(method), accuracy_fn=cnn.accuracy,
    )
    if profile_kind != "fc1":
        _swap_profiles(trainer, profile_kind)
    return trainer


def _swap_profiles(trainer: FLTrainer, kind: str) -> None:
    """Fig.-3 ablation: rebuild the DPP kernel from gradient-based profiles."""
    from repro.core import kernel_from_profiles, profiles as profiles_lib
    import jax.numpy as jnp

    rows = []
    for c in range(trainer.cfg.num_clients):
        if kind == "gradient":
            r = profiles_lib.gradient_profile(
                trainer.loss_fn, trainer.params, trainer.client_xs[c], trainer.client_ys[c]
            )
        elif kind == "repr_gradient":
            r = profiles_lib.representative_gradient_profile(
                trainer.loss_fn, trainer.params, trainer.client_xs[c], trainer.client_ys[c]
            )
        else:
            raise ValueError(kind)
        rows.append(r)
    f = jnp.stack(rows)
    trainer.round_state.profiles = f
    trainer.round_state.kernel = kernel_from_profiles(f)


def _case_key(dataset, xi, method, seed, exp, init_scheme="kaiming_uniform",
              profile_kind="fc1") -> str:
    return (
        f"{dataset}|xi={xi}|{method}|seed={seed}|init={init_scheme}|prof={profile_kind}|"
        f"C={exp.num_clients}x{exp.samples_per_client}|T={exp.rounds}"
    )


def prefill_grid(
    datasets: Sequence[str], xis: Sequence, methods: Sequence[str], exp=None
) -> int:
    """Fill the fl_grid cache for a (dataset × ξ × method × seed) sweep
    through the scanned federation engine.

    All methods share ONE multi-strategy ``round_fn`` (``lax.switch`` on
    ``ServerState.strategy_index``), so the entire grid executes through a
    single compiled scan program — the per-case data/params/kernel ride in
    the state.  Returns the number of newly computed cases.
    """
    exp = exp or scale()
    grid = _load_grid()
    missing = [
        (ds, xi, m, s)
        for ds in datasets
        for xi in xis
        for m in methods
        for s in range(exp.seeds)
        if _case_key(ds, xi, m, s, exp) not in grid
    ]
    if not missing:
        return 0
    methods = tuple(methods)
    strategies = tuple(make_strategy(m) for m in methods)
    cfg = paper_cnn.fl_config(exp, seed=0)
    round_fn = engine.make_round_fn(
        cfg, cnn.cnn_loss, strategies, accuracy_fn=cnn.accuracy
    )
    for ds, xi, m, s in missing:
        t0 = time.time()
        trainer = build_trainer(exp, ds, xi, m, s)
        state = dataclasses.replace(
            trainer.server_state(),
            strategy_index=np.int32(methods.index(m)),
        )
        state_f, outs = engine.run_scanned(round_fn, state, exp.rounds)
        final_acc = None
        if exp.rounds % exp.eval_every != 0:
            xs = trainer.client_xs.reshape((-1,) + trainer.client_xs.shape[2:])
            final_acc = float(cnn.accuracy(state_f.params, xs, trainer.client_ys.reshape(-1)))
        hist = engine.history_from_outputs(
            jax.tree_util.tree_map(np.asarray, outs), exp.eval_every, final_acc=final_acc
        )
        hist["wall_s"] = time.time() - t0
        grid = _load_grid()
        grid[_case_key(ds, xi, m, s, exp)] = hist
        _save_grid(grid)
    return len(missing)


def run_case(
    dataset: str, xi, method: str, seed: int, exp=None,
    init_scheme: str = "kaiming_uniform", profile_kind: str = "fc1",
    force: bool = False,
) -> Dict[str, List]:
    exp = exp or scale()
    key = _case_key(dataset, xi, method, seed, exp, init_scheme, profile_kind)
    grid = _load_grid()
    if key in grid and not force:
        return grid[key]
    t0 = time.time()
    trainer = build_trainer(exp, dataset, xi, method, seed, init_scheme, profile_kind)
    hist = trainer.run()
    hist["wall_s"] = time.time() - t0
    grid = _load_grid()  # re-read: other processes may have written
    grid[key] = hist
    _save_grid(grid)
    return hist


def rounds_to_accuracy(hist: Dict[str, List], target: float) -> Optional[int]:
    for r, a in zip(hist["round"], hist["acc"]):
        if a >= target:
            return r
    return None


def csv_line(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.1f},{derived}"

"""Fig. 3: profiling-method ablation on synth-MNIST ξ=1 — FC-1 profiles
(FL-DP³S) vs gradient profiles vs representative-gradient profiles.

Paper claim: FC-1 profiling converges faster / higher than gradient-based
profiling inside the same k-DPP selector.
"""

from __future__ import annotations

import numpy as np

from benchmarks import common

KINDS = ("fc1", "gradient", "repr_gradient")


def run(quiet=False):
    exp = common.scale()
    rows = []
    for kind in KINDS:
        accs = []
        for seed in range(exp.seeds):
            h = common.run_case(
                "synth-mnist", 1.0, "fl-dp3s", seed, exp, profile_kind=kind
            )
            accs.append(h["acc"])
        mean = np.mean(accs, axis=0)
        rows.append(dict(kind=kind, acc=mean.tolist(), final=float(mean.max())))
        if not quiet:
            print(f"  fig3 profile={kind:14s} best={mean.max():.3f}")
    return rows


def main():
    rows = run()
    finals = {r["kind"]: r["final"] for r in rows}
    best = max(finals, key=finals.get)
    derived = f"best={best} " + "/".join(f"{k}:{v:.3f}" for k, v in finals.items())
    print(common.csv_line("fig3_profiling_ablation", 0.0, derived))
    return rows


if __name__ == "__main__":
    main()

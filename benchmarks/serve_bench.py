"""Serving-engine throughput: scan-compiled decode + continuous batching.

Prices the DESIGN.md §13 serving path on reduced configs across the three
cache families (smollm = dense GQA KV, rwkv6 = O(1) recurrent state,
mixtral = SWA ring buffer + MoE):

  * per-arch — prefill tok/s, then decode tok/s for the legacy host loop
    (one jit dispatch per token, the old ``launch/serve.py``) vs the
    engine's ``lax.scan``-compiled decode of the same generation.  Both
    paths produce bit-identical greedy tokens (asserted in-bench).
  * continuous batching (smollm) — mixed-length traffic (seeded heavy-tail
    budgets) through :class:`repro.serve.ServeEngine` in continuous mode vs
    the drain-and-refill contrast arm.  Both arms share one engine instance,
    i.e. the SAME compiled admit/decode programs — only the scheduling
    differs — and the engine's jit caches are asserted unchanged after
    warmup (zero recompilation under mixed-length traffic).

Headline gates (full mode only; ratios are within-run so they transfer
across hosts, but check_regression still arms same-core-count only):

  * scan decode >= 2x legacy host-loop decode tok/s at batch >= 8 on the
    micro smollm row — ``reduced(**MICRO)``, the same reduced family with
    smaller gemms.  What the scan removes is *per-token host overhead*
    (dispatch, eager argmax chain, cache copy-out), which on an accelerator
    dwarfs per-step compute at any size; on this CPU-only host the standard
    reduced size is compute-bound (~60% of a step is gemm time), so the
    gate row is sized so the overhead the scan eliminates is a measurable
    fraction.  The standard-reduced speedup is still measured and reported
    on every arch row (informational + regression-tracked);
  * continuous >= 1.5x drain-and-refill aggregate tok/s on mixed lengths;
  * zero recompiles after warmup (asserted in smoke too — it's free).

Writes ``BENCH_serve.json`` (repo root); ``--smoke`` runs tiny shapes with
no throughput gate and writes ``BENCH_serve_smoke.json`` (CI harness +
check_regression input):

    PYTHONPATH=src python -m benchmarks.serve_bench [--smoke]
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

OUT_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_serve.json")
SMOKE_OUT_PATH = os.path.join(
    os.path.dirname(__file__), "..", "BENCH_serve_smoke.json"
)

# batch >= 8 so per-token host dispatch (what the scan removes) is priced
# against real per-step compute, per the gate's contract
FULL = dict(batch=8, prompt=16, gen=32, requests=24, chunk=4, reps=3,
            archs=("smollm-360m", "rwkv6-7b", "mixtral-8x7b"))
SMOKE = dict(batch=4, prompt=8, gen=8, requests=6, chunk=2, reps=2,
             archs=("smollm-360m",))
SCAN_SPEEDUP_GATE = 2.0
CONTINUOUS_SPEEDUP_GATE = 1.5
# the speedup-gate model: reduced smollm with smaller gemms, so per-token
# host overhead (what the scan removes) isn't drowned by single-core gemm
# time — see the module docstring
MICRO = dict(d_model=128, d_ff=256, num_heads=2, num_kv_heads=1, head_dim=32)
# mixed-length traffic: 80% short / 20% long budgets.  A drain wave runs at
# the wave max (~gen) while mean demand is ~0.8*short + 0.2*gen, so
# continuous refill has ~2.5x of slot-steps to win back
SHORT_FRAC = 0.8


def _best(fn, reps: int) -> float:
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def _bench_arch(arch: str, w: dict, overrides: dict | None = None) -> dict:
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import get_arch
    from repro.models import transformer as T
    from repro.serve import (ServeConfig, init_decode_state, make_decode_fn,
                             run_scan)

    cfg = get_arch(arch).model.reduced(
        param_dtype="float32", dtype="float32", remat=False,
        **(overrides or {}),
    )
    params = T.init_params(jax.random.key(0), cfg)
    b, p, g = w["batch"], w["prompt"], w["gen"]
    prompts = jax.random.randint(jax.random.key(1), (b, p), 0, cfg.vocab_size,
                                 jnp.int32)
    positions = jnp.broadcast_to(jnp.arange(p)[None], (b, p))
    if cfg.pos_style == "mrope":
        positions = jnp.broadcast_to(positions[None], (3, b, p))

    @jax.jit
    def prefill(prm, toks, caches):
        hidden, caches, _ = T.forward(cfg, prm, toks, positions, caches)
        return T.logits_from_hidden(cfg, prm, hidden[:, -1:]), caches

    decode = jax.jit(lambda prm, tok, c: T.decode_step(cfg, prm, tok, c))
    scfg = ServeConfig(batch=b, cache_len=p + g, max_new=g)
    decode_fn = make_decode_fn(cfg, scfg)
    scan = jax.jit(lambda prm, s: run_scan(decode_fn, prm, s, g - 1))

    # ---- prefill (shared by both paths; legacy scalar-pos cache) ----
    caches0 = T.init_caches(cfg, b, p + g)
    logits0, caches1 = prefill(params, prompts, caches0)  # warmup/compile
    t_prefill = _best(
        lambda: jax.block_until_ready(prefill(params, prompts, caches0)),
        w["reps"],
    )
    tok0 = jnp.argmax(logits0[:, 0], axis=-1).astype(jnp.int32)

    # ---- legacy host loop: one dispatch per token ----
    def legacy():
        tok, caches = tok0, caches1
        out = [tok]
        for _ in range(g - 1):
            lg, caches = decode(params, tok[:, None], caches)
            tok = jnp.argmax(lg[:, 0], axis=-1).astype(jnp.int32)
            out.append(tok)
        jax.block_until_ready(tok)
        return np.stack([np.asarray(t) for t in out], 1)

    legacy_out = legacy()  # warmup/compile
    t_legacy = _best(legacy, w["reps"])

    # ---- scan-compiled decode of the same generation ----
    pcaches0 = T.init_caches(cfg, b, p + g, per_slot=True)
    _, pcaches = prefill(params, prompts, pcaches0)
    state0 = dataclasses.replace(
        init_decode_state(cfg, scfg),
        caches=pcaches, last_tok=tok0[:, None],
        out_tokens=jnp.zeros((b, g), jnp.int32).at[:, 0].set(tok0),
        n_gen=jnp.ones((b,), jnp.int32),
        gen_target=jnp.full((b,), g, jnp.int32),
        active=jnp.ones((b,), bool),
        seq_ids=jnp.arange(b, dtype=jnp.int32),
    )
    scan_state = scan(params, state0)  # warmup/compile
    t_scan = _best(
        lambda: jax.block_until_ready(scan(params, state0)), w["reps"]
    )
    scan_out = np.asarray(scan_state.out_tokens)
    parity = bool((scan_out == legacy_out).all())

    dec_toks = b * (g - 1)
    return dict(
        prefill_toks_per_sec=b * p / t_prefill,
        legacy_decode_toks_per_sec=dec_toks / t_legacy,
        scan_decode_toks_per_sec=dec_toks / t_scan,
        scan_speedup=t_legacy / t_scan,
        parity_ok=parity,
    )


def _bench_continuous(w: dict) -> dict:
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.launch.serve import build_model
    from repro.serve import ServeConfig, ServeEngine

    cfg, params = build_model(w["archs"][0], seed=0)
    b, p, g, n = w["batch"], w["prompt"], w["gen"], w["requests"]
    scfg = ServeConfig(batch=b, cache_len=p + g, max_new=g,
                       decode_chunk=w["chunk"])
    eng = ServeEngine(cfg, scfg, params, prompt_len=p)
    prompts = np.asarray(jax.random.randint(
        jax.random.key(2), (n, p), 0, cfg.vocab_size, jnp.int32))
    rng = np.random.default_rng(0)
    short = max(2, g // 8)
    budgets = np.where(rng.random(n) < SHORT_FRAC, short, g).astype(int)

    def traffic(drain):
        eng.reset(jax.random.key(3))
        for i in range(n):
            eng.submit(prompts[i], int(budgets[i]))
        finished = eng.run(drain=drain)
        assert sorted(f.seq_id for f in finished) == list(range(n))
        assert sum(len(f.tokens) for f in finished) == int(budgets.sum())

    traffic(drain=False)  # warmup: compiles admit + decode chunk
    compiles_warm = eng.compile_counts()
    t_cont = _best(lambda: traffic(drain=False), w["reps"])
    t_drain = _best(lambda: traffic(drain=True), w["reps"])
    compiles_end = eng.compile_counts()

    # a count of -1 means the jit cache is unreadable (private jax API
    # changed); that must FAIL the gate, not vacuously pass as -1 == -1
    counts_ok = all(
        v >= 0 for c in (compiles_warm, compiles_end) for v in c.values()
    )

    toks = int(budgets.sum())
    return dict(
        requests=n,
        budgets=dict(short=int(short), long=int(g),
                     mean=float(budgets.mean())),
        continuous_toks_per_sec=toks / t_cont,
        drain_toks_per_sec=toks / t_drain,
        continuous_speedup=t_drain / t_cont,
        compiles_after_warmup=compiles_warm,
        compiles_after_timed=compiles_end,
        zero_recompile=counts_ok and compiles_warm == compiles_end,
    )


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes, no throughput gate (CI harness check)")
    args = ap.parse_args(argv)

    from benchmarks import common

    t0 = time.time()
    w = SMOKE if args.smoke else FULL

    by_arch = {}
    rows = [(a, None) for a in w["archs"]] + [(w["archs"][0] + ":micro", MICRO)]
    for name, ov in rows:
        row = _bench_arch(name.split(":")[0], w, overrides=ov)
        by_arch[name] = row
        print(f"  serve_bench {name:18s} prefill={row['prefill_toks_per_sec']:8.0f} tok/s "
              f"decode legacy={row['legacy_decode_toks_per_sec']:6.0f} "
              f"scan={row['scan_decode_toks_per_sec']:6.0f} tok/s "
              f"({row['scan_speedup']:.2f}x) parity={row['parity_ok']}")

    cont = _bench_continuous(w)
    print(f"  serve_bench continuous={cont['continuous_toks_per_sec']:6.0f} "
          f"drain={cont['drain_toks_per_sec']:6.0f} tok/s aggregate "
          f"({cont['continuous_speedup']:.2f}x) "
          f"zero_recompile={cont['zero_recompile']} "
          f"compiles={cont['compiles_after_timed']}")

    gate_enforced = not args.smoke
    gate_row = by_arch[w["archs"][0] + ":micro"]
    ok = all(r["parity_ok"] for r in by_arch.values()) and cont["zero_recompile"]
    if gate_enforced:
        ok = ok and gate_row["scan_speedup"] >= SCAN_SPEEDUP_GATE
        ok = ok and cont["continuous_speedup"] >= CONTINUOUS_SPEEDUP_GATE

    payload = dict(
        bench="serve_scan_continuous_batching",
        smoke=args.smoke,
        workload=dict(w, archs=list(w["archs"]), short_frac=SHORT_FRAC),
        host_cores=os.cpu_count() or 1,
        gate_enforced=gate_enforced,
        gate_note=(
            f"scan decode >= {SCAN_SPEEDUP_GATE}x legacy host-loop decode "
            f"tok/s at batch {w['batch']} on the micro reduced "
            f"{w['archs'][0]} row (overrides {MICRO}; the standard reduced "
            "size is single-core-gemm-bound on CPU hosts, drowning the "
            "per-token host overhead the scan removes — arch rows report "
            f"it informationally); continuous batching >= "
            f"{CONTINUOUS_SPEEDUP_GATE}x drain-and-refill aggregate tok/s "
            "under mixed-length traffic; zero recompiles after warmup and "
            "greedy scan/legacy parity always enforced (smoke included)"
        ),
        by_arch=by_arch,
        continuous=cont,
        ok=ok,
        total_s=round(time.time() - t0, 2),
    )
    out_path = SMOKE_OUT_PATH if args.smoke else OUT_PATH
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=2)
    print(common.csv_line(
        "serve_scan_vs_legacy",
        0.0,
        f"scan_speedup={gate_row['scan_speedup']:.2f} "
        f"continuous_speedup={cont['continuous_speedup']:.2f} "
        f"zero_recompile={cont['zero_recompile']} "
        f"gate_enforced={gate_enforced} ok={ok}",
    ))
    print(f"ok={ok}  wrote {os.path.abspath(out_path)}")
    if not ok:
        raise SystemExit(1)
    return payload


if __name__ == "__main__":
    main()

"""Robust aggregation under injected faults: convergence-to-target vs mean.

Prices the fault-tolerance layer (DESIGN.md §11) on the same tiny-MLP
federation the other engine benches use: four arms share one federation,
one selection strategy, and one key chain —

  * ``clean``          — no faults, plain eq.-(6) mean (the PR-5/6 engine
                         path: ``faults=None`` skips every guard branch at
                         Python level, so this IS the existing program);
  * ``mean_faulty``    — the ``corrupt`` fault model (≈10% of delivered
                         updates NaN'd or norm-scaled garbage) aggregated
                         with plain mean: the unprotected control;
  * ``clipped_faulty`` — same faults, ``clipped_mean`` (norm-clip outliers
                         to the cohort-median threshold);
  * ``trimmed_faulty`` — same faults, ``trimmed_mean`` (reject outliers +
                         non-finite updates from the weighted sum).

The headline gate (full mode only): both robust arms must reach the common
target loss — the clean arm's loss floor × ``TARGET_SLACK`` — while the
mean arm must NOT (its best *finite* round mean stays above target; NaN
rounds are excluded NaN-aware, which only helps the control).  A second
gate proves quarantine feedback: under the deterministic ``lemons`` model
(persistently-garbage clients) with ``quarantine_rounds >= rounds``, every
lemon is selected at most once across the whole run (first pick flags it,
the counter excludes it thereafter), while a ``quarantine_rounds=0``
contrast arm keeps re-selecting them.  The zero-fault parity contract —
sharded clean vs single-device clean, bit-identical cohorts and fp32-close
params — is always enforced, smoke included.

Convergence/quarantine metrics are core-count independent, so those gates
arm on every full run (like async_bench's simulated-time gate); the
rounds/sec numbers are informational and only compared same-host by
check_regression.  Runs in a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count`` (the sharded arm
needs a client mesh; the flag must precede jax init).  Writes
``BENCH_fault.json`` (repo root); ``--smoke`` runs tiny shapes with no
convergence gate and writes ``BENCH_fault_smoke.json`` (CI harness +
check_regression input):

    PYTHONPATH=src python -m benchmarks.fault_bench [--smoke]
"""

from __future__ import annotations

import argparse
import json
import os
import re
import subprocess
import sys
import time

OUT_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_fault.json")
SMOKE_OUT_PATH = os.path.join(
    os.path.dirname(__file__), "..", "BENCH_fault_smoke.json"
)

# one federation, four aggregation arms + a quarantine pair.  rounds is
# sized so the clean arm's loss floor is well separated from the mean arm's
# corrupted trajectory (garbage updates are 50x-norm deltas: one hit throws
# plain mean far off the descent path, and k=8 of C=16 at 10% corruption
# hits most rounds)
FULL = dict(clients=16, n_c=32, feat=16, hidden=32, steps=3, k=8, devices=4,
            rounds=40, lr=0.1, reps=2)
SMOKE = dict(clients=8, n_c=8, feat=8, hidden=16, steps=2, k=4, devices=2,
             rounds=8, lr=0.1, reps=1)
FAULT_MODEL = "corrupt"      # ~10% of delivered updates NaN/garbage
LEMON_MODEL = "lemons"       # deterministic persistently-bad clients
TARGET_SLACK = 1.10          # target = clean loss floor x slack


# ----------------------------------------------------------------- child


def _teacher_workload(w: dict):
    """Tiny-MLP federation with LEARNABLE labels (a random linear teacher).

    ``shard_bench._mlp_workload`` labels are random, so 40 rounds barely
    move the loss and a multiplicative target can't separate the arms; here
    the clean arm descends well below init, giving the corrupted-mean
    control real room to fail the target."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    c, n_c, feat, hid = w["clients"], w["n_c"], w["feat"], w["hidden"]
    ncls = 10
    rng = np.random.default_rng(0)
    xs = rng.normal(size=(c, n_c, feat)).astype(np.float32)
    teacher = rng.normal(size=(feat, ncls)).astype(np.float32)
    ys = np.argmax(xs.reshape(-1, feat) @ teacher, -1).reshape(c, n_c)
    params = {
        "w1": jnp.asarray(0.05 * rng.normal(size=(feat, hid)).astype(np.float32)),
        "b1": jnp.zeros((hid,), jnp.float32),
        "w2": jnp.asarray(0.05 * rng.normal(size=(hid, ncls)).astype(np.float32)),
        "b2": jnp.zeros((ncls,), jnp.float32),
    }

    def loss_fn(p, x, y):
        h = jax.nn.relu(x @ p["w1"] + p["b1"])
        logp = jax.nn.log_softmax(h @ p["w2"] + p["b2"])
        return -jnp.mean(jnp.take_along_axis(logp, y[..., None], axis=-1))

    return loss_fn, jnp.asarray(xs), jnp.asarray(ys, jnp.int32), params, ncls


def _child(w: dict) -> dict:
    import jax
    import numpy as np

    from benchmarks.shard_bench import _parity, _timed_run
    from repro.core import selection as selection_lib
    from repro.fl import engine, faults
    from repro.launch.mesh import make_client_mesh

    assert jax.device_count() == w["devices"], (jax.device_count(), w)
    loss_fn, xs, ys, params, ncls = _teacher_workload(w)
    mesh = make_client_mesh(w["devices"])
    strat = selection_lib.UniformSelection()
    base = dict(
        num_clients=w["clients"], clients_per_round=w["k"],
        local_epochs=w["steps"], lr=w["lr"], rounds=w["rounds"],
        eval_every=10 * w["rounds"], num_classes=ncls, seed=0,
    )

    def run(use_mesh=None, **kw):
        cfg = engine.FLConfig(**dict(base, **kw))
        state = engine.init_server_state(
            cfg, params, loss_fn, None, xs, ys, strategy=strat,
            profiles=xs.mean(axis=1), mesh=use_mesh,
        )
        rf = engine.make_round_fn(cfg, loss_fn, (strat,), mesh=use_mesh)
        secs, (st, outs) = _timed_run(rf, state, w["rounds"], w["reps"])
        return secs, st, jax.tree_util.tree_map(np.asarray, outs)

    arms = {}
    kept = {}
    arm_cfgs = dict(
        clean=dict(),
        mean_faulty=dict(faults=FAULT_MODEL, aggregator="mean"),
        clipped_faulty=dict(faults=FAULT_MODEL, aggregator="clipped_mean"),
        trimmed_faulty=dict(faults=FAULT_MODEL, aggregator="trimmed_mean"),
    )
    for name, kw in arm_cfgs.items():
        secs, st, outs = run(use_mesh=mesh, **kw)
        kept[name] = (st, outs)
        row = dict(
            rounds_per_sec=w["rounds"] / secs,
            best_finite_loss=(float(np.nanmin(outs["loss"]))
                              if np.isfinite(outs["loss"]).any() else None),
            final_loss=float(outs["loss"][-1]),
        )
        if "survivors" in outs:
            row.update(
                mean_survivors=float(np.mean(outs["survivors"])),
                flagged_total=int(np.sum(outs["flagged"])),
                identity_rounds=int(np.sum(outs["identity_round"])),
            )
        arms[name] = row

    # zero-fault parity: the sharded clean arm vs the single-device engine
    _, st1, outs1 = run(use_mesh=None)
    parity = _parity((st1, outs1), kept["clean"])

    # quarantine: deterministic lemons + long cooldown -> each lemon picked
    # at most once; the cooldown-0 contrast keeps re-selecting them.  Runs
    # SINGLE-DEVICE on purpose: the guard's norm median is shard-local
    # (DESIGN.md §11 — validation happens inside the shard_map, before the
    # psum), so a shard whose round cohort is a single lemon has no clean
    # reference scale and can miss the flag; the single-device guard sees
    # the whole cohort, which is the regime the quarantine property is
    # defined in
    model = faults.get_fault_model(LEMON_MODEL)
    lemons = np.nonzero(np.asarray(faults.lemon_mask(model, w["clients"])))[0]

    def lemon_picks(outs):
        sel = np.asarray(outs["selected"]).reshape(-1)
        return {int(c): int(np.sum(sel == c)) for c in lemons}

    _, _, out_q = run(use_mesh=None, faults=LEMON_MODEL,
                      aggregator="trimmed_mean",
                      quarantine_rounds=10 * w["rounds"])
    _, _, out_nq = run(use_mesh=None, faults=LEMON_MODEL,
                       aggregator="trimmed_mean", quarantine_rounds=0)
    picks_q = lemon_picks(out_q)
    picks_nq = lemon_picks(out_nq)
    quarantine = dict(
        lemons=[int(c) for c in lemons],
        picks_with_quarantine=picks_q,
        picks_without_quarantine=picks_nq,
        max_picks_with_quarantine=max(picks_q.values()),
        max_picks_without_quarantine=max(picks_nq.values()),
    )
    return dict(arms=arms, parity=parity, quarantine=quarantine)


# ---------------------------------------------------------------- parent


def _spawn(w: dict) -> dict:
    env = dict(os.environ)
    flags = re.sub(
        r"--xla_force_host_platform_device_count=\d+", "",
        env.get("XLA_FLAGS", ""),
    ).strip()
    env["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={w['devices']} " + flags
    ).strip()
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.fault_bench", "--child",
         json.dumps(w)],
        env=env, capture_output=True, text=True, timeout=1200,
    )
    if proc.returncode != 0:
        raise RuntimeError(
            f"fault_bench child failed:\n{proc.stdout}\n{proc.stderr}"
        )
    return json.loads(proc.stdout.splitlines()[-1])


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes, no convergence gate (CI harness check)")
    ap.add_argument("--child", default=None, help=argparse.SUPPRESS)
    args = ap.parse_args(argv)

    if args.child is not None:
        print(json.dumps(_child(json.loads(args.child))))
        return None

    from benchmarks import common

    t0 = time.time()
    w = SMOKE if args.smoke else FULL
    res = _spawn(w)

    arms = res["arms"]
    clean_floor = arms["clean"]["best_finite_loss"]
    target = clean_floor * TARGET_SLACK
    for name, row in arms.items():
        best = row["best_finite_loss"]
        best_s = f"{best:.4f}" if best is not None else "all-NaN"
        extra = (f" survivors={row['mean_survivors']:.1f} "
                 f"flagged={row['flagged_total']} "
                 f"identity={row['identity_rounds']}"
                 if "mean_survivors" in row else "")
        print(f"  fault_bench {name:15s} best_loss={best_s} "
              f"({row['rounds_per_sec']:6.2f} rounds/s){extra}")
    print(f"  fault_bench target_loss={target:.4f} "
          f"(clean floor {clean_floor:.4f} x {TARGET_SLACK})")

    q = res["quarantine"]
    print(f"  fault_bench lemons={q['lemons']}: "
          f"max picks {q['max_picks_with_quarantine']} with quarantine, "
          f"{q['max_picks_without_quarantine']} without")

    def reaches(row):
        return row["best_finite_loss"] is not None and \
            row["best_finite_loss"] <= target

    parity = res["parity"]
    gate_enforced = not args.smoke
    ok = bool(parity.get("ok", False))
    if gate_enforced:
        ok = ok and reaches(arms["trimmed_faulty"])
        ok = ok and reaches(arms["clipped_faulty"])
        ok = ok and not reaches(arms["mean_faulty"])
        ok = ok and q["max_picks_with_quarantine"] <= 1
        ok = ok and q["max_picks_without_quarantine"] > 1

    payload = dict(
        bench="fault_robust_aggregation_to_target",
        smoke=args.smoke,
        workload=dict(w, model="mlp(2-layer)", selection="uniform",
                      fault_model=FAULT_MODEL, lemon_model=LEMON_MODEL),
        host_cores=os.cpu_count() or 1,
        target_loss=target,
        target_slack=TARGET_SLACK,
        gate_enforced=gate_enforced,
        gate_note=(
            "robust arms (clipped_mean, trimmed_mean) must reach the clean "
            f"loss floor x {TARGET_SLACK} under {FAULT_MODEL} faults while "
            "plain mean must not; quarantined lemons picked <= 1x vs "
            "repeats without quarantine; convergence metrics are core-count "
            "independent so the gate arms on every full run; zero-fault "
            "parity always enforced"
        ),
        parity=parity,
        arms=arms,
        quarantine=q,
        ok=ok,
        total_s=round(time.time() - t0, 2),
    )
    out_path = SMOKE_OUT_PATH if args.smoke else OUT_PATH
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=2)
    print(common.csv_line(
        "fault_robust_vs_mean",
        0.0,
        f"trimmed_ok={reaches(arms['trimmed_faulty'])} "
        f"mean_degrades={not reaches(arms['mean_faulty'])} "
        f"parity_ok={parity.get('ok')} "
        f"gate_enforced={gate_enforced} ok={ok}",
    ))
    print(f"ok={ok}  wrote {os.path.abspath(out_path)}")
    if not ok:
        raise SystemExit(1)
    return payload


if __name__ == "__main__":
    main()

"""Cohort-size convergence study: rounds-to-target-loss vs k at fixed C.

The throughput half of the ROADMAP cohort-size study lives in
``shard_bench``'s k-sweep (slotted rounds cost ≈cap, not C_loc, local
updates); this module ships the **convergence half**: at a fixed federation
size C, how many rounds does each selection strategy need to reach a common
target loss as the cohort size k sweeps?  Where DPP diversity stops paying
vs uniform is exactly the question the selection surveys pose
(arXiv:2211.01549, arXiv:2310.00198).

Executed the cheap way the engine makes possible (DESIGN.md §§7-8): per k,
ALL strategies × seeds run as ONE ``run_many`` grid over a multi-strategy
``round_fn`` (``lax.switch`` on ``strategy_index``) through the
**capacity-slot** sharded engine (``cohort_cap = k``), so a k-client round
pays k — not C — local updates whatever the cohort size.  The federation is
class-skewed non-IID (each client dominated by two classes) so profile-kernel
diversity has signal to exploit.

Per k the common target is the loss floor every arm reaches; per strategy we
record the mean-over-seeds rounds to hit it, the mean cohort GEMD, and the
grid's steady-state scan throughput (the ``rounds_per_sec`` metric
``check_regression`` tracks).  Like the other gated harnesses the sweep runs
in a subprocess with a **pinned** ``--xla_force_host_platform_device_count``
(1 shard in smoke, the core-count divisor of C otherwise) and best-of-reps
timing, so the throughput baseline cannot drift with whatever XLA_FLAGS the
calling job exports.  Writes ``BENCH_cohort.json``; ``--smoke`` writes
``BENCH_cohort_smoke.json`` at tiny scale (CI harness):

    PYTHONPATH=src python -m benchmarks.cohort_sweep [--smoke]

``--algos`` runs the **local-algorithm axis** instead (DESIGN.md §12): at a
fixed high-skew federation and one cohort size, each registered local
algorithm (fedavg / fedprox / feddyn — a *static* trace constant, so one
``run_many`` grid over strategies × seeds per algorithm) races to the same
target loss, plus one feddyn × bounded-staleness row (the ROADMAP's
never-benchmarked interaction).  The ``ok`` gate asserts the paper-level
claim: at high non-IID skew a drift-correcting objective (fedprox or
feddyn) reaches target in fewer rounds than plain fedavg under DPP
selection.  Writes ``BENCH_algo.json`` / ``BENCH_algo_smoke.json``:

    PYTHONPATH=src python -m benchmarks.cohort_sweep --algos [--smoke]
"""

from __future__ import annotations

import argparse
import json
import os
import re
import subprocess
import sys
import time

OUT_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_cohort.json")
SMOKE_OUT_PATH = os.path.join(
    os.path.dirname(__file__), "..", "BENCH_cohort_smoke.json"
)

FULL = dict(clients=16, n_c=48, feat=16, hidden=32, ncls=8, steps=2,
            rounds=40, lr=0.1, ks=(2, 4, 8, 16), seeds=2, reps=3, spawns=2)
SMOKE = dict(clients=8, n_c=12, feat=8, hidden=16, ncls=4, steps=2,
             rounds=6, lr=0.1, ks=(2, 8), seeds=1, reps=4, spawns=2)
STRATEGIES = ("fl-dp3s", "fedavg", "fedsae")

ALGO_OUT_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_algo.json")
ALGO_SMOKE_OUT_PATH = os.path.join(
    os.path.dirname(__file__), "..", "BENCH_algo_smoke.json"
)
# high-skew regime (skew = probability mass on each client's two major
# classes): strong non-IID drift is where the drift-correcting objectives
# earn their keep — the ok gate below asserts exactly that
ALGO_FULL = dict(clients=16, n_c=8, feat=16, hidden=32, ncls=8, steps=32,
                 rounds=48, lr=1.0, k=4, seeds=3, reps=3, spawns=2,
                 skew=1.0, prox_mu=0.1, feddyn_alpha=0.05,
                 staleness_bound=2, scenario="heavy_tail")
ALGO_SMOKE = dict(clients=8, n_c=12, feat=8, hidden=16, ncls=4, steps=2,
                  rounds=6, lr=0.1, k=2, seeds=1, reps=2, spawns=2,
                  skew=0.9, prox_mu=0.1, feddyn_alpha=0.1,
                  staleness_bound=2, scenario="heavy_tail")


def _algo_rows(w: dict):
    """The algorithm axis: name -> FLConfig overrides.  The three registry
    algorithms race synchronously; the ``*_stale`` rows re-run fedavg and
    feddyn under bounded-staleness aggregation (feddyn × staleness is the
    ROADMAP's open interaction question — fedavg_stale is its control)."""
    stale = dict(staleness_bound=w["staleness_bound"], scenario=w["scenario"])
    return {
        "fedavg": dict(local_algo="fedavg"),
        "fedprox": dict(local_algo="fedprox", prox_mu=w["prox_mu"]),
        "feddyn": dict(local_algo="feddyn", feddyn_alpha=w["feddyn_alpha"]),
        "fedavg_stale": dict(local_algo="fedavg", **stale),
        "feddyn_stale": dict(local_algo="feddyn",
                             feddyn_alpha=w["feddyn_alpha"], **stale),
    }


def _pinned_devices(w: dict, smoke: bool) -> int:
    """Device count the child is pinned to: 1 in smoke (a deterministic
    harness check whatever the environment forces), else the largest divisor
    of C the physical cores can host."""
    if smoke:
        return 1
    cores = os.cpu_count() or 1
    c = w["clients"]
    return max(d for d in range(1, min(cores, c) + 1) if c % d == 0)


def _federation(w: dict):
    """Class-skewed non-IID clients over Gaussian class clusters: client c's
    labels concentrate on classes {c, c+1} mod ncls, so per-client mean
    features (the profiles) carry the skew the DPP kernel diversifies over.
    ``w['skew']`` (default 0.8) is the probability mass on the two major
    classes — the algorithm axis pushes it up for a high-drift regime."""
    import numpy as np

    rng = np.random.default_rng(7)
    c, n_c, feat, ncls = w["clients"], w["n_c"], w["feat"], w["ncls"]
    skew = w.get("skew", 0.8)
    means = rng.normal(scale=2.0, size=(ncls, feat)).astype(np.float32)
    xs = np.empty((c, n_c, feat), np.float32)
    ys = np.empty((c, n_c), np.int32)
    for ci in range(c):
        major = np.asarray([ci % ncls, (ci + 1) % ncls])
        probs = np.full((ncls,), (1.0 - skew) / ncls)
        probs[major] += skew / 2.0
        labels = rng.choice(ncls, size=(n_c,), p=probs / probs.sum())
        xs[ci] = means[labels] + rng.normal(size=(n_c, feat)).astype(np.float32)
        ys[ci] = labels
    return xs, ys, means


def _child_run(w: dict, n_shards: int) -> dict:
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core import dpp as dpp_lib
    from repro.core import make_strategy
    from repro.core import similarity as similarity_lib
    from repro.fl import engine
    from repro.launch.mesh import make_client_mesh

    assert jax.device_count() == n_shards, (jax.device_count(), n_shards)
    c, ncls = w["clients"], w["ncls"]
    xs_np, ys_np, _ = _federation(w)
    xs, ys = jnp.asarray(xs_np), jnp.asarray(ys_np)

    def loss_fn(p, x, y):
        h = jax.nn.relu(x @ p["w1"] + p["b1"])
        logp = jax.nn.log_softmax(h @ p["w2"] + p["b2"])
        return -jnp.mean(jnp.take_along_axis(logp, y[..., None], axis=-1))

    def init_params(seed):
        rng = np.random.default_rng(100 + seed)
        return {
            "w1": jnp.asarray(
                0.1 * rng.normal(size=(w["feat"], w["hidden"])).astype(np.float32)
            ),
            "b1": jnp.zeros((w["hidden"],), jnp.float32),
            "w2": jnp.asarray(
                0.1 * rng.normal(size=(w["hidden"], ncls)).astype(np.float32)
            ),
            "b2": jnp.zeros((ncls,), jnp.float32),
        }

    mesh = make_client_mesh(n_shards)
    strategies = tuple(make_strategy(s) for s in STRATEGIES)

    by_k = {}
    throughput = {}
    for k in w["ks"]:
        cfg = engine.FLConfig(
            num_clients=c, clients_per_round=k, local_epochs=w["steps"],
            lr=w["lr"], rounds=w["rounds"], eval_every=10 * w["rounds"],
            num_classes=ncls, seed=0, cohort_cap=k,
        )
        states = []
        for seed in range(w["seeds"]):
            params = init_params(seed)
            profiles = xs.mean(axis=1)
            kernel = similarity_lib.kernel_from_profiles(profiles)
            losses0 = jax.jit(jax.vmap(loss_fn, in_axes=(None, 0, 0)))(
                params, xs, ys
            )
            for si, strat in enumerate(strategies):
                eig = (
                    dpp_lib.kdpp_sampler_state(kernel, k)
                    if getattr(strat, "uses_spectral_cache", False)
                    else dpp_lib.identity_sampler_state(c, k)
                )
                states.append(engine.init_server_state(
                    cfg, params, loss_fn, None, xs, ys, strategy=strat,
                    strategy_index=si, key=jax.random.key(1000 * seed + si),
                    profiles=profiles, kernel=kernel, losses=losses0,
                    eig_state=eig,
                ))
        stacked = engine.stack_states(states)
        rf = engine.make_round_fn(cfg, loss_fn, strategies, mesh=mesh)
        out = engine.run_many(rf, stacked, w["rounds"], mesh=mesh)
        jax.block_until_ready(out)  # compile + warm
        best = float("inf")
        for _ in range(w["reps"]):
            t0 = time.perf_counter()
            _, outs = engine.run_many(rf, stacked, w["rounds"], mesh=mesh)
            jax.block_until_ready(outs)
            best = min(best, time.perf_counter() - t0)
        throughput[str(k)] = len(states) * w["rounds"] / best

        runs = engine.unstack_outputs(outs)
        floors = [float(np.min(r["loss"])) for r in runs]
        target = max(floors)
        per_strategy = {}
        for si, name in enumerate(STRATEGIES):
            arm = [runs[seed * len(strategies) + si]
                   for seed in range(w["seeds"])]
            rtt = []
            for r in arm:
                best_loss = np.minimum.accumulate(
                    np.asarray(r["loss"], np.float64)
                )
                hit = np.nonzero(best_loss <= target)[0]
                rtt.append(int(hit[0]) + 1 if hit.size else w["rounds"])
            per_strategy[name] = dict(
                rounds_to_target=float(np.mean(rtt)),
                final_loss=float(np.mean([np.min(r["loss"]) for r in arm])),
                mean_gemd=float(np.mean([np.mean(r["gemd"]) for r in arm])),
            )
        by_k[str(k)] = dict(k=k, target_loss=target, per_strategy=per_strategy)
    return dict(
        by_k=by_k, throughput_rounds_per_sec=throughput,
        workload=dict(w, model="mlp(2-layer)", strategies=STRATEGIES,
                      n_shards=n_shards),
    )


def _algo_child_run(w: dict, n_shards: int) -> dict:
    """The local-algorithm axis (DESIGN.md §12): per algorithm row — a
    *static* trace constant (feddyn even changes the ServerState pytree), so
    the rows are a Python loop — one ``run_many`` grid over strategies ×
    seeds through the capacity-slot engine, all rows on the SAME federation,
    params, and selection key streams (cohorts are algorithm-independent, so
    the races differ only in the local objective)."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core import dpp as dpp_lib
    from repro.core import make_strategy
    from repro.core import similarity as similarity_lib
    from repro.fl import engine
    from repro.launch.mesh import make_client_mesh

    assert jax.device_count() == n_shards, (jax.device_count(), n_shards)
    c, ncls, k = w["clients"], w["ncls"], w["k"]
    xs_np, ys_np, _ = _federation(w)
    xs, ys = jnp.asarray(xs_np), jnp.asarray(ys_np)

    def loss_fn(p, x, y):
        h = jax.nn.relu(x @ p["w1"] + p["b1"])
        logp = jax.nn.log_softmax(h @ p["w2"] + p["b2"])
        return -jnp.mean(jnp.take_along_axis(logp, y[..., None], axis=-1))

    def init_params(seed):
        rng = np.random.default_rng(100 + seed)
        return {
            "w1": jnp.asarray(
                0.1 * rng.normal(size=(w["feat"], w["hidden"])).astype(np.float32)
            ),
            "b1": jnp.zeros((w["hidden"],), jnp.float32),
            "w2": jnp.asarray(
                0.1 * rng.normal(size=(w["hidden"], ncls)).astype(np.float32)
            ),
            "b2": jnp.zeros((ncls,), jnp.float32),
        }

    mesh = make_client_mesh(n_shards)
    strategies = tuple(make_strategy(s) for s in STRATEGIES)

    rows = {}
    throughput = {}
    curves = {}  # row -> strategy -> list over seeds of best-loss curves
    for row_name, overrides in _algo_rows(w).items():
        # capacity-slot compaction assumes a synchronous cohort — the stale
        # rows run resident-mode instead (the engine rejects the combo), and
        # the staleness ring's per-shard layout doesn't stack into a
        # run_many grid, so stale arms run as sequential run_scanned calls
        # (one compiled program, async_bench-style)
        stale = "staleness_bound" in overrides
        cap = None if stale else k
        cfg = engine.FLConfig(
            num_clients=c, clients_per_round=k, local_epochs=w["steps"],
            lr=w["lr"], rounds=w["rounds"], eval_every=10 * w["rounds"],
            num_classes=ncls, seed=0, cohort_cap=cap, **overrides,
        )
        states = []
        for seed in range(w["seeds"]):
            params = init_params(seed)
            profiles = xs.mean(axis=1)
            kernel = similarity_lib.kernel_from_profiles(profiles)
            losses0 = jax.jit(jax.vmap(loss_fn, in_axes=(None, 0, 0)))(
                params, xs, ys
            )
            for si, strat in enumerate(strategies):
                eig = (
                    dpp_lib.kdpp_sampler_state(kernel, k)
                    if getattr(strat, "uses_spectral_cache", False)
                    else dpp_lib.identity_sampler_state(c, k)
                )
                states.append(engine.init_server_state(
                    cfg, params, loss_fn, None, xs, ys, strategy=strat,
                    strategy_index=si, key=jax.random.key(1000 * seed + si),
                    profiles=profiles, kernel=kernel, losses=losses0,
                    eig_state=eig, mesh=mesh if stale else None,
                ))
        rf = engine.make_round_fn(cfg, loss_fn, strategies, mesh=mesh)
        if stale:
            def grid(states=states, rf=rf):
                return [engine.run_scanned(rf, s, w["rounds"], mesh=mesh)[1]
                        for s in states]

            runs = grid()  # compile + warm
            jax.block_until_ready(runs)
            best = float("inf")
            for _ in range(w["reps"]):
                t0 = time.perf_counter()
                runs = grid()
                jax.block_until_ready(runs)
                best = min(best, time.perf_counter() - t0)
        else:
            stacked = engine.stack_states(states)
            out = engine.run_many(rf, stacked, w["rounds"], mesh=mesh)
            jax.block_until_ready(out)  # compile + warm
            best = float("inf")
            for _ in range(w["reps"]):
                t0 = time.perf_counter()
                _, outs = engine.run_many(rf, stacked, w["rounds"], mesh=mesh)
                jax.block_until_ready(outs)
                best = min(best, time.perf_counter() - t0)
            runs = engine.unstack_outputs(outs)
        throughput[row_name] = len(states) * w["rounds"] / best
        curves[row_name] = {}
        rows[row_name] = dict(config=dict(overrides),
                              stale="staleness_bound" in overrides)
        for si, name in enumerate(STRATEGIES):
            arm = [runs[seed * len(strategies) + si]
                   for seed in range(w["seeds"])]
            curves[row_name][name] = [
                np.minimum.accumulate(np.asarray(r["loss"], np.float64))
                for r in arm
            ]

    # common per-strategy target: the loss floor every SYNCHRONOUS algorithm
    # row reaches (stale rows race against the same bar, but don't set it —
    # staleness legitimately trades convergence for wall clock)
    sync_rows = [r for r, rec in rows.items() if not rec["stale"]]
    per_row = {}
    for row_name in rows:
        per_strategy = {}
        for name in STRATEGIES:
            target = max(
                float(cur[-1])
                for r in sync_rows for cur in curves[r][name]
            )
            rtt = []
            for cur in curves[row_name][name]:
                hit = np.nonzero(cur <= target)[0]
                rtt.append(int(hit[0]) + 1 if hit.size else w["rounds"])
            per_strategy[name] = dict(
                target_loss=target,
                rounds_to_target=float(np.mean(rtt)),
                final_loss=float(np.mean([cur[-1]
                                          for cur in curves[row_name][name]])),
            )
        per_row[row_name] = dict(rows[row_name], per_strategy=per_strategy)

    return dict(
        rows=per_row, throughput_rounds_per_sec=throughput,
        workload=dict(w, model="mlp(2-layer)", strategies=STRATEGIES,
                      n_shards=n_shards),
    )


def _spawn(w: dict, n_shards: int, algos: bool = False) -> dict:
    env = dict(os.environ)
    flags = re.sub(
        r"--xla_force_host_platform_device_count=\d+", "",
        env.get("XLA_FLAGS", ""),
    ).strip()
    env["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={n_shards} " + flags
    ).strip()
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.cohort_sweep", "--child",
         json.dumps(dict(workload=w, n_shards=n_shards, algos=algos))],
        env=env, capture_output=True, text=True, timeout=1800,
    )
    if proc.returncode != 0:
        raise RuntimeError(
            f"cohort_sweep child failed:\n{proc.stdout}\n{proc.stderr}"
        )
    return json.loads(proc.stdout.splitlines()[-1])


def _main_algos(smoke: bool):
    """Driver for the local-algorithm axis: spawn, merge throughput
    best-of, evaluate the drift-correction gate, write BENCH_algo[_smoke]."""
    from benchmarks import common

    t0 = time.time()
    w = ALGO_SMOKE if smoke else ALGO_FULL
    n_shards = _pinned_devices(w, smoke)
    res = _spawn(w, n_shards, algos=True)
    for _ in range(w.get("spawns", 1) - 1):
        again = _spawn(w, n_shards, algos=True)
        for rn, rps in again["throughput_rounds_per_sec"].items():
            res["throughput_rounds_per_sec"][rn] = max(
                res["throughput_rounds_per_sec"][rn], rps
            )
    primary = "fl-dp3s"
    rtt = {rn: rec["per_strategy"][primary]["rounds_to_target"]
           for rn, rec in res["rows"].items()}
    # the gate (ISSUE 8 acceptance): at high non-IID skew, a drift-correcting
    # local objective beats plain fedavg to target under DPP selection — and
    # the feddyn × staleness row exists and converges to a finite loss
    win = min(rtt["fedprox"], rtt["feddyn"]) < rtt["fedavg"]
    stale_row = res["rows"].get("feddyn_stale")
    stale_ok = (
        stale_row is not None
        and all(v["final_loss"] == v["final_loss"]  # not NaN
                for v in stale_row["per_strategy"].values())
    )
    ok = bool(win and stale_ok)
    for rn in ("fedavg", "fedprox", "feddyn", "fedavg_stale", "feddyn_stale"):
        rec = res["rows"][rn]
        row = "  ".join(
            f"{n}={rec['per_strategy'][n]['rounds_to_target']:.1f}r"
            for n in STRATEGIES
        )
        print(f"  algo_axis {rn:13s} {row} "
              f"({res['throughput_rounds_per_sec'][rn]:.1f} scan-rounds/s)")
    payload = dict(
        bench="local_algo_rounds_to_target",
        smoke=smoke,
        host_cores=os.cpu_count() or 1,
        primary_strategy=primary,
        ok=ok,
        total_s=round(time.time() - t0, 2),
        **res,
    )
    out_path = ALGO_SMOKE_OUT_PATH if smoke else ALGO_OUT_PATH
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=2)
    print(common.csv_line(
        "algo_axis",
        0.0,
        f"{primary} rounds-to-target: "
        + " ".join(f"{rn}={rtt[rn]:.1f}" for rn in sorted(rtt))
        + f" ok={ok}",
    ))
    print(f"wrote {os.path.abspath(out_path)}")
    # the gate only bites at full scale — smoke rounds are too few for a
    # meaningful race (the smoke JSON still records ok for the harness test)
    if not smoke and not ok:
        raise SystemExit(1)
    return payload


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes (CI harness check)")
    ap.add_argument("--algos", action="store_true",
                    help="local-algorithm axis (DESIGN.md §12) instead of "
                         "the cohort-size sweep")
    ap.add_argument("--child", default=None, help=argparse.SUPPRESS)
    args = ap.parse_args(argv)

    if args.child is not None:
        spec = json.loads(args.child)
        if spec.get("algos"):
            print(json.dumps(_algo_child_run(spec["workload"],
                                             spec["n_shards"])))
        else:
            spec["workload"]["ks"] = tuple(spec["workload"]["ks"])
            print(json.dumps(_child_run(spec["workload"], spec["n_shards"])))
        return None

    if args.algos:
        return _main_algos(smoke=args.smoke)

    from benchmarks import common

    t0 = time.time()
    w = SMOKE if args.smoke else FULL
    n_shards = _pinned_devices(w, args.smoke)
    res = _spawn(w, n_shards)
    # convergence results are deterministic across spawns; throughput is
    # best-of across independent children (shared-container scheduling noise
    # swings single child measurements — same treatment as shard_bench)
    for _ in range(w.get("spawns", 1) - 1):
        again = _spawn(w, n_shards)
        for kk, rps in again["throughput_rounds_per_sec"].items():
            res["throughput_rounds_per_sec"][kk] = max(
                res["throughput_rounds_per_sec"][kk], rps
            )
    for kk in sorted(res["by_k"], key=int):
        rec = res["by_k"][kk]
        row = "  ".join(
            f"{n}={rec['per_strategy'][n]['rounds_to_target']:.1f}r"
            for n in STRATEGIES
        )
        print(f"  cohort_sweep k={int(kk):3d} target={rec['target_loss']:.4f} "
              f"{row} ({res['throughput_rounds_per_sec'][kk]:.1f} "
              f"scan-rounds/s)")
    payload = dict(
        bench="cohort_size_rounds_to_target",
        smoke=args.smoke,
        host_cores=os.cpu_count() or 1,
        total_s=round(time.time() - t0, 2),
        **res,
    )
    out_path = SMOKE_OUT_PATH if args.smoke else OUT_PATH
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=2)
    ks = sorted(res["by_k"], key=int)
    dpp_rtt = {k: res["by_k"][k]["per_strategy"]["fl-dp3s"]["rounds_to_target"]
               for k in ks}
    print(common.csv_line(
        "cohort_sweep",
        0.0,
        "fl-dp3s rounds-to-target by k: "
        + " ".join(f"k{k}={dpp_rtt[k]:.1f}" for k in ks),
    ))
    print(f"wrote {os.path.abspath(out_path)}")
    return payload


if __name__ == "__main__":
    main()

"""Cohort-size convergence study: rounds-to-target-loss vs k at fixed C.

The throughput half of the ROADMAP cohort-size study lives in
``shard_bench``'s k-sweep (slotted rounds cost ≈cap, not C_loc, local
updates); this module ships the **convergence half**: at a fixed federation
size C, how many rounds does each selection strategy need to reach a common
target loss as the cohort size k sweeps?  Where DPP diversity stops paying
vs uniform is exactly the question the selection surveys pose
(arXiv:2211.01549, arXiv:2310.00198).

Executed the cheap way the engine makes possible (DESIGN.md §§7-8): per k,
ALL strategies × seeds run as ONE ``run_many`` grid over a multi-strategy
``round_fn`` (``lax.switch`` on ``strategy_index``) through the
**capacity-slot** sharded engine (``cohort_cap = k``), so a k-client round
pays k — not C — local updates whatever the cohort size.  The federation is
class-skewed non-IID (each client dominated by two classes) so profile-kernel
diversity has signal to exploit.

Per k the common target is the loss floor every arm reaches; per strategy we
record the mean-over-seeds rounds to hit it, the mean cohort GEMD, and the
grid's steady-state scan throughput (the ``rounds_per_sec`` metric
``check_regression`` tracks).  Like the other gated harnesses the sweep runs
in a subprocess with a **pinned** ``--xla_force_host_platform_device_count``
(1 shard in smoke, the core-count divisor of C otherwise) and best-of-reps
timing, so the throughput baseline cannot drift with whatever XLA_FLAGS the
calling job exports.  Writes ``BENCH_cohort.json``; ``--smoke`` writes
``BENCH_cohort_smoke.json`` at tiny scale (CI harness):

    PYTHONPATH=src python -m benchmarks.cohort_sweep [--smoke]
"""

from __future__ import annotations

import argparse
import json
import os
import re
import subprocess
import sys
import time

OUT_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_cohort.json")
SMOKE_OUT_PATH = os.path.join(
    os.path.dirname(__file__), "..", "BENCH_cohort_smoke.json"
)

FULL = dict(clients=16, n_c=48, feat=16, hidden=32, ncls=8, steps=2,
            rounds=40, lr=0.1, ks=(2, 4, 8, 16), seeds=2, reps=3, spawns=2)
SMOKE = dict(clients=8, n_c=12, feat=8, hidden=16, ncls=4, steps=2,
             rounds=6, lr=0.1, ks=(2, 8), seeds=1, reps=4, spawns=2)
STRATEGIES = ("fl-dp3s", "fedavg", "fedsae")


def _pinned_devices(w: dict, smoke: bool) -> int:
    """Device count the child is pinned to: 1 in smoke (a deterministic
    harness check whatever the environment forces), else the largest divisor
    of C the physical cores can host."""
    if smoke:
        return 1
    cores = os.cpu_count() or 1
    c = w["clients"]
    return max(d for d in range(1, min(cores, c) + 1) if c % d == 0)


def _federation(w: dict):
    """Class-skewed non-IID clients over Gaussian class clusters: client c's
    labels concentrate on classes {c, c+1} mod ncls, so per-client mean
    features (the profiles) carry the skew the DPP kernel diversifies over."""
    import numpy as np

    rng = np.random.default_rng(7)
    c, n_c, feat, ncls = w["clients"], w["n_c"], w["feat"], w["ncls"]
    means = rng.normal(scale=2.0, size=(ncls, feat)).astype(np.float32)
    xs = np.empty((c, n_c, feat), np.float32)
    ys = np.empty((c, n_c), np.int32)
    for ci in range(c):
        major = np.asarray([ci % ncls, (ci + 1) % ncls])
        probs = np.full((ncls,), 0.2 / ncls)
        probs[major] += 0.4
        labels = rng.choice(ncls, size=(n_c,), p=probs / probs.sum())
        xs[ci] = means[labels] + rng.normal(size=(n_c, feat)).astype(np.float32)
        ys[ci] = labels
    return xs, ys, means


def _child_run(w: dict, n_shards: int) -> dict:
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core import dpp as dpp_lib
    from repro.core import make_strategy
    from repro.core import similarity as similarity_lib
    from repro.fl import engine
    from repro.launch.mesh import make_client_mesh

    assert jax.device_count() == n_shards, (jax.device_count(), n_shards)
    c, ncls = w["clients"], w["ncls"]
    xs_np, ys_np, _ = _federation(w)
    xs, ys = jnp.asarray(xs_np), jnp.asarray(ys_np)

    def loss_fn(p, x, y):
        h = jax.nn.relu(x @ p["w1"] + p["b1"])
        logp = jax.nn.log_softmax(h @ p["w2"] + p["b2"])
        return -jnp.mean(jnp.take_along_axis(logp, y[..., None], axis=-1))

    def init_params(seed):
        rng = np.random.default_rng(100 + seed)
        return {
            "w1": jnp.asarray(
                0.1 * rng.normal(size=(w["feat"], w["hidden"])).astype(np.float32)
            ),
            "b1": jnp.zeros((w["hidden"],), jnp.float32),
            "w2": jnp.asarray(
                0.1 * rng.normal(size=(w["hidden"], ncls)).astype(np.float32)
            ),
            "b2": jnp.zeros((ncls,), jnp.float32),
        }

    mesh = make_client_mesh(n_shards)
    strategies = tuple(make_strategy(s) for s in STRATEGIES)

    by_k = {}
    throughput = {}
    for k in w["ks"]:
        cfg = engine.FLConfig(
            num_clients=c, clients_per_round=k, local_epochs=w["steps"],
            lr=w["lr"], rounds=w["rounds"], eval_every=10 * w["rounds"],
            num_classes=ncls, seed=0, cohort_cap=k,
        )
        states = []
        for seed in range(w["seeds"]):
            params = init_params(seed)
            profiles = xs.mean(axis=1)
            kernel = similarity_lib.kernel_from_profiles(profiles)
            losses0 = jax.jit(jax.vmap(loss_fn, in_axes=(None, 0, 0)))(
                params, xs, ys
            )
            for si, strat in enumerate(strategies):
                eig = (
                    dpp_lib.kdpp_sampler_state(kernel, k)
                    if getattr(strat, "uses_spectral_cache", False)
                    else dpp_lib.identity_sampler_state(c, k)
                )
                states.append(engine.init_server_state(
                    cfg, params, loss_fn, None, xs, ys, strategy=strat,
                    strategy_index=si, key=jax.random.key(1000 * seed + si),
                    profiles=profiles, kernel=kernel, losses=losses0,
                    eig_state=eig,
                ))
        stacked = engine.stack_states(states)
        rf = engine.make_round_fn(cfg, loss_fn, strategies, mesh=mesh)
        out = engine.run_many(rf, stacked, w["rounds"], mesh=mesh)
        jax.block_until_ready(out)  # compile + warm
        best = float("inf")
        for _ in range(w["reps"]):
            t0 = time.perf_counter()
            _, outs = engine.run_many(rf, stacked, w["rounds"], mesh=mesh)
            jax.block_until_ready(outs)
            best = min(best, time.perf_counter() - t0)
        throughput[str(k)] = len(states) * w["rounds"] / best

        runs = engine.unstack_outputs(outs)
        floors = [float(np.min(r["loss"])) for r in runs]
        target = max(floors)
        per_strategy = {}
        for si, name in enumerate(STRATEGIES):
            arm = [runs[seed * len(strategies) + si]
                   for seed in range(w["seeds"])]
            rtt = []
            for r in arm:
                best_loss = np.minimum.accumulate(
                    np.asarray(r["loss"], np.float64)
                )
                hit = np.nonzero(best_loss <= target)[0]
                rtt.append(int(hit[0]) + 1 if hit.size else w["rounds"])
            per_strategy[name] = dict(
                rounds_to_target=float(np.mean(rtt)),
                final_loss=float(np.mean([np.min(r["loss"]) for r in arm])),
                mean_gemd=float(np.mean([np.mean(r["gemd"]) for r in arm])),
            )
        by_k[str(k)] = dict(k=k, target_loss=target, per_strategy=per_strategy)
    return dict(
        by_k=by_k, throughput_rounds_per_sec=throughput,
        workload=dict(w, model="mlp(2-layer)", strategies=STRATEGIES,
                      n_shards=n_shards),
    )


def _spawn(w: dict, n_shards: int) -> dict:
    env = dict(os.environ)
    flags = re.sub(
        r"--xla_force_host_platform_device_count=\d+", "",
        env.get("XLA_FLAGS", ""),
    ).strip()
    env["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={n_shards} " + flags
    ).strip()
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.cohort_sweep", "--child",
         json.dumps(dict(workload=w, n_shards=n_shards))],
        env=env, capture_output=True, text=True, timeout=1800,
    )
    if proc.returncode != 0:
        raise RuntimeError(
            f"cohort_sweep child failed:\n{proc.stdout}\n{proc.stderr}"
        )
    return json.loads(proc.stdout.splitlines()[-1])


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes (CI harness check)")
    ap.add_argument("--child", default=None, help=argparse.SUPPRESS)
    args = ap.parse_args(argv)

    if args.child is not None:
        spec = json.loads(args.child)
        spec["workload"]["ks"] = tuple(spec["workload"]["ks"])
        print(json.dumps(_child_run(spec["workload"], spec["n_shards"])))
        return None

    from benchmarks import common

    t0 = time.time()
    w = SMOKE if args.smoke else FULL
    n_shards = _pinned_devices(w, args.smoke)
    res = _spawn(w, n_shards)
    # convergence results are deterministic across spawns; throughput is
    # best-of across independent children (shared-container scheduling noise
    # swings single child measurements — same treatment as shard_bench)
    for _ in range(w.get("spawns", 1) - 1):
        again = _spawn(w, n_shards)
        for kk, rps in again["throughput_rounds_per_sec"].items():
            res["throughput_rounds_per_sec"][kk] = max(
                res["throughput_rounds_per_sec"][kk], rps
            )
    for kk in sorted(res["by_k"], key=int):
        rec = res["by_k"][kk]
        row = "  ".join(
            f"{n}={rec['per_strategy'][n]['rounds_to_target']:.1f}r"
            for n in STRATEGIES
        )
        print(f"  cohort_sweep k={int(kk):3d} target={rec['target_loss']:.4f} "
              f"{row} ({res['throughput_rounds_per_sec'][kk]:.1f} "
              f"scan-rounds/s)")
    payload = dict(
        bench="cohort_size_rounds_to_target",
        smoke=args.smoke,
        host_cores=os.cpu_count() or 1,
        total_s=round(time.time() - t0, 2),
        **res,
    )
    out_path = SMOKE_OUT_PATH if args.smoke else OUT_PATH
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=2)
    ks = sorted(res["by_k"], key=int)
    dpp_rtt = {k: res["by_k"][k]["per_strategy"]["fl-dp3s"]["rounds_to_target"]
               for k in ks}
    print(common.csv_line(
        "cohort_sweep",
        0.0,
        "fl-dp3s rounds-to-target by k: "
        + " ".join(f"k{k}={dpp_rtt[k]:.1f}" for k in ks),
    ))
    print(f"wrote {os.path.abspath(out_path)}")
    return payload


if __name__ == "__main__":
    main()

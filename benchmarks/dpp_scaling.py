"""k-DPP selection cost vs federation size C (server-side per-round work).

The paper's selection runs once per round on the server; this bench shows
the split the spectral cache buys (see ``benchmarks/dpp_bench.py`` for the
scanned-engine view): the one-shot draw pays the O(C³) ``eigh`` every call,
the cached draw (``sample_kdpp_from_eigh``) is O(k²·C) and stays in the
microsecond-to-millisecond range far past C = 1024 clients."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core import dpp, similarity


def _time_us(fn, *args, iters=10):
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6


def main():
    rng = np.random.default_rng(0)
    for c in (50, 100, 256, 512, 1024):
        f = jnp.asarray(rng.normal(size=(c, 64)).astype(np.float32))
        kern = similarity.kernel_from_profiles(f)
        k = max(2, c // 10)
        eig = dpp.kdpp_sampler_state(kern, k)
        jax.block_until_ready(eig)
        us_oneshot = _time_us(
            lambda key: dpp.sample_kdpp(key, kern, k), jax.random.key(0)
        )
        us_cached = _time_us(
            lambda key: dpp.sample_kdpp_from_eigh(key, eig, k), jax.random.key(0)
        )
        us_map = _time_us(lambda: dpp.greedy_map_kdpp(kern, k), iters=3)
        print(
            common.csv_line(
                f"dpp_sample_C{c}_k{k}",
                us_cached,
                f"oneshot_us={us_oneshot:.0f},greedy_map_us={us_map:.0f}",
            )
        )


if __name__ == "__main__":
    main()

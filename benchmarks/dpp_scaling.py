"""k-DPP selection cost vs federation size C (server-side per-round work).

The paper's selection runs once per round on the server; this bench shows it
stays in the microsecond-to-millisecond range up to C = 1024 clients — i.e.
negligible against a training round."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core import dpp, similarity


def main():
    rng = np.random.default_rng(0)
    for c in (50, 100, 256, 512, 1024):
        f = jnp.asarray(rng.normal(size=(c, 64)).astype(np.float32))
        kern = similarity.kernel_from_profiles(f)
        k = max(2, c // 10)
        sample = jax.jit(lambda key, kk=kern, k=k: dpp.sample_kdpp(key, kk, k))
        out = sample(jax.random.key(0))
        jax.block_until_ready(out)
        t0 = time.perf_counter()
        iters = 10
        for i in range(iters):
            jax.block_until_ready(sample(jax.random.key(i)))
        us = (time.perf_counter() - t0) / iters * 1e6
        t0 = time.perf_counter()
        jax.block_until_ready(dpp.greedy_map_kdpp(kern, k))
        us_map = (time.perf_counter() - t0) * 1e6
        print(common.csv_line(f"dpp_sample_C{c}_k{k}", us, f"greedy_map_us={us_map:.0f}"))


if __name__ == "__main__":
    main()

"""Fig. 6: accuracy under four parameter-initialisation schemes (ξ = 1).

Paper claim: FL-DP³S performance is consistent across init schemes, while
FedAvg is sensitive to them.  Report the across-init std of final accuracy.
"""

from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.configs.paper_cnn import INIT_SCHEMES


def run(quiet=False):
    exp = common.scale()
    finals = {m: [] for m in ("fl-dp3s", "fedavg")}
    for m in finals:
        for scheme in INIT_SCHEMES:
            h = common.run_case("synth-mnist", 1.0, m, 0, exp, init_scheme=scheme)
            best = max(h["acc"])
            finals[m].append(best)
            if not quiet:
                print(f"  fig6 {m:8s} init={scheme:16s} best={best:.3f}")
    return finals


def main():
    finals = run()
    stds = {m: float(np.std(v)) for m, v in finals.items()}
    means = {m: float(np.mean(v)) for m, v in finals.items()}
    derived = (
        f"dp3s_mean={means['fl-dp3s']:.3f}±{stds['fl-dp3s']:.3f} "
        f"fedavg_mean={means['fedavg']:.3f}±{stds['fedavg']:.3f} "
        f"dp3s_more_robust={stds['fl-dp3s'] <= stds['fedavg']}"
    )
    print(common.csv_line("fig6_init_robustness", 0.0, derived))
    return finals


if __name__ == "__main__":
    main()

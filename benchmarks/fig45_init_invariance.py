"""Figs. 4-5: client profiles vary with the parameter-initialisation scheme,
but the similarity kernel is (nearly) init-invariant.

Reported: mean pairwise correlation between the kernels produced under the
four init schemes (paper: "imperceptible" differences → corr ≈ 1), against
the much lower correlation between raw profile matrices.
"""

from __future__ import annotations

import itertools
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.configs.paper_cnn import INIT_SCHEMES
from repro.core import kernel_from_profiles, profile_all_clients
from repro.data import make_image_dataset, skewness_partition
from repro.models import cnn


def run(quiet=False):
    exp = common.scale()
    c = 20  # paper's Fig-4/5 scenario uses C = 20
    ds = make_image_dataset(n=c * exp.samples_per_client, seed=11, noise=0.5)
    shards = skewness_partition(ds.ys, c, 1.0, 10,
                                samples_per_client=exp.samples_per_client, seed=0)
    cxs = [jnp.asarray(ds.xs[s]) for s in shards]

    profiles, kernels = {}, {}
    t0 = time.time()
    for scheme in INIT_SCHEMES:
        params = cnn.init_cnn(jax.random.key(7), channels=exp.cnn_channels,
                              fc1_dim=exp.fc1_dim, scheme=scheme)
        f = profile_all_clients(jax.jit(cnn.apply_with_features), params, cxs)
        profiles[scheme] = np.asarray(f)
        kernels[scheme] = np.asarray(kernel_from_profiles(f))
    wall = time.time() - t0

    def mean_corr(mats, center_cols=False):
        cs = []
        for a, b in itertools.combinations(mats, 2):
            if center_cols:
                # remove the per-neuron mean over clients: what remains is the
                # *client-distinguishing* structure (the paper's Fig-4 point
                # is that this part is init-dependent)
                a = a - a.mean(0, keepdims=True)
                b = b - b.mean(0, keepdims=True)
            cs.append(np.corrcoef(a.ravel(), b.ravel())[0, 1])
        return float(np.mean(cs))

    prof_corr = mean_corr(list(profiles.values()), center_cols=True)
    kern_corr = mean_corr(list(kernels.values()))
    if not quiet:
        print(f"  fig45 profile_corr={prof_corr:.3f} kernel_corr={kern_corr:.3f}")
    return dict(profile_corr=prof_corr, kernel_corr=kern_corr, wall=wall)


def main():
    r = run()
    derived = (
        f"kernel_corr={r['kernel_corr']:.3f} profile_corr={r['profile_corr']:.3f} "
        f"kernel_init_invariant={r['kernel_corr'] > 0.95}"
    )
    print(common.csv_line("fig45_init_invariance", r["wall"] * 1e6, derived))
    return r


if __name__ == "__main__":
    main()

"""Telemetry overhead: both engines with the DESIGN.md §14 obs layer on vs off.

Prices what ``FLConfig.telemetry=True`` + a live :class:`repro.obs.TelemetrySink`
cost on the two hot paths:

  * train — the scanned federation (paper CNN, fl-dp3s selection so the DPP
    spectrum / cache-age / funnel diagnostics are all live) with telemetry
    compiled into the round program AND the host-side JSONL drain inside the
    timed region, vs the identical workload with ``telemetry=False``.  The
    telemetry leaves are a handful of scalar reductions over values the round
    already computes, and the drain happens once per scan chunk — so the
    rounds/sec cost must stay in the noise.
  * serve — continuous batching (smollm reduced, mixed-length seeded traffic)
    through one :class:`~repro.serve.ServeEngine` with a sink (TTFT syncs +
    per-chunk timing + JSONL writes) vs an identical engine with
    ``telemetry=None``.  The sink adds one ``block_until_ready`` per admission
    and per decode chunk — host-side only, so the compiled-program set must
    stay exactly ``{decode_chunk: 1, admit: 1}`` (asserted, smoke included).

Headline gates (full mode only; within-run ratios):

  * train: telemetry-on rounds/sec >= 0.95x off (<= 5% overhead);
  * serve: telemetry-on aggregate tok/s >= 0.97x off (<= 3% overhead);
  * zero recompiles with the sink attached (always enforced — it's free).

Writes ``BENCH_obs.json`` (repo root); ``--smoke`` runs tiny shapes with no
overhead gate and writes ``BENCH_obs_smoke.json`` (CI + check_regression
input — absolute throughputs of all four arms are regression-tracked):

    PYTHONPATH=src python -m benchmarks.obs_bench [--smoke]
"""

from __future__ import annotations

import argparse
import json
import os
import tempfile
import time

OUT_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_obs.json")
SMOKE_OUT_PATH = os.path.join(
    os.path.dirname(__file__), "..", "BENCH_obs_smoke.json"
)

# train arm: the engine-bench compute scale (paper CNN at regular width),
# funneled fl-dp3s so the candidate-survival + DPP-spectrum diagnostics are
# all live.  A timed run is ~2 s, so the drain's per-round microseconds are
# measured against real round compute, not scheduler jitter; the drain's
# absolute cost is ALSO reported (drain_us_per_round) so the selection-bound
# regime — where rounds are ~ms and the drain fraction is largest — can be
# priced from the same JSON.
FULL = dict(
    num_clients=16, samples_per_client=20, clients_per_round=4, rounds=100,
    hw=14, channels=(4, 8), fc1_dim=32, candidate_frac=0.75, reps=5,
    serve=dict(batch=8, prompt=16, gen=32, requests=32, chunk=8, reps=8),
)
SMOKE = dict(
    num_clients=8, samples_per_client=2, clients_per_round=2, rounds=10,
    hw=8, channels=(1, 2), fc1_dim=8, candidate_frac=0.75, reps=2,
    serve=dict(batch=3, prompt=6, gen=8, requests=6, chunk=2, reps=2),
)
TRAIN_OVERHEAD_MAX = 0.05   # telemetry-on >= 0.95x off rounds/sec
SERVE_OVERHEAD_MAX = 0.03   # telemetry-on >= 0.97x off tok/s
SHORT_FRAC = 0.8            # serve traffic: 80% short / 20% full budgets


def _paired(fn_off, fn_on, reps: int):
    """(median wall_off, median wall_on, overhead) with the off/on arms
    INTERLEAVED and the overhead taken as the median of per-pair wall
    ratios: adjacent runs share the box's load conditions, so a load spike
    inflates both arms of a pair and cancels in its ratio — a best-of or
    ratio-of-means estimator instead hands whichever arm got the one quiet
    window a few spurious percent, which is the size of the gate."""
    import numpy as np

    walls = {"off": [], "on": []}
    for _ in range(reps):
        for name, fn in (("off", fn_off), ("on", fn_on)):
            t0 = time.perf_counter()
            fn()
            walls[name].append(time.perf_counter() - t0)
    ratios = [a / b for a, b in zip(walls["off"], walls["on"])]
    return (
        float(np.median(walls["off"])),
        float(np.median(walls["on"])),
        1.0 - float(np.median(ratios)),
    )


def _bench_train(w: dict) -> dict:
    import jax
    import numpy as np

    from repro.core import make_strategy
    from repro.data import make_image_dataset, skewness_partition
    from repro.fl import FLConfig, FLTrainer, engine
    from repro.models import cnn
    from repro.obs import TelemetrySink
    from repro.obs import sink as obs_sink

    ds = make_image_dataset(
        n=w["num_clients"] * w["samples_per_client"], seed=11,
        h=w["hw"], w=w["hw"],
    )
    shards = skewness_partition(
        ds.ys, w["num_clients"], 1.0, 10,
        samples_per_client=w["samples_per_client"], seed=0,
    )
    cxs = np.stack([ds.xs[s] for s in shards])
    cys = np.stack([ds.ys[s] for s in shards])
    rounds = w["rounds"]

    def trainer(telemetry: bool) -> FLTrainer:
        params = cnn.init_cnn(
            jax.random.key(0), in_hw=(w["hw"], w["hw"]),
            channels=w["channels"], fc1_dim=w["fc1_dim"],
        )
        cfg = FLConfig(
            num_clients=w["num_clients"],
            clients_per_round=w["clients_per_round"],
            rounds=rounds, local_epochs=1, lr=0.08, eval_every=rounds,
            seed=0, candidate_frac=w["candidate_frac"], telemetry=telemetry,
        )
        return FLTrainer(
            cfg, params, cnn.cnn_loss, cnn.apply_with_features, cxs, cys,
            make_strategy("fl-dp3s"), accuracy_fn=cnn.accuracy,
        )

    # both arms share the data/strategy; only cfg.telemetry differs, so the
    # off arm compiles the exact pre-PR round program (bit-identity contract)
    tr_off, tr_on = trainer(False), trainer(True)
    fn_off_r, fn_on_r = tr_off.round_fn(), tr_on.round_fn()
    st_off, st_on = tr_off.server_state(), tr_on.server_state()

    with tempfile.TemporaryDirectory() as d:
        with TelemetrySink(os.path.join(d, "t.jsonl")) as sink:
            # the drain rides inside the timed region — the gate prices the
            # sink's host cost, not just the compiled telemetry leaves
            def run_off():
                jax.block_until_ready(
                    engine.run_scanned(fn_off_r, st_off, rounds)[1]
                )

            def run_on():
                jax.block_until_ready(
                    engine.run_scanned(fn_on_r, st_on, rounds, sink=sink)[1]
                )

            run_off(), run_on()  # warmup compiles
            wall_off, wall_on, overhead = _paired(run_off, run_on, w["reps"])
            events = sink.event_counts.get("fl_round", 0)

            # absolute drain cost, timed in isolation: what one fl_round
            # event costs the host, independent of this workload's round size
            _, outs = engine.run_scanned(fn_on_r, st_on, rounds)
            jax.block_until_ready(outs)
            t0 = time.perf_counter()
            obs_sink.drain_fl_outputs(sink, outs)
            drain_us = (time.perf_counter() - t0) / rounds * 1e6

    return dict(
        rounds=rounds,
        rounds_per_sec=dict(off=rounds / wall_off, on=rounds / wall_on),
        overhead=overhead,
        drain_us_per_round=round(drain_us, 1),
        fl_round_events_per_run=events // (w["reps"] + 1),  # warmup + reps
    )


def _bench_serve(w: dict) -> dict:
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import get_arch
    from repro.models import transformer as T
    from repro.obs import TelemetrySink
    from repro.serve import ServeConfig, ServeEngine

    cfg = get_arch("smollm-360m").model.reduced(
        param_dtype="float32", dtype="float32", remat=False,
    )
    params = T.init_params(jax.random.key(0), cfg)
    b, p, g, n = w["batch"], w["prompt"], w["gen"], w["requests"]
    prompts = np.asarray(jax.random.randint(
        jax.random.key(1), (n, p), 0, cfg.vocab_size, jnp.int32
    ))
    rng = np.random.default_rng(0)
    budgets = np.where(
        rng.random(n) < SHORT_FRAC,
        rng.integers(max(1, g // 4), max(2, g // 2), size=n),
        g,
    ).astype(int)
    scfg = ServeConfig(batch=b, cache_len=p + g, max_new=g,
                       decode_chunk=w["chunk"])

    def traffic(eng: ServeEngine) -> int:
        eng.reset()
        for i in range(n):
            eng.submit(prompts[i], int(budgets[i]))
        finished = eng.run()
        return sum(len(f.tokens) for f in finished)

    with tempfile.TemporaryDirectory() as d:
        with TelemetrySink(os.path.join(d, "s.jsonl")) as sink:
            eng_off = ServeEngine(cfg, scfg, params, prompt_len=p,
                                  key=jax.random.key(0))
            eng_on = ServeEngine(cfg, scfg, params, prompt_len=p,
                                 key=jax.random.key(0), telemetry=sink)
            toks = traffic(eng_off)
            assert traffic(eng_on) == toks  # warmup compiles + parity
            wall_off, wall_on, overhead = _paired(
                lambda: traffic(eng_off), lambda: traffic(eng_on), w["reps"]
            )
            compiles = eng_on.compile_counts()
            events = dict(sink.event_counts)

    arms = dict(off=toks / wall_off, on=toks / wall_on)
    zero_recompile = compiles == {"decode_chunk": 1, "admit": 1}
    return dict(
        requests=n,
        tokens=int(budgets.sum()),
        toks_per_sec=dict(off=arms["off"], on=arms["on"]),
        overhead=overhead,
        compiles=compiles,
        zero_recompile=bool(zero_recompile),
        events={k: events.get(k, 0) for k in
                ("serve_submit", "serve_admit", "serve_chunk",
                 "serve_finish")},
    )


def main(argv=None):
    from benchmarks import common

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes, no overhead gate (CI harness)")
    args = ap.parse_args(argv)
    w = SMOKE if args.smoke else FULL
    t0 = time.perf_counter()

    train = _bench_train(w)
    print(
        f"  obs_bench[train] off={train['rounds_per_sec']['off']:.1f} r/s "
        f"on={train['rounds_per_sec']['on']:.1f} r/s "
        f"overhead={train['overhead']:+.1%}"
    )
    serve = _bench_serve(w["serve"])
    print(
        f"  obs_bench[serve] off={serve['toks_per_sec']['off']:,.0f} tok/s "
        f"on={serve['toks_per_sec']['on']:,.0f} tok/s "
        f"overhead={serve['overhead']:+.1%} "
        f"zero_recompile={serve['zero_recompile']}"
    )

    gate_enforced = not args.smoke
    ok = serve["zero_recompile"]  # free — enforced in smoke too
    if gate_enforced:
        ok = (ok and train["overhead"] <= TRAIN_OVERHEAD_MAX
              and serve["overhead"] <= SERVE_OVERHEAD_MAX)

    payload = dict(
        bench="obs_telemetry_overhead",
        smoke=args.smoke,
        workload={k: v for k, v in w.items() if k != "serve"},
        serve_workload=w["serve"],
        host_cores=os.cpu_count() or 1,
        train=train,
        serve=serve,
        gates=dict(train_overhead_max=TRAIN_OVERHEAD_MAX,
                   serve_overhead_max=SERVE_OVERHEAD_MAX),
        gate_enforced=gate_enforced,
        gate_note=(
            "telemetry-on rounds/sec >= "
            f"{1 - TRAIN_OVERHEAD_MAX:.2f}x off on the funneled fl-dp3s "
            "federation (JSONL drain inside the timed region) and "
            f"telemetry-on tok/s >= {1 - SERVE_OVERHEAD_MAX:.2f}x off on "
            "mixed-length continuous traffic; the sink must not add "
            "compiled programs — compile_counts stays "
            "{decode_chunk: 1, admit: 1} (asserted in smoke too)"
        ),
        ok=bool(ok),
        total_s=round(time.perf_counter() - t0, 2),
    )
    out_path = SMOKE_OUT_PATH if args.smoke else OUT_PATH
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=2)
    print(common.csv_line(
        "obs_telemetry_overhead",
        0.0,
        f"train_overhead={train['overhead']:+.1%} "
        f"serve_overhead={serve['overhead']:+.1%} "
        f"zero_recompile={serve['zero_recompile']} "
        f"gate_enforced={gate_enforced} ok={ok}",
    ))
    print(f"ok={ok}  wrote {os.path.abspath(out_path)}")
    if not ok:
        raise SystemExit(1)
    return payload


if __name__ == "__main__":
    main()

"""Bench-throughput regression gate for CI.

Compares the ``*_smoke`` bench JSONs produced by the current checkout against
the baselines committed under ``benchmarks/baselines/`` and fails (exit 1)
when any throughput metric regresses by more than ``--tolerance`` (default
25%).  Metrics are one-sided: being faster than baseline never fails.
Comparisons only arm when the baseline was recorded on a host with the same
core count (see the MANIFEST note) — refresh baselines from the CI run's own
``BENCH_*.json`` artifacts to gate a runner class.

    PYTHONPATH=src python -m benchmarks.dpp_bench --smoke
    PYTHONPATH=src python -m benchmarks.shard_bench --smoke
    PYTHONPATH=src python -m benchmarks.check_regression

``--scale F`` multiplies every *current* metric by F before comparing — an
injected-slowdown hook: ``--scale 0.5`` must make the gate fail on a healthy
checkout, proving the gate actually bites (exercised by
``tests/test_bench_regression.py``).

Baselines are refreshed by re-running the smoke benches and copying the JSONs
into ``benchmarks/baselines/`` in the same PR that changes the performance.
"""

from __future__ import annotations

import argparse
import json
import os
from typing import Callable, Dict, List

REPO_ROOT = os.path.join(os.path.dirname(__file__), "..")
BASELINE_DIR = os.path.join(os.path.dirname(__file__), "baselines")
DEFAULT_TOLERANCE = 0.25


# Extractors return (metrics, host_cores).  EVERY throughput metric —
# absolute rounds/sec and within-run speedup ratios alike — is compared only
# when the baseline and the current run report the SAME host core count:
# absolute throughput obviously doesn't transfer across boxes, and neither
# do the ratios (the dev-N scaling ratio is ceilinged by core count, and the
# tiny-shape cached/baseline ratio is ~1.0 ± scheduler noise).  On mismatch
# the gate prints a loud note and passes — arm it by refreshing
# benchmarks/baselines/ from the CI workflow's own BENCH_*.json artifacts so
# the recorded hardware matches the runner class that gates.


def _dpp_metrics(payload: Dict):
    out = {}
    for c, row in payload.get("scanned_rounds_per_sec", {}).items():
        for variant in ("baseline", "cached"):
            if variant in row:
                out[f"scanned_rounds_per_sec.C{c}.{variant}"] = float(row[variant])
    return out, payload.get("host_cores")


def _shard_metrics(payload: Dict):
    out = {}
    for n, row in payload.get("by_devices", {}).items():
        out[f"rounds_per_sec.dev{n}"] = float(row["rounds_per_sec"])
    # capacity-slot sweep (DESIGN.md §8): both arms per cohort size, so the
    # slotted path's win can't silently regress back to resident-mode cost
    for kk, row in payload.get("k_sweep", {}).get("by_k", {}).items():
        for variant, rps in row.get("rounds_per_sec", {}).items():
            out[f"slot_rounds_per_sec.k{kk}.{variant}"] = float(rps)
    return out, payload.get("host_cores")


def _async_metrics(payload: Dict):
    # simulated wall-clock-to-target speedups (DESIGN.md §9): deterministic
    # given the seeded scenario draws, and core-count independent — but the
    # same-host_cores arming rule still applies uniformly (jax/XLA version
    # drift across runner classes can move the loss trajectories)
    out = {}
    for scen, row in payload.get("by_scenario", {}).items():
        if row.get("speedup") is not None:
            out[f"sim_speedup.{scen}"] = float(row["speedup"])
    return out, payload.get("host_cores")


def _funnel_metrics(payload: Dict):
    # two-stage funnel (DESIGN.md §10): selection-phase speedup per
    # federation size, plus both engine arms' scanned throughput so the
    # funneled round can't silently regress back to full-federation cost
    out = {}
    for c, row in payload.get("selection_phase", {}).items():
        out[f"funnel_speedup.C{c}"] = float(row["speedup"])
    for c, row in payload.get("engine_rounds_per_sec", {}).items():
        for variant in ("full", "funnel"):
            if variant in row:
                out[f"funnel_rounds_per_sec.C{c}.{variant}"] = float(row[variant])
    return out, payload.get("host_cores")


def _fault_metrics(payload: Dict):
    # fault-tolerance layer (DESIGN.md §11): scanned throughput of the clean
    # arm (must stay the PR-5/6 engine cost — faults=None compiles the same
    # program) and of the guarded trimmed_mean arm (the guard's norm screen +
    # masked psum must not silently blow up the round)
    out = {}
    for name in ("clean", "trimmed_faulty"):
        row = payload.get("arms", {}).get(name)
        if row is not None:
            out[f"fault_rounds_per_sec.{name}"] = float(row["rounds_per_sec"])
    return out, payload.get("host_cores")


def _serve_metrics(payload: Dict):
    # serving engine (DESIGN.md §13): per-arch prefill + decode tok/s for
    # both decode paths (legacy host loop must not rot — it's the parity
    # oracle — and the scan path must stay scan-fast), plus the continuous
    # vs drain-and-refill aggregate throughput pair
    out = {}
    for arch, row in payload.get("by_arch", {}).items():
        out[f"serve_prefill_toks_per_sec.{arch}"] = float(
            row["prefill_toks_per_sec"])
        for variant in ("legacy", "scan"):
            out[f"serve_decode_toks_per_sec.{arch}.{variant}"] = float(
                row[f"{variant}_decode_toks_per_sec"])
    cont = payload.get("continuous", {})
    for variant in ("continuous", "drain"):
        key = f"{variant}_toks_per_sec"
        if key in cont:
            out[f"serve_aggregate_toks_per_sec.{variant}"] = float(cont[key])
    return out, payload.get("host_cores")


def _cohort_metrics(payload: Dict):
    # steady-state run_many scan throughput of the slotted cohort sweep
    out = {}
    for kk, rps in payload.get("throughput_rounds_per_sec", {}).items():
        out[f"cohort_rounds_per_sec.k{kk}"] = float(rps)
    return out, payload.get("host_cores")


def _algo_metrics(payload: Dict):
    # local-algorithm axis (DESIGN.md §12): scan throughput per algorithm
    # row — the registry indirection must stay free for fedavg, and the
    # stateful feddyn rows must not silently blow up the round program
    out = {}
    for row, rps in payload.get("throughput_rounds_per_sec", {}).items():
        out[f"algo_rounds_per_sec.{row}"] = float(rps)
    return out, payload.get("host_cores")


def _obs_metrics(payload: Dict):
    # telemetry layer (DESIGN.md §14): both arms of both engines — the off
    # arms guard the underlying engine throughput and the on arms guard the
    # sink/drain cost, so telemetry can't silently grow a fixed tax that the
    # obs_bench overhead gate (full mode only) wouldn't catch in CI smoke
    out = {}
    for variant, rps in payload.get("train", {}).get(
            "rounds_per_sec", {}).items():
        out[f"obs_train_rounds_per_sec.{variant}"] = float(rps)
    for variant, tps in payload.get("serve", {}).get(
            "toks_per_sec", {}).items():
        out[f"obs_serve_toks_per_sec.{variant}"] = float(tps)
    return out, payload.get("host_cores")


# every smoke bench JSON the gate knows how to read; a file listed here that
# exists in baselines/ but was not produced by the current run is itself a
# failure (the harness rotted)
MANIFEST: Dict[str, Callable] = {
    "BENCH_dpp_smoke.json": _dpp_metrics,
    "BENCH_shard_smoke.json": _shard_metrics,
    "BENCH_async_smoke.json": _async_metrics,
    "BENCH_cohort_smoke.json": _cohort_metrics,
    "BENCH_algo_smoke.json": _algo_metrics,
    "BENCH_funnel_smoke.json": _funnel_metrics,
    "BENCH_fault_smoke.json": _fault_metrics,
    "BENCH_serve_smoke.json": _serve_metrics,
    "BENCH_obs_smoke.json": _obs_metrics,
}


def check(
    current_dir: str = REPO_ROOT,
    baseline_dir: str = BASELINE_DIR,
    tolerance: float = DEFAULT_TOLERANCE,
    scale: float = 1.0,
) -> List[str]:
    """Return a list of failure strings (empty == gate passes)."""
    failures: List[str] = []
    compared = 0
    for name, extract in MANIFEST.items():
        base_path = os.path.join(baseline_dir, name)
        cur_path = os.path.join(current_dir, name)
        if not os.path.exists(base_path):
            print(f"[check_regression] no baseline for {name}; skipping")
            continue
        if not os.path.exists(cur_path):
            failures.append(f"{name}: baseline exists but current run "
                            "produced no JSON (bench harness broken?)")
            continue
        with open(base_path) as f:
            base, base_cores = extract(json.load(f))
        with open(cur_path) as f:
            cur, cur_cores = extract(json.load(f))
        same_hw = base_cores is not None and base_cores == cur_cores
        if not same_hw:
            print(f"[check_regression] {name}: host cores differ "
                  f"(baseline={base_cores}, current={cur_cores}) — "
                  "skipping (refresh baselines from this runner's artifacts "
                  "to arm the gate)")
            continue
        for metric, ref in sorted(base.items()):
            if metric not in cur:
                failures.append(f"{name}:{metric}: missing from current run")
                continue
            compared += 1
            now = cur[metric] * scale
            floor = ref * (1.0 - tolerance)
            verdict = "ok" if now >= floor else "REGRESSED"
            print(f"[check_regression] {name}:{metric}: "
                  f"baseline={ref:.2f} current={now:.2f} "
                  f"floor={floor:.2f} {verdict}")
            if now < floor:
                failures.append(
                    f"{name}:{metric}: {now:.2f} < {floor:.2f} "
                    f"(baseline {ref:.2f}, tolerance {tolerance:.0%})"
                )
    print(f"[check_regression] {compared} metrics compared, "
          f"{len(failures)} failures")
    return failures


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--current-dir", default=REPO_ROOT,
                    help="directory holding the current BENCH_*_smoke.json")
    ap.add_argument("--baseline-dir", default=BASELINE_DIR)
    ap.add_argument(
        "--tolerance", type=float,
        default=float(os.environ.get("REGRESSION_TOLERANCE",
                                     DEFAULT_TOLERANCE)),
        help="max allowed fractional throughput drop (default 0.25; "
             "REGRESSION_TOLERANCE env overrides)",
    )
    ap.add_argument("--scale", type=float, default=1.0,
                    help="multiply current metrics by F (slowdown-injection "
                         "test hook; --scale 0.5 must fail)")
    args = ap.parse_args(argv)
    failures = check(args.current_dir, args.baseline_dir,
                     args.tolerance, args.scale)
    if failures:
        for f in failures:
            print(f"FAIL: {f}")
        raise SystemExit(1)
    print("bench regression gate: PASS")


if __name__ == "__main__":
    main()

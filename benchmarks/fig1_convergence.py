"""Fig. 1: accuracy vs training rounds — FL-DP³S vs Cluster/FedAvg/FedSAE
across heterogeneity levels ξ ∈ {0.5, 0.8, H, 1} on both datasets.

Paper claim: FL-DP³S outperforms all baselines and the margin grows with ξ.
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks import common
from repro.configs.paper_cnn import METHODS, XIS


def run(datasets=None, xis=XIS, methods=METHODS, quiet=False):
    exp = common.scale()
    datasets = datasets or list(common.DATASETS)
    # one shared multi-strategy scan program fills every missing grid case
    common.prefill_grid(datasets, xis, methods, exp)
    rows = []
    for ds in datasets:
        for xi in xis:
            for m in methods:
                accs = []
                t0 = time.time()
                for seed in range(exp.seeds):
                    h = common.run_case(ds, xi, m, seed, exp)
                    accs.append(h["acc"])
                mean = np.mean(accs, axis=0)
                rounds = common.run_case(ds, xi, m, 0, exp)["round"]
                rows.append(dict(dataset=ds, xi=str(xi), method=m,
                                 rounds=rounds, acc=mean.tolist(),
                                 final=float(mean[-1]), best=float(mean.max()),
                                 wall=time.time() - t0))
                if not quiet:
                    print(f"  fig1 {ds} xi={xi} {m:10s} final={mean[-1]:.3f} "
                          f"best={mean.max():.3f}")
    return rows


def main():
    rows = run()
    # claim check: at high skew DP3S ends highest
    t0 = time.time()
    for ds in common.DATASETS:
        # best-over-trajectory: late-round full-batch local-SGD instabilities
        # (loss spikes after convergence) would otherwise dominate "final"
        bests = {
            r["method"]: r["best"] for r in rows if r["dataset"] == ds and r["xi"] == "1.0"
        }
        best = max(bests, key=bests.get)
        derived = f"xi=1 winner={best} best_acc=" + "/".join(
            f"{m}:{bests[m]:.3f}" for m in sorted(bests)
        )
        print(common.csv_line(f"fig1_convergence[{ds}]", (time.time() - t0) * 1e6, derived))
    return rows


if __name__ == "__main__":
    main()

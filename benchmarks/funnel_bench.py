"""Two-stage funnel benchmark (DESIGN.md §10): selection-phase speedup,
engine rounds/sec, cohort-quality parity, and million-client scaling.

Four sections:

* **selection_phase** — the cost the funnel attacks, end to end: build the
  eq.-(14) kernel, decompose it (the k-DPP spectral cache), draw R cohorts.
  The *full* arm does it on the C×C kernel (O(C³) eigh); the *funnel* arm
  prefilters to Q candidates first and lives on the Q×Q block.  The recorded
  gate: ``speedup >= 5x`` at C=4096, Q=512.
* **engine_rounds_per_sec** — the same comparison inside the scanned
  federation round (selection + local updates + aggregation + metrics), so
  the funnel's win is measured against everything it does NOT touch.  At
  Q=C the two arms must pick **bit-identical cohorts** — asserted.
* **gemd_parity** — cohort quality: mean GEMD of the funneled cohorts on a
  class-skewed federation must sit within 5% of full-DPP (recorded gate at
  C=4096, Q=512).
* **scaling** — C up to 2¹⁸ synthetic clients through the funnel selection
  phase.  A C×C fp32 kernel at C=2¹⁸ would be 256 GiB: completing at all is
  the memory proof, and where XLA exposes ``memory_analysis`` the peak temp
  bytes are recorded and asserted ≪ C².

Writes ``BENCH_funnel.json`` (repo root).  ``--smoke`` runs tiny shapes with
no perf assertions (CI keeps the harness from rotting):

    PYTHONPATH=src python -m benchmarks.funnel_bench [--smoke]
"""

from __future__ import annotations

import argparse
import functools
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dpp, metrics, selection, similarity
from repro.fl import engine

OUT_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_funnel.json")
SMOKE_OUT_PATH = os.path.join(
    os.path.dirname(__file__), "..", "BENCH_funnel_smoke.json"
)

FEAT, N_C, NUM_CLASSES = 16, 4, 8


def linear_loss(params, x, y):
    logits = x @ params["w"] + params["b"]
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, y[..., None], axis=-1))


def make_federation(c: int, seed: int = 0, skew: float = 0.8):
    """Class-skewed federation (ξ-style: one dominant class per client) —
    the regime where cohort GEMD actually separates selection strategies."""
    rng = np.random.default_rng(seed)
    xs = jnp.asarray(rng.normal(size=(c, N_C, FEAT)).astype(np.float32))
    dominant = np.arange(c) % NUM_CLASSES
    probs = np.full((c, NUM_CLASSES), (1.0 - skew) / (NUM_CLASSES - 1))
    probs[np.arange(c), dominant] = skew
    ys = np.stack([rng.choice(NUM_CLASSES, size=N_C, p=probs[i]) for i in range(c)])
    params = {
        "w": jnp.asarray(0.01 * rng.normal(size=(FEAT, NUM_CLASSES)).astype(np.float32)),
        "b": jnp.zeros((NUM_CLASSES,), jnp.float32),
    }
    return xs, jnp.asarray(ys, jnp.int32), params


# ------------------------------------------------------- selection phase


@functools.partial(jax.jit, static_argnames=("k", "draws"))
def full_selection_phase(profiles, keys, k: int, draws: int):
    """Unfunneled: C×C eq.-(14) kernel -> O(C³) spectral cache -> R draws."""
    kern = similarity.kernel_from_profiles(profiles)
    eig = dpp.kdpp_sampler_state(kern, k)
    return jax.vmap(lambda kk: dpp.sample_kdpp_from_eigh(kk, eig, k))(keys)


@functools.partial(jax.jit, static_argnames=("q", "k", "draws"))
def funnel_selection_phase(profiles, losses, keys, q: int, k: int, draws: int):
    """Funneled: O(C) prefilter -> Q×Q kernel -> O(Q³) cache -> R draws,
    gathered back to global ids.  Exactly the engine's funnel_fields data
    path, minus the mesh plumbing."""
    cand = selection.funnel_candidates(selection.funnel_scores(losses), q)
    fq = jnp.take(profiles, cand, axis=0)
    kern = similarity.kernel_from_profiles(fq)
    eig = dpp.kdpp_sampler_state(kern, k)
    local = jax.vmap(lambda kk: dpp.sample_kdpp_from_eigh(kk, eig, k))(keys)
    return jnp.take(cand, local)


def _best_of(fn, reps: int):
    jax.block_until_ready(fn())  # compile + warm
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best


def bench_selection_phase(c: int, q: int, k: int, draws: int) -> dict:
    rng = np.random.default_rng(0)
    profiles = jnp.asarray(rng.normal(size=(c, FEAT)).astype(np.float32))
    losses = jnp.asarray(rng.uniform(0.5, 2.0, size=(c,)).astype(np.float32))
    keys = jax.random.split(jax.random.key(0), draws)
    reps = 1 if c >= 2048 else 3
    t_full = _best_of(lambda: full_selection_phase(profiles, keys, k, draws), reps)
    t_fun = _best_of(
        lambda: funnel_selection_phase(profiles, losses, keys, q, k, draws), reps
    )
    return {
        "Q": q, "k": k, "draws": draws,
        "full_ms": t_full * 1e3,
        "funnel_ms": t_fun * 1e3,
        "speedup": t_full / t_fun,
    }


# ------------------------------------------------------- engine rounds/sec


def _engine_run(c, k, rounds, frac, xs, ys, params):
    cfg = engine.FLConfig(
        num_clients=c, clients_per_round=k, local_epochs=1, lr=0.1,
        rounds=rounds, eval_every=10, num_classes=NUM_CLASSES, seed=0,
        candidate_frac=frac,
    )
    strat = selection.DPPSelection()
    state = engine.init_server_state(
        cfg, params, linear_loss, None, xs, ys,
        strategy=strat, profiles=xs.mean(axis=1),
    )
    fn = engine.make_round_fn(cfg, linear_loss, (strat,))
    return cfg, state, fn


def _timed_scan(round_fn, state, rounds, reps):
    out = engine.run_scanned(round_fn, state, rounds)
    jax.block_until_ready(out)
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        out = engine.run_scanned(round_fn, state, rounds)
        jax.block_until_ready(out)
        best = min(best, time.perf_counter() - t0)
    return best, out[1]


def bench_engine(c: int, q_frac: float, k: int, rounds: int) -> dict:
    xs, ys, params = make_federation(c)
    row = {"rounds": rounds, "Q": None, "gemd": {}}
    outs = {}
    reps = 3 if c <= 256 else 1
    for name, frac in (("full", None), ("funnel", q_frac)):
        cfg, state, fn = _engine_run(c, k, rounds, frac, xs, ys, params)
        if frac is not None:
            row["Q"] = cfg.candidate_count()
        dt, out = _timed_scan(fn, state, rounds, reps)
        row[name] = rounds / dt
        outs[name] = out
        row["gemd"][name] = float(np.mean(np.asarray(out["gemd"])))
    row["speedup"] = row["funnel"] / row["full"]
    g_full, g_fun = row["gemd"]["full"], row["gemd"]["funnel"]
    row["gemd_rel_gap"] = abs(g_fun - g_full) / max(abs(g_full), 1e-12)
    return row


def assert_q_equals_c_bit_identical(c: int, k: int, rounds: int) -> bool:
    """In-bench parity: frac=1.0 must select the SAME cohorts as no funnel."""
    xs, ys, params = make_federation(c)
    sel = {}
    for name, frac in (("full", None), ("funnel", 1.0)):
        _, state, fn = _engine_run(c, k, rounds, frac, xs, ys, params)
        _, out = engine.run_scanned(fn, state, rounds)
        sel[name] = np.asarray(out["selected"])
    ok = bool(np.array_equal(sel["full"], sel["funnel"]))
    assert ok, f"C={c}: Q=C funnel cohorts diverged from unfunneled"
    return ok


# ------------------------------------------------------- cohort quality


def bench_gemd_parity(
    c: int, q: int, k: int, draws: int, noise: float
) -> dict:
    """Mean GEMD (eq. 15) of funneled vs full-DPP cohorts over many draws.

    Clients get well-resolved class-skewed label distributions and profiles
    that are those distributions + ``noise`` — so the eq.-(14) kernel
    genuinely encodes class similarity and the k-DPP's diversity shows up
    as lower GEMD (the paper's mechanism; Theorem 1's premise is exactly
    that FC-1 profiles are clean distribution fingerprints).  The gated
    row uses the clean-fingerprint regime: there the funnel-vs-full gap
    is a property of the *funnel*, not of fingerprint noise — with noisy
    profiles BOTH arms degrade toward uniform and the relative gap on a
    near-zero quantity is noise-dominated (recorded ungated for context,
    together with each arm's improvement retention over uniform).
    ``draws`` independent cohorts per arm keep the estimator tight enough
    for a 5% gate (a handful of engine rounds is far too noisy)."""
    rng = np.random.default_rng(2)
    skew = 0.8
    base = np.full(
        (c, NUM_CLASSES), (1.0 - skew) / (NUM_CLASSES - 1), np.float32
    )
    base[np.arange(c), np.arange(c) % NUM_CLASSES] = skew
    d = base + noise * np.abs(
        rng.normal(size=(c, NUM_CLASSES))
    ).astype(np.float32)
    d /= d.sum(axis=1, keepdims=True)
    label_dists = jnp.asarray(d)
    global_dist = label_dists.mean(axis=0)
    profiles = jnp.asarray(
        d + noise * rng.normal(size=(c, NUM_CLASSES)).astype(np.float32)
    )
    losses = jnp.asarray(rng.uniform(0.5, 2.0, size=(c,)).astype(np.float32))
    sizes = jnp.full((c,), float(N_C))
    keys = jax.random.split(jax.random.key(2), draws)
    g = jax.jit(jax.vmap(metrics.gemd, in_axes=(None, None, 0, None)))
    sel_full = full_selection_phase(profiles, keys, k, draws)
    sel_fun = funnel_selection_phase(profiles, losses, keys, q, k, draws)
    sel_uni = jax.vmap(
        lambda kk: jax.random.choice(kk, c, shape=(k,), replace=False)
    )(keys)
    m_full = float(jnp.mean(g(label_dists, sizes, sel_full, global_dist)))
    m_fun = float(jnp.mean(g(label_dists, sizes, sel_fun, global_dist)))
    m_uni = float(jnp.mean(g(label_dists, sizes, sel_uni, global_dist)))
    span = max(m_uni - m_full, 1e-12)
    return {
        "Q": q, "k": k, "draws": draws, "noise": noise,
        "uniform": m_uni,
        "full": m_full,
        "funnel": m_fun,
        "rel_gap": abs(m_fun - m_full) / max(abs(m_full), 1e-12),
        # fraction of full-DPP's GEMD win over uniform the funnel keeps
        "improvement_retention": (m_uni - m_fun) / span,
    }


# ----------------------------------------------------------- scaling


def bench_scaling(c: int, q: int, k: int, draws: int) -> dict:
    """Funnel selection phase at federation scale C — profiles are the only
    C-sized tensor (C·F floats); everything kernel-shaped is Q×Q."""
    rng = np.random.default_rng(1)
    profiles = jnp.asarray(rng.normal(size=(c, FEAT)).astype(np.float32))
    losses = jnp.asarray(rng.uniform(0.5, 2.0, size=(c,)).astype(np.float32))
    keys = jax.random.split(jax.random.key(1), draws)
    lowered = funnel_selection_phase.lower(profiles, losses, keys, q, k, draws)
    compiled = lowered.compile()
    row = {"Q": q, "draws": draws}
    cxc_bytes = float(c) * float(c) * 4.0
    try:
        mem = compiled.memory_analysis()
        peak = int(mem.temp_size_in_bytes) + int(mem.argument_size_in_bytes)
        row["peak_bytes"] = peak
        row["cxc_bytes"] = cxc_bytes
        row["no_cxc"] = peak < cxc_bytes
    except Exception:
        # backend doesn't expose the analysis: completing at C=2^18 (where a
        # C×C fp32 kernel alone is 256 GiB) is the memory proof
        row["peak_bytes"] = None
        row["no_cxc"] = True
    t0 = time.perf_counter()
    sel = jax.block_until_ready(compiled(profiles, losses, keys))
    row["funnel_ms"] = (time.perf_counter() - t0) * 1e3
    assert row["no_cxc"], (
        f"C={c}: funnel selection peaked at {row['peak_bytes']} bytes "
        f">= C*C*4 = {cxc_bytes:.0f}"
    )
    assert (np.asarray(sel) < c).all() and (np.asarray(sel) >= 0).all()
    return row


# ------------------------------------------------------------------ main


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--smoke", action="store_true",
        help="tiny shapes, no perf assertions (CI harness check)",
    )
    args = ap.parse_args(argv)

    k = 8
    if args.smoke:
        sel_grid = [(64, 16, 8)]           # (C, Q, draws)
        eng_grid = {32: (0.5, 2)}          # C -> (frac, rounds)
        parity_c, parity_rounds = 32, 2
        # (C, Q, draws, noise, gated) — smoke shapes never arm the gate
        gemd_grid = [(64, 16, 16, 0.005, False)]
        scale_grid = [(128, 16, 2)]
    else:
        sel_grid = [(1024, 256, 32), (4096, 512, 32)]
        eng_grid = {256: (0.25, 10), 1024: (0.25, 6), 4096: (0.125, 6)}
        parity_c, parity_rounds = 256, 6
        # gated: clean fingerprints (Theorem-1 regime); recorded: noisy
        gemd_grid = [(4096, 512, 192, 0.005, True), (4096, 512, 192, 0.02, False)]
        scale_grid = [(2 ** 14, 512, 8), (2 ** 16, 512, 8), (2 ** 18, 512, 8)]

    report = {
        "smoke": args.smoke,
        "backend": jax.default_backend(),
        "host_cores": os.cpu_count(),
        "k": k,
        "target_speedup": 5.0,
        "gemd_tolerance": 0.05,
        "selection_phase": {},
        "engine_rounds_per_sec": {},
        "gemd_parity": {},
        "scaling": {},
    }

    for c, q, draws in sel_grid:
        row = bench_selection_phase(c, q, k, draws)
        report["selection_phase"][str(c)] = row
        print(
            f"selection C={c:6d} Q={q:4d}: full={row['full_ms']:9.1f} ms  "
            f"funnel={row['funnel_ms']:8.1f} ms  speedup={row['speedup']:7.1f}x"
        )

    for c, (frac, rounds) in eng_grid.items():
        row = bench_engine(c, frac, k, rounds)
        report["engine_rounds_per_sec"][str(c)] = row
        print(
            f"engine    C={c:6d} Q={row['Q']:4d}: full={row['full']:8.2f} r/s  "
            f"funnel={row['funnel']:8.2f} r/s  speedup={row['speedup']:5.1f}x  "
            f"gemd full={row['gemd']['full']:.3f} funnel={row['gemd']['funnel']:.3f} "
            f"(gap {row['gemd_rel_gap']:.1%})"
        )

    report["q_equals_c_bit_identical"] = assert_q_equals_c_bit_identical(
        parity_c, k, parity_rounds
    )
    print(f"parity    C={parity_c}: Q=C cohorts bit-identical to unfunneled")

    for c, q, draws, noise, gated in gemd_grid:
        row = bench_gemd_parity(c, q, k, draws, noise)
        row["gated"] = gated
        report["gemd_parity"][f"C{c}_noise{noise}"] = row
        print(
            f"gemd      C={c:6d} Q={q:4d} noise={noise}: "
            f"uniform={row['uniform']:.4f}  full={row['full']:.4f}  "
            f"funnel={row['funnel']:.4f}  gap={row['rel_gap']:.1%}  "
            f"retention={row['improvement_retention']:.1%} "
            f"({draws} draws{', gated' if gated else ''})"
        )

    for c, q, draws in scale_grid:
        row = bench_scaling(c, q, k, draws)
        report["scaling"][str(c)] = row
        peak = row["peak_bytes"]
        print(
            f"scaling   C={c:6d} Q={q:4d}: funnel={row['funnel_ms']:8.1f} ms  "
            f"peak={peak if peak is not None else 'n/a'} bytes  "
            f"no_cxc={row['no_cxc']}"
        )

    # recorded acceptance gates (dpp_bench-style): smoke shapes never reach
    # the gated sizes, so smoke's ok reduces to the parity/no-C×C asserts
    sel_gate = [
        r for c, r in report["selection_phase"].items() if int(c) >= 4096
    ]
    gemd_gate = [r for r in report["gemd_parity"].values() if r["gated"]]
    report["ok"] = (
        report["q_equals_c_bit_identical"]
        and all(r["no_cxc"] for r in report["scaling"].values())
        and all(r["speedup"] >= report["target_speedup"] for r in sel_gate)
        and all(r["rel_gap"] <= report["gemd_tolerance"] for r in gemd_gate)
    )
    if not report["ok"]:
        for c, r in report["selection_phase"].items():
            if int(c) >= 4096 and r["speedup"] < report["target_speedup"]:
                print(f"FAIL: selection speedup at C={c} below 5x: "
                      f"{r['speedup']:.1f}")
        for name, r in report["gemd_parity"].items():
            if r["gated"] and r["rel_gap"] > report["gemd_tolerance"]:
                print(f"FAIL: GEMD gap at {name} above 5%: "
                      f"{r['rel_gap']:.1%}")

    out_path = SMOKE_OUT_PATH if args.smoke else OUT_PATH
    with open(out_path, "w") as fh:
        json.dump(report, fh, indent=2)
    print(f"ok={report['ok']}  wrote {os.path.abspath(out_path)}")
    if not args.smoke and not report["ok"]:
        raise SystemExit(1)


if __name__ == "__main__":
    main()

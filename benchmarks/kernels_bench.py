"""Kernel microbenchmarks: µs/call for the three Pallas kernels vs their
pure-jnp oracles.

On this CPU container the Pallas bodies run in interpret mode, so absolute
timings characterise the *oracle* path and interpretation overhead — the
purpose here is the per-call CSV contract plus a correctness spot check;
TPU timings come from the roofline model (§Roofline)."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.kernels.flash_attention import ops as flash_ops
from repro.kernels.flash_attention import ref as flash_ref
from repro.kernels.pairwise_l2 import ops as pw_ops
from repro.kernels.pairwise_l2 import ref as pw_ref
from repro.kernels.rwkv6_scan import ops as wkv_ops
from repro.kernels.rwkv6_scan import ref as wkv_ref


def _time(fn, *args, iters=5):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6, out


def main():
    rng = np.random.default_rng(0)

    # pairwise_l2 at the paper's scale: C=100 clients, Q=128 profile dims
    f = jnp.asarray(rng.normal(size=(100, 128)).astype(np.float32))
    us_k, out_k = _time(pw_ops.pairwise_sq_dists, f)
    us_r, out_r = _time(jax.jit(pw_ref.pairwise_sq_dists_ref), f)
    err = float(jnp.max(jnp.abs(out_k - out_r * (1 - jnp.eye(100)))))
    print(common.csv_line("kernel_pairwise_l2_C100xQ128", us_k,
                          f"ref_us={us_r:.1f} max_err={err:.1e}"))

    # flash attention, prefill-ish tile
    q = jnp.asarray(rng.normal(size=(1, 256, 4, 64)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(1, 256, 2, 64)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(1, 256, 2, 64)).astype(np.float32))
    us_k, out_k = _time(lambda *a: flash_ops.flash_attention(*a), q, k, v, iters=2)
    us_r, out_r = _time(jax.jit(flash_ref.attention_ref), q, k, v)
    err = float(jnp.max(jnp.abs(out_k - out_r)))
    print(common.csv_line("kernel_flash_attn_s256_gqa", us_k,
                          f"ref_us={us_r:.1f} max_err={err:.1e}"))

    # rwkv6 scan
    r = jnp.asarray(rng.normal(size=(1, 128, 2, 64)).astype(np.float32))
    kk = jnp.asarray(rng.normal(size=(1, 128, 2, 64)).astype(np.float32))
    vv = jnp.asarray(rng.normal(size=(1, 128, 2, 64)).astype(np.float32))
    w = jnp.asarray(rng.uniform(0.5, 0.99, size=(1, 128, 2, 64)).astype(np.float32))
    u = jnp.asarray(rng.normal(size=(2, 64)).astype(np.float32))
    s0 = jnp.zeros((1, 2, 64, 64), jnp.float32)
    us_k, out_k = _time(lambda *a: wkv_ops.wkv6(*a), r, kk, vv, w, u, s0, iters=2)
    us_r, out_r = _time(jax.jit(wkv_ref.wkv6_scan_ref), r, kk, vv, w, u, s0)
    err = float(jnp.max(jnp.abs(out_k[0] - out_r[0])))
    print(common.csv_line("kernel_rwkv6_scan_T128", us_k,
                          f"ref_us={us_r:.1f} max_err={err:.1e}"))


if __name__ == "__main__":
    main()

"""Spectral-cache benchmark: scanned FL-DP³S rounds/sec, eigh-per-round vs
the cached O(k²·C) draw, plus fused-vs-jnp kernel-build latency.

The workload is the engine's scanned federation round (selection → local
step → aggregation → loss refresh → GEMD) on a deliberately tiny linear
model, so the measurement isolates the *selection* cost the spectral cache
amortises: the baseline (``DPPSelection(use_cache=False)``) re-runs the
O(C³) ``eigh`` inside every scanned round, the cached path
(``DPPSelection()``) draws from the ``ServerState.eig_state`` computed once
at init.  Both paths must pick **bit-identical cohorts** for the same keys —
asserted per federation size.

Writes ``BENCH_dpp.json`` (repo root).  ``--smoke`` runs tiny shapes with no
perf assertions (CI keeps the harness from rotting):

    PYTHONPATH=src python -m benchmarks.dpp_bench [--smoke]
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dpp, selection, similarity
from repro.fl import engine

# smoke mode writes to a separate path so the CI harness check can never
# clobber a real full-run benchmark record
OUT_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_dpp.json")
SMOKE_OUT_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_dpp_smoke.json")

FEAT, N_C, NUM_CLASSES = 16, 4, 4


def linear_loss(params, x, y):
    logits = x @ params["w"] + params["b"]
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, y[..., None], axis=-1))


def build_state(c: int, k: int, seed: int = 0) -> engine.ServerState:
    """A selection-bound ServerState: tiny linear model, C clients."""
    rng = np.random.default_rng(seed)
    xs = jnp.asarray(rng.normal(size=(c, N_C, FEAT)).astype(np.float32))
    ys = jnp.asarray(rng.integers(0, NUM_CLASSES, size=(c, N_C)), jnp.int32)
    params = {
        "w": jnp.asarray(0.01 * rng.normal(size=(FEAT, NUM_CLASSES)).astype(np.float32)),
        "b": jnp.zeros((NUM_CLASSES,), jnp.float32),
    }
    profiles = xs.mean(axis=1)
    kernel = similarity.kernel_from_profiles(profiles)
    label_dists = jax.nn.one_hot(ys, NUM_CLASSES).mean(axis=1)
    losses = jax.vmap(linear_loss, in_axes=(None, 0, 0))(params, xs, ys)
    return engine.ServerState(
        params=params,
        key=jax.random.key(seed),
        round=jnp.asarray(0, jnp.int32),
        losses=losses,
        kernel=kernel,
        profiles=profiles,
        eig_state=dpp.kdpp_sampler_state(kernel, k),
        cluster_labels=jnp.zeros((c,), jnp.int32),
        client_xs=xs,
        client_ys=ys,
        client_sizes=jnp.full((c,), float(N_C)),
        client_label_dists=label_dists,
        global_label_dist=label_dists.mean(axis=0),
        strategy_index=jnp.asarray(0, jnp.int32),
    )


def _timed_scan(round_fn, state, rounds, reps: int = 1):
    """Compile (warm run), then time ``reps`` scanned executions (best-of)."""
    out = engine.run_scanned(round_fn, state, rounds)
    jax.block_until_ready(out)
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        out = engine.run_scanned(round_fn, state, rounds)
        jax.block_until_ready(out)
        best = min(best, time.perf_counter() - t0)
    return best, out[1]


def bench_rounds(c: int, k: int, rounds: int) -> dict:
    cfg = engine.FLConfig(
        num_clients=c, clients_per_round=k, local_epochs=1, lr=0.1,
        rounds=rounds, eval_every=10, num_classes=NUM_CLASSES, seed=0,
    )
    state = build_state(c, k)
    jax.block_until_ready(state)
    fns = {
        name: engine.make_round_fn(cfg, linear_loss, (strat,))
        for name, strat in (
            ("baseline", selection.DPPSelection(use_cache=False)),
            ("cached", selection.DPPSelection()),
        )
    }
    row = {"rounds": rounds}
    selected = {}
    reps = 5 if c <= 256 else 1  # small-C runs are fast but noisy
    for name, fn in fns.items():
        dt, outs = _timed_scan(fn, state, rounds, reps=reps)
        row[name] = rounds / dt
        selected[name] = np.asarray(outs["selected"])
    row["speedup"] = row["cached"] / row["baseline"]
    # same keys, same kernel -> the cached draw must pick identical cohorts
    row["bit_identical"] = bool(
        np.array_equal(selected["baseline"], selected["cached"])
    )
    assert row["bit_identical"], f"C={c}: cached selections diverged from baseline"
    return row


def bench_kernel_build(c: int, q: int) -> dict:
    from repro.kernels.gram import ops as gram_ops

    rng = np.random.default_rng(0)
    f = jnp.asarray(rng.normal(size=(c, q)).astype(np.float32))
    jnp_fn = jax.jit(lambda x: similarity.kernel_from_profiles(x))
    out = {"C": c, "Q": q, "interpret_mode": jax.default_backend() != "tpu"}
    for name, fn in (("jnp", jnp_fn), ("fused_pallas", gram_ops.kernel_from_profiles)):
        jax.block_until_ready(fn(f))
        t0 = time.perf_counter()
        jax.block_until_ready(fn(f))
        out[f"{name}_ms"] = (time.perf_counter() - t0) * 1e3
    # numerical contract, always checked
    err = float(
        jnp.max(jnp.abs(gram_ops.kernel_from_profiles(f) - jnp_fn(f)))
    )
    out["max_abs_err"] = err
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--smoke", action="store_true",
        help="tiny shapes, no perf assertions (CI harness check)",
    )
    args = ap.parse_args(argv)

    k = 8
    if args.smoke:
        grid = {16: 2, 32: 2}
        kb = bench_kernel_build(32, 16)
    else:
        grid = {64: 20, 256: 10, 1024: 4, 4096: 2}
        kb = bench_kernel_build(256, 64)

    report = {
        "smoke": args.smoke,
        "backend": jax.default_backend(),
        "host_cores": os.cpu_count(),
        "k": k,
        "scanned_rounds_per_sec": {},
        "kernel_build_ms": kb,
    }
    for c, rounds in grid.items():
        row = bench_rounds(c, k, rounds)
        report["scanned_rounds_per_sec"][str(c)] = row
        print(
            f"C={c:5d}  baseline={row['baseline']:8.2f} r/s  "
            f"cached={row['cached']:8.2f} r/s  speedup={row['speedup']:6.1f}x  "
            f"bit_identical={row['bit_identical']}"
        )
    # acceptance gate (recorded, engine_bench-style): >=5x at C >= 512 with
    # bit-identical cohorts everywhere — smoke shapes never reach the gate
    report["target_speedup"] = 5.0
    gated = [
        row for c, row in report["scanned_rounds_per_sec"].items() if int(c) >= 512
    ]
    report["ok"] = all(
        r["bit_identical"] for r in report["scanned_rounds_per_sec"].values()
    ) and all(r["speedup"] >= report["target_speedup"] for r in gated)
    if not report["ok"]:
        for c, row in report["scanned_rounds_per_sec"].items():
            if int(c) >= 512 and row["speedup"] < report["target_speedup"]:
                print(f"FAIL: speedup at C={c} below 5x: {row['speedup']:.1f}")
    print(
        f"kernel build C={kb['C']} Q={kb['Q']}: jnp={kb['jnp_ms']:.2f} ms, "
        f"fused={kb['fused_pallas_ms']:.2f} ms "
        f"(interpret={kb['interpret_mode']} — the fused win is a TPU story; "
        f"CPU runs the kernel body under the Pallas interpreter)"
    )
    out_path = SMOKE_OUT_PATH if args.smoke else OUT_PATH
    with open(out_path, "w") as fh:
        json.dump(report, fh, indent=2)
    print(f"ok={report['ok']}  wrote {os.path.abspath(out_path)}")
    if not args.smoke and not report["ok"]:
        raise SystemExit(1)


if __name__ == "__main__":
    main()

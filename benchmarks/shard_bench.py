"""Mesh-sharded cohort execution benchmark: rounds/sec vs device count.

Measures the scanned federation engine (DESIGN.md §8) on a selection-light,
full-participation workload (k = C, uniform selection, tiny MLP) where the
per-round cost is the cohort's local updates — the regime where the client
mesh axis should scale.  Each device count runs in its OWN subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` (the flag must be set
before jax initialises), so the 1-device baseline engine gets the whole
machine and every sharded config gets exactly its N virtual devices.

The max-device child also re-runs the single-device engine in-process and
asserts the sharded path picked **bit-identical cohorts** with fp32-close
final params (the parity contract of ``tests/test_shard_engine.py``).

A second sweep measures **capacity-slot scheduling** (DESIGN.md §8): at a
fixed device count, k runs from 1 to C comparing slotted
(``cohort_cap = k`` ⇒ ``cap = min(C_loc, k)`` local updates per shard)
against unslotted (``C_loc`` updates whatever the cohort) rounds/sec — the
expected win is ≈ C_loc/cap at small k, because slotting removes *work*,
not just parallelism.  The sweep child asserts slotted-vs-unslotted parity
(bit-identical cohorts, fp32-close params) at every k.

Writes ``BENCH_shard.json`` (repo root).  Two hardware-aware gates:
the ≥2x @ 8 devices *scaling* gate is enforced only when the host has ≥8
physical cores (virtual devices are threads, so wall-clock speedup is
capped at the core count — a 2-core container cannot express an 8-way win
and records ``gate_enforced: false``); the ≥2x *slot* gate (some cap ≤
C_loc/2 must run ≥2x the unslotted round) is enforced whenever the host
has at least as many cores as the sweep's device count, since a work
reduction shows up at any core count that can host the mesh.  Parity is
always enforced.  ``--smoke`` runs tiny shapes (device counts (1, 8) plus
a small-k slot case) with no perf gates and writes a separate
``BENCH_shard_smoke.json`` (CI harness + regression-check input):

    PYTHONPATH=src python -m benchmarks.shard_bench [--smoke]
"""

from __future__ import annotations

import argparse
import json
import os
import re
import subprocess
import sys
import time

OUT_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_shard.json")
SMOKE_OUT_PATH = os.path.join(
    os.path.dirname(__file__), "..", "BENCH_shard_smoke.json"
)

# selection-light full-participation workloads (k = C): per-round cost is the
# cohort's local SGD scans, psum'd FedAvg is the only cross-device traffic.
# spawns = independent child processes per device count (best-of across
# them): shared-container scheduling noise swings single measurements ~2x
FULL = dict(clients=8, n_c=64, feat=64, hidden=128, steps=32, rounds=10,
            reps=6, spawns=2, device_counts=(1, 2, 4, 8))
SMOKE = dict(clients=8, n_c=16, feat=16, hidden=32, steps=4, rounds=4,
             reps=2, spawns=1, device_counts=(1, 8))
# capacity-slot k-sweep: C_loc = clients/devices residents per shard; the
# slotted round should run ≈ C_loc/min(C_loc, k)× faster than unslotted
FULL_KSWEEP = dict(clients=16, n_c=64, feat=64, hidden=128, steps=32,
                   rounds=10, reps=4, spawns=2, devices=2, ks=(1, 2, 4, 8, 16))
SMOKE_KSWEEP = dict(clients=16, n_c=16, feat=16, hidden=32, steps=4,
                    rounds=4, reps=2, spawns=1, devices=2, ks=(2, 16))
TARGET_SPEEDUP = 2.0
GATE_DEVICES = 8
GATE_MIN_CORES = 8
SLOT_TARGET_SPEEDUP = 2.0  # at some cap <= C_loc/2


# ----------------------------------------------------------------- child


def _mlp_workload(w: dict):
    """Shared tiny-MLP federation for the bench children."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    c, n_c, feat, hid = w["clients"], w["n_c"], w["feat"], w["hidden"]
    ncls = 10
    rng = np.random.default_rng(0)
    xs = jnp.asarray(rng.normal(size=(c, n_c, feat)).astype(np.float32))
    ys = jnp.asarray(rng.integers(0, ncls, size=(c, n_c)), jnp.int32)
    params = {
        "w1": jnp.asarray(0.05 * rng.normal(size=(feat, hid)).astype(np.float32)),
        "b1": jnp.zeros((hid,), jnp.float32),
        "w2": jnp.asarray(0.05 * rng.normal(size=(hid, ncls)).astype(np.float32)),
        "b2": jnp.zeros((ncls,), jnp.float32),
    }

    def loss_fn(p, x, y):
        h = jax.nn.relu(x @ p["w1"] + p["b1"])
        logp = jax.nn.log_softmax(h @ p["w2"] + p["b2"])
        return -jnp.mean(jnp.take_along_axis(logp, y[..., None], axis=-1))

    return loss_fn, xs, ys, params, ncls


def _timed_run(round_fn, state, rounds: int, reps: int):
    """Warm (compile) once, then best-of-``reps`` wall time for one scanned
    run.  Returns ``(best_seconds, (final_state, outputs))`` — shared by
    both bench children so they measure the identical protocol."""
    import jax

    from repro.fl import engine

    out = engine.run_scanned(round_fn, state, rounds)
    jax.block_until_ready(out)  # compile + warm
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        out = engine.run_scanned(round_fn, state, rounds)
        jax.block_until_ready(out)
        best = min(best, time.perf_counter() - t0)
    return best, out


def _parity(ref, got) -> dict:
    """The bench parity contract (mirrors tests/test_{shard,slot}_engine.py):
    bit-identical cohorts, fp32-close final params.  ``ref``/``got`` are
    ``(final_state, outputs)`` pairs from :func:`_timed_run`."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    cohorts_ok = bool(np.array_equal(
        np.asarray(ref[1]["selected"]), np.asarray(got[1]["selected"])
    ))
    pdiff = max(
        float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
        for a, b in zip(jax.tree_util.tree_leaves(ref[0].params),
                        jax.tree_util.tree_leaves(got[0].params))
    )
    return dict(
        cohorts_bit_identical=cohorts_ok,
        max_param_diff=pdiff,
        ok=bool(cohorts_ok and pdiff < 1e-5),
    )


def _child(devices: int, w: dict, check_parity: bool) -> dict:
    import jax

    from repro.core import selection as selection_lib
    from repro.fl import engine
    from repro.launch.mesh import make_client_mesh

    assert jax.device_count() == devices, (jax.device_count(), devices)
    c = w["clients"]
    loss_fn, xs, ys, params, ncls = _mlp_workload(w)

    cfg = engine.FLConfig(
        num_clients=c, clients_per_round=c, local_epochs=w["steps"], lr=0.02,
        rounds=w["rounds"], eval_every=10 * w["rounds"], num_classes=ncls,
        seed=0,
    )
    strat = selection_lib.UniformSelection()
    state = engine.init_server_state(
        cfg, params, loss_fn, None, xs, ys, strategy=strat,
        profiles=xs.mean(axis=1),
    )
    mesh = make_client_mesh(devices) if devices > 1 else None
    round_fn = engine.make_round_fn(cfg, loss_fn, (strat,), mesh=mesh)
    rounds = w["rounds"]
    # lay the state out ONCE, outside the timed region — the measurement is
    # steady-state rounds/sec, not the one-time host->mesh transfer
    run_state = (
        engine.shard_server_state(state, mesh) if mesh is not None else state
    )

    wall, run = _timed_run(round_fn, run_state, rounds, w["reps"])
    rec = dict(devices=devices, wall_s=wall, rounds_per_sec=rounds / wall)

    if check_parity and mesh is not None:
        ref_fn = engine.make_round_fn(cfg, loss_fn, (strat,))
        rec["parity"] = _parity(engine.run_scanned(ref_fn, state, rounds), run)
    return rec


def _slot_child(devices: int, w: dict) -> dict:
    """Capacity-slot k-sweep: slotted vs unslotted sharded rounds/sec.

    One mesh, one state; for each cohort size k the same federation runs
    through the unslotted sharded round (C_loc local updates per shard) and
    the slot-compacted round (cohort_cap = k ⇒ cap = min(C_loc, k)).
    Selection is identical by construction, so parity is asserted on every
    k — the speedup must come purely from skipping zero-weight updates.
    """
    import dataclasses

    import jax

    from repro.core import selection as selection_lib
    from repro.fl import engine
    from repro.launch.mesh import make_client_mesh

    assert jax.device_count() == devices, (jax.device_count(), devices)
    c = w["clients"]
    loss_fn, xs, ys, params, ncls = _mlp_workload(w)
    mesh = make_client_mesh(devices)
    c_loc = c // devices
    rounds = w["rounds"]
    strat = selection_lib.UniformSelection()
    by_k = {}
    for k in w["ks"]:
        cfg = engine.FLConfig(
            num_clients=c, clients_per_round=k, local_epochs=w["steps"],
            lr=0.02, rounds=rounds, eval_every=10 * rounds, num_classes=ncls,
            seed=0,
        )
        state = engine.init_server_state(
            cfg, params, loss_fn, None, xs, ys, strategy=strat,
            profiles=xs.mean(axis=1),
        )
        run_state = engine.shard_server_state(state, mesh)
        rps, runs = {}, {}
        for name, cohort_cap in (("unslotted", None), ("slotted", k)):
            vcfg = dataclasses.replace(cfg, cohort_cap=cohort_cap)
            fn = engine.make_round_fn(vcfg, loss_fn, (strat,), mesh=mesh)
            best, runs[name] = _timed_run(fn, run_state, rounds, w["reps"])
            rps[name] = rounds / best
        by_k[str(k)] = dict(
            k=k, cap=min(c_loc, k),
            rounds_per_sec=rps,
            slot_speedup=rps["slotted"] / rps["unslotted"],
            parity=_parity(runs["unslotted"], runs["slotted"]),
        )
    return dict(devices=devices, clients=c, c_loc=c_loc, by_k=by_k)


# ---------------------------------------------------------------- parent


def _spawn_payload(devices: int, payload: dict) -> dict:
    env = dict(os.environ)
    flags = re.sub(
        r"--xla_force_host_platform_device_count=\d+", "",
        env.get("XLA_FLAGS", ""),
    ).strip()
    env["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={devices} " + flags
    ).strip()
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.shard_bench", "--child",
         json.dumps(payload)],
        env=env, capture_output=True, text=True, timeout=1200,
    )
    if proc.returncode != 0:
        raise RuntimeError(
            f"child (devices={devices}) failed:\n{proc.stdout}\n{proc.stderr}"
        )
    return json.loads(proc.stdout.splitlines()[-1])


def _spawn(devices: int, w: dict, check_parity: bool) -> dict:
    return _spawn_payload(
        devices, dict(devices=devices, workload=w, parity=check_parity)
    )


def _spawn_ksweep(w: dict) -> dict:
    return _spawn_payload(
        w["devices"],
        dict(mode="ksweep", devices=w["devices"], workload=w),
    )


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes, no perf gate (CI harness check)")
    ap.add_argument("--child", default=None, help=argparse.SUPPRESS)
    args = ap.parse_args(argv)

    if args.child is not None:
        spec = json.loads(args.child)
        if spec.get("mode") == "ksweep":
            print(json.dumps(_slot_child(spec["devices"], spec["workload"])))
        else:
            print(json.dumps(
                _child(spec["devices"], spec["workload"], spec["parity"])
            ))
        return None

    from benchmarks import common

    t0 = time.time()
    w = SMOKE if args.smoke else FULL
    kw = SMOKE_KSWEEP if args.smoke else FULL_KSWEEP
    cores = os.cpu_count() or 1
    max_dev = max(w["device_counts"])
    rows = {}
    for n in w["device_counts"]:
        rec = _spawn(n, w, check_parity=(n == max_dev))
        for _ in range(w.get("spawns", 1) - 1):
            again = _spawn(n, w, check_parity=False)
            if again["rounds_per_sec"] > rec["rounds_per_sec"]:
                if "parity" in rec:
                    again["parity"] = rec["parity"]
                rec = again
        rows[str(n)] = rec
        extra = ""
        if "parity" in rec:
            extra = (f"  parity_ok={rec['parity']['ok']} "
                     f"(cohorts={rec['parity']['cohorts_bit_identical']}, "
                     f"param_diff={rec['parity']['max_param_diff']:.2e})")
        print(f"  shard_bench devices={n}  "
              f"{rec['rounds_per_sec']:8.2f} rounds/s{extra}")

    base = rows["1"]["rounds_per_sec"]
    for rec in rows.values():
        rec["speedup_vs_1dev"] = rec["rounds_per_sec"] / base
        # virtual devices are host threads: ideal wall-clock speedup is
        # bounded by physical cores, whatever the device count
        rec["ideal_speedup"] = float(min(rec["devices"], cores))

    # ---- capacity-slot k-sweep (slotted vs unslotted at fixed devices) ----
    sweep = _spawn_ksweep(kw)
    for _ in range(kw.get("spawns", 1) - 1):
        again = _spawn_ksweep(kw)
        for kk, rec in sweep["by_k"].items():
            arec = again["by_k"][kk]
            for variant in rec["rounds_per_sec"]:
                rec["rounds_per_sec"][variant] = max(
                    rec["rounds_per_sec"][variant],
                    arec["rounds_per_sec"][variant],
                )
            rec["slot_speedup"] = (
                rec["rounds_per_sec"]["slotted"]
                / rec["rounds_per_sec"]["unslotted"]
            )
            # best-of applies to throughput only; parity must hold on EVERY
            # spawn that contributed a measurement
            p, ap = rec["parity"], arec["parity"]
            rec["parity"] = dict(
                cohorts_bit_identical=(p["cohorts_bit_identical"]
                                       and ap["cohorts_bit_identical"]),
                max_param_diff=max(p["max_param_diff"], ap["max_param_diff"]),
                ok=bool(p["ok"] and ap["ok"]),
            )
    c_loc = sweep["c_loc"]
    slot_parity_ok = all(r["parity"]["ok"] for r in sweep["by_k"].values())
    small_caps = [r for r in sweep["by_k"].values() if r["cap"] <= c_loc // 2]
    slot_speedup = max((r["slot_speedup"] for r in small_caps), default=0.0)
    # a slot win is WORK reduction, not parallelism: it shows at any core
    # count that can host the sweep's mesh (unlike the dev-scaling gate)
    slot_gate_enforced = (not args.smoke) and cores >= kw["devices"]
    for kk in sorted(sweep["by_k"], key=int):
        rec = sweep["by_k"][kk]
        print(f"  shard_bench slot k={kk:>3s} cap={rec['cap']}/{c_loc}  "
              f"unslotted={rec['rounds_per_sec']['unslotted']:8.2f} r/s  "
              f"slotted={rec['rounds_per_sec']['slotted']:8.2f} r/s  "
              f"speedup={rec['slot_speedup']:.2f}x "
              f"parity_ok={rec['parity']['ok']}")

    speedup = rows[str(max_dev)]["speedup_vs_1dev"]
    parity = rows[str(max_dev)].get("parity", {})
    gate_enforced = (not args.smoke) and cores >= GATE_MIN_CORES
    ok = bool(parity.get("ok", False)) and slot_parity_ok
    if gate_enforced:
        ok = ok and speedup >= TARGET_SPEEDUP
    if slot_gate_enforced:
        ok = ok and slot_speedup >= SLOT_TARGET_SPEEDUP

    payload = dict(
        bench="shard_engine_rounds_per_sec_vs_devices",
        smoke=args.smoke,
        workload=dict(w, model="mlp(2-layer)", selection="uniform-full-cohort"),
        host_cores=cores,
        target_speedup=TARGET_SPEEDUP,
        gate_devices=GATE_DEVICES,
        gate_enforced=gate_enforced,
        gate_note=(
            f"the >= {TARGET_SPEEDUP}x @ {GATE_DEVICES} virtual devices gate "
            f"needs >= {GATE_MIN_CORES} host cores (virtual devices are "
            "threads; speedup ceiling == cores); parity always enforced"
        ),
        speedup_at_max_devices=speedup,
        parity=parity,
        k_sweep=dict(
            sweep,
            workload=dict(kw, model="mlp(2-layer)", selection="uniform"),
            slot_target_speedup=SLOT_TARGET_SPEEDUP,
            slot_gate_enforced=slot_gate_enforced,
            slot_gate_note=(
                f"the >= {SLOT_TARGET_SPEEDUP}x slotted-vs-unslotted gate "
                f"(at some cap <= C_loc/2) needs >= {kw['devices']} host "
                "cores (slot compaction removes work, so it holds at any "
                "core count hosting the mesh); parity always enforced"
            ),
            best_small_cap_speedup=slot_speedup,
        ),
        ok=ok,
        by_devices=rows,
        total_s=round(time.time() - t0, 2),
    )
    out_path = SMOKE_OUT_PATH if args.smoke else OUT_PATH
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=2)
    print(common.csv_line(
        "shard_engine_scaling",
        0.0,
        f"speedup@{max_dev}dev={speedup:.2f}x cores={cores} "
        f"gate_enforced={gate_enforced} parity_ok={parity.get('ok')} "
        f"slot_speedup={slot_speedup:.2f}x "
        f"slot_gate_enforced={slot_gate_enforced} "
        f"slot_parity_ok={slot_parity_ok} ok={ok}",
    ))
    print(f"ok={ok}  wrote {os.path.abspath(out_path)}")
    if not ok:
        raise SystemExit(1)
    return payload


if __name__ == "__main__":
    main()

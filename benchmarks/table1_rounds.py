"""In-text table: rounds to reach the target accuracy at ξ = 1.

Paper (MNIST, 90%): FL-DP³S 62, Cluster 122, FedAvg 127, FedSAE 259 — i.e.
the *ordering* DP³S < Cluster ≈ FedAvg < FedSAE.  At bench scale we use the
max accuracy all methods reach (the ordering is the claim, not the absolute
round counts, which depend on scale)."""

from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.configs.paper_cnn import METHODS


def run(target=None, quiet=False):
    exp = common.scale()
    # one shared multi-strategy scan program fills every missing grid case
    common.prefill_grid(["synth-mnist"], [1.0], METHODS, exp)
    # choose a target all methods can reach at this scale
    hists = {
        m: [common.run_case("synth-mnist", 1.0, m, s, exp) for s in range(exp.seeds)]
        for m in METHODS
    }
    if target is None:
        target = 0.95 * min(
            np.mean([h["acc"][-1] for h in hs]) for hs in hists.values()
        )
    rounds = {}
    for m, hs in hists.items():
        rs = [common.rounds_to_accuracy(h, target) for h in hs]
        rs = [r if r is not None else exp.rounds * 2 for r in rs]
        rounds[m] = float(np.mean(rs))
        if not quiet:
            print(f"  table1 {m:10s} rounds_to_{target:.2f} = {rounds[m]:.0f}")
    return target, rounds


def main():
    target, rounds = run()
    order = sorted(rounds, key=rounds.get)
    derived = (
        f"target={target:.2f} order={'<'.join(order)} "
        + " ".join(f"{m}:{r:.0f}" for m, r in rounds.items())
        + f" dp3s_fastest={order[0] == 'fl-dp3s'}"
    )
    print(common.csv_line("table1_rounds_to_target", 0.0, derived))
    return rounds


if __name__ == "__main__":
    main()

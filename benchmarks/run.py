"""Benchmark harness — one entry per paper table/figure (+ kernels + DPP +
the engine/spectral-cache/sharding/staleness perf benches and the
cohort-size study, so ``--all`` covers every harness in the tree).

    PYTHONPATH=src python -m benchmarks.run            # full suite
    REPRO_BENCH_SCALE=tiny PYTHONPATH=src python -m benchmarks.run   # CI smoke

Prints ``name,us_per_call,derived`` CSV lines (harness contract).  FL runs
are cached in results/fl_grid.json, so figures sharing a grid (fig1/fig2/
table1) reuse each other's training runs.  At the tiny scale the perf
benches (dpp_bench, shard_bench) run in ``--smoke`` mode: harness coverage
without perf gates.
"""

from __future__ import annotations

import os
import sys
import time


def main() -> None:
    from benchmarks import (
        async_bench,
        cohort_sweep,
        dpp_bench,
        dpp_scaling,
        engine_bench,
        fault_bench,
        fig1_convergence,
        fig2_gemd,
        fig3_profiling,
        fig45_init_invariance,
        fig6_init_robustness,
        funnel_bench,
        kernels_bench,
        obs_bench,
        serve_bench,
        shard_bench,
        table1_rounds,
    )

    t0 = time.time()
    smoke = os.environ.get("REPRO_BENCH_SCALE") == "tiny"
    perf_args = ["--smoke"] if smoke else []
    gate_failures = []

    def gated(name, fn):
        # perf benches raise SystemExit when their recorded gate fails on
        # this hardware; record it, finish the figure suite, fail at the end
        try:
            fn()
        except SystemExit as e:
            if e.code:
                gate_failures.append(name)
                print(f"{name},0.0,perf gate FAILED (suite continues)",
                      file=sys.stderr)

    print("name,us_per_call,derived")
    kernels_bench.main()
    dpp_scaling.main()
    engine_bench.main()
    gated("dpp_bench", lambda: dpp_bench.main(perf_args))
    gated("shard_bench", lambda: shard_bench.main(perf_args))
    gated("async_bench", lambda: async_bench.main(perf_args))
    gated("funnel_bench", lambda: funnel_bench.main(perf_args))
    gated("fault_bench", lambda: fault_bench.main(perf_args))
    gated("serve_bench", lambda: serve_bench.main(perf_args))
    gated("obs_bench", lambda: obs_bench.main(perf_args))
    cohort_sweep.main(perf_args)
    gated("cohort_sweep_algos",
          lambda: cohort_sweep.main(["--algos"] + perf_args))
    fig45_init_invariance.main()
    fig1_convergence.main()
    fig2_gemd.main()
    table1_rounds.main()
    fig3_profiling.main()
    fig6_init_robustness.main()
    print(f"total_wall,{(time.time() - t0) * 1e6:.0f},benchmark suite complete",
          file=sys.stderr)
    if gate_failures:
        raise SystemExit(f"perf gates failed: {', '.join(gate_failures)}")


if __name__ == "__main__":
    main()

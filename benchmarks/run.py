"""Benchmark harness — one entry per paper table/figure (+ kernels + DPP).

    PYTHONPATH=src python -m benchmarks.run            # full suite
    REPRO_BENCH_SCALE=tiny PYTHONPATH=src python -m benchmarks.run   # CI smoke

Prints ``name,us_per_call,derived`` CSV lines (harness contract).  FL runs
are cached in results/fl_grid.json, so figures sharing a grid (fig1/fig2/
table1) reuse each other's training runs.
"""

from __future__ import annotations

import sys
import time


def main() -> None:
    from benchmarks import (
        dpp_scaling,
        engine_bench,
        fig1_convergence,
        fig2_gemd,
        fig3_profiling,
        fig45_init_invariance,
        fig6_init_robustness,
        kernels_bench,
        table1_rounds,
    )

    t0 = time.time()
    print("name,us_per_call,derived")
    kernels_bench.main()
    dpp_scaling.main()
    engine_bench.main()
    fig45_init_invariance.main()
    fig1_convergence.main()
    fig2_gemd.main()
    table1_rounds.main()
    fig3_profiling.main()
    fig6_init_robustness.main()
    print(f"total_wall,{(time.time() - t0) * 1e6:.0f},benchmark suite complete",
          file=sys.stderr)


if __name__ == "__main__":
    main()

"""Logical-axis sharding: parameter/cache PartitionSpec trees + activation
constraints (MaxText-style logical axis rules).

* ``param_logical_specs(cfg)`` mirrors ``transformer.init_params`` with an
  :class:`Ax` leaf (tuple of *logical* axis names) per tensor;
* ``rules`` (per arch × mode, see ``repro.configs``) map each logical name to
  a mesh axis (``'data'``, ``'model'``) or ``None`` (replicate); under the
  multi-pod mesh every ``'data'`` entry widens to ``('pod', 'data')``
  (:func:`resolve_axis`);
* activation constraints are installed with :func:`use_rules` (a context
  manager); model code calls :func:`constrain`, which is a no-op outside a
  rules context — single-device smoke tests never see sharding machinery.
"""

from __future__ import annotations

import contextlib
import contextvars
from typing import Dict, Optional

import jax
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig

__all__ = [
    "Ax",
    "ax",
    "use_rules",
    "constrain",
    "resolve_axis",
    "param_logical_specs",
    "cache_logical_specs",
    "specs_from_logical",
    "optimizer_state_specs",
    "CLIENT_AXIS",
    "client_axis_spec",
]

# Mesh axis name carrying the federation's client dimension (DESIGN.md §8).
# The FL engine shards ServerState's per-client fields over it and runs the
# local-update core as a shard_map; launchers build the mesh with
# ``repro.launch.mesh.make_client_mesh``.
CLIENT_AXIS = "clients"


def client_axis_spec(ndim: int, axis: str = CLIENT_AXIS, batch_dims: int = 0):
    """PartitionSpec sharding dimension ``batch_dims`` of a rank-``ndim``
    per-client array over the client mesh axis (leading batch dims, e.g. a
    ``stack_states`` grid axis, stay replicated)."""
    return P(*([None] * batch_dims), axis, *([None] * (ndim - batch_dims - 1)))


class Ax(tuple):
    """Marker leaf: the logical axis names of one tensor's dims."""


def ax(*names: Optional[str]) -> Ax:
    return Ax(names)


def _is_ax(x) -> bool:
    return isinstance(x, Ax)


_ACTIVE_RULES: contextvars.ContextVar[Optional[Dict]] = contextvars.ContextVar(
    "repro_sharding_rules", default=None
)


def resolve_axis(axis, multi_pod: bool):
    """'data' widens to ('pod', 'data') on the multi-pod mesh."""
    if axis == "data" and multi_pod:
        return ("pod", "data")
    return axis


def _resolve_rules(rules: Dict, multi_pod: bool) -> Dict:
    return {k: resolve_axis(v, multi_pod) for k, v in rules.items()}


@contextlib.contextmanager
def use_rules(rules: Dict, multi_pod: bool = False):
    """Install activation-constraint rules for model code running under jit."""
    token = _ACTIVE_RULES.set(_resolve_rules(rules, multi_pod))
    try:
        yield
    finally:
        _ACTIVE_RULES.reset(token)


def constrain(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    rules = _ACTIVE_RULES.get()
    if rules is None:
        return x
    spec = P(*(rules.get(l) if l else None for l in logical))
    return jax.lax.with_sharding_constraint(x, spec)


# --------------------------------------------------------------- param specs


def _attn_specs() -> Dict:
    return {
        "wq": {"w": ax("attn_in_w", "heads_w")},
        "wk": {"w": ax("attn_in_w", "kv_w")},
        "wv": {"w": ax("attn_in_w", "kv_w")},
        "wo": {"w": ax("heads_w", "attn_out_w")},
    }


def _mlp_specs(cfg: ModelConfig) -> Dict:
    if cfg.mlp_variant in ("swiglu", "geglu"):
        return {
            "wi": {"w": ax("embed_w", "mlp_w")},
            "wg": {"w": ax("embed_w", "mlp_w")},
            "wo": {"w": ax("mlp_w", "embed_w")},
        }
    return {"wi": {"w": ax("embed_w", "mlp_w")}, "wo": {"w": ax("mlp_w", "embed_w")}}


def _moe_specs(cfg: ModelConfig) -> Dict:
    s = {
        "router": {"w": ax("embed_w", None)},
        "wi": ax("experts_w", "expert_embed_w", "expert_mlp_w"),
        "wg": ax("experts_w", "expert_embed_w", "expert_mlp_w"),
        "wo": ax("experts_w", "expert_mlp_w", "expert_embed_w"),
    }
    if cfg.shared_expert:
        s["shared"] = _mlp_specs(cfg)
    return s


def _rglru_specs() -> Dict:
    return {
        "w_in": {"w": ax("embed_w", "rnn_w")},
        "w_gate": {"w": ax("embed_w", "rnn_w")},
        "w_out": {"w": ax("rnn_w", "embed_w")},
        "conv_w": ax(None, "rnn_w"),
        "conv_b": ax("rnn_w"),
        "w_r": {"w": ax(None, "rnn_w")},
        "b_r": ax("rnn_w"),
        "w_i": {"w": ax(None, "rnn_w")},
        "b_i": ax("rnn_w"),
        "lam": ax("rnn_w"),
    }


def _rwkv_tmix_specs() -> Dict:
    vec = ax("embed_w_vec")
    return {
        "mu_x": vec, "mu_w": vec, "mu_k": vec, "mu_v": vec, "mu_r": vec, "mu_g": vec,
        # decay path / per-head norm live in the attention (H·hd) dim, not the
        # residual stream — "att_vec_w" lets variants co-shard them with att_w
        # so the wkv inputs keep one consistent head sharding (see §Perf).
        "w0": ax("att_vec_w"),
        "a_w": ax("embed_w", None),
        "b_w": ax(None, "att_vec_w"),
        "u": ax(None, None),
        "wr": {"w": ax("embed_w", "att_w")},
        "wk": {"w": ax("embed_w", "att_w")},
        "wv": {"w": ax("embed_w", "att_w")},
        "wg": {"w": ax("embed_w", "att_w")},
        "wo": {"w": ax("att_w", "embed_w")},
        "ln_scale": ax("att_vec_w"),
    }


def _rwkv_cmix_specs() -> Dict:
    return {
        "mu_k": ax("embed_w_vec"),
        "mu_r": ax("embed_w_vec"),
        "wk": {"w": ax("embed_w", "mlp_w")},
        "wv": {"w": ax("mlp_w", "embed_w")},
        "wr": {"w": ax("embed_w", "att_w")},
    }


def _norm_specs(cfg: ModelConfig) -> Dict:
    s = {"scale": ax("embed_w_vec")}
    if cfg.norm_type == "layernorm":
        s["bias"] = ax("embed_w_vec")
    return s


def _block_specs(cfg: ModelConfig, btype: str) -> Dict:
    mixer, ffn = btype.split("+")
    out = {"norm1": _norm_specs(cfg), "norm2": _norm_specs(cfg)}
    out["mixer"] = (
        _attn_specs()
        if mixer in ("attn", "swa", "local")
        else _rglru_specs() if mixer == "rglru" else _rwkv_tmix_specs()
    )
    out["ffn"] = (
        _mlp_specs(cfg)
        if ffn == "mlp"
        else _moe_specs(cfg) if ffn == "moe" else _rwkv_cmix_specs()
    )
    return out


def _prepend(tree, axis):
    return jax.tree_util.tree_map(lambda t: Ax((axis,) + tuple(t)), tree, is_leaf=_is_ax)


def param_logical_specs(cfg: ModelConfig) -> Dict:
    pattern = cfg.block_pattern
    reps, rem = divmod(cfg.num_layers, len(pattern))
    specs: Dict = {
        "embed": {"w": ax("vocab_w", "embed_w")},
        "final_norm": _norm_specs(cfg),
    }
    if not cfg.tie_embeddings:
        specs["lm_head"] = {"w": ax("embed_w", "vocab_w")}
    specs["unit"] = tuple(_prepend(_block_specs(cfg, b), None) for b in pattern)
    specs["rem"] = tuple(_block_specs(cfg, pattern[j]) for j in range(rem))
    return specs


def cache_logical_specs(cfg: ModelConfig) -> Dict:
    def block_cache(btype: str, stacked: bool):
        mixer, _ = btype.split("+")
        if mixer in ("attn", "swa", "local"):
            c = {
                "k": ax("act_batch", "cache_seq", None, None),
                "v": ax("act_batch", "cache_seq", None, None),
                "pos": ax(),
            }
        elif mixer == "rglru":
            c = {
                "conv": ax("act_batch", None, "rnn_w"),
                "h": ax("act_batch", "rnn_w"),
                "pos": ax(),
            }
        else:  # rwkv (tmix + cmix states)
            c = {
                "tm_x": ax("act_batch", "embed_act"),
                "wkv": ax("act_batch", "rwkv_heads", None, None),
                "cm_x": ax("act_batch", "embed_act"),
                "pos": ax(),
            }
        if stacked:
            c = _prepend(c, None)
        return c

    pattern = cfg.block_pattern
    reps, rem = divmod(cfg.num_layers, len(pattern))
    return {
        "unit": tuple(block_cache(b, True) for b in pattern),
        "rem": tuple(block_cache(pattern[j], False) for j in range(rem)),
    }


def specs_from_logical(logical_tree, rules: Dict, multi_pod: bool = False):
    """Logical Ax leaves -> PartitionSpec tree under the given rules."""
    rr = _resolve_rules(rules, multi_pod)

    def to_spec(t: Ax):
        return P(*(rr.get(l) if l else None for l in t))

    return jax.tree_util.tree_map(to_spec, logical_tree, is_leaf=_is_ax)


def optimizer_state_specs(opt_name: str, param_specs):
    """PartitionSpec tree for optimizer state, derived from param specs."""
    from repro.optim.optimizers import _AdafactorState, _AdamState

    is_p = lambda s: isinstance(s, P)
    if opt_name == "sgd":
        return ()
    if opt_name in ("adam", "adamw"):
        return _AdamState(P(), param_specs, param_specs)
    if opt_name == "adafactor":
        vr = jax.tree_util.tree_map(
            lambda s: P(*s[:-1]) if len(s) >= 2 else s, param_specs, is_leaf=is_p
        )
        vc = jax.tree_util.tree_map(
            lambda s: P(*(tuple(s[:-2]) + (s[-1],))) if len(s) >= 2 else P(),
            param_specs,
            is_leaf=is_p,
        )
        return _AdafactorState(P(), vr, vc)
    raise ValueError(opt_name)

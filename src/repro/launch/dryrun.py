"""Multi-pod dry-run: prove every (arch × input shape × mesh) lowers,
compiles, fits, and report its roofline inputs — without real hardware.

    python -m repro.launch.dryrun --arch granite-3-2b --shape train_4k
    python -m repro.launch.dryrun --sweep --out results/dryrun.jsonl
    python -m repro.launch.dryrun --arch mixtral-8x7b --shape decode_32k --multi-pod

The first two lines below force 512 host platform devices; this module must
therefore never be imported by tests/benches directly (they spawn it as a
subprocess) — smoke tests must see 1 device.
"""

import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

import argparse  # noqa: E402
import dataclasses  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402
from typing import Dict, Optional, Tuple  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro import optim as optim_lib  # noqa: E402
from repro.analysis import hlo as hlo_lib  # noqa: E402
from repro.configs import ARCH_NAMES, INPUT_SHAPES, get_arch  # noqa: E402
from repro.configs.registry import ArchSpec  # noqa: E402
from repro.fl import rounds as rounds_lib  # noqa: E402
from repro.launch import sharding as sh  # noqa: E402
from repro.launch.mesh import make_client_mesh, make_production_mesh  # noqa: E402
from repro.models import transformer as T  # noqa: E402

SHAPE_NAMES = list(INPUT_SHAPES)


# ------------------------------------------------------------------ helpers


def _sds(tree_specs, tree_shapes, mesh):
    """Zip a PartitionSpec tree onto a ShapeDtypeStruct tree."""

    def mk(sdt, spec):
        return jax.ShapeDtypeStruct(
            sdt.shape, sdt.dtype, sharding=NamedSharding(mesh, spec)
        )

    return jax.tree_util.tree_map(
        mk, tree_shapes, tree_specs,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
    )


def _prepend_axis(spec_tree, axis):
    return jax.tree_util.tree_map(
        lambda s: P(axis, *s), spec_tree,
        is_leaf=lambda s: isinstance(s, P),
    )


def _replicated_like(tree, mesh):
    return jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype, sharding=NamedSharding(mesh, P())),
        tree,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
    )


def _batch_axes(multi_pod: bool):
    return ("pod", "data") if multi_pod else "data"


@dataclasses.dataclass
class DryRunCase:
    arch: str
    shape: str
    multi_pod: bool
    reduced: bool = False
    accounting: bool = False  # unroll scans so static HLO counts are exact
    scan_rounds: int = 1  # >1: engine-style lax.scan over N FL rounds

    @property
    def mesh_name(self) -> str:
        return "2x16x16" if self.multi_pod else "16x16"


def _case_config(case: DryRunCase) -> Tuple[ArchSpec, "ModelConfig", Dict]:
    spec = get_arch(case.arch)
    ishape = INPUT_SHAPES[case.shape]
    cfg = spec.long_context_model() if case.shape == "long_500k" else spec.model
    dims = dict(seq=ishape.seq_len, batch=ishape.global_batch, kind=ishape.kind)
    if case.reduced:
        cfg = cfg.reduced(param_dtype="bfloat16", dtype="bfloat16")
        # batch>1 shapes must stay divisible by the data axis (32 multi-pod)
        min_b = (32 if case.multi_pod else 16) if ishape.global_batch > 1 else 1
        dims.update(
            seq=min(dims["seq"], 128),
            batch=max(min(dims["batch"], 8), min_b) if ishape.global_batch > 1 else 1,
        )
        # reduced head/state dims no longer divide the 16-way model axis
        relax = dict(rwkv_heads=None)
        spec = dataclasses.replace(
            spec,
            serve_rules=dict(spec.serve_rules, **relax),
            train_rules=dict(spec.train_rules, **relax),
        )
    return spec, cfg, dims


# ------------------------------------------------------------ step builders


def _make_loss(cfg, uses_embeds: bool):
    if uses_embeds:
        return lambda p, batch: T.lm_loss(
            cfg, p, embeds=batch["embeds"], targets=batch["targets"]
        )
    return lambda p, batch: T.lm_loss(cfg, p, batch["tokens"])


def _uses_embeds(cfg) -> bool:
    return cfg.arch_type == "vlm"


def _train_case(spec, cfg, dims, mesh, multi_pod, steps_unroll=1, scan_rounds=1):
    """Build (step_fn, example_args_sds) for the training shape.

    ``scan_rounds > 1`` wraps the Mode-A round step the way the federation
    engine does (``repro.fl.engine``): N rounds compile into one ``lax.scan``
    program — proving the multi-round engine graph lowers/fits at production
    shapes, with per-round batches stacked on a leading ``(N,)`` axis.
    """
    rules = spec.train_rules
    b, s = dims["batch"], dims["seq"]
    uses_embeds = _uses_embeds(cfg)
    loss_fn = _make_loss(cfg, uses_embeds)

    params_shapes = jax.eval_shape(lambda k: T.init_params(k, cfg), jax.random.key(0))
    pspecs = sh.specs_from_logical(sh.param_logical_specs(cfg), rules, multi_pod)
    params_sds = _sds(pspecs, params_shapes, mesh)
    batch_ax = _batch_axes(multi_pod)

    if spec.fl.mode == "client_parallel":
        n_clients = 32 if multi_pod else 16
        local_b = max(1, b // n_clients)
        steps = spec.fl.local_steps
        # per-client params lay out over the data axis on top of the
        # serve-style model sharding
        serve_pspecs = sh.specs_from_logical(
            sh.param_logical_specs(cfg), spec.serve_rules, multi_pod
        )
        client_specs = _prepend_axis(serve_pspecs, batch_ax)

        def constraint(tree):
            return jax.tree_util.tree_map(
                lambda x, sp: jax.lax.with_sharding_constraint(x, sp),
                tree, client_specs,
                is_leaf=lambda x: isinstance(x, jax.Array),
            )

        micro = max(1, min(spec.fl.micro_batches, local_b))
        while local_b % micro:
            micro -= 1
        if steps_unroll is True:
            micro = 1  # accounting: keep all flops outside rolled loops
        step = rounds_lib.build_client_parallel_round(
            loss_fn, spec.fl.lr, steps, client_constraint=constraint,
            unroll=steps_unroll, micro_batches=micro,
        )
        if uses_embeds:
            batch_shapes = {
                "embeds": jax.ShapeDtypeStruct(
                    (n_clients, steps, local_b, s, cfg.d_model), jnp.bfloat16
                ),
                "targets": jax.ShapeDtypeStruct((n_clients, steps, local_b, s), jnp.int32),
            }
            batch_specs = {
                "embeds": P(batch_ax, None, None, None, None),
                "targets": P(batch_ax, None, None, None),
            }
        else:
            batch_shapes = {
                "tokens": jax.ShapeDtypeStruct((n_clients, steps, local_b, s), jnp.int32)
            }
            batch_specs = {"tokens": P(batch_ax, None, None, None)}
        if scan_rounds > 1:
            inner = step

            def step(params, batches, weights):  # noqa: F811
                def body(p, b):
                    p2, loss = inner(p, b, weights)
                    return p2, loss

                return jax.lax.scan(body, params, batches)

            batch_shapes = {
                k: jax.ShapeDtypeStruct((scan_rounds,) + v.shape, v.dtype)
                for k, v in batch_shapes.items()
            }
            batch_specs = {k: P(None, *v) for k, v in batch_specs.items()}
        batch_sds = _sds(batch_specs, batch_shapes, mesh)
        w_sds = jax.ShapeDtypeStruct(
            (n_clients,), jnp.float32, sharding=NamedSharding(mesh, P(batch_ax))
        )
        return step, (params_sds, batch_sds, w_sds)

    # Mode B: fedsgd_fsdp
    opt = getattr(optim_lib, spec.optimizer)(spec.fl.lr)
    micro = max(1, min(spec.fl.micro_batches, b))
    while b % micro:
        micro -= 1
    if steps_unroll is True:
        micro = 1  # accounting: keep all flops outside rolled loops
    step = rounds_lib.build_fedsgd_step(loss_fn, opt, micro_batches=micro)
    opt_shapes = jax.eval_shape(opt.init, params_shapes)
    opt_specs = sh.optimizer_state_specs(spec.optimizer, pspecs)
    opt_sds = _sds(opt_specs, opt_shapes, mesh)
    if uses_embeds:
        batch_shapes = {
            "embeds": jax.ShapeDtypeStruct((b, s, cfg.d_model), jnp.bfloat16),
            "targets": jax.ShapeDtypeStruct((b, s), jnp.int32),
        }
        batch_specs = {"embeds": P(batch_ax, None, None), "targets": P(batch_ax, None)}
    else:
        batch_shapes = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}
        batch_specs = {"tokens": P(batch_ax, None)}
    batch_sds = _sds(batch_specs, batch_shapes, mesh)
    return step, (params_sds, opt_sds, batch_sds)


def _serve_case(spec, cfg, dims, mesh, multi_pod, prefill: bool):
    """(step_fn, args_sds) for prefill / decode shapes."""
    rules = spec.serve_rules
    b, s = dims["batch"], dims["seq"]
    uses_embeds = _uses_embeds(cfg)
    batch_ax = _batch_axes(multi_pod) if b > 1 else None

    params_shapes = jax.eval_shape(lambda k: T.init_params(k, cfg), jax.random.key(0))
    pspecs = sh.specs_from_logical(sh.param_logical_specs(cfg), rules, multi_pod)
    params_sds = _sds(pspecs, params_shapes, mesh)

    cache_shapes = jax.eval_shape(lambda: T.init_caches(cfg, b, s))
    crules = dict(rules)
    if batch_ax is None:
        crules["act_batch"] = None
    cspecs = sh.specs_from_logical(sh.cache_logical_specs(cfg), crules, multi_pod)
    caches_sds = _sds(cspecs, cache_shapes, mesh)

    if prefill:
        def step(params, batch, caches):
            tokens = batch.get("tokens")
            positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
            if cfg.pos_style == "mrope":
                positions = jnp.broadcast_to(positions[None], (3, b, s))
            hidden, new_caches, _ = T.forward(
                cfg, params, tokens, positions, caches, embeds=batch.get("embeds")
            )
            logits = T.logits_from_hidden(cfg, params, hidden[:, -1:])
            return logits, new_caches

        if uses_embeds:
            batch_shapes = {"embeds": jax.ShapeDtypeStruct((b, s, cfg.d_model), jnp.bfloat16)}
            batch_specs = {"embeds": P(batch_ax, None, None)}
        else:
            batch_shapes = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}
            batch_specs = {"tokens": P(batch_ax, None)}
        batch_sds = _sds(batch_specs, batch_shapes, mesh)
        return step, (params_sds, batch_sds, caches_sds)

    def step(params, tokens, caches):
        return T.decode_step(cfg, params, tokens, caches)

    tok_sds = jax.ShapeDtypeStruct(
        (b, 1), jnp.int32, sharding=NamedSharding(mesh, P(batch_ax, None))
    )
    return step, (params_sds, tok_sds, caches_sds)


# ----------------------------------------------------- sharded FL engine


def run_fl_sharded_case(num_devices: int = 64, clients: int = 256,
                        clients_per_round: int = 32, rounds: int = 4,
                        cohort_cap: Optional[int] = None,
                        staleness_bound: Optional[int] = None,
                        scenario: Optional[str] = None,
                        candidate_frac: Optional[float] = None,
                        faults: Optional[str] = None,
                        aggregator: str = "mean",
                        local_algo: str = "fedavg",
                        prox_mu: Optional[float] = None,
                        feddyn_alpha: Optional[float] = None) -> Dict:
    """Prove the mesh-sharded federation engine (DESIGN.md §8) lowers and
    compiles at scale: C clients sharded over an N-device client mesh, the
    scanned round's local-update core as a shard_map with psum'd FedAvg.

    Drives the exact production path — ``engine.init_server_state(mesh=...)``
    + ``engine.make_round_fn(mesh=...)`` — on the forced host platform, and
    reports the compiled program's collective footprint (the all-gather-free
    claim is checkable in the HLO: params move only through reduce ops).

    ``cohort_cap`` compiles the capacity-slot variant instead: each shard's
    local-update scan is sized to ``min(C/N, cohort_cap)`` slots, proving the
    k ≪ C round really lowers to slot-count work (visible in the HLO loop
    trip counts) with the psum rendezvous unchanged.

    ``staleness_bound``/``scenario`` compile the bounded-staleness variant
    (DESIGN.md §9): the scan carries the ``s+1``-slot param ring buffer +
    per-shard staleness counters, every shard's base params come from a
    dynamic ring read, and the latency scenario's straggler bookkeeping all
    lower inside the same single-psum round — proving the stale temporal
    dimension fits the compiled-scan contract at production scale.

    ``candidate_frac`` compiles the two-stage funnel variant (DESIGN.md
    §10): the state carries the (Q,) candidate table and a Q×Q kernel +
    spectral cache instead of C×C, selection draws in candidate space and
    gathers back to global ids — proving the funneled round (and its
    shard-local candidate-profile psum at init) lowers on the client mesh.

    ``faults``/``aggregator`` compile the fault-tolerant variant (DESIGN.md
    §11): jit-level fault draws sharded into the round, the update-validation
    guard (finite screening + norm-outlier rejection against the shard-local
    cohort median) inside the shard_map before the unchanged single psum,
    quarantine counters carried in the scan, and the survivors-floor identity
    round — the full robustness layer must lower on the client mesh.

    ``local_algo`` compiles the pluggable local-update variant (DESIGN.md
    §12): ``feddyn`` carries the client-sharded per-client penalty state
    through the scan (gathered/scattered by the same slot machinery),
    proving a stateful local algorithm lowers on the client mesh with the
    aggregation path untouched.
    """
    import numpy as np

    from repro.core import selection as selection_lib
    from repro.fl import engine as engine_lib

    t0 = time.perf_counter()
    case = "fl_sharded_engine"
    if cohort_cap is not None:
        case = "fl_sharded_engine_slotted"
    elif staleness_bound is not None:
        case = "fl_sharded_engine_stale"
    elif candidate_frac is not None:
        case = "fl_sharded_engine_funnel"
    elif faults is not None or aggregator != "mean":
        case = "fl_sharded_engine_faulty"
    elif local_algo != "fedavg":
        case = f"fl_sharded_engine_{local_algo}"
    rec: Dict = {
        "case": case,
        "mesh": f"{num_devices}x1({sh.CLIENT_AXIS})",
        "clients": clients,
        "clients_per_round": clients_per_round,
        "cohort_cap": cohort_cap,
        "staleness_bound": staleness_bound,
        "scenario": scenario,
        "candidate_frac": candidate_frac,
        "faults": faults,
        "aggregator": aggregator,
        "local_algo": local_algo,
        "scan_rounds": rounds,
    }
    try:
        mesh = make_client_mesh(num_devices)
        feat, n_c, ncls = 32, 8, 10
        rng = np.random.default_rng(0)
        xs = jnp.asarray(rng.normal(size=(clients, n_c, feat)).astype("float32"))
        ys = jnp.asarray(rng.integers(0, ncls, size=(clients, n_c)), jnp.int32)
        params = {
            "w": jnp.asarray(0.01 * rng.normal(size=(feat, ncls)).astype("float32")),
            "b": jnp.zeros((ncls,), jnp.float32),
        }

        def loss_fn(p, x, y):
            logp = jax.nn.log_softmax(x @ p["w"] + p["b"])
            return -jnp.mean(jnp.take_along_axis(logp, y[..., None], axis=-1))

        cfg = engine_lib.FLConfig(
            num_clients=clients, clients_per_round=clients_per_round,
            local_epochs=2, lr=0.1, rounds=rounds, eval_every=rounds,
            num_classes=ncls, seed=0, cohort_cap=cohort_cap,
            staleness_bound=staleness_bound, scenario=scenario,
            candidate_frac=candidate_frac, faults=faults,
            aggregator=aggregator, local_algo=local_algo,
            prox_mu=prox_mu, feddyn_alpha=feddyn_alpha,
        )
        strat = selection_lib.DPPSelection()
        state = engine_lib.init_server_state(
            cfg, params, loss_fn, None, xs, ys, strategy=strat,
            profiles=xs.mean(axis=1), mesh=mesh,
        )
        if candidate_frac is not None:
            rec["candidates"] = int(state.candidates.shape[0])
            rec["kernel_shape"] = list(state.kernel.shape)
        round_fn = engine_lib.make_round_fn(cfg, loss_fn, (strat,), mesh=mesh)
        program = jax.jit(
            lambda s: jax.lax.scan(round_fn, s, None, length=rounds)
        )
        compiled = program.lower(state).compile()
        rec["compile_s"] = round(time.perf_counter() - t0, 2)
        rec["collectives"] = hlo_lib.collective_bytes(compiled.as_text())
        rec["ok"] = True
    except Exception as e:
        rec["ok"] = False
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    rec["total_s"] = round(time.perf_counter() - t0, 2)
    return rec


# ----------------------------------------------------- serving engine


def run_serve_engine_case(arch: str, batch: int = 4, prompt: int = 8,
                          gen: int = 8) -> Dict:
    """Prove the serving engine's two compiled programs (DESIGN.md §13)
    lower and compile for a reduced arch: the ``lax.scan`` decode over the
    per-slot :class:`~repro.serve.DecodeState`, and the continuous-batching
    slot-refill admission (prefill + stable-argsort slot scatter).  Pure
    ``lower().compile()`` on ShapeDtypeStructs — no weights materialised."""
    from repro.serve import (ServeConfig, init_decode_state, make_admit_fn,
                             make_decode_fn, run_scan)

    t0 = time.perf_counter()
    rec: Dict = {"case": "serve_engine", "arch": arch,
                 "batch": batch, "prompt": prompt, "gen": gen}
    try:
        cfg = get_arch(arch).model.reduced(
            param_dtype="float32", dtype="float32", remat=False
        )
        scfg = ServeConfig(batch=batch, cache_len=prompt + gen, max_new=gen)
        params_sds = jax.eval_shape(
            lambda k: T.init_params(k, cfg), jax.random.key(0)
        )
        state_sds = jax.eval_shape(lambda: init_decode_state(cfg, scfg))

        decode_fn = make_decode_fn(cfg, scfg)
        t1 = time.perf_counter()
        scan = jax.jit(lambda p, s: run_scan(decode_fn, p, s, gen - 1))
        scan.lower(params_sds, state_sds).compile()
        rec["scan_compile_s"] = round(time.perf_counter() - t1, 2)

        admit_fn = make_admit_fn(cfg, scfg, prompt)
        prompt_sds = jax.ShapeDtypeStruct((1, prompt), jnp.int32)
        scalar_sds = jax.ShapeDtypeStruct((), jnp.int32)
        key_sds = jax.eval_shape(
            lambda k: jax.random.key_data(k), jax.random.key(0)
        )
        t1 = time.perf_counter()
        jax.jit(admit_fn).lower(
            params_sds, state_sds, prompt_sds, scalar_sds, scalar_sds, key_sds
        ).compile()
        rec["admit_compile_s"] = round(time.perf_counter() - t1, 2)
        rec["ok"] = True
    except Exception as e:
        rec["ok"] = False
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    rec["total_s"] = round(time.perf_counter() - t0, 2)
    return rec


# ------------------------------------------------------------------ runner


def _compile_once(spec, cfg, dims, mesh, multi_pod, steps_unroll=1, scan_rounds=1):
    """Lower+compile one variant; return compiled.

    Buffers are donated the way the production loop would donate them
    (params/opt-state in, updated params/opt-state out; caches in, updated
    caches out) so memory_analysis reflects steady-state aliasing.
    """
    if dims["kind"] == "train":
        step, args = _train_case(spec, cfg, dims, mesh, multi_pod,
                                 steps_unroll=steps_unroll,
                                 scan_rounds=scan_rounds)
        rules = spec.train_rules
        if spec.fl.mode == "client_parallel":
            # the client axis owns 'data'; activation constraints inside the
            # per-client vmap must NOT re-claim it for the local batch dim —
            # doing so forced spurious regathers (§Perf: 5.2x collective
            # reduction on rwkv6 train from this alone).
            rules = dict(rules, act_batch=None)
        donate = (0,) if spec.fl.mode == "client_parallel" else (0, 1)
    else:
        step, args = _serve_case(
            spec, cfg, dims, mesh, multi_pod, prefill=dims["kind"] == "prefill"
        )
        rules = spec.serve_rules
        donate = (2,)  # caches
    # jax.set_mesh is >= 0.5; entering the Mesh object is the older spelling
    mesh_ctx = jax.set_mesh(mesh) if hasattr(jax, "set_mesh") else mesh
    with mesh_ctx, sh.use_rules(rules, multi_pod):
        compiled = jax.jit(step, donate_argnums=donate).lower(*args).compile()
    return compiled


def _counts(compiled) -> Dict:
    cost = compiled.cost_analysis()
    cost = cost[0] if isinstance(cost, (list, tuple)) else cost
    text = compiled.as_text()
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "collectives": hlo_lib.collective_bytes(text),
        "text": text,
    }


def _accounting_counts(spec, cfg, dims, mesh, multi_pod) -> Dict:
    """Two-point unroll delta: XLA cost analysis counts while bodies once, so
    with the local-step and loss scans fully unrolled and the layer scan at
    unroll u ∈ {1, 2}:  reported(u) = C + u·B  ⇒  exact = reported(1) +
    (R − 1)·(reported(2) − reported(1)), R = layer-scan trip count.
    The rwkv time scan stays rolled (its flops are added analytically in
    analysis.roofline)."""
    import dataclasses as dc

    loss_chunk = max(512, dims["seq"] // 4)
    cfg1 = dc.replace(cfg, scan_unroll=1, loss_unroll=True, loss_chunk=loss_chunk)
    cfg2 = dc.replace(cfg, scan_unroll=2, loss_unroll=True, loss_chunk=loss_chunk)
    reps = cfg.num_layers // len(cfg.block_pattern)
    c1 = _counts(_compile_once(spec, cfg1, dims, mesh, multi_pod, steps_unroll=True))
    c2 = _counts(_compile_once(spec, cfg2, dims, mesh, multi_pod, steps_unroll=True))

    def corr(a, b):
        return a + (reps - 1) * (b - a)

    coll = {}
    keys = set(c1["collectives"]) | set(c2["collectives"])
    for k in keys:
        coll[k] = max(0.0, corr(c1["collectives"].get(k, 0.0), c2["collectives"].get(k, 0.0)))
    return {
        "flops": corr(c1["flops"], c2["flops"]),
        "bytes": corr(c1["bytes"], c2["bytes"]),
        "collectives": coll,
        "layer_reps": reps,
        "raw": {
            "u1": {k: c1[k] for k in ("flops", "bytes")},
            "u2": {k: c2[k] for k in ("flops", "bytes")},
        },
    }


def run_case(case: DryRunCase, dump_hlo: Optional[str] = None,
             mesh_override=None) -> Dict:
    t0 = time.perf_counter()
    spec, cfg, dims = _case_config(case)
    mesh = mesh_override or make_production_mesh(multi_pod=case.multi_pod)
    rec: Dict = {
        "arch": case.arch,
        "shape": case.shape,
        "mesh": case.mesh_name if mesh_override is None else "x".join(
            str(s) for s in mesh.devices.shape
        ),
        "kind": dims["kind"],
        "fl_mode": spec.fl.mode if dims["kind"] == "train" else "serve",
        "reduced": case.reduced,
        "accounting": case.accounting,
        # the scan wrapper only applies to client_parallel train compiles;
        # record the EFFECTIVE value so sweep records stay comparable
        "scan_rounds": case.scan_rounds
        if (
            dims["kind"] == "train"
            and spec.fl.mode == "client_parallel"
            and not case.accounting
        )
        else 1,
    }
    try:
        if case.accounting:
            acc = _accounting_counts(spec, cfg, dims, mesh, case.multi_pod)
            rec["cost"] = {"flops": acc["flops"], "bytes accessed": acc["bytes"]}
            rec["collectives"] = acc["collectives"]
            rec["layer_reps"] = acc["layer_reps"]
            rec["raw"] = acc["raw"]
            rec["ok"] = True
            rec["total_s"] = round(time.perf_counter() - t0, 2)
            return rec

        compiled = _compile_once(spec, cfg, dims, mesh, case.multi_pod,
                                 scan_rounds=case.scan_rounds)
        rec["compile_s"] = round(time.perf_counter() - t0, 2)
        rec["params"] = int(
            sum(
                x.size
                for x in jax.tree_util.tree_leaves(
                    jax.eval_shape(lambda k: T.init_params(k, cfg), jax.random.key(0))
                )
            )
        )

        try:
            mem = compiled.memory_analysis()
            rec["memory"] = {
                k: int(getattr(mem, k))
                for k in (
                    "argument_size_in_bytes",
                    "output_size_in_bytes",
                    "temp_size_in_bytes",
                    "generated_code_size_in_bytes",
                )
                if hasattr(mem, k)
            }
        except Exception as e:  # pragma: no cover - backend dependent
            rec["memory"] = {"error": str(e)}

        try:
            cost = compiled.cost_analysis()
            cost = cost[0] if isinstance(cost, (list, tuple)) else cost
            rec["cost"] = {
                k: float(v)
                for k, v in cost.items()
                if k in ("flops", "bytes accessed", "utilization operand 0")
                or k.startswith("bytes accessed")
            }
        except Exception as e:  # pragma: no cover
            rec["cost"] = {"error": str(e)}

        text = compiled.as_text()
        rec["collectives"] = hlo_lib.collective_bytes(text)
        rec["hlo_ops"] = hlo_lib.op_histogram(text)
        if dump_hlo:
            os.makedirs(os.path.dirname(dump_hlo) or ".", exist_ok=True)
            with open(dump_hlo, "w") as f:
                f.write(text)
        rec["ok"] = True
    except Exception as e:
        rec["ok"] = False
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    rec["total_s"] = round(time.perf_counter() - t0, 2)
    return rec


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=ARCH_NAMES)
    ap.add_argument("--shape", choices=SHAPE_NAMES)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true",
                    help="run single-pod AND multi-pod for each case")
    ap.add_argument("--sweep", action="store_true", help="all arch x shapes")
    ap.add_argument("--reduced", action="store_true",
                    help="reduced configs + tiny shapes (CI smoke)")
    ap.add_argument("--accounting", action="store_true",
                    help="unroll scans for exact static HLO counts (§Roofline)")
    ap.add_argument("--scan-rounds", type=int, default=1,
                    help="compile N FL rounds as one engine-style lax.scan "
                         "(client_parallel train shapes; DESIGN.md §7)")
    ap.add_argument("--fl-sharded", action="store_true",
                    help="compile the mesh-sharded federation engine on a "
                         "client mesh (DESIGN.md §8) instead of an arch case")
    ap.add_argument("--fl-devices", type=int, default=64,
                    help="client-mesh size for --fl-sharded")
    ap.add_argument("--fl-cohort-cap", type=int, default=2,
                    help="per-shard slot count (and cohort size) for the "
                         "--fl-sharded capacity-slot case (DESIGN.md §8)")
    ap.add_argument("--fl-staleness-bound", type=int, default=2,
                    help="staleness bound for the --fl-sharded bounded-"
                         "staleness compile case (DESIGN.md §9)")
    ap.add_argument("--fl-candidate-frac", type=float, default=0.25,
                    help="candidate fraction for the --fl-sharded two-stage "
                         "funnel compile case (DESIGN.md §10)")
    ap.add_argument("--serve-engine", action="store_true",
                    help="compile the serving engine's scan-decode and "
                         "continuous slot-refill programs on reduced archs "
                         "(DESIGN.md §13) instead of an arch case")
    ap.add_argument("--out", default=None, help="append JSONL records here")
    ap.add_argument("--dump-hlo", default=None)
    args = ap.parse_args()

    if args.serve_engine:
        # scan decode + continuous slot-refill admission must lower and
        # compile for each cache family: dense GQA KV (smollm), O(1)
        # recurrent state (rwkv6), SWA ring buffer + MoE (mixtral)
        archs = [args.arch] if args.arch else [
            "smollm-360m", "rwkv6-7b", "mixtral-8x7b"
        ]
        recs = [run_serve_engine_case(a) for a in archs]
        any_fail = False
        for rec in recs:
            status = "OK " if rec["ok"] else "FAIL"
            timing = (
                f"scan={rec.get('scan_compile_s', 0):5.1f}s "
                f"admit={rec.get('admit_compile_s', 0):5.1f}s"
                if rec["ok"] else f"  {rec['error'][:120]}"
            )
            print(f"[{status}] serve_engine {rec['arch']:28s} "
                  f"b={rec['batch']} p={rec['prompt']} g={rec['gen']} "
                  f"{rec['total_s']:7.1f}s  {timing}")
            if not rec["ok"]:
                any_fail = True
                print(rec.get("traceback", "")[-800:])
            if args.out:
                os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
                with open(args.out, "a") as f:
                    f.write(json.dumps(rec) + "\n")
        if any_fail:
            raise SystemExit(1)
        return

    if args.fl_sharded:
        # resident-mode round, the capacity-slot variant on a k ≪ C_loc
        # cohort (cap = min(C/N, k)), the bounded-staleness variant (ring
        # buffer + counters under heavy-tail latency, DESIGN.md §9), the
        # two-stage funnel variant (Q×Q candidate kernel, DESIGN.md §10),
        # the fault-tolerant variant (chaos faults + trimmed_mean guard,
        # DESIGN.md §11), and the stateful local-algorithm variant (feddyn's
        # client-sharded penalty state, DESIGN.md §12) — all six must lower
        # and compile
        recs = [
            run_fl_sharded_case(num_devices=args.fl_devices),
            run_fl_sharded_case(
                num_devices=args.fl_devices,
                clients_per_round=args.fl_cohort_cap,
                cohort_cap=args.fl_cohort_cap,
            ),
            run_fl_sharded_case(
                num_devices=args.fl_devices,
                staleness_bound=args.fl_staleness_bound,
                scenario="heavy_tail",
            ),
            run_fl_sharded_case(
                num_devices=args.fl_devices,
                candidate_frac=args.fl_candidate_frac,
            ),
            run_fl_sharded_case(
                num_devices=args.fl_devices,
                faults="chaos",
                aggregator="trimmed_mean",
            ),
            run_fl_sharded_case(
                num_devices=args.fl_devices,
                local_algo="feddyn",
                feddyn_alpha=0.01,
            ),
        ]
        any_fail = False
        for rec in recs:
            status = "OK " if rec["ok"] else "FAIL"
            cap = rec["cohort_cap"]
            stale = rec.get("staleness_bound")
            frac = rec.get("candidate_frac")
            print(
                f"[{status}] {rec['case']} {rec['mesh']:14s} "
                f"C={rec['clients']} k={rec['clients_per_round']}"
                + (f" cap={cap}" if cap is not None else "")
                + (f" stale<=%d(%s)" % (stale, rec["scenario"])
                   if stale is not None else "")
                + (f" Q={rec.get('candidates')}({frac})"
                   if frac is not None else "")
                + (f" faults={rec['faults']}/{rec['aggregator']}"
                   if rec.get("faults") is not None else "")
                + (f" algo={rec['local_algo']}"
                   if rec.get("local_algo", "fedavg") != "fedavg" else "")
                + f" {rec['total_s']:7.1f}s"
                + ("" if rec["ok"] else f"  {rec['error'][:120]}")
            )
            if not rec["ok"]:
                any_fail = True
                print(rec.get("traceback", "")[-800:])
            if args.out:
                os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
                with open(args.out, "a") as f:
                    f.write(json.dumps(rec) + "\n")
        if any_fail:
            raise SystemExit(1)
        return

    if args.sweep:
        cases = [
            DryRunCase(a, s, mp, reduced=args.reduced, accounting=args.accounting,
                       scan_rounds=args.scan_rounds)
            for a in ARCH_NAMES
            for s in SHAPE_NAMES
            for mp in ((False, True) if args.both_meshes else (args.multi_pod,))
        ]
    else:
        assert args.arch and args.shape, "--arch/--shape or --sweep required"
        meshes = (False, True) if args.both_meshes else (args.multi_pod,)
        cases = [
            DryRunCase(args.arch, args.shape, mp, reduced=args.reduced,
                       accounting=args.accounting, scan_rounds=args.scan_rounds)
            for mp in meshes
        ]

    done = set()
    if args.out and os.path.exists(args.out):
        with open(args.out) as f:
            for line in f:
                try:
                    r = json.loads(line)
                    if r.get("ok"):
                        done.add((r["arch"], r["shape"], r["mesh"], r.get("reduced", False)))
                except json.JSONDecodeError:
                    pass

    for case in cases:
        key = (case.arch, case.shape, case.mesh_name, case.reduced)
        if key in done:
            print(f"[skip] {key} (cached)")
            continue
        rec = run_case(case, dump_hlo=args.dump_hlo)
        status = "OK " if rec["ok"] else "FAIL"
        print(
            f"[{status}] {case.arch:28s} {case.shape:12s} {case.mesh_name:8s} "
            f"{rec['total_s']:7.1f}s"
            + ("" if rec["ok"] else f"  {rec['error'][:120]}")
        )
        if not rec["ok"]:
            print(rec.get("traceback", "")[-800:])
        if args.out:
            os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
            with open(args.out, "a") as f:
                f.write(json.dumps(rec) + "\n")


if __name__ == "__main__":
    main()

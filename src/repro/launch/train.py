"""Training driver: federated (FL-DP³S) or plain pretrain, on real devices.

On this CPU container it runs reduced configs end-to-end (the full configs
are exercised by the dry-run); on a TPU slice the same driver scales via
``--mesh`` because every step is the same pjit program the dry-run compiles.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-360m \
        --mode fl --rounds 30 --selection fl-dp3s
    PYTHONPATH=src python -m repro.launch.train --arch smollm-360m \
        --mode pretrain --steps 200
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import optim as optim_lib
from repro.checkpoint import save
from repro.configs import ARCH_NAMES, get_arch
from repro.core import RoundState, kernel_from_profiles, make_strategy
from repro.data import make_token_dataset
from repro.fl import rounds as rounds_lib
from repro.models import transformer as T


def _token_clients(cfg, num_clients, docs_per_client, seq, seed=0):
    """Topic-skewed client corpora (ξ=1-style: one topic per client)."""
    docs, topics = make_token_dataset(
        n_docs=num_clients * docs_per_client * 2,
        doc_len=seq,
        vocab=min(cfg.vocab_size, 512),
        num_topics=min(10, num_clients),
        seed=seed,
    )
    clients = []
    for c in range(num_clients):
        topic = c % min(10, num_clients)
        idx = np.nonzero(topics == topic)[0][:docs_per_client]
        clients.append(docs[idx])
    return np.stack(clients)  # (C, docs, seq)


def run_fl(args):
    spec = get_arch(args.arch)
    cfg = spec.model.reduced(param_dtype="float32", dtype="float32", remat=False)
    params = T.init_params(jax.random.key(args.seed), cfg)
    clients = _token_clients(cfg, args.clients, args.docs_per_client, args.seq)
    c, n_docs, _ = clients.shape

    # --- Alg. 1 init: profile every client once, build the eq.-14 kernel ---
    feats = []
    feat_fn = jax.jit(lambda p, xs: T.features(cfg, p, xs)[1].mean(0))
    for ci in range(c):
        feats.append(feat_fn(params, jnp.asarray(clients[ci][: min(8, n_docs)])))
    profiles = jnp.stack(feats)
    state = RoundState(
        num_clients=c,
        profiles=profiles,
        kernel=kernel_from_profiles(profiles),
        client_sizes=jnp.full((c,), float(n_docs)),
        losses=jnp.ones((c,)),
    )
    strategy = make_strategy(args.selection)

    loss_fn = lambda p, batch: T.lm_loss(cfg, p, batch)
    round_step = jax.jit(
        rounds_lib.build_client_parallel_round(loss_fn, spec.fl.lr, args.local_steps)
    )
    key = jax.random.key(args.seed)
    for t in range(1, args.rounds + 1):
        key, k_sel, k_b = jax.random.split(key, 3)
        sel = np.asarray(strategy.select(k_sel, state, args.per_round))
        batch = []
        for ci in sel:
            ids = jax.random.choice(
                jax.random.fold_in(k_b, int(ci)), n_docs,
                shape=(args.local_steps, args.local_batch), replace=True,
            )
            batch.append(clients[ci][np.asarray(ids)])
        batch = jnp.asarray(np.stack(batch))  # (C_p, steps, B, S)
        weights = jnp.full((len(sel),), float(n_docs))
        params, loss = round_step(params, batch, weights)
        if t % args.log_every == 0 or t == args.rounds:
            print(f"[fl:{args.selection}] round {t:4d} sel={sel.tolist()} "
                  f"loss={float(loss):.4f}")
    if args.ckpt:
        save(args.ckpt, args.rounds, params)
        print(f"checkpoint -> {args.ckpt}")
    return params


def run_pretrain(args):
    spec = get_arch(args.arch)
    cfg = spec.model.reduced(param_dtype="float32", dtype="float32", remat=False)
    params = T.init_params(jax.random.key(args.seed), cfg)
    opt = getattr(optim_lib, spec.optimizer)(getattr(args, "lr", 1e-3))
    opt_state = opt.init(params)
    docs, _ = make_token_dataset(
        n_docs=4096, doc_len=args.seq, vocab=min(cfg.vocab_size, 512), seed=args.seed
    )
    loss_fn = lambda p, batch: T.lm_loss(cfg, p, batch["tokens"])
    step = jax.jit(rounds_lib.build_fedsgd_step(loss_fn, opt, grad_clip=1.0))
    rng = np.random.default_rng(args.seed)
    t0 = time.time()
    for i in range(1, args.steps + 1):
        idx = rng.integers(0, len(docs), size=args.local_batch)
        params, opt_state, loss = step(params, opt_state, {"tokens": jnp.asarray(docs[idx])})
        if i % args.log_every == 0 or i == args.steps:
            tps = i * args.local_batch * args.seq / (time.time() - t0)
            print(f"[pretrain] step {i:5d} loss={float(loss):.4f} tok/s={tps:,.0f}")
    if args.ckpt:
        save(args.ckpt, args.steps, {"params": params, "opt": opt_state})
        print(f"checkpoint -> {args.ckpt}")
    return params


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=ARCH_NAMES, default="smollm-360m")
    ap.add_argument("--mode", choices=("fl", "pretrain"), default="fl")
    ap.add_argument("--selection", default="fl-dp3s")
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--clients", type=int, default=10)
    ap.add_argument("--per-round", type=int, default=4)
    ap.add_argument("--docs-per-client", type=int, default=16)
    ap.add_argument("--local-steps", type=int, default=2)
    ap.add_argument("--local-batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=5)
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()
    (run_fl if args.mode == "fl" else run_pretrain)(args)


if __name__ == "__main__":
    main()

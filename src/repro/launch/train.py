"""Training driver: federated (FL-DP³S) or plain pretrain, on real devices.

On this CPU container it runs reduced configs end-to-end (the full configs
are exercised by the dry-run); on a TPU slice the same driver scales via
``--mesh`` because every step is the same pjit program the dry-run compiles.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-360m \
        --mode fl --rounds 30 --selection fl-dp3s
    PYTHONPATH=src python -m repro.launch.train --arch smollm-360m \
        --mode pretrain --steps 200
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import optim as optim_lib
from repro.checkpoint import latest_step, save
from repro.configs import ARCH_NAMES, get_arch
from repro.core import make_strategy
from repro.data import make_token_dataset
from repro.fl import engine as engine_lib
from repro.fl import rounds as rounds_lib
from repro.fl.faults import AGGREGATORS, FAULT_NAMES
from repro.fl.local_algos import ALGO_NAMES
from repro.fl.scenarios import SCENARIO_NAMES
from repro.fl.staleness import DECAY_FAMILIES
from repro.launch.mesh import make_client_mesh
from repro.models import transformer as T
from repro.obs import TelemetrySink
from repro.obs import tracing as obs_tracing_lib


def _token_clients(cfg, num_clients, docs_per_client, seq, seed=0):
    """Topic-skewed client corpora (ξ=1-style: one topic per client)."""
    docs, topics = make_token_dataset(
        n_docs=num_clients * docs_per_client * 2,
        doc_len=seq,
        vocab=min(cfg.vocab_size, 512),
        num_topics=min(10, num_clients),
        seed=seed,
    )
    clients = []
    for c in range(num_clients):
        topic = c % min(10, num_clients)
        idx = np.nonzero(topics == topic)[0][:docs_per_client]
        clients.append(docs[idx])
    return np.stack(clients)  # (C, docs, seq)


def run_fl(args):
    """Federated LM training through the scanned engine (DESIGN.md §7).

    Algorithm-1 init (profiles → eq.-14 kernel) runs once on host; then all
    ``--rounds`` rounds — selection, per-client local steps, aggregation,
    loss refresh, topic-GEMD — execute as ONE compiled ``lax.scan``.

    ``--shard-clients N`` lays the federation out over an N-device client
    mesh (DESIGN.md §8): same engine, same scan, with the local-update core
    shard_mapped so each device trains its resident clients and the FedAvg
    reduction runs as psum'd partial sums.  On CPU hosts combine with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N``.

    ``--cohort-cap M`` (requires ``--shard-clients``) switches the sharded
    round to capacity-slot scheduling: each shard trains at most
    ``min(C/N, M)`` clients per round instead of all its residents, so a
    small diverse cohort (k ≪ C, the paper's regime) stops paying
    full-federation compute.  ``M`` must be ≥ min(--per-round, C/N);
    ``M = --per-round`` is the natural setting.

    ``--scenario NAME`` attaches a system-heterogeneity model (DESIGN.md
    §9): per-client latency draws priced into a simulated round wall clock
    (reported at the end), and availability-masked selection for scenarios
    with an availability model.  ``--staleness-bound S`` (requires
    ``--shard-clients`` and ``--scenario``) relaxes the sharded round's
    psum barrier to bounded-staleness aggregation: shards that miss the
    scenario deadline contribute partial sums computed on params up to S
    rounds old, weighted by ``--staleness-decay``/``--staleness-alpha``.

    ``--candidate-frac F`` (DESIGN.md §10) turns on the two-stage selection
    funnel: a cheap loss/latency/availability prefilter keeps Q = F·C
    candidates, and the eq.-(14) kernel + k-DPP spectral cache live on the
    Q×Q block — the O(C³) eigh and the C×C Gram never happen (the
    million-client regime).  Composes with every flag above.

    ``--faults NAME`` injects the named fault model (DESIGN.md §11):
    per-client dropout, NaN/garbage/sign-flip corruption, shard blackout.
    ``--aggregator {mean,clipped_mean,trimmed_mean}`` picks the robust
    aggregation mode that screens/clips the faulty updates.  With
    ``--ckpt-every N`` and ``--ckpt DIR`` the full ``ServerState`` snapshots
    every N rounds and a re-launch resumes bit-identically from the latest
    snapshot.

    ``--local-algo {fedavg,fedprox,feddyn}`` (DESIGN.md §12) swaps the
    client-side objective without touching any of the above: e.g.
    ``--local-algo fedprox --prox-mu 0.01`` adds the proximal drift
    penalty, ``--local-algo feddyn --feddyn-alpha 0.01`` carries a
    per-client linear-penalty state across rounds (client-sharded,
    checkpointed with the ServerState).  Composes with every flag above.
    """
    mesh = None
    shard_clients = getattr(args, "shard_clients", 0)
    cohort_cap = getattr(args, "cohort_cap", None)
    staleness_bound = getattr(args, "staleness_bound", None)
    if shard_clients:
        if args.clients % shard_clients:
            raise SystemExit(
                f"--clients={args.clients} must be divisible by "
                f"--shard-clients={shard_clients}"
            )
        mesh = make_client_mesh(shard_clients)
    elif cohort_cap is not None:
        raise SystemExit("--cohort-cap requires --shard-clients")
    elif staleness_bound is not None:
        raise SystemExit("--staleness-bound requires --shard-clients")
    if getattr(args, "ckpt_every", None) is not None and not args.ckpt:
        raise SystemExit("--ckpt-every requires --ckpt DIR")
    spec = get_arch(args.arch)
    cfg = spec.model.reduced(param_dtype="float32", dtype="float32", remat=False)
    params = T.init_params(jax.random.key(args.seed), cfg)
    clients = _token_clients(cfg, args.clients, args.docs_per_client, args.seq)
    c, n_docs, _ = clients.shape
    num_topics = min(10, args.clients)
    # per-doc topic labels (one topic per client) — the engine's GEMD then
    # measures how topic-representative each selected cohort is
    topics = np.stack(
        [np.full((n_docs,), ci % num_topics, np.int32) for ci in range(c)]
    )

    # --- Alg. 1 init: profile every client once, build the eq.-14 kernel ---
    feats = []
    feat_fn = jax.jit(lambda p, xs: T.features(cfg, p, xs)[1].mean(0))
    for ci in range(c):
        feats.append(feat_fn(params, jnp.asarray(clients[ci][: min(8, n_docs)])))
    profiles = jnp.stack(feats)
    strategy = make_strategy(args.selection)

    loss_fn = lambda p, x, y: T.lm_loss(cfg, p, x)  # topics only feed GEMD
    telemetry_path = getattr(args, "telemetry", None)
    flcfg = engine_lib.FLConfig(
        num_clients=c,
        clients_per_round=args.per_round,
        local_batch_size=args.local_batch,
        local_steps=args.local_steps,
        sample_with_replacement=True,
        lr=spec.fl.lr,
        rounds=args.rounds,
        eval_every=max(args.log_every, 1),
        num_classes=num_topics,
        seed=args.seed,
        cohort_cap=cohort_cap,
        staleness_bound=staleness_bound,
        staleness_decay=getattr(args, "staleness_decay", "polynomial"),
        staleness_alpha=getattr(args, "staleness_alpha", 0.5),
        scenario=getattr(args, "scenario", None),
        candidate_frac=getattr(args, "candidate_frac", None),
        faults=getattr(args, "faults", None),
        aggregator=getattr(args, "aggregator", "mean"),
        ckpt_every=getattr(args, "ckpt_every", None),
        local_algo=getattr(args, "local_algo", "fedavg"),
        prox_mu=getattr(args, "prox_mu", None),
        feddyn_alpha=getattr(args, "feddyn_alpha", None),
        telemetry=telemetry_path is not None,
    )
    sink = None
    if telemetry_path:
        sink = TelemetrySink(telemetry_path)
        sink.write_manifest(
            config=dataclasses.asdict(flcfg), mesh=mesh,
            extra={"mode": "fl", "arch": args.arch,
                   "selection": args.selection},
        )
    state = engine_lib.init_server_state(
        flcfg, params, loss_fn, None, clients, topics,
        strategy=strategy, profiles=profiles, losses=jnp.ones((c,)),
        mesh=mesh,
    )
    if flcfg.candidate_frac is not None:
        print(f"[fl:{args.selection}] funnel: C={c} -> "
              f"Q={flcfg.candidate_count()} candidates "
              f"(kernel {state.kernel.shape})")
    round_fn = engine_lib.make_round_fn(flcfg, loss_fn, (strategy,), mesh=mesh)
    # crash-resume (DESIGN.md §11): with --ckpt-every the checkpoint dir
    # holds full ServerState snapshots, so a re-launch picks up from the
    # latest one and runs only the remaining rounds — bit-identical to an
    # uninterrupted run
    start = 0
    if flcfg.ckpt_every is not None and args.ckpt:
        step = latest_step(args.ckpt)
        if step is not None:
            state = engine_lib.restore_server_state(args.ckpt, state, step=step)
            if mesh is not None:
                state = engine_lib.shard_server_state(state, mesh)
            start = int(jax.device_get(state.round))
            print(f"[fl:{args.selection}] resumed round {start} from "
                  f"{args.ckpt}/step_{step:08d}")
    remaining = max(args.rounds - start, 0)
    with obs_tracing_lib.trace(getattr(args, "profile_dir", None)):
        if flcfg.ckpt_every is not None and args.ckpt:
            state, outs = engine_lib.run_checkpointed(
                round_fn, state, remaining, ckpt_dir=args.ckpt,
                ckpt_every=flcfg.ckpt_every, mesh=mesh, sink=sink,
            )
        else:
            state, outs = engine_lib.run_scanned(
                round_fn, state, remaining, mesh=mesh, sink=sink
            )
    sels = np.asarray(outs["selected"]) if remaining else np.zeros((0, 0), int)
    losses = np.asarray(outs["loss"]) if remaining else np.zeros((0,))
    gemds = np.asarray(outs["gemd"]) if remaining else np.zeros((0,))
    rnds = np.asarray(outs["round"]).astype(int) if remaining else np.zeros((0,), int)
    for i, t in enumerate(rnds):
        if t % args.log_every == 0 or t == args.rounds:
            print(f"[fl:{args.selection}] round {t:4d} sel={sels[i].tolist()} "
                  f"loss={losses[i]:.4f} gemd={gemds[i]:.3f}")
    if flcfg.guarded() and remaining:
        # NaN-aware summary: identity rounds and corrupt cohorts report NaN
        # round means by convention — they must not poison the run summary
        surv = np.asarray(outs["survivors"])
        best = (f"{np.nanmin(losses):.4f}" if np.isfinite(losses).any()
                else "n/a (no finite round losses)")
        print(f"[fl:{args.selection}] faults={flcfg.faults or 'none'} "
              f"aggregator={flcfg.aggregator}: "
              f"mean survivors {surv.mean():.1f}/{args.per_round}, "
              f"flagged {int(np.asarray(outs['flagged']).sum())}, "
              f"identity rounds {int(np.asarray(outs['identity_round']).sum())}, "
              f"best finite loss {best}")
    if "sim_time" in outs:
        sim = np.asarray(outs["sim_time"])
        mode = ("bounded-staleness" if staleness_bound is not None
                else "synchronous barrier")
        print(f"[fl:{args.selection}] scenario={args.scenario} ({mode}): "
              f"simulated wall clock {sim.sum():.2f} "
              f"(mean round {sim.mean():.2f})")
    if sink is not None:
        n_ev = sum(sink.event_counts.values())
        sink.close()
        print(f"[fl:{args.selection}] telemetry -> {telemetry_path} "
              f"({n_ev} events; render with "
              f"`python -m repro.analysis.report {telemetry_path}`)")
    params = state.params
    if args.ckpt and flcfg.ckpt_every is None:
        # legacy raw-params snapshot; with --ckpt-every the dir already holds
        # full ServerState snapshots (run_checkpointed) at these steps
        save(args.ckpt, args.rounds, params)
        print(f"checkpoint -> {args.ckpt}")
    return params


def run_pretrain(args):
    spec = get_arch(args.arch)
    cfg = spec.model.reduced(param_dtype="float32", dtype="float32", remat=False)
    params = T.init_params(jax.random.key(args.seed), cfg)
    opt = getattr(optim_lib, spec.optimizer)(getattr(args, "lr", 1e-3))
    opt_state = opt.init(params)
    docs, _ = make_token_dataset(
        n_docs=4096, doc_len=args.seq, vocab=min(cfg.vocab_size, 512), seed=args.seed
    )
    loss_fn = lambda p, batch: T.lm_loss(cfg, p, batch["tokens"])
    step = jax.jit(rounds_lib.build_fedsgd_step(loss_fn, opt, grad_clip=1.0))
    rng = np.random.default_rng(args.seed)
    t0 = time.time()
    for i in range(1, args.steps + 1):
        idx = rng.integers(0, len(docs), size=args.local_batch)
        params, opt_state, loss = step(params, opt_state, {"tokens": jnp.asarray(docs[idx])})
        if i % args.log_every == 0 or i == args.steps:
            tps = i * args.local_batch * args.seq / (time.time() - t0)
            print(f"[pretrain] step {i:5d} loss={float(loss):.4f} tok/s={tps:,.0f}")
    if args.ckpt:
        save(args.ckpt, args.steps, {"params": params, "opt": opt_state})
        print(f"checkpoint -> {args.ckpt}")
    return params


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=ARCH_NAMES, default="smollm-360m")
    ap.add_argument("--mode", choices=("fl", "pretrain"), default="fl")
    ap.add_argument("--selection", default="fl-dp3s")
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--clients", type=int, default=10)
    ap.add_argument("--per-round", type=int, default=4)
    ap.add_argument("--docs-per-client", type=int, default=16)
    ap.add_argument("--local-steps", type=int, default=2)
    ap.add_argument("--local-batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=5)
    ap.add_argument("--shard-clients", type=int, default=0,
                    help="shard the client axis over an N-device mesh "
                         "(FL mode; DESIGN.md §8)")
    ap.add_argument("--cohort-cap", type=int, default=None,
                    help="capacity-slot scheduling: max cohort clients "
                         "trained per shard (requires --shard-clients; "
                         ">= min(--per-round, clients/shards); the natural "
                         "setting is --per-round)")
    ap.add_argument("--scenario", choices=SCENARIO_NAMES, default=None,
                    help="system-heterogeneity scenario (DESIGN.md §9): "
                         "per-client latency model + optional availability "
                         "masks; prices a simulated round wall clock")
    ap.add_argument("--staleness-bound", type=int, default=None,
                    help="bounded-staleness aggregation: max rounds a shard "
                         "may lag (requires --shard-clients and --scenario; "
                         "0 = synchronous semantics)")
    ap.add_argument("--staleness-decay", choices=DECAY_FAMILIES,
                    default="polynomial",
                    help="staleness-decay weighting family for stale "
                         "contributions")
    ap.add_argument("--staleness-alpha", type=float, default=0.5,
                    help="decay rate for polynomial/exponential staleness "
                         "weighting")
    ap.add_argument("--candidate-frac", type=float, default=None,
                    help="two-stage selection funnel (DESIGN.md §10): keep "
                         "Q = F*C prefilter candidates and run the DPP on "
                         "the QxQ block only (F in (0, 1]; 1.0 is "
                         "bit-identical to no funnel)")
    ap.add_argument("--faults", choices=FAULT_NAMES, default=None,
                    help="fault-injection model (DESIGN.md §11): per-client "
                         "dropout, NaN/garbage/sign-flip corruption, shard "
                         "blackout — drawn jit-level off the carried key")
    ap.add_argument("--aggregator", choices=AGGREGATORS, default="mean",
                    help="aggregation mode: mean (eq. 6), clipped_mean "
                         "(norm-clip outliers to the cohort-median "
                         "threshold), trimmed_mean (reject outliers)")
    ap.add_argument("--local-algo", choices=ALGO_NAMES, default="fedavg",
                    help="local-update algorithm (DESIGN.md §12): fedavg "
                         "(plain SGD), fedprox (proximal drift penalty), "
                         "feddyn (per-client linear-penalty state)")
    ap.add_argument("--prox-mu", type=float, default=None,
                    help="fedprox proximal coefficient mu (requires "
                         "--local-algo fedprox)")
    ap.add_argument("--feddyn-alpha", type=float, default=None,
                    help="feddyn penalty coefficient alpha (requires "
                         "--local-algo feddyn)")
    ap.add_argument("--ckpt-every", type=int, default=None,
                    help="snapshot the full ServerState to --ckpt every N "
                         "rounds; a re-launch resumes from the latest "
                         "snapshot bit-identically (requires --ckpt)")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--telemetry", default=None, metavar="PATH",
                    help="write JSONL telemetry (run manifest + per-round "
                         "diagnostics, DESIGN.md §14) to PATH; also turns "
                         "on the in-program Telemetry outputs "
                         "(FLConfig.telemetry)")
    ap.add_argument("--profile-dir", default=None, metavar="PATH",
                    help="capture a jax.profiler trace of the run into PATH "
                         "(TensorBoard-loadable)")
    args = ap.parse_args()
    (run_fl if args.mode == "fl" else run_pretrain)(args)


if __name__ == "__main__":
    main()

"""Production mesh definitions (TPU v5e target).

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state; the 512-device host platform is
forced only inside ``launch/dryrun.py``.

Single pod: 16×16 = 256 chips, axes (data, model).
Multi pod:  2×16×16 = 512 chips, axes (pod, data, model) — the ``pod`` axis
carries the data-parallel/client dimension across pods (DCN-ish boundary).
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "HW"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    axis_type = getattr(jax.sharding, "AxisType", None)  # jax >= 0.5 only
    if axis_type is not None:
        return jax.make_mesh(shape, axes, axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


class HW:
    """TPU v5e hardware constants used by the roofline analysis."""

    PEAK_FLOPS_BF16 = 197e12  # per chip
    HBM_BW = 819e9  # bytes/s per chip
    ICI_BW = 50e9  # bytes/s per link
    HBM_BYTES = 16 * 2**30  # per chip

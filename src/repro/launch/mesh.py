"""Production mesh definitions (TPU v5e target).

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state; the 512-device host platform is
forced only inside ``launch/dryrun.py``.

Single pod: 16×16 = 256 chips, axes (data, model).
Multi pod:  2×16×16 = 512 chips, axes (pod, data, model) — the ``pod`` axis
carries the data-parallel/client dimension across pods (DCN-ish boundary).
"""

from __future__ import annotations

from typing import Optional

import jax
import numpy as np

from repro.launch.sharding import CLIENT_AXIS

__all__ = ["make_production_mesh", "make_client_mesh", "HW"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    axis_type = getattr(jax.sharding, "AxisType", None)  # jax >= 0.5 only
    if axis_type is not None:
        return jax.make_mesh(shape, axes, axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_client_mesh(
    num_devices: Optional[int] = None, axis: str = CLIENT_AXIS
) -> jax.sharding.Mesh:
    """1-D mesh carrying the federation's client axis (DESIGN.md §8).

    Uses the first ``num_devices`` visible devices (all of them by default) —
    on CPU hosts scale the axis with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N``.
    """
    devices = jax.devices()
    if num_devices is not None:
        if num_devices > len(devices):
            raise ValueError(
                f"requested {num_devices} devices, only {len(devices)} visible "
                "(set --xla_force_host_platform_device_count on CPU)"
            )
        devices = devices[:num_devices]
    return jax.sharding.Mesh(np.asarray(devices), (axis,))


class HW:
    """TPU v5e hardware constants used by the roofline analysis."""

    PEAK_FLOPS_BF16 = 197e12  # per chip
    HBM_BW = 819e9  # bytes/s per chip
    ICI_BW = 50e9  # bytes/s per link
    HBM_BYTES = 16 * 2**30  # per chip

"""Serving driver: prefill + batched decode against the KV cache.

Three decode modes over the same reduced model (DESIGN.md §13):

* legacy (default) — host Python loop, one jit dispatch per token.  Kept as
  the parity oracle: greedy scan mode must reproduce its tokens bit for bit.
* ``--scan`` — the serving engine's ``lax.scan``-compiled decode: the whole
  generation is one compiled program (greedy or ``--temperature`` sampling).
* ``--continuous`` — slot-based continuous batching via
  :class:`repro.serve.ServeEngine`: ``--requests`` sequences stream through
  ``--batch`` slots, finished slots refilled from the admission queue with
  zero recompilation.

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m \
        --batch 4 --prompt-len 32 --gen 32 --scan --check

Token accounting is identical across modes: prefill is charged the ``b*p``
prompt tokens *and* samples the first generated token (so generated totals
are ``b*g``); decode is charged the remaining ``b*(g-1)``.  All timings use
``time.perf_counter()``.
"""

from __future__ import annotations

import argparse
import contextlib
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_NAMES, get_arch
from repro.models import transformer as T
from repro.obs import TelemetrySink
from repro.obs import tracing as obs_tracing_lib
from repro.serve import (
    ServeConfig,
    ServeEngine,
    init_decode_state,
    make_decode_fn,
    run_scan,
)


def build_model(arch: str, seed: int):
    cfg = get_arch(arch).model.reduced(param_dtype="float32", dtype="float32", remat=False)
    params = T.init_params(jax.random.key(seed), cfg)
    return cfg, params


def _prefill_fn(cfg, b, p):
    @jax.jit
    def prefill(params, tokens, caches):
        positions = jnp.broadcast_to(jnp.arange(p)[None], (b, p))
        if cfg.pos_style == "mrope":
            positions = jnp.broadcast_to(positions[None], (3, b, p))
        hidden, caches, _ = T.forward(cfg, params, tokens, positions, caches)
        return T.logits_from_hidden(cfg, params, hidden[:, -1:]), caches

    return prefill


def run_legacy(cfg, params, prompts, gen: int):
    """Host-loop greedy decode — the parity oracle.

    -> (tokens (B, gen), {"t_prefill": s, "t_decode": s})."""
    b, p = prompts.shape
    prefill = _prefill_fn(cfg, b, p)
    decode = jax.jit(lambda prm, tok, c: T.decode_step(cfg, prm, tok, c))

    caches = T.init_caches(cfg, b, p + gen)
    t0 = time.perf_counter()
    logits, caches = prefill(params, prompts, caches)
    toks = jnp.argmax(logits[:, 0], axis=-1)[:, None].astype(jnp.int32)
    jax.block_until_ready(toks)  # first generated token belongs to prefill
    t_prefill = time.perf_counter() - t0

    out = [toks]
    t0 = time.perf_counter()
    for _ in range(gen - 1):
        logits, caches = decode(params, toks, caches)
        toks = jnp.argmax(logits[:, 0], axis=-1)[:, None].astype(jnp.int32)
        out.append(toks)
    jax.block_until_ready(toks)
    t_decode = time.perf_counter() - t0
    gen_toks = np.asarray(jnp.concatenate(out, axis=1))
    return gen_toks, {"t_prefill": t_prefill, "t_decode": t_decode}


def run_scan_mode(cfg, params, prompts, gen: int, temperature: float = 0.0,
                  use_flash: bool = False, seed: int = 0):
    """Engine scan decode: batch prefill into per-slot caches, then the whole
    generation as one compiled ``lax.scan``.

    -> (tokens (B, gen), {"t_prefill": s, "t_decode": s})."""
    b, p = prompts.shape
    scfg = ServeConfig(batch=b, cache_len=p + gen, max_new=gen,
                       temperature=temperature, use_flash=use_flash)
    prefill = _prefill_fn(cfg, b, p)
    decode_fn = make_decode_fn(cfg, scfg)
    scan = jax.jit(lambda prm, s: run_scan(decode_fn, prm, s, gen - 1))

    state = init_decode_state(cfg, scfg, jax.random.key(seed))
    caches = T.init_caches(cfg, b, p + gen, per_slot=True)
    t0 = time.perf_counter()
    logits, caches = prefill(params, prompts, caches)
    from repro.serve.sampling import sample_tokens

    tok0, keys = jax.jit(sample_tokens, static_argnums=2)(
        logits, state.sample_keys, temperature
    )
    jax.block_until_ready(tok0)
    t_prefill = time.perf_counter() - t0

    state = dataclasses.replace(
        state,
        caches=caches,
        last_tok=tok0[:, None],
        out_tokens=state.out_tokens.at[:, 0].set(tok0),
        n_gen=jnp.ones((b,), jnp.int32),
        gen_target=jnp.full((b,), gen, jnp.int32),
        active=jnp.ones((b,), bool),
        seq_ids=jnp.arange(b, dtype=jnp.int32),
        sample_keys=keys,
    )
    t0 = time.perf_counter()
    state = scan(params, state)
    jax.block_until_ready(state.out_tokens)
    t_decode = time.perf_counter() - t0
    return np.asarray(state.out_tokens), {"t_prefill": t_prefill, "t_decode": t_decode}


def run_continuous(cfg, params, prompts, budgets, batch: int,
                   temperature: float = 0.0, decode_chunk: int = 8,
                   use_flash: bool = False, seed: int = 0, telemetry=None):
    """Continuous batching: stream len(prompts) requests through ``batch``
    slots.  -> (finished list, {"t_total": s, "tokens": n, "compiles": {...}}).

    ``telemetry`` (a :class:`repro.obs.TelemetrySink`) records the TTFT /
    per-chunk tok/s / occupancy / queue-depth series (DESIGN.md §14)."""
    n, p = prompts.shape
    gmax = int(max(budgets))
    scfg = ServeConfig(batch=batch, cache_len=p + gmax, max_new=gmax,
                       temperature=temperature, decode_chunk=decode_chunk,
                       use_flash=use_flash)
    eng = ServeEngine(cfg, scfg, params, prompt_len=p, key=jax.random.key(seed),
                      telemetry=telemetry)
    t0 = time.perf_counter()
    for i in range(n):
        eng.submit(np.asarray(prompts[i]), int(budgets[i]))
    finished = eng.run()
    t_total = time.perf_counter() - t0
    tokens = sum(len(f.tokens) for f in finished)
    return finished, {"t_total": t_total, "tokens": tokens,
                      "compiles": eng.compile_counts()}


def serve(args):
    cfg, params = build_model(args.arch, args.seed)
    b, p, g = args.batch, args.prompt_len, args.gen
    prompts = jax.random.randint(jax.random.key(1), (b, p), 0, cfg.vocab_size, jnp.int32)
    print(f"arch={args.arch} (reduced) batch={b} prompt={p} gen={g}")

    stack = contextlib.ExitStack()
    sink = None
    telemetry_path = getattr(args, "telemetry", None)
    with stack:
        stack.enter_context(
            obs_tracing_lib.trace(getattr(args, "profile_dir", None))
        )
        if telemetry_path:
            sink = stack.enter_context(TelemetrySink(telemetry_path))
            sink.write_manifest(
                config={"arch": args.arch, "batch": b, "prompt_len": p,
                        "gen": g, "temperature": args.temperature,
                        "use_flash": bool(args.flash), "seed": args.seed},
                extra={"mode": "serve"},
            )

        if args.continuous:
            n = args.requests or 2 * b
            all_prompts = jax.random.randint(
                jax.random.key(1), (n, p), 0, cfg.vocab_size, jnp.int32
            )
            rng = np.random.default_rng(args.seed)
            budgets = rng.integers(max(1, g // 4), g + 1, size=n) if args.mixed \
                else np.full(n, g)
            finished, stats = run_continuous(
                cfg, params, all_prompts, budgets, b,
                temperature=args.temperature, use_flash=args.flash,
                seed=args.seed, telemetry=sink,
            )
            print(f"continuous: {len(finished)} seqs, {stats['tokens']} generated "
                  f"tokens in {stats['t_total']*1e3:.1f} ms "
                  f"({stats['tokens']/stats['t_total']:,.0f} tok/s aggregate)")
            print(f"compiled programs: {stats['compiles']}")
            if sink is not None:
                print(f"telemetry -> {telemetry_path} (render with "
                      f"`python -m repro.analysis.report {telemetry_path}`)")
            return finished

        if args.scan:
            gen_toks, t = run_scan_mode(
                cfg, params, prompts, g, temperature=args.temperature,
                use_flash=args.flash, seed=args.seed,
            )
            mode = "scan"
        else:
            if args.temperature:
                raise SystemExit("--temperature requires --scan or --continuous "
                                 "(the legacy oracle is greedy-only)")
            gen_toks, t = run_legacy(cfg, params, prompts, g)
            mode = "legacy"

        if sink is not None:
            # batch modes have no admission queue — one summary event
            sink.emit("serve_summary", mode=mode, t_prefill_s=t["t_prefill"],
                      t_decode_s=t["t_decode"], tokens=b * g,
                      decode_tok_s=b * (g - 1) / max(t["t_decode"], 1e-9))
            print(f"telemetry -> {telemetry_path}")

    print(f"prefill: {t['t_prefill']*1e3:.1f} ms "
          f"({b*p/t['t_prefill']:,.0f} prompt tok/s, +{b} sampled)")
    print(f"decode[{mode}]: {t['t_decode']*1e3:.1f} ms "
          f"({b*(g-1)/max(t['t_decode'],1e-9):,.0f} tok/s)")
    print(f"generated total: {b*g} tokens")
    print("sample tokens:", gen_toks[0, :16].tolist())

    if args.check:
        if args.temperature:
            raise SystemExit("--check compares against the greedy oracle; "
                             "drop --temperature")
        oracle, _ = run_legacy(cfg, params, prompts, g)
        if not (gen_toks == oracle).all():
            raise SystemExit("parity FAILED: scan tokens != legacy tokens")
        print("parity OK: scan tokens bit-identical to legacy loop")
    return gen_toks


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=ARCH_NAMES, default="smollm-360m")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--scan", action="store_true",
                    help="scan-compiled decode (serving engine)")
    ap.add_argument("--continuous", action="store_true",
                    help="slot-based continuous batching")
    ap.add_argument("--requests", type=int, default=0,
                    help="continuous mode: total requests (default 2*batch)")
    ap.add_argument("--mixed", action="store_true",
                    help="continuous mode: mixed generation budgets")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--flash", action="store_true",
                    help="route decode attention through the Pallas flash-decode kernel")
    ap.add_argument("--check", action="store_true",
                    help="assert scan tokens match the legacy oracle")
    ap.add_argument("--telemetry", default=None, metavar="PATH",
                    help="write JSONL telemetry to PATH (manifest + TTFT / "
                         "per-chunk tok/s / occupancy / queue-depth series "
                         "in --continuous mode, DESIGN.md §14)")
    ap.add_argument("--profile-dir", default=None, metavar="PATH",
                    help="capture a jax.profiler trace of the run into PATH "
                         "(TensorBoard-loadable)")
    serve(ap.parse_args())


if __name__ == "__main__":
    main()

"""Serving driver: prefill + batched decode against the KV cache.

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m \
        --batch 4 --prompt-len 32 --gen 32
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_NAMES, get_arch
from repro.models import transformer as T


def serve(args):
    spec = get_arch(args.arch)
    cfg = spec.model.reduced(param_dtype="float32", dtype="float32", remat=False)
    params = T.init_params(jax.random.key(args.seed), cfg)
    b, p, g = args.batch, args.prompt_len, args.gen
    cache_len = p + g
    prompts = jax.random.randint(jax.random.key(1), (b, p), 0, cfg.vocab_size)

    @jax.jit
    def prefill(params, tokens, caches):
        positions = jnp.broadcast_to(jnp.arange(p)[None], (b, p))
        if cfg.pos_style == "mrope":
            positions = jnp.broadcast_to(positions[None], (3, b, p))
        hidden, caches, _ = T.forward(cfg, params, tokens, positions, caches)
        return T.logits_from_hidden(cfg, params, hidden[:, -1:]), caches

    decode = jax.jit(lambda prm, tok, c: T.decode_step(cfg, prm, tok, c))

    caches = T.init_caches(cfg, b, cache_len)
    t0 = time.time()
    logits, caches = prefill(params, prompts, caches)
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0

    toks = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    out = [toks]
    t0 = time.time()
    for _ in range(g - 1):
        logits, caches = decode(params, toks, caches)
        toks = jnp.argmax(logits[:, 0], axis=-1)[:, None].astype(jnp.int32)
        out.append(toks)
    jax.block_until_ready(toks)
    t_dec = time.time() - t0
    gen = np.asarray(jnp.concatenate(out, axis=1))
    print(f"arch={args.arch} (reduced) batch={b} prompt={p} gen={g}")
    print(f"prefill: {t_prefill*1e3:.1f} ms ({b*p/t_prefill:,.0f} tok/s)")
    print(f"decode:  {t_dec*1e3:.1f} ms ({b*(g-1)/max(t_dec,1e-9):,.0f} tok/s)")
    print("sample tokens:", gen[0, :16].tolist())
    return gen


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=ARCH_NAMES, default="smollm-360m")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    serve(ap.parse_args())


if __name__ == "__main__":
    main()

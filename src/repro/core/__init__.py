"""FL-DP³S core: data profiling, eq.-(14) similarity kernel, k-DPP selection.

The paper's primary contribution as a composable JAX module — see DESIGN.md §1.
"""

from repro.core.dpp import (
    KDPPSamplerState,
    elementary_symmetric,
    greedy_map_kdpp,
    kdpp_log_prob,
    kdpp_sampler_state,
    log_det_subset,
    sample_kdpp,
    sample_kdpp_from_eigh,
)
from repro.core.metrics import cohort_label_distribution, gemd, label_distribution
from repro.core.profiles import (
    fc1_profile,
    gradient_profile,
    profile_all_clients,
    representative_gradient_profile,
)
from repro.core.selection import (
    CandidateSet,
    ClusterSelection,
    DPPSelection,
    FedSAESelection,
    PowerOfChoiceSelection,
    RoundState,
    SelectionStrategy,
    UniformSelection,
    funnel_candidates,
    funnel_scores,
    make_strategy,
)
from repro.core.similarity import (
    candidate_kernel,
    dpp_kernel,
    kernel_from_profiles,
    pairwise_dists,
    pairwise_sq_dists,
    similarity_matrix,
)

"""Diversity / heterogeneity metrics.

GEMD (group earth mover's distance, paper eq. 15) quantifies how far the
label distribution of the selected cohort's *union* dataset is from the global
label distribution; lower = more diverse/representative cohort.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "safe_div",
    "finite_mean",
    "gemd",
    "label_distribution",
    "cohort_label_distribution",
]


def safe_div(num: jax.Array, den: jax.Array, eps: float = 1e-30) -> jax.Array:
    """``num / max(den, eps)`` — the weighted-sum denominator guard.

    One shared definition for every Σwᵢ·xᵢ / Σwᵢ normalisation (eq. 6 FedAvg,
    eq. 15 cohort label mix): an all-zero weight vector yields 0, never
    inf/NaN.  ``eps`` floors only the denominator, so any real weight sum
    (≥ 1 sample) is untouched.
    """
    return num / jnp.maximum(den, eps)


def finite_mean(x: jax.Array, where: jax.Array = None) -> jax.Array:
    """Mean over the finite (optionally ``where``-masked) entries of ``x``.

    The NaN-aware round-mean helper (DESIGN.md §11): NaN is the documented
    non-cohort loss mask and a NaN/Inf-corrupt client's loss report is
    garbage, so round summaries reduce only over finite entries.  Returns
    NaN (not 0) when nothing qualifies — a dead round must not read as
    perfect convergence.  ``jnp.where`` (never ``mask·x``) keeps a masked
    NaN from poisoning the sum, and the reduction order over the kept
    entries matches a plain masked sum, so all-finite inputs are
    bit-identical to the pre-guard mean.
    """
    ok = jnp.isfinite(x)
    if where is not None:
        ok = ok & where
    tot = jnp.sum(jnp.where(ok, x, jnp.zeros((), x.dtype)))
    cnt = jnp.sum(ok.astype(jnp.float32))
    return jnp.where(cnt > 0, tot / jnp.maximum(cnt, 1.0), jnp.nan)


def label_distribution(ys: jax.Array, num_classes: int) -> jax.Array:
    """Empirical label distribution P(y = j) of one dataset."""
    counts = jnp.bincount(ys.astype(jnp.int32), length=num_classes)
    return counts / jnp.maximum(jnp.sum(counts), 1)


def cohort_label_distribution(
    client_dists: jax.Array, client_sizes: jax.Array, selected: jax.Array
) -> jax.Array:
    """Size-weighted label distribution of the union of selected clients.

    ``client_dists``: (C, N) per-client label distributions P_c(y = j);
    ``client_sizes``: (C,) n_c; ``selected``: (k,) int indices.
    """
    n = client_sizes[selected].astype(jnp.float32)
    d = client_dists[selected]
    return safe_div((n[:, None] * d).sum(0), n.sum())


def gemd(
    client_dists: jax.Array,
    client_sizes: jax.Array,
    selected: jax.Array,
    global_dist: jax.Array,
) -> jax.Array:
    """Group earth mover's distance of a cohort (paper eq. 15).

    ``G(C_t) = Σ_j | Σ_c n_c P_c(j) / Σ_c n_c − P_g(j) |``
    """
    mix = cohort_label_distribution(client_dists, client_sizes, selected)
    return jnp.sum(jnp.abs(mix - global_dist))

"""Diversity / heterogeneity metrics.

GEMD (group earth mover's distance, paper eq. 15) quantifies how far the
label distribution of the selected cohort's *union* dataset is from the global
label distribution; lower = more diverse/representative cohort.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["safe_div", "gemd", "label_distribution", "cohort_label_distribution"]


def safe_div(num: jax.Array, den: jax.Array, eps: float = 1e-30) -> jax.Array:
    """``num / max(den, eps)`` — the weighted-sum denominator guard.

    One shared definition for every Σwᵢ·xᵢ / Σwᵢ normalisation (eq. 6 FedAvg,
    eq. 15 cohort label mix): an all-zero weight vector yields 0, never
    inf/NaN.  ``eps`` floors only the denominator, so any real weight sum
    (≥ 1 sample) is untouched.
    """
    return num / jnp.maximum(den, eps)


def label_distribution(ys: jax.Array, num_classes: int) -> jax.Array:
    """Empirical label distribution P(y = j) of one dataset."""
    counts = jnp.bincount(ys.astype(jnp.int32), length=num_classes)
    return counts / jnp.maximum(jnp.sum(counts), 1)


def cohort_label_distribution(
    client_dists: jax.Array, client_sizes: jax.Array, selected: jax.Array
) -> jax.Array:
    """Size-weighted label distribution of the union of selected clients.

    ``client_dists``: (C, N) per-client label distributions P_c(y = j);
    ``client_sizes``: (C,) n_c; ``selected``: (k,) int indices.
    """
    n = client_sizes[selected].astype(jnp.float32)
    d = client_dists[selected]
    return safe_div((n[:, None] * d).sum(0), n.sum())


def gemd(
    client_dists: jax.Array,
    client_sizes: jax.Array,
    selected: jax.Array,
    global_dist: jax.Array,
) -> jax.Array:
    """Group earth mover's distance of a cohort (paper eq. 15).

    ``G(C_t) = Σ_j | Σ_c n_c P_c(j) / Σ_c n_c − P_g(j) |``
    """
    mix = cohort_label_distribution(client_dists, client_sizes, selected)
    return jnp.sum(jnp.abs(mix - global_dist))

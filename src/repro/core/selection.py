"""Client-selection strategies (paper §3.3 + §4 baselines).

* :class:`DPPSelection` — FL-DP³S (the paper): k-DPP over the eq.-(14) kernel.
* :class:`UniformSelection` — FedAvg's uniform-without-replacement sampling.
* :class:`FedSAESelection` — prefers clients with higher local loss
  (Li et al., IJCNN'21, as characterised in the paper's §4).
* :class:`ClusterSelection` — clustered sampling (Fraboni et al., ICML'21,
  Alg. 2): agglomerative clustering of client fingerprints into C_p clusters,
  one client drawn per cluster ∝ n_c.
* :class:`PowerOfChoiceSelection` — beyond-paper extra baseline (Cho et al.):
  d uniform candidates, keep the C_p with the highest loss.

Two layers of API (DESIGN.md §7, §12):

* ``draw_fn(key, SelectionState, k, avail=None) -> (k,) int32`` — THE
  canonical overridable: one **pure, jit/vmap/scan-compatible** entry point
  per strategy, availability-aware via the optional ``avail`` mask (a
  static ``avail is None`` branch, so the mask-free program is bit-identical
  to the old ``select_fn``).  :class:`SelectionState` is a registered pytree
  of concrete arrays (kernel, losses, sizes, precomputed cluster labels), so
  the whole federation round — selection included — compiles into a single
  ``lax.scan`` with zero host round-trips (see ``repro.fl.engine``).
  Anything that genuinely needs the host (agglomerative clustering) happens
  once in ``fit()``, not per round.  The legacy ``select_fn`` /
  ``select_avail_fn`` pair survives as base-class adapters over ``draw_fn``
  (and pre-registry strategies that still override the pair keep working —
  the base ``draw_fn`` dispatches to their overrides).  The engine calls
  ``select_global_fn``, the funnel-aware wrapper around ``draw_fn``.
* ``select(key, RoundState, k)`` — the legacy convenience wrapper.
  ``RoundState`` carries whatever the server legitimately knows: the one-shot
  profiles/kernel, last-known local losses, and client sizes — never raw
  data.  It builds a :class:`SelectionState` (running ``fit()`` if needed)
  and delegates to the draw.
"""

from __future__ import annotations

import dataclasses
import functools
import hashlib
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dpp as dpp_mod

__all__ = [
    "RoundState",
    "CandidateSet",
    "SelectionState",
    "availability_logits",
    "candidate_availability",
    "funnel_scores",
    "funnel_candidates",
    "selection_state",
    "SelectionStrategy",
    "UniformSelection",
    "DPPSelection",
    "FedSAESelection",
    "ClusterSelection",
    "PowerOfChoiceSelection",
    "make_strategy",
    "STRATEGY_NAMES",
]


@dataclasses.dataclass
class RoundState:
    """Server-side knowledge available to a selection strategy (host view)."""

    num_clients: int
    round: int = 0
    kernel: Optional[jax.Array] = None  # (C, C) PSD, from profiles (eq. 14)
    profiles: Optional[jax.Array] = None  # (C, Q)
    losses: Optional[jax.Array] = None  # (C,) last-known local losses
    client_sizes: Optional[jax.Array] = None  # (C,) n_c
    grad_profiles: Optional[jax.Array] = None  # (C, G) representative gradients


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class CandidateSet:
    """Stage-1 output of the two-stage selection funnel (DESIGN.md §10):
    the global ids of the Q clients that survived the cheap prefilter.

    ``ids`` is **sorted ascending**, which makes the degenerate Q=C funnel
    the identity permutation (``arange(C)``) — the bit-identical-parity
    contract every funnel test leans on.  A :class:`SelectionState` whose
    ``candidates`` is a :class:`CandidateSet` is *candidate-space*: kernel
    (Q, Q), losses/sizes/labels (Q,), spectral cache over the Q×Q block."""

    ids: jax.Array  # (Q,) int32 global client ids, sorted ascending

    @property
    def size(self) -> int:
        return self.ids.shape[0]


def funnel_scores(
    losses: jax.Array,
    avail: Optional[jax.Array] = None,
    latency: Optional[jax.Array] = None,
) -> jax.Array:
    """Stage-1 prefilter score (DESIGN.md §10), cheap and O(C):

        score_i = max(loss_i, eps) / (1 + max(latency_i, 0)) * avail_i

    High running loss promotes a client (FedSAE's signal, eq.-SAE in §4 of
    the paper's baselines); predicted latency demotes stragglers; an
    unavailable client scores exactly 0, so with ≥ Q available clients no
    unavailable one enters the candidate set, and ties at 0 break
    deterministically by client id (``top_k`` index order).  Pure/jittable,
    never touches profiles — the privacy point of the funnel: only the Q
    survivors are ever asked to upload an eq.-(11) profile."""
    score = jnp.maximum(losses.astype(jnp.float32), 1e-8)
    if latency is not None:
        score = score / (1.0 + jnp.maximum(latency.astype(jnp.float32), 0.0))
    if avail is not None:
        score = score * avail.astype(jnp.float32)
    return score


def funnel_candidates(scores: jax.Array, q: int) -> jax.Array:
    """Top-``q`` prefilter survivors as **ascending** global ids (see
    :class:`CandidateSet` for why ordering matters).  One fused ``top_k``
    over the full federation — the only O(C) step of a funneled round."""
    _, idx = jax.lax.top_k(scores, q)
    return jnp.sort(idx).astype(jnp.int32)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class SelectionState:
    """Pure-array view of :class:`RoundState` — a pytree every ``select_fn``
    can consume under ``jit``/``vmap``/``scan``.  All fields are concrete
    (no ``None``) so the pytree structure is stable across rounds.

    ``eig_state`` is the k-DPP **spectral cache** (one eigh + ESP table,
    DESIGN.md §6): the engine computes it at init / reprofile boundaries so
    the per-round DPP draw never re-decomposes.  Strategies that never draw
    from a DPP carry the cheap identity-kernel cache (same pytree layout)."""

    kernel: jax.Array  # (C, C) PSD profile kernel
    losses: jax.Array  # (C,) last-known local losses
    client_sizes: jax.Array  # (C,) n_c
    cluster_labels: jax.Array  # (C,) int32 — host-fitted, 0 when unused
    eig_state: dpp_mod.KDPPSamplerState  # spectral cache of ``kernel``
    # Two-stage funnel (DESIGN.md §10): when set, every array field above is
    # candidate-space (Q-sized) and ``candidates.ids`` maps local -> global.
    candidates: Optional[CandidateSet] = None

    @property
    def num_clients(self) -> int:
        """Population the ``select_fn``s draw over — Q under the funnel."""
        return self.losses.shape[0]


def selection_state(
    num_clients: int,
    k: int,
    kernel: Optional[jax.Array] = None,
    losses: Optional[jax.Array] = None,
    client_sizes: Optional[jax.Array] = None,
    cluster_labels: Optional[jax.Array] = None,
    eig_state: Optional[dpp_mod.KDPPSamplerState] = None,
    decompose_kernel: bool = False,
    candidates: Optional[CandidateSet] = None,
) -> SelectionState:
    """Build a :class:`SelectionState`, filling neutral defaults for the
    signals a given strategy does not use.

    ``k`` (the cohort size) shapes the spectral cache's ESP table.  The
    eigendecomposition is only paid when ``decompose_kernel=True`` (the DPP
    strategy's ``prepare``) and no precomputed ``eig_state`` is passed in;
    every other strategy gets the O(k·C) identity cache.
    """
    c = num_clients
    if eig_state is None:
        if decompose_kernel and kernel is not None:
            eig_state = dpp_mod.kdpp_sampler_state(kernel, k)
        else:
            eig_state = dpp_mod.identity_sampler_state(c, k)
    return SelectionState(
        kernel=jnp.eye(c, dtype=jnp.float32) if kernel is None else kernel,
        losses=jnp.ones((c,), jnp.float32) if losses is None else losses,
        client_sizes=(
            jnp.ones((c,), jnp.float32) if client_sizes is None else client_sizes
        ),
        cluster_labels=(
            jnp.zeros((c,), jnp.int32) if cluster_labels is None else cluster_labels
        ),
        eig_state=eig_state,
        candidates=candidates,
    )


def availability_logits(
    avail: jax.Array, k: int, logits: jax.Array
) -> jax.Array:
    """Mask sampling logits to available clients, with a degenerate-mask
    fallback: when fewer than ``k`` clients are available the unmasked
    logits are used unchanged (the round must still field a k-cohort —
    DESIGN.md §9 documents the convention).  Pure/jittable."""
    masked = jnp.where(avail, logits, -jnp.inf)
    enough = jnp.sum(avail) >= k
    return jnp.where(enough, masked, logits)


def candidate_availability(
    avail: jax.Array, candidates: CandidateSet
) -> jax.Array:
    """Gather a global (C,) availability mask into candidate space — THE
    shared guard in front of every ``select_avail_fn`` (DESIGN.md §10).

    Under the funnel the strategies only ever see this (Q,) view, so the
    <k-available fallback of :func:`availability_logits` — "drop the mask,
    use the unmasked logits" — can only fall back to *candidates*: logits
    are candidate-space, and the gather-back maps the draw through
    ``candidates.ids``.  Selecting a non-candidate is unrepresentable."""
    return jnp.take(avail, candidates.ids)


class SelectionStrategy:
    name = "base"
    # True when select_fn draws from SelectionState.eig_state: tells state
    # builders (engine init, reprofile boundaries) to pay the O(C³) eigh;
    # everyone else gets the O(k·C) identity-layout placeholder.
    uses_spectral_cache = False

    # -- pure path (engine) -------------------------------------------------
    def draw_fn(
        self,
        key: jax.Array,
        state: SelectionState,
        k: int,
        avail: Optional[jax.Array] = None,
    ) -> jax.Array:
        """THE canonical pure draw: ``(key, SelectionState, static k,
        avail=None) -> (k,) int32`` — what every strategy overrides.

        ``avail`` (a (C,) bool mask from a scenario's availability model,
        DESIGN.md §9) restricts the draw when given; ``avail is None`` is a
        *static* branch, so the mask-free program is exactly the strategy's
        plain draw.  All built-ins share one fallback convention
        (:func:`availability_logits`): with fewer than ``k`` available
        clients the unmasked draw is used.

        The base implementation is the backward-compat adapter for
        pre-registry strategies that still override the legacy
        ``select_fn`` / ``select_avail_fn`` pair: it dispatches to whichever
        of the two the subclass actually overrode (an un-overridden
        ``select_avail_fn`` falls through to ``select_fn`` — the old
        availability-*blind* base default)."""
        base = SelectionStrategy
        if avail is not None and type(self).select_avail_fn is not base.select_avail_fn:
            return self.select_avail_fn(key, state, k, avail)
        if type(self).select_fn is not base.select_fn:
            return self.select_fn(key, state, k)
        raise NotImplementedError(
            f"{type(self).__name__} must override draw_fn (or the legacy "
            "select_fn)"
        )

    def select_fn(self, key: jax.Array, state: SelectionState, k: int) -> jax.Array:
        """Legacy adapter: the mask-free draw.  Override :meth:`draw_fn`."""
        return self.draw_fn(key, state, k)

    def select_avail_fn(
        self, key: jax.Array, state: SelectionState, k: int, avail: jax.Array
    ) -> jax.Array:
        """Legacy adapter: the availability-masked draw.  Override
        :meth:`draw_fn`."""
        return self.draw_fn(key, state, k, avail)

    def select_global_fn(
        self,
        key: jax.Array,
        state: SelectionState,
        k: int,
        avail: Optional[jax.Array] = None,
    ) -> jax.Array:
        """Selection in **global** client ids, funnel-aware (DESIGN.md §10).

        Without a funnel (``state.candidates is None``) this is exactly
        :meth:`draw_fn`.  With one, ``state`` is candidate-space: the draw
        happens over the Q candidates (``avail``, a *global* (C,) mask, is
        first gathered through :func:`candidate_availability` — the shared
        guard) and the local picks are mapped back through
        ``candidates.ids``.  Pure/jittable; this is the one entry point the
        engine's round dispatch calls."""
        cand = state.candidates
        if cand is None:
            return self.draw_fn(key, state, k, avail)
        local = self.draw_fn(
            key, state, k,
            None if avail is None else candidate_availability(avail, cand),
        )
        return jnp.take(cand.ids, local).astype(jnp.int32)

    def prepare(self, state: RoundState, k: int) -> SelectionState:
        """RoundState -> SelectionState (host-side; runs ``fit`` if any)."""
        return selection_state(
            state.num_clients,
            k,
            kernel=state.kernel,
            losses=state.losses,
            client_sizes=state.client_sizes,
        )

    # -- legacy path --------------------------------------------------------
    def select(self, key: jax.Array, state: RoundState, k: int) -> jax.Array:
        return self.select_fn(key, self.prepare(state, k), k)


class UniformSelection(SelectionStrategy):
    """FedAvg: k clients uniformly at random without replacement."""

    name = "fedavg"

    def draw_fn(self, key, state, k, avail=None):
        if avail is None:
            return jax.random.choice(
                key, state.num_clients, shape=(k,), replace=False
            ).astype(jnp.int32)
        logits = availability_logits(
            avail, k, jnp.zeros((state.num_clients,), jnp.float32)
        )
        return _gumbel_topk_without_replacement(key, logits, k)


class DPPSelection(SelectionStrategy):
    """FL-DP³S: sample the cohort from the k-DPP built on the profile kernel.

    ``mode='sample'`` is the paper's stochastic k-DPP; ``mode='map'`` is the
    deterministic greedy-MAP variant (beyond paper; see DESIGN.md §6).

    ``use_cache=True`` (default) draws from ``SelectionState.eig_state`` —
    the spectral cache the engine refreshes only at reprofile boundaries, so
    each scanned round is O(k²·C).  ``use_cache=False`` keeps the
    eigh-per-draw path (the perf baseline; bit-identical selections).
    """

    name = "fl-dp3s"

    def __init__(self, mode: str = "sample", use_cache: bool = True):
        assert mode in ("sample", "map")
        self.mode = mode
        self.use_cache = use_cache
        self.uses_spectral_cache = mode == "sample" and use_cache
        if mode == "map":
            self.name = "fl-dp3s-map"

    def draw_fn(self, key, state, k, avail=None):
        if avail is None:
            if self.mode == "map":
                return dpp_mod.greedy_map_kdpp(state.kernel, k)
            if self.use_cache:
                return dpp_mod.sample_kdpp_from_eigh(key, state.eig_state, k)
            return dpp_mod.sample_kdpp(key, state.kernel, k)
        # Fold the availability mask into the kernel before sampling
        # (DESIGN.md §9): L' = m mᵀ ⊙ L keeps PSD-ness with its spectrum
        # supported on the available block, so the draw can only return
        # available clients.  The spectral cache decomposes the *unmasked*
        # kernel, so availability rounds pay the one-shot eigh path (the
        # mask changes every round — no cacheable spectrum to reuse).
        enough = jnp.sum(avail) >= k
        kern = jnp.where(enough, dpp_mod.masked_kernel(state.kernel, avail),
                         state.kernel)
        if self.mode == "map":
            return dpp_mod.greedy_map_kdpp(kern, k)
        return dpp_mod.sample_kdpp(key, kern, k)

    def prepare(self, state, k):
        assert state.kernel is not None, "DPPSelection needs the profile kernel"
        return selection_state(
            state.num_clients,
            k,
            kernel=state.kernel,
            losses=state.losses,
            client_sizes=state.client_sizes,
            decompose_kernel=self.uses_spectral_cache,
        )


def _gumbel_topk_without_replacement(key, log_weights, k):
    """Weighted sampling without replacement via Gumbel top-k (jittable)."""
    g = jax.random.gumbel(key, log_weights.shape, log_weights.dtype)
    _, idx = jax.lax.top_k(log_weights + g, k)
    return idx.astype(jnp.int32)


class FedSAESelection(SelectionStrategy):
    """Prefer clients with higher local loss (sample ∝ loss, w/o repl.)."""

    name = "fedsae"

    def draw_fn(self, key, state, k, avail=None):
        logits = jnp.log(jnp.maximum(state.losses, 1e-8))
        if avail is not None:
            logits = availability_logits(avail, k, logits)
        return _gumbel_topk_without_replacement(key, logits, k)


class PowerOfChoiceSelection(SelectionStrategy):
    """d uniform candidates -> keep the k with the highest loss."""

    name = "power-of-choice"

    def __init__(self, d: int = 30):
        self.d = d

    def draw_fn(self, key, state, k, avail=None):
        d = min(self.d, state.num_clients)
        k1, _ = jax.random.split(key)
        if avail is None:
            cand = jax.random.choice(
                k1, state.num_clients, shape=(d,), replace=False
            )
            order = jnp.argsort(-state.losses[cand])
            return cand[order[:k]].astype(jnp.int32)
        # candidates drawn uniformly among available clients, then the usual
        # loss top-k.  Gumbel over -inf-masked logits ranks every available
        # client ahead of the unavailable padding, so with ≥ k available the
        # d candidates contain ≥ k available entries; masking the candidate
        # losses then keeps unavailable padding out of the final top-k.  The
        # shared fallback (fewer than k available ⇒ unmasked draw) applies.
        enough = jnp.sum(avail) >= k
        logits = availability_logits(
            avail, k, jnp.zeros((state.num_clients,), jnp.float32)
        )
        cand = _gumbel_topk_without_replacement(k1, logits, d)
        cand_losses = jnp.where(
            avail[cand] | ~enough, state.losses[cand], -jnp.inf
        )
        order = jnp.argsort(-cand_losses)
        return cand[order[:k]].astype(jnp.int32)

    def prepare(self, state, k):
        # unknown losses -> all-equal weights => pure power-of-d over uniforms
        losses = state.losses
        if losses is None:
            losses = jnp.zeros((state.num_clients,))
        return selection_state(
            state.num_clients, k, kernel=state.kernel, losses=losses,
            client_sizes=state.client_sizes,
        )


class ClusterSelection(SelectionStrategy):
    """Clustered sampling (Fraboni et al., Alg. 2).

    Split into the engine-friendly two phases (DESIGN.md §7):

    * :meth:`fit` — **one-shot, host**: agglomerative average-linkage
      clustering (cosine distance) of client fingerprints (representative
      gradients / profiles) into ``k`` clusters.  The labels are cached on
      the *content* of the fingerprints (not just their shape), so refreshed
      profiles — e.g. ``FLConfig.reprofile_every`` — correctly re-cluster.
    * :meth:`select_fn` — **pure, per round**: one client drawn per cluster
      with probability ∝ n_c via ``jax.random.categorical`` over masked
      logits; jit/scan-compatible.
    """

    name = "cluster"

    def __init__(self):
        self._labels: Optional[np.ndarray] = None
        self._fingerprint = None

    @staticmethod
    def _cluster(feats: np.ndarray, k: int) -> np.ndarray:
        c = feats.shape[0]
        norm = np.linalg.norm(feats, axis=1, keepdims=True)
        f = feats / np.maximum(norm, 1e-12)
        sim = f @ f.T
        dist = 1.0 - sim
        # average-linkage agglomerative clustering, O(C^3) worst case — fine
        # for C in the hundreds/thousands (runs once).
        clusters = [[i] for i in range(c)]
        d = dist.copy()
        np.fill_diagonal(d, np.inf)
        active = list(range(c))
        while len(active) > k:
            sub = d[np.ix_(active, active)]
            i_loc, j_loc = np.unravel_index(np.argmin(sub), sub.shape)
            i, j = active[i_loc], active[j_loc]
            if i > j:
                i, j = j, i
            ni, nj = len(clusters[i]), len(clusters[j])
            # average-linkage update of row/col i
            d[i, :] = (ni * d[i, :] + nj * d[j, :]) / (ni + nj)
            d[:, i] = d[i, :]
            d[i, i] = np.inf
            clusters[i] = clusters[i] + clusters[j]
            active.remove(j)
        labels = np.zeros(c, np.int32)
        for lbl, a in enumerate(active):
            labels[np.asarray(clusters[a])] = lbl
        return labels

    def fit(self, feats, k: int) -> jax.Array:
        """Cluster fingerprints into ``k`` labels (cached on content)."""
        feats = np.asarray(feats, np.float32)
        fp = (feats.shape, k, hashlib.sha1(feats.tobytes()).hexdigest())
        if self._fingerprint != fp:
            self._labels = self._cluster(feats, k)
            self._fingerprint = fp
        return jnp.asarray(self._labels, jnp.int32)

    @staticmethod
    def _cluster_logits(member, base):
        """Row l of the (k, C) draw logits: ``base`` masked to cluster l's
        members, falling back to plain ``base`` for rows with no finite
        member entry (empty/degenerate — or fully unavailable, when ``base``
        itself is availability-masked).  The ONE construction both
        :meth:`select_fn` and :meth:`select_avail_fn` draw from, so the
        fewer-than-k-available fallback is provably the unmasked draw."""
        logits = jnp.where(member, base[None, :], -jnp.inf)
        ok = jnp.any(member & jnp.isfinite(base)[None, :], axis=1, keepdims=True)
        return jnp.where(ok, logits, base[None, :])

    def draw_fn(self, key, state, k, avail=None):
        # One vmapped masked-categorical draw over all k clusters (the
        # unrolled Python loop emitted k separate categorical ops into every
        # scanned round).  Row l masks the size-logits to cluster l's
        # members; an empty/degenerate cluster falls back to size-weighted
        # sampling over all clients.  With an availability mask, row l
        # samples cluster l's *available* members ∝ n_c; a cluster with no
        # available member falls back to size-weighted sampling over all
        # available clients, and fewer than k available clients drops the
        # mask entirely (the shared availability_logits convention).
        labels = state.cluster_labels
        log_sizes = jnp.log(jnp.maximum(state.client_sizes, 1e-30))
        member = labels[None, :] == jnp.arange(k, dtype=labels.dtype)[:, None]
        if avail is None:
            logits = self._cluster_logits(member, log_sizes)
        else:
            logits = jnp.where(
                jnp.sum(avail) >= k,
                self._cluster_logits(member, jnp.where(avail, log_sizes, -jnp.inf)),
                self._cluster_logits(member, log_sizes),
            )
        picks = jax.vmap(jax.random.categorical)(jax.random.split(key, k), logits)
        return picks.astype(jnp.int32)

    def prepare(self, state, k):
        # Fraboni et al. cluster on representative gradients when available.
        feats = (
            state.grad_profiles if state.grad_profiles is not None else state.profiles
        )
        assert feats is not None, "ClusterSelection needs client fingerprints"
        return selection_state(
            state.num_clients,
            k,
            kernel=state.kernel,
            losses=state.losses,
            client_sizes=state.client_sizes,
            cluster_labels=self.fit(feats, k),
        )


_REGISTRY = {
    "fedavg": UniformSelection,
    "uniform": UniformSelection,
    "fl-dp3s": DPPSelection,
    "dpp": DPPSelection,
    "fl-dp3s-map": functools.partial(DPPSelection, mode="map"),
    "fedsae": FedSAESelection,
    "cluster": ClusterSelection,
    "power-of-choice": PowerOfChoiceSelection,
}

STRATEGY_NAMES = tuple(sorted(_REGISTRY))


def make_strategy(name: str, **kw) -> SelectionStrategy:
    """Build a strategy by registry name; ``**kw`` forwards uniformly to the
    constructor for every name (e.g. ``make_strategy('power-of-choice', d=20)``
    or ``make_strategy('fl-dp3s', mode='map')``)."""
    try:
        factory = _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown selection strategy {name!r}; known: {list(STRATEGY_NAMES)}"
        ) from None
    return factory(**kw)

"""Client-selection strategies (paper §3.3 + §4 baselines).

* :class:`DPPSelection` — FL-DP³S (the paper): k-DPP over the eq.-(14) kernel.
* :class:`UniformSelection` — FedAvg's uniform-without-replacement sampling.
* :class:`FedSAESelection` — prefers clients with higher local loss
  (Li et al., IJCNN'21, as characterised in the paper's §4).
* :class:`ClusterSelection` — clustered sampling (Fraboni et al., ICML'21,
  Alg. 2): agglomerative clustering of client fingerprints into C_p clusters,
  one client drawn per cluster ∝ n_c.
* :class:`PowerOfChoiceSelection` — beyond-paper extra baseline (Cho et al.):
  d uniform candidates, keep the C_p with the highest loss.

All strategies share ``select(key, state) -> (C_p,) int32 indices``.
``RoundState`` carries whatever the server legitimately knows: the one-shot
profiles/kernel, last-known local losses, and client sizes — never raw data.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dpp as dpp_mod

__all__ = [
    "RoundState",
    "SelectionStrategy",
    "UniformSelection",
    "DPPSelection",
    "FedSAESelection",
    "ClusterSelection",
    "PowerOfChoiceSelection",
    "make_strategy",
]


@dataclasses.dataclass
class RoundState:
    """Server-side knowledge available to a selection strategy."""

    num_clients: int
    round: int = 0
    kernel: Optional[jax.Array] = None  # (C, C) PSD, from profiles (eq. 14)
    profiles: Optional[jax.Array] = None  # (C, Q)
    losses: Optional[jax.Array] = None  # (C,) last-known local losses
    client_sizes: Optional[jax.Array] = None  # (C,) n_c
    grad_profiles: Optional[jax.Array] = None  # (C, G) representative gradients


class SelectionStrategy:
    name = "base"

    def select(self, key: jax.Array, state: RoundState, k: int) -> jax.Array:
        raise NotImplementedError


class UniformSelection(SelectionStrategy):
    """FedAvg: k clients uniformly at random without replacement."""

    name = "fedavg"

    def select(self, key, state, k):
        return jax.random.choice(
            key, state.num_clients, shape=(k,), replace=False
        ).astype(jnp.int32)


class DPPSelection(SelectionStrategy):
    """FL-DP³S: sample the cohort from the k-DPP built on the profile kernel.

    ``mode='sample'`` is the paper's stochastic k-DPP; ``mode='map'`` is the
    deterministic greedy-MAP variant (beyond paper; see DESIGN.md §6).
    """

    name = "fl-dp3s"

    def __init__(self, mode: str = "sample"):
        assert mode in ("sample", "map")
        self.mode = mode
        if mode == "map":
            self.name = "fl-dp3s-map"

    def select(self, key, state, k):
        assert state.kernel is not None, "DPPSelection needs the profile kernel"
        if self.mode == "map":
            return dpp_mod.greedy_map_kdpp(state.kernel, k)
        return dpp_mod.sample_kdpp(key, state.kernel, k)


def _gumbel_topk_without_replacement(key, log_weights, k):
    """Weighted sampling without replacement via Gumbel top-k (jittable)."""
    g = jax.random.gumbel(key, log_weights.shape, log_weights.dtype)
    _, idx = jax.lax.top_k(log_weights + g, k)
    return idx.astype(jnp.int32)


class FedSAESelection(SelectionStrategy):
    """Prefer clients with higher local loss (sample ∝ loss, w/o repl.)."""

    name = "fedsae"

    def select(self, key, state, k):
        losses = state.losses
        if losses is None:
            losses = jnp.ones((state.num_clients,))
        w = jnp.maximum(losses, 1e-8)
        return _gumbel_topk_without_replacement(key, jnp.log(w), k)


class PowerOfChoiceSelection(SelectionStrategy):
    """d uniform candidates -> keep the k with the highest loss."""

    name = "power-of-choice"

    def __init__(self, d: int = 30):
        self.d = d

    def select(self, key, state, k):
        d = min(self.d, state.num_clients)
        k1, _ = jax.random.split(key)
        cand = jax.random.choice(k1, state.num_clients, shape=(d,), replace=False)
        losses = state.losses if state.losses is not None else jnp.zeros((state.num_clients,))
        order = jnp.argsort(-losses[cand])
        return cand[order[:k]].astype(jnp.int32)


class ClusterSelection(SelectionStrategy):
    """Clustered sampling (Fraboni et al., Alg. 2).

    Agglomerative average-linkage clustering (cosine distance) of client
    fingerprints (representative gradients / profiles) into ``k`` clusters;
    each round one client is drawn per cluster with probability ∝ n_c.
    Clustering runs on host once (or whenever fingerprints refresh).
    """

    name = "cluster"

    def __init__(self):
        self._labels = None
        self._for_shape = None

    def _cluster(self, feats: np.ndarray, k: int) -> np.ndarray:
        c = feats.shape[0]
        norm = np.linalg.norm(feats, axis=1, keepdims=True)
        f = feats / np.maximum(norm, 1e-12)
        sim = f @ f.T
        dist = 1.0 - sim
        # average-linkage agglomerative clustering, O(C^3) worst case — fine
        # for C in the hundreds/thousands (runs once).
        clusters = [[i] for i in range(c)]
        d = dist.copy()
        np.fill_diagonal(d, np.inf)
        active = list(range(c))
        while len(active) > k:
            sub = d[np.ix_(active, active)]
            i_loc, j_loc = np.unravel_index(np.argmin(sub), sub.shape)
            i, j = active[i_loc], active[j_loc]
            if i > j:
                i, j = j, i
            ni, nj = len(clusters[i]), len(clusters[j])
            # average-linkage update of row/col i
            d[i, :] = (ni * d[i, :] + nj * d[j, :]) / (ni + nj)
            d[:, i] = d[i, :]
            d[i, i] = np.inf
            clusters[i] = clusters[i] + clusters[j]
            active.remove(j)
        labels = np.zeros(c, np.int32)
        for lbl, a in enumerate(active):
            labels[np.asarray(clusters[a])] = lbl
        return labels

    def select(self, key, state, k):
        # Fraboni et al. cluster on representative gradients when available.
        feats = state.grad_profiles if state.grad_profiles is not None else state.profiles
        assert feats is not None, "ClusterSelection needs client fingerprints"
        feats = np.asarray(feats)
        if self._labels is None or self._for_shape != (feats.shape, k):
            self._labels = self._cluster(feats, k)
            self._for_shape = (feats.shape, k)
        sizes = (
            np.asarray(state.client_sizes)
            if state.client_sizes is not None
            else np.ones(state.num_clients)
        )
        rng = np.random.default_rng(np.asarray(jax.random.key_data(key)).ravel()[-1].item())
        picks = []
        for lbl in range(k):
            members = np.nonzero(self._labels == lbl)[0]
            if len(members) == 0:  # degenerate cluster — fall back to uniform
                members = np.arange(state.num_clients)
            p = sizes[members] / sizes[members].sum()
            picks.append(int(rng.choice(members, p=p)))
        return jnp.asarray(picks, jnp.int32)


def make_strategy(name: str, **kw) -> SelectionStrategy:
    table = {
        "fedavg": UniformSelection,
        "uniform": UniformSelection,
        "fl-dp3s": DPPSelection,
        "dpp": DPPSelection,
        "fl-dp3s-map": lambda: DPPSelection(mode="map"),
        "fedsae": FedSAESelection,
        "cluster": ClusterSelection,
        "power-of-choice": PowerOfChoiceSelection,
    }
    return table[name](**kw) if name not in ("fl-dp3s-map",) else table[name]()

"""k-DPP sampling (Kulesza & Taskar, ICML'11) in pure JAX.

This is the selection engine of FL-DP3S (paper eq. (12)-(13)): given a PSD
similarity kernel ``L`` over ``C`` clients, sample a subset of fixed size
``k = C_p`` with probability proportional to ``det(L_Y)``.

The sampler is factored into a **spectral cache** and a **cheap per-round
draw** so that callers who keep the kernel fixed between reprofile boundaries
(the federation engine, ``repro.fl.engine``) never pay the O(C³) ``eigh``
inside the scanned round:

* :func:`kdpp_sampler_state` — one ``jnp.linalg.eigh`` plus the elementary-
  symmetric-polynomial table, packed into a :class:`KDPPSamplerState` pytree.
  Computed once per kernel refresh; O(C³) but amortised over all rounds of a
  reprofile segment.
* :func:`sample_kdpp_from_eigh` — a pure draw from the cached spectrum:
  phase 1 walks the precomputed ESP table (O(C)), phase 2 samples the k items
  with rank-1 Householder orthogonal-complement conditioning (O(k²·C) total,
  bit-reproducible).  jit/vmap/scan-compatible with static ``k``.
* :func:`sample_kdpp` — the legacy one-shot convenience: decompose + draw in
  one call.  Bit-identical to the two-step path given the same key.
* :func:`greedy_map_kdpp` — deterministic greedy MAP inference (Chen et al.,
  NeurIPS'18 fast greedy MAP), a beyond-paper variant that is O(C·k) per step,
  device-friendly and reproducible — useful at serving scale.

Everything here is **size-agnostic in the leading dimension**: under the
two-stage selection funnel (DESIGN.md §10) the same spectral cache + draw
run on the Q×Q candidate block instead of the full C×C kernel — the eigh
drops from O(C³) to O(Q³) and the per-round draw to O(k²·Q), with local
candidate indices mapped back to global ids by the caller
(``SelectionStrategy.select_global_fn``).
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
from jax import lax

__all__ = [
    "KDPPSamplerState",
    "elementary_symmetric",
    "identity_sampler_state",
    "kdpp_log_prob",
    "kdpp_sampler_state",
    "log_det_subset",
    "greedy_map_kdpp",
    "masked_kernel",
    "sample_kdpp",
    "sample_kdpp_from_eigh",
    "sampler_dtype",
]


def sampler_dtype() -> jnp.dtype:
    """The float dtype the sampler runs in: float64 under x64, else float32.

    Shared dtype-promotion helper for the spectral cache and the one-shot
    path (replaces the deprecated ``jax.config.read("jax_enable_x64")``
    probe): ``canonicalize_dtype`` maps float64 onto the widest enabled
    float type.
    """
    return jax.dtypes.canonicalize_dtype(jnp.float64)


def elementary_symmetric(lam: jax.Array, k: int) -> jax.Array:
    """Elementary symmetric polynomials ``E[l, n] = e_l(lam_1..lam_n)``.

    Returns an array of shape ``(k + 1, N + 1)`` with the standard DP
    recurrence ``E[l, n] = E[l, n-1] + lam_n * E[l-1, n-1]``.
    """
    n = lam.shape[0]

    def body(carry, lam_n):
        # carry: row of E over l = 0..k for prefix length n-1
        prev = carry
        shifted = jnp.concatenate([jnp.zeros((1,), lam.dtype), prev[:-1]])
        new = prev + lam_n * shifted
        return new, new

    init = jnp.zeros((k + 1,), lam.dtype).at[0].set(1.0)
    _, rows = lax.scan(body, init, lam)
    # rows[n-1] is E[:, n]; prepend the n=0 column.
    e = jnp.concatenate([init[:, None], rows.T], axis=1)
    return e  # (k+1, N+1)


# ------------------------------------------------------------ spectral cache


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class KDPPSamplerState:
    """Everything :func:`sample_kdpp_from_eigh` needs — one eigh, many draws.

    ``lam`` holds the clipped eigenvalues *after* the scale normalisation
    phase 1 uses for stability (divide by mean |λ|), so ``esp`` and ``lam``
    share one scale and a draw touches neither the kernel nor ``eigh``.
    All fields are concrete arrays, so the state threads through
    ``lax.scan`` / ``vmap`` and stacks across a run grid.
    """

    lam: jax.Array  # (C,) normalised non-negative eigenvalues
    vecs: jax.Array  # (C, C) orthonormal eigenvectors (columns)
    esp: jax.Array  # (k+1, C+1) elementary-symmetric table of ``lam``

    @property
    def num_items(self) -> int:
        return self.lam.shape[0]

    @property
    def k(self) -> int:
        return self.esp.shape[0] - 1


def _sampler_state(kernel: jax.Array, k: int) -> KDPPSamplerState:
    kernel = kernel.astype(sampler_dtype())
    lam, vecs = jnp.linalg.eigh(kernel)
    lam = jnp.maximum(lam, 0.0)  # clip tiny negative eigenvalues
    lam = lam / jnp.maximum(jnp.mean(jnp.abs(lam)), 1e-30)
    return KDPPSamplerState(
        lam=lam, vecs=vecs, esp=elementary_symmetric(lam, k)
    )


@functools.partial(jax.jit, static_argnames=("k",))
def kdpp_sampler_state(kernel: jax.Array, k: int) -> KDPPSamplerState:
    """Spectral cache for the k-DPP on PSD ``kernel``: the one O(C³) step.

    Compute once per kernel refresh (``init_server_state`` /
    ``reprofile_every`` boundaries in the engine); every subsequent draw via
    :func:`sample_kdpp_from_eigh` is O(k²·C).
    """
    return _sampler_state(kernel, k)


@functools.partial(jax.jit, static_argnames=("num_items", "k"))
def identity_sampler_state(num_items: int, k: int) -> KDPPSamplerState:
    """The spectral cache of the identity kernel, built in O(k·C) (no eigh).

    Used as the neutral ``SelectionState`` default for strategies that never
    draw from a DPP — same pytree structure as a real cache, so the engine's
    ``lax.switch`` branches all consume one state layout.
    """
    dt = sampler_dtype()
    lam = jnp.ones((num_items,), dt)
    return KDPPSamplerState(
        lam=lam,
        vecs=jnp.eye(num_items, dtype=dt),
        esp=elementary_symmetric(lam, k),
    )


# ------------------------------------------------------------------ phases


def _phase1_select_eigenvectors(
    key: jax.Array, lam: jax.Array, esp: jax.Array, k: int
) -> jax.Array:
    """Phase 1: choose exactly ``k`` eigenvectors; returns a bool mask (N,).

    Iterates n = N..1; eigenvector n is kept with probability
    ``lam_n * E[r-1, n-1] / E[r, n]`` where ``r`` is the number of vectors
    still to pick.  ``lam``/``esp`` come precomputed from the sampler state
    (one shared normalised scale), so this is O(N) per draw.
    """
    n = lam.shape[0]

    def body(carry, idx):
        key, rem = carry
        # idx runs 0..N-1 mapping to n = N-idx
        nn = n - idx
        key, sub = jax.random.split(key)
        denom = esp[rem, nn]
        num = lam[nn - 1] * esp[jnp.maximum(rem - 1, 0), nn - 1]
        p = jnp.where(denom > 0, num / denom, 0.0)
        # Force-take when we must (rem == nn) and never take when rem == 0.
        p = jnp.where(rem == nn, 1.0, p)
        p = jnp.where(rem == 0, 0.0, jnp.clip(p, 0.0, 1.0))
        take = jax.random.uniform(sub) < p
        rem = rem - take.astype(rem.dtype)
        return (key, rem), take

    (_, rem), takes = lax.scan(body, (key, jnp.asarray(k, jnp.int32)), jnp.arange(n))
    # takes[idx] corresponds to eigenvector index n-1-idx; reverse to (N,).
    return takes[::-1]


def _phase2_sample_items(key: jax.Array, v_sel: jax.Array, k: int) -> jax.Array:
    """Phase 2: sample ``k`` items from the elementary DPP given by ``v_sel``.

    ``v_sel`` is (N, k) whose columns are the selected eigenvectors (already
    orthonormal).  Returns int32 indices of shape (k,).  After picking item
    ``i`` via p(i) ∝ Σ_c V[i, c]², the subspace is conditioned on the
    complement of e_i with one **rank-1 Householder reflection** in
    coefficient space: H maps row i of V onto a single pivot column, so
    ``V ← V·H`` (an O(k·N) rank-1 update) followed by zeroing that column
    leaves an exactly orthonormal basis of span(V) ∩ e_i^⊥.  O(k²·N) total —
    no per-step Gram-Schmidt re-orthonormalisation — and bit-reproducible.
    """

    def body(carry, _):
        key, v = carry
        key, k_i = jax.random.split(key)
        weights = jnp.sum(v * v, axis=1)  # (N,)
        logits = jnp.log(jnp.maximum(weights, 1e-30))
        i = jax.random.categorical(k_i, logits)
        row = v[i, :]  # (k,) coefficients of e_i in the current basis
        c_star = jnp.argmax(jnp.abs(row))  # pivot column (stability)
        # Householder u = row + sign(row_c)·‖row‖·e_c ; H = I − 2uuᵀ/‖u‖².
        # H·row = ∓‖row‖·e_c, so (V·H) has row i supported on the pivot
        # column only; columns already consumed (zero) have u = 0 and stay
        # untouched.
        u = row.at[c_star].add(jnp.copysign(jnp.linalg.norm(row), row[c_star]))
        beta = 2.0 / jnp.maximum(jnp.dot(u, u), 1e-30)
        v = v - jnp.outer(v @ u, u) * beta
        v = v.at[:, c_star].set(0.0)
        return (key, v), i

    (_, _), items = lax.scan(body, (key, v_sel), None, length=k)
    return items.astype(jnp.int32)


def _sample_from_state(key: jax.Array, state: KDPPSamplerState, k: int) -> jax.Array:
    key1, key2 = jax.random.split(key)
    mask = _phase1_select_eigenvectors(key1, state.lam, state.esp, k)
    # Pack the selected eigenvectors into the first k columns (static shape):
    # order columns by (selected desc, index) and take the top k.
    order = jnp.argsort(~mask, stable=True)  # selected first
    vecs = state.vecs
    v_sel = vecs[:, order[:k]] * mask[order[:k]][None, :].astype(vecs.dtype)
    return _phase2_sample_items(key2, v_sel, k)


@functools.partial(jax.jit, static_argnames=("k",))
def sample_kdpp_from_eigh(
    key: jax.Array, state: KDPPSamplerState, k: int
) -> jax.Array:
    """Draw ``k`` distinct indices from the cached spectrum — no ``eigh``.

    O(k²·C) per draw; pure and scan/vmap-safe.  ``k`` must match the table
    the state was built with (``state.k``).
    """
    if state.esp.shape[0] != k + 1:
        raise ValueError(
            f"sampler state was built for k={state.esp.shape[0] - 1}, got k={k}"
        )
    return _sample_from_state(key, state, k)


@functools.partial(jax.jit, static_argnames=("k",))
def sample_kdpp(key: jax.Array, kernel: jax.Array, k: int) -> jax.Array:
    """Sample ``k`` distinct indices from the k-DPP defined by PSD ``kernel``.

    One-shot convenience (decompose + draw): O(C³) per call.  Returns int32
    indices of shape ``(k,)`` (unordered, distinct).  Bit-identical to
    ``sample_kdpp_from_eigh(key, kdpp_sampler_state(kernel, k), k)``.
    """
    return _sample_from_state(key, _sampler_state(kernel, k), k)


@functools.partial(jax.jit, static_argnames=("k",))
def greedy_map_kdpp(kernel: jax.Array, k: int) -> jax.Array:
    """Deterministic greedy MAP for the k-DPP: argmax det(L_Y), |Y| = k.

    Fast greedy MAP (Chen et al. 2018): maintains for every item ``i`` the
    squared Cholesky diagonal ``d2[i]`` = marginal log-det gain; each of the
    ``k`` steps picks argmax d2 and downdates in O(C).
    """
    c = kernel.shape[0]

    def body(carry, step):
        d2, cis, chosen_mask = carry
        gains = jnp.where(chosen_mask, -jnp.inf, d2)
        j = jnp.argmax(gains)
        dj = jnp.sqrt(jnp.maximum(d2[j], 1e-30))
        # e_i = (L[j, i] - <c_j, c_i>) / dj for all i
        e = (kernel[j, :] - cis[:, :] @ cis[j, :]) / dj
        cis = cis.at[:, step].set(e)
        d2 = d2 - e * e
        chosen_mask = chosen_mask.at[j].set(True)
        return (d2, cis, chosen_mask), j

    d2 = jnp.diag(kernel)
    cis = jnp.zeros((c, k), kernel.dtype)
    mask = jnp.zeros((c,), bool)
    (_, _, _), items = lax.scan(body, (d2, cis, mask), jnp.arange(k))
    return items.astype(jnp.int32)


def masked_kernel(kernel: jax.Array, avail: jax.Array) -> jax.Array:
    """Fold an availability mask into a PSD kernel (DESIGN.md §9).

    Zeroes the rows/columns of unavailable items: ``L' = m mᵀ ⊙ L`` with
    ``m = avail``.  L' stays PSD (a congruence by ``diag(m)``), its spectrum
    is supported on the available block, and every eigenvector is zero on
    unavailable coordinates — so a k-DPP draw from L' can only return
    available items (phase-2 weights vanish there).  Requires the available
    block to have rank ≥ k; callers fall back to the unmasked kernel when
    fewer than k items are available.
    """
    m = avail.astype(kernel.dtype)
    return kernel * (m[:, None] * m[None, :])


def log_det_subset(kernel: jax.Array, idx: jax.Array) -> jax.Array:
    """log det(L_Y) for the subset ``idx`` (sign-safe via slogdet)."""
    sub = kernel[jnp.ix_(idx, idx)]
    sign, logdet = jnp.linalg.slogdet(sub)
    return jnp.where(sign > 0, logdet, -jnp.inf)


def kdpp_log_prob(kernel: jax.Array, idx: jax.Array) -> jax.Array:
    """Unnormalised k-DPP log probability of subset ``idx`` (eq. 13 numerator)."""
    return log_det_subset(kernel, idx)

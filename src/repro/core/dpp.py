"""k-DPP sampling (Kulesza & Taskar, ICML'11) in pure JAX.

This is the selection engine of FL-DP3S (paper eq. (12)-(13)): given a PSD
similarity kernel ``L`` over ``C`` clients, sample a subset of fixed size
``k = C_p`` with probability proportional to ``det(L_Y)``.

Everything here is jit-compatible (static ``k``); the eigendecomposition uses
``jnp.linalg.eigh``. Two samplers are provided:

* :func:`sample_kdpp` — exact k-DPP sampling (two-phase eigenvector algorithm,
  Kulesza & Taskar Alg. 8 specialised to fixed cardinality).
* :func:`greedy_map_kdpp` — deterministic greedy MAP inference (Chen et al.,
  NeurIPS'18 fast greedy MAP), a beyond-paper variant that is O(C·k) per step,
  device-friendly and reproducible — useful at serving scale.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax

__all__ = [
    "elementary_symmetric",
    "sample_kdpp",
    "greedy_map_kdpp",
    "log_det_subset",
    "kdpp_log_prob",
]


def elementary_symmetric(lam: jax.Array, k: int) -> jax.Array:
    """Elementary symmetric polynomials ``E[l, n] = e_l(lam_1..lam_n)``.

    Returns an array of shape ``(k + 1, N + 1)`` with the standard DP
    recurrence ``E[l, n] = E[l, n-1] + lam_n * E[l-1, n-1]``.
    """
    n = lam.shape[0]

    def body(carry, lam_n):
        # carry: row of E over l = 0..k for prefix length n-1
        prev = carry
        shifted = jnp.concatenate([jnp.zeros((1,), lam.dtype), prev[:-1]])
        new = prev + lam_n * shifted
        return new, new

    init = jnp.zeros((k + 1,), lam.dtype).at[0].set(1.0)
    _, rows = lax.scan(body, init, lam)
    # rows[n-1] is E[:, n]; prepend the n=0 column.
    e = jnp.concatenate([init[:, None], rows.T], axis=1)
    return e  # (k+1, N+1)


def _phase1_select_eigenvectors(key: jax.Array, lam: jax.Array, k: int) -> jax.Array:
    """Phase 1: choose exactly ``k`` eigenvectors; returns a bool mask (N,).

    Iterates n = N..1; eigenvector n is kept with probability
    ``lam_n * E[r-1, n-1] / E[r, n]`` where ``r`` is the number of vectors
    still to pick.  Scale-invariant in ``lam`` (we normalise for stability).
    """
    n = lam.shape[0]
    lam = lam / jnp.maximum(jnp.mean(jnp.abs(lam)), 1e-30)
    e = elementary_symmetric(lam, k)  # (k+1, N+1)

    def body(carry, idx):
        key, rem = carry
        # idx runs 0..N-1 mapping to n = N-idx
        nn = n - idx
        key, sub = jax.random.split(key)
        denom = e[rem, nn]
        num = lam[nn - 1] * e[jnp.maximum(rem - 1, 0), nn - 1]
        p = jnp.where(denom > 0, num / denom, 0.0)
        # Force-take when we must (rem == nn) and never take when rem == 0.
        p = jnp.where(rem == nn, 1.0, p)
        p = jnp.where(rem == 0, 0.0, jnp.clip(p, 0.0, 1.0))
        take = jax.random.uniform(sub) < p
        rem = rem - take.astype(rem.dtype)
        return (key, rem), take

    (_, rem), takes = lax.scan(body, (key, jnp.asarray(k, jnp.int32)), jnp.arange(n))
    # takes[idx] corresponds to eigenvector index n-1-idx; reverse to (N,).
    return takes[::-1]


def _phase2_sample_items(key: jax.Array, v_sel: jax.Array, k: int) -> jax.Array:
    """Phase 2: sample ``k`` items from the elementary DPP given by ``v_sel``.

    ``v_sel`` is (N, k) whose columns are the selected eigenvectors (already
    orthonormal).  Returns int32 indices of shape (k,).  Uses the standard
    conditioning step: after picking item ``i`` via p(i) ∝ Σ_c V[i, c]^2,
    project V onto the complement of e_i and re-orthonormalise (masked
    modified Gram-Schmidt keeps shapes static).
    """
    n = v_sel.shape[0]

    def gram_schmidt(v):
        # Masked MGS over the k columns; zero columns stay zero.
        def gs_col(v, c):
            col = v[:, c]
            def gs_prev(col, j):
                prev = v[:, j]
                coef = jnp.where(j < c, jnp.dot(prev, col), 0.0)
                return col - coef * prev, None
            col, _ = lax.scan(gs_prev, col, jnp.arange(v.shape[1]))
            nrm = jnp.linalg.norm(col)
            col = jnp.where(nrm > 1e-8, col / jnp.maximum(nrm, 1e-30), jnp.zeros_like(col))
            return v.at[:, c].set(col), None

        v, _ = lax.scan(gs_col, v, jnp.arange(v.shape[1]))
        return v

    def body(carry, _):
        key, v = carry
        key, k_i = jax.random.split(key)
        weights = jnp.sum(v * v, axis=1)  # (N,)
        logits = jnp.log(jnp.maximum(weights, 1e-30))
        i = jax.random.categorical(k_i, logits)
        # Column with the largest |V[i, c]| to pivot on.
        row = v[i, :]
        c_star = jnp.argmax(jnp.abs(row))
        pivot = v[:, c_star]
        denom = jnp.where(jnp.abs(row[c_star]) > 1e-30, row[c_star], 1.0)
        v = v - jnp.outer(pivot, row / denom)
        v = v.at[:, c_star].set(jnp.zeros((n,), v.dtype))
        v = gram_schmidt(v)
        return (key, v), i

    (_, _), items = lax.scan(body, (key, v_sel), None, length=k)
    return items.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("k",))
def sample_kdpp(key: jax.Array, kernel: jax.Array, k: int) -> jax.Array:
    """Sample ``k`` distinct indices from the k-DPP defined by PSD ``kernel``.

    Returns int32 indices of shape ``(k,)`` (unordered, distinct).
    """
    kernel = kernel.astype(jnp.float64 if jax.config.read("jax_enable_x64") else jnp.float32)
    lam, vecs = jnp.linalg.eigh(kernel)
    lam = jnp.maximum(lam, 0.0)  # clip tiny negative eigenvalues
    key1, key2 = jax.random.split(key)
    mask = _phase1_select_eigenvectors(key1, lam, k)
    # Pack the selected eigenvectors into the first k columns (static shape):
    # order columns by (selected desc, index) and take the top k.
    order = jnp.argsort(~mask, stable=True)  # selected first
    v_sel = vecs[:, order[:k]] * mask[order[:k]][None, :].astype(vecs.dtype)
    items = _phase2_sample_items(key2, v_sel, k)
    return items


@functools.partial(jax.jit, static_argnames=("k",))
def greedy_map_kdpp(kernel: jax.Array, k: int) -> jax.Array:
    """Deterministic greedy MAP for the k-DPP: argmax det(L_Y), |Y| = k.

    Fast greedy MAP (Chen et al. 2018): maintains for every item ``i`` the
    squared Cholesky diagonal ``d2[i]`` = marginal log-det gain; each of the
    ``k`` steps picks argmax d2 and downdates in O(C).
    """
    c = kernel.shape[0]

    def body(carry, step):
        d2, cis, chosen_mask = carry
        gains = jnp.where(chosen_mask, -jnp.inf, d2)
        j = jnp.argmax(gains)
        dj = jnp.sqrt(jnp.maximum(d2[j], 1e-30))
        # e_i = (L[j, i] - <c_j, c_i>) / dj for all i
        e = (kernel[j, :] - cis[:, :] @ cis[j, :]) / dj
        cis = cis.at[:, step].set(e)
        d2 = d2 - e * e
        chosen_mask = chosen_mask.at[j].set(True)
        return (d2, cis, chosen_mask), j

    d2 = jnp.diag(kernel)
    cis = jnp.zeros((c, k), kernel.dtype)
    mask = jnp.zeros((c,), bool)
    (_, _, _), items = lax.scan(body, (d2, cis, mask), jnp.arange(k))
    return items.astype(jnp.int32)


def log_det_subset(kernel: jax.Array, idx: jax.Array) -> jax.Array:
    """log det(L_Y) for the subset ``idx`` (sign-safe via slogdet)."""
    sub = kernel[jnp.ix_(idx, idx)]
    sign, logdet = jnp.linalg.slogdet(sub)
    return jnp.where(sign > 0, logdet, -jnp.inf)


def kdpp_log_prob(kernel: jax.Array, idx: jax.Array) -> jax.Array:
    """Unnormalised k-DPP log probability of subset ``idx`` (eq. 13 numerator)."""
    return log_det_subset(kernel, idx)

"""Similarity kernel construction from client data profiles (paper §3.2).

Implements eq. (14): pairwise L2 distances between profiles, min-max
normalised and flipped into similarities ``S``, then the PSD DPP kernel
``L = Sᵀ S`` (eq. below (13)).

Two execution paths (DESIGN.md §5/§7):

* **Pure jnp** (default, ``use_kernel=False``) — the oracle and the CPU
  path: a chain of XLA ops (expansion distances → sqrt → min-max → matmul).
* **Fused Pallas** (``use_kernel=True``) — :func:`kernel_from_profiles`
  runs the whole chain as **two TPU kernel launches**
  (``repro.kernels.pairwise_l2`` distance tiles with a sqrt/min-max-stats
  epilogue, then the ``repro.kernels.gram`` normalise-and-Gram kernel); the
  similarity matrix never materialises in HBM.  Dtype contract: fp32
  profiles match the oracle to ~1e-5; bf16 profiles keep bf16 MXU inputs
  with fp32 accumulation.  The stage-wise helpers (:func:`pairwise_sq_dists`
  etc.) keep routing just the distance stage through Pallas.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "pairwise_sq_dists",
    "pairwise_dists",
    "similarity_matrix",
    "dpp_kernel",
    "kernel_from_profiles",
    "candidate_kernel",
]


def pairwise_sq_dists(f: jax.Array, use_kernel: bool = False) -> jax.Array:
    """Squared L2 distances between profile rows: (C, Q) -> (C, C).

    Uses the MXU-friendly expansion ``‖a‖² + ‖b‖² − 2 a·b``.
    """
    if use_kernel:
        from repro.kernels.pairwise_l2 import ops as _ops

        return _ops.pairwise_sq_dists(f)
    sq = jnp.sum(f * f, axis=-1)
    d2 = sq[:, None] + sq[None, :] - 2.0 * (f @ f.T)
    d2 = jnp.maximum(d2, 0.0)
    # the expansion is exact-zero-free on the diagonal only up to fp error;
    # pin it (distance to self) so eq.-(14) keeps an exact unit diagonal.
    return d2 * (1.0 - jnp.eye(d2.shape[0], dtype=d2.dtype))


def pairwise_dists(f: jax.Array, use_kernel: bool = False) -> jax.Array:
    """L2 distances ``s⁰_{m,n} = ‖f_m − f_n‖₂`` (paper eq. 14)."""
    return jnp.sqrt(pairwise_sq_dists(f, use_kernel=use_kernel))


def similarity_matrix(f: jax.Array, use_kernel: bool = False) -> jax.Array:
    """Similarity matrix ``S`` per eq. (14).

    ``s_{m,n} = 1 − (s⁰_{m,n} − min(S⁰)) / (max(S⁰) − min(S⁰))``; values in
    [0, 1], diagonal = 1 (since min(S⁰) = 0 on the diagonal).
    """
    s0 = pairwise_dists(f, use_kernel=use_kernel)
    lo = jnp.min(s0)
    hi = jnp.max(s0)
    rng = jnp.maximum(hi - lo, 1e-30)
    return 1.0 - (s0 - lo) / rng


def dpp_kernel(s: jax.Array) -> jax.Array:
    """DPP kernel ``L = Sᵀ S`` — PSD by construction (Gram matrix)."""
    return s.T @ s


def kernel_from_profiles(f: jax.Array, use_kernel: bool = False) -> jax.Array:
    """Profiles (C, Q) -> PSD k-DPP kernel (C, C): eq. (14) then L = SᵀS.

    ``use_kernel=True`` runs the fused two-launch Pallas pipeline (distance
    tiles + normalise-and-Gram) instead of the XLA op chain.
    """
    if use_kernel:
        from repro.kernels.gram import ops as _gram_ops

        return _gram_ops.kernel_from_profiles(f)
    return dpp_kernel(similarity_matrix(f, use_kernel=use_kernel))


def candidate_kernel(
    f: jax.Array, candidates: jax.Array, use_kernel: bool = False
) -> jax.Array:
    """Q×Q eq.-(14) kernel over a funnel candidate block (DESIGN.md §10).

    Semantics: ``kernel_from_profiles(f[candidates])`` — the min-max
    normalisation runs over the *candidate* distance block, NOT the full
    federation, so this is deliberately **not** a submatrix of the C×C
    kernel.  (With ``candidates == arange(C)`` the two coincide — the Q=C
    parity contract.)  The gather plus the Q-sized pipeline never touch a
    C×C intermediate; ``use_kernel=True`` routes the ragged-Q block through
    the fused Pallas pipeline, whose pad-to-tile masking already handles
    non-tile-multiple Q.
    """
    fq = jnp.take(f, jnp.asarray(candidates, jnp.int32), axis=0)
    if use_kernel:
        from repro.kernels.gram import ops as _gram_ops

        return _gram_ops.candidate_kernel_from_profiles(fq)
    return kernel_from_profiles(fq, use_kernel=False)

"""Client data profiling (paper §3.1, Theorem 1).

Each client summarises its local dataset by the *mean vector of the FC-1
outputs* of the (shared, freshly initialised) global model — eq. (11):
``f_c = [u_1^c, …, u_Q^c]``.  By Theorem 1 (CLT over the weighted inputs of
each FC-1 neuron) the per-neuron output is asymptotically Gaussian with mean
``u_q = Σ_v ω_{q,v} μ_v + b_q`` — a linear image of the mean latent feature
vector, i.e. a distribution fingerprint that leaks far less than a label
histogram and is uploaded once (B·Q bits).

Models plug in via ``apply_with_features(params, x) -> (logits, feats)`` where
``feats`` is the designated profile layer output:
* paper CNN: FC-1 *pre-activation* outputs (exactly Theorem 1's ``h_q``);
* decoder LMs: mean-over-tokens of the pre-logits hidden state (the analogue
  of "first dense layer after the feature extractor"; see DESIGN.md §3).

Also implements the Fig.-3 ablation baselines: gradient profiles and
representative-gradient profiles (Fraboni et al., ICML'21).
"""

from __future__ import annotations

from typing import Callable, Iterable, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "fc1_profile",
    "gradient_profile",
    "representative_gradient_profile",
    "profile_all_clients",
]

FeatureFn = Callable[..., Tuple[jax.Array, jax.Array]]


def fc1_profile(feature_fn: FeatureFn, params, xs: jax.Array, batch_size: int = 256) -> jax.Array:
    """Mean FC-1 output over a client's local dataset (eq. 11).

    ``feature_fn(params, x_batch) -> (logits, feats)`` with feats (B, Q).
    Streams in fixed-size batches so the profile pass is O(batch) memory.

    A client with an **empty** local dataset (n = 0) gets the zero profile of
    width Q — probed with an empty forward batch so the width matches every
    populated client's row and ``profile_all_clients`` can still stack.
    (The mean of zero samples is undefined; zero is the neutral element of
    the eq.-(14) similarity pipeline and keeps the kernel finite.)
    """
    n = xs.shape[0]
    if n == 0:
        _, feats = feature_fn(params, xs[:0])
        # width from the static shape: reshape(0, -1) is ambiguous on a
        # zero-row array, so flatten the trailing dims by hand
        width = int(np.prod(feats.shape[1:]))
        return jnp.zeros((width,), feats.dtype)
    total = None
    for start in range(0, n, batch_size):
        xb = xs[start : start + batch_size]
        _, feats = feature_fn(params, xb)
        feats = feats.reshape(feats.shape[0], -1)
        s = jnp.sum(feats, axis=0)
        total = s if total is None else total + s
    return total / n


def gradient_profile(
    loss_fn: Callable, params, xs: jax.Array, ys: jax.Array, max_dim: int = 4096
) -> jax.Array:
    """Fig.-3 ablation: profile = flattened loss gradient on the local data.

    Truncated/strided to ``max_dim`` entries so profiles stay comparable in
    size with FC-1 profiles (the paper's point is that gradients are a *worse*
    and much heavier fingerprint).
    """
    g = jax.grad(loss_fn)(params, xs, ys)
    flat = jnp.concatenate([x.reshape(-1) for x in jax.tree_util.tree_leaves(g)])
    if flat.shape[0] > max_dim:
        stride = flat.shape[0] // max_dim
        flat = flat[: stride * max_dim : stride]
    return flat


def representative_gradient_profile(
    loss_fn: Callable, params, xs: jax.Array, ys: jax.Array, layer: str = "out"
) -> jax.Array:
    """Fig.-3 ablation: representative gradients (Fraboni et al. Alg. 2 input).

    Uses only the output-layer gradient — the low-dimensional "representative"
    slice used by clustered sampling.
    """
    g = jax.grad(loss_fn)(params, xs, ys)
    leaves = {"/".join(map(str, p)): v for p, v in _flatten_with_paths(g)}
    picked = [v for k, v in sorted(leaves.items()) if layer in k]
    if not picked:  # fall back to the last parameter tensor
        picked = [jax.tree_util.tree_leaves(g)[-1]]
    return jnp.concatenate([p.reshape(-1) for p in picked])


def _flatten_with_paths(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    for path, leaf in flat:
        yield tuple(getattr(p, "key", getattr(p, "idx", str(p))) for p in path), leaf


def profile_all_clients(
    feature_fn: FeatureFn, params, client_data: Iterable[jax.Array], batch_size: int = 256
) -> jax.Array:
    """Stack eq.-(11) profiles for every client: -> (C, Q).

    In deployment each client computes its own row locally and uploads it once
    (Algorithm 1 lines 2-4); here we loop over the simulated clients.
    """
    rows = [fc1_profile(feature_fn, params, xs, batch_size=batch_size) for xs in client_data]
    return jnp.stack(rows, axis=0)

"""Three-term roofline analysis from the dry-run's compiled artifacts.

For each (arch × input-shape) pair on the single-pod 16×16 mesh:

    compute    = HLO_FLOPs_per_device / peak_FLOP/s            (197e12 bf16)
    memory     = HLO_bytes_per_device / HBM_bw                 (819e9 B/s)
    collective = collective_bytes_per_device / link_bw         (50e9 B/s)

``compiled.cost_analysis()`` reports the *per-device* SPMD program (verified
against analytic FLOPs for known cases), so the chips factor in the formulas
from the brief is already applied by SPMD partitioning.  Collective bytes
come from the HLO parse (see ``analysis.hlo`` for the per-op estimators).

MODEL_FLOPS is the analytic "useful" count:
    train:   6·N_active·tokens + 2·attn_flops(S)·3
    prefill: 2·N_active·tokens + attn_flops(S)
    decode:  2·N_active·batch + attn_kv_flops(S_cache)
with N_active = non-embedding active params (MoE: k/E of routed experts +
shared).  The ratio MODEL_FLOPS / (HLO_FLOPs × chips) flags remat recompute
(ratio < 1 by the remat factor) and redundant compute.

    python -m repro.analysis.roofline --inp results/dryrun.jsonl \
        --out results/roofline.md
"""

from __future__ import annotations

import argparse
import json
from typing import Dict, List, Optional

from repro.configs import INPUT_SHAPES, get_arch
from repro.launch.mesh import HW
from repro.models.transformer import vocab_padded

__all__ = ["active_param_count", "model_flops", "analyse", "render_markdown"]


def _layer_param_counts(cfg) -> Dict[str, float]:
    d, f = cfg.d_model, cfg.d_ff
    qd, kvd = cfg.q_dim, cfg.kv_dim
    dr = cfg.rnn_width or d
    mlp = 3 * d * f if cfg.mlp_variant in ("swiglu", "geglu") else 2 * d * f
    counts = {
        "attn": d * qd + 2 * d * kvd + qd * d,
        "mlp": mlp,
        "moe_total": cfg.num_experts * 3 * d * f + (mlp if cfg.shared_expert else 0),
        "moe_active": cfg.experts_per_token * 3 * d * f
        + (mlp if cfg.shared_expert else 0),
        "rglru": 3 * d * dr + 2 * dr * dr + 5 * dr,
        "rwkv_tmix": 5 * d * d + 2 * d * 32,
        "rwkv_cmix": 2 * d * f + d * d,
    }
    return counts


def active_param_count(cfg, total: bool = False) -> float:
    """Non-embedding params; MoE layers count active (or total) experts."""
    lc = _layer_param_counts(cfg)
    n = 0.0
    for btype in cfg.layer_types():
        mixer, ffn = btype.split("+")
        n += {"attn": lc["attn"], "swa": lc["attn"], "local": lc["attn"],
              "rglru": lc["rglru"], "rwkv": lc["rwkv_tmix"]}[mixer]
        n += {"mlp": lc["mlp"], "cmix": lc["rwkv_cmix"],
              "moe": lc["moe_total"] if total else lc["moe_active"]}[ffn]
    n += cfg.d_model * vocab_padded(cfg)  # lm head (tied or not — the matmul runs)
    return n


def _attn_flops(cfg, batch: int, s_q: int, s_kv: int) -> float:
    """2 matmuls (qk, pv), 2 flops/MAC, causal halves the square case."""
    per_layer = 4.0 * batch * s_q * s_kv * cfg.num_heads * cfg.head_dim
    if s_q == s_kv:
        per_layer *= 0.5  # causal
    n_attn = sum(1 for b in cfg.layer_types() if b.split("+")[0] in ("attn", "swa", "local"))
    return per_layer * n_attn


def model_flops(arch: str, shape: str, fl_mode: str, local_steps: int = 4) -> float:
    spec = get_arch(arch)
    cfg = spec.long_context_model() if shape == "long_500k" else spec.model
    ishape = INPUT_SHAPES[shape]
    n_act = active_param_count(cfg)
    b, s = ishape.global_batch, ishape.seq_len
    if ishape.kind == "train":
        steps = local_steps if fl_mode == "client_parallel" else 1
        tokens = b * s * steps
        return 6.0 * n_act * tokens + 3.0 * steps * _attn_flops(cfg, b, s, s)
    if ishape.kind == "prefill":
        return 2.0 * n_act * b * s + _attn_flops(cfg, b, s, s)
    # decode: one token against the cache (window-clamped for swa/local)
    win = {"swa": cfg.window, "local": cfg.local_window}
    kv = min(s, max((win.get(bt.split("+")[0], s) for bt in cfg.layer_types()), default=s))
    return 2.0 * n_act * b + _attn_flops(cfg, b, 1, kv)


def _wkv_flops_correction(arch: str, shape: str, chips: int, fl_mode: str,
                          local_steps: int) -> float:
    """The rwkv time scan stays rolled even in accounting compiles (its trip
    count is the sequence length); add its per-device flops analytically:
    ~8·hd² flops per head per token per layer (state update + readout)."""
    if arch != "rwkv6-7b":
        return 0.0
    spec = get_arch(arch)
    cfg = spec.model
    ishape = INPUT_SHAPES[shape]
    heads = cfg.d_model // cfg.rwkv_head_dim
    tokens = ishape.global_batch * (ishape.seq_len if ishape.kind != "decode" else 1)
    if ishape.kind == "train":
        tokens *= local_steps if fl_mode == "client_parallel" else 1
        mult = 3.0  # fwd + bwd
    else:
        mult = 1.0
    per_layer = 8.0 * cfg.rwkv_head_dim**2 * heads * tokens
    return mult * per_layer * cfg.num_layers / chips


def analyse(records: List[Dict], mesh: str = "16x16",
            accounting: Optional[List[Dict]] = None) -> List[Dict]:
    # Prefer accounting records (exact static counts, see dryrun
    # _accounting_counts) for flops/bytes/collectives; production records
    # supply memory_analysis and the ok/compile evidence.
    acc_by_key = {}
    for a in accounting or []:
        if a.get("ok") and a.get("mesh") == mesh:
            acc_by_key[(a["arch"], a["shape"])] = a
    out = []
    for r in records:
        if not r.get("ok") or r.get("mesh") != mesh or r.get("reduced"):
            continue
        chips = 512 if mesh == "2x16x16" else 256
        acc = acc_by_key.get((r["arch"], r["shape"]), r)
        spec0 = get_arch(r["arch"])
        flops_dev = acc.get("cost", {}).get("flops", 0.0)
        flops_dev += _wkv_flops_correction(
            r["arch"], r["shape"], chips, r.get("fl_mode", "serve"),
            spec0.fl.local_steps,
        )
        bytes_dev = acc.get("cost", {}).get("bytes accessed", 0.0)
        coll_dev = acc.get("collectives", {}).get("total", 0.0)
        t_compute = flops_dev / HW.PEAK_FLOPS_BF16
        t_memory = bytes_dev / HW.HBM_BW
        t_coll = coll_dev / HW.ICI_BW
        terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
        dominant = max(terms, key=terms.get)
        spec = get_arch(r["arch"])
        mf = model_flops(r["arch"], r["shape"], r.get("fl_mode", "serve"),
                         spec.fl.local_steps)
        hlo_global = flops_dev * chips
        ratio = mf / hlo_global if hlo_global else float("nan")
        out.append(
            dict(
                arch=r["arch"], shape=r["shape"], mesh=mesh,
                fl_mode=r.get("fl_mode"),
                t_compute=t_compute, t_memory=t_memory, t_collective=t_coll,
                dominant=dominant,
                model_flops=mf, hlo_flops_global=hlo_global, useful_ratio=ratio,
                collectives={k: v for k, v in acc.get("collectives", {}).items() if k != "total"},
                memory_bytes=r.get("memory", {}),
                accounting=acc is not r,
            )
        )
    return out


_SUGGEST = {
    "compute": "more chips / lower remat recompute / MoE capacity-factor cut",
    "memory": "fuse bandwidth-bound ops, widen per-chip batch, bf16 cache",
    "collective": "shard to cut cross-chip traffic (fewer all-gathers), raise "
                  "E local steps (Mode A amortises the round all-reduce), overlap",
}


def render_markdown(rows: List[Dict]) -> str:
    lines = [
        "| arch | shape | mode | compute s | memory s | collective s | dominant | "
        "MODEL_FLOPS | useful ratio | what moves the dominant term |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in sorted(rows, key=lambda x: (x["arch"], x["shape"])):
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['fl_mode']} "
            f"| {r['t_compute']:.3e} | {r['t_memory']:.3e} | {r['t_collective']:.3e} "
            f"| **{r['dominant']}** | {r['model_flops']:.2e} | {r['useful_ratio']:.2f} "
            f"| {_SUGGEST[r['dominant']]} |"
        )
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--inp", default="results/dryrun.jsonl")
    ap.add_argument("--acc", default="results/dryrun_acc.jsonl")
    ap.add_argument("--out", default="results/roofline.md")
    ap.add_argument("--mesh", default="16x16")
    args = ap.parse_args()
    records = [json.loads(l) for l in open(args.inp)]
    accounting = None
    import os
    if args.acc and os.path.exists(args.acc):
        accounting = [json.loads(l) for l in open(args.acc)]
    rows = analyse(records, mesh=args.mesh, accounting=accounting)
    md = render_markdown(rows)
    with open(args.out, "w") as f:
        f.write(md + "\n")
    print(md)
    with open(args.out.replace(".md", ".json"), "w") as f:
        json.dump(rows, f, indent=1)


if __name__ == "__main__":
    main()

"""Render a human-readable run summary from a telemetry JSONL file.

    PYTHONPATH=src python -m repro.analysis.report runs/train.jsonl

Works for both engines' streams (DESIGN.md §14): the manifest header, a
train convergence table sampled from the ``fl_round`` events (round / loss /
acc / GEMD plus whichever diagnostics the config produced), robustness and
staleness totals, and the serve latency tables (TTFT / end-to-end
percentiles, per-chunk decode tok/s, occupancy, queue depth).  Pure stdlib +
numpy — no jax import, so it runs anywhere the JSONL lands.
"""

from __future__ import annotations

import argparse
from typing import Any, Dict, List, Sequence

import numpy as np

from repro.obs.sink import load_events

__all__ = ["load_events", "summarize"]


def _fmt(v: Any) -> str:
    if v is None:
        return "-"
    if isinstance(v, float):
        return f"{v:.4g}"
    return str(v)


def _table(headers: Sequence[str], rows: List[Sequence[Any]]) -> List[str]:
    cells = [[_fmt(c) for c in row] for row in rows]
    widths = [
        max(len(h), *(len(r[i]) for r in cells)) if cells else len(h)
        for i, h in enumerate(headers)
    ]
    line = lambda r: "  " + "  ".join(c.rjust(w) for c, w in zip(r, widths))
    return [line(headers), line(["-" * w for w in widths])] + [
        line(r) for r in cells
    ]


def _pct(xs: List[float], q: float) -> float:
    return float(np.percentile(np.asarray(xs, np.float64), q))


def _manifest_lines(man: Dict[str, Any]) -> List[str]:
    lines = ["run manifest"]
    for k in ("config_hash", "git_sha", "jax_version", "backend",
              "device_count", "device_kind", "mesh", "mode", "arch"):
        if man.get(k) is not None:
            lines.append(f"  {k}: {man[k]}")
    return lines


def _train_lines(rounds: List[Dict[str, Any]], max_rows: int) -> List[str]:
    lines = [f"training: {len(rounds)} rounds"]
    cols = ["round", "loss", "acc", "gemd"]
    for extra in ("sim_time", "staleness", "survivors", "flagged",
                  "quarantined", "cache_age", "spectrum_erank", "avail_frac"):
        if any(r.get(extra) is not None for r in rounds):
            cols.append(extra)
    step = max(1, len(rounds) // max_rows)
    idx = sorted(set(range(0, len(rounds), step)) | {len(rounds) - 1})
    lines += _table(cols, [[rounds[i].get(c) for c in cols] for i in idx])
    ident = sum(int(r.get("identity_round") or 0) for r in rounds)
    if ident:
        lines.append(f"  identity rounds (survivors floor): {ident}")
    gemds = [r["gemd"] for r in rounds if r.get("gemd") is not None]
    if len(gemds) > 1:
        drift = float(np.mean(np.abs(np.diff(gemds))))
        lines.append(f"  mean |GEMD drift| per round: {drift:.4g}")
    return lines


def _serve_lines(events: List[Dict[str, Any]]) -> List[str]:
    admits = [e for e in events if e["event"] == "serve_admit"]
    chunks = [e for e in events if e["event"] == "serve_chunk"]
    finishes = [e for e in events if e["event"] == "serve_finish"]
    lines = [
        f"serving: {len(finishes)} finished seqs, "
        f"{len(admits)} admissions, {len(chunks)} decode chunks"
    ]
    rows = []
    ttft = [e["ttft_s"] for e in admits if e.get("ttft_s") is not None]
    if ttft:
        rows.append(["TTFT (s)", _pct(ttft, 50), _pct(ttft, 90),
                     _pct(ttft, 99), max(ttft)])
    lat = [e["latency_s"] for e in finishes if e.get("latency_s") is not None]
    if lat:
        rows.append(["latency (s)", _pct(lat, 50), _pct(lat, 90),
                     _pct(lat, 99), max(lat)])
    if rows:
        lines += _table(["metric", "p50", "p90", "p99", "max"], rows)
    if chunks:
        toks = sum(e.get("tokens", 0) for e in chunks)
        secs = sum(e.get("dt_s", 0.0) for e in chunks)
        occ = [e["active_slots"] / e["batch"] for e in chunks
               if e.get("batch")]
        qd = [e.get("queue_depth", 0) for e in chunks]
        lines.append(
            f"  decode: {toks} tokens in {secs:.3f} s "
            f"({toks / max(secs, 1e-9):,.0f} tok/s aggregate), "
            f"mean occupancy {np.mean(occ):.0%}, "
            f"max queue depth {max(qd)}"
        )
    return lines


def summarize(events: List[Dict[str, Any]], max_rows: int = 12) -> str:
    """The whole report as one string (empty-stream safe)."""
    lines: List[str] = []
    man = next((e for e in events if e["event"] == "manifest"), None)
    if man is not None:
        lines += _manifest_lines(man)
    rounds = [e for e in events if e["event"] == "fl_round"]
    if rounds:
        lines += [""] + _train_lines(rounds, max_rows)
    repro_ev = [e for e in events if e["event"] == "fl_reprofile"]
    if repro_ev:
        lines.append(f"  reprofile boundaries: {len(repro_ev)}")
    ckpts = [e for e in events if e["event"] == "fl_checkpoint"]
    if ckpts:
        lines.append(
            f"  checkpoints: {len(ckpts)} "
            f"(last at round {ckpts[-1].get('round')})"
        )
    if any(e["event"].startswith("serve_") for e in events):
        lines += [""] + _serve_lines(events)
    if not lines:
        return "no telemetry events"
    return "\n".join(lines)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("path", help="telemetry JSONL file")
    ap.add_argument("--max-rows", type=int, default=12,
                    help="max convergence-table rows (sampled evenly)")
    args = ap.parse_args()
    print(summarize(load_events(args.path), max_rows=args.max_rows))


if __name__ == "__main__":
    main()

"""Roofline analysis from compiled dry-run artifacts, HLO-text accounting
(:mod:`repro.analysis.hlo`), and telemetry run reports
(:mod:`repro.analysis.report`, DESIGN.md §14)."""

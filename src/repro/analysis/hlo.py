"""Post-SPMD HLO text parsing: collective byte counts + op histograms.

``cost_analysis()`` does not expose collective traffic, so we parse the
optimized (partitioned) HLO.  Byte estimators per op (ring-algorithm
per-device traffic, documented for §Roofline):

* all-reduce:          2 × size(result)          (reduce-scatter + all-gather)
* all-gather:          size(result)              (each device receives ~full)
* reduce-scatter:      size(result) × group      (operand bytes reduced)
* all-to-all:          size(result)              (full exchange)
* collective-permute:  size(result)
"""

from __future__ import annotations

import re
from typing import Dict

__all__ = ["collective_bytes", "op_histogram", "DTYPE_BYTES"]

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}

_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)

# e.g.:  %all-gather.3 = bf16[16,512,128]{2,1,0} all-gather(...) replica_groups=...
_INSTR = re.compile(
    r"=\s*(?:\()?\s*([a-z0-9]+)\[([0-9,]*)\][^=]*?\s("
    + "|".join(_COLLECTIVES)
    + r")((?:-start)?)\("
)
_GROUPS = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_IOTA = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * DTYPE_BYTES.get(dtype, 4)


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA.search(line)
    if m:  # iota format [groups, group_size]
        return int(m.group(2))
    m = _GROUPS.search(line)
    if m:
        return len(m.group(1).split(","))
    return 1


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Sum estimated per-device collective traffic, keyed by op kind."""
    out: Dict[str, float] = {}
    for line in hlo_text.splitlines():
        m = _INSTR.search(line)
        if not m:
            continue
        dtype, dims, op, _suffix = m.groups()
        size = _shape_bytes(dtype, dims)
        if op == "all-reduce":
            b = 2.0 * size
        elif op == "reduce-scatter":
            b = float(size) * _group_size(line)
        else:
            b = float(size)
        out[op] = out.get(op, 0.0) + b
    out["total"] = sum(out.values())
    return out


def op_histogram(hlo_text: str, top: int = 25) -> Dict[str, int]:
    """Count fusion-root op kinds — enough to spot remat recompute and
    layout-churn (transpose/reshape storms) when iterating §Perf."""
    counts: Dict[str, int] = {}
    for m in re.finditer(r"=\s*(?:\()?\s*[a-z0-9]+\[[0-9,]*\][^\s]*\s+([a-z-]+)\(", hlo_text):
        op = m.group(1)
        counts[op] = counts.get(op, 0) + 1
    return dict(sorted(counts.items(), key=lambda kv: -kv[1])[:top])

"""§Perf hillclimb driver: evaluate sharding/config variants of one
(arch × shape) pair against the roofline terms.

    python -m repro.analysis.hillclimb --pair rwkv6-7b:train_4k
    python -m repro.analysis.hillclimb --all

Each variant is (name, rule overrides, cfg overrides, fl overrides); the
driver recompiles the accounting counts (exact static HLO numbers, see
dryrun._accounting_counts), derives the three roofline terms, and appends to
``results/hillclimb.jsonl``.  Variant v0 is always the paper-faithful
baseline.  The hypothesis / verdict narrative lives in EXPERIMENTS.md §Perf.
"""

from repro.launch import dryrun  # noqa: F401  (must be first: XLA device flags)

import argparse  # noqa: E402
import dataclasses  # noqa: E402
import json  # noqa: E402
import os  # noqa: E402
import time  # noqa: E402

from repro.analysis.roofline import model_flops  # noqa: E402
from repro.configs import get_arch  # noqa: E402
from repro.launch.mesh import HW, make_production_mesh  # noqa: E402

OUT = "results/hillclimb.jsonl"


def _variants(arch: str, shape: str):
    """Ordered candidate list per pair: (name, rules_t, rules_s, cfg, fl)."""
    v = [("v0-baseline", {}, {}, {}, {})]
    if arch == "rwkv6-7b":
        # H1: Mode-A activation constraints must not claim the data axis for
        # the inner batch (the client axis already owns it).
        v.append(("v1-modeA-act-batch-free", {"act_batch": None}, {}, {}, {}))
        # H2: co-shard the decay/group-norm path with att_w so wkv r/k/v/w
        # keep one head sharding end-to-end (kills the 1 GiB fp32 regathers).
        v.append((
            "v2-headsharded-decay",
            {"act_batch": None, "att_vec_w": "model", "act_rwkv_h": "model"},
            {}, {}, {},
        ))
        # H3: paper lever — more local steps amortise the round sync.
        v.append((
            "v3-v2+E8",
            {"act_batch": None, "att_vec_w": "model", "act_rwkv_h": "model"},
            {}, {}, {"local_steps": 8},
        ))
    if arch == "mixtral-8x7b":
        # H1: expert-slice TP all-reduces dominate; move the second shard axis
        # of expert weights from d_ff to d_model (input-sharded => XLA can
        # all-gather weights instead of all-reducing (tokens × d) partials).
        v.append((
            "v1-expert-embed-sharded",
            {}, {"expert_mlp_w": None, "expert_embed_w": "model"}, {}, {},
        ))
        # H2: keep d_ff TP but head-shard attention activations explicitly.
        v.append((
            "v2-attn-head-constraint",
            {}, {"act_attn_h": "model"}, {}, {},
        ))
    if arch == "musicgen-medium":
        # H1: 24 heads can't shard 16-way => attention is replicated across
        # the model axis.  Batch-parallel attention: shard the per-client
        # local batch (16) over 'model' for the attention block; weights
        # replicate (0.9 GB total), activations drop 16x.
        v.append((
            "v1-batch-parallel-attn",
            {"act_attn_b": "model", "attn_in_w": None, "attn_out_w": None},
            {}, {}, {},
        ))
        # H2: v1 + Mode-A inner-batch axis freed
        v.append((
            "v2-v1+act-batch-free",
            {"act_attn_b": "model", "attn_in_w": None, "attn_out_w": None,
             "act_batch": None},
            {}, {}, {},
        ))
    return v


def eval_variant(arch, shape, name, rules_t=None, rules_s=None, cfg_over=None,
                 fl_over=None):
    t0 = time.time()
    spec = get_arch(arch)
    if rules_t:
        spec = dataclasses.replace(spec, train_rules=dict(spec.train_rules, **rules_t))
    if rules_s:
        spec = dataclasses.replace(spec, serve_rules=dict(spec.serve_rules, **rules_s))
    if fl_over:
        spec = dataclasses.replace(spec, fl=dataclasses.replace(spec.fl, **fl_over))
    case = dryrun.DryRunCase(arch, shape, multi_pod=False, accounting=True)
    _, cfg, dims = dryrun._case_config(case)
    if cfg_over:
        cfg = dataclasses.replace(cfg, **cfg_over)
    mesh = make_production_mesh(multi_pod=False)
    rec = {"arch": arch, "shape": shape, "variant": name,
           "rules_t": rules_t, "rules_s": rules_s, "fl": fl_over}
    try:
        acc = dryrun._accounting_counts(spec, cfg, dims, mesh, False)
        flops, byts = acc["flops"], acc["bytes"]
        coll = acc["collectives"].get("total", 0.0)
        rec.update(
            ok=True,
            t_compute=flops / HW.PEAK_FLOPS_BF16,
            t_memory=byts / HW.HBM_BW,
            t_collective=coll / HW.ICI_BW,
            collectives=acc["collectives"],
            flops=flops, bytes=byts,
        )
        mf = model_flops(arch, shape, spec.fl.mode, spec.fl.local_steps)
        rec["useful_ratio"] = mf / (flops * 256) if flops else None
    except Exception as e:
        import traceback

        rec.update(ok=False, error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-1500:])
    rec["wall_s"] = round(time.time() - t0, 1)
    return rec


def run_pair(arch, shape):
    rows = []
    for name, rt, rs, co, fo in _variants(arch, shape):
        rec = eval_variant(arch, shape, name, rt, rs, co, fo)
        rows.append(rec)
        if rec["ok"]:
            print(f"{arch} {shape} {name:28s} compute {rec['t_compute']:8.3f}s "
                  f"memory {rec['t_memory']:8.3f}s coll {rec['t_collective']:8.3f}s "
                  f"ratio {rec['useful_ratio']:.2f}  ({rec['wall_s']}s)")
        else:
            print(f"{arch} {shape} {name:28s} FAIL {rec['error'][:120]}")
        os.makedirs("results", exist_ok=True)
        with open(OUT, "a") as f:
            f.write(json.dumps(rec) + "\n")
    return rows


PAIRS = [
    ("rwkv6-7b", "train_4k"),        # most collective-bound
    ("mixtral-8x7b", "prefill_32k"),  # collective-bound serving
    ("musicgen-medium", "train_4k"),  # worst roofline fraction + Mode A (paper)
]


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--pair", help="arch:shape")
    ap.add_argument("--all", action="store_true")
    args = ap.parse_args()
    pairs = PAIRS if args.all else [tuple(args.pair.split(":"))]
    for arch, shape in pairs:
        run_pair(arch, shape)


if __name__ == "__main__":
    main()

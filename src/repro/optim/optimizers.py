"""Functional optimizers.

API (optax-shaped, dependency-free)::

    opt = adam(3e-4)
    state = opt.init(params)
    updates, state = opt.update(grads, state, params)
    params = apply_updates(params, updates)

``sgd`` is the FL-local optimizer (paper eq. 3-4 is plain SGD — stateless when
momentum = 0, which is what lets Mode-A client-parallel rounds avoid
replicating optimizer state per client).  ``adafactor`` provides the factored
second moment needed to fit llama4-maverick-400b optimizer state in HBM
(DESIGN.md §2).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = [
    "Optimizer",
    "apply_updates",
    "clip_by_global_norm",
    "sgd",
    "adam",
    "adamw",
    "adafactor",
]

PyTree = Any


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[PyTree], PyTree]
    update: Callable[[PyTree, PyTree, Optional[PyTree]], Tuple[PyTree, PyTree]]


def apply_updates(params: PyTree, updates: PyTree) -> PyTree:
    return jax.tree_util.tree_map(lambda p, u: (p + u).astype(p.dtype), params, updates)


def clip_by_global_norm(grads: PyTree, max_norm: float) -> PyTree:
    leaves = jax.tree_util.tree_leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-12))
    return jax.tree_util.tree_map(lambda g: g * scale, grads)


def sgd(lr: float, momentum: float = 0.0, nesterov: bool = False) -> Optimizer:
    def init(params):
        if momentum == 0.0:
            return ()
        return jax.tree_util.tree_map(jnp.zeros_like, params)

    def update(grads, state, params=None):
        if momentum == 0.0:
            return jax.tree_util.tree_map(lambda g: -lr * g, grads), ()
        new_m = jax.tree_util.tree_map(lambda m, g: momentum * m + g, state, grads)
        if nesterov:
            upd = jax.tree_util.tree_map(lambda m, g: -lr * (momentum * m + g), new_m, grads)
        else:
            upd = jax.tree_util.tree_map(lambda m: -lr * m, new_m)
        return upd, new_m

    return Optimizer(init, update)


class _AdamState(NamedTuple):
    step: jax.Array
    mu: PyTree
    nu: PyTree


def adam(
    lr: float,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
) -> Optimizer:
    def init(params):
        zeros32 = lambda p: jnp.zeros(p.shape, jnp.float32)
        return _AdamState(jnp.zeros((), jnp.int32), *(
            jax.tree_util.tree_map(zeros32, params) for _ in range(2)
        ))

    def update(grads, state, params=None):
        step = state.step + 1
        g32 = jax.tree_util.tree_map(lambda g: g.astype(jnp.float32), grads)
        mu = jax.tree_util.tree_map(lambda m, g: b1 * m + (1 - b1) * g, state.mu, g32)
        nu = jax.tree_util.tree_map(lambda v, g: b2 * v + (1 - b2) * g * g, state.nu, g32)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(m, v, p):
            u = -lr * (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            if weight_decay and p is not None:
                u = u - lr * weight_decay * p.astype(jnp.float32)
            return u

        if weight_decay and params is not None:
            updates = jax.tree_util.tree_map(upd, mu, nu, params)
        else:
            updates = jax.tree_util.tree_map(lambda m, v: upd(m, v, None), mu, nu)
        return updates, _AdamState(step, mu, nu)

    return Optimizer(init, update)


def adamw(lr: float, weight_decay: float = 0.01, **kw) -> Optimizer:
    return adam(lr, weight_decay=weight_decay, **kw)


class _AdafactorState(NamedTuple):
    step: jax.Array
    vr: PyTree  # row second moments (or full v for <2D tensors)
    vc: PyTree  # col second moments (or () for <2D tensors)


def adafactor(
    lr: float = 1e-2,
    decay: float = 0.8,
    eps: float = 1e-30,
    clip_threshold: float = 1.0,
) -> Optimizer:
    """Adafactor (Shazeer & Stern) with factored second moments for >=2-D
    tensors — O(n+m) optimizer state instead of O(n·m)."""

    def _factored(p):
        return p.ndim >= 2

    def init(params):
        def vr_init(p):
            if _factored(p):
                return jnp.zeros(p.shape[:-1], jnp.float32)
            return jnp.zeros(p.shape, jnp.float32)

        def vc_init(p):
            if _factored(p):
                return jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
            return jnp.zeros((), jnp.float32)

        return _AdafactorState(
            jnp.zeros((), jnp.int32),
            jax.tree_util.tree_map(vr_init, params),
            jax.tree_util.tree_map(vc_init, params),
        )

    def update(grads, state, params=None):
        step = state.step + 1
        beta = 1.0 - (step.astype(jnp.float32)) ** (-decay)

        def upd(g, vr, vc):
            g = g.astype(jnp.float32)
            g2 = g * g + eps
            if g.ndim >= 2:
                vr_n = beta * vr + (1 - beta) * jnp.mean(g2, axis=-1)
                vc_n = beta * vc + (1 - beta) * jnp.mean(g2, axis=-2)
                r = vr_n / jnp.maximum(jnp.mean(vr_n, axis=-1, keepdims=True), eps)
                v = r[..., None] * vc_n[..., None, :]
            else:
                vr_n = beta * vr + (1 - beta) * g2
                vc_n = vc
                v = vr_n
            u = g / jnp.sqrt(jnp.maximum(v, eps))
            rms = jnp.sqrt(jnp.mean(u * u) + eps)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            return -lr * u, vr_n, vc_n

        flat_g, treedef = jax.tree_util.tree_flatten(grads)
        flat_vr = treedef.flatten_up_to(state.vr)
        flat_vc = treedef.flatten_up_to(state.vc)
        out = [upd(g, vr, vc) for g, vr, vc in zip(flat_g, flat_vr, flat_vc)]
        updates = treedef.unflatten([o[0] for o in out])
        vr = treedef.unflatten([o[1] for o in out])
        vc = treedef.unflatten([o[2] for o in out])
        return updates, _AdafactorState(step, vr, vc)

    return Optimizer(init, update)

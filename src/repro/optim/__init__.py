"""Native optimizers (no optax): functional ``init/update`` pairs."""

from repro.optim.optimizers import (
    Optimizer,
    adafactor,
    adam,
    adamw,
    apply_updates,
    clip_by_global_norm,
    sgd,
)

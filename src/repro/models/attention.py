"""GQA attention (full + sliding-window) with KV-cache decode.

Conventions:
* activations: (B, S, D); q/k/v: (B, S, H|Hk, head_dim);
* KV cache: {"k","v": (B, cache_len, Hk, hd), "pos": ()} — for SWA blocks the
  cache is a ring buffer of ``window`` slots (slot = pos % window), so a
  524k-token decode only ever holds ``window`` KV entries (the long_500k
  story for dense archs, DESIGN.md §3);
* GQA grouping: q heads are folded to (Hk, G) so k/v are used ungrouped — no
  repeat_kv materialisation.

``use_flash`` routes the no-cache causal path through the Pallas
flash-attention kernel (TPU target; interpret mode on CPU).
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.launch.sharding import constrain
from repro.models import layers as L

__all__ = ["init_attention", "init_cache", "apply_attention"]

NEG_INF = -2.0e38


def init_attention(key, cfg: ModelConfig) -> Dict:
    dtype = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 4)
    return {
        "wq": L.init_dense(ks[0], cfg.d_model, cfg.q_dim, dtype),
        "wk": L.init_dense(ks[1], cfg.d_model, cfg.kv_dim, dtype),
        "wv": L.init_dense(ks[2], cfg.d_model, cfg.kv_dim, dtype),
        "wo": L.init_dense(ks[3], cfg.q_dim, cfg.d_model, dtype),
    }


def init_cache(
    cfg: ModelConfig, batch: int, cache_len: int, window: Optional[int],
    per_slot: bool = False,
) -> Dict:
    """Preallocated KV cache; ring buffer of ``window`` slots for SWA.

    ``per_slot=True`` tracks one position *per batch row* (``pos: (B,)``) so
    heterogeneous decode slots — each sequence at its own depth — are
    representable (the serving engine's contract, DESIGN.md §13).  The
    default scalar convention is unchanged."""
    slots = min(cache_len, window) if window else cache_len
    dtype = jnp.dtype(cfg.dtype)
    return {
        "k": jnp.zeros((batch, slots, cfg.num_kv_heads, cfg.head_dim), dtype),
        "v": jnp.zeros((batch, slots, cfg.num_kv_heads, cfg.head_dim), dtype),
        "pos": jnp.zeros((batch,) if per_slot else (), jnp.int32),
    }


def _positions_rope(cfg: ModelConfig, x: jax.Array, positions: jax.Array) -> jax.Array:
    if cfg.pos_style == "mrope":
        return L.apply_mrope(x, positions, cfg.rope_theta, cfg.mrope_sections)
    if cfg.pos_style == "rope":
        if positions.ndim == 3:  # M-RoPE-style stream given to a RoPE model
            positions = positions[0]
        return L.apply_rope(x, positions, cfg.rope_theta)
    return x  # sinusoidal/none handled at the embedding level


def _attend(
    q: jax.Array,  # (B, Sq, H, hd)
    k: jax.Array,  # (B, Skv, Hk, hd)
    v: jax.Array,  # (B, Skv, Hk, hd)
    q_pos: jax.Array,  # (B, Sq)
    kv_pos: jax.Array,  # (B, Skv)
    kv_valid: jax.Array,  # (B, Skv) bool
    window: Optional[int],
    chunk: Optional[int] = None,
    unroll=1,
) -> jax.Array:
    """Exact masked GQA attention.

    ``chunk=None`` materialises the full (B, Hk, G, Sq, Skv) score tensor —
    fine for smoke tests, catastrophic at 32k+ sequence (S² fp32 temps blow
    the 16 GB/chip budget; see EXPERIMENTS.md §Perf iteration 1).  With
    ``chunk`` set, queries are processed in blocks via ``lax.scan`` so live
    scores are (…, chunk, Skv) — the pure-jnp analogue of the Pallas flash
    kernel (which remains the TPU fast path via ``use_flash``).
    """
    b, sq, h, hd = q.shape
    hk = k.shape[2]
    g = h // hk

    def block(q_blk, qpos_blk):
        # q_blk: (B, cq, H, hd); scores (B, Hk, G, cq, Skv) fp32
        qg = q_blk.reshape(b, q_blk.shape[1], hk, g, hd)
        scores = jnp.einsum("bqkgd,bskd->bkgqs", qg, k).astype(jnp.float32)
        scores = scores * (hd**-0.5)
        mask = kv_pos[:, None, :] <= qpos_blk[:, :, None]
        if window is not None:
            mask &= kv_pos[:, None, :] > qpos_blk[:, :, None] - window
        mask &= kv_valid[:, None, :]
        scores = jnp.where(mask[:, None, None, :, :], scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
        out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v)
        return out.reshape(b, q_blk.shape[1], h, hd)

    if chunk is None or chunk >= sq:
        return block(q, q_pos)

    n, rem = divmod(sq, chunk)
    qs = q[:, : n * chunk].reshape(b, n, chunk, h, hd).swapaxes(0, 1)
    ps = q_pos[:, : n * chunk].reshape(b, n, chunk).swapaxes(0, 1)

    def body(_, xs):
        q_blk, p_blk = xs
        return None, block(q_blk, p_blk)

    _, outs = jax.lax.scan(body, None, (qs, ps), unroll=unroll)
    out = outs.swapaxes(0, 1).reshape(b, n * chunk, h, hd)
    if rem:
        out = jnp.concatenate([out, block(q[:, n * chunk :], q_pos[:, n * chunk :])], axis=1)
    return out


def apply_attention(
    cfg: ModelConfig,
    p: Dict,
    x: jax.Array,
    positions: jax.Array,
    cache: Optional[Dict] = None,
    window: Optional[int] = None,
    use_flash: bool = False,
) -> Tuple[jax.Array, Optional[Dict]]:
    """Attention block body.  ``cache=None`` → training (no cache returned);
    with a cache: S == cache write length (prefill) or 1 (decode step)."""
    b, s, _ = x.shape
    q = L.dense(p["wq"], x).reshape(b, s, cfg.num_heads, cfg.head_dim)
    k = L.dense(p["wk"], x).reshape(b, s, cfg.num_kv_heads, cfg.head_dim)
    v = L.dense(p["wv"], x).reshape(b, s, cfg.num_kv_heads, cfg.head_dim)

    if positions.ndim == 2:
        q_pos = positions
    else:  # mrope (3, B, S): causal masking follows the temporal stream
        q_pos = positions[0]
    q = _positions_rope(cfg, q, positions)
    k = _positions_rope(cfg, k, positions)
    # hillclimb-gated layouts (default no-op): batch-parallel attention for
    # archs whose head counts can't shard the 16-way model axis (§Perf)
    q = constrain(q, "act_attn_b", "act_seq", "act_attn_h", None)
    k = constrain(k, "act_attn_b", "act_seq", "act_attn_kv", None)
    v = constrain(v, "act_attn_b", "act_seq", "act_attn_kv", None)

    if cache is None:
        if use_flash and window is None:
            from repro.kernels.flash_attention import ops as flash_ops

            out = flash_ops.flash_attention(q, k, v, causal=True)
        else:
            valid = jnp.ones((b, s), bool)
            out = _attend(q, k, v, q_pos, q_pos, valid, window,
                          chunk=cfg.attention_chunk, unroll=cfg.loss_unroll)
        new_cache = None
    else:
        slots = cache["k"].shape[1]
        pos0 = cache["pos"]
        per_slot = pos0.ndim == 1  # (B,) heterogeneous slot positions
        if not per_slot and s == slots and window is None:
            # prefill writing the whole cache
            ck, cv = k.astype(cache["k"].dtype), v.astype(cache["v"].dtype)
        elif per_slot:
            # each row writes at its own ring offset
            idx = (pos0[:, None] + jnp.arange(s)[None, :]) % slots  # (B, s)
            bidx = jnp.arange(b)[:, None]
            ck = cache["k"].at[bidx, idx].set(k.astype(cache["k"].dtype))
            cv = cache["v"].at[bidx, idx].set(v.astype(cache["v"].dtype))
        else:
            idx = (pos0 + jnp.arange(s)) % slots
            ck = cache["k"].at[:, idx].set(k.astype(cache["k"].dtype))
            cv = cache["v"].at[:, idx].set(v.astype(cache["v"].dtype))
        new_pos = pos0 + s
        # absolute positions held in each slot (ring-aware)
        slot_ids = jnp.arange(slots)
        np_b = new_pos[:, None] if per_slot else new_pos  # (B,1) | ()
        if window is None:
            kv_pos = slot_ids[None, :].repeat(b, 0)
            kv_valid = slot_ids[None, :] < np_b
        else:
            # slot holds the latest absolute position congruent mod `slots`
            last = np_b - 1
            kv_pos = last - ((last - slot_ids[None, :]) % slots)
            kv_pos = jnp.broadcast_to(kv_pos, (b, slots))
            kv_valid = (kv_pos >= 0) & (kv_pos < np_b)
        if use_flash and s == 1 and window is None:
            from repro.kernels.flash_attention import ops as flash_ops

            lengths = jnp.broadcast_to(jnp.minimum(new_pos, slots), (b,))
            out = flash_ops.flash_decode(q, ck, cv, lengths)
        else:
            out = _attend(q, ck, cv, q_pos, kv_pos, kv_valid, window,
                          chunk=cfg.attention_chunk, unroll=cfg.loss_unroll)
        new_cache = {"k": ck, "v": cv, "pos": new_pos}

    y = L.dense(p["wo"], out.reshape(b, s, cfg.q_dim))
    return y, new_cache

"""RG-LRU recurrent block (Griffin / RecurrentGemma, arXiv:2402.19427).

Block:  y = W_out( GeLU(W_gate x) ⊙ RG-LRU(causal_conv1d(W_in x)) )

RG-LRU (per channel):
    r_t = σ(W_r ξ_t + b_r)                 recurrence gate
    i_t = σ(W_i ξ_t + b_i)                 input gate
    a_t = exp(−c · softplus(Λ) · r_t)      data-dependent decay (c = 8)
    h_t = a_t ⊙ h_{t−1} + √(1 − a_t²) ⊙ (i_t ⊙ ξ_t)

Training uses ``jax.lax.associative_scan`` over the linear recurrence
(h_t = a_t h_{t−1} + b_t is associative) — the TPU-friendly parallel form;
decode carries (conv buffer, h) state with O(1) work per token.  This is the
"recurrent-scan sharding" path the assignment calls out: the scan is over
*time*, states shard over (batch, rnn-width) mesh axes.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L

__all__ = ["init_rglru", "init_rglru_state", "apply_rglru"]

_C = 8.0
_CONV_W = 4  # causal conv width (griffin uses 4)


def _rnn_width(cfg: ModelConfig) -> int:
    return cfg.rnn_width or cfg.d_model


def init_rglru(key, cfg: ModelConfig) -> Dict:
    dtype = jnp.dtype(cfg.param_dtype)
    d, dr = cfg.d_model, _rnn_width(cfg)
    ks = jax.random.split(key, 7)
    # Λ init so that a ∈ (0.9, 0.999) at r = 1 (griffin appendix)
    u = jax.random.uniform(ks[0], (dr,), jnp.float32, 0.9**2, 0.999**2)
    lam = jnp.log(jnp.expm1(-jnp.log(u) / (2.0 * _C)))  # softplus^-1
    return {
        "w_in": L.init_dense(ks[1], d, dr, dtype),
        "w_gate": L.init_dense(ks[2], d, dr, dtype),
        "w_out": L.init_dense(ks[3], dr, d, dtype),
        "conv_w": (jax.random.normal(ks[4], (_CONV_W, dr)) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((dr,), dtype),
        "w_r": L.init_dense(ks[5], dr, dr, dtype),
        "b_r": jnp.zeros((dr,), dtype),
        "w_i": L.init_dense(ks[6], dr, dr, dtype),
        "b_i": jnp.zeros((dr,), dtype),
        "lam": lam.astype(jnp.float32),
    }


def init_rglru_state(cfg: ModelConfig, batch: int, per_slot: bool = False) -> Dict:
    dr = _rnn_width(cfg)
    return {
        "conv": jnp.zeros((batch, _CONV_W - 1, dr), jnp.dtype(cfg.dtype)),
        "h": jnp.zeros((batch, dr), jnp.float32),
        "pos": jnp.zeros((batch,) if per_slot else (), jnp.int32),
    }


def _causal_conv(p: Dict, xi: jax.Array, buf: Optional[jax.Array]) -> Tuple[jax.Array, Optional[jax.Array]]:
    """Depthwise causal conv over (B, S, dr); ``buf`` carries the last W-1
    inputs for decode."""
    if buf is not None:
        full = jnp.concatenate([buf.astype(xi.dtype), xi], axis=1)
        new_buf = full[:, -(_CONV_W - 1):, :]
    else:
        pad = jnp.zeros((xi.shape[0], _CONV_W - 1, xi.shape[2]), xi.dtype)
        full = jnp.concatenate([pad, xi], axis=1)
        new_buf = None
    s = xi.shape[1]
    out = sum(
        full[:, i : i + s, :] * p["conv_w"][i] for i in range(_CONV_W)
    ) + p["conv_b"]
    return out, new_buf


def _gates(p: Dict, xi: jax.Array) -> Tuple[jax.Array, jax.Array]:
    r = jax.nn.sigmoid((xi @ p["w_r"]["w"] + p["b_r"]).astype(jnp.float32))
    i = jax.nn.sigmoid((xi @ p["w_i"]["w"] + p["b_i"]).astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(p["lam"]) * r
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (
        i * xi.astype(jnp.float32)
    )
    return a, b


def apply_rglru(
    cfg: ModelConfig,
    p: Dict,
    x: jax.Array,
    state: Optional[Dict] = None,
) -> Tuple[jax.Array, Optional[Dict]]:
    """x: (B, S, D) -> (y, new_state).  ``state=None`` → parallel train path
    (associative scan from h_0 = 0); otherwise sequential from state["h"]."""
    gate = jax.nn.gelu(L.dense(p["w_gate"], x), approximate=True)
    xi = L.dense(p["w_in"], x)

    if state is None:
        xi, _ = _causal_conv(p, xi, None)
        a, b = _gates(p, xi)  # (B, S, dr) fp32
        # associative linear recurrence: (a, b) ∘ (a', b') = (aa', a'b + b')
        def combine(lhs, rhs):
            al, bl = lhs
            ar, br = rhs
            return al * ar, ar * bl + br

        _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
        new_state = None
    else:
        xi, new_buf = _causal_conv(p, xi, state["conv"])
        a, b = _gates(p, xi)

        def step(h, ab):
            a_t, b_t = ab
            h = a_t * h + b_t
            return h, h

        h_last, h = jax.lax.scan(
            step, state["h"], (a.swapaxes(0, 1), b.swapaxes(0, 1))
        )
        h = h.swapaxes(0, 1)
        new_state = {"conv": new_buf, "h": h_last, "pos": state["pos"] + x.shape[1]}

    y = L.dense(p["w_out"], (gate.astype(jnp.float32) * h).astype(x.dtype))
    return y, new_state

"""Shared decoder-LM layers: norms, position encodings, MLP variants.

Pure init/apply pairs over dict pytrees; everything is shape-polymorphic over
a leading batch dim and takes the ``ModelConfig`` for variant switches.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

__all__ = [
    "rms_norm",
    "layer_norm",
    "init_norm",
    "apply_norm",
    "rope_freqs",
    "apply_rope",
    "apply_mrope",
    "sinusoidal_positions",
    "init_mlp",
    "apply_mlp",
    "init_dense",
    "dense",
]


def _init_dense_w(key, shape, dtype, scale: Optional[float] = None):
    fan_in = shape[0]
    std = scale if scale is not None else fan_in**-0.5
    return (jax.random.normal(key, shape) * std).astype(dtype)


def init_dense(key, d_in: int, d_out: int, dtype) -> Dict:
    return {"w": _init_dense_w(key, (d_in, d_out), dtype)}


def dense(p: Dict, x: jax.Array) -> jax.Array:
    return x @ p["w"]


# ----------------------------------------------------------------- norms


def init_norm(cfg: ModelConfig, dim: Optional[int] = None) -> Dict:
    d = dim or cfg.d_model
    p = {"scale": jnp.ones((d,), jnp.dtype(cfg.param_dtype))}
    if cfg.norm_type == "layernorm":
        p["bias"] = jnp.zeros((d,), jnp.dtype(cfg.param_dtype))
    return p


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)).astype(x.dtype)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array, eps: float = 1e-5) -> jax.Array:
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x32 - mu), axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(x.dtype)


def apply_norm(cfg: ModelConfig, p: Dict, x: jax.Array) -> jax.Array:
    if cfg.norm_type == "layernorm":
        return layer_norm(x, p["scale"], p["bias"])
    return rms_norm(x, p["scale"])


# ----------------------------------------------------------------- RoPE


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def _rotate(x: jax.Array, angles: jax.Array) -> jax.Array:
    """Rotate pairs (half-split convention).  x: (..., head_dim); angles:
    broadcastable (..., head_dim//2)."""
    d2 = x.shape[-1] // 2
    x1, x2 = x[..., :d2], x[..., d2:]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    ).astype(x.dtype)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Standard RoPE.  x: (B, S, H, hd); positions: (B, S) int."""
    inv = rope_freqs(x.shape[-1], theta)  # (hd/2,)
    ang = positions.astype(jnp.float32)[..., None] * inv  # (B, S, hd/2)
    return _rotate(x, ang[:, :, None, :])


def apply_mrope(
    x: jax.Array, positions: jax.Array, theta: float, sections: Tuple[int, int, int]
) -> jax.Array:
    """Multimodal RoPE (Qwen2-VL §2.1): the hd/2 frequency slots are split
    into (t, h, w) sections, each rotated by its own position stream.

    x: (B, S, H, hd); positions: (3, B, S) int — temporal, height, width.
    For pure text all three streams are equal and M-RoPE == RoPE.
    """
    d2 = x.shape[-1] // 2
    assert sum(sections) == d2, (sections, d2)
    inv = rope_freqs(x.shape[-1], theta)  # (hd/2,)
    sec_id = jnp.repeat(jnp.arange(3), jnp.asarray(sections), total_repeat_length=d2)
    pos_per_slot = jnp.take(positions.astype(jnp.float32), sec_id, axis=0)  # (d2,B,S)
    ang = jnp.moveaxis(pos_per_slot, 0, -1) * inv  # (B, S, d2)
    return _rotate(x, ang[:, :, None, :])


def sinusoidal_positions(positions: jax.Array, d_model: int) -> jax.Array:
    """Sinusoidal absolute embeddings (musicgen-style). positions: (B, S)."""
    half = d_model // 2
    freqs = jnp.exp(-jnp.log(10_000.0) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ----------------------------------------------------------------- MLPs


def init_mlp(key, cfg: ModelConfig, d_ff: Optional[int] = None) -> Dict:
    dtype = jnp.dtype(cfg.param_dtype)
    ff = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.mlp_variant in ("swiglu", "geglu"):
        return {
            "wi": init_dense(ks[0], cfg.d_model, ff, dtype),
            "wg": init_dense(ks[1], cfg.d_model, ff, dtype),
            "wo": init_dense(ks[2], ff, cfg.d_model, dtype),
        }
    return {
        "wi": init_dense(ks[0], cfg.d_model, ff, dtype),
        "wo": init_dense(ks[2], ff, cfg.d_model, dtype),
    }


def apply_mlp(cfg: ModelConfig, p: Dict, x: jax.Array) -> jax.Array:
    if cfg.mlp_variant == "swiglu":
        h = jax.nn.silu(dense(p["wg"], x)) * dense(p["wi"], x)
    elif cfg.mlp_variant == "geglu":
        h = jax.nn.gelu(dense(p["wg"], x), approximate=True) * dense(p["wi"], x)
    else:
        h = jax.nn.gelu(dense(p["wi"], x), approximate=True)
    return dense(p["wo"], h)

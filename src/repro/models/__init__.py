"""Model substrate: the paper's CNN + a composable decoder-LM stack."""

"""Composable decoder transformer covering all assigned architectures.

A model is a ``block_pattern`` — a repeating unit of "mixer+ffn" layer specs:

    mixers:  attn (full GQA) | swa (window=cfg.window) |
             local (window=cfg.local_window) | rglru | rwkv
    ffns:    mlp | moe | cmix

e.g. granite = ("attn+mlp",); mixtral = ("swa+moe",);
llama4 = ("attn+mlp", "attn+moe") (MoE every other layer);
recurrentgemma = ("rglru+mlp", "rglru+mlp", "local+mlp"); rwkv6 = ("rwkv+cmix",).

Layers run as ``lax.scan`` over repeats of the pattern unit (stacked params →
HLO size ~independent of depth, which keeps all 80 dry-run compiles
tractable), with the non-multiple remainder applied unstacked.  ``cfg.remat``
wraps the scanned unit in ``jax.checkpoint``.

The LM loss is *vocab-chunk-free but sequence-chunked*: logits are produced
per sequence chunk inside a scan so the (B, S, V) tensor is never
materialised — at gemma's 256k vocab that is the difference between 67 GB and
<1 GB of live logits per device (see §Perf).
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.launch.sharding import constrain
from repro.models import attention as attn_mod
from repro.models import layers as L
from repro.models import moe as moe_mod
from repro.models import rglru as rglru_mod
from repro.models import rwkv6 as rwkv_mod

__all__ = [
    "init_params",
    "init_caches",
    "forward",
    "lm_loss",
    "decode_step",
    "features",
    "param_count",
]


def _parse(btype: str) -> Tuple[str, str]:
    mixer, ffn = btype.split("+")
    return mixer, ffn


def _mixer_window(cfg: ModelConfig, mixer: str) -> Optional[int]:
    return {"attn": None, "swa": cfg.window, "local": cfg.local_window}.get(mixer)


def vocab_padded(cfg: ModelConfig) -> int:
    return -(-cfg.vocab_size // 128) * 128


# ------------------------------------------------------------------ init


def _init_block(key, cfg: ModelConfig, btype: str) -> Dict:
    mixer, ffn = _parse(btype)
    ks = jax.random.split(key, 4)
    p: Dict[str, Any] = {"norm1": L.init_norm(cfg), "norm2": L.init_norm(cfg)}
    if mixer in ("attn", "swa", "local"):
        p["mixer"] = attn_mod.init_attention(ks[0], cfg)
    elif mixer == "rglru":
        p["mixer"] = rglru_mod.init_rglru(ks[0], cfg)
    elif mixer == "rwkv":
        p["mixer"] = rwkv_mod.init_rwkv_tmix(ks[0], cfg)
    else:
        raise ValueError(mixer)
    if ffn == "mlp":
        p["ffn"] = L.init_mlp(ks[1], cfg)
    elif ffn == "moe":
        p["ffn"] = moe_mod.init_moe(ks[1], cfg)
    elif ffn == "cmix":
        p["ffn"] = rwkv_mod.init_rwkv_cmix(ks[1], cfg)
    else:
        raise ValueError(ffn)
    return p


def init_params(key, cfg: ModelConfig) -> Dict:
    dtype = jnp.dtype(cfg.param_dtype)
    pattern = cfg.block_pattern
    reps, rem = divmod(cfg.num_layers, len(pattern))
    ks = jax.random.split(key, 4 + len(pattern))
    v = vocab_padded(cfg)
    params: Dict[str, Any] = {
        "embed": {"w": (jax.random.normal(ks[0], (v, cfg.d_model)) * 0.02).astype(dtype)},
        "final_norm": L.init_norm(cfg),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = L.init_dense(ks[1], cfg.d_model, v, dtype)
    unit = []
    for j, btype in enumerate(pattern):
        rep_keys = jax.random.split(ks[3 + j], max(reps, 1))
        stacked = jax.vmap(lambda k, b=btype: _init_block(k, cfg, b))(rep_keys)
        if reps == 0:
            stacked = jax.tree_util.tree_map(lambda x: x[:0], stacked)
        unit.append(stacked)
    params["unit"] = tuple(unit)
    params["rem"] = tuple(
        _init_block(jax.random.fold_in(ks[2], j), cfg, pattern[j]) for j in range(rem)
    )
    return params


def _init_block_cache(cfg: ModelConfig, btype: str, batch: int, cache_len: int,
                      per_slot: bool = False):
    mixer, _ = _parse(btype)
    if mixer in ("attn", "swa", "local"):
        return attn_mod.init_cache(cfg, batch, cache_len,
                                   _mixer_window(cfg, mixer), per_slot=per_slot)
    if mixer == "rglru":
        return rglru_mod.init_rglru_state(cfg, batch, per_slot=per_slot)
    return rwkv_mod.init_rwkv_state(cfg, batch, per_slot=per_slot)


def init_caches(cfg: ModelConfig, batch: int, cache_len: int,
                per_slot: bool = False) -> Dict:
    """``per_slot=True`` carries one position per batch row (``pos: (B,)``)
    so decode slots at heterogeneous depths share one compiled program — the
    serving-engine cache layout (DESIGN.md §13).  Default is the legacy
    shared-scalar convention, bit-identical to before."""
    pattern = cfg.block_pattern
    reps, rem = divmod(cfg.num_layers, len(pattern))
    unit = []
    for btype in pattern:
        one = _init_block_cache(cfg, btype, batch, cache_len, per_slot)
        unit.append(
            jax.tree_util.tree_map(
                lambda x: jnp.broadcast_to(x, (reps,) + x.shape).copy(), one
            )
        )
    rem_caches = tuple(
        _init_block_cache(cfg, pattern[j], batch, cache_len, per_slot)
        for j in range(rem)
    )
    return {"unit": tuple(unit), "rem": rem_caches}


# ------------------------------------------------------------------ blocks


def _apply_block(
    cfg: ModelConfig,
    p: Dict,
    btype: str,
    x: jax.Array,
    positions: jax.Array,
    cache,
    use_flash: bool,
) -> Tuple[jax.Array, Any, jax.Array]:
    mixer, ffn = _parse(btype)
    h = L.apply_norm(cfg, p["norm1"], x)
    if mixer in ("attn", "swa", "local"):
        y, new_cache = attn_mod.apply_attention(
            cfg, p["mixer"], h, positions, cache, _mixer_window(cfg, mixer), use_flash
        )
    elif mixer == "rglru":
        y, new_cache = rglru_mod.apply_rglru(cfg, p["mixer"], h, cache)
    else:
        y, new_cache = rwkv_mod.apply_rwkv_tmix(cfg, p["mixer"], h, cache)
    x = x + y

    h = L.apply_norm(cfg, p["norm2"], x)
    aux = jnp.zeros((), jnp.float32)
    if ffn == "mlp":
        y = L.apply_mlp(cfg, p["ffn"], h)
    elif ffn == "moe":
        y, aux = moe_mod.apply_moe(cfg, p["ffn"], h)
    else:  # cmix shares the rwkv state dict
        y, new_cache = rwkv_mod.apply_rwkv_cmix(cfg, p["ffn"], h, new_cache)
    return x + y, new_cache, aux


# ------------------------------------------------------------------ forward


def _embed_in(cfg, params, tokens, positions, embeds):
    if embeds is not None:
        x = embeds.astype(jnp.dtype(cfg.dtype))
    else:
        x = params["embed"]["w"][tokens].astype(jnp.dtype(cfg.dtype))
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model**0.5, x.dtype)
    if cfg.pos_style == "sinusoidal":
        pos = positions if positions.ndim == 2 else positions[0]
        x = x + L.sinusoidal_positions(pos, cfg.d_model).astype(x.dtype)
    return x


def forward(
    cfg: ModelConfig,
    params: Dict,
    tokens: Optional[jax.Array],
    positions: jax.Array,
    caches: Optional[Dict] = None,
    embeds: Optional[jax.Array] = None,
    use_flash: bool = False,
) -> Tuple[jax.Array, Optional[Dict], jax.Array]:
    """-> (final hidden (B, S, D), new caches, total aux loss)."""
    pattern = cfg.block_pattern
    x = _embed_in(cfg, params, tokens, positions, embeds)
    x = constrain(x, "act_batch", "act_seq", "act_embed")

    if caches is None:

        def unit_body(carry, unit_slice):
            x, aux = carry
            for j, btype in enumerate(pattern):
                x, _, a = _apply_block(cfg, unit_slice[j], btype, x, positions, None, use_flash)
                x = constrain(x, "act_batch", "act_seq", "act_embed")
                aux = aux + a
            return (x, aux), None

        body = jax.checkpoint(unit_body) if cfg.remat else unit_body
        (x, aux), _ = lax.scan(
            body, (x, jnp.zeros((), jnp.float32)), params["unit"],
            unroll=cfg.scan_unroll,
        )
        for j, p in enumerate(params["rem"]):
            x, _, a = _apply_block(cfg, p, pattern[j], x, positions, None, use_flash)
            aux = aux + a
        new_caches = None
    else:

        def unit_body(x, xs):
            unit_slice, cache_slice = xs
            new_slice = []
            for j, btype in enumerate(pattern):
                x, nc, _ = _apply_block(
                    cfg, unit_slice[j], btype, x, positions, cache_slice[j], use_flash
                )
                new_slice.append(nc)
            return x, tuple(new_slice)

        x, new_unit = lax.scan(
            unit_body, x, (params["unit"], caches["unit"]), unroll=cfg.scan_unroll
        )
        new_rem = []
        for j, p in enumerate(params["rem"]):
            x, nc, _ = _apply_block(
                cfg, p, pattern[j], x, positions, caches["rem"][j], use_flash
            )
            new_rem.append(nc)
        new_caches = {"unit": new_unit, "rem": tuple(new_rem)}
        aux = jnp.zeros((), jnp.float32)

    x = L.apply_norm(cfg, params["final_norm"], x)
    return x, new_caches, aux


def _head_weight(cfg: ModelConfig, params: Dict) -> jax.Array:
    if cfg.tie_embeddings:
        return params["embed"]["w"].T  # (D, V)
    return params["lm_head"]["w"]


def logits_from_hidden(cfg: ModelConfig, params: Dict, hidden: jax.Array) -> jax.Array:
    logits = hidden @ _head_weight(cfg, params).astype(hidden.dtype)
    if cfg.logits_soft_cap:
        c = cfg.logits_soft_cap
        logits = jnp.tanh(logits / c) * c
    return logits


def lm_loss(
    cfg: ModelConfig,
    params: Dict,
    tokens: Optional[jax.Array] = None,
    positions: Optional[jax.Array] = None,
    loss_chunk: Optional[int] = None,
    use_flash: bool = False,
    embeds: Optional[jax.Array] = None,
    targets: Optional[jax.Array] = None,
) -> jax.Array:
    """Next-token CE, sequence-chunked so (B, S, V) logits never materialise.

    VLM/audio stubs pass ``embeds`` (frontend output) + ``targets``; text LMs
    pass ``tokens`` and targets default to the shifted tokens."""
    b, s = tokens.shape[:2] if tokens is not None else embeds.shape[:2]
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
        if cfg.pos_style == "mrope":
            positions = jnp.broadcast_to(positions[None], (3, b, s))
    hidden, _, aux = forward(
        cfg, params, tokens, positions, embeds=embeds, use_flash=use_flash
    )
    h_in = hidden[:, :-1]
    if targets is None:
        targets = tokens[:, 1:]
    else:
        targets = targets[:, 1:] if targets.shape[1] == s else targets
    n = h_in.shape[1]
    chunk = min(loss_chunk or cfg.loss_chunk, n)
    n_chunks, tail = divmod(n, chunk)
    w = _head_weight(cfg, params)

    def ce(h_c, t_c):
        logits = h_c @ w.astype(h_c.dtype)
        if cfg.logits_soft_cap:
            logits = jnp.tanh(logits / cfg.logits_soft_cap) * cfg.logits_soft_cap
        logits = logits.astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, t_c[..., None].astype(jnp.int32), axis=-1)[..., 0]
        return jnp.sum(lse - gold)

    def body(tot, xs):
        h_c, t_c = xs
        return tot + ce(h_c, t_c), None

    h_main = h_in[:, : n_chunks * chunk].reshape(b, n_chunks, chunk, -1).swapaxes(0, 1)
    t_main = targets[:, : n_chunks * chunk].reshape(b, n_chunks, chunk).swapaxes(0, 1)
    total, _ = lax.scan(
        body, jnp.zeros((), jnp.float32), (h_main, t_main), unroll=cfg.loss_unroll
    )
    if tail:
        total = total + ce(h_in[:, n_chunks * chunk :], targets[:, n_chunks * chunk :])
    return total / (b * n) + aux


def decode_step(
    cfg: ModelConfig,
    params: Dict,
    tokens: jax.Array,  # (B, 1) int32 (or embeds via kwarg)
    caches: Dict,
    embeds: Optional[jax.Array] = None,
    use_flash: bool = False,
) -> Tuple[jax.Array, Dict]:
    """One-token decode against the cache -> (logits (B, 1, V), new caches).

    With a per-slot cache (``init_caches(..., per_slot=True)``) each batch
    row decodes at its own position; the shared-scalar cache keeps the old
    uniform program bit-identical."""
    b = tokens.shape[0] if tokens is not None else embeds.shape[0]
    pos = _cache_pos(caches)
    if pos.ndim:  # per-slot (B,)
        positions = pos[:, None].astype(jnp.int32)
    else:
        positions = jnp.full((b, 1), pos, jnp.int32)
    if cfg.pos_style == "mrope":
        positions = jnp.broadcast_to(positions[None], (3, b, 1))
    hidden, new_caches, _ = forward(
        cfg, params, tokens, positions, caches, embeds, use_flash=use_flash
    )
    return logits_from_hidden(cfg, params, hidden), new_caches


def _cache_pos(caches: Dict) -> jax.Array:
    """Current position(s): () shared-scalar or (B,) per-slot.

    Unit caches are stacked with a leading (reps,) axis — every layer holds
    the same position, so read entry 0; remainder caches are unstacked."""
    if caches["unit"] and caches["unit"][0]["pos"].shape[0]:
        return caches["unit"][0]["pos"][0]  # (reps,)->() or (reps, B)->(B,)
    leaf = caches["rem"][0]["pos"]
    return leaf  # () or (B,)


def features(
    cfg: ModelConfig,
    params: Dict,
    tokens: jax.Array,
    positions: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array]:
    """(logits over last position, mean pre-logits hidden) — the FL data
    profile for LM clients (DESIGN.md §3: Theorem-1 analogue)."""
    b, s = tokens.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
        if cfg.pos_style == "mrope":
            positions = jnp.broadcast_to(positions[None], (3, b, s))
    hidden, _, _ = forward(cfg, params, tokens, positions)
    feats = hidden.mean(axis=1)  # (B, D)
    logits = logits_from_hidden(cfg, params, hidden[:, -1:])
    return logits, feats


def param_count(params: Dict) -> int:
    return sum(x.size for x in jax.tree_util.tree_leaves(params))

"""The paper's CNN: two conv layers + two fully-connected layers (§4).

Pure-JAX (init/apply pairs).  ``apply_with_features`` exposes the FC-1
*pre-activation* outputs — exactly the ``h_q`` of Theorem 1 — for data
profiling (eq. 11).  Four parameter-initialisation schemes are provided for
the Fig. 4-6 robustness experiments: kaiming_{uniform,normal} and
xavier_{uniform,normal}.
"""

from __future__ import annotations

import functools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax import lax

__all__ = ["init_cnn", "apply_cnn", "apply_with_features", "cnn_loss", "accuracy", "INIT_SCHEMES"]


def _fan_in_out(shape):
    if len(shape) == 4:  # HWIO conv kernel
        rf = shape[0] * shape[1]
        return shape[2] * rf, shape[3] * rf
    return shape[0], shape[1]


def _kaiming_uniform(key, shape, dtype=jnp.float32):
    fan_in, _ = _fan_in_out(shape)
    bound = jnp.sqrt(6.0 / fan_in)
    return jax.random.uniform(key, shape, dtype, -bound, bound)


def _kaiming_normal(key, shape, dtype=jnp.float32):
    fan_in, _ = _fan_in_out(shape)
    return jax.random.normal(key, shape, dtype) * jnp.sqrt(2.0 / fan_in)


def _xavier_uniform(key, shape, dtype=jnp.float32):
    fan_in, fan_out = _fan_in_out(shape)
    bound = jnp.sqrt(6.0 / (fan_in + fan_out))
    return jax.random.uniform(key, shape, dtype, -bound, bound)


def _xavier_normal(key, shape, dtype=jnp.float32):
    fan_in, fan_out = _fan_in_out(shape)
    return jax.random.normal(key, shape, dtype) * jnp.sqrt(2.0 / (fan_in + fan_out))


INIT_SCHEMES = {
    "kaiming_uniform": _kaiming_uniform,
    "kaiming_normal": _kaiming_normal,
    "xavier_uniform": _xavier_uniform,
    "xavier_normal": _xavier_normal,
}


def init_cnn(
    key: jax.Array,
    num_classes: int = 10,
    in_hw: Tuple[int, int] = (28, 28),
    channels: Tuple[int, int] = (16, 32),
    fc1_dim: int = 128,
    scheme: str = "kaiming_uniform",
) -> Dict:
    """Initialise the 2-conv/2-FC CNN; FC-1 width = Q = profile dimension."""
    init = INIT_SCHEMES[scheme]
    k = jax.random.split(key, 4)
    h, w = in_hw
    flat = (h // 4) * (w // 4) * channels[1]  # two 2x2 maxpools
    return {
        "conv1": {"w": init(k[0], (5, 5, 1, channels[0])), "b": jnp.zeros((channels[0],))},
        "conv2": {"w": init(k[1], (5, 5, channels[0], channels[1])), "b": jnp.zeros((channels[1],))},
        "fc1": {"w": init(k[2], (flat, fc1_dim)), "b": jnp.zeros((fc1_dim,))},
        "fc2": {"w": init(k[3], (fc1_dim, num_classes)), "b": jnp.zeros((num_classes,))},
    }


def _conv(x, p):
    y = lax.conv_general_dilated(
        x, p["w"], window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    return y + p["b"]


def _maxpool2(x):
    return lax.reduce_window(
        x, -jnp.inf, lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
    )


def apply_with_features(params: Dict, x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Forward pass returning (logits, FC-1 pre-activations).

    The FC-1 pre-activation is the Theorem-1 variable whose per-neuron mean
    over the local dataset forms the client's data profile f_c (eq. 11).
    """
    h = jax.nn.relu(_conv(x, params["conv1"]))
    h = _maxpool2(h)
    h = jax.nn.relu(_conv(h, params["conv2"]))
    h = _maxpool2(h)
    h = h.reshape(h.shape[0], -1)
    fc1_pre = h @ params["fc1"]["w"] + params["fc1"]["b"]
    h = jax.nn.relu(fc1_pre)
    logits = h @ params["fc2"]["w"] + params["fc2"]["b"]
    return logits, fc1_pre


def apply_cnn(params: Dict, x: jax.Array) -> jax.Array:
    return apply_with_features(params, x)[0]


def cnn_loss(params: Dict, x: jax.Array, y: jax.Array) -> jax.Array:
    logits = apply_cnn(params, x)
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, y[:, None].astype(jnp.int32), axis=1))


@functools.partial(jax.jit, static_argnames=("batch_size",))
def accuracy(params: Dict, x: jax.Array, y: jax.Array, batch_size: int = 2048) -> jax.Array:
    """Full-dataset accuracy via scan over fixed-size chunks (pads tail)."""
    n = x.shape[0]
    pad = (-n) % batch_size
    xp = jnp.pad(x, ((0, pad),) + ((0, 0),) * (x.ndim - 1))
    yp = jnp.pad(y, (0, pad), constant_values=-1)
    xb = xp.reshape(-1, batch_size, *x.shape[1:])
    yb = yp.reshape(-1, batch_size)

    def body(acc, xy):
        xc, yc = xy
        pred = jnp.argmax(apply_cnn(params, xc), axis=-1)
        return acc + jnp.sum((pred == yc) & (yc >= 0)), None

    total, _ = lax.scan(body, jnp.zeros((), jnp.int32), (xb, yb))
    return total / n

"""Mixture-of-Experts MLP with sort-based capacity dispatch.

Static-shape, SPMD-friendly top-k routing (Switch/MaxText style):

1. router logits -> top-k experts per token (softmax combine for mixtral,
   sigmoid scaling for llama4-style top-1 + optional shared expert);
2. routed (token, expert) pairs are *sorted by expert id* and packed into a
   fixed ``(num_experts, capacity)`` slot grid — tokens past an expert's
   capacity are dropped (capacity_factor controls slack, the standard
   trade-off — no dynamic shapes anywhere);
3. per-expert matmuls run as one stacked einsum over the expert dim, so the
   expert dimension (and/or d_ff) can shard over mesh axes — XLA inserts the
   all-to-alls for expert parallelism (inspected in §Roofline);
4. outputs scatter back with the combine weights; aux load-balance loss
   (Switch eq. 4) encourages uniform routing.

FLOPs scale with *active* parameters (E·C ≈ T·k·cf), which is what the
MODEL_FLOPS/HLO_FLOPs roofline ratio checks for the MoE archs.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L

__all__ = ["init_moe", "apply_moe"]


def init_moe(key, cfg: ModelConfig) -> Dict:
    dtype = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 5)
    e, d, f = cfg.num_experts, cfg.d_model, cfg.d_ff
    std = d**-0.5

    def ew(k, shape):
        return (jax.random.normal(k, shape) * std).astype(dtype)

    p = {
        "router": L.init_dense(ks[0], d, e, jnp.float32),  # router math in fp32
        "wi": ew(ks[1], (e, d, f)),
        "wg": ew(ks[2], (e, d, f)),
        "wo": ew(ks[3], (e, f, d)) * (f**-0.5) / std,
    }
    if cfg.shared_expert:
        p["shared"] = L.init_mlp(ks[4], cfg)
    return p


def _route(cfg: ModelConfig, logits: jax.Array) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """-> (expert_idx (T,k), combine_w (T,k), aux_loss ())."""
    t, e = logits.shape
    k = cfg.experts_per_token
    if cfg.router_type == "sigmoid":  # llama4: top-k then sigmoid gate
        gate_val, idx = jax.lax.top_k(logits, k)
        combine = jax.nn.sigmoid(gate_val)
        probs = jax.nn.softmax(logits, axis=-1)  # aux loss still uses softmax
    else:  # mixtral: softmax over the top-k logits
        gate_val, idx = jax.lax.top_k(logits, k)
        combine = jax.nn.softmax(gate_val, axis=-1)
        probs = jax.nn.softmax(logits, axis=-1)
    # Switch-style load-balance aux: E * Σ_e fraction_e * prob_e
    frac = jnp.mean(
        jax.nn.one_hot(idx, e, dtype=jnp.float32).sum(axis=1), axis=0
    ) / k
    aux = e * jnp.sum(frac * jnp.mean(probs, axis=0)) * cfg.router_aux_coef
    return idx, combine.astype(jnp.float32), aux


def _capacity(cfg: ModelConfig, t: int) -> int:
    cap = int(cfg.capacity_factor * t * cfg.experts_per_token / cfg.num_experts)
    return max(8, -(-cap // 8) * 8)  # round up to 8 for lane alignment


def apply_moe(cfg: ModelConfig, p: Dict, x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, D) -> (y, aux_loss)."""
    b, s, d = x.shape
    t = b * s
    k = cfg.experts_per_token
    e = cfg.num_experts
    cap = _capacity(cfg, t)
    xf = x.reshape(t, d)

    logits = (xf.astype(jnp.float32) @ p["router"]["w"]).astype(jnp.float32)
    idx, combine, aux = _route(cfg, logits)  # (T,k)

    # ---- pack (token, choice) pairs into (E, cap) slots by stable sort ----
    flat_expert = idx.reshape(-1)  # (T*k,)
    order = jnp.argsort(flat_expert, stable=True)  # token pairs grouped by expert
    # rank of each pair within its expert group:
    sorted_e = flat_expert[order]
    pos_in_sorted = jnp.arange(t * k)
    group_start = jnp.searchsorted(sorted_e, jnp.arange(e), side="left")
    rank = pos_in_sorted - group_start[sorted_e]
    keep = rank < cap
    slot = jnp.where(keep, sorted_e * cap + rank, e * cap)  # overflow -> trash slot
    token_of_pair = order // k

    # gather tokens into the slot grid (+1 trash row)
    slot_token = jnp.zeros((e * cap + 1,), jnp.int32).at[slot].set(
        token_of_pair.astype(jnp.int32), mode="drop"
    )
    slot_used = jnp.zeros((e * cap + 1,), bool).at[slot].set(keep, mode="drop")
    slot_token, slot_used = slot_token[:-1], slot_used[:-1]
    xe = xf[slot_token].reshape(e, cap, d) * slot_used.reshape(e, cap, 1).astype(x.dtype)

    # ---- expert computation (stacked over the expert dim) ----
    h = jnp.einsum("ecd,edf->ecf", xe, p["wg"])
    h = jax.nn.silu(h) * jnp.einsum("ecd,edf->ecf", xe, p["wi"])
    ye = jnp.einsum("ecf,efd->ecd", h, p["wo"])  # (E, cap, D)

    # ---- combine back ----
    pair_weight = combine.reshape(-1)[order]  # aligned with sorted pairs
    w_slot = jnp.zeros((e * cap + 1,), jnp.float32).at[slot].set(
        jnp.where(keep, pair_weight, 0.0), mode="drop"
    )[:-1]
    yf = jnp.zeros((t, d), jnp.float32)
    yf = yf.at[slot_token].add(
        ye.reshape(e * cap, d).astype(jnp.float32) * w_slot[:, None],
        mode="drop",
    )
    y = yf.astype(x.dtype).reshape(b, s, d)
    if cfg.shared_expert:
        y = y + L.apply_mlp(cfg, p["shared"], x)
    return y, aux

"""RWKV6 "Finch" block (arXiv:2404.05892): attention-free time mix with
data-dependent decay + squared-ReLU channel mix.

Time mix (per head, k/v/r in R^hd):
    S_t = diag(w_t) S_{t−1} + k_t v_tᵀ            state (hd_k × hd_v)
    y_t = rᵀ_t (S_{t−1} + diag(u ⊙ k_t) v_tᵀ)     u = per-head bonus
with w_t = exp(−exp(w0 + LoRA_w(x̃_t))) a *data-dependent* per-channel decay
(the Finch contribution vs RWKV5's static decay), and all of r/k/v/w/g
produced from data-dependent token-shift interpolations (ddlerp).

The pure-jnp path scans over time (decode state is O(1) per token — the
long_500k story for this arch); the Pallas ``rwkv6_scan`` kernel implements
the chunked TPU form (DESIGN.md §5).
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.launch.sharding import constrain
from repro.models import layers as L

__all__ = ["init_rwkv_tmix", "init_rwkv_cmix", "init_rwkv_state", "apply_rwkv_tmix", "apply_rwkv_cmix", "wkv6_scan_ref"]

_LORA_RANK = 32


def _num_heads(cfg: ModelConfig) -> int:
    return cfg.d_model // cfg.rwkv_head_dim


def init_rwkv_tmix(key, cfg: ModelConfig) -> Dict:
    dtype = jnp.dtype(cfg.param_dtype)
    d = cfg.d_model
    h, hd = _num_heads(cfg), cfg.rwkv_head_dim
    ks = jax.random.split(key, 12)
    mu = lambda k: jax.random.uniform(k, (d,), jnp.float32).astype(dtype)
    return {
        # ddlerp static mixes (x + (shift(x) − x) ⊙ mu_*)
        "mu_x": mu(ks[0]),
        "mu_w": mu(ks[1]),
        "mu_k": mu(ks[2]),
        "mu_v": mu(ks[3]),
        "mu_r": mu(ks[4]),
        "mu_g": mu(ks[5]),
        # decay: w_t = exp(−exp(w0 + tanh(x̃ A_w) B_w))
        "w0": (jax.random.uniform(ks[6], (d,), jnp.float32) * -1.0 - 5.0),
        "a_w": (jax.random.normal(ks[7], (d, _LORA_RANK)) * 0.01).astype(dtype),
        "b_w": (jax.random.normal(ks[8], (_LORA_RANK, d)) * 0.01).astype(dtype),
        "u": (jax.random.normal(ks[9], (h, hd)) * 0.1).astype(jnp.float32),
        "wr": L.init_dense(ks[10], d, d, dtype),
        "wk": L.init_dense(ks[11], d, d, dtype),
        "wv": L.init_dense(jax.random.fold_in(key, 101), d, d, dtype),
        "wg": L.init_dense(jax.random.fold_in(key, 102), d, d, dtype),
        "wo": L.init_dense(jax.random.fold_in(key, 103), d, d, dtype),
        "ln_scale": jnp.ones((d,), dtype),  # per-head group norm scale
    }


def init_rwkv_cmix(key, cfg: ModelConfig) -> Dict:
    dtype = jnp.dtype(cfg.param_dtype)
    d = cfg.d_model
    ks = jax.random.split(key, 3)
    return {
        "mu_k": jax.random.uniform(ks[0], (d,), jnp.float32).astype(dtype),
        "mu_r": jax.random.uniform(ks[1], (d,), jnp.float32).astype(dtype),
        "wk": L.init_dense(ks[2], d, cfg.d_ff, dtype),
        "wv": L.init_dense(jax.random.fold_in(key, 7), cfg.d_ff, d, dtype),
        "wr": L.init_dense(jax.random.fold_in(key, 8), d, d, dtype),
    }


def init_rwkv_state(cfg: ModelConfig, batch: int, per_slot: bool = False) -> Dict:
    h, hd = _num_heads(cfg), cfg.rwkv_head_dim
    return {
        "tm_x": jnp.zeros((batch, cfg.d_model), jnp.dtype(cfg.dtype)),
        "wkv": jnp.zeros((batch, h, hd, hd), jnp.float32),
        "cm_x": jnp.zeros((batch, cfg.d_model), jnp.dtype(cfg.dtype)),
        "pos": jnp.zeros((batch,) if per_slot else (), jnp.int32),
    }


def _shift(x: jax.Array, last: Optional[jax.Array]) -> jax.Array:
    """Token shift: previous token's activation (zero/state at t = 0)."""
    if last is None:
        pad = jnp.zeros_like(x[:, :1])
    else:
        pad = last[:, None].astype(x.dtype)
    return jnp.concatenate([pad, x[:, :-1]], axis=1)


def wkv6_scan_ref(
    r: jax.Array,  # (B, T, H, hd)
    k: jax.Array,
    v: jax.Array,
    w: jax.Array,  # (B, T, H, hd) decay in (0, 1)
    u: jax.Array,  # (H, hd)
    state: jax.Array,  # (B, H, hd, hd)
) -> Tuple[jax.Array, jax.Array]:
    """Sequential WKV6 recurrence (pure-jnp oracle for the Pallas kernel)."""

    def step(s, rkvw):
        r_t, k_t, v_t, w_t = rkvw  # (B, H, hd)
        kv = k_t[..., :, None] * v_t[..., None, :]  # (B, H, hd, hd)
        y = jnp.einsum("bhk,bhkv->bhv", r_t, s + u[None, :, :, None] * kv)
        s = w_t[..., :, None] * s + kv
        return s, y

    xs = tuple(jnp.moveaxis(a.astype(jnp.float32), 1, 0) for a in (r, k, v, w))
    state, ys = jax.lax.scan(step, state, xs)
    return jnp.moveaxis(ys, 0, 1), state  # (B, T, H, hd), final state


def _group_norm(x: jax.Array, scale: jax.Array, h: int) -> jax.Array:
    """Per-head LayerNorm over hd (RWKV's GroupNorm(heads))."""
    b, t, d = x.shape
    xh = x.reshape(b, t, h, d // h).astype(jnp.float32)
    mu = xh.mean(-1, keepdims=True)
    var = xh.var(-1, keepdims=True)
    xh = (xh - mu) * jax.lax.rsqrt(var + 1e-5)
    return (xh.reshape(b, t, d) * scale.astype(jnp.float32)).astype(x.dtype)


def apply_rwkv_tmix(
    cfg: ModelConfig,
    p: Dict,
    x: jax.Array,
    state: Optional[Dict] = None,
    use_kernel: bool = False,
) -> Tuple[jax.Array, Optional[Dict]]:
    b, t, d = x.shape
    h, hd = _num_heads(cfg), cfg.rwkv_head_dim
    last = state["tm_x"] if state is not None else None
    xx = _shift(x, last)
    delta = xx - x

    def lerp(mu):
        return x + delta * mu

    xw, xk, xv, xr, xg = (lerp(p[f"mu_{n}"]) for n in ("w", "k", "v", "r", "g"))
    r = L.dense(p["wr"], xr).reshape(b, t, h, hd)
    k = L.dense(p["wk"], xk).reshape(b, t, h, hd)
    v = L.dense(p["wv"], xv).reshape(b, t, h, hd)
    g = jax.nn.silu(L.dense(p["wg"], xg))
    # data-dependent decay (Finch): w = exp(−exp(w0 + tanh(xw A) B))
    dd = jnp.tanh(xw @ p["a_w"]) @ p["b_w"]
    logw = -jnp.exp(
        jnp.clip(p["w0"].astype(jnp.float32) + dd.astype(jnp.float32), -20.0, 8.0)
    )
    w = jnp.exp(logw).reshape(b, t, h, hd)
    # keep the wkv inputs on ONE consistent head sharding — without this the
    # replicated decay path forces (B,T,H,hd) fp32 regathers (§Perf rwkv)
    r, k, v, w = (
        constrain(x, "act_inner_b", "act_seq", "act_rwkv_h", None) for x in (r, k, v, w)
    )

    s0 = (
        state["wkv"]
        if state is not None
        else jnp.zeros((b, h, hd, hd), jnp.float32)
    )
    if use_kernel:
        from repro.kernels.rwkv6_scan import ops as wkv_ops

        y, s_new = wkv_ops.wkv6(r, k, v, w, p["u"], s0)
    else:
        y, s_new = wkv6_scan_ref(r, k, v, w, p["u"], s0)

    y = _group_norm(y.reshape(b, t, d).astype(x.dtype), p["ln_scale"], h)
    out = L.dense(p["wo"], y * g)
    new_state = None
    if state is not None:
        new_state = dict(state, tm_x=x[:, -1], wkv=s_new, pos=state["pos"] + t)
    return out, new_state


def apply_rwkv_cmix(
    cfg: ModelConfig, p: Dict, x: jax.Array, state: Optional[Dict] = None
) -> Tuple[jax.Array, Optional[Dict]]:
    last = state["cm_x"] if state is not None else None
    xx = _shift(x, last)
    delta = xx - x
    xk = x + delta * p["mu_k"]
    xr = x + delta * p["mu_r"]
    kk = jnp.square(jax.nn.relu(L.dense(p["wk"], xk)))
    out = jax.nn.sigmoid(L.dense(p["wr"], xr)) * L.dense(p["wv"], kk)
    new_state = dict(state, cm_x=x[:, -1]) if state is not None else None
    return out, new_state

"""In-program telemetry: the per-round diagnostics pytree (DESIGN.md §14).

:func:`round_telemetry` is called from the tail of the engine's ``round_fn``
— *only* when ``FLConfig.telemetry`` is set, so disabled configs trace the
exact pre-telemetry program.  Everything here is computed from values the
round already holds (the selection cohort, the spectral cache, the guard
counters, the staleness counters): no extra collectives, no extra PRNG
draws, no state fields — the telemetry never touches the key chain or the
carried pytree, which is what makes the on/off parity contract
(`tests/test_obs.py`) a bit-equality, not an approximation.

The :class:`Telemetry` pytree stacks across the scan like any other output
leaf and is drained to JSONL on host at chunk boundaries by
:func:`repro.obs.sink.drain_fl_outputs`.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

__all__ = ["Telemetry", "round_telemetry"]


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class Telemetry:
    """Per-round diagnostics riding the scan outputs (all scalars unless
    noted).  Optional fields are ``None`` when the corresponding feature is
    off — same convention as ``ServerState``'s optional fields, so the
    pytree (and the JSONL schema) only carries what the config can produce.
    """

    # -- selection ---------------------------------------------------------
    # stage-1 candidate count Q (C when unfunneled) and the survival
    # fraction Q/C — static per program, recorded per round so a JSONL
    # stream is self-describing across re-funnel segments
    funnel_q: jax.Array  # int32
    funnel_survival: jax.Array  # float32, Q/C in (0, 1]
    # rounds since the last aligned reprofile boundary — the age of the
    # spectral cache / candidate set serving this round's draw (0 = the
    # round right after a reprofile; monotone when reprofile_every is None)
    cache_age: jax.Array  # int32
    # DPP kernel spectrum summary from the cached eigendecomposition
    # (normalised eigenvalues; identity-placeholder caches give the trivial
    # all-ones spectrum): top eigenvalue, trace, and participation-ratio
    # effective rank (Σλ)²/Σλ² — how many directions the kernel spreads over
    spectrum_top: jax.Array  # float32
    spectrum_trace: jax.Array  # float32
    spectrum_erank: jax.Array  # float32
    # -- robustness --------------------------------------------------------
    # guard-off configs report the honest-path constants (k survivors,
    # nothing flagged/quarantined) so the schema is uniform across modes
    survivors: jax.Array  # int32, cohort updates retained by the aggregator
    flagged: jax.Array  # int32, guard-rejected updates this round
    quarantined: jax.Array  # int32, clients currently in cooldown
    identity_round: jax.Array  # int32 0/1, survivors floor tripped
    # -- staleness / scenario ---------------------------------------------
    avail_frac: Optional[jax.Array] = None  # float32, mean availability
    # (staleness_bound+1,) int32: shards contributing at lag s this round
    staleness_hist: Optional[jax.Array] = None


def round_telemetry(
    cfg,
    state,
    *,
    t: jax.Array,
    avail: Optional[jax.Array] = None,
    new_s: Optional[jax.Array] = None,
    flagged: Optional[jax.Array] = None,
    survivors: Optional[jax.Array] = None,
    quarantine: Optional[jax.Array] = None,
) -> Telemetry:
    """Build the round's :class:`Telemetry` from values already in scope.

    ``cfg``/``state`` are the engine's ``FLConfig``/``ServerState`` (taken
    duck-typed to keep this package free of ``fl`` imports); the keyword
    arguments are the round body's availability mask, post-round staleness
    counters, and guard outputs — each ``None`` when its feature is off.
    """
    k = cfg.clients_per_round
    c = cfg.num_clients
    q = cfg.candidate_count() if cfg.candidate_frac is not None else c

    lam = state.eig_state.lam.astype(jnp.float32)
    trace = jnp.sum(lam)
    sumsq = jnp.maximum(jnp.sum(lam * lam), jnp.float32(1e-30))
    if cfg.reprofile_every:
        age = (t - 1) % cfg.reprofile_every
    else:
        age = t - 1

    if survivors is None:
        surv = jnp.asarray(k, jnp.int32)
        ident = jnp.asarray(0, jnp.int32)
    else:
        surv = jnp.asarray(survivors, jnp.int32)
        ident = jnp.asarray(survivors < cfg.min_survivors, jnp.int32)
    n_flag = (
        jnp.asarray(0, jnp.int32)
        if flagged is None
        else jnp.sum(flagged.astype(jnp.int32))
    )
    n_quar = (
        jnp.asarray(0, jnp.int32)
        if quarantine is None
        else jnp.sum((quarantine > 0).astype(jnp.int32))
    )

    hist = None
    if new_s is not None:
        # shards contributing at each lag s ∈ [0, bound] — tiny static-width
        # comparison, no bincount data-dependence
        lags = jnp.arange(cfg.staleness_bound + 1, dtype=jnp.int32)
        hist = jnp.sum(
            (new_s[None, :] == lags[:, None]).astype(jnp.int32), axis=1
        )

    return Telemetry(
        funnel_q=jnp.asarray(q, jnp.int32),
        funnel_survival=jnp.asarray(q / c, jnp.float32),
        cache_age=jnp.asarray(age, jnp.int32),
        spectrum_top=jnp.max(lam),
        spectrum_trace=trace,
        spectrum_erank=(trace * trace) / sumsq,
        survivors=surv,
        flagged=n_flag,
        quarantined=n_quar,
        identity_round=ident,
        avail_frac=(
            None if avail is None else jnp.mean(avail.astype(jnp.float32))
        ),
        staleness_hist=hist,
    )

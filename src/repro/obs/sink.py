"""Host-side telemetry sink: JSONL events + the run manifest (DESIGN.md §14).

One event per line, strict JSON (no NaN/Inf — they sanitise to ``null`` so
any consumer round-trips).  Every event carries::

    {"event": <type>, "t": <seconds since sink creation, perf_counter>,
     "wall": <unix seconds>, ...payload}

The sink is *pulled* from, never pushed into a compiled program: the
engines drain it at scan-chunk / admit / harvest boundaries (the
chunk-boundary drain rule — see DESIGN.md §14 for why there is no
``io_callback`` inside a scan body).
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import math
import os
import subprocess
import time
from typing import Any, Dict, List, Optional

import jax
import numpy as np

__all__ = [
    "TelemetrySink",
    "config_hash",
    "drain_fl_outputs",
    "load_events",
    "run_manifest",
]


def _jsonable(v: Any) -> Any:
    """Coerce numpy/jax scalars and arrays into strict-JSON values.

    Plain scalars short-circuit first: the per-round drain funnels thousands
    of already-converted values through here (see :func:`drain_fl_outputs`),
    so the common case must be a couple of isinstance checks, not an
    ``np.asarray`` round-trip."""
    if v is None or isinstance(v, (bool, int, str)):
        return v
    if isinstance(v, float):  # includes np.float64 (a float subclass)
        return float(v) if math.isfinite(v) else None
        # NaN/Inf are not strict JSON; eval-off rounds emit null
    if isinstance(v, (np.generic, jax.Array, np.ndarray)):
        v = np.asarray(v)
        return _jsonable(v.item() if v.ndim == 0 else v.tolist())
    if isinstance(v, (list, tuple)):
        return [_jsonable(x) for x in v]
    if isinstance(v, dict):
        return {str(k): _jsonable(x) for k, x in v.items()}
    return v


def config_hash(config: Any) -> str:
    """Stable short hash of a config (dataclass or plain dict): canonical
    JSON (sorted keys) → sha256.  Same config ⇒ same hash across processes —
    the manifest-determinism contract tests pin this."""
    if dataclasses.is_dataclass(config) and not isinstance(config, type):
        config = dataclasses.asdict(config)
    blob = json.dumps(_jsonable(config), sort_keys=True, separators=(",", ":"))
    return hashlib.sha256(blob.encode()).hexdigest()[:16]


def _git_sha() -> Optional[str]:
    try:
        out = subprocess.run(
            ["git", "rev-parse", "HEAD"],
            capture_output=True, text=True, timeout=5,
            cwd=os.path.dirname(os.path.abspath(__file__)),
        )
        return out.stdout.strip() or None if out.returncode == 0 else None
    except Exception:  # pragma: no cover - no git in deployment images
        return None


def run_manifest(
    config: Any = None,
    mesh: Optional[Any] = None,
    extra: Optional[Dict[str, Any]] = None,
) -> Dict[str, Any]:
    """The run's identity card: config + hash, jax/device/mesh info, git SHA.

    Written once per run as the sink's first event, so every JSONL file is
    self-describing — a report can always answer "what produced this?".
    """
    devices = jax.devices()
    man: Dict[str, Any] = {
        "jax_version": jax.__version__,
        "backend": jax.default_backend(),
        "device_count": len(devices),
        "device_kind": devices[0].device_kind if devices else None,
        "host_cores": os.cpu_count(),
        "git_sha": _git_sha(),
    }
    if config is not None:
        if dataclasses.is_dataclass(config) and not isinstance(config, type):
            config = dataclasses.asdict(config)
        man["config"] = _jsonable(config)
        man["config_hash"] = config_hash(config)
    if mesh is not None:
        man["mesh"] = {
            "axes": {str(k): int(v) for k, v in mesh.shape.items()},
            "devices": int(np.prod(list(mesh.shape.values()))),
        }
    if extra:
        man.update(_jsonable(extra))
    return man


class TelemetrySink:
    """Append-only JSONL event emitter.

    Lines are buffered through the underlying file object and flushed on
    :meth:`flush`/:meth:`close` (and per-event when ``line_buffered``), so a
    crashed run keeps everything up to its last drain boundary.  Usable as a
    context manager; ``event_counts`` keeps per-type totals for cheap
    end-of-run summaries without re-reading the file.
    """

    def __init__(self, path: str, line_buffered: bool = False):
        self.path = str(path)
        parent = os.path.dirname(os.path.abspath(self.path))
        os.makedirs(parent, exist_ok=True)
        self._f = open(self.path, "a", encoding="utf-8")
        self._t0 = time.perf_counter()
        self._line_buffered = line_buffered
        self.event_counts: Dict[str, int] = {}

    def emit(self, event: str, **payload: Any) -> None:
        rec = {
            "event": event,
            "t": round(time.perf_counter() - self._t0, 6),
            "wall": round(time.time(), 3),
        }
        for k, v in payload.items():
            rec[k] = _jsonable(v)
        self._f.write(json.dumps(rec, separators=(",", ":")) + "\n")
        self.event_counts[event] = self.event_counts.get(event, 0) + 1
        if self._line_buffered:
            self._f.flush()

    def emit_many(self, event: str, records: List[Dict[str, Any]]) -> None:
        """Bulk-emit pre-sanitised records (the scan-chunk drain path).

        Values must already be strict-JSON (run them through the module's
        converter first); the whole batch shares one timestamp pair — they
        all land at the same drain boundary, so per-record clock reads would
        only record the emit loop's own speed."""
        if not records:
            return
        t = round(time.perf_counter() - self._t0, 6)
        wall = round(time.time(), 3)
        lines = []
        for payload in records:
            rec = {"event": event, "t": t, "wall": wall}
            rec.update(payload)
            lines.append(json.dumps(rec, separators=(",", ":")))
        self._f.write("\n".join(lines) + "\n")
        self.event_counts[event] = (
            self.event_counts.get(event, 0) + len(records)
        )
        if self._line_buffered:
            self._f.flush()

    def write_manifest(
        self,
        config: Any = None,
        mesh: Optional[Any] = None,
        extra: Optional[Dict[str, Any]] = None,
    ) -> Dict[str, Any]:
        man = run_manifest(config=config, mesh=mesh, extra=extra)
        self.emit("manifest", **man)
        self.flush()
        return man

    def flush(self) -> None:
        self._f.flush()

    def close(self) -> None:
        if not self._f.closed:
            self._f.flush()
            self._f.close()

    def __enter__(self) -> "TelemetrySink":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def _column(v: Any) -> List[Any]:
    """Whole stacked column -> strict-JSON python, skipping the per-element
    sanitiser when the dtype can't hide a NaN/Inf (int/bool) or the column
    is verifiably all-finite — one vectorised check instead of thousands of
    scalar conversions."""
    a = np.asarray(v)
    if a.dtype.kind in "iub":
        return a.tolist()
    if a.dtype.kind == "f" and bool(np.isfinite(a).all()):
        return a.tolist()
    return _jsonable(a.tolist())


def drain_fl_outputs(sink: TelemetrySink, outputs: Dict[str, Any]) -> int:
    """Emit one ``fl_round`` event per round of a scanned segment's stacked
    outputs dict (the chunk-boundary drain).  The optional ``telemetry``
    subtree (a :class:`~repro.obs.telemetry.Telemetry`) flattens into the
    same event under its field names; the per-client ``avail`` mask is
    dropped (C-wide — its mean already rides ``avail_frac``).  Returns the
    number of rounds drained."""
    # one vectorised device->host->python conversion per FIELD (not per
    # round-and-field): the drain rides inside the engines' timed region, so
    # its cost per round must stay a dict build + json.dumps
    host: Dict[str, Any] = {
        k: _column(v)
        for k, v in outputs.items()
        if k not in ("telemetry", "avail")
    }
    tel = outputs.get("telemetry")
    if tel is not None:
        for f in dataclasses.fields(tel):
            v = getattr(tel, f.name)
            if v is not None:
                host[f.name] = _column(v)
    if not host:
        return 0
    n = len(next(iter(host.values())))
    sink.emit_many(
        "fl_round", [{k: v[i] for k, v in host.items()} for i in range(n)]
    )
    sink.flush()
    return n


def load_events(path: str) -> List[Dict[str, Any]]:
    """Parse a telemetry JSONL file back into event dicts (strict JSON)."""
    events = []
    with open(path, encoding="utf-8") as f:
        for line in f:
            line = line.strip()
            if line:
                events.append(json.loads(line))
    return events

"""repro.obs — structured telemetry for both engines (DESIGN.md §14).

Three layers, strictly separated so nothing here ever changes a compiled
program:

* :mod:`repro.obs.telemetry` — the jit side: a :class:`Telemetry` pytree of
  per-round diagnostics that rides the federation scan outputs when (and
  only when) ``FLConfig.telemetry`` is set.  The flag is static, so
  ``telemetry=False`` configs lower bit-identical XLA programs — the same
  convention faults / funnel / staleness follow.
* :mod:`repro.obs.sink` — the host side: a JSONL event emitter
  (:class:`TelemetrySink`) plus the run manifest (config dict + stable
  hash, jax/device/mesh info, git SHA).  Events are drained at scan-chunk /
  admit / harvest boundaries only — never from inside a scan body.
* :mod:`repro.obs.tracing` — thin ``jax.profiler`` wrappers
  (:func:`trace`, :func:`annotate`) with no-op fallbacks, so profiler
  support costs nothing when no trace is active.

This package depends only on jax/numpy/stdlib — ``fl/`` and ``serve/``
import it, never the reverse.
"""

from repro.obs.sink import (
    TelemetrySink,
    config_hash,
    drain_fl_outputs,
    load_events,
    run_manifest,
)
from repro.obs.telemetry import Telemetry, round_telemetry
from repro.obs.tracing import annotate, trace

__all__ = [
    "Telemetry",
    "TelemetrySink",
    "annotate",
    "config_hash",
    "drain_fl_outputs",
    "load_events",
    "round_telemetry",
    "trace",
]

"""Profiler hooks: thin wrappers over ``jax.profiler`` (DESIGN.md §14).

Two context managers:

* :func:`trace` — one per run, wrapping the whole driver in
  ``jax.profiler.trace(dir)`` (TensorBoard-loadable); a ``None`` dir is a
  no-op so launchers can pass ``--profile-dir`` through unconditionally.
* :func:`annotate` — named host spans (``jax.profiler.TraceAnnotation``)
  around the hot boundaries: scan chunks, selection reprofiles, serve
  decode chunks and admissions.  Annotations are cheap enough to apply
  unconditionally — they only record when a trace is active — and fall
  back to a no-op on jax builds without ``TraceAnnotation``.
"""

from __future__ import annotations

import contextlib
from typing import Iterator, Optional

import jax

__all__ = ["annotate", "trace"]

_TraceAnnotation = getattr(jax.profiler, "TraceAnnotation", None)


@contextlib.contextmanager
def trace(profile_dir: Optional[str]) -> Iterator[None]:
    """Profile the enclosed block into ``profile_dir`` (no-op when None)."""
    if not profile_dir:
        yield
        return
    with jax.profiler.trace(str(profile_dir)):
        yield


def annotate(name: str):
    """A named profiler span (no-op context on jax builds without one)."""
    if _TraceAnnotation is None:  # pragma: no cover - jax-version dependent
        return contextlib.nullcontext()
    return _TraceAnnotation(name)

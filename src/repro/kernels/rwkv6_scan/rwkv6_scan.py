"""RWKV6 WKV recurrence kernel — the TPU answer to the CUDA wkv6 kernel.

The CUDA original keeps the per-head (hd×hd) state in registers/shared memory
with warp-level parallelism over the value dim; the TPU adaptation keeps the
state in a VMEM fp32 scratch that *persists across the sequential time-chunk
grid dimension*, processes ``block_t`` tokens per grid step entirely out of
VMEM, and expresses the per-token update as rank-1 outer products over the
(hd_k × hd_v) state — vector-unit work with hd-wide lanes (hd = 64 → full
native lanes; no warp shuffles exist or are needed).

Grid: (B, H, T/block_t) — time is innermost/sequential per (batch, head).
Recurrence (per head):

    y_t = r_tᵀ (S + diag(u ⊙ k_t) v_tᵀ)
    S  ← diag(w_t) S + k_t v_tᵀ

Inputs r/k/v/w are (B, T, H, hd); the initial state (B, H, hd, hd) streams in
once at chunk 0 and the final state streams out at the last chunk (decode
hand-off).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["wkv6_kernel"]


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, y_ref, sout_ref, s_scr, *, bt, n_t):
    ti = pl.program_id(2)

    @pl.when(ti == 0)
    def _load_state():
        s_scr[...] = s0_ref[0, 0].astype(jnp.float32)

    u = u_ref[0].astype(jnp.float32)  # (hd,)

    def step(t, s):
        r = r_ref[0, t, 0, :].astype(jnp.float32)  # (hd,)
        k = k_ref[0, t, 0, :].astype(jnp.float32)
        v = v_ref[0, t, 0, :].astype(jnp.float32)
        w = w_ref[0, t, 0, :].astype(jnp.float32)
        kv = k[:, None] * v[None, :]  # (hd_k, hd_v) rank-1
        y = jnp.sum((s + u[:, None] * kv) * r[:, None], axis=0)  # (hd_v,)
        y_ref[0, t, 0, :] = y.astype(y_ref.dtype)
        return w[:, None] * s + kv

    s = lax.fori_loop(0, bt, step, s_scr[...])
    s_scr[...] = s

    @pl.when(ti == n_t - 1)
    def _store_state():
        sout_ref[0, 0] = s_scr[...].astype(sout_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_t", "interpret"))
def wkv6_kernel(
    r: jax.Array,  # (B, T, H, hd)
    k: jax.Array,
    v: jax.Array,
    w: jax.Array,  # decay in (0, 1)
    u: jax.Array,  # (H, hd)
    s0: jax.Array,  # (B, H, hd, hd) fp32
    block_t: int = 128,
    interpret: bool = False,
):
    """-> (y (B, T, H, hd), final state (B, H, hd, hd))."""
    b, t, h, hd = r.shape
    bt = min(block_t, t)
    tp = -(-t // bt) * bt
    if tp != t:
        pad = ((0, 0), (0, tp - t), (0, 0), (0, 0))
        # pad with w=1, k=0 so padded steps leave the state untouched
        r, k, v = (jnp.pad(x, pad) for x in (r, k, v))
        w = jnp.pad(w, pad, constant_values=1.0)
    n_t = tp // bt
    grid = (b, h, n_t)

    seq_spec = pl.BlockSpec((1, bt, 1, hd), lambda bi, hi, ti: (bi, ti, hi, 0))
    state_spec = pl.BlockSpec((1, 1, hd, hd), lambda bi, hi, ti: (bi, hi, 0, 0))
    y, s_out = pl.pallas_call(
        functools.partial(_kernel, bt=bt, n_t=n_t),
        grid=grid,
        in_specs=[
            seq_spec,
            seq_spec,
            seq_spec,
            seq_spec,
            pl.BlockSpec((1, hd), lambda bi, hi, ti: (hi, 0)),
            state_spec,
        ],
        out_specs=[seq_spec, state_spec],
        out_shape=[
            jax.ShapeDtypeStruct((b, tp, h, hd), r.dtype),
            jax.ShapeDtypeStruct((b, h, hd, hd), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((hd, hd), jnp.float32)],
        interpret=interpret,
    )(r, k, v, w, u, s0)
    return y[:, :t], s_out

"""Jit'd public wrapper for the rwkv6_scan Pallas kernel.

``repro.models.rwkv6.apply_rwkv_tmix(use_kernel=True)`` routes through here.
"""

from __future__ import annotations

import jax

from repro.kernels.rwkv6_scan.rwkv6_scan import wkv6_kernel

__all__ = ["wkv6"]


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def wkv6(r, k, v, w, u, s0, block_t: int = 128):
    if r.ndim != 4:
        raise ValueError("r/k/v/w must be (B, T, H, head_dim)")
    if s0.shape != (r.shape[0], r.shape[2], r.shape[3], r.shape[3]):
        raise ValueError(f"bad state shape {s0.shape}")
    return wkv6_kernel(r, k, v, w, u, s0, block_t=block_t, interpret=_interpret())

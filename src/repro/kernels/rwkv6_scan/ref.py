"""Pure-jnp oracle for the rwkv6_scan kernel (the model's sequential scan)."""

from repro.models.rwkv6 import wkv6_scan_ref

__all__ = ["wkv6_scan_ref"]

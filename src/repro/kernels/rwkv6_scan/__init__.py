"""Pallas TPU kernel: RWKV6 (Finch) WKV recurrence with data-dependent decay."""

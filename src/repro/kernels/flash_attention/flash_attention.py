"""Blocked causal GQA flash attention for TPU.

Online-softmax over KV tiles (Rabe & Staats / FlashAttention), adapted to the
TPU memory hierarchy: q/k/v tiles are explicit VMEM blocks, the two matmuls
per tile ((bq×hd)·(hd×bk) and (bq×bk)·(bk×hd)) land on the MXU, and the
softmax running stats (m, l) plus the (bq×hd) accumulator persist in VMEM
scratch across the sequential innermost KV grid dimension.

Grid: (B, H, S/bq, S/bk) — the KV dim is innermost/sequential.  GQA is
handled in the BlockSpec index maps: query head ``h`` reads KV head
``h // (H / Hk)`` — no repeated-KV materialisation in HBM.

Causality + optional sliding window are applied as in-tile masks; KV tiles
entirely above the diagonal (or entirely outside the window) write nothing
(`pl.when` guards), which on TPU skips their DMA+compute.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["flash_attention_kernel"]

NEG_INF = -1.0e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *, scale, window, bq, bk, n_kv):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    q_start = qi * bq
    k_start = ki * bk

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # tile is relevant iff some kv pos <= some q pos (causal) and, with a
    # window, some kv pos is inside the window of some q pos.
    q_end = q_start + bq - 1
    relevant = k_start <= q_end
    if window is not None:
        relevant = relevant & ((k_start + bk) > (q_start - window + 1))

    @pl.when(relevant)
    def _body():
        q = q_ref[0, :, 0, :].astype(jnp.float32) * scale  # (bq, hd)
        k = k_ref[0, :, 0, :].astype(jnp.float32)  # (bk, hd)
        v = v_ref[0, :, 0, :].astype(jnp.float32)  # (bk, hd)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )  # (bq, bk)
        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = k_pos <= q_pos
        if window is not None:
            mask &= k_pos > q_pos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]  # (bq, 1)
        m_cur = jnp.maximum(m_prev[:, 0], jnp.max(s, axis=1))[:, None]
        p = jnp.exp(s - m_cur)  # (bq, bk)
        alpha = jnp.exp(m_prev - m_cur)  # (bq, 1)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_scr[...] = m_cur

    @pl.when(ki == n_kv - 1)
    def _finalize():
        l = l_scr[...]
        o = acc_scr[...] / jnp.where(l == 0.0, 1.0, l)
        o_ref[0, :, 0, :] = o.astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_k", "interpret"),
)
def flash_attention_kernel(
    q: jax.Array,  # (B, S, H, hd)
    k: jax.Array,  # (B, S, Hk, hd)
    v: jax.Array,
    causal: bool = True,
    window: int | None = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    assert causal, "only the causal decoder path is implemented"
    b, s, h, hd = q.shape
    hk = k.shape[2]
    g = h // hk
    bq = min(block_q, s)
    bk = min(block_k, s)
    sp = -(-s // max(bq, bk)) * max(bq, bk)
    if sp != s:
        pad = sp - s
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    n_q, n_kv = sp // bq, sp // bk
    grid = (b, h, n_q, n_kv)

    kernel = functools.partial(
        _kernel,
        scale=hd**-0.5,
        window=window,
        bq=bq,
        bk=bk,
        n_kv=n_kv,
    )
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, 1, hd), lambda bi, hi, qi, ki: (bi, qi, hi, 0)),
            pl.BlockSpec((1, bk, 1, hd), lambda bi, hi, qi, ki, g=g: (bi, ki, hi // g, 0)),
            pl.BlockSpec((1, bk, 1, hd), lambda bi, hi, qi, ki, g=g: (bi, ki, hi // g, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, 1, hd), lambda bi, hi, qi, ki: (bi, qi, hi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, sp, h, hd), q.dtype),
        scratch_shapes=[_vmem((bq, 1)), _vmem((bq, 1)), _vmem((bq, hd))],
        interpret=interpret,
    )(q, k, v)
    return out[:, :s]


def _vmem(shape):
    from jax.experimental.pallas import tpu as pltpu

    return pltpu.VMEM(shape, jnp.float32)

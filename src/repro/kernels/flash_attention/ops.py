"""Jit'd public wrappers for the flash_attention Pallas kernels."""

from __future__ import annotations

import jax

from repro.kernels.flash_attention.decode import flash_decode_kernel
from repro.kernels.flash_attention.flash_attention import flash_attention_kernel

__all__ = ["flash_attention", "flash_decode"]


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    causal: bool = True,
    window: int | None = None,
    block_q: int = 128,
    block_k: int = 128,
) -> jax.Array:
    if q.ndim != 4 or k.ndim != 4 or v.ndim != 4:
        raise ValueError("q/k/v must be (B, S, H|Hk, head_dim)")
    if q.shape[2] % k.shape[2]:
        raise ValueError(f"q heads {q.shape[2]} not a multiple of kv heads {k.shape[2]}")
    return flash_attention_kernel(
        q, k, v, causal=causal, window=window,
        block_q=block_q, block_k=block_k, interpret=_interpret(),
    )


def flash_decode(
    q: jax.Array,  # (B, 1, H, hd)
    k: jax.Array,  # (B, S, Hk, hd) cached keys
    v: jax.Array,
    lengths: jax.Array,  # (B,) int32 valid prefix per slot
    block_k: int = 128,
) -> jax.Array:
    if q.ndim != 4 or k.ndim != 4 or v.ndim != 4:
        raise ValueError("q/k/v must be (B, 1|S, H|Hk, head_dim)")
    if q.shape[1] != 1:
        raise ValueError(f"flash_decode takes one query per slot, got S={q.shape[1]}")
    if q.shape[2] % k.shape[2]:
        raise ValueError(f"q heads {q.shape[2]} not a multiple of kv heads {k.shape[2]}")
    if lengths.shape != (q.shape[0],):
        raise ValueError(f"lengths must be (B,)=({q.shape[0]},), got {lengths.shape}")
    return flash_decode_kernel(q, k, v, lengths, block_k=block_k, interpret=_interpret())

"""Jit'd public wrapper for the flash_attention Pallas kernel."""

from __future__ import annotations

import jax

from repro.kernels.flash_attention.flash_attention import flash_attention_kernel

__all__ = ["flash_attention"]


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    causal: bool = True,
    window: int | None = None,
    block_q: int = 128,
    block_k: int = 128,
) -> jax.Array:
    if q.ndim != 4 or k.ndim != 4 or v.ndim != 4:
        raise ValueError("q/k/v must be (B, S, H|Hk, head_dim)")
    if q.shape[2] % k.shape[2]:
        raise ValueError(f"q heads {q.shape[2]} not a multiple of kv heads {k.shape[2]}")
    return flash_attention_kernel(
        q, k, v, causal=causal, window=window,
        block_q=block_q, block_k=block_k, interpret=_interpret(),
    )

"""Flash-decode: single-query attention against cached KV for TPU.

The serving engine's decode step attends one new query per slot to that
slot's valid cache prefix.  This kernel is the decode-shaped sibling of
``flash_attention._kernel``: the same online-softmax over KV tiles, the same
VMEM scratch discipline (running (m, l) stats + accumulator persist across
the sequential innermost KV grid dimension), the same GQA handling via
BlockSpec index maps.  Two decode-specific twists:

* the query tile packs the **G query heads of one KV head** as its rows —
  a (G, hd) × (hd, bk) MXU matmul per tile instead of G separate
  vector-matrix products, and k/v tiles are fetched once per KV head;
* causality degenerates to a **per-slot valid length**: slot ``b`` may only
  attend cache entries ``< lengths[b]`` (its prefill + decoded prefix).
  The length rides in as a (B, 1) int32 block and is masked in-tile; KV
  tiles entirely past the length skip their compute via ``pl.when``.

Grid: (B, Hk, S/bk) with the KV dim innermost/sequential.  Ragged lengths
(heterogeneous slots) cost nothing extra: masking is per-tile arithmetic.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["flash_decode_kernel"]

NEG_INF = -1.0e30


def _decode_kernel(q_ref, k_ref, v_ref, len_ref, o_ref, m_scr, l_scr, acc_scr,
                   *, scale, bk, n_kv):
    ki = pl.program_id(2)
    k_start = ki * bk

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    length = len_ref[0, 0]  # this slot's valid cache prefix

    @pl.when(k_start < length)
    def _body():
        q = q_ref[0, 0, :, :].astype(jnp.float32) * scale  # (G, hd)
        k = k_ref[0, :, 0, :].astype(jnp.float32)  # (bk, hd)
        v = v_ref[0, :, 0, :].astype(jnp.float32)  # (bk, hd)
        g = q.shape[0]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )  # (G, bk)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (g, bk), 1)
        s = jnp.where(k_pos < length, s, NEG_INF)

        m_prev = m_scr[...]  # (G, 1)
        m_cur = jnp.maximum(m_prev[:, 0], jnp.max(s, axis=1))[:, None]
        p = jnp.exp(s - m_cur)  # (G, bk)
        alpha = jnp.exp(m_prev - m_cur)  # (G, 1)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_scr[...] = m_cur

    @pl.when(ki == n_kv - 1)
    def _finalize():
        l = l_scr[...]
        o = acc_scr[...] / jnp.where(l == 0.0, 1.0, l)  # empty slot -> zeros
        o_ref[0, 0, :, :] = o.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def flash_decode_kernel(
    q: jax.Array,  # (B, 1, H, hd) — one query per slot
    k: jax.Array,  # (B, S, Hk, hd) — cached keys
    v: jax.Array,  # (B, S, Hk, hd)
    lengths: jax.Array,  # (B,) int32 valid cache prefix per slot
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    b, sq, h, hd = q.shape
    assert sq == 1, "flash-decode is the single-query path"
    s = k.shape[1]
    hk = k.shape[2]
    g = h // hk
    bk = min(block_k, s)
    sp = -(-s // bk) * bk
    if sp != s:
        pad = sp - s
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    n_kv = sp // bk
    grid = (b, hk, n_kv)

    qg = q[:, 0].reshape(b, hk, g, hd)  # query heads grouped under KV head
    len2d = lengths.astype(jnp.int32)[:, None]  # (B, 1)

    kernel = functools.partial(_decode_kernel, scale=hd**-0.5, bk=bk, n_kv=n_kv)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, g, hd), lambda bi, hi, ki: (bi, hi, 0, 0)),
            pl.BlockSpec((1, bk, 1, hd), lambda bi, hi, ki: (bi, ki, hi, 0)),
            pl.BlockSpec((1, bk, 1, hd), lambda bi, hi, ki: (bi, ki, hi, 0)),
            pl.BlockSpec((1, 1), lambda bi, hi, ki: (bi, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, hd), lambda bi, hi, ki: (bi, hi, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hk, g, hd), q.dtype),
        scratch_shapes=[_vmem((g, 1)), _vmem((g, 1)), _vmem((g, hd))],
        interpret=interpret,
    )(qg, k, v, len2d)
    return out.reshape(b, 1, h, hd)


def _vmem(shape):
    from jax.experimental.pallas import tpu as pltpu

    return pltpu.VMEM(shape, jnp.float32)

"""Pure-jnp oracle for the flash_attention kernel: exact causal GQA
softmax attention with optional sliding window."""

import jax
import jax.numpy as jnp

__all__ = ["attention_ref"]


def attention_ref(
    q: jax.Array,  # (B, S, H, hd)
    k: jax.Array,  # (B, S, Hk, hd)
    v: jax.Array,
    window: int | None = None,
) -> jax.Array:
    b, s, h, hd = q.shape
    hk = k.shape[2]
    g = h // hk
    qg = q.reshape(b, s, hk, g, hd).astype(jnp.float32) * hd**-0.5
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg, k.astype(jnp.float32))
    pos = jnp.arange(s)
    mask = pos[None, :] <= pos[:, None]
    if window is not None:
        mask &= pos[None, :] > pos[:, None] - window
    scores = jnp.where(mask[None, None, None], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", p, v.astype(jnp.float32))
    return out.reshape(b, s, h, hd).astype(q.dtype)

"""Pure-jnp oracles for the flash_attention kernels: exact causal GQA
softmax attention with optional sliding window, and single-query decode
attention against a cached-KV prefix of per-slot valid length."""

import jax
import jax.numpy as jnp

__all__ = ["attention_ref", "decode_attention_ref"]


def attention_ref(
    q: jax.Array,  # (B, S, H, hd)
    k: jax.Array,  # (B, S, Hk, hd)
    v: jax.Array,
    window: int | None = None,
) -> jax.Array:
    b, s, h, hd = q.shape
    hk = k.shape[2]
    g = h // hk
    qg = q.reshape(b, s, hk, g, hd).astype(jnp.float32) * hd**-0.5
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg, k.astype(jnp.float32))
    pos = jnp.arange(s)
    mask = pos[None, :] <= pos[:, None]
    if window is not None:
        mask &= pos[None, :] > pos[:, None] - window
    scores = jnp.where(mask[None, None, None], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", p, v.astype(jnp.float32))
    return out.reshape(b, s, h, hd).astype(q.dtype)


def decode_attention_ref(
    q: jax.Array,  # (B, 1, H, hd)
    k: jax.Array,  # (B, S, Hk, hd) cached keys
    v: jax.Array,
    lengths: jax.Array,  # (B,) int32 valid cache prefix per slot
) -> jax.Array:
    """Each slot's single query attends exactly its ``lengths[b]`` cached
    entries; a zero-length slot returns zeros (matching the kernel's
    empty-accumulator finalize)."""
    b, _, h, hd = q.shape
    s, hk = k.shape[1], k.shape[2]
    g = h // hk
    qg = q[:, 0].reshape(b, hk, g, hd).astype(jnp.float32) * hd**-0.5
    scores = jnp.einsum("bkgd,bskd->bkgs", qg, k.astype(jnp.float32))
    mask = jnp.arange(s)[None, :] < lengths[:, None]  # (B, S)
    scores = jnp.where(mask[:, None, None, :], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    p = jnp.where(mask[:, None, None, :], p, 0.0)  # empty slot -> zeros
    out = jnp.einsum("bkgs,bskd->bkgd", p, v.astype(jnp.float32))
    return out.reshape(b, 1, h, hd).astype(q.dtype)

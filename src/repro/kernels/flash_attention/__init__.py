"""Pallas TPU kernel: blocked causal GQA flash attention (+ sliding window)."""

"""Pure-jnp oracles for the Gram / fused profile→kernel Pallas kernels."""

import jax
import jax.numpy as jnp

__all__ = ["gram_ref", "kernel_from_profiles_ref"]


def gram_ref(x: jax.Array) -> jax.Array:
    """Naive ``XᵀX`` in fp32 — the exact reference."""
    x = x.astype(jnp.float32)
    return x.T @ x


def kernel_from_profiles_ref(f: jax.Array) -> jax.Array:
    """The eq.-(14) chain as plain XLA ops (mirrors ``repro.core.similarity``
    with ``use_kernel=False``): expansion distances → clamp → zero diagonal →
    sqrt → min-max normalise → ``L = SᵀS``."""
    f = f.astype(jnp.float32)
    sq = jnp.sum(f * f, axis=-1)
    d2 = sq[:, None] + sq[None, :] - 2.0 * (f @ f.T)
    d2 = jnp.maximum(d2, 0.0) * (1.0 - jnp.eye(f.shape[0], dtype=jnp.float32))
    s0 = jnp.sqrt(d2)
    lo = jnp.min(s0)
    rng = jnp.maximum(jnp.max(s0) - lo, 1e-30)
    s = 1.0 - (s0 - lo) / rng
    return s.T @ s

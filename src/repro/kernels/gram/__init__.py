"""Tiled Gram (L = XᵀX) Pallas kernel + the fused profiles→DPP-kernel path."""

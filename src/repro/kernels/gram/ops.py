"""Jit'd public wrappers for the Gram kernel and the fused eq.-(14) pipeline.

On CPU (this container) the kernel bodies execute under ``interpret=True``;
on TPU they compile to Mosaic.  ``repro.core.similarity`` routes through
:func:`kernel_from_profiles` when ``use_kernel=True``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.gram.gram import gram_kernel, normalized_gram_kernel
from repro.kernels.pairwise_l2.pairwise_l2 import pairwise_dists_stats_kernel

__all__ = ["gram", "kernel_from_profiles", "candidate_kernel_from_profiles"]


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def gram(x: jax.Array, block_m: int = 128, block_n: int = 128,
         block_k: int = 128) -> jax.Array:
    """X (M, N) -> XᵀX (N, N), fp32 accumulation (bf16 inputs welcome)."""
    if x.ndim != 2:
        raise ValueError(f"gram expects a 2-D matrix, got {x.shape}")
    return gram_kernel(
        x, block_m=block_m, block_n=block_n, block_k=block_k,
        interpret=_interpret(),
    )


def kernel_from_profiles(
    f: jax.Array,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 512,
    block_gram: int = 128,
) -> jax.Array:
    """Profiles (C, Q) -> PSD DPP kernel (C, C) in **two kernel launches**.

    Launch 1 (``pairwise_dists_stats_kernel``): tiled ‖·‖² expansion with the
    sqrt/diag-pin epilogue and per-tile min/max stats.  Launch 2
    (``normalized_gram_kernel``): the min-max normalise epilogue fused into
    the Gram contraction prologue — ``S`` never hits HBM.  Between them only
    a (grid_m × grid_n) scalar reduction runs as plain XLA.  bf16 profiles
    keep the MXU inputs bf16 with fp32 accumulation; the fp32 path matches
    the jnp oracle to ~1e-5.
    """
    if f.ndim != 2:
        raise ValueError(f"profiles must be (C, Q), got {f.shape}")
    interpret = _interpret()
    s0, lo, hi = pairwise_dists_stats_kernel(
        f, block_m=block_m, block_n=block_n, block_k=block_k,
        interpret=interpret,
    )
    rng = jnp.maximum(hi - lo, 1e-30)
    compute_dtype = jnp.bfloat16 if f.dtype == jnp.bfloat16 else jnp.float32
    return normalized_gram_kernel(
        s0, lo, rng, f.shape[0],
        block_m=block_gram, block_n=block_gram, block_k=block_gram,
        compute_dtype=compute_dtype, interpret=interpret,
    )


def candidate_kernel_from_profiles(
    fq: jax.Array,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 512,
    block_gram: int = 128,
) -> jax.Array:
    """Funnel candidate block (Q, F) -> PSD DPP kernel (Q, Q) — DESIGN.md §10.

    The ragged-Q path of the fused two-launch pipeline: the candidate count Q
    is whatever ``FLConfig.candidate_frac`` yields and is rarely a tile
    multiple, so both launches run with their pad-to-tile masking doing real
    work — ``pairwise_dists_stats_kernel`` excludes the pad region from the
    min/max stats (``(rows < c) & (cols < c)``) and ``normalized_gram_kernel``
    zeroes pad rows (``rows < c``) before the contraction, exactly as for a
    ragged C.  Tile sizes deliberately stay the :func:`kernel_from_profiles`
    defaults: identical tiling means identical fp32 accumulation order, so
    the Q=C funnel is **bit-identical** to the unfunneled pipeline (the
    parity contract tests assert) — a worst case of one mostly-pad tile row
    is cheaper than losing that guarantee.
    """
    if fq.ndim != 2:
        raise ValueError(f"candidate profiles must be (Q, F), got {fq.shape}")
    return kernel_from_profiles(
        fq, block_m=block_m, block_n=block_n, block_k=block_k,
        block_gram=block_gram,
    )

"""Tiled Gram kernel ``L = XᵀX`` + the fused eq.-(14) normalise-and-Gram.

Two Pallas entry points, both accumulating (bm × bn) fp32 output tiles in
VMEM over the row (reduction) dimension, with the contraction running on the
MXU (``preferred_element_type=float32`` — bf16 inputs accumulate in fp32):

* :func:`gram_kernel` — plain ``XᵀX`` for an (M, N) matrix, zero-padded to
  tile multiples (zero rows contribute nothing, so no masking is needed).
* :func:`normalized_gram_kernel` — the back half of the fused
  profiles→DPP-kernel pipeline: takes the padded distance matrix ``S0`` from
  ``pairwise_l2.pairwise_dists_stats_kernel`` plus the min-max scalars and
  applies the eq.-(14) **normalise epilogue in the tile prologue** —
  ``S = 1 − (S0 − lo)/rng`` with pad rows masked to 0 — before the Gram
  contraction.  One launch produces ``L = SᵀS`` without ``S`` ever
  materialising in HBM.

Grid: (N/bm, N/bn, M/bk), K innermost (sequential on TPU).  The default
(128, 128, 128) tiles keep the working set ≈ 0.2 MB ≪ VMEM and all matmul
dims 128-aligned.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["gram_kernel", "normalized_gram_kernel"]


def _pad_up(x: int, b: int) -> int:
    return -(-x // b) * b


def _gram_body(a_ref, b_ref, out_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += jax.lax.dot_general(
        a_ref[...], b_ref[...], (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


@functools.partial(
    jax.jit, static_argnames=("block_m", "block_n", "block_k", "interpret")
)
def gram_kernel(
    x: jax.Array,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """X (M, N) -> XᵀX (N, N) in fp32 (bf16 inputs keep fp32 accumulation)."""
    m, n = x.shape
    bm, bn, bk = min(block_m, n), min(block_n, n), min(block_k, m)
    np_ = max(_pad_up(n, bm), _pad_up(_pad_up(n, bm), bn))
    mp = _pad_up(m, bk)
    xp = jnp.pad(x, ((0, mp - m), (0, np_ - n)))
    out = pl.pallas_call(
        _gram_body,
        grid=(np_ // bm, np_ // bn, mp // bk),
        in_specs=[
            pl.BlockSpec((bk, bm), lambda i, j, k: (k, i)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((np_, np_), jnp.float32),
        interpret=interpret,
    )(xp, xp)
    return out[:n, :n]


def _norm_gram_body(a_ref, b_ref, lo_ref, rng_ref, out_ref, *, c, bk, compute_dtype):
    k_idx = pl.program_id(2)

    @pl.when(k_idx == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    lo = lo_ref[0, 0]
    rng = rng_ref[0, 0]
    # eq.-(14) epilogue fused into the contraction prologue: similarity
    # S = 1 − (S0 − lo)/rng; pad rows (the reduction dim) masked to 0 so the
    # garbage region of the padded S0 never reaches the accumulator.
    rows = k_idx * bk + jax.lax.broadcasted_iota(jnp.int32, (bk, 1), 0)
    sa = jnp.where(rows < c, 1.0 - (a_ref[...] - lo) / rng, 0.0)
    sb = jnp.where(rows < c, 1.0 - (b_ref[...] - lo) / rng, 0.0)
    out_ref[...] += jax.lax.dot_general(
        sa.astype(compute_dtype), sb.astype(compute_dtype),
        (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


@functools.partial(
    jax.jit,
    static_argnames=("c", "block_m", "block_n", "block_k", "compute_dtype", "interpret"),
)
def normalized_gram_kernel(
    s0: jax.Array,
    lo: jax.Array,
    rng: jax.Array,
    c: int,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 128,
    compute_dtype=jnp.float32,
    interpret: bool = False,
) -> jax.Array:
    """Padded distances S0 (P, P) + min-max scalars -> DPP kernel L (c, c).

    ``c`` is the real client count (rows/cols ≥ c of ``s0`` are pad garbage);
    ``compute_dtype`` is the MXU input dtype for the contraction (bf16 for
    bf16 profiles — accumulation stays fp32).
    """
    p = s0.shape[0]
    bm, bn, bk = min(block_m, c), min(block_n, c), min(block_k, p)
    pp = max(_pad_up(p, bm), _pad_up(_pad_up(p, bm), bn), _pad_up(p, bk))
    s0p = jnp.pad(s0, ((0, pp - p), (0, pp - p)))
    lo2 = jnp.asarray(lo, jnp.float32).reshape(1, 1)
    rng2 = jnp.asarray(rng, jnp.float32).reshape(1, 1)
    out = pl.pallas_call(
        functools.partial(_norm_gram_body, c=c, bk=bk, compute_dtype=compute_dtype),
        grid=(pp // bm, pp // bn, pp // bk),
        in_specs=[
            pl.BlockSpec((bk, bm), lambda i, j, k: (k, i)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, 1), lambda i, j, k: (0, 0)),
            pl.BlockSpec((1, 1), lambda i, j, k: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((pp, pp), jnp.float32),
        interpret=interpret,
    )(s0p, s0p, lo2, rng2)
    return out[:c, :c]

"""Jit'd public wrapper for the pairwise_l2 Pallas kernel.

On CPU (this container) the kernel body executes under ``interpret=True``;
on TPU it compiles to Mosaic.  ``repro.core.similarity`` routes through here
when ``use_kernel=True``.
"""

from __future__ import annotations

import jax

from repro.kernels.pairwise_l2.pairwise_l2 import pairwise_sq_dists_kernel

__all__ = ["pairwise_sq_dists"]


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def pairwise_sq_dists(f: jax.Array, block_m: int = 128, block_n: int = 128,
                      block_k: int = 512) -> jax.Array:
    if f.ndim != 2:
        raise ValueError(f"profiles must be (C, Q), got {f.shape}")
    return pairwise_sq_dists_kernel(
        f, block_m=block_m, block_n=block_n, block_k=block_k,
        interpret=_interpret(),
    )

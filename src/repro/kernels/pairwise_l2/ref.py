"""Pure-jnp oracle for the pairwise_l2 kernel."""

import jax
import jax.numpy as jnp

__all__ = ["pairwise_sq_dists_ref"]


def pairwise_sq_dists_ref(f: jax.Array) -> jax.Array:
    """Naive O(C²·Q) differences — the exact reference (fp32)."""
    f = f.astype(jnp.float32)
    diff = f[:, None, :] - f[None, :, :]
    return jnp.sum(diff * diff, axis=-1)

"""Pallas TPU kernel: tiled pairwise squared-L2 distances (profile kernel)."""

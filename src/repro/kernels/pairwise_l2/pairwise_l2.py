"""Tiled pairwise squared-L2 distance kernel (the paper's O(C²·Q) hot spot).

Computes ``D2[m, n] = ‖F[m] − F[n]‖²`` for a profile matrix ``F (C, Q)`` via
the MXU-friendly decomposition, accumulated per K-tile:

    D2 = Σ_k ( rowsum(A_k²) + rowsum(B_k²)ᵀ − 2 A_k B_kᵀ )

Grid: (C/bm, C/bn, Q/bk) — the K dim is innermost (sequential on TPU), the
(bm × bn) fp32 output tile lives in VMEM across the K loop.  A and B tiles
are (bm × bk) / (bn × bk) VMEM blocks; the −2·A·Bᵀ term is a (bm×bk)·(bk×bn)
MXU matmul.  Tile defaults (128, 128, 512) keep the working set
(2·128·512 + 128·128)·4 B ≈ 0.6 MB ≪ 16 MB VMEM and the matmul dims
128-aligned for the MXU.

Two entry points:

* :func:`pairwise_sq_dists_kernel` — plain squared distances (clamped,
  zero diagonal).
* :func:`pairwise_dists_stats_kernel` — the fused eq.-(14) front end: the
  last K iteration runs a **sqrt epilogue** in-tile (clamp → pin diagonal →
  ``√``) and reduces each tile's masked min/max into (grid_m, grid_n) stats
  outputs, so the min-max normalisation scalars cost one tiny reduction
  instead of a second O(C²) pass.  Feeds the ``gram`` kernel
  (``repro.kernels.gram``), making profiles → DPP kernel two launches.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["pairwise_dists_stats_kernel", "pairwise_sq_dists_kernel"]


def _kernel(a_ref, b_ref, out_ref):
    k_idx = pl.program_id(2)

    @pl.when(k_idx == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    a = a_ref[...].astype(jnp.float32)  # (bm, bk)
    b = b_ref[...].astype(jnp.float32)  # (bn, bk)
    a2 = jnp.sum(a * a, axis=1, keepdims=True)  # (bm, 1)
    b2 = jnp.sum(b * b, axis=1, keepdims=True)  # (bn, 1)
    ab = jax.lax.dot_general(
        a, b, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (bm, bn) on the MXU
    out_ref[...] += a2 + b2.T - 2.0 * ab


@functools.partial(
    jax.jit, static_argnames=("block_m", "block_n", "block_k", "interpret")
)
def pairwise_sq_dists_kernel(
    f: jax.Array,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """F (C, Q) -> D2 (C, C); pads C and Q up to tile multiples internally."""
    c, q = f.shape
    bm, bn, bk = min(block_m, c), min(block_n, c), min(block_k, q)
    cp = -(-c // bm) * bm
    cpn = -(-cp // bn) * bn  # common padded C for both tilings
    cp = max(cp, cpn)
    qp = -(-q // bk) * bk
    fp = jnp.pad(f, ((0, cp - c), (0, qp - q)))

    grid = (cp // bm, cp // bn, qp // bk)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bn, bk), lambda i, j, k: (j, k)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((cp, cp), jnp.float32),
        interpret=interpret,
    )(fp, fp)
    d2 = out[:c, :c]
    # numerical hygiene to match the reference contract: clamp & zero diag
    d2 = jnp.maximum(d2, 0.0)
    return d2 * (1.0 - jnp.eye(c, dtype=d2.dtype))


def _stats_kernel(a_ref, b_ref, out_ref, mn_ref, mx_ref, *, c, bm, bn):
    i, j, k_idx = pl.program_id(0), pl.program_id(1), pl.program_id(2)

    @pl.when(k_idx == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    a = a_ref[...].astype(jnp.float32)  # (bm, bk)
    b = b_ref[...].astype(jnp.float32)  # (bn, bk)
    a2 = jnp.sum(a * a, axis=1, keepdims=True)  # (bm, 1)
    b2 = jnp.sum(b * b, axis=1, keepdims=True)  # (bn, 1)
    ab = jax.lax.dot_general(
        a, b, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (bm, bn) on the MXU
    out_ref[...] += a2 + b2.T - 2.0 * ab

    @pl.when(k_idx == pl.num_programs(2) - 1)
    def _epilogue():
        # clamp → pin the diagonal (distance to self is exactly 0, which
        # makes min(S⁰) = 0, eq. 14) → sqrt, all while the tile is in VMEM
        rows = i * bm + jax.lax.broadcasted_iota(jnp.int32, (bm, bn), 0)
        cols = j * bn + jax.lax.broadcasted_iota(jnp.int32, (bm, bn), 1)
        d2 = jnp.maximum(out_ref[...], 0.0)
        s0 = jnp.sqrt(jnp.where(rows == cols, 0.0, d2))
        out_ref[...] = s0
        # masked per-tile min/max (pad region excluded) for the eq.-(14)
        # min-max normalisation — reduced to scalars by the caller
        valid = (rows < c) & (cols < c)
        mn_ref[0, 0] = jnp.min(jnp.where(valid, s0, jnp.inf))
        mx_ref[0, 0] = jnp.max(jnp.where(valid, s0, -jnp.inf))


@functools.partial(
    jax.jit, static_argnames=("block_m", "block_n", "block_k", "interpret")
)
def pairwise_dists_stats_kernel(
    f: jax.Array,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 512,
    interpret: bool = False,
):
    """F (C, Q) -> (S0 (Cp, Cp), lo, hi): L2 distances + min/max scalars.

    ``S0`` is returned at the padded tile size (rows/cols ≥ C hold garbage —
    downstream consumers mask on the real C); ``lo``/``hi`` are the exact
    min/max over the real (C, C) region, fp monotonicity making them equal
    to the reference's post-sqrt extrema.
    """
    c, q = f.shape
    bm, bn, bk = min(block_m, c), min(block_n, c), min(block_k, q)
    cp = -(-c // bm) * bm
    cpn = -(-cp // bn) * bn  # common padded C for both tilings
    cp = max(cp, cpn)
    qp = -(-q // bk) * bk
    fp = jnp.pad(f, ((0, cp - c), (0, qp - q)))

    grid = (cp // bm, cp // bn, qp // bk)
    s0, mn, mx = pl.pallas_call(
        functools.partial(_stats_kernel, c=c, bm=bm, bn=bn),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bn, bk), lambda i, j, k: (j, k)),
        ],
        out_specs=[
            pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j, k: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j, k: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((cp, cp), jnp.float32),
            jax.ShapeDtypeStruct((grid[0], grid[1]), jnp.float32),
            jax.ShapeDtypeStruct((grid[0], grid[1]), jnp.float32),
        ],
        interpret=interpret,
    )(fp, fp)
    return s0, jnp.min(mn), jnp.max(mx)

"""Pytree checkpointing (npz payload + json treedef sidecar)."""

from repro.checkpoint.checkpoint import latest_step, restore, save

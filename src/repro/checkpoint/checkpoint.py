"""Dependency-free pytree checkpointing.

Layout: ``<dir>/step_<N>/arrays.npz`` + ``tree.json``.  Arrays are stored by
flattened index; the treedef is reconstructed by unflattening against a
template (restore requires a pytree-structure template, which training loops
always have — their init state).
"""

from __future__ import annotations

import json
import os
import re
from typing import Any, Optional

import jax
import numpy as np

__all__ = ["save", "restore", "latest_step"]


def save(ckpt_dir: str, step: int, tree: Any) -> str:
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    os.makedirs(path, exist_ok=True)
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    arrays = {f"leaf_{i}": np.asarray(x) for i, x in enumerate(leaves)}
    np.savez(os.path.join(path, "arrays.npz"), **arrays)
    meta = {
        "step": step,
        "num_leaves": len(leaves),
        "treedef": str(treedef),
        "shapes": [list(np.shape(x)) for x in leaves],
        "dtypes": [str(np.asarray(x).dtype) for x in leaves],
    }
    with open(os.path.join(path, "tree.json"), "w") as f:
        json.dump(meta, f)
    return path


def restore(ckpt_dir: str, template: Any, step: Optional[int] = None) -> Any:
    """Load a snapshot and unflatten it against ``template``'s treedef.

    The snapshot must MATCH the template: leaf count, per-leaf shape, and
    per-leaf dtype are all validated (against both ``tree.json`` and the
    loaded arrays) and any mismatch raises a descriptive ``ValueError`` —
    a checkpoint from a different config must never silently
    reshape/cast-unflatten into garbage state.
    """
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "tree.json")) as f:
        meta = json.load(f)
    with np.load(os.path.join(path, "arrays.npz")) as z:
        leaves = [z[f"leaf_{i}"] for i in range(len(z.files))]
    if meta.get("num_leaves") != len(leaves):
        raise ValueError(
            f"corrupt checkpoint at {path}: tree.json records "
            f"{meta.get('num_leaves')} leaves but arrays.npz holds "
            f"{len(leaves)}"
        )
    t_leaves, treedef = jax.tree_util.tree_flatten(template)
    if len(t_leaves) != len(leaves):
        raise ValueError(
            f"checkpoint at {path} has {len(leaves)} leaves, template has "
            f"{len(t_leaves)} — snapshot and restore config disagree"
        )
    meta_shapes = [tuple(s) for s in meta.get("shapes", [])]
    meta_dtypes = list(meta.get("dtypes", []))
    for i, (x, t) in enumerate(zip(leaves, t_leaves)):
        if meta_shapes and (
            tuple(x.shape) != meta_shapes[i] or str(x.dtype) != meta_dtypes[i]
        ):
            raise ValueError(
                f"corrupt checkpoint at {path}: leaf {i} is "
                f"{x.dtype}{tuple(x.shape)} but tree.json recorded "
                f"{meta_dtypes[i]}{meta_shapes[i]}"
            )
        tt = np.asarray(t)
        if tuple(x.shape) != tuple(tt.shape):
            raise ValueError(
                f"checkpoint leaf {i} at {path}: saved shape "
                f"{tuple(x.shape)} does not match template shape "
                f"{tuple(tt.shape)} — snapshot and restore config disagree"
            )
        if x.dtype != tt.dtype:
            raise ValueError(
                f"checkpoint leaf {i} at {path}: saved dtype {x.dtype} does "
                f"not match template dtype {tt.dtype} — snapshot and "
                "restore config disagree"
            )
    return treedef.unflatten(leaves)


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [
        int(m.group(1))
        for d in os.listdir(ckpt_dir)
        if (m := re.fullmatch(r"step_(\d+)", d))
    ]
    return max(steps) if steps else None

"""Dependency-free pytree checkpointing.

Layout: ``<dir>/step_<N>/arrays.npz`` + ``tree.json``.  Arrays are stored by
flattened index; the treedef is reconstructed by unflattening against a
template (restore requires a pytree-structure template, which training loops
always have — their init state).
"""

from __future__ import annotations

import json
import os
import re
from typing import Any, Optional

import jax
import numpy as np

__all__ = ["save", "restore", "latest_step"]


def save(ckpt_dir: str, step: int, tree: Any) -> str:
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    os.makedirs(path, exist_ok=True)
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    arrays = {f"leaf_{i}": np.asarray(x) for i, x in enumerate(leaves)}
    np.savez(os.path.join(path, "arrays.npz"), **arrays)
    meta = {
        "step": step,
        "num_leaves": len(leaves),
        "treedef": str(treedef),
        "shapes": [list(np.shape(x)) for x in leaves],
        "dtypes": [str(np.asarray(x).dtype) for x in leaves],
    }
    with open(os.path.join(path, "tree.json"), "w") as f:
        json.dump(meta, f)
    return path


def restore(ckpt_dir: str, template: Any, step: Optional[int] = None) -> Any:
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with np.load(os.path.join(path, "arrays.npz")) as z:
        leaves = [z[f"leaf_{i}"] for i in range(len(z.files))]
    t_leaves, treedef = jax.tree_util.tree_flatten(template)
    if len(t_leaves) != len(leaves):
        raise ValueError(
            f"checkpoint has {len(leaves)} leaves, template has {len(t_leaves)}"
        )
    leaves = [
        np.asarray(x).astype(np.asarray(t).dtype).reshape(np.shape(t))
        for x, t in zip(leaves, t_leaves)
    ]
    return treedef.unflatten(leaves)


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [
        int(m.group(1))
        for d in os.listdir(ckpt_dir)
        if (m := re.fullmatch(r"step_(\d+)", d))
    ]
    return max(steps) if steps else None

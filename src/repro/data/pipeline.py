"""Minimal deterministic batch pipeline (host-side numpy, device-fed)."""

from __future__ import annotations

from typing import Iterator, Tuple

import numpy as np

__all__ = ["batch_iterator", "epoch_batches"]


def epoch_batches(
    xs: np.ndarray, ys: np.ndarray, batch_size: int, rng: np.random.Generator
) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
    """One shuffled pass; drops the ragged tail (static shapes for jit)."""
    order = rng.permutation(len(xs))
    for start in range(0, len(xs) - batch_size + 1, batch_size):
        sel = order[start : start + batch_size]
        yield xs[sel], ys[sel]


def batch_iterator(
    xs: np.ndarray, ys: np.ndarray, batch_size: int, seed: int = 0
) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
    """Endless shuffled batches (re-shuffles every epoch)."""
    rng = np.random.default_rng(seed)
    while True:
        yield from epoch_batches(xs, ys, batch_size, rng)

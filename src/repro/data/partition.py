"""Non-IID partitioners (paper §4 protocol).

``skewness_partition`` implements the paper's ξ protocol exactly:

* ξ = 1   — every sample of a client belongs to one (dominant) class;
* ξ = 0.8 — 80% dominant class, 20% uniformly from the other classes;
* ξ = 0.5 — 50% / 50%;
* ξ = 'H' — evenly split between exactly two classes.

Clients have uniform dataset sizes (paper: "clients' local datasets are of a
uniform size").  Dominant classes rotate round-robin so the global
distribution stays balanced.  ``dirichlet_partition`` is the standard
Dir(α) alternative used by the wider FL literature (beyond paper).
"""

from __future__ import annotations

from typing import List, Union

import numpy as np

__all__ = ["skewness_partition", "dirichlet_partition"]


def _pools(ys: np.ndarray, num_classes: int, rng: np.random.Generator) -> List[np.ndarray]:
    pools = []
    for j in range(num_classes):
        idx = np.nonzero(ys == j)[0]
        rng.shuffle(idx)
        pools.append(list(idx))
    return pools


def _draw(pools, cls, count, rng, num_classes):
    """Draw ``count`` sample indices of class ``cls`` (with refill fallback)."""
    out = []
    for _ in range(count):
        if not pools[cls]:
            # pool exhausted -> steal from the globally largest pool
            cls = int(np.argmax([len(p) for p in pools]))
        out.append(pools[cls].pop())
    return out


def skewness_partition(
    ys: np.ndarray,
    num_clients: int,
    xi: Union[float, str],
    num_classes: int,
    samples_per_client: int | None = None,
    seed: int = 0,
) -> List[np.ndarray]:
    """Partition sample indices into ``num_clients`` ξ-skewed shards."""
    rng = np.random.default_rng(seed)
    n = len(ys)
    spc = samples_per_client or n // num_clients
    pools = _pools(ys, num_classes, rng)
    shards = []
    for c in range(num_clients):
        dom = c % num_classes
        if xi == "H" or xi == "h":
            second = (dom + 1 + c // num_classes) % num_classes
            idx = _draw(pools, dom, spc // 2, rng, num_classes) + _draw(
                pools, second, spc - spc // 2, rng, num_classes
            )
        else:
            xi_f = float(xi)
            n_dom = int(round(xi_f * spc))
            idx = _draw(pools, dom, n_dom, rng, num_classes)
            others = [j for j in range(num_classes) if j != dom]
            for i in range(spc - n_dom):
                idx += _draw(pools, others[i % len(others)], 1, rng, num_classes)
        arr = np.asarray(idx, np.int64)
        rng.shuffle(arr)
        shards.append(arr)
    return shards


def dirichlet_partition(
    ys: np.ndarray,
    num_clients: int,
    alpha: float,
    num_classes: int,
    seed: int = 0,
) -> List[np.ndarray]:
    """Standard Dir(α) label-skew partition (lower α = more skew)."""
    rng = np.random.default_rng(seed)
    shards = [[] for _ in range(num_clients)]
    for j in range(num_classes):
        idx = np.nonzero(ys == j)[0]
        rng.shuffle(idx)
        p = rng.dirichlet(alpha * np.ones(num_clients))
        cuts = (np.cumsum(p)[:-1] * len(idx)).astype(int)
        for c, part in enumerate(np.split(idx, cuts)):
            shards[c].extend(part.tolist())
    out = []
    for s in shards:
        arr = np.asarray(s, np.int64)
        rng.shuffle(arr)
        out.append(arr)
    return out

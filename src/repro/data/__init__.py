"""Data substrate: synthetic datasets, non-IID partitioners, batch pipeline."""

from repro.data.partition import dirichlet_partition, skewness_partition
from repro.data.pipeline import batch_iterator, epoch_batches
from repro.data.synthetic import (
    SyntheticImageDataset,
    make_image_dataset,
    make_token_dataset,
)

"""Synthetic class-conditional datasets (simulated data gate — DESIGN.md §4).

MNIST / Fashion-MNIST are not available offline, so the paper's experiments
run on a *class-structured* synthetic image dataset with the same interface:
28×28×1 images, 10 classes, 60k samples, normalised to zero mean / unit-ish
variance (Assumption 1 asks for normalised inputs).

Each class j has a smooth random prototype field P_j; a sample is
``α·P_j + shift + texture-noise`` with per-sample jitter, so (i) classes are
separable by a small CNN but not trivially, (ii) per-class latent feature
distributions differ — which is exactly what FC-1 profiling must pick up.

``make_token_dataset`` provides topic-conditional token streams (per-class
bigram-ish Markov chains over a vocab) for the FL-LLM examples.
"""

from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["SyntheticImageDataset", "make_image_dataset", "make_token_dataset"]


@dataclasses.dataclass
class SyntheticImageDataset:
    xs: np.ndarray  # (N, H, W, 1) float32, normalised
    ys: np.ndarray  # (N,) int32
    num_classes: int

    def subset(self, idx: np.ndarray) -> "SyntheticImageDataset":
        return SyntheticImageDataset(self.xs[idx], self.ys[idx], self.num_classes)


def _smooth_field(rng: np.random.Generator, h: int, w: int, passes: int = 3) -> np.ndarray:
    f = rng.normal(size=(h, w)).astype(np.float32)
    for _ in range(passes):  # box blur => smooth blob structure
        f = (
            f
            + np.roll(f, 1, 0)
            + np.roll(f, -1, 0)
            + np.roll(f, 1, 1)
            + np.roll(f, -1, 1)
        ) / 5.0
    f = (f - f.mean()) / (f.std() + 1e-8)
    return f


def make_image_dataset(
    n: int = 60_000,
    num_classes: int = 10,
    h: int = 28,
    w: int = 28,
    seed: int = 0,
    noise: float = 0.6,
    max_shift: int = 3,
) -> SyntheticImageDataset:
    """Class-conditional synthetic images, MNIST-like in shape and scale."""
    rng = np.random.default_rng(seed)
    protos = np.stack([_smooth_field(rng, h, w) for _ in range(num_classes)])
    ys = rng.integers(0, num_classes, size=n).astype(np.int32)
    alpha = rng.uniform(0.7, 1.3, size=(n, 1, 1)).astype(np.float32)
    xs = protos[ys] * alpha
    # small random translations (classes stay separable, samples vary)
    sx = rng.integers(-max_shift, max_shift + 1, size=n)
    sy = rng.integers(-max_shift, max_shift + 1, size=n)
    for i in range(n):  # vectorised roll per unique shift would be overkill here
        if sx[i] or sy[i]:
            xs[i] = np.roll(xs[i], (sx[i], sy[i]), axis=(0, 1))
    xs = xs + noise * rng.normal(size=xs.shape).astype(np.float32)
    xs = (xs - xs.mean()) / (xs.std() + 1e-8)
    return SyntheticImageDataset(xs[..., None].astype(np.float32), ys, num_classes)


def make_token_dataset(
    n_docs: int = 2_000,
    doc_len: int = 256,
    vocab: int = 512,
    num_topics: int = 10,
    seed: int = 0,
) -> tuple[np.ndarray, np.ndarray]:
    """Topic-conditional token documents: returns (docs (N, L) int32, topics (N,)).

    Each topic owns a sparse transition structure over a preferred token band,
    so language-model loss is topic-dependent — giving the LM-FL examples real
    non-IID structure.
    """
    rng = np.random.default_rng(seed)
    topics = rng.integers(0, num_topics, size=n_docs).astype(np.int32)
    band = vocab // num_topics
    docs = np.zeros((n_docs, doc_len), np.int32)
    for t in range(num_topics):
        idx = np.nonzero(topics == t)[0]
        if idx.size == 0:
            continue
        lo = t * band
        # 80% in-band tokens with a deterministic drift, 20% uniform
        cur = rng.integers(lo, lo + band, size=idx.size)
        for pos in range(doc_len):
            docs[idx, pos] = cur
            drift = (cur + rng.integers(1, 4, size=idx.size) - lo) % band + lo
            uni = rng.integers(0, vocab, size=idx.size)
            use_band = rng.random(idx.size) < 0.8
            cur = np.where(use_band, drift, uni)
    return docs, topics

"""Bounded-staleness aggregation primitives (DESIGN.md §9).

The synchronous sharded round (DESIGN.md §8) is a hard barrier: the eq.-(6)
psum rendezvous waits for every shard, so one straggler sets the round's
wall clock.  Bounded staleness relaxes exactly that: a shard that misses the
round deadline keeps contributing, but its partial weighted sums are
computed against params from round ``t − s_d`` (its *staleness* ``s_d``,
capped at ``FLConfig.staleness_bound``) and enter the SAME single psum
scaled by a staleness-decay weight ``λ(s_d)``.

This module holds the pure, jit/scan-compatible pieces the engine composes:

* **Ring buffer** — the scan carries the last ``s + 1`` param snapshots as
  one pytree whose leaves lead with ``(s + 1, ...)``; slot ``t mod (s+1)``
  holds the round-``t`` params (:func:`init_param_hist`,
  :func:`update_param_hist`, :func:`read_slots`).
* **Staleness counters** — per-shard int32 ``s_d`` with the bounded-lag
  dynamics of :func:`staleness_step`: a shard that beats the deadline syncs
  (``s_d ← 0``); one that misses falls behind (``s_d ← s_d + 1``) until the
  bound forces a blocking sync (``s_d ← 0``, the round waits for it).
* **Decay weighting** — :data:`DECAY_FAMILIES` (constant / polynomial /
  exponential), ``λ(0) = 1`` for every family so ``staleness_bound = 0``
  reduces *bit-identically* to the synchronous round.  Normalisation is the
  psum'd ``Σ λ·w`` denominator itself (``core.metrics.safe_div``);
  :func:`normalized_decay_weights` exposes the explicit distribution form
  for analysis and the property tests.
* **Simulated wall clock** — :func:`round_sim_time` prices one round under
  a latency scenario (``repro.fl.scenarios``): fast shards finish at their
  own latency, slow-but-unforced shards are cut off at the deadline (their
  work lands stale), forced shards block the round at full latency.
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import metrics as metrics_lib

__all__ = [
    "DECAY_FAMILIES",
    "decay_weights",
    "normalized_decay_weights",
    "init_param_hist",
    "init_staleness_fields",
    "update_param_hist",
    "read_slots",
    "staleness_step",
    "round_sim_time",
]

PyTree = Any

# Staleness-decay families λ(s), all with λ(0) = 1 and λ non-increasing:
#   constant     λ(s) = 1                 (plain stale FedAvg)
#   polynomial   λ(s) = (1 + s)^{-α}      (Xie et al.-style poly decay)
#   exponential  λ(s) = exp(-α·s)
DECAY_FAMILIES = ("constant", "polynomial", "exponential")


def decay_weights(staleness: jax.Array, family: str, alpha: float) -> jax.Array:
    """λ(s) per entry of ``staleness`` (int array) — raw, un-normalised.

    The engine multiplies each shard's eq.-(6) weights by its λ(s_d); the
    Σλw denominator of the psum rendezvous (``safe_div``) then performs the
    normalisation, so every family yields a convex combination of client
    params.  λ is strictly positive, so the weight-0 ⟺ non-cohort masking
    convention (NaN losses, DESIGN.md §8) survives the rescale.
    """
    s = jnp.asarray(staleness).astype(jnp.float32)
    if family == "constant":
        return jnp.ones_like(s)
    if family == "polynomial":
        return (1.0 + s) ** jnp.float32(-alpha)
    if family == "exponential":
        return jnp.exp(jnp.float32(-alpha) * s)
    raise ValueError(
        f"unknown staleness decay family {family!r}; known: {DECAY_FAMILIES}"
    )


def normalized_decay_weights(
    staleness: jax.Array, family: str, alpha: float
) -> jax.Array:
    """λ(s) normalised to a distribution via :func:`~repro.core.metrics.safe_div`.

    The explicit form of the weighting the psum denominator applies
    implicitly — non-negative, sums to 1 for any non-empty staleness vector
    (property-tested in ``tests/test_staleness_engine.py``).
    """
    lam = decay_weights(staleness, family, alpha)
    return metrics_lib.safe_div(lam, jnp.sum(lam))


# -------------------------------------------------------------- ring buffer


def init_param_hist(params: PyTree, bound: int) -> PyTree:
    """Ring buffer of ``bound + 1`` param snapshots, every slot = ``params``.

    Slot convention: slot ``t mod (bound + 1)`` holds the round-``t`` global
    params, so at init (round 0) every reachable staleness reads θ₀.
    """
    n = bound + 1
    return jax.tree_util.tree_map(
        lambda x: jnp.tile(x[None], (n,) + (1,) * x.ndim), params
    )


def init_staleness_fields(params, bound: int, mesh, client_axis: str):
    """Fresh staleness bookkeeping for a ``ServerState``: ``(param_hist,
    shard_staleness)`` — the ring buffer with every slot at ``params`` and
    zeroed per-shard lag counters.  The ONE constructor every state builder
    (``engine.init_server_state``, ``FLTrainer.server_state``) goes through,
    so the ring/counter layout can never drift between paths.  Staleness is
    a per-shard property, so a mesh is mandatory.
    """
    if mesh is None:
        raise ValueError(
            f"staleness_bound={bound} requires a client mesh (pass mesh=...; "
            "launchers: --staleness-bound needs --shard-clients)"
        )
    return (
        init_param_hist(params, bound),
        jnp.zeros((mesh.shape[client_axis],), jnp.int32),
    )


def update_param_hist(
    hist: PyTree, params: PyTree, round_t: jax.Array, bound: int
) -> PyTree:
    """Write the round-``round_t`` params into their ring slot."""
    slot = jnp.mod(jnp.asarray(round_t, jnp.int32), bound + 1)
    return jax.tree_util.tree_map(
        lambda h, p: lax.dynamic_update_index_in_dim(
            h, p.astype(h.dtype), slot, 0
        ),
        hist,
        params,
    )


def read_slots(round_t: jax.Array, staleness: jax.Array, bound: int) -> jax.Array:
    """Ring slots holding the round-``t − s_d`` params, per shard.

    Counters satisfy ``s_d ≤ min(round_t + 1, bound)`` (they start at 0 and
    bump at most once per round, and the engine reads with the post-update
    counters), so ``t − s_d ≥ −1`` and the read never leaves the
    ``{θ_max(0, t−bound) … θ_t}`` window the ring holds — the ``t = 0``,
    ``s_d = 1`` corner lands on a slot still carrying the init value θ₀.
    """
    return jnp.mod(round_t - staleness, bound + 1).astype(jnp.int32)


# ----------------------------------------------------------------- dynamics


def staleness_step(
    staleness: jax.Array, slow: jax.Array, bound: int
) -> Tuple[jax.Array, jax.Array]:
    """One round of the bounded-lag counter dynamics.

    ``slow`` marks shards that missed this round's deadline.  Fast shards
    sync (``0``); slow shards fall one round further behind; a shard whose
    counter would exceed ``bound`` is **forced**: the round blocks on it
    (see :func:`round_sim_time`) and it re-syncs to 0.  With ``bound = 0``
    every slow shard is forced every round — the synchronous barrier.

    The engine keys the round's decay weight and ring read on the
    POST-update counters returned here: what lands by round ``t``'s deadline
    is work based on pre-miss params, so a deadline-capped round never
    aggregates information the simulated clock says arrived after it closed
    (a first-time straggler delivers round-``t−1`` work, not free fresh
    work).  Forced shards block the round and deliver fresh work at 0.

    Returns ``(new_staleness, forced)``.
    """
    s = jnp.asarray(staleness, jnp.int32)
    bumped = jnp.where(slow, s + 1, 0)
    forced = bumped > bound
    return jnp.where(forced, 0, bumped).astype(jnp.int32), forced


def round_sim_time(
    shard_lat: jax.Array,
    slow: jax.Array,
    forced: jax.Array,
    deadline: float,
) -> jax.Array:
    """Simulated wall clock of one bounded-staleness round.

    Fast shards finish at their own latency; slow-but-unforced shards are
    cut off at the ``deadline`` (their work continues into later rounds as
    staleness); forced shards block the round at their full latency.  The
    round closes at the max over shards — with ``bound = 0`` (all slow
    shards forced) this is exactly the synchronous ``max(latency)`` barrier.
    """
    per_shard = jnp.where(
        slow, jnp.where(forced, shard_lat, jnp.float32(deadline)), shard_lat
    )
    return jnp.max(per_shard)

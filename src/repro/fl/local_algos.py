"""Pluggable local-update algorithms (DESIGN.md §12).

The engine decides *who* trains (selection, funnel, availability) and *how
updates are aggregated* (sharding, slots, staleness, robust aggregation);
this registry decides *what each client computes*.  Every algorithm is a
pure recipe with one canonical signature:

* ``algo.init(params) -> client_state`` — the per-client state carried
  across rounds (``()`` for stateless algorithms, so the pytree adds zero
  leaves to any carry);
* ``step(params, client_state, global_params, batch) -> (params,
  client_state, loss)`` — one local SGD step, obtained by *binding* the
  algorithm to the round's training hyperparameters with
  :meth:`LocalAlgo.bind` (the algorithm itself stays a pure recipe that a
  registry can hand out without knowing the model).

Algorithms customise two hooks on top of plain SGD:

* :meth:`LocalAlgo.transform_grad` — fold a per-step term into the raw
  gradient (FedProx's proximal pull ``mu·(w − w_global)``; FedDyn's linear
  penalty ``−h + alpha·(w − w_global)``).  The FedAvg identity hook keeps
  the compiled graph bit-identical to the pre-registry engine.
* :meth:`LocalAlgo.finalize` — evolve the per-client state once per round
  after the local scan (FedDyn's ``h ← h − alpha·(w_final − w_global)``).

``global_params`` is the round's *base* params — whatever the client
actually trained from.  Under bounded staleness that is the shard's stale
ring read (DESIGN.md §9): the proximal/penalty anchors follow the stale
base on purpose, so a drift-corrected stale shard pulls toward the params
it trained from, not toward a future snapshot it never saw.

FedDyn here is the **client-side** variant: the per-client linear-penalty
state ``h_k`` corrects local drift, while the server keeps the plain
eq.-(6) weighted average (no server-side ``−h/alpha`` shift).  That keeps
every aggregation path — single psum, slots, staleness decay, robust
guards — byte-for-byte untouched; the drift correction lives entirely in
the per-step gradient.

The registry raises the same ``ValueError`` shape as the scenario / fault /
selection registries: ``unknown local algorithm 'x'; known: [...]``.
"""

from __future__ import annotations

from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

__all__ = [
    "LocalAlgo",
    "BoundLocalAlgo",
    "FedAvg",
    "FedProx",
    "FedDyn",
    "LOCAL_ALGOS",
    "ALGO_NAMES",
    "get_local_algo",
    "algo_from_config",
    "init_client_states",
]

PyTree = Any


class LocalAlgo:
    """Base local-update algorithm: plain SGD (eq. 3-5), stateless.

    Subclasses override :meth:`transform_grad` (per-step) and — for
    algorithms with per-client state — ``stateful = True`` plus
    :meth:`init` / :meth:`finalize`.  ``name`` is the registry key.
    """

    name = "base"
    # True when init() returns real per-client state that must be carried
    # across rounds (a client-sharded ServerState field); stateless
    # algorithms return () so no carry/pytree changes anywhere.
    stateful = False

    def init(self, params: PyTree) -> PyTree:
        """Fresh per-client state for one client (stateless: ``()``)."""
        return ()

    def transform_grad(
        self, grad: PyTree, params: PyTree, client_state: PyTree,
        global_params: PyTree,
    ) -> PyTree:
        """Fold the algorithm's per-step term into the raw gradient.

        The base (FedAvg) hook returns ``grad`` unchanged — the SAME
        object, so the compiled program is bit-identical to plain SGD."""
        return grad

    def finalize(
        self, params: PyTree, client_state: PyTree, global_params: PyTree
    ) -> PyTree:
        """Evolve the per-client state once after the round's local scan."""
        return client_state

    def bind(
        self,
        loss_fn: Callable[[PyTree, PyTree], jax.Array],
        lr: float,
        grad_clip: Optional[float] = None,
        micro_batches: int = 1,
    ) -> "BoundLocalAlgo":
        """Bind the recipe to training hyperparameters, yielding the
        canonical ``step(params, client_state, global_params, batch)``."""
        return BoundLocalAlgo(self, loss_fn, lr, grad_clip, micro_batches)


class BoundLocalAlgo:
    """A :class:`LocalAlgo` bound to (loss_fn, lr, grad_clip, micro_batches)
    — the object exposing the canonical per-step signature."""

    def __init__(self, algo, loss_fn, lr, grad_clip, micro_batches):
        from repro.fl.rounds import make_grad_fn  # local import: no cycle at module load

        self.algo = algo
        self.lr = lr
        self.grad_clip = grad_clip
        self._grad_fn = make_grad_fn(loss_fn, micro_batches)

    @property
    def name(self) -> str:
        return self.algo.name

    @property
    def stateful(self) -> bool:
        return self.algo.stateful

    def init(self, params: PyTree) -> PyTree:
        return self.algo.init(params)

    def step(self, params, client_state, global_params, batch):
        """One local SGD step: ``(params, client_state, global_params,
        batch) -> (params, client_state, loss)`` (eq. 3-5 plus the
        algorithm's per-step gradient term)."""
        from repro import optim as optim_lib

        loss, g = self._grad_fn(params, batch)
        g = self.algo.transform_grad(g, params, client_state, global_params)
        if self.grad_clip is not None:
            g = optim_lib.clip_by_global_norm(g, self.grad_clip)
        params = jax.tree_util.tree_map(
            lambda w, gw: (w - self.lr * gw).astype(w.dtype), params, g
        )
        return params, client_state, loss

    def finalize(self, params, client_state, global_params):
        return self.algo.finalize(params, client_state, global_params)


class FedAvg(LocalAlgo):
    """Plain local SGD (McMahan et al.) — every hook is the base identity,
    so the compiled round is bit-identical to the pre-registry engine."""

    name = "fedavg"


class FedProx(LocalAlgo):
    """FedProx (Li et al., arXiv:1812.06127): the proximal term
    ``mu/2·||w − w_global||²`` folded into every per-step gradient as
    ``g + mu·(w − w_global)``, taming client drift under non-IID data.

    ``prox_mu == 0`` short-circuits to the identity hook at trace time, so
    a zero-mu FedProx compiles to exactly the FedAvg program (the
    hypothesis-tested reduction property)."""

    name = "fedprox"

    def __init__(self, prox_mu: float = 0.01):
        if prox_mu < 0:
            raise ValueError(f"prox_mu={prox_mu} must be >= 0")
        self.prox_mu = float(prox_mu)

    def transform_grad(self, grad, params, client_state, global_params):
        if self.prox_mu == 0.0:
            return grad  # static shortcut: mu=0 IS fedavg, same program
        mu = self.prox_mu
        return jax.tree_util.tree_map(
            lambda g, w, wg: g
            + mu * (w.astype(g.dtype) - wg.astype(g.dtype)),
            grad, params, global_params,
        )


class FedDyn(LocalAlgo):
    """FedDyn (Acar et al., ICLR'21), client-side variant: each client
    carries a linear-penalty state ``h_k`` (params-shaped, fp32) making the
    local objective ``L_k(w) − ⟨h_k, w⟩ + alpha/2·||w − w_global||²``:

    * per step: ``g ← g − h_k + alpha·(w − w_global)``
    * per round: ``h_k ← h_k − alpha·(w_final − w_global)``

    ``h_k`` accumulates each client's historical drift so repeated local
    training is pulled toward the *federation's* stationary point, not the
    client's — the strongest known local correction at high non-IID skew.
    The server keeps the plain eq.-(6) average (see the module docstring
    for why the server-side shift is deliberately omitted)."""

    name = "feddyn"
    stateful = True

    def __init__(self, feddyn_alpha: float = 0.01):
        if feddyn_alpha <= 0:
            raise ValueError(
                f"feddyn_alpha={feddyn_alpha} must be > 0 (alpha=0 is "
                "fedavg with dead state — use local_algo='fedavg')"
            )
        self.feddyn_alpha = float(feddyn_alpha)

    def init(self, params):
        return jax.tree_util.tree_map(
            lambda w: jnp.zeros(w.shape, jnp.float32), params
        )

    def transform_grad(self, grad, params, client_state, global_params):
        a = self.feddyn_alpha
        return jax.tree_util.tree_map(
            lambda g, h, w, wg: g
            - h.astype(g.dtype)
            + a * (w.astype(g.dtype) - wg.astype(g.dtype)),
            grad, client_state, params, global_params,
        )

    def finalize(self, params, client_state, global_params):
        a = self.feddyn_alpha
        return jax.tree_util.tree_map(
            lambda h, w, wg: h - a * (w.astype(h.dtype) - wg.astype(h.dtype)),
            client_state, params, global_params,
        )


LOCAL_ALGOS = {
    "fedavg": FedAvg,
    "fedprox": FedProx,
    "feddyn": FedDyn,
}

ALGO_NAMES = tuple(sorted(LOCAL_ALGOS))


def get_local_algo(name: str, **kw) -> LocalAlgo:
    """Build a local-update algorithm by registry name; ``**kw`` forwards to
    the constructor (e.g. ``get_local_algo('fedprox', prox_mu=0.01)``)."""
    if name not in LOCAL_ALGOS:
        raise ValueError(
            f"unknown local algorithm {name!r}; known: {list(ALGO_NAMES)}"
        )
    return LOCAL_ALGOS[name](**kw)


def algo_from_config(
    name: str,
    prox_mu: Optional[float] = None,
    feddyn_alpha: Optional[float] = None,
) -> LocalAlgo:
    """The FLConfig -> algorithm mapping (one definition for engine,
    trainer, and launchers).  Hyperparameter/algorithm combos are validated
    by ``FLConfig.__post_init__``; here unset values fall back to each
    constructor's default."""
    kw = {}
    if name == "fedprox" and prox_mu is not None:
        kw["prox_mu"] = prox_mu
    if name == "feddyn" and feddyn_alpha is not None:
        kw["feddyn_alpha"] = feddyn_alpha
    return get_local_algo(name, **kw)


def init_client_states(algo: LocalAlgo, params: PyTree, num_clients: int):
    """Stacked per-client algorithm state: every leaf of ``algo.init``
    broadcast to a leading ``(C,)`` client axis — the layout
    ``CLIENT_SHARDED_FIELDS`` lays over the mesh.  ``None`` for stateless
    algorithms so the ServerState pytree (and every compiled program keyed
    on it) is unchanged."""
    if not algo.stateful:
        return None
    proto = algo.init(params)
    return jax.tree_util.tree_map(
        lambda s: jnp.zeros((num_clients,) + s.shape, s.dtype), proto
    )

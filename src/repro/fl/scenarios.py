"""System-heterogeneity scenario simulator (DESIGN.md §9).

The client-selection surveys (arXiv:2211.01549, arXiv:2207.03681) split the
selection problem into *statistical* heterogeneity (non-IID data — the
paper's axis) and *system* heterogeneity (stragglers and intermittent
availability).  This registry models the second axis as pure, PRNG-keyed
functions the scanned engine calls **at the jit level** — no host callbacks,
scan/vmap-compatible, bit-reproducible per key:

* ``latency(key, n) -> (n,) float32`` — one round's per-client wall-clock
  draw.  Families: uniform (homogeneous fleet), lognormal (moderate
  dispersion), heavy-tail Pareto (the straggler regime: occasional clients
  10–100× slower than the median).
* ``availability(key, t, n) -> (n,) bool`` — time-varying participation
  mask (diurnal sine-modulated Bernoulli, per-client phase).  When present,
  the engine routes selection through the strategies'
  ``select_avail_fn`` hook so cohorts are drawn from available clients only
  (DPP folds the mask into the kernel before sampling).
* ``deadline`` — the round cutoff the bounded-staleness engine
  (``FLConfig.staleness_bound``, ``repro.fl.staleness``) holds shards to: a
  shard whose selected residents exceed it misses the round and goes stale.

Scenarios are *static* config (named in ``FLConfig.scenario``, resolved at
``make_round_fn`` time); all per-round randomness flows from the scanned key
chain, so a scenario never perturbs the selection/batch key streams — a
latency-only scenario leaves cohorts bit-identical to a scenario-free run.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp

__all__ = ["Scenario", "SCENARIOS", "SCENARIO_NAMES", "get_scenario"]

LatencyFn = Callable[[jax.Array, int], jax.Array]
AvailabilityFn = Callable[[jax.Array, jax.Array, int], jax.Array]


@dataclasses.dataclass(frozen=True)
class Scenario:
    """One named system-heterogeneity model (latency + optional availability).

    Time units are arbitrary "round-cost" units — only ratios matter for the
    sync-vs-stale comparisons in ``benchmarks/async_bench.py``.
    """

    name: str
    deadline: float  # round cutoff for the bounded-staleness engine
    latency: LatencyFn
    availability: Optional[AvailabilityFn] = None


def _uniform_latency(lo: float, hi: float) -> LatencyFn:
    def draw(key, n):
        return jax.random.uniform(key, (n,), jnp.float32, lo, hi)

    return draw


def _lognormal_latency(sigma: float) -> LatencyFn:
    def draw(key, n):
        return jnp.exp(sigma * jax.random.normal(key, (n,), jnp.float32))

    return draw


def _pareto_latency(alpha: float, scale: float) -> LatencyFn:
    # inverse-CDF Pareto: scale · (1 − u)^{−1/α}; α near 1 ⇒ very heavy tail
    # (infinite variance), the regime where a synchronous barrier pays the
    # max of the cohort's draws while bounded staleness pays ~the deadline.
    def draw(key, n):
        u = jax.random.uniform(key, (n,), jnp.float32)
        return scale * (1.0 - u) ** jnp.float32(-1.0 / alpha)

    return draw


def _diurnal_availability(
    period: float = 24.0, base: float = 0.55, swing: float = 0.4
) -> AvailabilityFn:
    # per-client phase spread over the day: client c is "on its charger"
    # with probability base + swing·sin(2π(t/period + c/n)) at round t
    def draw(key, t, n):
        phase = jnp.arange(n, dtype=jnp.float32) / jnp.float32(n)
        tt = jnp.asarray(t).astype(jnp.float32)
        p = base + swing * jnp.sin(2.0 * jnp.pi * (tt / period + phase))
        return jax.random.uniform(key, (n,), jnp.float32) < p

    return draw


SCENARIOS = {
    # homogeneous fleet: barrier ≈ deadline, staleness buys ~nothing (the
    # honest control arm for BENCH_async)
    "uniform": Scenario(
        name="uniform", deadline=1.15, latency=_uniform_latency(0.8, 1.2)
    ),
    # moderate dispersion: median 1, P95 ≈ 2.7
    "lognormal": Scenario(
        name="lognormal", deadline=1.6, latency=_lognormal_latency(0.6)
    ),
    # straggler regime: Pareto(α=1.1), median ≈ 0.94, unbounded mean — the
    # synchronous max-of-cohort barrier is dominated by the tail
    "heavy_tail": Scenario(
        name="heavy_tail", deadline=2.0, latency=_pareto_latency(1.1, 0.5)
    ),
    # heavy-tail latency + diurnal availability: exercises the
    # availability-aware selection hook on top of staleness
    "flaky": Scenario(
        name="flaky",
        deadline=2.0,
        latency=_pareto_latency(1.1, 0.5),
        availability=_diurnal_availability(),
    ),
}

SCENARIO_NAMES = tuple(sorted(SCENARIOS))


def get_scenario(name: str) -> Scenario:
    """Resolve a registry name; raises ``ValueError`` listing known names."""
    try:
        return SCENARIOS[name]
    except KeyError:
        raise ValueError(
            f"unknown scenario {name!r}; known: {list(SCENARIO_NAMES)}"
        ) from None

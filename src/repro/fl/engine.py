"""Functional federation engine — N rounds as one compiled program.

The legacy :class:`~repro.fl.trainer.FLTrainer` runs Algorithm 1 as a host
Python loop: every round pays host↔device round-trips for selection, the loss
refresh, GEMD, and eval, and every (strategy, seed) pair re-runs the whole
loop serially.  This module replaces that with a **pure state machine**
(DESIGN.md §7):

* :class:`ServerState` — one pytree holding everything the server evolves:
  global params, the PRNG key, the profile kernel, last-known local losses,
  the (host-prefitted) cluster labels, the simulated client shards, and the
  round counter.  Because *all* fields are concrete arrays, the state can be
  carried through ``lax.scan`` and stacked/vmapped across seeds and
  strategies.
* :func:`make_round_fn` — builds the pure ``round_fn(state, _) -> (state,
  metrics)`` for a static :class:`FLConfig`: select cohort (via the pure
  ``select_fn`` layer of ``repro.core.selection``, dispatched through
  ``lax.switch`` on ``state.strategy_index``) → build local batches → Mode-A
  round step (eq. 3-6) → refresh last-known losses → GEMD → (conditional)
  eval.  Zero host synchronisation anywhere.
* :func:`run_scanned` — compiles ``num_rounds`` applications of ``round_fn``
  into a single ``lax.scan``; per-round metrics come back as stacked scan
  outputs (one device→host transfer for the whole run).
* :func:`run_many` — vmaps ``run_scanned`` over a stacked batch of states,
  so S seeds × K strategies of the paper protocol execute as **one** XLA
  program (the Fig.-1 / Table-1 sweep workload).

Host-only work (agglomerative cluster fitting, profile refresh for
``reprofile_every``) happens *between* scans: callers run scan segments and
refresh state on the segment boundary (see ``FLTrainer.run``).  The k-DPP
**spectral cache** (``ServerState.eig_state``, DESIGN.md §6) follows the same
lifecycle: :func:`init_server_state` pays the one O(C³) ``eigh``, reprofile
boundaries rebuild it together with the kernel, and the scanned round only
ever draws from it — O(k²·C) per round instead of an in-scan decomposition.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

try:  # newer jax graduates shard_map out of experimental
    from jax import shard_map as _shard_map
except ImportError:  # jax 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map


def _checked_shard_map(f, mesh, in_specs, out_specs):
    """shard_map with replication checking off, across jax versions (the
    ``check_rep`` kwarg is renamed/retired after 0.4.x)."""
    try:
        return _shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=False
        )
    except TypeError:
        return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs)

from repro import checkpoint as checkpoint_lib
from repro.core import dpp as dpp_lib
from repro.core import metrics as metrics_lib
from repro.core import profiles as profiles_lib
from repro.core import selection as selection_lib
from repro.core import similarity as similarity_lib
from repro.fl import faults as faults_lib
from repro.fl import local_algos as local_algos_lib
from repro.fl import rounds as rounds_lib
from repro.fl import scenarios as scenarios_lib
from repro.fl import staleness as staleness_lib
from repro.launch.sharding import CLIENT_AXIS, client_axis_spec
from repro.obs import sink as obs_sink_lib
from repro.obs import telemetry as obs_telemetry_lib
from repro.obs import tracing as obs_tracing_lib

__all__ = [
    "FLConfig",
    "ServerState",
    "CLIENT_AXIS",
    "make_round_fn",
    "run_scanned",
    "run_many",
    "run_checkpointed",
    "save_server_state",
    "restore_server_state",
    "stack_states",
    "unstack_outputs",
    "init_server_state",
    "shard_server_state",
    "history_from_outputs",
    "funnel_fields",
    "candidate_profile_block",
]

PyTree = Any


@dataclasses.dataclass
class FLConfig:
    """Static federation protocol configuration (hashable trace constants)."""

    num_clients: int = 100
    clients_per_round: int = 10
    local_epochs: int = 2  # E in eq. (3)
    local_batch_size: Optional[int] = None  # None = full-batch GD (paper eq. 4)
    lr: float = 0.05
    rounds: int = 100
    eval_every: int = 5
    num_classes: int = 10
    seed: int = 0
    reprofile_every: Optional[int] = None  # beyond-paper: refresh profiles
    use_pallas_kernel: bool = False  # pairwise distances through Pallas
    grad_clip: Optional[float] = None  # stabilises late-round full-batch SGD
    local_steps: Optional[int] = None  # explicit steps/round (token workloads)
    sample_with_replacement: bool = False  # iid batch draws instead of perms
    # Capacity-slot scheduling (DESIGN.md §8, sharded path only): max cohort
    # clients trained per shard.  None = legacy resident execution (every
    # resident computes a possibly-zero-weighted update); an int packs each
    # shard's selected residents into cap = min(C_loc, cohort_cap) slots so
    # k ≪ C cohorts stop paying D·(C/D) redundant local updates.  Must be
    # >= min(clients_per_round, C_loc) so no shard can overflow its slots.
    cohort_cap: Optional[int] = None
    # Bounded-staleness aggregation (DESIGN.md §9, sharded path only).
    # None = synchronous psum barrier; an int s lets shards that miss the
    # scenario's round deadline contribute eq.-(6) partial sums computed
    # against params from round t−s_d (s_d <= s, ring buffer in
    # ServerState.param_hist) weighted by the staleness-decay family below.
    # s = 0 reduces bit-identically to the synchronous sharded round.
    # Requires a mesh (make_round_fn validates) and a `scenario`; mutually
    # exclusive with cohort_cap (validated here, not inside jit tracing).
    staleness_bound: Optional[int] = None
    # one default across every surface (FLConfig, train.py --staleness-decay,
    # dryrun): polynomial (1+s)^-alpha, the standard stale-gradient weighting
    staleness_decay: str = "polynomial"  # constant | polynomial | exponential
    staleness_alpha: float = 0.5  # decay rate for polynomial/exponential
    # System-heterogeneity scenario (repro.fl.scenarios registry): drives
    # per-client latency draws (simulated round wall clock in the metrics,
    # straggler/staleness dynamics when staleness_bound is set) and, for
    # scenarios with an availability model, availability-masked selection.
    scenario: Optional[str] = None
    # Two-stage selection funnel (DESIGN.md §10): fraction of the federation
    # surviving the cheap stage-1 prefilter (loss / predicted-latency /
    # availability score, one fused top-Q).  None = no funnel; with a float
    # in (0, 1], Q = candidate_count() candidates carry the (Q, Q) eq.-(14)
    # kernel + spectral cache — the O(C³) eigh and the C×C Gram disappear
    # (the million-client regime).  Candidates are fixed per reprofile
    # segment, so the spectral cache stays valid between boundaries.
    candidate_frac: Optional[float] = None
    # Fault tolerance (DESIGN.md §11).  ``faults`` names a
    # repro.fl.faults.FAULT_MODELS entry injecting per-round client failures
    # (dropout / NaN / garbage / sign-flip / shard blackout) from a salted
    # fold_in stream — faults=None never touches the key chain, so
    # fault-free configs stay bit-identical to the pre-fault engine.
    faults: Optional[str] = None
    # Robust aggregation mode (repro.fl.faults.AGGREGATORS): "mean" is the
    # plain eq.-(6) weighted sum (vulnerable control — a delivered NaN or
    # norm-exploded update flows straight in); "clipped_mean" rescales
    # over-norm deltas to robust_norm_mult × the cohort's median update
    # norm; "trimmed_mean" rejects them (weight 0, safe_div renormalises).
    # Both robust modes always reject non-finite updates and flag offenders
    # for quarantine.  Any aggregator != "mean" (or any fault model) turns
    # the update-validation guard on.
    aggregator: str = "mean"
    robust_norm_mult: float = 3.0  # clip/trim threshold × cohort median norm
    # survivors floor: a guarded round whose weighted sum retains fewer
    # clients becomes an identity round (params carried over, recorded in
    # the scan metrics) instead of aggregating noise/zeros
    min_survivors: int = 1
    # rounds a flagged client is excluded from selection (via the
    # select_avail_fn availability hook); 0 disables the cooldown
    quarantine_rounds: int = 5
    # run_checkpointed snapshot period (rounds); None = no snapshots
    ckpt_every: Optional[int] = None
    # Local-update algorithm (DESIGN.md §12, repro.fl.local_algos registry):
    # what each selected client computes.  "fedavg" is plain local SGD —
    # bit-identical to the pre-registry engine in every mode; "fedprox"
    # folds the proximal pull mu·(w − w_global) into each per-step grad;
    # "feddyn" carries a per-client linear-penalty state (a client-sharded
    # ServerState field) correcting historical drift.  Orthogonal to every
    # other flag: sharding, slots, staleness, faults, and the funnel accept
    # any registered algorithm without forking round bodies.
    local_algo: str = "fedavg"
    prox_mu: Optional[float] = None  # fedprox proximal strength (>= 0)
    feddyn_alpha: Optional[float] = None  # feddyn penalty strength (> 0)
    # In-program telemetry (DESIGN.md §14, repro.obs): when True the round
    # emits a per-round Telemetry pytree of selection / robustness /
    # staleness diagnostics alongside the scan outputs, drained to a JSONL
    # sink at chunk boundaries.  STATIC flag with the repo-wide bit-identity
    # contract: telemetry=False lowers the exact pre-telemetry program (no
    # extra outputs, no key-stream or state changes), and telemetry=True
    # only *adds* output leaves — the carried state and every shared metric
    # stay bit-identical.
    telemetry: bool = False

    def local_algo_obj(self) -> "local_algos_lib.LocalAlgo":
        """The configured :class:`repro.fl.local_algos.LocalAlgo` instance
        (combos already validated by ``__post_init__``)."""
        return local_algos_lib.algo_from_config(
            self.local_algo, self.prox_mu, self.feddyn_alpha
        )

    def guarded(self) -> bool:
        """True when the update-validation / quarantine layer is active."""
        return self.faults is not None or self.aggregator != "mean"

    def candidate_count(self) -> int:
        """Q — stage-1 survivors; ``round(C·frac)`` clamped to
        ``[clients_per_round, num_clients]`` (a cohort must always fit)."""
        assert self.candidate_frac is not None
        q = int(round(self.num_clients * self.candidate_frac))
        return max(self.clients_per_round, min(q, self.num_clients))

    def __post_init__(self):
        # flag-combination contract: every invalid combo dies HERE with one
        # clear ValueError, never inside jit tracing
        if self.staleness_bound is not None:
            if self.staleness_bound < 0:
                raise ValueError(
                    f"staleness_bound={self.staleness_bound} must be >= 0"
                )
            if self.cohort_cap is not None:
                raise ValueError(
                    f"cohort_cap={self.cohort_cap} is incompatible with "
                    f"staleness_bound={self.staleness_bound}: capacity-slot "
                    "compaction assumes a synchronous cohort (every slot "
                    "trains on round-t params) — drop one of the two flags"
                )
            if self.scenario is None:
                raise ValueError(
                    f"staleness_bound={self.staleness_bound} requires a "
                    "latency scenario (set FLConfig.scenario / --scenario): "
                    "without a latency model no shard ever goes stale"
                )
            if self.staleness_decay not in staleness_lib.DECAY_FAMILIES:
                raise ValueError(
                    f"unknown staleness_decay {self.staleness_decay!r}; "
                    f"known: {staleness_lib.DECAY_FAMILIES}"
                )
            if self.staleness_alpha < 0:
                raise ValueError(
                    f"staleness_alpha={self.staleness_alpha} must be >= 0"
                )
        if self.scenario is not None:
            scenarios_lib.get_scenario(self.scenario)  # unknown name raises
        if self.candidate_frac is not None:
            if not (0.0 < self.candidate_frac <= 1.0):
                raise ValueError(
                    f"candidate_frac={self.candidate_frac} must be in (0, 1] "
                    "(1.0 = degenerate funnel, bit-identical to no funnel)"
                )
        if self.aggregator not in faults_lib.AGGREGATORS:
            raise ValueError(
                f"unknown aggregator {self.aggregator!r}; "
                f"known: {list(faults_lib.AGGREGATORS)}"
            )
        if self.faults is not None:
            faults_lib.get_fault_model(self.faults)  # unknown name raises
        if self.guarded():
            if self.robust_norm_mult <= 0:
                raise ValueError(
                    f"robust_norm_mult={self.robust_norm_mult} must be > 0"
                )
            if self.min_survivors < 1:
                raise ValueError(
                    f"min_survivors={self.min_survivors} must be >= 1: with "
                    "0 survivors the weighted sum is all-zero and the "
                    "aggregate would silently zero the params — the floor "
                    "exists so that round degrades to identity instead"
                )
            if self.min_survivors > self.clients_per_round:
                raise ValueError(
                    f"min_survivors={self.min_survivors} > clients_per_round"
                    f"={self.clients_per_round}: every round would be an "
                    "identity round"
                )
            if self.quarantine_rounds < 0:
                raise ValueError(
                    f"quarantine_rounds={self.quarantine_rounds} must be >= 0"
                )
        if self.ckpt_every is not None and self.ckpt_every < 1:
            raise ValueError(
                f"ckpt_every={self.ckpt_every} must be >= 1 (None disables "
                "snapshots)"
            )
        if self.local_algo not in local_algos_lib.LOCAL_ALGOS:
            raise ValueError(
                f"unknown local algorithm {self.local_algo!r}; "
                f"known: {list(local_algos_lib.ALGO_NAMES)}"
            )
        if self.prox_mu is not None:
            if self.local_algo != "fedprox":
                raise ValueError(
                    f"prox_mu={self.prox_mu} only applies to "
                    f"local_algo='fedprox' (got {self.local_algo!r})"
                )
            if self.prox_mu < 0:
                raise ValueError(f"prox_mu={self.prox_mu} must be >= 0")
        if self.feddyn_alpha is not None:
            if self.local_algo != "feddyn":
                raise ValueError(
                    f"feddyn_alpha={self.feddyn_alpha} only applies to "
                    f"local_algo='feddyn' (got {self.local_algo!r})"
                )
            if self.feddyn_alpha <= 0:
                raise ValueError(
                    f"feddyn_alpha={self.feddyn_alpha} must be > 0"
                )


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class ServerState:
    """Everything the server evolves across rounds, as one pytree.

    Leading-axis stacking of several states (see :func:`stack_states`) yields
    a batch state that :func:`run_many` vmaps over — per-seed client shards,
    per-seed params, and per-combination strategy indices all ride along.
    """

    params: PyTree  # global model
    key: jax.Array  # server PRNG key
    round: jax.Array  # int32 scalar, rounds completed
    losses: jax.Array  # (C,) last-known local losses
    kernel: jax.Array  # eq.-(14) DPP kernel: (C, C), or (Q, Q) under funnel
    profiles: jax.Array  # (C, Q_f) eq.-(11) client profiles
    eig_state: dpp_lib.KDPPSamplerState  # spectral cache of ``kernel``
    cluster_labels: jax.Array  # (C,)/(Q,) int32, host-prefitted (0 if unused)
    client_xs: jax.Array  # (C, n_c, ...) simulated client shards
    client_ys: jax.Array  # (C, n_c)
    client_sizes: jax.Array  # (C,) n_c
    client_label_dists: jax.Array  # (C, num_classes)
    global_label_dist: jax.Array  # (num_classes,)
    strategy_index: jax.Array  # int32 scalar into the round_fn's strategies
    # Bounded-staleness bookkeeping (DESIGN.md §9) — None on synchronous
    # configs, so the pytree stays unchanged for every existing path:
    param_hist: Optional[PyTree] = None  # (s+1, ...) ring of param snapshots
    shard_staleness: Optional[jax.Array] = None  # (D,) int32 per-shard lag
    # Two-stage funnel (DESIGN.md §10) — None on unfunneled configs.  When
    # set: (Q,) int32 ascending global ids of the stage-1 survivors, and the
    # kernel / eig_state / cluster_labels above live on the Q-block.  Fixed
    # per reprofile segment (rebuilt with the profiles), replicated.
    candidates: Optional[jax.Array] = None
    # Quarantine cooldowns (DESIGN.md §11) — None unless the update-
    # validation guard is on (cfg.guarded()).  (C,) int32 rounds remaining
    # before a flagged client may be selected again; feeds selection through
    # the select_avail_fn availability hook.  Replicated (selection is
    # replicated trivia, like the staleness counters).
    quarantine: Optional[jax.Array] = None
    # Per-client local-algorithm state (DESIGN.md §12) — None unless the
    # configured algorithm is stateful (FedDyn's linear-penalty h_k).  A
    # pytree whose leaves lead with (C, ...), client-sharded like the data
    # fields (CLIENT_SHARDED_FIELDS), gathered through the slot machinery,
    # and snapshotted by checkpointing like every other leaf.
    algo_state: Optional[PyTree] = None

    @property
    def num_clients(self) -> int:
        return self.losses.shape[0]

    def selection_state(self) -> selection_lib.SelectionState:
        """The per-round :class:`~repro.core.selection.SelectionState` view.

        Under the funnel this is **candidate-space**: the O(Q) gathers of the
        per-client signals are the only per-round funnel cost, and the
        strategies then draw over Q with ``select_global_fn`` mapping the
        picks back to global ids."""
        if self.candidates is None:
            return selection_lib.SelectionState(
                kernel=self.kernel,
                losses=self.losses,
                client_sizes=self.client_sizes,
                cluster_labels=self.cluster_labels,
                eig_state=self.eig_state,
            )
        return selection_lib.SelectionState(
            kernel=self.kernel,
            losses=jnp.take(self.losses, self.candidates),
            client_sizes=jnp.take(self.client_sizes, self.candidates),
            cluster_labels=self.cluster_labels,
            eig_state=self.eig_state,
            candidates=selection_lib.CandidateSet(ids=self.candidates),
        )


# ----------------------------------------------------------------- batches


def _num_batches(n_c: int, batch_size: int) -> int:
    """Minibatches per local epoch: ``max(1, n_c // b)`` (drop-remainder, at
    least one batch).  The ONE definition shared by :func:`_steps_per_round`
    and :func:`batches_from_indices` — sizing the jitted scan and slicing the
    data must agree or per-step batches silently drift."""
    return max(1, n_c // batch_size)


def _steps_per_round(cfg: FLConfig, n_c: int) -> int:
    if cfg.local_steps is not None:
        return cfg.local_steps
    if cfg.local_batch_size is None:
        return cfg.local_epochs  # E full-batch passes (paper eq. 4)
    return cfg.local_epochs * _num_batches(n_c, cfg.local_batch_size)


def batch_indices_from_keys(cfg: FLConfig, keys, n_c: int):
    """Per-client random *index plans*: ``keys[i]`` drives client i's draws.

    Returns ``None`` for full-batch mode (no randomness), the (M, steps, B)
    replacement draws, or the (M, n_c) epoch permutation.  Split from
    :func:`batches_from_indices` so the mesh-sharded round can generate every
    plan at the jit level (replicated, tiny int arrays) and keep only the
    data slicing inside its ``shard_map`` — random-bit generation fused into
    the shard body miscompiles on jax 0.4.37 (wrong clients' draws).
    """
    if cfg.local_batch_size is None:
        return None
    steps = _steps_per_round(cfg, n_c)
    b = cfg.local_batch_size
    if cfg.sample_with_replacement:
        # token-style workloads: iid uniform draws per step (replacement)
        return jax.vmap(lambda k: jax.random.randint(k, (steps, b), 0, n_c))(keys)
    return jax.vmap(lambda k: jax.random.permutation(k, n_c))(keys)


def batches_from_indices(cfg: FLConfig, ids, xs, ys):
    """Apply :func:`batch_indices_from_keys` plans to M clients' data."""
    n_c = xs.shape[1]
    steps = _steps_per_round(cfg, n_c)
    if cfg.local_batch_size is None:
        # full-batch: each local step sees the whole local dataset
        xb = jnp.broadcast_to(xs[:, None], (xs.shape[0], steps) + xs.shape[1:])
        yb = jnp.broadcast_to(ys[:, None], (ys.shape[0], steps) + ys.shape[1:])
        return (xb, yb)
    b = cfg.local_batch_size
    if cfg.sample_with_replacement:
        xb = jax.vmap(jnp.take, in_axes=(0, 0, None))(xs, ids, 0)
        yb = jax.vmap(jnp.take, in_axes=(0, 0, None))(ys, ids, 0)
        return (xb, yb)
    # clamp to the local dataset: n_c < b means ONE short full batch (the
    # same count _num_batches floors to), not an impossible (nb, b) reshape
    b = min(b, n_c)
    nb = _num_batches(n_c, b)
    perm = ids
    xs = jnp.take_along_axis(
        xs, perm.reshape(perm.shape + (1,) * (xs.ndim - 2)), axis=1
    )
    ys = jnp.take_along_axis(ys, perm, axis=1)
    xb = xs[:, : nb * b].reshape(xs.shape[0], nb, b, *xs.shape[2:])
    yb = ys[:, : nb * b].reshape(ys.shape[0], nb, b)
    reps = cfg.local_epochs
    xb = jnp.tile(xb, (1, reps) + (1,) * (xb.ndim - 2))
    yb = jnp.tile(yb, (1, reps, 1))
    return (xb, yb)


def client_batches_from_keys(cfg: FLConfig, keys, xs, ys):
    """Per-client batch slicing for an explicit (M,) key-per-client vector."""
    return batches_from_indices(
        cfg, batch_indices_from_keys(cfg, keys, xs.shape[1]), xs, ys
    )


def make_client_batches(cfg: FLConfig, key, client_xs, client_ys, sel):
    """Slice the selected clients' data into (C_p, steps, B, ...) batches.

    Pure/jittable; shared by the scanned engine and the legacy trainer loop
    so both execute bit-identical batch construction.
    """
    xs = jnp.take(client_xs, sel, axis=0)
    ys = jnp.take(client_ys, sel, axis=0)
    keys = jax.random.split(key, xs.shape[0])
    return client_batches_from_keys(cfg, keys, xs, ys)


# ---------------------------------------------------------------- round_fn

# fold_in salt branching the scenario's environment stream (latency /
# availability draws) off the carried server key WITHOUT consuming a split:
# the selection/batch key streams stay bit-identical with or without a
# scenario attached.
_ENV_SALT = 0x5CE7A210


def make_round_fn(
    cfg: FLConfig,
    loss_fn: Callable,  # loss_fn(params, x, y) -> scalar
    strategies: Sequence[selection_lib.SelectionStrategy],
    accuracy_fn: Optional[Callable] = None,
    eval_data: Optional[Tuple[jax.Array, jax.Array]] = None,
    sequential_clients: bool = True,
    mesh: Optional[jax.sharding.Mesh] = None,
    client_axis: str = CLIENT_AXIS,
) -> Callable[[ServerState, Any], Tuple[ServerState, Dict[str, jax.Array]]]:
    """Build the pure per-round transition ``round_fn(state, _)``.

    ``strategies`` is the static tuple the traced ``state.strategy_index``
    dispatches over via ``lax.switch`` — pass one strategy for single runs or
    the full method grid for :func:`run_many`.  ``accuracy_fn(params, xs, ys)``
    is evaluated every ``cfg.eval_every`` rounds under ``lax.cond`` (NaN on
    the other rounds); with ``eval_data=None`` it scores the union training
    set (the paper's Fig.-1 protocol).

    With ``mesh`` set (DESIGN.md §8) the local-update core runs as a
    ``shard_map`` over the mesh's ``client_axis``: every device executes
    local updates for the clients *resident* in its shard (cohort membership
    becomes a weight mask, so there is no cross-device gather of client
    data), and eq.-(6) aggregation happens as per-shard partial weighted
    sums combined with ``psum`` — the parameter tree is never all-gathered.
    Selection stays replicated (same kernel + key on every device ⇒
    bit-identical cohorts vs. the single-device path); per-client losses are
    refreshed in place on their home shard.  The state must be laid out with
    :func:`shard_server_state` over the same mesh/axis.

    ``cfg.cohort_cap`` switches the sharded body to capacity-slot execution:
    each shard packs its selected residents into ``cap = min(C_loc,
    cohort_cap)`` slots (slot table computed at the jit level from the
    replicated cohort; batch-index plans are generated **sized to slots**,
    ``D·cap`` rows instead of ``C``), runs local updates only over slots,
    and scatters losses back to resident layout — same selection, same
    single-psum aggregation, ``C_loc/cap``× less local-update work for
    k ≪ C cohorts.  Ignored without a mesh (the single-device body already
    gathers exactly the k selected clients).

    ``cfg.scenario`` attaches a system-heterogeneity model (DESIGN.md §9):
    per-round latency draws priced into a ``sim_time`` metric, and — for
    scenarios with an availability model — selection routed through the
    strategies' ``select_avail_fn`` hook (cohorts drawn from available
    clients only; the mask rides the outputs as ``avail``).
    ``cfg.staleness_bound`` additionally relaxes the sharded round's psum
    barrier to bounded-staleness aggregation: shards that miss the
    scenario's deadline contribute eq.-(6) partials computed against ring-
    buffered params from round ``t − s_d`` (``s_d ≤ staleness_bound``),
    scaled by the ``cfg.staleness_decay`` family — same single psum, with
    ``staleness_bound = 0`` reducing bit-identically to the synchronous
    sharded round.  Requires a mesh and a scenario (validated here / in
    ``FLConfig``); the state must carry the staleness fields
    (:func:`init_server_state` builds them).
    """
    strategies = tuple(strategies)
    k = cfg.clients_per_round
    if mesh is not None and cfg.cohort_cap is not None:
        n_shards = mesh.shape[client_axis]
        c_loc_cfg = cfg.num_clients // n_shards
        if cfg.cohort_cap < min(k, c_loc_cfg):
            raise ValueError(
                f"cohort_cap={cfg.cohort_cap} < min(clients_per_round={k}, "
                f"C_loc={c_loc_cfg}): a shard could hold more cohort members "
                "than slots (clients would be silently dropped)"
            )
    if cfg.staleness_bound is not None and mesh is None:
        raise ValueError(
            f"staleness_bound={cfg.staleness_bound} requires the mesh-sharded "
            "engine (pass mesh=...; launchers: --staleness-bound needs "
            "--shard-clients): staleness is a per-shard property"
        )
    scen = (
        scenarios_lib.get_scenario(cfg.scenario)
        if cfg.scenario is not None
        else None
    )
    avail_aware = scen is not None and scen.availability is not None
    # Fault tolerance (DESIGN.md §11): the fault model's per-round draws and
    # the update-validation guard.  guard_on also without a fault model —
    # the robust aggregators screen honest-path updates too.  Quarantine
    # feeds selection through the same availability hook as the scenario, so
    # guarded configs route selection avail-aware even without a scenario.
    fault_model = (
        faults_lib.get_fault_model(cfg.faults) if cfg.faults is not None
        else None
    )
    guard_on = cfg.guarded()
    lemons = (
        faults_lib.lemon_mask(fault_model, cfg.num_clients)
        if fault_model is not None else None
    )
    guard = (
        faults_lib.make_update_guard(
            cfg.aggregator, cfg.robust_norm_mult,
            garbage_scale=(
                fault_model.garbage_scale if fault_model is not None else 1.0
            ),
            inject=fault_model is not None,
        )
        if guard_on else None
    )
    route_avail = avail_aware or guard_on
    batched_loss = lambda p, batch: loss_fn(p, batch[0], batch[1])
    loss_of = jax.vmap(loss_fn, in_axes=(None, 0, 0))
    # the local-update algorithm is a static trace constant (DESIGN.md §12):
    # every round body hands it to the rounds builders; a stateful one
    # threads ServerState.algo_state through gather → update → masked
    # write-back without forking any body
    algo = cfg.local_algo_obj()
    stateful = algo.stateful
    # selection dispatches through select_global_fn — the ONE canonical
    # entry point ``(key, state, k, avail=None)``: without candidates it is
    # exactly the legacy draw; with them the draw runs in candidate space
    # (the avail mask gathered through the shared candidate_availability
    # guard) and the picks come back as global ids, so everything downstream
    # of ``sel`` — batches, aggregation, loss refresh, GEMD, slots,
    # staleness — is untouched by funnelling.  ``avail`` defaulting to None
    # makes the same branch tuple serve both call arities, so avail-routed
    # and plain configs share one construction.
    branches = tuple(
        functools.partial(
            lambda strat, key, sstate, avail=None: strat.select_global_fn(
                key, sstate, k, avail
            ),
            strat,
        )
        for strat in strategies
    )
    steps_of = lambda state: _steps_per_round(cfg, state.client_xs.shape[1])

    def _algo_writeback(full_states, sel_or_mask, cand_states, refresh, scatter):
        """Masked per-client algorithm-state refresh (DESIGN.md §12): a
        client's state advances iff its update was kept (cohort member,
        delivered, unflagged, round above the survivors floor).

        ``scatter=True`` — cohort layout: ``cand_states`` lead with (k, ...)
        and land at ``sel_or_mask`` (the cohort ids); ``scatter=False`` —
        resident layout: ``cand_states`` match ``full_states`` and
        ``refresh`` selects rows in place."""

        def bmask(m, x):
            return m.reshape(m.shape + (1,) * (x.ndim - m.ndim))

        if scatter:
            sel = sel_or_mask
            old = jax.tree_util.tree_map(
                lambda s: jnp.take(s, sel, axis=0), full_states
            )
            kept = jax.tree_util.tree_map(
                lambda n, o: jnp.where(bmask(refresh, n), n, o), cand_states, old
            )
            return jax.tree_util.tree_map(
                lambda full, new: full.at[sel].set(new), full_states, kept
            )
        return jax.tree_util.tree_map(
            lambda n, o: jnp.where(bmask(refresh, n), n, o),
            cand_states, full_states,
        )

    def _single_device_body(state, k_batch, sel, draws=None):
        """Cohort gather + vmapped/mapped local updates on one device."""
        batches = make_client_batches(cfg, k_batch, state.client_xs, state.client_ys, sel)
        weights = jnp.take(state.client_sizes, sel)
        round_step = rounds_lib.build_client_parallel_round(
            batched_loss, cfg.lr, steps_of(state), grad_clip=cfg.grad_clip,
            sequential_clients=sequential_clients, update_transform=guard,
            algo=algo,
        )
        g = metrics_lib.gemd(
            state.client_label_dists, state.client_sizes, sel, state.global_label_dist
        )
        state_kw = {}
        if stateful:
            state_kw["client_states"] = jax.tree_util.tree_map(
                lambda s: jnp.take(s, sel, axis=0), state.algo_state
            )
        if guard is None:
            res = round_step(state.params, batches, weights, **state_kw)
            if stateful:
                params, mean_loss, cand_states = res
                refresh = jnp.ones(sel.shape, jnp.bool_)
                algo_state = _algo_writeback(
                    state.algo_state, sel, cand_states, refresh, scatter=True
                )
            else:
                params, mean_loss = res
                algo_state = None
            # refresh last-known losses for the selected clients
            sel_losses = loss_of(
                params, jnp.take(state.client_xs, sel, 0), jnp.take(state.client_ys, sel, 0)
            )
            losses = state.losses.at[sel].set(sel_losses)
            out = (params, mean_loss, losses, g)
            return out + (algo_state,) if stateful else out
        # fault masks gathered to the cohort layout (draws are (C,) rows)
        g_args = (
            () if draws is None else tuple(jnp.take(m, sel) for m in draws)
        )
        res = round_step(state.params, batches, weights, *g_args, **state_kw)
        if stateful:
            params, mean_loss, flagged, survivors, cand_states = res
        else:
            params, mean_loss, flagged, survivors = res
        c = state.losses.shape[0]
        flagged_c = jnp.zeros((c,), jnp.bool_).at[sel].set(flagged)
        delivered = (
            jnp.take(draws.delivered, sel) if draws is not None
            else jnp.ones(sel.shape, jnp.bool_)
        )
        # refresh only trusted participants, and only when the round's
        # aggregate will actually be kept (survivors floor)
        refresh = delivered & ~flagged & (survivors >= cfg.min_survivors)
        sel_losses = loss_of(
            params, jnp.take(state.client_xs, sel, 0), jnp.take(state.client_ys, sel, 0)
        )
        keep = jnp.take(state.losses, sel)
        losses = state.losses.at[sel].set(jnp.where(refresh, sel_losses, keep))
        out = (params, mean_loss, losses, g, flagged_c, survivors)
        if stateful:
            algo_state = _algo_writeback(
                state.algo_state, sel, cand_states, refresh, scatter=True
            )
            return out + (algo_state,)
        return out

    def _resident_batch_plans(state, k_batch, sel):
        """Jit-level per-resident batch *index plans*: every client adopts
        the batch key of its cohort slot, so a selected client sees
        bit-identical batches to the gathered single-device path.  The ONE
        construction shared by the synchronous (:func:`_sharded_body`) and
        bounded-staleness (:func:`_stale_sharded_body`) resident-layout
        bodies — the cross-path bit-identical-batches parity contract lives
        here, and only data slicing / SGD scans / the psum go inside the
        shard_map (fusing random-bit generation into the shard body
        miscompiles on jax 0.4.37: clients read other slots' draws)."""
        c = state.losses.shape[0]
        n_c = state.client_xs.shape[1]
        slot_full = jnp.argmax(sel[None, :] == jnp.arange(c)[:, None], axis=1)
        key_data = jax.random.key_data(jax.random.split(k_batch, k))
        client_keys = jax.random.wrap_key_data(key_data[slot_full])
        return batch_indices_from_keys(cfg, client_keys, n_c)  # (C, ...) | None

    def _sharded_body(state, k_batch, sel, draws=None):
        """shard_map core: in-place masked local updates + psum'd FedAvg.

        Random index plans come from :func:`_resident_batch_plans` (jit
        level); only data slicing, the local SGD scans, and the psum'd
        aggregation live inside the shard_map.  With the guard on, the fault
        masks (jit-level draws, resident layout) shard over the client axis
        like the index plans; validation/rejection happens inside the
        shard_map strictly before the single psum.
        """
        shard_round = rounds_lib.build_shard_cohort_round(
            batched_loss, cfg.lr, client_axis, grad_clip=cfg.grad_clip,
            sequential_clients=sequential_clients, update_transform=guard,
            algo=algo,
        )
        ids = _resident_batch_plans(state, k_batch, sel)
        n_ids = 0 if ids is None else 1
        mask_args = () if draws is None else tuple(draws)
        # algo_state shards like the data fields (resident layout); the
        # masked write-back happens inside the shard body — per-device
        # state, never psum'd
        state_args = (state.algo_state,) if stateful else ()

        def local_body(sel, params, local_xs, local_ys, local_sizes,
                       local_losses, local_dists, global_dist, *rest):
            if stateful:
                local_states, rest = rest[0], rest[1:]
            else:
                local_states = None
            local_ids = rest[:n_ids]
            fmasks = rest[n_ids:]
            c_loc = local_xs.shape[0]
            gids = lax.axis_index(client_axis) * c_loc + jnp.arange(c_loc)
            mask = jnp.any(sel[None, :] == gids[:, None], axis=1)
            batches = batches_from_indices(
                cfg, local_ids[0] if local_ids else None, local_xs, local_ys
            )
            weights = local_sizes * mask
            # GEMD (eq. 15) partials ride the round's single psum: the cohort
            # label-mix numerator/denominator over this shard's residents
            w = weights.astype(jnp.float32)
            gemd_parts = ((w[:, None] * local_dists).sum(0), jnp.sum(w))
            if guard is None:
                res = shard_round(
                    params, batches, weights, extras=gemd_parts,
                    local_states=local_states,
                )
                if stateful:
                    params, _, mean_loss, (num, den), cand_states = res
                else:
                    params, _, mean_loss, (num, den) = res
                g = jnp.sum(jnp.abs(metrics_lib.safe_div(num, den) - global_dist))
                # loss refresh stays on the client's home shard (no scatter)
                fresh = loss_of(params, local_xs, local_ys)
                losses = jnp.where(mask, fresh, local_losses)
                if stateful:
                    new_states = _algo_writeback(
                        local_states, None, cand_states, mask, scatter=False
                    )
                    return params, mean_loss, losses, g, new_states
                return params, mean_loss, losses, g
            res = shard_round(
                params, batches, weights, extras=gemd_parts, guard_args=fmasks,
                local_states=local_states,
            )
            if stateful:
                (params, _, mean_loss, (num, den), flagged, survivors,
                 cand_states) = res
            else:
                params, _, mean_loss, (num, den), flagged, survivors = res
            g = jnp.sum(jnp.abs(metrics_lib.safe_div(num, den) - global_dist))
            delivered = fmasks[0] if fmasks else jnp.ones_like(mask)
            refresh = (
                mask & delivered & ~flagged
                & (survivors >= cfg.min_survivors)
            )
            fresh = loss_of(params, local_xs, local_ys)
            losses = jnp.where(refresh, fresh, local_losses)
            if stateful:
                new_states = _algo_writeback(
                    local_states, None, cand_states, refresh, scatter=False
                )
                return params, mean_loss, losses, g, flagged, survivors, new_states
            return params, mean_loss, losses, g, flagged, survivors

        lead = P(client_axis)
        id_args = () if ids is None else (ids,)
        out = (P(), P(), lead, P())
        if guard is not None:
            out = out + (lead, P())
        if stateful:
            out = out + (lead,)
        body = _checked_shard_map(
            local_body, mesh=mesh,
            in_specs=(P(), P(), lead, lead, lead, lead, lead, P())
            + (lead,) * len(state_args)
            + (lead,) * (len(id_args) + len(mask_args)),
            out_specs=out,
        )
        return body(
            sel, state.params, state.client_xs, state.client_ys,
            state.client_sizes, state.losses, state.client_label_dists,
            state.global_label_dist, *(state_args + id_args + mask_args),
        )

    def _slot_sharded_body(state, k_batch, sel, draws=None):
        """Capacity-slot shard_map core: per-shard top-``cap`` slot gather.

        The slot table is computed at the jit level from the replicated
        cohort (``sel``): for each shard, a stable argsort over the resident
        cohort mask packs selected residents (ascending local position)
        first, padded with unselected residents up to ``cap`` — padding
        slots carry weight 0 and behave exactly like resident mode's
        zero-weighted clients, only there are ``cap`` of them instead of
        ``C_loc``.  Batch-index plans are generated sized to slots (D·cap
        keyed rows, each slot adopting its client's cohort-position key, so
        selected clients see bit-identical batches to the other paths) and
        shard over the client axis alongside the slot positions.  Inside the
        shard: slot-gather data, build slot batches, ``cap`` local SGD
        scans, the same single psum (FedAvg/loss/GEMD partials), and the
        loss refresh runs over slots only before scattering home.
        """
        c = state.losses.shape[0]
        n_c = state.client_xs.shape[1]
        n_shards = mesh.shape[client_axis]
        c_loc = c // n_shards
        cap = min(c_loc, cfg.cohort_cap)
        shard_round = rounds_lib.build_shard_cohort_round(
            batched_loss, cfg.lr, client_axis, grad_clip=cfg.grad_clip,
            sequential_clients=sequential_clients, cap=cap,
            update_transform=guard, algo=algo,
        )
        in_cohort = jnp.any(
            sel[None, :] == jnp.arange(c)[:, None], axis=1
        ).reshape(n_shards, c_loc)
        # (D, cap) local resident positions: selected-first, stable order
        slot_pos = jnp.argsort(~in_cohort, axis=1, stable=True)[:, :cap]
        slot_gid = slot_pos + jnp.arange(n_shards)[:, None] * c_loc
        slot_cohort = jnp.argmax(
            sel[None, None, :] == slot_gid[..., None], axis=-1
        )  # (D, cap) cohort position (0 for weight-0 padding slots)
        key_data = jax.random.key_data(jax.random.split(k_batch, k))
        slot_keys = jax.random.wrap_key_data(key_data[slot_cohort.reshape(-1)])
        ids = batch_indices_from_keys(cfg, slot_keys, n_c)  # (D*cap, ...) | None
        flat_pos = slot_pos.reshape(-1)  # (D*cap,)
        n_ids = 0 if ids is None else 1
        # fault masks gathered to the slot layout at the jit level (the
        # draws are (C,) resident rows; slots shard like the index plans)
        mask_args = (
            () if draws is None
            else tuple(jnp.take(m, slot_gid.reshape(-1)) for m in draws)
        )
        # resident-layout state rides into the shard body; the slot round
        # gathers it by slot_index and scatters the trained slots back
        state_args = (state.algo_state,) if stateful else ()

        def local_body(sel, slot_index, params, local_xs, local_ys,
                       local_sizes, local_losses, local_dists, global_dist,
                       *rest):
            if stateful:
                local_states, rest = rest[0], rest[1:]
            else:
                local_states = None
            slot_ids = rest[:n_ids]
            fmasks = rest[n_ids:]
            c_loc_ = local_xs.shape[0]
            gids = lax.axis_index(client_axis) * c_loc_ + jnp.arange(c_loc_)
            mask = jnp.any(sel[None, :] == gids[:, None], axis=1)
            weights = local_sizes * mask
            slot_xs = jnp.take(local_xs, slot_index, axis=0)
            slot_ys = jnp.take(local_ys, slot_index, axis=0)
            batches = batches_from_indices(
                cfg, slot_ids[0] if slot_ids else None, slot_xs, slot_ys
            )
            # GEMD (eq. 15) partials are unchanged from resident mode (the
            # resident-layout mask is already O(C_loc) trivia) and ride the
            # round's single psum
            w = weights.astype(jnp.float32)
            gemd_parts = ((w[:, None] * local_dists).sum(0), jnp.sum(w))
            if guard is None:
                res = shard_round(
                    params, batches, weights, slot_index, extras=gemd_parts,
                    local_states=local_states,
                )
                if stateful:
                    params, _, mean_loss, (num, den), cand_states = res
                else:
                    params, _, mean_loss, (num, den) = res
                g = jnp.sum(jnp.abs(metrics_lib.safe_div(num, den) - global_dist))
                # loss refresh over slots only — the cap-not-C_loc saving
                # applies to the refresh pass too; unselected residents keep
                # their last known loss (scatter of distinct local positions,
                # no collisions)
                fresh = loss_of(params, slot_xs, slot_ys)
                keep = jnp.take(local_losses, slot_index)
                slot_mask = jnp.take(mask, slot_index)
                losses = local_losses.at[slot_index].set(
                    jnp.where(slot_mask, fresh, keep)
                )
                if stateful:
                    new_states = _algo_writeback(
                        local_states, None, cand_states, mask, scatter=False
                    )
                    return params, mean_loss, losses, g, new_states
                return params, mean_loss, losses, g
            res = shard_round(
                params, batches, weights, slot_index, extras=gemd_parts,
                guard_args=fmasks, local_states=local_states,
            )
            if stateful:
                (params, _, mean_loss, (num, den), flagged, survivors,
                 cand_states) = res
            else:
                params, _, mean_loss, (num, den), flagged, survivors = res
            g = jnp.sum(jnp.abs(metrics_lib.safe_div(num, den) - global_dist))
            # fmasks are already slot-layout (gathered by slot_gid above)
            slot_delivered = (
                fmasks[0] if fmasks
                else jnp.ones(slot_index.shape, jnp.bool_)
            )
            slot_flagged = jnp.take(flagged, slot_index)
            slot_mask = jnp.take(mask, slot_index)
            refresh = (
                slot_mask & slot_delivered & ~slot_flagged
                & (survivors >= cfg.min_survivors)
            )
            fresh = loss_of(params, slot_xs, slot_ys)
            keep = jnp.take(local_losses, slot_index)
            losses = local_losses.at[slot_index].set(
                jnp.where(refresh, fresh, keep)
            )
            if stateful:
                # refresh scattered home to resident layout: residents no
                # slot covered stay un-refreshed by construction
                r_res = (
                    jnp.zeros(mask.shape, jnp.bool_)
                    .at[slot_index]
                    .set(refresh)
                )
                new_states = _algo_writeback(
                    local_states, None, cand_states, r_res, scatter=False
                )
                return params, mean_loss, losses, g, flagged, survivors, new_states
            return params, mean_loss, losses, g, flagged, survivors

        lead = P(client_axis)
        id_args = () if ids is None else (ids,)
        out = (P(), P(), lead, P())
        if guard is not None:
            out = out + (lead, P())
        if stateful:
            out = out + (lead,)
        body = _checked_shard_map(
            local_body, mesh=mesh,
            in_specs=(P(), lead, P(), lead, lead, lead, lead, lead, P())
            + (lead,) * len(state_args)
            + (lead,) * (len(id_args) + len(mask_args)),
            out_specs=out,
        )
        return body(
            sel, flat_pos, state.params, state.client_xs, state.client_ys,
            state.client_sizes, state.losses, state.client_label_dists,
            state.global_label_dist, *(state_args + id_args + mask_args),
        )

    def _stale_sharded_body(state, k_batch, sel, lat, draws=None):
        """Bounded-staleness shard_map core (DESIGN.md §9).

        Same residents, masks, batch plans, and single psum as
        :func:`_sharded_body`; the difference is each shard's *base* params
        come from the ring buffer at its staleness ``s_d`` (params of round
        ``t − s_d``), and its eq.-(6) partials are scaled by λ(s_d).  All
        staleness bookkeeping — deadline misses from the scenario's
        per-client latency draw, counter dynamics, decay weights, ring
        slots, the simulated round wall clock — is computed at the jit
        level on tiny replicated arrays; only the ring read, the SGD scans,
        and the psum live inside the shard_map.  With ``staleness_bound=0``
        every slow shard is forced to sync, λ ≡ 1, and the ring read
        returns the current params: bit-identical to the synchronous round.
        """
        bound = cfg.staleness_bound
        c = state.losses.shape[0]
        n_shards = mesh.shape[client_axis]
        c_loc = c // n_shards
        t_prev = state.round  # rounds completed; ring slot t_prev holds θ_t
        shard_round = rounds_lib.build_stale_shard_cohort_round(
            batched_loss, cfg.lr, client_axis, grad_clip=cfg.grad_clip,
            sequential_clients=sequential_clients, update_transform=guard,
            algo=algo,
        )
        in_cohort = jnp.any(sel[None, :] == jnp.arange(c)[:, None], axis=1)
        # a shard's round latency is its slowest selected resident (shards
        # with no cohort member are instant and re-sync for free)
        shard_lat = (
            jnp.where(in_cohort, lat, 0.0).reshape(n_shards, c_loc).max(axis=1)
        )
        slow = shard_lat > scen.deadline
        # the POST-update counters price this round's contribution: a shard
        # that misses the deadline delivers work based on pre-miss params
        # (read slot t − s_d with s_d including this round's miss), so a
        # deadline-capped round never aggregates information the simulated
        # clock says arrived after it closed.  Forced shards block the round
        # (full latency) and deliver fresh work with a reset counter.
        new_s, forced = staleness_lib.staleness_step(
            state.shard_staleness, slow, bound
        )
        lam = staleness_lib.decay_weights(
            new_s, cfg.staleness_decay, cfg.staleness_alpha
        )
        read_slot = staleness_lib.read_slots(t_prev, new_s, bound)
        sim_time = staleness_lib.round_sim_time(
            shard_lat, slow, forced, scen.deadline
        )
        ids = _resident_batch_plans(state, k_batch, sel)
        n_ids = 0 if ids is None else 1
        mask_args = () if draws is None else tuple(draws)
        # algo_state shards like the data fields; the drift-correction
        # anchor is automatically the shard's stale ring read (the inner
        # round anchors to its entry base params)
        state_args = (state.algo_state,) if stateful else ()

        def local_body(sel, lam_d, slot_d, hist, local_xs, local_ys,
                       local_sizes, local_losses, local_dists, global_dist,
                       *rest):
            if stateful:
                local_states, rest = rest[0], rest[1:]
            else:
                local_states = None
            local_ids = rest[:n_ids]
            fmasks = rest[n_ids:]
            c_loc_ = local_xs.shape[0]
            gids = lax.axis_index(client_axis) * c_loc_ + jnp.arange(c_loc_)
            mask = jnp.any(sel[None, :] == gids[:, None], axis=1)
            batches = batches_from_indices(
                cfg, local_ids[0] if local_ids else None, local_xs, local_ys
            )
            weights = local_sizes * mask
            # GEMD partials stay λ-free: the metric describes the cohort's
            # label mix, not the staleness-decayed aggregation weights
            w = weights.astype(jnp.float32)
            gemd_parts = ((w[:, None] * local_dists).sum(0), jnp.sum(w))
            if guard is None:
                res = shard_round(
                    hist, slot_d[0], lam_d[0], batches, weights,
                    extras=gemd_parts, local_states=local_states,
                )
                if stateful:
                    params, _, mean_loss, (num, den), cand_states = res
                else:
                    params, _, mean_loss, (num, den) = res
                g = jnp.sum(jnp.abs(metrics_lib.safe_div(num, den) - global_dist))
                # the refresh measures the NEW aggregate on each home shard —
                # fresh params, even when the contribution was stale
                fresh = loss_of(params, local_xs, local_ys)
                losses = jnp.where(mask, fresh, local_losses)
                if stateful:
                    new_states = _algo_writeback(
                        local_states, None, cand_states, mask, scatter=False
                    )
                    return params, mean_loss, losses, g, new_states
                return params, mean_loss, losses, g
            res = shard_round(
                hist, slot_d[0], lam_d[0], batches, weights,
                extras=gemd_parts, guard_args=fmasks,
                local_states=local_states,
            )
            if stateful:
                (params, _, mean_loss, (num, den), flagged, survivors,
                 cand_states) = res
            else:
                params, _, mean_loss, (num, den), flagged, survivors = res
            g = jnp.sum(jnp.abs(metrics_lib.safe_div(num, den) - global_dist))
            delivered = fmasks[0] if fmasks else jnp.ones_like(mask)
            refresh = (
                mask & delivered & ~flagged
                & (survivors >= cfg.min_survivors)
            )
            fresh = loss_of(params, local_xs, local_ys)
            losses = jnp.where(refresh, fresh, local_losses)
            if stateful:
                new_states = _algo_writeback(
                    local_states, None, cand_states, refresh, scatter=False
                )
                return params, mean_loss, losses, g, flagged, survivors, new_states
            return params, mean_loss, losses, g, flagged, survivors

        lead = P(client_axis)
        id_args = () if ids is None else (ids,)
        out = (P(), P(), lead, P())
        if guard is not None:
            out = out + (lead, P())
        if stateful:
            out = out + (lead,)
        body = _checked_shard_map(
            local_body, mesh=mesh,
            in_specs=(P(), lead, lead, P(), lead, lead, lead, lead, lead, P())
            + (lead,) * len(state_args)
            + (lead,) * (len(id_args) + len(mask_args)),
            out_specs=out,
        )
        res = body(
            sel, lam, read_slot, state.param_hist, state.client_xs,
            state.client_ys, state.client_sizes, state.losses,
            state.client_label_dists, state.global_label_dist,
            *(state_args + id_args + mask_args),
        )
        new_algo_state = None
        if stateful:
            res, new_algo_state = res[:-1], res[-1]
        if guard is None:
            params, mean_loss, losses, g = res
            flagged = survivors = None
        else:
            params, mean_loss, losses, g, flagged, survivors = res
            # apply the survivors floor BEFORE the ring write: the ring must
            # record the params the round actually kept, or a resumed /
            # stale read would replay a discarded aggregate
            ok_round = survivors >= cfg.min_survivors
            params = jax.tree_util.tree_map(
                lambda a, o: jnp.where(ok_round, a, o).astype(o.dtype),
                params, state.params,
            )
        hist = staleness_lib.update_param_hist(
            state.param_hist, params, t_prev + 1, bound
        )
        if guard is None:
            out = (params, mean_loss, losses, g, hist, new_s, sim_time)
        else:
            out = (params, mean_loss, losses, g, hist, new_s, sim_time,
                   flagged, survivors)
        return out + (new_algo_state,) if stateful else out

    def round_fn(state: ServerState, _=None):
        t = state.round + 1
        key, k_sel, k_batch = jax.random.split(state.key, 3)
        # the scenario's environment stream branches off the carried key so
        # the selection/batch streams are untouched: a latency-only scenario
        # leaves cohorts and batches bit-identical to a scenario-free run
        lat = avail = None
        if scen is not None:
            k_env = jax.random.fold_in(state.key, _ENV_SALT)
            lat = scen.latency(jax.random.fold_in(k_env, 0), state.num_clients)
            if avail_aware:
                avail = scen.availability(
                    jax.random.fold_in(k_env, 1), t, state.num_clients
                )
        # fault draws branch off the carried key the same way (FAULT_SALT):
        # jit-level tiny boolean rows, generated OUTSIDE the shard_map (the
        # batch-plan rule) and sharded in — faults=None skips all of this,
        # leaving every key stream bit-identical to the pre-fault engine
        draws = None
        if fault_model is not None:
            n_sh = 1 if mesh is None else mesh.shape[client_axis]
            draws = faults_lib.draw_round_faults(
                jax.random.fold_in(state.key, faults_lib.FAULT_SALT),
                fault_model, cfg.num_clients, n_sh, lemons,
            )
        sel_args = (k_sel, state.selection_state())
        if route_avail:
            # quarantined clients are "unavailable" to selection — the same
            # availability hook the scenario uses, masks AND-composed
            sel_mask = avail
            if guard_on:
                q_ok = state.quarantine <= 0
                sel_mask = q_ok if sel_mask is None else (sel_mask & q_ok)
            sel_args = sel_args + (sel_mask,)
        if len(branches) == 1:
            sel = branches[0](*sel_args)
        else:
            sel = lax.switch(state.strategy_index, branches, *sel_args)
        hist = new_s = sim_time = None
        flagged_c = survivors = None
        new_algo = None
        if mesh is None:
            res = _single_device_body(state, k_batch, sel, draws=draws)
        elif cfg.staleness_bound is not None:
            res = _stale_sharded_body(state, k_batch, sel, lat, draws=draws)
        elif cfg.cohort_cap is not None:
            res = _slot_sharded_body(state, k_batch, sel, draws=draws)
        else:
            res = _sharded_body(state, k_batch, sel, draws=draws)
        if stateful:
            # every body appends the already-written-back algo state last
            res, new_algo = res[:-1], res[-1]
        if mesh is not None and cfg.staleness_bound is not None:
            if guard is None:
                params, mean_loss, losses, g, hist, new_s, sim_time = res
            else:
                (params, mean_loss, losses, g, hist, new_s, sim_time,
                 flagged_c, survivors) = res
        elif guard is None:
            params, mean_loss, losses, g = res
        else:
            params, mean_loss, losses, g, flagged_c, survivors = res
        if guard is not None:
            # graceful degradation: a round below the survivors floor keeps
            # the old params (identity round, recorded in the metrics).  The
            # stale body already floored before its ring write; re-applying
            # here is an exact no-op for it.
            ok_round = survivors >= cfg.min_survivors
            params = jax.tree_util.tree_map(
                lambda a, o: jnp.where(ok_round, a, o).astype(o.dtype),
                params, state.params,
            )
        if scen is not None and sim_time is None:
            # synchronous barrier under the scenario: the round closes at
            # the slowest selected client
            c = state.losses.shape[0]
            in_cohort = jnp.any(sel[None, :] == jnp.arange(c)[:, None], axis=1)
            sim_time = jnp.max(jnp.where(in_cohort, lat, 0.0))

        if accuracy_fn is None:
            acc = jnp.float32(jnp.nan)
        else:
            if eval_data is not None:
                exs, eys = eval_data
            else:
                exs = state.client_xs.reshape((-1,) + state.client_xs.shape[2:])
                eys = state.client_ys.reshape(-1)
            acc = lax.cond(
                t % cfg.eval_every == 0,
                lambda p: jnp.asarray(accuracy_fn(p, exs, eys), jnp.float32),
                lambda p: jnp.float32(jnp.nan),
                params,
            )

        updates = dict(params=params, key=key, round=t, losses=losses)
        if hist is not None:
            updates.update(param_hist=hist, shard_staleness=new_s)
        if stateful:
            updates["algo_state"] = new_algo
        if guard_on:
            # quarantine dynamics: freshly flagged clients (re)start the
            # cooldown, everyone else's counter ticks down toward release
            q = jnp.maximum(state.quarantine - 1, 0)
            q = jnp.where(
                flagged_c, jnp.int32(cfg.quarantine_rounds), q
            ).astype(jnp.int32)
            updates["quarantine"] = q
        new_state = dataclasses.replace(state, **updates)
        out = {
            "round": t,
            "acc": acc,
            "gemd": jnp.asarray(g, jnp.float32),
            "loss": jnp.asarray(mean_loss, jnp.float32),
            "selected": sel,
        }
        if scen is not None:
            out["sim_time"] = jnp.asarray(sim_time, jnp.float32)
        if avail_aware:
            out["avail"] = avail
        if cfg.staleness_bound is not None:
            # mean lag the round's contributions were computed at
            out["staleness"] = jnp.mean(new_s.astype(jnp.float32))
        if guard_on:
            out["survivors"] = jnp.asarray(survivors, jnp.int32)
            out["identity_round"] = jnp.asarray(
                survivors < cfg.min_survivors, jnp.int32
            )
            out["flagged"] = jnp.sum(flagged_c.astype(jnp.int32))
            out["quarantined"] = jnp.sum((q > 0).astype(jnp.int32))
        if cfg.telemetry:
            # telemetry only ADDS output leaves — computed entirely from
            # values the round already holds, so the carried state and every
            # existing metric stay bit-identical to telemetry=False
            out["telemetry"] = obs_telemetry_lib.round_telemetry(
                cfg, state, t=t, avail=avail, new_s=new_s,
                flagged=flagged_c, survivors=survivors,
                quarantine=(q if guard_on else None),
            )
        return new_state, out

    return round_fn


# ------------------------------------------------------------------ runners

# Program-cache contract (identity keying): compiled scan/vmap executables
# are cached ON the round_fn object itself (``round_fn.__engine_programs__``),
# keyed by (kind, num_rounds).  Reuse of the compiled program therefore
# requires passing the SAME round_fn object — callers that rebuild a closure
# per call recompile, but the stale executables die with the closure instead
# of accumulating in a global table pinning their closed-over arrays (eval
# data!) alive.  ``FLTrainer`` memoises its round_fn per instance (plus a
# semantics-keyed cross-trainer cache) to hit this cache.  Callables that
# reject attributes (e.g. functools.partial) fall back to a small bounded
# FIFO table.

_FALLBACK_PROGRAMS: Dict = {}
_FALLBACK_LIMIT = 8


def _programs(round_fn) -> Dict:
    cache = getattr(round_fn, "__engine_programs__", None)
    if cache is None:
        cache = {}
        try:
            round_fn.__engine_programs__ = cache
        except AttributeError:
            if round_fn not in _FALLBACK_PROGRAMS:
                while len(_FALLBACK_PROGRAMS) >= _FALLBACK_LIMIT:
                    _FALLBACK_PROGRAMS.pop(next(iter(_FALLBACK_PROGRAMS)))
                _FALLBACK_PROGRAMS[round_fn] = cache
            return _FALLBACK_PROGRAMS[round_fn]
    return cache


def _scanned(round_fn, num_rounds: int):
    cache = _programs(round_fn)
    key = ("scan", num_rounds)
    if key not in cache:
        cache[key] = jax.jit(
            lambda state: lax.scan(round_fn, state, None, length=num_rounds)
        )
    return cache[key]


def run_scanned(
    round_fn, state: ServerState, num_rounds: int,
    mesh: Optional[jax.sharding.Mesh] = None,
    client_axis: str = CLIENT_AXIS,
    sink: Optional["obs_sink_lib.TelemetrySink"] = None,
) -> Tuple[ServerState, Dict[str, jax.Array]]:
    """Run ``num_rounds`` rounds as ONE compiled ``lax.scan`` program.

    Returns the final state and the per-round metrics stacked on a leading
    ``(num_rounds,)`` axis.  Re-invocations with the same ``round_fn`` object
    and round count reuse the compiled executable (see the program-cache
    contract above).

    ``mesh`` lays the state out with :func:`shard_server_state` before the
    scan (idempotent if already sharded); pass the mesh the ``round_fn`` was
    built with — single-device round_fns must be run without one.  Slot-capped
    round_fns (``cfg.cohort_cap``, DESIGN.md §8) run through this exact path:
    the state layout is identical (slots are transient inside the round), so
    no extra argument is needed here.

    ``sink`` (DESIGN.md §14) drains the segment's stacked outputs to JSONL
    *after* the compiled scan returns — the chunk-boundary drain rule: the
    host only ever observes scan outputs, never injects callbacks into the
    scan body, so a sink can never change the compiled program.
    """
    if mesh is not None:
        state = shard_server_state(state, mesh, client_axis)
    with obs_tracing_lib.annotate(f"fl.scan_chunk[{num_rounds}]"):
        state, outputs = _scanned(round_fn, num_rounds)(state)
    if sink is not None and num_rounds:
        obs_sink_lib.drain_fl_outputs(sink, outputs)
    return state, outputs


def _vmapped(round_fn, num_rounds: int):
    cache = _programs(round_fn)
    key = ("vmap", num_rounds)
    if key not in cache:
        cache[key] = jax.jit(
            jax.vmap(lambda state: lax.scan(round_fn, state, None, length=num_rounds))
        )
    return cache[key]


def run_many(
    round_fn, stacked_state: ServerState, num_rounds: int,
    mesh: Optional[jax.sharding.Mesh] = None,
    client_axis: str = CLIENT_AXIS,
) -> Tuple[ServerState, Dict[str, jax.Array]]:
    """Batched simulation: vmap the scanned run over stacked states.

    ``stacked_state`` is a :class:`ServerState` whose every leaf carries a
    leading batch axis (see :func:`stack_states`) — e.g. S seeds × K
    strategies flattened to one axis.  One XLA program executes the whole
    grid; outputs keep the ``(batch, num_rounds, ...)`` layout.  The k-DPP
    spectral caches ride in the stacked state (hoisted out of the vmapped
    round at :func:`init_server_state` time), so no branch of the grid pays
    an in-round ``eigh``.

    With ``mesh``, every grid point's client axis (axis 1 of the stacked
    client fields) lays out over the mesh — the batch axis stays replicated,
    so the D-way cohort parallelism multiplies the grid parallelism.
    Slot-capped round_fns (``cfg.cohort_cap``) compose unchanged: the cap
    applies per grid point inside the vmapped round.
    """
    if mesh is not None:
        stacked_state = shard_server_state(
            stacked_state, mesh, client_axis, batch_dims=1
        )
    return _vmapped(round_fn, num_rounds)(stacked_state)


# -------------------------------------------------------------- crash-resume


def save_server_state(ckpt_dir: str, state: ServerState) -> str:
    """Snapshot the FULL :class:`ServerState` (params, PRNG key, ring
    buffer, staleness counters, spectral cache, candidate set, quarantine
    state — every pytree leaf) under ``<ckpt_dir>/step_<round>/``.

    The typed PRNG key is stored as its raw ``key_data`` (npz can't hold
    extension dtypes); :func:`restore_server_state` re-wraps it.  Sharded
    states gather transparently through ``np.asarray``.
    """
    step = int(jax.device_get(state.round))
    host = dataclasses.replace(state, key=jax.random.key_data(state.key))
    return checkpoint_lib.save(ckpt_dir, step, host)


def restore_server_state(
    ckpt_dir: str, template: ServerState, step: Optional[int] = None
) -> ServerState:
    """Load a :func:`save_server_state` snapshot against a template state
    (e.g. the fresh ``init_server_state`` of the same config).

    Validation (leaf count / shapes / dtypes vs ``tree.json``) happens in
    ``repro.checkpoint.restore`` — a snapshot from a different config raises
    instead of unflattening garbage.  The returned state continues
    **bit-identically**: every carried array, including the PRNG key chain,
    is exactly the value the snapshotting run held after round
    ``state.round``.
    """
    t_host = dataclasses.replace(template, key=jax.random.key_data(template.key))
    restored = checkpoint_lib.restore(ckpt_dir, t_host, step=step)
    key = jax.random.wrap_key_data(jnp.asarray(restored.key))
    return dataclasses.replace(restored, key=key)


def run_checkpointed(
    round_fn, state: ServerState, num_rounds: int,
    ckpt_dir: Optional[str] = None,
    ckpt_every: Optional[int] = None,
    mesh: Optional[jax.sharding.Mesh] = None,
    client_axis: str = CLIENT_AXIS,
    sink: Optional["obs_sink_lib.TelemetrySink"] = None,
) -> Tuple[ServerState, Dict[str, jax.Array]]:
    """:func:`run_scanned` with periodic :class:`ServerState` snapshots.

    Runs the scan in ``ckpt_every``-round segments, snapshotting the full
    state after each (DESIGN.md §11) — the per-round computation inside each
    segment is the same compiled ``round_fn`` body, so segmenting changes
    nothing numerically, and a crashed run restored from the latest
    ``step_*`` snapshot (:func:`restore_server_state`) continues
    bit-identically (the resume-parity contract: run N ≡ run n → restore →
    run N−n).  With ``ckpt_dir``/``ckpt_every`` unset this IS
    :func:`run_scanned`.
    """
    if ckpt_dir is None or not ckpt_every:
        return run_scanned(
            round_fn, state, num_rounds, mesh=mesh, client_axis=client_axis,
            sink=sink,
        )
    done = 0
    outs: List[Dict[str, Any]] = []
    while done < num_rounds:
        n = min(ckpt_every, num_rounds - done)
        state, seg = run_scanned(
            round_fn, state, n, mesh=mesh, client_axis=client_axis, sink=sink
        )
        # tree_map (not a dict comprehension): the telemetry subtree is a
        # Telemetry pytree, not a bare array
        outs.append(jax.tree_util.tree_map(np.asarray, seg))
        save_server_state(ckpt_dir, state)
        if sink is not None:
            sink.emit("fl_checkpoint", round=int(jax.device_get(state.round)))
        done += n
    if not outs:
        _, empty = run_scanned(
            round_fn, state, 0, mesh=mesh, client_axis=client_axis
        )
        return state, empty
    merged = jax.tree_util.tree_map(
        lambda *xs: np.concatenate(xs, axis=0), *outs
    )
    return state, merged


def stack_states(states: Sequence[ServerState]) -> ServerState:
    """Stack per-run states leaf-wise onto a leading batch axis."""
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *states)


def unstack_outputs(outputs: Dict[str, jax.Array]) -> List[Dict[str, np.ndarray]]:
    """Split ``run_many`` outputs back into one per-run metrics dict each
    (tree-aware: the optional telemetry subtree splits along for the ride).
    """
    outs = jax.tree_util.tree_map(np.asarray, outputs)
    n = jax.tree_util.tree_leaves(outs)[0].shape[0]
    return [jax.tree_util.tree_map(lambda v: v[i], outs) for i in range(n)]


# -------------------------------------------------------------- state build

# ServerState fields carrying one row per client: these shard over the mesh
# client axis; everything else (params, kernel, spectral cache, PRNG key,
# counters) replicates.  The kernel stays replicated on purpose — selection
# needs the full Gram matrix and stays bit-identical across devices.  The
# staleness fields (DESIGN.md §9) also replicate: every device needs the
# full param ring buffer (any shard may read any slot), and the (D,)
# counters are trivia the stale shard_map re-slices per shard.
CLIENT_SHARDED_FIELDS = (
    "losses",
    "profiles",
    "client_xs",
    "client_ys",
    "client_sizes",
    "client_label_dists",
    "algo_state",
)


def shard_server_state(
    state: ServerState,
    mesh: jax.sharding.Mesh,
    client_axis: str = CLIENT_AXIS,
    batch_dims: int = 0,
) -> ServerState:
    """Lay a :class:`ServerState` out over ``mesh``'s client axis.

    Per-client fields (:data:`CLIENT_SHARDED_FIELDS`) get
    ``NamedSharding(mesh, P(clients, ...))`` on their client dimension
    (dimension ``batch_dims`` — pass ``batch_dims=1`` for :func:`stack_states`
    batches); every other field is replicated.  Idempotent: re-sharding an
    already-sharded state is a no-op device_put.  The layout is the same with
    or without ``cfg.cohort_cap``: capacity slots are a transient in-round
    compaction, never part of the persistent state.
    """
    n_shards = mesh.shape[client_axis]
    c = state.losses.shape[batch_dims]
    if c % n_shards:
        raise ValueError(
            f"num_clients={c} not divisible by mesh axis "
            f"{client_axis!r}={n_shards}"
        )
    replicated = NamedSharding(mesh, P())

    def rep(tree):
        return jax.tree_util.tree_map(lambda x: jax.device_put(x, replicated), tree)

    def lead(x):
        spec = client_axis_spec(x.ndim, client_axis, batch_dims=batch_dims)
        return jax.device_put(x, NamedSharding(mesh, spec))

    # tree_map handles pytree-valued fields (algo_state) and Nones alike
    updates = {
        f: jax.tree_util.tree_map(lead, getattr(state, f))
        for f in CLIENT_SHARDED_FIELDS
    }
    for f in dataclasses.fields(state):
        if f.name not in updates:
            updates[f.name] = rep(getattr(state, f.name))
    return ServerState(**updates)


# ------------------------------------------------------------------- funnel

# fold_in salt branching the funnel's stage-1 environment stream (predicted
# latency / availability at the segment boundary) off the caller's key
# WITHOUT consuming a split — the per-round selection/batch key streams stay
# bit-identical funnel-or-not, which the Q=C parity tests assert.
_FUNNEL_SALT = 0xF0A11E17


def candidate_profile_block(
    profiles: jax.Array,
    candidates: jax.Array,
    mesh: Optional[jax.sharding.Mesh] = None,
    client_axis: str = CLIENT_AXIS,
) -> jax.Array:
    """Gather the Q candidate profile rows (Q, F) — shard-locally on a mesh.

    Without a mesh this is one ``take``.  With one, ``profiles`` is laid out
    over the client axis (:data:`CLIENT_SHARDED_FIELDS`), so each shard
    contributes exactly the candidate rows it owns — non-resident candidate
    slots are zero-filled — and ONE ``psum`` assembles the replicated (Q, F)
    block.  That psum is the funnel's only collective: ``Q·F`` floats cross
    the interconnect, never anything C-sized, and adding the other shards'
    exact zeros leaves the owned rows bit-identical to an unsharded gather
    (the mesh Q=C parity contract).
    """
    cand = jnp.asarray(candidates, jnp.int32)
    profiles = jnp.asarray(profiles)
    if mesh is None:
        return jnp.take(profiles, cand, axis=0)

    def gather(local_f, ids):
        c_loc = local_f.shape[0]
        pos = ids - lax.axis_index(client_axis) * c_loc
        owned = (pos >= 0) & (pos < c_loc)
        rows = jnp.take(local_f, jnp.clip(pos, 0, c_loc - 1), axis=0)
        rows = jnp.where(owned[:, None], rows, jnp.zeros((), local_f.dtype))
        return lax.psum(rows, client_axis)

    body = _checked_shard_map(
        gather, mesh=mesh, in_specs=(P(client_axis), P()), out_specs=P()
    )
    return body(profiles, cand)


def funnel_fields(
    cfg: FLConfig,
    key: jax.Array,
    profiles: jax.Array,
    losses: jax.Array,
    strategy: Optional[selection_lib.SelectionStrategy] = None,
    mesh: Optional[jax.sharding.Mesh] = None,
    client_axis: str = CLIENT_AXIS,
    round_index: int = 0,
) -> Tuple[jax.Array, jax.Array, dpp_lib.KDPPSamplerState]:
    """Stage 1 of the two-stage funnel (DESIGN.md §10): the segment-boundary
    state pieces ``(candidates, kernel, eig_state)``.

    * **prefilter** — ``funnel_scores`` (running loss × scenario-predicted
      latency × availability; the scenario draws branch off ``key`` via
      ``_FUNNEL_SALT`` as a *prediction* of next-round conditions) and one
      fused ``top_k`` pick Q ascending global ids;
    * **candidate Gram** — the (Q, F) profile block assembled shard-locally
      (:func:`candidate_profile_block`), then the eq.-(14) pipeline on the
      Q-block only (Pallas-fused when ``cfg.use_pallas_kernel``) — min-max
      normalisation runs over the candidate block, NOT a C×C submatrix;
    * **spectral cache** — the O(Q³) eigh + ESP table (or the identity
      placeholder for strategies that never draw from it), replacing the
      O(C³) decomposition entirely.

    Called by :func:`init_server_state` and at every reprofile boundary
    (``FLTrainer.run``) — never per round, so the cache stays valid for the
    whole segment.  Non-candidates never ship a profile row anywhere: the
    privacy note of DESIGN.md §10.
    """
    assert cfg.candidate_frac is not None
    q = cfg.candidate_count()
    c = losses.shape[0]
    lat = avail = None
    scen = (
        scenarios_lib.get_scenario(cfg.scenario) if cfg.scenario is not None
        else None
    )
    if scen is not None:
        k_env = jax.random.fold_in(key, _FUNNEL_SALT)
        lat = scen.latency(jax.random.fold_in(k_env, 0), c)
        if scen.availability is not None:
            avail = scen.availability(
                jax.random.fold_in(k_env, 1), round_index, c
            )
    scores = selection_lib.funnel_scores(losses, avail=avail, latency=lat)
    candidates = selection_lib.funnel_candidates(scores, q)
    fq = candidate_profile_block(
        profiles, candidates, mesh=mesh, client_axis=client_axis
    )
    if cfg.use_pallas_kernel:
        from repro.kernels.gram import ops as gram_ops

        kernel = gram_ops.candidate_kernel_from_profiles(fq)
    else:
        kernel = similarity_lib.kernel_from_profiles(fq, use_kernel=False)
    if strategy is None or getattr(strategy, "uses_spectral_cache", False):
        eig_state = dpp_lib.kdpp_sampler_state(kernel, cfg.clients_per_round)
    else:
        eig_state = dpp_lib.identity_sampler_state(q, cfg.clients_per_round)
    return candidates, kernel, eig_state


def init_server_state(
    cfg: FLConfig,
    params: PyTree,
    loss_fn: Callable,
    feature_fn: Optional[Callable],
    client_xs,
    client_ys,
    strategy: Optional[selection_lib.SelectionStrategy] = None,
    strategy_index: int = 0,
    key: Optional[jax.Array] = None,
    profiles: Optional[jax.Array] = None,
    kernel: Optional[jax.Array] = None,
    losses: Optional[jax.Array] = None,
    cluster_labels: Optional[jax.Array] = None,
    eig_state: Optional[dpp_lib.KDPPSamplerState] = None,
    mesh: Optional[jax.sharding.Mesh] = None,
    client_axis: str = CLIENT_AXIS,
) -> ServerState:
    """Algorithm-1 initialisation as a :class:`ServerState`.

    Profiles every client once with the fresh global model (Alg. 1 lines
    2-5), builds the eq.-(14) kernel **and its k-DPP spectral cache** (the
    one O(C³) ``eigh`` — every scanned round then draws in O(k²·C)), takes
    one loss pass for the initial last-known losses, and — when ``strategy``
    is a :class:`~repro.core.selection.ClusterSelection` — runs the one-shot
    host ``fit`` so the per-round draw is pure.  Any precomputed piece can be
    passed in to skip recomputation.  ``mesh`` lays the result out with
    :func:`shard_server_state` for the sharded execution path.

    With ``cfg.candidate_frac`` set (DESIGN.md §10) the kernel, spectral
    cache, and cluster labels are built by :func:`funnel_fields` on the
    Q-candidate block instead — this path never materialises a C×C array,
    and passing a precomputed full-federation ``kernel``/``eig_state`` is a
    :class:`ValueError`.
    """
    client_xs = jnp.asarray(client_xs)
    client_ys = jnp.asarray(client_ys)
    c, n_c = client_xs.shape[0], client_xs.shape[1]
    if profiles is None:
        assert feature_fn is not None, "need feature_fn to compute profiles"
        profiles = profiles_lib.profile_all_clients(
            jax.jit(feature_fn), params, list(client_xs)
        )
    if losses is None:
        losses = jax.jit(jax.vmap(loss_fn, in_axes=(None, 0, 0)))(
            params, client_xs, client_ys
        )
    candidates = None
    if cfg.candidate_frac is not None:
        # Funnel init (DESIGN.md §10): losses come FIRST (they are the
        # stage-1 prefilter score), then every kernel-shaped piece lives on
        # the Q-block — this path never materialises a C×C array.
        if kernel is not None or eig_state is not None:
            raise ValueError(
                "candidate_frac is set: the kernel and spectral cache are "
                "funnel-owned (Q×Q, rebuilt with the candidates) — don't "
                "pass precomputed full-federation kernel/eig_state"
            )
        candidates, kernel, eig_state = funnel_fields(
            cfg,
            key if key is not None else jax.random.key(cfg.seed),
            profiles, losses, strategy=strategy,
            mesh=mesh, client_axis=client_axis,
        )
    if kernel is None:
        kernel = similarity_lib.kernel_from_profiles(
            profiles, use_kernel=cfg.use_pallas_kernel
        )
    if eig_state is None:
        # Pay the O(C³) decomposition only when the strategy's select_fn
        # actually draws from the cache; strategy=None (unknown — e.g. a
        # caller assembling a multi-strategy run_many grid) keeps the real
        # spectrum as the safe default.  The identity placeholder shares the
        # pytree layout, so lax.switch grids stay shape-stable either way.
        if strategy is None or getattr(strategy, "uses_spectral_cache", False):
            eig_state = dpp_lib.kdpp_sampler_state(kernel, cfg.clients_per_round)
        else:
            eig_state = dpp_lib.identity_sampler_state(c, cfg.clients_per_round)
    if cluster_labels is None:
        if isinstance(strategy, selection_lib.ClusterSelection):
            # funnel mode fits the clusters on the SAME fingerprints as the
            # unfunneled path, restricted to the candidate rows — with
            # candidates == arange(C) (Q=C) the labels are bit-identical
            idx = (
                range(c) if candidates is None
                else np.asarray(candidates).tolist()
            )
            gp = jnp.stack([
                profiles_lib.representative_gradient_profile(
                    loss_fn, params, client_xs[i], client_ys[i]
                )
                for i in idx
            ])
            cluster_labels = strategy.fit(gp, cfg.clients_per_round)
        else:
            n_lbl = c if candidates is None else candidates.shape[0]
            cluster_labels = jnp.zeros((n_lbl,), jnp.int32)
    label_dists = jnp.stack([
        metrics_lib.label_distribution(client_ys[i], cfg.num_classes)
        for i in range(c)
    ])
    global_dist = metrics_lib.label_distribution(
        client_ys.reshape(-1), cfg.num_classes
    )
    param_hist = shard_staleness = None
    if cfg.staleness_bound is not None:
        param_hist, shard_staleness = staleness_lib.init_staleness_fields(
            params, cfg.staleness_bound, mesh, client_axis
        )
    # quarantine counters only exist on guarded configs so the pytree (and
    # every compiled program keyed on it) is unchanged for fault-free runs
    quarantine = jnp.zeros((c,), jnp.int32) if cfg.guarded() else None
    # per-client algorithm state only exists for stateful algorithms
    # (DESIGN.md §12) — None keeps the pytree unchanged for fedavg/fedprox
    algo_state = local_algos_lib.init_client_states(
        cfg.local_algo_obj(), params, c
    )
    state = ServerState(
        params=params,
        key=key if key is not None else jax.random.key(cfg.seed),
        round=jnp.asarray(0, jnp.int32),
        losses=losses,
        kernel=kernel,
        profiles=profiles,
        eig_state=eig_state,
        cluster_labels=cluster_labels,
        client_xs=client_xs,
        client_ys=client_ys,
        client_sizes=jnp.full((c,), float(n_c)),
        client_label_dists=label_dists,
        global_label_dist=global_dist,
        strategy_index=jnp.asarray(strategy_index, jnp.int32),
        param_hist=param_hist,
        shard_staleness=shard_staleness,
        candidates=candidates,
        quarantine=quarantine,
        algo_state=algo_state,
    )
    if mesh is not None:
        state = shard_server_state(state, mesh, client_axis)
    return state


# ------------------------------------------------------------------ history


def history_from_outputs(
    outputs: Dict[str, jax.Array],
    eval_every: int,
    final_acc: Optional[float] = None,
) -> Dict[str, List]:
    """Stacked scan outputs -> the legacy FLTrainer history dict.

    Keeps the legacy recording protocol: one entry per round where
    ``t % eval_every == 0``, plus the final round.  ``final_acc`` fills the
    accuracy of a final round that is not an eval round (the scan only
    evaluates on the eval grid)."""
    rounds = np.asarray(outputs["round"]).astype(int)
    hist: Dict[str, List] = {"round": [], "acc": [], "gemd": [], "loss": []}
    if rounds.size == 0:
        # zero-round runs (e.g. a run_many grid scanned for 0 rounds) have
        # no history — not an IndexError on rounds[-1]
        return hist
    acc = np.asarray(outputs["acc"], np.float64)
    gemd = np.asarray(outputs["gemd"], np.float64)
    loss = np.asarray(outputs["loss"], np.float64)
    n = int(rounds[-1])
    for i, t in enumerate(rounds):
        t = int(t)
        if t % eval_every == 0 or t == n:
            a = acc[i]
            if np.isnan(a) and t == n and final_acc is not None:
                a = final_acc
            hist["round"].append(t)
            hist["acc"].append(float(a))
            hist["gemd"].append(float(gemd[i]))
            hist["loss"].append(float(loss[i]))
    return hist

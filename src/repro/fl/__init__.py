"""Federated runtime: local updates (eq. 3-5), aggregation (eq. 6), rounds,
the scan-compiled federation engine (DESIGN.md §7), and the bounded-staleness
subsystem + system-heterogeneity scenarios (DESIGN.md §9)."""

from repro.fl.engine import (
    ServerState,
    history_from_outputs,
    init_server_state,
    make_round_fn,
    run_many,
    run_scanned,
    stack_states,
    unstack_outputs,
)
from repro.fl.rounds import (
    build_client_parallel_round,
    build_fedsgd_step,
    build_server_opt_round,
    weighted_average,
)
from repro.fl.scenarios import SCENARIO_NAMES, Scenario, get_scenario
from repro.fl.staleness import (
    DECAY_FAMILIES,
    decay_weights,
    normalized_decay_weights,
)
from repro.fl.trainer import FLConfig, FLTrainer

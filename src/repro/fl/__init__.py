"""Federated runtime: local updates (eq. 3-5), aggregation (eq. 6), rounds,
and the scan-compiled federation engine (DESIGN.md §7)."""

from repro.fl.engine import (
    ServerState,
    history_from_outputs,
    init_server_state,
    make_round_fn,
    run_many,
    run_scanned,
    stack_states,
    unstack_outputs,
)
from repro.fl.rounds import (
    build_client_parallel_round,
    build_fedsgd_step,
    build_server_opt_round,
    weighted_average,
)
from repro.fl.trainer import FLConfig, FLTrainer

"""Federated runtime: local updates (eq. 3-5), aggregation (eq. 6), rounds."""

from repro.fl.rounds import (
    build_client_parallel_round,
    build_fedsgd_step,
    build_server_opt_round,
    weighted_average,
)
from repro.fl.trainer import FLConfig, FLTrainer

"""FLTrainer — Algorithm 1 (FL-DP³S) end-to-end, model-agnostic.

Simulates the full federation on one host: profiles every client once with
the freshly initialised global model (Alg. 1 lines 2-5), builds the eq.-(14)
kernel, then runs rounds: select cohort → vmapped local updates (eq. 3-5) →
eq.-(6) aggregation.  Metrics: training-set accuracy (Fig. 1 protocol), GEMD
per round (Fig. 2), last-known local losses (FedSAE's signal).

Since the engine refactor (DESIGN.md §7) this class is a thin compatibility
wrapper over :mod:`repro.fl.engine`: :meth:`run` packs the server knowledge
into a :class:`~repro.fl.engine.ServerState` and executes all rounds as
``lax.scan`` segments with zero per-round host round-trips, falling back to
the legacy Python loop (:meth:`run_legacy`) only for custom strategies that
don't expose a pure ``select_fn``.

Works for any model exposing ``loss_fn(params, x, y)`` and
``feature_fn(params, x) -> (logits, feats)``; the paper's CNN is the default.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dpp as dpp_lib
from repro.core import metrics as metrics_lib
from repro.core import profiles as profiles_lib
from repro.core import selection as selection_lib
from repro.core import similarity as similarity_lib
from repro.fl import engine as engine_lib
from repro.fl import local_algos as local_algos_lib
from repro.fl import rounds as rounds_lib
from repro.fl import staleness as staleness_lib
from repro.fl.engine import FLConfig
from repro.obs import tracing as obs_tracing_lib

__all__ = ["FLConfig", "FLTrainer"]


@functools.lru_cache(maxsize=64)
def _cached_round_step(loss_fn, lr: float, steps: int, grad_clip=None):
    """One jitted Mode-A round step per (loss_fn, lr, steps) — lets a
    benchmark sweep re-use the compiled XLA program across trainers."""
    batched = lambda p, batch: loss_fn(p, batch[0], batch[1])
    return jax.jit(
        rounds_lib.build_client_parallel_round(
            batched, lr, steps, grad_clip=grad_clip, sequential_clients=True
        )
    )


@functools.lru_cache(maxsize=64)
def _cached_loss_of(loss_fn):
    return jax.jit(jax.vmap(loss_fn, in_axes=(None, 0, 0)))


# round_fns are cached across trainers on the *semantics* of the round, not
# on instance identity, so a benchmark grid (datasets × ξ × seeds) compiles
# each (method, rounds) scan exactly once — the data rides in ServerState.
_ROUND_FN_CACHE: Dict = {}


def _strategy_sig(s: selection_lib.SelectionStrategy):
    return (
        type(s).__module__,
        type(s).__qualname__,
        getattr(s, "mode", None),
        getattr(s, "d", None),
        getattr(s, "use_cache", None),
    )


def _cached_round_fn(cfg: FLConfig, loss_fn, accuracy_fn, strategy, mesh, client_axis):
    key = (
        loss_fn,
        accuracy_fn,
        _strategy_sig(strategy),
        cfg.clients_per_round,
        cfg.local_epochs,
        cfg.local_batch_size,
        cfg.lr,
        cfg.grad_clip,
        cfg.eval_every,
        cfg.local_steps,
        cfg.sample_with_replacement,
        cfg.cohort_cap,
        cfg.staleness_bound,
        cfg.staleness_decay,
        cfg.staleness_alpha,
        cfg.scenario,
        cfg.candidate_frac,
        cfg.faults,
        cfg.aggregator,
        cfg.robust_norm_mult,
        cfg.min_survivors,
        cfg.quarantine_rounds,
        cfg.local_algo,
        cfg.prox_mu,
        cfg.feddyn_alpha,
        cfg.telemetry,
        mesh,
        client_axis,
    )
    if key not in _ROUND_FN_CACHE:
        _ROUND_FN_CACHE[key] = engine_lib.make_round_fn(
            cfg, loss_fn, (strategy,), accuracy_fn=accuracy_fn,
            mesh=mesh, client_axis=client_axis,
        )
    return _ROUND_FN_CACHE[key]


class FLTrainer:
    def __init__(
        self,
        cfg: FLConfig,
        params,
        loss_fn: Callable,
        feature_fn: Callable,
        client_xs: np.ndarray,  # (C, n_c, ...)
        client_ys: np.ndarray,  # (C, n_c)
        strategy: selection_lib.SelectionStrategy,
        eval_xs: Optional[np.ndarray] = None,
        eval_ys: Optional[np.ndarray] = None,
        accuracy_fn: Optional[Callable] = None,
        mesh: Optional[jax.sharding.Mesh] = None,
        client_axis: str = engine_lib.CLIENT_AXIS,
    ):
        assert client_xs.shape[0] == cfg.num_clients
        self.cfg = cfg
        self.loss_fn = loss_fn
        self.feature_fn = feature_fn
        self.strategy = strategy
        self.params = params
        # mesh-sharded cohort execution (DESIGN.md §8): the engine path lays
        # ServerState out over the mesh's client axis and runs local updates
        # as a shard_map; run_legacy always stays single-device.  With
        # cfg.cohort_cap set, the sharded rounds run slot-compacted (each
        # shard trains at most min(C_loc, cohort_cap) clients per round) —
        # segments, reprofile boundaries, and re-sharding work unchanged.
        self.mesh = mesh
        self.client_axis = client_axis
        self.client_xs = jnp.asarray(client_xs)
        self.client_ys = jnp.asarray(client_ys)
        self.eval_xs = jnp.asarray(eval_xs) if eval_xs is not None else None
        self.eval_ys = jnp.asarray(eval_ys) if eval_ys is not None else None
        self.accuracy_fn = accuracy_fn
        self.key = jax.random.key(cfg.seed)
        # round_fn memo (engine program-cache contract: executables are keyed
        # on round_fn identity, so the trainer must hand back the same object
        # across run() calls)
        self._round_fn_memo = None
        # k-DPP spectral cache, keyed on the kernel array it was built from;
        # _init_profiles (reprofile boundaries) invalidates it with the kernel
        self._eig_state = None
        self._eig_kernel = None

        n_c = client_xs.shape[1]
        self.client_sizes = jnp.full((cfg.num_clients,), float(n_c))
        self.client_label_dists = jnp.stack(
            [
                metrics_lib.label_distribution(self.client_ys[c], cfg.num_classes)
                for c in range(cfg.num_clients)
            ]
        )
        self.global_label_dist = metrics_lib.label_distribution(
            self.client_ys.reshape(-1), cfg.num_classes
        )

        # --- jitted building blocks (memoised across trainers) -----------
        steps = self._steps_per_round(n_c)
        self._round_step = _cached_round_step(loss_fn, cfg.lr, steps, cfg.grad_clip)
        self._loss_of = _cached_loss_of(loss_fn)

        # history
        self.history: Dict[str, List] = {"round": [], "acc": [], "gemd": [], "loss": []}
        self.round_state = selection_lib.RoundState(
            num_clients=cfg.num_clients,
            client_sizes=self.client_sizes,
        )
        self._init_profiles()
        # initial last-known local losses (one global pass — the server can
        # get these from the initial broadcast in practice)
        self.losses = self._loss_of(self.params, self.client_xs, self.client_ys)
        self.round_state.losses = self.losses

    # ------------------------------------------------------------------
    def _steps_per_round(self, n_c: int) -> int:
        return engine_lib._steps_per_round(self.cfg, n_c)

    def _init_profiles(self):
        """Alg. 1 lines 2-5: one-shot FC-1 profiling + kernel construction."""
        feats = profiles_lib.profile_all_clients(
            jax.jit(self.feature_fn), self.params, list(self.client_xs)
        )
        self.round_state.profiles = feats
        if self.cfg.candidate_frac is None:
            self.round_state.kernel = similarity_lib.kernel_from_profiles(
                feats, use_kernel=self.cfg.use_pallas_kernel
            )
        else:
            # funnel (DESIGN.md §10): the kernel lives on the Q-candidate
            # block and is rebuilt per segment by engine.funnel_fields — the
            # trainer never materialises the C×C matrix
            self.round_state.kernel = None
        # the spectral cache decomposes exactly this kernel — invalidate
        self._eig_state = None
        self._eig_kernel = None
        # representative-gradient fingerprints for the Cluster baseline
        if isinstance(self.strategy, selection_lib.ClusterSelection):
            gp = [
                profiles_lib.representative_gradient_profile(
                    self.loss_fn, self.params, self.client_xs[c], self.client_ys[c]
                )
                for c in range(self.cfg.num_clients)
            ]
            self.round_state.grad_profiles = jnp.stack(gp)

    def _make_client_batches(self, key, sel: jax.Array):
        """Slice the selected clients' data into (C_p, steps, B, ...) batches."""
        return engine_lib.make_client_batches(
            self.cfg, key, self.client_xs, self.client_ys, sel
        )

    # ------------------------------------------------------------------
    def _supports_engine(self) -> bool:
        """Pure-selection strategies run scanned; host-only customs fall back.

        A strategy is engine-capable when it overrides the canonical
        ``draw_fn`` — or, pre-registry style, the legacy ``select_fn``
        (which the base ``draw_fn`` dispatches to)."""
        base = selection_lib.SelectionStrategy
        return (
            type(self.strategy).draw_fn is not base.draw_fn
            or type(self.strategy).select_fn is not base.select_fn
        )

    def _cluster_labels(self, candidates=None) -> jax.Array:
        """Host-fitted cluster labels — restricted to the funnel candidate
        rows when ``candidates`` is given, so the fit sees the same
        fingerprints as the unfunneled path (with ``candidates == arange(C)``
        the labels are bit-identical: the Q=C parity contract)."""
        cfg = self.cfg
        if isinstance(self.strategy, selection_lib.ClusterSelection):
            feats = (
                self.round_state.grad_profiles
                if self.round_state.grad_profiles is not None
                else self.round_state.profiles
            )
            if candidates is not None:
                feats = jnp.take(feats, candidates, axis=0)
            return self.strategy.fit(feats, cfg.clients_per_round)
        n = cfg.num_clients if candidates is None else candidates.shape[0]
        return jnp.zeros((n,), jnp.int32)

    def eig_state(self) -> dpp_lib.KDPPSamplerState:
        """Spectral cache of the current kernel (one eigh per kernel refresh).

        Memoised on the kernel array identity; ``_init_profiles`` (i.e. every
        ``reprofile_every`` boundary) drops the memo together with the kernel
        it decomposed, so a stale spectrum can never outlive its kernel.
        Strategies that never draw from the cache get the cheap
        identity-layout placeholder instead of an O(C³) eigh.
        """
        kern = self.round_state.kernel
        if self._eig_state is None or self._eig_kernel is not kern:
            k = self.cfg.clients_per_round
            if getattr(self.strategy, "uses_spectral_cache", False):
                self._eig_state = dpp_lib.kdpp_sampler_state(kern, k)
            else:
                self._eig_state = dpp_lib.identity_sampler_state(
                    self.cfg.num_clients, k
                )
            self._eig_kernel = kern
        return self._eig_state

    def server_state(self) -> engine_lib.ServerState:
        """Pack the trainer's current server knowledge into a ServerState
        (laid out over ``self.mesh``'s client axis when a mesh is set).

        With ``cfg.staleness_bound`` set (DESIGN.md §9) the staleness
        bookkeeping is (re-)initialised from the *current* params: the ring
        buffer starts with every slot at θ_now and the per-shard counters at
        0 — each ``run()`` call opens with a freshly synced federation (the
        scanned segments inside one run carry the evolving ring/counters
        through unchanged)."""
        cfg = self.cfg
        candidates = None
        if cfg.candidate_frac is not None:
            # funnel (DESIGN.md §10): stage-1 prefilter on the *current*
            # losses, candidate kernel + spectral cache on the Q-block
            candidates, kernel, eig_state = engine_lib.funnel_fields(
                cfg, self.key, self.round_state.profiles, self.losses,
                strategy=self.strategy, mesh=self.mesh,
                client_axis=self.client_axis,
                round_index=self.round_state.round,
            )
            cluster_labels = self._cluster_labels(candidates)
        else:
            kernel = self.round_state.kernel
            eig_state = self.eig_state()
            cluster_labels = self._cluster_labels()
        param_hist = shard_staleness = None
        if cfg.staleness_bound is not None:
            param_hist, shard_staleness = staleness_lib.init_staleness_fields(
                self.params, cfg.staleness_bound, self.mesh, self.client_axis
            )
        state = engine_lib.ServerState(
            params=self.params,
            key=self.key,
            round=jnp.asarray(self.round_state.round, jnp.int32),
            losses=self.losses,
            kernel=kernel,
            profiles=self.round_state.profiles,
            eig_state=eig_state,
            cluster_labels=cluster_labels,
            client_xs=self.client_xs,
            client_ys=self.client_ys,
            client_sizes=self.client_sizes,
            client_label_dists=self.client_label_dists,
            global_label_dist=self.global_label_dist,
            strategy_index=jnp.asarray(0, jnp.int32),
            param_hist=param_hist,
            shard_staleness=shard_staleness,
            candidates=candidates,
            quarantine=(
                jnp.zeros((cfg.num_clients,), jnp.int32)
                if cfg.guarded()
                else None
            ),
            algo_state=local_algos_lib.init_client_states(
                cfg.local_algo_obj(), self.params, cfg.num_clients
            ),
        )
        if self.mesh is not None:
            state = engine_lib.shard_server_state(
                state, self.mesh, self.client_axis
            )
        return state

    def round_fn(self):
        """The engine's pure per-round transition for this trainer.

        Memoised on the instance: the engine caches compiled scan programs ON
        the round_fn object (identity keying — see ``engine._programs``), so
        handing back a fresh closure per call would recompile the whole
        program every ``run()``.  The no-eval-data path additionally shares
        one round_fn across trainers with identical round semantics
        (``_cached_round_fn``), letting benchmark sweeps reuse the executable.
        """
        if self._round_fn_memo is None:
            if self.eval_xs is not None:
                # held-out eval data lives in the closure -> per-trainer memo
                self._round_fn_memo = engine_lib.make_round_fn(
                    self.cfg, self.loss_fn, (self.strategy,),
                    accuracy_fn=self.accuracy_fn,
                    eval_data=(self.eval_xs, self.eval_ys),
                    mesh=self.mesh, client_axis=self.client_axis,
                )
            else:
                self._round_fn_memo = _cached_round_fn(
                    self.cfg, self.loss_fn, self.accuracy_fn, self.strategy,
                    self.mesh, self.client_axis,
                )
        return self._round_fn_memo

    def _absorb(self, state: engine_lib.ServerState):
        """Pull the scanned segment's final state back into trainer fields."""
        self.params = state.params
        self.key = state.key
        self.losses = state.losses
        self.round_state.losses = self.losses
        self.round_state.round = int(state.round)

    # ------------------------------------------------------------------
    def run(
        self, rounds: Optional[int] = None, progress: bool = False,
        sink=None,
    ) -> Dict[str, List]:
        """Run rounds through the scanned engine (legacy loop as fallback).

        Profile refreshes (``reprofile_every``) happen on scan-segment
        boundaries: each segment is one compiled ``lax.scan``, then profiles
        / kernel / cluster labels are re-fitted on host and the next segment
        starts from the refreshed state.

        ``sink`` (an :class:`repro.obs.TelemetrySink`, DESIGN.md §14) drains
        each segment's stacked outputs to JSONL at the same boundaries and
        records the reprofile events — strictly host-side, so passing a sink
        never changes the compiled program.
        """
        cfg = self.cfg
        rounds = rounds or cfg.rounds
        if not self._supports_engine():
            if cfg.candidate_frac is not None:
                raise ValueError(
                    "candidate_frac requires a strategy with a pure "
                    "select_fn (the scanned engine path): the legacy host "
                    "loop is unfunneled"
                )
            if cfg.guarded():
                raise ValueError(
                    "faults / robust aggregation require a strategy with a "
                    "pure select_fn (the scanned engine path): the legacy "
                    "host loop has no fault-injection or quarantine layer"
                )
            if cfg.local_algo != "fedavg":
                raise ValueError(
                    f"local_algo={cfg.local_algo!r} requires a strategy with "
                    "a pure draw_fn (the scanned engine path): the legacy "
                    "host loop is hardwired to plain SGD (fedavg)"
                )
            return self.run_legacy(rounds=rounds, progress=progress)

        round_fn = self.round_fn()
        segment = cfg.reprofile_every or rounds
        start_round = self.round_state.round
        done = 0
        outs: List[Dict] = []
        state = self.server_state()
        while done < rounds:
            n = min(segment, rounds - done)
            state, seg_outs = engine_lib.run_scanned(
                round_fn, state, n, sink=sink
            )
            outs.append(jax.tree_util.tree_map(np.asarray, seg_outs))
            done += n
            if done < rounds and cfg.reprofile_every:
                self._absorb(state)
                with obs_tracing_lib.annotate("fl.reprofile"):
                    self._init_profiles()  # host: re-profile + re-fit clusters
                if sink is not None:
                    sink.emit(
                        "fl_reprofile",
                        round=self.round_state.round,
                        funneled=cfg.candidate_frac is not None,
                    )
                if cfg.candidate_frac is not None:
                    # reprofile segments RE-FUNNEL (DESIGN.md §10): fresh
                    # profiles + evolved losses -> new candidate set, new
                    # Q×Q kernel, new spectral cache — the carried key gives
                    # fresh environment predictions without touching the
                    # per-round selection/batch streams
                    cand, kern, eig = engine_lib.funnel_fields(
                        cfg, self.key, self.round_state.profiles,
                        self.losses, strategy=self.strategy,
                        mesh=self.mesh, client_axis=self.client_axis,
                        round_index=self.round_state.round,
                    )
                    state = dataclasses.replace(
                        state,
                        kernel=kern,
                        profiles=self.round_state.profiles,
                        eig_state=eig,
                        cluster_labels=self._cluster_labels(cand),
                        candidates=cand,
                    )
                else:
                    state = dataclasses.replace(
                        state,
                        kernel=self.round_state.kernel,
                        profiles=self.round_state.profiles,
                        eig_state=self.eig_state(),  # re-decompose refreshed kernel
                        cluster_labels=self._cluster_labels(),
                    )
                if self.mesh is not None:
                    # restore the mesh layout on the refreshed host arrays so
                    # every segment reuses one compiled scan program
                    state = engine_lib.shard_server_state(
                        state, self.mesh, self.client_axis
                    )
        self._absorb(state)
        merged = jax.tree_util.tree_map(
            lambda *xs: np.concatenate(xs, axis=0), *outs
        )
        final_acc = None
        total = start_round + rounds
        if total % cfg.eval_every != 0:
            final_acc = self._evaluate()
        hist = engine_lib.history_from_outputs(
            merged, cfg.eval_every, final_acc=final_acc
        )
        for k in self.history:
            self.history[k].extend(hist[k])
        if progress:
            for t, a, g, l in zip(
                hist["round"], hist["acc"], hist["gemd"], hist["loss"]
            ):
                print(
                    f"[{self.strategy.name}] round {t:4d} acc={a:.4f} "
                    f"gemd={g:.3f} loss={l:.4f}"
                )
        return self.history

    def run_legacy(
        self, rounds: Optional[int] = None, progress: bool = False
    ) -> Dict[str, List]:
        """The host loop: one jitted step per round, selection and metrics
        dispatched from host.  Kept as the oracle for the scanned engine (see
        ``benchmarks/engine_bench.py``) and for strategies without a pure
        ``select_fn``.

        Note: selection math is the *current* pure layer for both paths —
        in particular ``ClusterSelection``'s per-round draw is now a jax
        categorical (was a host numpy RNG pre-engine), so 'cluster' cohorts
        differ from pre-engine runs at the same seed (same distribution)."""
        cfg = self.cfg
        rounds = rounds or cfg.rounds
        for t in range(1, rounds + 1):
            self.key, k_sel, k_batch = jax.random.split(self.key, 3)
            self.round_state.round = t
            sel = self.strategy.select(k_sel, self.round_state, cfg.clients_per_round)
            batches = self._make_client_batches(k_batch, sel)
            weights = jnp.take(self.client_sizes, sel)
            self.params, mean_loss = self._round_step(self.params, batches, weights)

            # refresh last-known losses for the selected clients
            sel_losses = self._loss_of(
                self.params, jnp.take(self.client_xs, sel, 0), jnp.take(self.client_ys, sel, 0)
            )
            self.losses = self.losses.at[sel].set(sel_losses)
            self.round_state.losses = self.losses

            g = metrics_lib.gemd(
                self.client_label_dists, self.client_sizes, sel, self.global_label_dist
            )
            if cfg.reprofile_every and t % cfg.reprofile_every == 0:
                self._init_profiles()

            if t % cfg.eval_every == 0 or t == rounds:
                acc = self._evaluate()
                self.history["round"].append(t)
                self.history["acc"].append(float(acc))
                self.history["gemd"].append(float(g))
                self.history["loss"].append(float(mean_loss))
                if progress:
                    print(
                        f"[{self.strategy.name}] round {t:4d} acc={float(acc):.4f} "
                        f"gemd={float(g):.3f} loss={float(mean_loss):.4f}"
                    )
        return self.history

    def _evaluate(self) -> float:
        if self.accuracy_fn is None:
            return float("nan")
        if self.eval_xs is not None:
            return self.accuracy_fn(self.params, self.eval_xs, self.eval_ys)
        # Fig.-1 protocol: accuracy of the global model on the training set
        xs = self.client_xs.reshape((-1,) + self.client_xs.shape[2:])
        ys = self.client_ys.reshape(-1)
        return self.accuracy_fn(self.params, xs, ys)

"""FLTrainer — Algorithm 1 (FL-DP³S) end-to-end, model-agnostic.

Simulates the full federation on one host: profiles every client once with
the freshly initialised global model (Alg. 1 lines 2-5), builds the eq.-(14)
kernel, then loops: select cohort → vmapped local updates (eq. 3-5) →
eq.-(6) aggregation.  Metrics: training-set accuracy (Fig. 1 protocol), GEMD
per round (Fig. 2), last-known local losses (FedSAE's signal).

Works for any model exposing ``loss_fn(params, x, y)`` and
``feature_fn(params, x) -> (logits, feats)``; the paper's CNN is the default.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import metrics as metrics_lib
from repro.core import profiles as profiles_lib
from repro.core import selection as selection_lib
from repro.core import similarity as similarity_lib
from repro.fl import rounds as rounds_lib

__all__ = ["FLConfig", "FLTrainer"]


@functools.lru_cache(maxsize=64)
def _cached_round_step(loss_fn, lr: float, steps: int, grad_clip=None):
    """One jitted Mode-A round step per (loss_fn, lr, steps) — lets a
    benchmark sweep re-use the compiled XLA program across trainers."""
    batched = lambda p, batch: loss_fn(p, batch[0], batch[1])
    return jax.jit(
        rounds_lib.build_client_parallel_round(
            batched, lr, steps, grad_clip=grad_clip, sequential_clients=True
        )
    )


@functools.lru_cache(maxsize=64)
def _cached_loss_of(loss_fn):
    return jax.jit(jax.vmap(loss_fn, in_axes=(None, 0, 0)))


@dataclasses.dataclass
class FLConfig:
    num_clients: int = 100
    clients_per_round: int = 10
    local_epochs: int = 2  # E in eq. (3)
    local_batch_size: Optional[int] = None  # None = full-batch GD (paper eq. 4)
    lr: float = 0.05
    rounds: int = 100
    eval_every: int = 5
    num_classes: int = 10
    seed: int = 0
    reprofile_every: Optional[int] = None  # beyond-paper: refresh profiles
    use_pallas_kernel: bool = False  # pairwise distances through Pallas
    grad_clip: Optional[float] = None  # stabilises late-round full-batch SGD


class FLTrainer:
    def __init__(
        self,
        cfg: FLConfig,
        params,
        loss_fn: Callable,
        feature_fn: Callable,
        client_xs: np.ndarray,  # (C, n_c, ...)
        client_ys: np.ndarray,  # (C, n_c)
        strategy: selection_lib.SelectionStrategy,
        eval_xs: Optional[np.ndarray] = None,
        eval_ys: Optional[np.ndarray] = None,
        accuracy_fn: Optional[Callable] = None,
    ):
        assert client_xs.shape[0] == cfg.num_clients
        self.cfg = cfg
        self.loss_fn = loss_fn
        self.feature_fn = feature_fn
        self.strategy = strategy
        self.params = params
        self.client_xs = jnp.asarray(client_xs)
        self.client_ys = jnp.asarray(client_ys)
        self.eval_xs = jnp.asarray(eval_xs) if eval_xs is not None else None
        self.eval_ys = jnp.asarray(eval_ys) if eval_ys is not None else None
        self.accuracy_fn = accuracy_fn
        self.key = jax.random.key(cfg.seed)

        n_c = client_xs.shape[1]
        self.client_sizes = jnp.full((cfg.num_clients,), float(n_c))
        self.client_label_dists = jnp.stack(
            [
                metrics_lib.label_distribution(self.client_ys[c], cfg.num_classes)
                for c in range(cfg.num_clients)
            ]
        )
        self.global_label_dist = metrics_lib.label_distribution(
            self.client_ys.reshape(-1), cfg.num_classes
        )

        # --- jitted building blocks (memoised across trainers) -----------
        steps = self._steps_per_round(n_c)
        self._round_step = _cached_round_step(loss_fn, cfg.lr, steps, cfg.grad_clip)
        self._loss_of = _cached_loss_of(loss_fn)

        # history
        self.history: Dict[str, List] = {"round": [], "acc": [], "gemd": [], "loss": []}
        self.round_state = selection_lib.RoundState(
            num_clients=cfg.num_clients,
            client_sizes=self.client_sizes,
        )
        self._init_profiles()
        # initial last-known local losses (one global pass — the server can
        # get these from the initial broadcast in practice)
        self.losses = self._loss_of(self.params, self.client_xs, self.client_ys)
        self.round_state.losses = self.losses

    # ------------------------------------------------------------------
    def _steps_per_round(self, n_c: int) -> int:
        if self.cfg.local_batch_size is None:
            return self.cfg.local_epochs  # E full-batch passes (paper eq. 4)
        return self.cfg.local_epochs * max(1, n_c // self.cfg.local_batch_size)

    def _init_profiles(self):
        """Alg. 1 lines 2-5: one-shot FC-1 profiling + kernel construction."""
        feats = profiles_lib.profile_all_clients(
            jax.jit(self.feature_fn), self.params, list(self.client_xs)
        )
        self.round_state.profiles = feats
        self.round_state.kernel = similarity_lib.kernel_from_profiles(
            feats, use_kernel=self.cfg.use_pallas_kernel
        )
        # representative-gradient fingerprints for the Cluster baseline
        if isinstance(self.strategy, selection_lib.ClusterSelection):
            gp = [
                profiles_lib.representative_gradient_profile(
                    self.loss_fn, self.params, self.client_xs[c], self.client_ys[c]
                )
                for c in range(self.cfg.num_clients)
            ]
            self.round_state.grad_profiles = jnp.stack(gp)

    def _make_client_batches(self, key, sel: jax.Array):
        """Slice the selected clients' data into (C_p, steps, B, ...) batches."""
        xs = jnp.take(self.client_xs, sel, axis=0)
        ys = jnp.take(self.client_ys, sel, axis=0)
        steps = self._steps_per_round(xs.shape[1])
        if self.cfg.local_batch_size is None:
            # full-batch: each local step sees the whole local dataset
            xb = jnp.broadcast_to(xs[:, None], (xs.shape[0], steps) + xs.shape[1:])
            yb = jnp.broadcast_to(ys[:, None], (ys.shape[0], steps) + ys.shape[1:])
            return (xb, yb)
        b = self.cfg.local_batch_size
        n_c = xs.shape[1]
        nb = max(1, n_c // b)
        perm = jax.vmap(
            lambda k: jax.random.permutation(k, n_c)
        )(jax.random.split(key, xs.shape[0]))
        xs = jnp.take_along_axis(
            xs, perm.reshape(perm.shape + (1,) * (xs.ndim - 2)), axis=1
        )
        ys = jnp.take_along_axis(ys, perm, axis=1)
        xb = xs[:, : nb * b].reshape(xs.shape[0], nb, b, *xs.shape[2:])
        yb = ys[:, : nb * b].reshape(ys.shape[0], nb, b)
        reps = self.cfg.local_epochs
        xb = jnp.tile(xb, (1, reps) + (1,) * (xb.ndim - 2))
        yb = jnp.tile(yb, (1, reps, 1))
        return (xb, yb)

    # ------------------------------------------------------------------
    def run(self, rounds: Optional[int] = None, progress: bool = False) -> Dict[str, List]:
        cfg = self.cfg
        rounds = rounds or cfg.rounds
        for t in range(1, rounds + 1):
            self.key, k_sel, k_batch = jax.random.split(self.key, 3)
            self.round_state.round = t
            sel = self.strategy.select(k_sel, self.round_state, cfg.clients_per_round)
            batches = self._make_client_batches(k_batch, sel)
            weights = jnp.take(self.client_sizes, sel)
            self.params, mean_loss = self._round_step(self.params, batches, weights)

            # refresh last-known losses for the selected clients
            sel_losses = self._loss_of(
                self.params, jnp.take(self.client_xs, sel, 0), jnp.take(self.client_ys, sel, 0)
            )
            self.losses = self.losses.at[sel].set(sel_losses)
            self.round_state.losses = self.losses

            g = metrics_lib.gemd(
                self.client_label_dists, self.client_sizes, sel, self.global_label_dist
            )
            if cfg.reprofile_every and t % cfg.reprofile_every == 0:
                self._init_profiles()

            if t % cfg.eval_every == 0 or t == rounds:
                acc = self._evaluate()
                self.history["round"].append(t)
                self.history["acc"].append(float(acc))
                self.history["gemd"].append(float(g))
                self.history["loss"].append(float(mean_loss))
                if progress:
                    print(
                        f"[{self.strategy.name}] round {t:4d} acc={float(acc):.4f} "
                        f"gemd={float(g):.3f} loss={float(mean_loss):.4f}"
                    )
        return self.history

    def _evaluate(self) -> float:
        if self.accuracy_fn is None:
            return float("nan")
        if self.eval_xs is not None:
            return self.accuracy_fn(self.params, self.eval_xs, self.eval_ys)
        # Fig.-1 protocol: accuracy of the global model on the training set
        xs = self.client_xs.reshape((-1,) + self.client_xs.shape[2:])
        ys = self.client_ys.reshape(-1)
        return self.accuracy_fn(self.params, xs, ys)

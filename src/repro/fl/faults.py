"""Fault-injection registry + update-validation guard (DESIGN.md §11).

The scenario simulator (``repro.fl.scenarios``) prices *slowness*; this module
models the other deployment reality the client-selection surveys
(arXiv:2211.01549, arXiv:2207.03681) call dominant: clients that **fail** —
abort mid-round, deliver NaN/Inf or norm-exploded garbage, flip the sign of
their update ("Byzantine"), or disappear with their whole shard for a round.
Like scenarios, fault models are *static* config (named in
``FLConfig.faults``) and all per-round randomness is drawn **at the jit
level** off the carried server key via a salted ``fold_in`` — a fault-free
config never touches the selection/batch key streams, so it stays
bit-identical to the pre-fault engine.

Two halves:

* :func:`draw_round_faults` — one round's per-client fault masks
  (``delivered`` / ``nan`` / ``garbage`` / ``sign_flip``) plus per-shard
  blackout folded into ``delivered``, all pure functions of
  ``fold_in(key, FAULT_SALT)``.  Persistent "lemon" clients (a fixed
  fraction that corrupts *every* round — the quarantine workload) come from
  :func:`lemon_mask`, a static draw independent of the round key.
* :func:`make_update_guard` — the update-validation transform the round
  builders (``repro.fl.rounds``) apply between the local updates and the
  eq.-(6) weighted sum, **inside the shard_map, before the single psum**:
  inject the drawn corruption, zero undelivered clients out of the weights,
  then screen per-client update norms ``‖θ_c − base‖`` against the
  aggregator's policy — ``mean`` admits everything (the vulnerable control),
  ``clipped_mean`` rescales over-norm deltas to ``norm_mult × median`` and
  flags them, ``trimmed_mean`` rejects them outright (weight → 0, the
  ``safe_div`` denominator renormalises).  Non-finite updates are always
  rejected under the robust aggregators, and every rejected/clipped cohort
  member comes back in the ``flagged`` mask that feeds the engine's
  quarantine counters.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.metrics import safe_div

__all__ = [
    "FAULT_SALT",
    "AGGREGATORS",
    "FaultModel",
    "FAULT_MODELS",
    "FAULT_NAMES",
    "get_fault_model",
    "lemon_mask",
    "FaultDraws",
    "draw_round_faults",
    "apply_faults",
    "update_norms",
    "masked_median",
    "make_update_guard",
]

# fold_in salt branching the fault stream off the carried server key WITHOUT
# consuming a split (the _ENV_SALT / _FUNNEL_SALT convention): configs with
# faults=None never evaluate it, so their selection/batch streams are
# untouched.
FAULT_SALT = 0xFA017ED5

# FLConfig.aggregator values — shared by engine validation and launch flags.
AGGREGATORS = ("mean", "clipped_mean", "trimmed_mean")

_LEMON_SEED = 0x1E303535  # static draw for the persistent-lemon set
_LEMON_MODES = ("nan", "garbage", "sign_flip")


@dataclasses.dataclass(frozen=True)
class FaultModel:
    """One named fault-injection model; every rate is per-client-per-round
    (``shard_blackout`` per-shard-per-round).

    ``lemon_frac`` marks a fixed fraction of clients *persistently* faulty
    (they emit ``lemon_mode`` corruption on every round they are selected) —
    the workload quarantine must learn to stop re-selecting.
    """

    name: str
    dropout: float = 0.0  # mid-round abort: the update never arrives
    nan: float = 0.0  # NaN/Inf-corrupted update
    garbage: float = 0.0  # norm-scaled garbage: delta × garbage_scale
    sign_flip: float = 0.0  # Byzantine: delta → −delta (same norm!)
    shard_blackout: float = 0.0  # whole shard misses the round
    garbage_scale: float = 50.0
    lemon_frac: float = 0.0  # persistently faulty fraction
    lemon_mode: str = "garbage"

    def __post_init__(self):
        for f in ("dropout", "nan", "garbage", "sign_flip",
                  "shard_blackout", "lemon_frac"):
            v = getattr(self, f)
            if not 0.0 <= v <= 1.0:
                raise ValueError(f"FaultModel.{f}={v} must be in [0, 1]")
        if self.garbage_scale <= 0:
            raise ValueError(
                f"FaultModel.garbage_scale={self.garbage_scale} must be > 0"
            )
        if self.lemon_mode not in _LEMON_MODES:
            raise ValueError(
                f"unknown lemon_mode {self.lemon_mode!r}; "
                f"known: {list(_LEMON_MODES)}"
            )


FAULT_MODELS = {
    # mid-round aborts only: plain FedAvg handles these via the delivered
    # mask — the control showing dropout alone needs no robust aggregator
    "dropout": FaultModel(name="dropout", dropout=0.15),
    # the BENCH_fault workload: 10% corrupted-update rate (half NaN, half
    # norm-exploded garbage) — plain mean degrades, robust aggregation holds
    "corrupt": FaultModel(name="corrupt", nan=0.05, garbage=0.05),
    # sign-flipped updates at honest norm: invisible to norm screening, the
    # documented limitation of the per-shard validation layer
    "byzantine": FaultModel(name="byzantine", sign_flip=0.10),
    # whole-shard outages + light dropout: exercises the survivors floor
    "blackout": FaultModel(name="blackout", shard_blackout=0.15, dropout=0.05),
    # persistently faulty clients: the quarantine workload
    "lemons": FaultModel(name="lemons", lemon_frac=0.10),
    # everything at once (the dryrun compile case)
    "chaos": FaultModel(
        name="chaos", dropout=0.10, nan=0.03, garbage=0.03, sign_flip=0.04,
        shard_blackout=0.05, lemon_frac=0.05,
    ),
}

FAULT_NAMES = tuple(sorted(FAULT_MODELS))


def get_fault_model(name: str) -> FaultModel:
    """Resolve a registry name; raises ``ValueError`` listing known names."""
    try:
        return FAULT_MODELS[name]
    except KeyError:
        raise ValueError(
            f"unknown fault model {name!r}; known: {list(FAULT_NAMES)}"
        ) from None


def lemon_mask(model: FaultModel, num_clients: int) -> jax.Array:
    """(C,) bool mask of the persistently faulty clients.

    A *static* draw (fixed seed, independent of the carried round key — the
    lemon set is a property of the federation, not of a round) with exactly
    ``max(1, round(C · lemon_frac))`` lemons when ``lemon_frac > 0``.
    """
    if model.lemon_frac <= 0.0:
        return jnp.zeros((num_clients,), jnp.bool_)
    n = max(1, int(round(num_clients * model.lemon_frac)))
    u = jax.random.uniform(jax.random.key(_LEMON_SEED), (num_clients,))
    order = jnp.argsort(u)
    return jnp.zeros((num_clients,), jnp.bool_).at[order[:n]].set(True)


class FaultDraws(NamedTuple):
    """One round's fault masks, resident (global-id) layout, precedence
    applied: corruption masks are mutually exclusive and only ever set for
    delivered clients (an aborted client's update never arrives, so it can
    poison nothing)."""

    delivered: jax.Array  # (C,) bool — survived dropout AND shard blackout
    nan: jax.Array  # (C,) bool
    garbage: jax.Array  # (C,) bool
    sign_flip: jax.Array  # (C,) bool


def draw_round_faults(
    key: jax.Array,
    model: FaultModel,
    num_clients: int,
    num_shards: int = 1,
    lemons: Optional[jax.Array] = None,
) -> FaultDraws:
    """Pure jit-level fault draws for one round.

    ``key`` must already be the salted fault stream
    (``fold_in(state.key, FAULT_SALT)``).  Each fault category draws from its
    own ``fold_in`` lane so adding a category never shifts the others.
    ``num_shards`` sizes the blackout draw; the per-shard mask is expanded to
    clients in resident layout (shard d owns global ids
    ``[d·C/D, (d+1)·C/D)`` — the engine's gid convention).
    """

    def bern(lane: int, p: float, n: int) -> jax.Array:
        if p <= 0.0:
            return jnp.zeros((n,), jnp.bool_)
        u = jax.random.uniform(jax.random.fold_in(key, lane), (n,), jnp.float32)
        return u < jnp.float32(p)

    dropped = bern(1, model.dropout, num_clients)
    nan_m = bern(2, model.nan, num_clients)
    garb = bern(3, model.garbage, num_clients)
    flip = bern(4, model.sign_flip, num_clients)
    blackout = bern(5, model.shard_blackout, num_shards)
    if model.lemon_frac > 0.0 and lemons is not None:
        if model.lemon_mode == "nan":
            nan_m = nan_m | lemons
        elif model.lemon_mode == "garbage":
            garb = garb | lemons
        else:
            flip = flip | lemons
    delivered = ~dropped & ~jnp.repeat(blackout, num_clients // num_shards)
    # precedence: nan > garbage > sign_flip; undelivered never corrupts
    nan_m = nan_m & delivered
    garb = garb & ~nan_m & delivered
    flip = flip & ~nan_m & ~garb & delivered
    return FaultDraws(delivered=delivered, nan=nan_m, garbage=garb,
                      sign_flip=flip)


# ------------------------------------------------------------ update guard


def _bshape(mask: jax.Array, ndim: int):
    return mask.reshape((-1,) + (1,) * (ndim - 1))


def apply_faults(new_params, base_params, losses, nan_m, garb_m, flip_m,
                 garbage_scale: float):
    """Corrupt the delivered per-client updates (leading axis M) per the
    drawn masks: ``sign_flip`` negates the delta, ``garbage`` scales it by
    ``garbage_scale``, ``nan`` replaces the whole update with NaN — and a
    NaN-faulty client's *loss report* is garbage too (the NaN non-cohort
    masking convention then keeps it out of every round mean)."""

    def leaf(n, b):
        d = n.astype(jnp.float32) - b.astype(jnp.float32)
        d = jnp.where(_bshape(flip_m, d.ndim), -d, d)
        d = jnp.where(_bshape(garb_m, d.ndim), jnp.float32(garbage_scale) * d, d)
        out = b.astype(jnp.float32) + d
        out = jnp.where(_bshape(nan_m, d.ndim), jnp.nan, out)
        return out.astype(n.dtype)

    corrupted = jax.tree_util.tree_map(leaf, new_params, base_params)
    losses = jnp.where(_bshape(nan_m, losses.ndim), jnp.nan, losses)
    return corrupted, losses


def update_norms(new_params, base_params) -> jax.Array:
    """(M,) global L2 norms of the per-client deltas ``θ_c − base`` — any
    non-finite leaf entry makes the whole norm non-finite (the finite
    screen's one signal)."""
    sq = None
    for n, b in zip(jax.tree_util.tree_leaves(new_params),
                    jax.tree_util.tree_leaves(base_params)):
        d = n.astype(jnp.float32) - b.astype(jnp.float32)
        s = jnp.sum(d * d, axis=tuple(range(1, d.ndim)))
        sq = s if sq is None else sq + s
    return jnp.sqrt(sq)


def masked_median(x: jax.Array, mask: jax.Array) -> jax.Array:
    """Lower median of ``x`` where ``mask`` — jittable, +inf when the mask is
    empty (callers' thresholds then admit everything finite)."""
    padded = jnp.where(mask, x, jnp.inf)
    s = jnp.sort(padded)
    cnt = jnp.sum(mask.astype(jnp.int32))
    idx = jnp.clip(jnp.maximum(cnt - 1, 0) // 2, 0, x.shape[0] - 1)
    return jnp.take(s, idx)


def make_update_guard(
    aggregator: str,
    norm_mult: float,
    garbage_scale: float = 1.0,
    inject: bool = False,
):
    """Build the update-validation transform the round builders apply
    between the local updates and the eq.-(6) weighted sum.

    ``guard(new_params, base_params, weights, losses, *masks) ->
    (new_params, weights, losses, flagged)`` where every array leads with the
    per-client axis M.  ``masks`` is the :class:`FaultDraws` 4-tuple sliced
    to this shard/slot layout when ``inject`` (a fault model is attached),
    else empty — the robust aggregators screen honest-path runs too.

    The returned weights are the eq.-(6) weights with undelivered and
    rejected clients zeroed; the existing ``safe_div`` denominator
    renormalises, so rejection is exactly "masked out of the weighted sum".
    Rejected clients' params are also zeroed (sanitised): a 0-weight NaN
    update would otherwise poison the partial sums through ``0 · NaN``.
    ``flagged`` marks the cohort members validation rejected (or, under
    ``clipped_mean``, clipped) — the engine's quarantine signal.
    """
    if aggregator not in AGGREGATORS:
        raise ValueError(
            f"unknown aggregator {aggregator!r}; known: {list(AGGREGATORS)}"
        )

    def guard(new_params, base_params, weights, losses, *masks):
        if inject:
            delivered, nan_m, garb_m, flip_m = masks
            new_params, losses = apply_faults(
                new_params, base_params, losses, nan_m, garb_m, flip_m,
                garbage_scale,
            )
            w = weights * delivered.astype(weights.dtype)
        else:
            w = weights
        cohort = w > 0
        if aggregator == "mean":
            # the vulnerable control: delivered corruption flows straight
            # into the weighted sum, nothing is flagged
            return new_params, w, losses, jnp.zeros_like(cohort)
        norms = update_norms(new_params, base_params)
        finite = jnp.isfinite(norms)
        med = masked_median(norms, cohort & finite)
        tau = jnp.float32(norm_mult) * med
        over = finite & (norms > tau)
        if aggregator == "clipped_mean":
            # rescale over-norm deltas to the threshold; they stay in the
            # sum (clipped) but are flagged for quarantine
            s = jnp.where(over, safe_div(tau, norms), 1.0)
            new_params = jax.tree_util.tree_map(
                lambda n, b: (
                    b.astype(jnp.float32)
                    + _bshape(s, n.ndim)
                    * (n.astype(jnp.float32) - b.astype(jnp.float32))
                ).astype(n.dtype),
                new_params, base_params,
            )
            valid = cohort & finite
        else:  # trimmed_mean: reject norm outliers outright
            valid = cohort & finite & ~over
        flagged = cohort & (~valid | over)
        new_params = jax.tree_util.tree_map(
            lambda n: jnp.where(_bshape(valid, n.ndim), n,
                                jnp.zeros((), n.dtype)),
            new_params,
        )
        return new_params, w * valid.astype(w.dtype), losses, flagged

    return guard

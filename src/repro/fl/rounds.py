"""Jitted FL round steps — the distributed heart of the framework.

Two execution modes (DESIGN.md §2):

* ``build_client_parallel_round`` — Mode A (paper-faithful): per-client param
  copies on a leading C_p axis (sharded over the mesh ``data`` axis under
  pjit), ``E`` local SGD steps via ``lax.scan`` with **no cross-client
  collectives inside**, then one eq.-(6) weighted aggregation.  The collective
  term of the roofline is paid once per round instead of once per step —
  the communication-efficiency claim of FL, measurable in §Roofline.
* ``build_fedsgd_step`` — Mode B (paper's E=1 reduction, eq. 9): one global
  weighted-gradient step; params keep a single (optionally FSDP-sharded)
  copy.  Used when per-client copies cannot fit HBM (llama4-maverick).

Both are pure functions of (params, batch pytrees) so ``jax.jit`` +
``in_shardings`` decide the distribution; nothing here touches devices.

Every round builder here consumes **global** client ids / resident masks —
the two-stage selection funnel (DESIGN.md §10) lives entirely upstream in
``SelectionStrategy.select_global_fn``, which hands back global ids whatever
the candidate set was.  That is why slot-capped (``cohort_cap``) and
bounded-staleness execution compose with ``candidate_frac`` with no code
here changing: a funneled cohort is just a cohort by the time it reaches a
round step.

*What* each client computes is pluggable (DESIGN.md §12): every builder
takes an ``algo`` — a :class:`repro.fl.local_algos.LocalAlgo` — whose
per-step gradient hook and per-round state evolution are folded into the
client scan by :func:`build_local_algo_update`.  ``algo=None`` means
FedAvg and keeps every legacy signature, return shape, and compiled graph
untouched; a *stateful* algorithm (FedDyn) extends the signatures with a
per-client state pytree in and a *candidate* new state out — masked
write-back (cohort membership, guard verdicts, survivor floors) is the
engine's job, since only it knows the round's refresh mask.
"""

from __future__ import annotations

import warnings
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro import optim as optim_lib
from repro.core.metrics import finite_mean, safe_div

__all__ = [
    "weighted_average",
    "make_grad_fn",
    "build_local_update",
    "build_local_algo_update",
    "build_client_parallel_round",
    "build_shard_cohort_round",
    "build_stale_shard_cohort_round",
    "build_fedsgd_step",
    "build_server_opt_round",
]

PyTree = Any
# loss_fn(params, batch) -> scalar loss
LossFn = Callable[[PyTree, PyTree], jax.Array]


def weighted_average(trees: PyTree, weights: jax.Array) -> PyTree:
    """Eq. (6): Σ_c (n_c / Σ n_c) · w_c over the leading client axis."""
    w = safe_div(weights, jnp.sum(weights)).astype(jnp.float32)

    def avg(x):
        wb = w.reshape((-1,) + (1,) * (x.ndim - 1)).astype(jnp.float32)
        return jnp.sum(wb * x.astype(jnp.float32), axis=0).astype(x.dtype)

    return jax.tree_util.tree_map(avg, trees)


def make_grad_fn(
    loss_fn: LossFn, micro_batches: int = 1
) -> Callable[[PyTree, PyTree], Tuple[jax.Array, PyTree]]:
    """``grad_fn(params, batch) -> (loss, grad)``, optionally accumulated
    over ``micro_batches`` slices of the batch's leading sample axis —
    identical full-batch gradient, 1/micro_batches the live activations
    (§Perf memory lever).  The one gradient definition shared by every
    local-update algorithm and the Mode-B FedSGD step."""

    def _full_grad(p, batch):
        if micro_batches == 1:
            return jax.value_and_grad(loss_fn)(p, batch)
        micro = jax.tree_util.tree_map(
            lambda x: x.reshape((micro_batches, x.shape[0] // micro_batches) + x.shape[1:]),
            batch,
        )

        def acc(carry, mb):
            tot_l, tot_g = carry
            l, g = jax.value_and_grad(loss_fn)(p, mb)
            return (tot_l + l, jax.tree_util.tree_map(jnp.add, tot_g, g)), None

        zeros = jax.tree_util.tree_map(lambda w: jnp.zeros(w.shape, jnp.float32), p)
        (loss, g), _ = lax.scan(acc, (jnp.zeros((), jnp.float32), zeros), micro)
        inv = 1.0 / micro_batches
        return loss * inv, jax.tree_util.tree_map(lambda x: x * inv, g)

    return _full_grad


def build_local_algo_update(
    algo,
    loss_fn: LossFn,
    lr: float,
    grad_clip: Optional[float] = None,
    unroll=1,
    micro_batches: int = 1,
) -> Callable:
    """One client's E local passes of a registered algorithm (DESIGN.md §12).

    The entry ``params`` are the round's base — the anchor every
    drift-correcting term measures against (under bounded staleness that is
    the shard's stale ring read, exactly the params the client trained
    from).  Two signatures, chosen by ``algo.stateful``:

    * stateless — ``local_update(params, steps_batch) -> (params, losses)``,
      the legacy :func:`build_local_update` contract.  The FedAvg identity
      hook makes this trace to the *same* program as the pre-registry SGD
      scan, so ``local_algo="fedavg"`` is bit-identical everywhere.
    * stateful — ``local_update(params, client_state, steps_batch) ->
      (params, new_client_state, losses)``; the state is constant during
      the scan (a per-*round* quantity) and evolved once by
      ``algo.finalize`` after the final step.
    """
    if algo is None:
        from repro.fl.local_algos import FedAvg

        algo = FedAvg()
    _full_grad = make_grad_fn(loss_fn, micro_batches)

    def _scan_steps(params, client_state, anchor, steps_batch):
        # eq. (3)-(5): E SGD passes with the algorithm's per-step grad term
        def one_step(p, batch):
            loss, g = _full_grad(p, batch)
            g = algo.transform_grad(g, p, client_state, anchor)
            if grad_clip is not None:
                g = optim_lib.clip_by_global_norm(g, grad_clip)
            p = jax.tree_util.tree_map(lambda w, gw: (w - lr * gw).astype(w.dtype), p, g)
            return p, loss

        return lax.scan(one_step, params, steps_batch, unroll=unroll)

    if not algo.stateful:

        def local_update(params: PyTree, steps_batch: PyTree):
            return _scan_steps(params, (), params, steps_batch)

        return local_update

    def stateful_local_update(params: PyTree, client_state: PyTree, steps_batch: PyTree):
        anchor = params
        new_params, losses = _scan_steps(params, client_state, anchor, steps_batch)
        new_state = algo.finalize(new_params, client_state, anchor)
        return new_params, new_state, losses

    return stateful_local_update


def build_local_update(
    loss_fn: LossFn,
    lr: float,
    grad_clip: Optional[float] = None,
    unroll=1,
    micro_batches: int = 1,
) -> Callable[[PyTree, PyTree], Tuple[PyTree, jax.Array]]:
    """Deprecated alias for the registry's FedAvg (DESIGN.md §12).

    ``local_update(params, steps_batch) -> (params, losses)`` — the exact
    pre-registry plain-SGD scan, now produced by
    ``build_local_algo_update(get_local_algo("fedavg"), ...)``.  Kept so
    existing imports and the legacy parity oracle keep working; new code
    should go through the registry.
    """
    warnings.warn(
        "build_local_update is deprecated; use "
        "build_local_algo_update(get_local_algo('fedavg'), ...) instead",
        DeprecationWarning,
        stacklevel=2,
    )
    from repro.fl.local_algos import get_local_algo

    return build_local_algo_update(
        get_local_algo("fedavg"), loss_fn, lr, grad_clip=grad_clip,
        unroll=unroll, micro_batches=micro_batches,
    )


def build_client_parallel_round(
    loss_fn: LossFn,
    lr: float,
    local_steps: int,
    grad_clip: Optional[float] = None,
    client_constraint: Optional[Callable[[PyTree], PyTree]] = None,
    unroll=1,
    sequential_clients: bool = False,
    micro_batches: int = 1,
    update_transform: Optional[Callable] = None,
    algo=None,
) -> Callable[[PyTree, PyTree, jax.Array], Tuple[PyTree, jax.Array]]:
    """Mode A round step.

    ``round_step(global_params, client_batches, client_weights)`` where every
    leaf of ``client_batches`` has leading shape ``(C_p, local_steps, ...)``
    and ``client_weights`` is ``(C_p,)`` (= n_c).  Returns the aggregated
    global params (eq. 6) and the mean local loss.

    ``client_constraint`` (used by the distributed launchers) applies a
    sharding constraint to the per-client broadcast params so the leading
    client axis lays out over the mesh ``data`` axis.

    ``update_transform`` (DESIGN.md §11) is the fault-injection +
    update-validation guard from ``repro.fl.faults.make_update_guard``,
    applied between the local updates and the eq.-(6) weighted sum.  When
    set, ``round_step(global_params, client_batches, client_weights,
    *guard_args)`` returns ``(agg, mean_loss, flagged, survivors)`` — the
    NaN-aware cohort mean, the per-client quarantine flags, and the count of
    clients left in the weighted sum.  When ``None`` (the default) the
    legacy signature, return, and compiled graph are untouched.

    ``algo`` (DESIGN.md §12) selects the local-update algorithm (``None`` =
    FedAvg, legacy-identical graph).  A *stateful* algorithm adds a required
    keyword ``client_states`` (leaves leading ``(C_p, ...)``) and appends
    the candidate new states as the final return element — the caller owns
    the masked write-back, since only it knows the round's refresh mask.
    """
    local_update = build_local_algo_update(
        algo, loss_fn, lr, grad_clip=grad_clip, unroll=unroll,
        micro_batches=micro_batches,
    )
    stateful = algo is not None and algo.stateful

    def round_step(
        global_params, client_batches, client_weights, *guard_args,
        client_states=None,
    ):
        n_clients = client_weights.shape[0]
        per_client = jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x, (n_clients,) + x.shape), global_params
        )
        if client_constraint is not None:
            per_client = client_constraint(per_client)
        operands = (
            (per_client, client_states, client_batches)
            if stateful
            else (per_client, client_batches)
        )
        if sequential_clients:
            # CPU-simulation path: vmapped convs lower to grouped convolutions
            # (XLA-CPU pathology, ~10x slow); on the mesh each device owns one
            # client so vmap is right there, lax.map is right here.
            out = jax.lax.map(lambda args: local_update(*args), operands)
        else:
            out = jax.vmap(local_update)(*operands)
        if stateful:
            new_params, new_states, losses = out
        else:
            new_params, losses = out
        if update_transform is None:
            agg = weighted_average(new_params, client_weights)
            if stateful:
                return agg, jnp.mean(losses), new_states
            return agg, jnp.mean(losses)
        new_params, w, losses, flagged = update_transform(
            new_params, global_params, client_weights, losses, *guard_args
        )
        agg = weighted_average(new_params, w)
        entry = jnp.mean(losses, axis=tuple(range(1, losses.ndim)))
        mean_loss = finite_mean(entry, where=w > 0)
        survivors = jnp.sum((w > 0).astype(jnp.int32))
        if stateful:
            return agg, mean_loss, flagged, survivors, new_states
        return agg, mean_loss, flagged, survivors

    return round_step


def build_shard_cohort_round(
    loss_fn: LossFn,
    lr: float,
    axis: str,
    grad_clip: Optional[float] = None,
    unroll=1,
    sequential_clients: bool = True,
    micro_batches: int = 1,
    cap: Optional[int] = None,
    update_transform: Optional[Callable] = None,
    algo=None,
) -> Callable[..., Tuple[PyTree, jax.Array, jax.Array, Any]]:
    """Mesh-sharded Mode-A round step for ONE client shard.

    Must be called *inside* a ``shard_map`` body whose mesh carries ``axis``:
    each device runs local updates only for clients resident in its shard,
    then the eq.-(6) aggregation happens as per-shard partial weighted sums
    combined with ``lax.psum`` — the parameter tree is never all-gathered,
    each device contributes exactly its Σ_local w_c·θ_c term.

    Two execution modes, selected by ``cap``:

    * ``cap=None`` (resident mode) —
      ``round_step(global_params, local_batches, local_weights, extras=None)``
      where every leaf of ``local_batches`` has leading shape ``(C_loc,
      local_steps, ...)`` and ``local_weights`` is ``(C_loc,)`` with ``0``
      marking clients outside the round's cohort.  Every resident computes a
      (possibly zero-weighted) update: D·(C/D) work however small the cohort.
    * ``cap=int`` (slot-compacted mode, DESIGN.md §8) —
      ``round_step(global_params, slot_batches, local_weights, slot_index,
      extras=None)``: the caller packs the shard's (at most ``cap =
      min(C_loc, k)``) selected residents into a compact slot axis —
      ``slot_batches`` leaves lead with ``(cap, local_steps, ...)`` and
      ``slot_index`` is ``(cap,)`` distinct local resident positions,
      selected residents first (padding slots point at unselected residents
      and carry weight 0).  Local updates run only over slots, the slot
      weights are gathered from the resident-layout ``local_weights``, and
      per-client losses are scattered back to resident layout — so a
      k-client cohort pays ``cap`` local updates per shard instead of
      ``C_loc``.  Eq.-(6) stays the same partial weighted sums over the same
      nonzero terms (zero-weight slots contribute exact zeros) and the
      single psum rendezvous is unchanged, so aggregation matches resident
      mode to fp32 tolerance.

    Both modes return ``(agg_params, client_losses, mean_loss, extras)``:
    the aggregated global params (replicated), the per-shard client losses
    ``(C_loc,)`` (mean over local steps; **NaN for every client outside the
    round's cohort** — the documented masking convention, so an unselected
    client's stale/zero-weight loss can never be mistaken for a cohort
    measurement), the cohort mean local loss (replicated), and ``extras``
    summed over the axis — callers fold their own per-shard partials (e.g.
    GEMD numerators) into the round's single psum rendezvous instead of
    paying a second one.

    ``update_transform`` (DESIGN.md §11) is the fault-injection +
    update-validation guard from ``repro.fl.faults.make_update_guard``.
    When set, both modes accept ``guard_args=()`` — the per-shard (or
    per-slot) fault-mask rows — apply the guard between the local updates
    and the partial weighted sums (strictly *before* the single psum, so a
    rejected update never crosses a device boundary), and the surviving-
    client count rides that same psum: the return grows to ``(agg,
    client_losses, mean_loss, extras, flagged, survivors)`` with ``flagged``
    in resident layout.  When ``None`` the legacy signature, return, and
    compiled graph are untouched.

    ``algo`` (DESIGN.md §12) selects the local-update algorithm (``None`` =
    FedAvg, legacy-identical graph).  A *stateful* algorithm adds a
    required keyword ``local_states`` — this shard's resident-layout state
    slice, leaves leading ``(C_loc, ...)`` — and appends the candidate new
    states (same layout; slot mode gathers states by ``slot_index`` and
    scatters the trained slots back, untouched residents keep their old
    state) as the final return element.  Per-device state, never psum'd:
    the caller owns the masked write-back.
    """
    local_update = build_local_algo_update(
        algo, loss_fn, lr, grad_clip=grad_clip, unroll=unroll,
        micro_batches=micro_batches,
    )
    stateful = algo is not None and algo.stateful

    def _updates(global_params, batches, n, states=None):
        per_client = jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x, (n,) + x.shape), global_params
        )
        operands = (
            (per_client, states, batches) if stateful else (per_client, batches)
        )
        if sequential_clients:
            out = jax.lax.map(lambda args: local_update(*args), operands)
        else:
            out = jax.vmap(local_update)(*operands)
        if stateful:
            new_params, new_states, losses = out
            return new_params, losses, new_states
        new_params, losses = out
        return new_params, losses, None

    def _aggregate(new_params, losses, weights, extras, survivors_local=None):
        # eq. (6) as partial weighted sums: Σ_c w_c·θ_c / Σ_c w_c.  ALL the
        # round's partial reductions ride ONE psum call so the per-round
        # cross-device rendezvous count stays constant in tree size.
        w = weights.astype(jnp.float32)
        mask = (w > 0).astype(jnp.float32)
        entry_losses = jnp.mean(losses, axis=tuple(range(1, losses.ndim)))
        # NaN-aware cohort mean: only finite cohort entries enter tot/cnt
        # (``where``, never ``mask·x`` — 0·NaN = NaN).  All-finite inputs
        # keep the exact pre-guard values: same entries, same reduction
        # order.  A round with no finite cohort entry reports NaN, not 0.
        ok = (mask > 0) & jnp.isfinite(entry_losses)

        def part_leaf(x):
            wb = w.reshape((-1,) + (1,) * (x.ndim - 1))
            return jnp.sum(wb * x.astype(jnp.float32), axis=0)

        partials = jax.tree_util.tree_map(part_leaf, new_params)
        reduced = (
            partials,
            jnp.sum(w),
            jnp.sum(jnp.where(ok, entry_losses, jnp.zeros((), entry_losses.dtype))),
            jnp.sum(ok.astype(jnp.float32)),
            extras,
        )
        if survivors_local is not None:
            reduced = reduced + (survivors_local,)
        reduced = lax.psum(reduced, axis)
        partials, wsum, tot, cnt, extras = reduced[:5]
        inv = safe_div(jnp.float32(1.0), wsum)
        agg = jax.tree_util.tree_map(
            lambda part, x: (part * inv).astype(x.dtype), partials, new_params
        )
        mean_loss = jnp.where(cnt > 0, tot / jnp.maximum(cnt, 1.0), jnp.nan)
        masked_losses = jnp.where(mask > 0, entry_losses, jnp.nan)
        if survivors_local is None:
            return agg, masked_losses, mean_loss, extras
        return agg, masked_losses, mean_loss, extras, reduced[5]

    def round_step(
        global_params, local_batches, local_weights, extras=None, guard_args=(),
        local_states=None,
    ):
        new_params, losses, new_states = _updates(
            global_params, local_batches, local_weights.shape[0], local_states
        )
        if update_transform is None:
            out = _aggregate(new_params, losses, local_weights, extras)
            return out + (new_states,) if stateful else out
        new_params, w, losses, flagged = update_transform(
            new_params, global_params, local_weights, losses, *guard_args
        )
        survivors_local = jnp.sum((w > 0).astype(jnp.int32))
        agg, client_losses, mean_loss, extras, survivors = _aggregate(
            new_params, losses, w, extras, survivors_local
        )
        out = (agg, client_losses, mean_loss, extras, flagged, survivors)
        return out + (new_states,) if stateful else out

    def slot_round_step(
        global_params, slot_batches, local_weights, slot_index, extras=None,
        guard_args=(), local_states=None,
    ):
        slot_states = (
            jax.tree_util.tree_map(
                lambda s: jnp.take(s, slot_index, axis=0), local_states
            )
            if stateful
            else None
        )
        new_params, losses, new_slot_states = _updates(
            global_params, slot_batches, cap, slot_states
        )
        if stateful:
            # scatter trained slot states back to resident layout; residents
            # no slot covered keep their old state (their refresh mask is
            # False anyway — weight-0 padding slots never pass write-back)
            new_states = jax.tree_util.tree_map(
                lambda full, slot_new: full.at[slot_index].set(slot_new),
                local_states, new_slot_states,
            )
        else:
            new_states = None
        slot_weights = jnp.take(local_weights, slot_index)
        if update_transform is not None:
            new_params, slot_weights, losses, slot_flagged = update_transform(
                new_params, global_params, slot_weights, losses, *guard_args
            )
            survivors_local = jnp.sum((slot_weights > 0).astype(jnp.int32))
            agg, slot_losses, mean_loss, extras, survivors = _aggregate(
                new_params, losses, slot_weights, extras, survivors_local
            )
        else:
            agg, slot_losses, mean_loss, extras = _aggregate(
                new_params, losses, slot_weights, extras
            )
        # scatter slot losses back to resident layout; everything the slots
        # did not cover (and weight-0 padding slots) stays NaN by convention
        client_losses = (
            jnp.full(local_weights.shape, jnp.nan, slot_losses.dtype)
            .at[slot_index]
            .set(slot_losses)
        )
        if update_transform is None:
            out = (agg, client_losses, mean_loss, extras)
            return out + (new_states,) if stateful else out
        # scatter flags the same way: padding slots carry weight 0, so they
        # can never be flagged and the scatter stays collision-free
        flagged = (
            jnp.zeros(local_weights.shape, jnp.bool_)
            .at[slot_index]
            .set(slot_flagged)
        )
        out = (agg, client_losses, mean_loss, extras, flagged, survivors)
        return out + (new_states,) if stateful else out

    return round_step if cap is None else slot_round_step


def build_stale_shard_cohort_round(
    loss_fn: LossFn,
    lr: float,
    axis: str,
    grad_clip: Optional[float] = None,
    unroll=1,
    sequential_clients: bool = True,
    micro_batches: int = 1,
    update_transform: Optional[Callable] = None,
    algo=None,
) -> Callable[..., Tuple[PyTree, jax.Array, jax.Array, Any]]:
    """Bounded-staleness variant of :func:`build_shard_cohort_round`
    (DESIGN.md §9) — same residents, same local updates, same single psum,
    but the shard's *base* params are stale.

    Must run inside a ``shard_map`` body over ``axis``.
    ``round_step(param_hist, read_slot, stale_scale, local_batches,
    local_weights, extras=None)`` where ``param_hist`` is the replicated
    ring buffer of global param snapshots (leaves lead with ``(s+1, ...)``,
    see ``repro.fl.staleness``), ``read_slot`` is this shard's ring index
    (the round-``t − s_d`` snapshot) and ``stale_scale`` is its
    staleness-decay weight λ(s_d).

    The shard reads its base params from the ring, runs the standard
    resident-mode local updates (:func:`build_local_algo_update` via the
    synchronous round — bit-identical per-client math), and contributes
    eq.-(6) partial weighted sums with weights ``λ(s_d)·w_c`` to the SAME
    single psum rendezvous; the psum'd ``Σ λw`` denominator normalises the
    decay (``core.metrics.safe_div``), so the aggregate is a convex
    combination across shards of different staleness.  ``stale_scale`` must
    be > 0 (every decay family satisfies this), which preserves the
    weight-0 ⟺ non-cohort NaN loss-masking convention unchanged; with
    ``read_slot`` pointing at the current round and ``stale_scale = 1`` the
    step is bit-identical to the synchronous round.

    ``algo`` (DESIGN.md §12) passes through to the inner resident round;
    a stateful algorithm adds the ``local_states`` keyword / trailing
    candidate-state return.  The drift-correction anchor is the shard's
    *stale* ring read — the params the clients actually trained from —
    because the inner round anchors to its entry base params.
    """
    inner = build_shard_cohort_round(
        loss_fn, lr, axis, grad_clip=grad_clip, unroll=unroll,
        sequential_clients=sequential_clients, micro_batches=micro_batches,
        update_transform=update_transform, algo=algo,
    )

    def round_step(
        param_hist, read_slot, stale_scale, local_batches, local_weights,
        extras=None, guard_args=(), local_states=None,
    ):
        # the guard's base params are the shard's *stale* ring read — update
        # norms are measured against the params the clients actually trained
        # from, and λ > 0 keeps the weight-0 ⟺ rejected/non-cohort
        # convention intact under the staleness-decay scaling
        base = jax.tree_util.tree_map(
            lambda h: lax.dynamic_index_in_dim(h, read_slot, 0, keepdims=False),
            param_hist,
        )
        if update_transform is None:
            return inner(
                base, local_batches, local_weights * stale_scale, extras=extras,
                local_states=local_states,
            )
        return inner(
            base, local_batches, local_weights * stale_scale, extras=extras,
            guard_args=guard_args, local_states=local_states,
        )

    return round_step


def build_server_opt_round(
    loss_fn: LossFn,
    client_lr: float,
    local_steps: int,
    server_optimizer: optim_lib.Optimizer,
    grad_clip: Optional[float] = None,
) -> Callable:
    """Beyond-paper: FedOpt (Reddi et al.) on top of Mode-A rounds.

    The eq.-(6) aggregate is reinterpreted as a *pseudo-gradient*
    ``Δ = w_global − avg(w_clients)`` and fed to a server optimizer
    (momentum/Adam), which is known to stabilise non-IID training — and
    composes orthogonally with DPP cohort selection.

    ``round_step(params, server_state, batches, weights) ->
    (params, server_state, loss)``.
    """
    inner = build_client_parallel_round(loss_fn, client_lr, local_steps, grad_clip)

    def round_step(params, server_state, client_batches, client_weights):
        agg, loss = inner(params, client_batches, client_weights)
        pseudo_grad = jax.tree_util.tree_map(
            lambda w, a: (w.astype(jnp.float32) - a.astype(jnp.float32)), params, agg
        )
        updates, server_state = server_optimizer.update(pseudo_grad, server_state, params)
        params = optim_lib.apply_updates(params, updates)
        return params, server_state, loss

    return round_step


def build_fedsgd_step(
    loss_fn: LossFn,
    optimizer: optim_lib.Optimizer,
    grad_clip: Optional[float] = None,
    micro_batches: int = 1,
) -> Callable:
    """Mode B step: one optimizer step on the weighted global gradient.

    ``step(params, opt_state, batch) -> (params, opt_state, loss)``.  The
    batch carries all selected clients' data; per-client weighting happens via
    the sample dimension (uniform n_c ⇒ plain mean, matching eq. 9).
    ``micro_batches`` accumulates the gradient over batch slices (exact).
    """

    def grad_of(params, batch):
        if micro_batches == 1:
            return jax.value_and_grad(loss_fn)(params, batch)
        micro = jax.tree_util.tree_map(
            lambda x: x.reshape((micro_batches, x.shape[0] // micro_batches) + x.shape[1:]),
            batch,
        )

        def acc(carry, mb):
            tot_l, tot_g = carry
            l, g = jax.value_and_grad(loss_fn)(params, mb)
            return (tot_l + l, jax.tree_util.tree_map(jnp.add, tot_g, g)), None

        zeros = jax.tree_util.tree_map(lambda w: jnp.zeros(w.shape, jnp.float32), params)
        (loss, g), _ = lax.scan(acc, (jnp.zeros((), jnp.float32), zeros), micro)
        inv = 1.0 / micro_batches
        return loss * inv, jax.tree_util.tree_map(lambda x: x * inv, g)

    def step(params, opt_state, batch):
        loss, g = grad_of(params, batch)
        if grad_clip is not None:
            g = optim_lib.clip_by_global_norm(g, grad_clip)
        updates, opt_state = optimizer.update(g, opt_state, params)
        params = optim_lib.apply_updates(params, updates)
        return params, opt_state, loss

    return step

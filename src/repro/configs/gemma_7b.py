"""gemma-7b [dense]: 28L d_model=3072 16H (kv=16, i.e. MHA) d_ff=24576
vocab=256000 — GeGLU, head_dim=256, MQA on the 2b sibling [arXiv:2403.08295].

Gemma particulars carried over: GeGLU MLP, embeddings scaled by √d_model,
q/k/v projected to 16·256 = 4096 (≠ d_model), logits over a 256k vocab (the
seq-chunked LM loss matters most here — see transformer.lm_loss)."""

from repro.configs.base import FLRunConfig, ModelConfig
from repro.configs.registry import SERVE_RULES, TRAIN_RULES, ArchSpec


def spec() -> ArchSpec:
    model = ModelConfig(
        name="gemma-7b",
        arch_type="dense",
        num_layers=28,
        d_model=3072,
        num_heads=16,
        num_kv_heads=16,
        head_dim=256,
        d_ff=24_576,
        vocab_size=256_000,
        block_pattern=("attn+mlp",),
        mlp_variant="geglu",
        embed_scale=True,
        rope_theta=10_000.0,
        tie_embeddings=True,
        param_dtype="bfloat16",
        dtype="bfloat16",
        remat=True,
    )
    rules_t = dict(TRAIN_RULES, kv_w="model")  # MHA: kv heads shard too
    rules_s = dict(SERVE_RULES, kv_w="model")
    return ArchSpec(
        model=model,
        fl=FLRunConfig(mode="client_parallel", local_steps=4, lr=2e-3),
        train_rules=rules_t,
        serve_rules=rules_s,
        optimizer="adam",
        long_context="swa_variant",
        notes="256k vocab: logits sharded over model axis; seq-chunked CE loss",
    )

"""internlm2-20b [dense]: 48L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=92544 — GQA [arXiv:2403.17297]."""

from repro.configs.base import FLRunConfig, ModelConfig
from repro.configs.registry import SERVE_RULES, TRAIN_RULES, ArchSpec


def spec() -> ArchSpec:
    model = ModelConfig(
        name="internlm2-20b",
        arch_type="dense",
        num_layers=48,
        d_model=6144,
        num_heads=48,
        num_kv_heads=8,
        head_dim=128,
        d_ff=16_384,
        vocab_size=92_544,
        block_pattern=("attn+mlp",),
        mlp_variant="swiglu",
        rope_theta=1_000_000.0,
        tie_embeddings=False,
        param_dtype="bfloat16",
        dtype="bfloat16",
        remat=True,
    )
    return ArchSpec(
        model=model,
        fl=FLRunConfig(mode="client_parallel", local_steps=2, lr=2e-3),
        train_rules=dict(TRAIN_RULES),
        serve_rules=dict(SERVE_RULES),
        optimizer="adam",
        long_context="swa_variant",
        notes="48 heads shard 16-way (3/chip); kv=8 replicated over model axis",
    )

"""smollm-360m [dense]: 32L d_model=960 15H (GQA kv=5) d_ff=2560
vocab=49152 — llama-arch small [hf:HuggingFaceTB/SmolLM-135M]."""

from repro.configs.base import FLRunConfig, ModelConfig
from repro.configs.registry import SERVE_RULES, TRAIN_RULES, ArchSpec


def spec() -> ArchSpec:
    model = ModelConfig(
        name="smollm-360m",
        arch_type="dense",
        num_layers=32,
        d_model=960,
        num_heads=15,
        num_kv_heads=5,
        head_dim=64,
        d_ff=2560,
        vocab_size=49_152,
        block_pattern=("attn+mlp",),
        mlp_variant="swiglu",
        rope_theta=10_000.0,
        tie_embeddings=True,
        param_dtype="bfloat16",
        dtype="bfloat16",
        remat=True,
    )
    # 15 heads / kv=5 divide neither 16 nor 32: attention projections shard
    # on the embed dims (960 = 16·60 = 32·30) — DESIGN.md §3.
    rules_t = dict(TRAIN_RULES, heads_w=None, attn_in_w="model")
    rules_s = dict(SERVE_RULES, heads_w=None, attn_in_w="model", attn_out_w="model")
    return ArchSpec(
        model=model,
        fl=FLRunConfig(mode="client_parallel", local_steps=8, lr=5e-3),
        train_rules=rules_t,
        serve_rules=rules_s,
        optimizer="adam",
        long_context="swa_variant",
        notes="15 heads -> attention sharded on embed; model axis on d_ff/vocab",
    )

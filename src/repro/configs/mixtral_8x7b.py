"""mixtral-8x7b [moe]: 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=32000, 8 experts top-2, sliding-window attention [arXiv:2401.04088].

Every layer is SWA (window 4096) + MoE; softmax-over-top-2 routing.  With
SWA part of the published arch, long_500k is *native* (window-sized cache).
8 experts < 16-way model axis ⇒ experts replicate and d_ff shards
("expert-slice" tensor parallelism); the FSDP axis covers the expert embed
dim in training."""

from repro.configs.base import FLRunConfig, ModelConfig
from repro.configs.registry import SERVE_RULES, TRAIN_RULES, ArchSpec


def spec() -> ArchSpec:
    model = ModelConfig(
        name="mixtral-8x7b",
        arch_type="moe",
        num_layers=32,
        d_model=4096,
        num_heads=32,
        num_kv_heads=8,
        head_dim=128,
        d_ff=14_336,
        vocab_size=32_000,
        block_pattern=("swa+moe",),
        mlp_variant="swiglu",
        rope_theta=1_000_000.0,
        window=4096,
        num_experts=8,
        experts_per_token=2,
        router_type="softmax",
        capacity_factor=1.25,
        tie_embeddings=False,
        param_dtype="bfloat16",
        dtype="bfloat16",
        remat=True,
    )
    rules_t = dict(TRAIN_RULES, experts_w=None, expert_embed_w="data", expert_mlp_w="model")
    rules_s = dict(SERVE_RULES, experts_w=None, expert_mlp_w="model")
    return ArchSpec(
        model=model,
        fl=FLRunConfig(mode="client_parallel", local_steps=2, lr=2e-3),
        train_rules=rules_t,
        serve_rules=rules_s,
        optimizer="adafactor",
        long_context="native",
        notes="SWA 4096 native; experts replicated, d_ff tensor-parallel",
    )

"""granite-3-2b [dense]: 40L d_model=2048 32H (GQA kv=8) d_ff=8192
vocab=49155 — GQA [hf:ibm-granite/granite-3.0-2b-base]."""

from repro.configs.base import FLRunConfig, ModelConfig
from repro.configs.registry import SERVE_RULES, TRAIN_RULES, ArchSpec


def spec() -> ArchSpec:
    model = ModelConfig(
        name="granite-3-2b",
        arch_type="dense",
        num_layers=40,
        d_model=2048,
        num_heads=32,
        num_kv_heads=8,
        head_dim=64,
        d_ff=8192,
        vocab_size=49_155,
        block_pattern=("attn+mlp",),
        mlp_variant="swiglu",
        rope_theta=10_000.0,
        tie_embeddings=True,
        param_dtype="bfloat16",
        dtype="bfloat16",
        remat=True,
    )
    return ArchSpec(
        model=model,
        fl=FLRunConfig(mode="client_parallel", local_steps=4, lr=3e-3),
        train_rules=dict(TRAIN_RULES),
        serve_rules=dict(SERVE_RULES),
        optimizer="adam",
        long_context="swa_variant",
        notes="vocab 49155 padded to 49280 (multiple of 128) for sharding",
    )

"""rwkv6-7b [ssm]: 32L d_model=4096 (attention-free) d_ff=14336
vocab=65536 — Finch, data-dependent decay [arXiv:2404.05892].

64 WKV heads of dim 64; decode state is O(1) in sequence length
(tm_x + (H, 64, 64) wkv state + cm_x per layer) ⇒ long_500k is native.
The paper's technique applies unchanged: profiles are activation means and
the k-DPP never looks at the mixer type (DESIGN.md §3)."""

from repro.configs.base import FLRunConfig, ModelConfig
from repro.configs.registry import SERVE_RULES, TRAIN_RULES, ArchSpec


def spec() -> ArchSpec:
    model = ModelConfig(
        name="rwkv6-7b",
        arch_type="ssm",
        num_layers=32,
        d_model=4096,
        num_heads=64,  # wkv heads (d_model / rwkv_head_dim)
        num_kv_heads=64,
        head_dim=64,
        d_ff=14_336,
        vocab_size=65_536,
        block_pattern=("rwkv+cmix",),
        pos_style="none",
        rwkv_head_dim=64,
        tie_embeddings=False,
        param_dtype="bfloat16",
        dtype="bfloat16",
        remat=True,
    )
    return ArchSpec(
        model=model,
        fl=FLRunConfig(mode="client_parallel", local_steps=2, lr=2e-3),
        train_rules=dict(TRAIN_RULES),
        serve_rules=dict(SERVE_RULES),
        optimizer="adam",
        long_context="native",
        notes="wkv state (B, 64, 64, 64) shards (data, model) per layer",
    )

"""Model / sharding / FL configuration dataclasses.

``ModelConfig`` describes any of the assigned architectures (dense GQA, MoE,
RG-LRU hybrid, RWKV6, VLM, audio) for the composable decoder in
``repro.models.transformer``.  ``ShardingRules`` maps *logical* axes to mesh
axes per execution mode (MaxText-style logical-axis rules); each arch config
overrides what it must (e.g. smollm's 15 heads can't shard over a 16-way
``model`` axis — it shards attention on ``embed`` instead).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

__all__ = ["ModelConfig", "ShardingRules", "FLRunConfig", "INPUT_SHAPES", "InputShape"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str  # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    # block pattern: the repeating unit of "mixer+ffn" layer specs; layers =
    # pattern * (num_layers // len(pattern)) + pattern[:remainder].
    # mixers: attn | swa | local | rglru | rwkv;  ffns: mlp | moe | cmix.
    block_pattern: Tuple[str, ...] = ("attn+mlp",)
    mlp_variant: str = "swiglu"  # swiglu | geglu | gelu
    norm_type: str = "rmsnorm"  # rmsnorm | layernorm
    pos_style: str = "rope"  # rope | mrope | sinusoidal | none
    rope_theta: float = 10_000.0
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)  # qwen2-vl (t, h, w)
    window: int = 4096  # SWA window for "local_attn" blocks / long-context variant
    # query-chunked attention (exact; flash-like memory): live scores are
    # (B, Hk, G, chunk, Skv) instead of (…, Sq, Skv).  chunk >= Sq degrades
    # to the naive single-block path, so smoke tests are unaffected.
    attention_chunk: Optional[int] = 512
    embed_scale: bool = False  # gemma: scale embeddings by sqrt(d_model)
    logits_soft_cap: Optional[float] = None
    tie_embeddings: bool = True
    # MoE
    num_experts: int = 0
    experts_per_token: int = 0
    router_type: str = "softmax"  # softmax (mixtral) | sigmoid (llama4)
    shared_expert: bool = False  # llama4 shared expert alongside routed ones
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    # RWKV
    rwkv_head_dim: int = 64
    # hybrid (recurrentgemma)
    rnn_width: Optional[int] = None  # d_rnn (defaults to d_model)
    local_window: int = 2048  # griffin local-attention window
    # numerics
    param_dtype: str = "float32"  # smoke tests fp32; dry-run configs bf16
    dtype: str = "float32"  # activation dtype
    remat: bool = False  # activation checkpointing over the layer scan
    # scan unrolling: 1 = rolled while-loop (production; compact HLO),
    # True = fully unrolled (cost-accounting dry-runs: XLA's cost analysis
    # counts while bodies ONCE, so rolled loops undercount flops/bytes —
    # see EXPERIMENTS.md §Roofline methodology).
    scan_unroll: object = 1
    loss_chunk: int = 512  # sequence chunking of the CE loss
    loss_unroll: object = 1  # unroll of the loss chunk scan (accounting)

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    def layer_types(self) -> Tuple[str, ...]:
        p = self.block_pattern
        reps, rem = divmod(self.num_layers, len(p))
        return p * reps + p[:rem]

    def reduced(self, **overrides) -> "ModelConfig":
        """A smoke-test-sized variant of the same family (<=2 repeat units,
        d_model<=512, <=4 experts) — per the assignment's smoke-test rule."""
        small: Dict = dict(
            num_layers=min(self.num_layers, 2 * len(self.block_pattern)),
            d_model=min(self.d_model, 256),
            num_heads=min(self.num_heads, 4),
            num_kv_heads=min(self.num_kv_heads, 2),
            head_dim=64,
            d_ff=min(self.d_ff, 512),
            vocab_size=min(self.vocab_size, 512),
            rnn_width=None if self.rnn_width is None else 256,
            rwkv_head_dim=min(self.rwkv_head_dim, 64),
            window=min(self.window, 64),
            local_window=min(self.local_window, 64),
        )
        if self.num_experts:
            small["num_experts"] = min(self.num_experts, 4)
            small["experts_per_token"] = min(self.experts_per_token, 2)
        if self.pos_style == "mrope":
            # rescale the (t, h, w) frequency sections to the reduced head dim
            old_d2 = sum(self.mrope_sections)
            new_d2 = small["head_dim"] // 2
            t = max(1, self.mrope_sections[0] * new_d2 // old_d2)
            h = max(1, self.mrope_sections[1] * new_d2 // old_d2)
            small["mrope_sections"] = (t, h, new_d2 - t - h)
        # keep head structure consistent: kv must divide q heads
        if small["num_heads"] % small["num_kv_heads"]:
            small["num_kv_heads"] = 1
        small.update(overrides)
        return dataclasses.replace(self, **small)


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """Logical-axis -> mesh-axis mapping (None = replicate).

    Logical axes used by the model code:
      batch, seq, embed, q_heads, kv_heads, head_dim, mlp, vocab, experts,
      expert_mlp, rnn, clients (Mode-A leading client axis).
    """

    rules: Dict[str, Optional[str]]

    def axis(self, logical: str):
        return self.rules.get(logical)

    def spec(self, *logical: Optional[str]):
        """Build a PartitionSpec-compatible tuple for the given logical dims."""
        return tuple(self.rules.get(l) if l else None for l in logical)


@dataclasses.dataclass(frozen=True)
class FLRunConfig:
    """How FL rounds execute for an architecture (DESIGN.md §2)."""

    mode: str = "client_parallel"  # client_parallel (Mode A) | fedsgd_fsdp (Mode B)
    local_steps: int = 4  # E (Mode A); Mode B is inherently E = 1
    lr: float = 1e-2
    optimizer: str = "sgd"  # Mode-B server optimizer: sgd | adam | adafactor
    micro_batches: int = 4  # grad-accumulation within each local step (exact)


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


INPUT_SHAPES: Dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}

"""Registry of the 10 assigned architectures.

Each ``src/repro/configs/<id>.py`` exposes ``spec() -> ArchSpec`` with the
exact published configuration (cited in its docstring) plus its sharding
rules and FL execution mode.  ``get_arch(name)`` is the single lookup used by
launchers, smoke tests, and benchmarks (``--arch <id>``).
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, Optional

from repro.configs.base import FLRunConfig, ModelConfig

__all__ = ["ArchSpec", "get_arch", "ARCH_NAMES"]

ARCH_NAMES = [
    "granite-3-2b",
    "qwen2-vl-2b",
    "internlm2-20b",
    "smollm-360m",
    "gemma-7b",
    "recurrentgemma-9b",
    "llama4-maverick-400b-a17b",
    "rwkv6-7b",
    "mixtral-8x7b",
    "musicgen-medium",
]

_MODULES = {n: "repro.configs." + n.replace("-", "_") for n in ARCH_NAMES}


# Baseline logical->mesh rules (DESIGN.md §3); arch modules override entries.
# 'data' is widened to ('pod','data') automatically on the multi-pod mesh.
SERVE_RULES: Dict[str, Optional[str]] = {
    "act_batch": "data",
    "act_seq": None,
    "act_embed": None,
    "embed_w": None,
    "embed_w_vec": None,
    "vocab_w": "model",
    "heads_w": "model",
    "attn_in_w": None,
    "attn_out_w": None,
    "kv_w": None,  # most assigned archs have kv_heads < 16 -> replicate
    "mlp_w": "model",
    "att_w": "model",
    "rnn_w": "model",
    "experts_w": None,
    "expert_embed_w": None,
    "expert_mlp_w": "model",
    "cache_seq": "model",
    "embed_act": None,
    "rwkv_heads": "model",
    "act_experts": None,
    # hillclimb-gated logical axes (§Perf): default None = baseline behavior
    "att_vec_w": None,  # rwkv decay/group-norm vectors co-sharded with att_w
    "act_rwkv_h": None,  # explicit head sharding of the wkv r/k/v/w tensors
    "act_attn_b": None,  # batch-parallel attention (archs whose heads can't
    "act_attn_h": None,  # shard) / explicit head sharding of q/k/v
    "act_attn_kv": None,
    "act_inner_b": None,  # Mode-A per-client local batch dim
}

TRAIN_RULES: Dict[str, Optional[str]] = dict(
    SERVE_RULES,
    embed_w="data",  # FSDP-style second axis on the big matrices
    attn_in_w="data",
    attn_out_w="data",
    expert_embed_w=None,
)


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    model: ModelConfig
    fl: FLRunConfig
    train_rules: Dict[str, Optional[str]]
    serve_rules: Dict[str, Optional[str]]
    optimizer: str = "adam"  # Mode-B / pretrain optimizer
    long_context: str = "swa_variant"  # native | swa_variant
    notes: str = ""

    def long_context_model(self) -> ModelConfig:
        """Model config used for the long_500k shape."""
        if self.long_context == "native":
            return self.model
        pattern = tuple(
            b.replace("attn+", "swa+") for b in self.model.block_pattern
        )
        return dataclasses.replace(self.model, block_pattern=pattern)


def get_arch(name: str) -> ArchSpec:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {ARCH_NAMES}")
    return importlib.import_module(_MODULES[name]).spec()

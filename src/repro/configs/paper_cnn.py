"""The paper's own experimental configuration (§4).

CNN: two conv layers + two fully-connected layers; C = 100 clients,
C_p = 10 per round, MNIST/Fashion-MNIST-scale data (60k samples, 10 classes,
28×28), skewness ξ ∈ {0.5, 0.8, 'H', 1}.  ``bench_scale()`` is the
CPU-budget variant used by the benchmark harness (same protocol, smaller
round count / client datasets; the paper's qualitative claims are scale-free).
"""

from __future__ import annotations

import dataclasses

from repro.fl.trainer import FLConfig

XIS = (0.5, 0.8, "H", 1.0)
INIT_SCHEMES = ("kaiming_uniform", "kaiming_normal", "xavier_uniform", "xavier_normal")
METHODS = ("fl-dp3s", "cluster", "fedavg", "fedsae")


@dataclasses.dataclass(frozen=True)
class PaperExperiment:
    num_clients: int = 100
    clients_per_round: int = 10
    samples_per_client: int = 600
    local_epochs: int = 2
    lr: float = 0.05
    rounds: int = 300
    eval_every: int = 5
    seeds: int = 50
    cnn_channels: tuple = (16, 32)
    fc1_dim: int = 128


def paper_scale() -> PaperExperiment:
    return PaperExperiment()


def bench_scale() -> PaperExperiment:
    """CPU-feasible protocol: same C/C_p ratio and selection mechanics."""
    return PaperExperiment(
        num_clients=40,
        clients_per_round=10,
        samples_per_client=60,
        local_epochs=2,
        lr=0.08,
        rounds=30,
        eval_every=3,
        seeds=1,
        cnn_channels=(8, 16),
        fc1_dim=64,
    )


def fl_config(exp: PaperExperiment, seed: int = 0) -> FLConfig:
    return FLConfig(
        num_clients=exp.num_clients,
        clients_per_round=exp.clients_per_round,
        local_epochs=exp.local_epochs,
        lr=exp.lr,
        rounds=exp.rounds,
        eval_every=exp.eval_every,
        seed=seed,
    )

"""Architecture configs (assigned pool) + registry."""

from repro.configs.base import INPUT_SHAPES, FLRunConfig, InputShape, ModelConfig
from repro.configs.registry import ARCH_NAMES, ArchSpec, get_arch

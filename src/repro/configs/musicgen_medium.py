"""musicgen-medium [audio]: 48L d_model=1536 24H (kv=24, MHA) d_ff=6144
vocab=2048 — decoder-only over EnCodec tokens [arXiv:2306.05284].

The EnCodec conv codec (mel frontend) is a STUB per the assignment:
``input_specs()`` feeds codebook token ids (vocab 2048); this module is the
acoustic-token decoder (LayerNorm + GELU + sinusoidal positions, MHA)."""

from repro.configs.base import FLRunConfig, ModelConfig
from repro.configs.registry import SERVE_RULES, TRAIN_RULES, ArchSpec


def spec() -> ArchSpec:
    model = ModelConfig(
        name="musicgen-medium",
        arch_type="audio",
        num_layers=48,
        d_model=1536,
        num_heads=24,
        num_kv_heads=24,
        head_dim=64,
        d_ff=6144,
        vocab_size=2048,
        block_pattern=("attn+mlp",),
        mlp_variant="gelu",
        norm_type="layernorm",
        pos_style="sinusoidal",
        tie_embeddings=False,
        param_dtype="bfloat16",
        dtype="bfloat16",
        remat=True,
    )
    # 24 heads: 24 % 16 != 0 -> attention shards on embed (1536 = 16·96).
    rules_t = dict(TRAIN_RULES, heads_w=None, attn_in_w="model", vocab_w=None)
    rules_s = dict(
        SERVE_RULES, heads_w=None, attn_in_w="model", attn_out_w="model", vocab_w=None
    )
    return ArchSpec(
        model=model,
        fl=FLRunConfig(mode="client_parallel", local_steps=8, lr=3e-3),
        train_rules=rules_t,
        serve_rules=rules_s,
        optimizer="adam",
        long_context="swa_variant",
        notes="EnCodec frontend stubbed (token ids in); vocab 2048 replicated",
    )

"""llama4-maverick-400b-a17b [moe]: 48L d_model=5120 40H (GQA kv=8) d_ff=8192
vocab=202048, MoE 128 experts top-1 — MoE, early fusion
[hf:meta-llama/Llama-4-Scout-17B-16E].

Maverick interleaves MoE every other layer (period 2) with a shared expert
next to the 128 routed experts and a sigmoid top-1 router:
24 MoE layers × 128 × 3 × 5120 × 8192 ≈ 386 B routed params + dense/attn
≈ 400 B total, ~17 B active per token.

This is the only Mode-B (FedSGD/FSDP) architecture: per-client parameter
copies cannot fit HBM (DESIGN.md §2), and the optimizer is Adafactor so the
second-moment state is O(n+m) per matrix.  Expert weights shard over BOTH
mesh axes: experts over ``data`` (128/16 = 8 per row), d_ff over ``model``
— real expert parallelism; XLA inserts the dispatch all-to-alls (§Roofline).
"""

from repro.configs.base import FLRunConfig, ModelConfig
from repro.configs.registry import SERVE_RULES, TRAIN_RULES, ArchSpec


def spec() -> ArchSpec:
    model = ModelConfig(
        name="llama4-maverick-400b-a17b",
        arch_type="moe",
        num_layers=48,
        d_model=5120,
        num_heads=40,
        num_kv_heads=8,
        head_dim=128,
        d_ff=8192,
        vocab_size=202_048,
        block_pattern=("attn+mlp", "attn+moe"),  # MoE every other layer
        mlp_variant="swiglu",
        rope_theta=500_000.0,
        num_experts=128,
        experts_per_token=1,
        router_type="sigmoid",
        shared_expert=True,
        capacity_factor=1.25,
        tie_embeddings=False,
        param_dtype="bfloat16",
        dtype="bfloat16",
        remat=True,
    )
    rules_t = dict(
        TRAIN_RULES,
        heads_w="model",  # 40 heads: 40 % 16 != 0 -> see below
        experts_w="data",
        expert_mlp_w="model",
        act_experts="data",
    )
    # 40 heads don't divide 16 -> shard attention on embed dims instead.
    rules_t.update(heads_w=None, attn_in_w="model")
    rules_s = dict(
        SERVE_RULES,
        heads_w=None,
        attn_in_w="model",
        attn_out_w="model",
        experts_w="data",
        expert_mlp_w="model",
        act_experts="data",
    )
    return ArchSpec(
        model=model,
        fl=FLRunConfig(mode="fedsgd_fsdp", local_steps=1, lr=1e-3, micro_batches=8),
        train_rules=rules_t,
        serve_rules=rules_s,
        optimizer="adafactor",
        long_context="swa_variant",
        notes=(
            "Mode B (E=1 FedSGD, eq. 9): 800 GB bf16 params can't replicate "
            "per client; experts sharded (data=experts, model=d_ff); vocab "
            "202048 padded to 202112"
        ),
    )

"""recurrentgemma-9b [hybrid]: 38L d_model=4096 16H (kv=1, MQA) d_ff=12288
vocab=256000 — RG-LRU + local attention, 2 recurrent : 1 local [arXiv:2402.19427].

Griffin block pattern (rglru, rglru, local_attn) × 12 + 2 remainder recurrent
layers = 38.  The local-attention window is 2048; RG-LRU state is O(1) per
token ⇒ long_500k decode is *native* (no SWA variant needed)."""

from repro.configs.base import FLRunConfig, ModelConfig
from repro.configs.registry import SERVE_RULES, TRAIN_RULES, ArchSpec


def spec() -> ArchSpec:
    model = ModelConfig(
        name="recurrentgemma-9b",
        arch_type="hybrid",
        num_layers=38,
        d_model=4096,
        num_heads=16,
        num_kv_heads=1,
        head_dim=256,
        d_ff=12_288,
        vocab_size=256_000,
        block_pattern=("rglru+mlp", "rglru+mlp", "local+mlp"),
        mlp_variant="geglu",
        embed_scale=True,
        rope_theta=10_000.0,
        local_window=2048,
        rnn_width=4096,
        tie_embeddings=True,
        param_dtype="bfloat16",
        dtype="bfloat16",
        remat=True,
    )
    return ArchSpec(
        model=model,
        fl=FLRunConfig(mode="client_parallel", local_steps=2, lr=2e-3),
        train_rules=dict(TRAIN_RULES),
        serve_rules=dict(SERVE_RULES),
        optimizer="adam",
        long_context="native",
        notes="RG-LRU states shard (batch, rnn) over (data, model); MQA kv=1 replicated",
    )

"""qwen2-vl-2b [vlm]: 28L d_model=1536 12H (GQA kv=2) d_ff=8960
vocab=151936 — M-RoPE, dynamic resolution [arXiv:2409.12191].

The SigLIP-style vision tower + projector is a STUB per the assignment:
``input_specs()`` feeds precomputed patch/token embeddings of shape
(B, S, d_model) plus the (3, B, S) M-RoPE position streams (temporal /
height / width).  This module is the language decoder that consumes them.
"""

from repro.configs.base import FLRunConfig, ModelConfig
from repro.configs.registry import SERVE_RULES, TRAIN_RULES, ArchSpec


def spec() -> ArchSpec:
    model = ModelConfig(
        name="qwen2-vl-2b",
        arch_type="vlm",
        num_layers=28,
        d_model=1536,
        num_heads=12,
        num_kv_heads=2,
        head_dim=128,
        d_ff=8960,
        vocab_size=151_936,
        block_pattern=("attn+mlp",),
        mlp_variant="swiglu",
        pos_style="mrope",
        mrope_sections=(16, 24, 24),  # t/h/w frequency sections (sum = hd/2)
        rope_theta=1_000_000.0,
        tie_embeddings=True,
        param_dtype="bfloat16",
        dtype="bfloat16",
        remat=True,
    )
    # 12 heads don't divide the 16-way model axis; sharding the fused
    # 12·128 = 1536 head*hd dim 16-way would split head boundaries (RoPE /
    # attention math reshapes by head).  Instead attention projections shard
    # on the embed dims (1536 = 16·96 = 32·48).
    rules_t = dict(TRAIN_RULES, heads_w=None, attn_in_w="model")
    rules_s = dict(SERVE_RULES, heads_w=None, attn_in_w="model", attn_out_w="model")
    return ArchSpec(
        model=model,
        fl=FLRunConfig(mode="client_parallel", local_steps=4, lr=3e-3),
        train_rules=rules_t,
        serve_rules=rules_s,
        optimizer="adam",
        long_context="swa_variant",
        notes="vision frontend stubbed (embeddings in); M-RoPE sections 16/24/24",
    )

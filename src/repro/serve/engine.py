"""Scan-compiled continuous-batching serving engine (DESIGN.md §13).

The legacy ``launch/serve.py`` loop pays one host→device dispatch per token
and drains a whole batch before admitting new traffic.  This module rebuilds
serving the way ``fl/engine.py`` rebuilt the trainer — as a pure state
machine:

* :class:`DecodeState` — one pytree holding everything a slot batch evolves:
  per-slot model caches (``init_caches(..., per_slot=True)``: every slot at
  its own depth), the last sampled token, the generated-token buffer,
  per-slot generation counters/budgets, active/stop masks, and per-slot
  sampling key streams.
* :func:`make_decode_fn` — one decode step for **all** slots as a pure
  ``state -> state`` body: model ``decode_step`` (optionally through the
  Pallas flash-decode kernel), per-slot sampling, stop handling (budget
  reached or EOS), masked token write-back.  Inactive slots ride along with
  their updates masked — fixed shapes, zero recompilation.
* :func:`run_scan` / :func:`run_while` — N steps as one ``lax.scan``, or a
  while-scan that exits as soon as every slot has stopped (per-slot
  stopping with early wall-clock exit).
* :func:`make_admit_fn` — **slot-based continuous batching**: admit one
  queued sequence into the first free slot entirely at the jit level
  (prefill → sample the first token → scatter cache/buffer rows at the slot
  index via the PR-4 stable-argsort slot table).  Mixed-length traffic
  reuses the same compiled program for every admission — the engine asserts
  this (see :meth:`ServeEngine.compile_counts`).
* :class:`ServeEngine` — the host-side admission queue: chunked scan decode,
  harvest finished slots, refill from the queue, repeat.  The only host
  work is queue bookkeeping between compiled chunks.

Everything is arch-generic through ``models.transformer``: dense GQA caches,
SWA ring buffers (mixtral), RWKV/RG-LRU O(1) recurrent states — a slot row
is whatever the model's cache holds for one sequence.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import transformer as T
from repro.obs import tracing as obs_tracing_lib
from repro.serve.sampling import fresh_key_data, sample_tokens

__all__ = [
    "ServeConfig",
    "DecodeState",
    "init_decode_state",
    "make_decode_fn",
    "make_admit_fn",
    "run_scan",
    "run_while",
    "ServeEngine",
]

PyTree = Any


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Static serving knobs (trace constants)."""

    batch: int  # slot count B
    cache_len: int  # per-slot cache capacity (>= prompt + generation budget)
    max_new: int  # output buffer width (>= any per-slot budget)
    temperature: float = 0.0  # 0.0 = greedy (the parity-oracle path)
    eos_id: Optional[int] = None  # None = budget-only stopping
    use_flash: bool = False  # route decode attention through flash-decode
    decode_chunk: int = 8  # scan steps between admission checks

    def __post_init__(self):
        if self.batch < 1:
            raise ValueError(f"batch={self.batch} must be >= 1")
        if self.max_new < 1 or self.max_new > self.cache_len:
            raise ValueError(
                f"max_new={self.max_new} must be in [1, cache_len={self.cache_len}]"
            )
        if self.temperature < 0.0:
            raise ValueError(f"temperature={self.temperature} must be >= 0")
        if self.decode_chunk < 1:
            raise ValueError(f"decode_chunk={self.decode_chunk} must be >= 1")


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class DecodeState:
    """Everything a slot batch evolves, as one pytree (all leaves lead with
    the slot axis B except the caches, whose unit leaves lead with the layer
    stack: (reps, B, ...) — see ``_scatter_slot_rows``)."""

    caches: PyTree  # per-slot model caches (pos: (B,))
    last_tok: jax.Array  # (B, 1) int32 next decode input
    out_tokens: jax.Array  # (B, max_new) int32 generated tokens
    n_gen: jax.Array  # (B,) int32 generated so far (incl. prefill sample)
    gen_target: jax.Array  # (B,) int32 per-slot generation budget
    active: jax.Array  # (B,) bool slot is decoding
    seq_ids: jax.Array  # (B,) int32 sequence id; -1 = empty (occupancy: set
    # at admission, cleared only at harvest — unlike ``active``, which a
    # budget-1 admission or a stop clears before the host has the tokens)
    sample_keys: jax.Array  # (B, key_words) uint32 per-slot PRNG streams
    step: jax.Array  # () int32 decode steps taken


def init_decode_state(cfg: ModelConfig, scfg: ServeConfig,
                      key: Optional[jax.Array] = None) -> DecodeState:
    """All-empty slots; admission fills them."""
    b = scfg.batch
    key = jax.random.key(0) if key is None else key
    return DecodeState(
        caches=T.init_caches(cfg, b, scfg.cache_len, per_slot=True),
        last_tok=jnp.zeros((b, 1), jnp.int32),
        out_tokens=jnp.zeros((b, scfg.max_new), jnp.int32),
        n_gen=jnp.zeros((b,), jnp.int32),
        gen_target=jnp.zeros((b,), jnp.int32),
        active=jnp.zeros((b,), bool),
        seq_ids=jnp.full((b,), -1, jnp.int32),
        sample_keys=fresh_key_data(key, b),
        step=jnp.zeros((), jnp.int32),
    )


# ------------------------------------------------------------- decode step


def make_decode_fn(cfg: ModelConfig, scfg: ServeConfig) -> Callable:
    """Pure one-token step for all slots: ``(params, state) -> state``.

    Inactive slots run the model too (fixed shapes are the whole point) but
    every visible update — token write, counter, stop mask — is masked, and
    their sampled tokens are pinned to 0.  Their caches do advance; a slot's
    cache is only meaningful between admission and stop, and admission
    rewrites it wholesale.
    """

    def decode_fn(params: PyTree, state: DecodeState) -> DecodeState:
        logits, caches = T.decode_step(
            cfg, params, state.last_tok, state.caches, use_flash=scfg.use_flash
        )
        toks, keys = sample_tokens(logits, state.sample_keys, scfg.temperature)
        toks = jnp.where(state.active, toks, 0)

        # record into each slot's next free cell (masked; clip keeps the
        # scatter in bounds for exhausted slots)
        b = toks.shape[0]
        cell = jnp.minimum(state.n_gen, scfg.max_new - 1)
        cur = state.out_tokens[jnp.arange(b), cell]
        out = state.out_tokens.at[jnp.arange(b), cell].set(
            jnp.where(state.active, toks, cur)
        )
        n_gen = state.n_gen + state.active.astype(jnp.int32)

        # per-slot stopping: budget reached, or EOS sampled
        active = state.active & (n_gen < state.gen_target)
        if scfg.eos_id is not None:
            active &= toks != scfg.eos_id
        return DecodeState(
            caches=caches,
            last_tok=toks[:, None],
            out_tokens=out,
            n_gen=n_gen,
            gen_target=state.gen_target,
            active=active,
            seq_ids=state.seq_ids,
            sample_keys=keys,
            step=state.step + 1,
        )

    return decode_fn


def run_scan(decode_fn: Callable, params: PyTree, state: DecodeState,
             steps: int) -> DecodeState:
    """``steps`` decode steps as one ``lax.scan`` (fixed trip count)."""

    def body(s, _):
        return decode_fn(params, s), None

    state, _ = lax.scan(body, state, None, length=steps)
    return state


def run_while(decode_fn: Callable, params: PyTree, state: DecodeState,
              max_steps: int) -> DecodeState:
    """While-scan with per-slot stopping: exits as soon as every slot is
    done (or at ``max_steps``), so a batch of short sequences doesn't pay
    the long tail's wall-clock."""
    limit = state.step + max_steps

    def cond(s):
        return jnp.any(s.active) & (s.step < limit)

    return lax.while_loop(cond, lambda s: decode_fn(params, s), state)


# ----------------------------------------------------- slot-based admission


def _scatter_slot_rows(dst: jax.Array, src: jax.Array, slot: jax.Array,
                       axis: int) -> jax.Array:
    """Write ``src`` (one slot row, batch dim of size 1 at ``axis``) into
    ``dst`` at index ``slot`` along ``axis``."""
    idx = (slice(None),) * axis + (slot,)
    return dst.at[idx].set(jnp.squeeze(src, axis=axis))


def _scatter_caches(dst: PyTree, src: PyTree, slot: jax.Array) -> PyTree:
    """Slot-scatter a whole cache pytree: unit leaves are layer-stacked
    (reps, B, ...) -> batch at axis 1; remainder leaves lead with B."""
    unit = jax.tree_util.tree_map(
        lambda d, s: _scatter_slot_rows(d, s, slot, axis=1),
        dst["unit"], src["unit"],
    )
    rem = jax.tree_util.tree_map(
        lambda d, s: _scatter_slot_rows(d, s, slot, axis=0),
        dst["rem"], src["rem"],
    )
    return {"unit": unit, "rem": rem}


def make_admit_fn(cfg: ModelConfig, scfg: ServeConfig,
                  prompt_len: int) -> Callable:
    """Jit-level admission: prefill one queued sequence and install it in
    the first free slot.

    ``(params, state, prompt (1, P), gen_target (), seq_id (), key_data)
    -> state``.  The free slot comes from the PR-4 stable-argsort slot
    table (``argsort(seq_ids >= 0, stable=True)[0]`` — empty-first order),
    the prefill runs on a width-1 per-slot cache of the same ``cache_len``
    so every leaf scatters row-for-row, and the first token is sampled from
    the prefill logits with the sequence's own key stream.  One compiled
    program serves every admission — no retracing as traffic mixes lengths.

    Free means *unoccupied* (``seq_ids < 0``), not merely inactive: a
    budget-1 admission finishes at prefill and sits inactive-but-occupied
    until the host harvests it, and a second admission in the same refill
    wave must not overwrite that un-harvested result.
    """

    def admit_fn(params: PyTree, state: DecodeState, prompt: jax.Array,
                 gen_target: jax.Array, seq_id: jax.Array,
                 key_data: jax.Array) -> DecodeState:
        # slot table: stable argsort puts empty (seq_id < 0 -> False) slots
        # first; occupancy, not activity — see the docstring
        slot = jnp.argsort(state.seq_ids >= 0, stable=True)[0]

        caches1 = T.init_caches(cfg, 1, scfg.cache_len, per_slot=True)
        positions = jnp.arange(prompt_len, dtype=jnp.int32)[None, :]
        if cfg.pos_style == "mrope":
            positions = jnp.broadcast_to(positions[None], (3, 1, prompt_len))
        hidden, caches1, _ = T.forward(
            cfg, params, prompt, positions, caches1, use_flash=scfg.use_flash
        )
        logits = T.logits_from_hidden(cfg, params, hidden[:, -1:])
        tok, key_data = sample_tokens(logits, key_data[None], scfg.temperature)
        tok, key_data = tok[0], key_data[0]

        b = scfg.batch
        onehot = jnp.arange(b) == slot
        out_row = jnp.zeros((scfg.max_new,), jnp.int32).at[0].set(tok)
        return DecodeState(
            caches=_scatter_caches(state.caches, caches1, slot),
            last_tok=state.last_tok.at[slot, 0].set(tok),
            out_tokens=state.out_tokens.at[slot].set(out_row),
            n_gen=state.n_gen.at[slot].set(1),
            gen_target=state.gen_target.at[slot].set(gen_target),
            active=state.active | (onehot & (gen_target > 1)),
            seq_ids=state.seq_ids.at[slot].set(seq_id),
            sample_keys=state.sample_keys.at[slot].set(key_data),
            step=state.step,
        )

    return admit_fn


# ------------------------------------------------------------- host engine


@dataclasses.dataclass
class Finished:
    seq_id: int
    tokens: np.ndarray  # (n_gen,) generated tokens (incl. prefill sample)


class ServeEngine:
    """Host-side continuous batching on top of the compiled pieces.

    The host owns only the admission queue and harvest bookkeeping; decode
    runs in compiled chunks of ``scfg.decode_chunk`` steps, and every
    admission reuses one compiled ``admit_fn``.  ``compile_counts()``
    exposes the jit caches so benches/tests can assert zero recompilation
    after warmup.
    """

    def __init__(self, cfg: ModelConfig, scfg: ServeConfig, params: PyTree,
                 prompt_len: int, key: Optional[jax.Array] = None,
                 telemetry=None):
        if prompt_len < 1:
            raise ValueError(f"prompt_len={prompt_len} must be >= 1")
        if scfg.cache_len < prompt_len + scfg.max_new:
            # an undersized cache wraps its write index (pos % slots in
            # attention.py) and silently corrupts the oldest context
            raise ValueError(
                f"cache_len={scfg.cache_len} < prompt_len + max_new = "
                f"{prompt_len + scfg.max_new}; size the per-slot cache to "
                "hold the full prompt plus the generation budget"
            )
        self.cfg, self.scfg, self.params = cfg, scfg, params
        self.prompt_len = prompt_len
        key = jax.random.key(0) if key is None else key
        self._host_key, state_key = jax.random.split(key)
        self.state = init_decode_state(cfg, scfg, state_key)
        decode_fn = make_decode_fn(cfg, scfg)
        self._chunk = jax.jit(
            lambda p, s: run_scan(decode_fn, p, s, scfg.decode_chunk)
        )
        self._admit = jax.jit(make_admit_fn(cfg, scfg, prompt_len))
        self.finished: List[Finished] = []
        self._queue: List[Tuple[int, np.ndarray, int]] = []
        self._next_id = 0
        # Telemetry (DESIGN.md §14): an optional repro.obs.TelemetrySink.
        # Strictly host-side — events are emitted from the queue bookkeeping
        # between compiled chunks (submit / admit / harvest / chunk
        # boundaries), so telemetry=None is byte-identical behaviour and a
        # sink can never add a compiled program (compile_counts() stays 2).
        self._sink = telemetry
        self._t_submit: Dict[int, float] = {}
        self._pending_admits: List[Tuple[int, int]] = []

    # -- queue ------------------------------------------------------------

    def submit(self, prompt: np.ndarray, gen_target: int) -> int:
        """Queue one prompt (``(prompt_len,)`` int tokens); returns seq id."""
        prompt = np.asarray(prompt, np.int32)
        if prompt.shape != (self.prompt_len,):
            raise ValueError(
                f"prompt must be ({self.prompt_len},), got {prompt.shape}"
            )
        if not 1 <= gen_target <= self.scfg.max_new:
            raise ValueError(
                f"gen_target={gen_target} must be in [1, {self.scfg.max_new}]"
            )
        seq_id = self._next_id
        self._next_id += 1
        self._queue.append((seq_id, prompt, gen_target))
        if self._sink is not None:
            self._t_submit[seq_id] = time.perf_counter()
            self._sink.emit(
                "serve_submit", seq_id=seq_id, gen_target=gen_target,
                queue_depth=len(self._queue),
            )
        return seq_id

    # -- engine steps ------------------------------------------------------

    def _refill(self) -> None:
        # free = unoccupied (seq_id < 0), not merely inactive: stopped slots
        # keep their seq_id until harvest and must not be admitted over
        free = int((np.asarray(self.state.seq_ids) < 0).sum())
        n = min(free, len(self._queue))
        admitted = []
        for _ in range(n):
            seq_id, prompt, tgt = self._queue.pop(0)
            self._host_key, sub = jax.random.split(self._host_key)
            with obs_tracing_lib.annotate("serve.admit"):
                self.state = self._admit(
                    self.params, self.state, jnp.asarray(prompt)[None],
                    jnp.int32(tgt), jnp.int32(seq_id), fresh_key_data(sub, 1)[0],
                )
            # budget-1 sequences finish at admission (prefill sampled their
            # only token); harvest them below like any stopped slot
            admitted.append((seq_id, len(self._queue)))
        if self._sink is not None:
            # TTFT is emitted from _harvest, right after its done-mask fetch
            # — a sync on the same dependency chain as the wave's prefills,
            # which the telemetry-off path pays identically.  Blocking here
            # instead would serialise admit dispatches the off path
            # pipelines, and the gap between the two sync points is one
            # fused elementwise op on (batch,) arrays.
            self._pending_admits.extend(admitted)
        self._harvest()

    def _harvest(self) -> None:
        """Collect slots that stopped (budget/EOS) and mark them free."""
        st = self.state
        done = np.asarray(~st.active & (st.seq_ids >= 0) & (st.n_gen > 0))
        if self._sink is not None and self._pending_admits:
            # the done-mask fetch above blocked on the admit wave's prefills
            # — the admitted sequences' first tokens exist as of now
            now = time.perf_counter()
            occupancy = int((np.asarray(st.seq_ids) >= 0).sum())
            for seq_id, depth in self._pending_admits:
                self._sink.emit(
                    "serve_admit", seq_id=seq_id,
                    ttft_s=round(now - self._t_submit.get(seq_id, now), 6),
                    queue_depth=depth, occupancy=occupancy,
                )
            self._pending_admits = []
        if not done.any():
            return
        out = np.asarray(st.out_tokens)
        n_gen = np.asarray(st.n_gen)
        ids = np.asarray(st.seq_ids)
        for slot in np.nonzero(done)[0]:
            self.finished.append(
                Finished(int(ids[slot]), out[slot, : int(n_gen[slot])].copy())
            )
            if self._sink is not None:
                seq_id = int(ids[slot])
                now = time.perf_counter()
                t_sub = self._t_submit.pop(seq_id, now)
                self._sink.emit(
                    "serve_finish", seq_id=seq_id,
                    n_tokens=int(n_gen[slot]),
                    latency_s=round(now - t_sub, 6),
                )
        mask = jnp.asarray(done)
        self.state = dataclasses.replace(
            st, seq_ids=jnp.where(mask, -1, st.seq_ids),
            n_gen=jnp.where(mask, 0, st.n_gen),
        )

    def run(self, drain: bool = False) -> List[Finished]:
        """Drive queue + slots to completion; returns finished sequences in
        completion order.

        ``drain=True`` only admits at wave boundaries (every slot idle) —
        the drain-and-refill contrast arm for the continuous-batching
        benches: same compiled admit/decode programs, worse scheduling."""
        self._maybe_refill(drain)
        while self._queue or bool(np.any(np.asarray(self.state.active))):
            if bool(np.any(np.asarray(self.state.active))):
                if self._sink is None:
                    with obs_tracing_lib.annotate("serve.decode_chunk"):
                        self.state = self._chunk(self.params, self.state)
                else:
                    self._timed_chunk()
            self._harvest()
            self._maybe_refill(drain)
        return self.finished

    def _timed_chunk(self) -> None:
        """One decode chunk with a ``serve_chunk`` event: chunk wall time,
        exact tokens generated (n_gen delta), tok/s, slot occupancy and
        queue depth.  Only runs with a sink attached — the telemetry-off
        path never pays the extra sync."""
        n_before, active_arr = jax.device_get(
            (self.state.n_gen, self.state.active)
        )
        active = int(active_arr.sum())
        t0 = time.perf_counter()
        with obs_tracing_lib.annotate("serve.decode_chunk"):
            self.state = self._chunk(self.params, self.state)
        n_after = jax.device_get(self.state.n_gen)
        dt = time.perf_counter() - t0
        tokens = int((n_after - n_before).sum())
        self._sink.emit(
            "serve_chunk", steps=self.scfg.decode_chunk, tokens=tokens,
            dt_s=round(dt, 6), tok_s=round(tokens / max(dt, 1e-9), 1),
            active_slots=active, batch=self.scfg.batch,
            queue_depth=len(self._queue),
        )

    def _maybe_refill(self, drain: bool) -> None:
        if drain and bool(np.any(np.asarray(self.state.active))):
            self._harvest()
            return
        self._refill()

    def reset(self, key: Optional[jax.Array] = None) -> None:
        """Fresh state/queue/results; compiled programs are kept (benches
        time repeat traffic without re-paying compilation)."""
        if key is not None:
            self._host_key, key = jax.random.split(key)
        else:
            self._host_key, key = jax.random.split(self._host_key)
        self.state = init_decode_state(self.cfg, self.scfg, key)
        self.finished = []
        self._queue = []
        self._next_id = 0
        self._t_submit = {}
        self._pending_admits = []

    # -- introspection -----------------------------------------------------

    def compile_counts(self) -> Dict[str, int]:
        """Compiled-program counts per jitted entry point (warmup leaves
        exactly one each; continuous traffic must not add more)."""
        return {
            "decode_chunk": _jit_cache_size(self._chunk),
            "admit": _jit_cache_size(self._admit),
        }


def _jit_cache_size(fn) -> int:
    try:
        return int(fn._cache_size())
    except Exception:  # pragma: no cover - jax-version dependent
        return -1

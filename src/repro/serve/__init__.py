"""Scan-compiled continuous-batching serving engine (DESIGN.md §13)."""

from repro.serve.engine import (
    DecodeState,
    Finished,
    ServeConfig,
    ServeEngine,
    init_decode_state,
    make_admit_fn,
    make_decode_fn,
    run_scan,
    run_while,
)
from repro.serve.sampling import fresh_key_data, sample_tokens

__all__ = [
    "DecodeState",
    "Finished",
    "ServeConfig",
    "ServeEngine",
    "init_decode_state",
    "make_admit_fn",
    "make_decode_fn",
    "run_scan",
    "run_while",
    "fresh_key_data",
    "sample_tokens",
]

"""Per-slot token sampling for the serving engine.

Greedy (``temperature == 0``) is a *static* Python branch producing exactly
the legacy host loop's ``jnp.argmax(logits[:, 0], axis=-1)`` — the parity
oracle contract — and leaves the key stream untouched, so greedy programs
carry no PRNG ops.  Temperature sampling draws one categorical per slot from
that slot's own key (vmapped split + draw), so slots are statistically
independent no matter how they were admitted or refilled.

Keys live in the :class:`~repro.serve.engine.DecodeState` as **raw**
``uint32`` key data (``jax.random.key_data`` layout) rather than typed keys:
slot refill scatters key rows with the same gather/scatter arithmetic as
every other per-slot buffer, and checkpoint-style tooling can treat the
state as a plain array pytree.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

__all__ = ["fresh_key_data", "sample_tokens"]


def fresh_key_data(key: jax.Array, batch: int) -> jax.Array:
    """(B, key_words) uint32 — one independent stream per slot."""
    return jax.random.key_data(jax.random.split(key, batch))


def sample_tokens(
    logits: jax.Array,  # (B, 1, V)
    key_data: jax.Array,  # (B, key_words) uint32 per-slot streams
    temperature: float,  # static; 0.0 = greedy
) -> Tuple[jax.Array, jax.Array]:
    """-> (tokens (B,) int32, advanced key_data)."""
    if temperature == 0.0:
        return jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32), key_data

    def draw(kd, row):
        nxt, use = jax.random.split(jax.random.wrap_key_data(kd))
        tok = jax.random.categorical(use, row / temperature)
        return jax.random.key_data(nxt), tok.astype(jnp.int32)

    new_kd, toks = jax.vmap(draw)(key_data, logits[:, 0])
    return toks, new_kd
